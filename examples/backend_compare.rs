//! Fig. 5 right, as a program: the same Algorithm 2, four communication
//! backends, zero changes to the algorithm code (§3's FooPar-X claim).
//!
//! The stock OpenMPI java bindings and MPJ-Express use a Θ(p) reduction
//! (§6); watch them fall behind the patched Θ(log p) backend as p grows.
//!
//! Run with:  cargo run --release --example backend_compare

use std::sync::Arc;

use foopar::comm::backend::{registry, Backend, BackendProfile};
use foopar::config::MachineConfig;
use foopar::experiments::fig5;

fn main() {
    let machine = MachineConfig::horseshoe6();
    let n = 5_040;
    println!(
        "DNS MMM on {} (rate {:.2} GF/s/core), n = {n}, modeled:",
        machine.name,
        machine.rate / 1e9
    );
    println!("{:>14} {:>6} {:>10} {:>8}", "backend", "p", "T_P (s)", "E");
    for profile in BackendProfile::all() {
        let backend = registry::by_name(profile.name).expect("built-in backend registered");
        for p in [8usize, 64, 216, 512] {
            let row = fig5::run_point(&machine, &backend, n, p, false);
            println!(
                "{:>14} {:>6} {:>10.3} {:>7.1}%",
                backend.name(),
                p,
                row.t_parallel,
                row.efficiency * 100.0
            );
        }
    }

    // The crossover claim: at p=512 the tree-reduce backend must beat the
    // linear-reduce ones.
    let arc = |b: BackendProfile| -> Arc<dyn Backend> { Arc::new(b) };
    let fixed = fig5::run_point(&machine, &arc(BackendProfile::openmpi_fixed()), n, 512, false);
    let stock = fig5::run_point(&machine, &arc(BackendProfile::openmpi_stock()), n, 512, false);
    let mpj = fig5::run_point(&machine, &arc(BackendProfile::mpj_express()), n, 512, false);
    assert!(fixed.efficiency > stock.efficiency);
    assert!(stock.efficiency > mpj.efficiency); // mpj adds serialization costs
    println!(
        "\nat p=512: openmpi-fixed {:.1}% > openmpi-stock {:.1}% > mpj-express {:.1}%  (paper §6 ordering)",
        fixed.efficiency * 100.0,
        stock.efficiency * 100.0,
        mpj.efficiency * 100.0
    );
    println!("backend_compare OK");
}
