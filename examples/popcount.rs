//! The paper's §3.2 introductory example, verbatim.
//!
//! ```scala
//! def ones(i: Int): Int = i.toBinaryString.count(_ == '1')
//! val seq    = 0 to worldSize - 3
//! val counts = seq mapD ones
//! println(globalRank + ":" + counts)
//! ```
//!
//! Every process generates the sequence (lazily — Fig. 2), only the
//! owning processes perform the mapD, and the printed output is
//! `rank:Some(count)` on owners and `rank:None` elsewhere (Fig. 3,
//! arbitrary order).
//!
//! Run with:  cargo run --release --example popcount

use foopar::data::dseq::DistSeq;
use foopar::Runtime;

fn ones(i: usize) -> u32 {
    (i as u32).count_ones() // i.toBinaryString.count(_ == '1')
}

fn main() {
    let world = 8;
    let res = Runtime::builder()
        .world(world)
        .backend("shmem")
        .machine("local")
        .run(|ctx| {
            // val seq = 0 to worldSize - 3  (i.e. worldSize-2 elements)
            let seq = DistSeq::range(ctx, ctx.world - 2, |i| i);
            // val counts = seq mapD ones
            let counts = seq.map_d(ones);
            // println(globalRank + ":" + counts)
            let shown = match counts.local() {
                Some(c) => format!("Some({c})"),
                None => "None".to_string(),
            };
            println!("{}:{}", ctx.rank, shown);
            counts.into_local()
        })
        .expect("popcount runtime");

    // Fig. 3: ranks 0..worldSize-2 hold Some(popcount), the rest None.
    for (rank, c) in res.results.iter().enumerate() {
        if rank < world - 2 {
            assert_eq!(*c, Some(ones(rank)));
        } else {
            assert_eq!(*c, None);
        }
    }
    println!("popcount OK");
}
