//! End-to-end driver (the repo's headline example): Algorithm 2 —
//! matrix-matrix multiplication on a Grid3D — through the full stack:
//!
//!   rust SPMD coordinator  →  distributed collections  →  per-rank
//!   block GEMM executed as the AOT-compiled JAX/Pallas artifact via
//!   PJRT  →  result verified against the sequential oracle.
//!
//! Then the same algorithm is re-run *modeled* at the paper's scale
//! (n = 40320, p = 512) and the Fig. 5 headline efficiency is printed.
//!
//! Run with:  cargo run --release --example matmul_dns
//! (needs `make artifacts` + the `pjrt` feature for the PJRT path;
//! falls back to native gemm)

use std::sync::Arc;

use foopar::algos::{collect_c, matmul, seq, MatmulSpec, PlanMode, Schedule};
use foopar::analysis;
use foopar::comm::backend::registry;
use foopar::config::MachineConfig;
use foopar::experiments::fig5;
use foopar::matrix::block::BlockSource;
use foopar::runtime::compute::Compute;
use foopar::runtime::engine::EngineServer;
use foopar::Runtime;

fn main() {
    // ---------- real mode: q=2 grid, 64x64 blocks, PJRT kernels ----------
    let q = 2;
    let b = 64;
    let n = q * b;
    let (comp, path) = match EngineServer::start_default() {
        Ok(srv) => {
            let h = Arc::new(srv.handle());
            std::mem::forget(srv); // keep the device server for the process
            (Compute::Pjrt(h), "pjrt (AOT pallas artifact)")
        }
        Err(e) => {
            eprintln!("note: PJRT unavailable ({e:#}), using native gemm");
            (Compute::Native, "native gemm")
        }
    };
    println!("real mode: n={n}, p={}, per-block path: {path}", q * q * q);

    let a = BlockSource::real(b, 0xA);
    let bm = BlockSource::real(b, 0xB);
    let res = Runtime::builder()
        .world(q * q * q)
        .backend("shmem")
        .machine("local")
        .run(|ctx| {
            let spec = MatmulSpec::new(&comp, q, &a, &bm)
                .mode(PlanMode::Forced(Schedule::DnsBlocking));
            matmul(ctx, spec)
        })
        .expect("matmul_dns runtime");
    let c = collect_c(&res.results, q, b);
    let want = seq::matmul_seq(&a.assemble(q), &bm.assemble(q));
    let diff = c.max_abs_diff(&want);
    println!("  verified vs sequential oracle: max|Δ| = {diff:.2e}");
    assert!(diff < 1e-2, "parallel result diverged");
    println!("  wall: {:.3}s, virtual T_P: {:.6}s", res.wall.as_secs_f64(), res.t_parallel);

    // ---------- modeled mode: the paper's scale ----------
    let machine = MachineConfig::carver();
    println!("\nmodeled mode (Fig. 5 headline, Carver):");
    let (row, vs_peak) = fig5::headline(&machine);
    println!(
        "  n={} p={}: T_P={:.2}s  {:.2} TFlop/s  E={:.1}% of empirical peak ({:.1}% of theoretical)",
        row.n,
        row.p,
        row.t_parallel,
        row.tflops,
        row.efficiency * 100.0,
        vs_peak * 100.0
    );
    println!("  paper §6: 4.84 TFlop/s, 93.7% / 88.8%");

    // speedup curve snippet
    println!("\nspeedup at n=20160 (modeled, Carver):");
    let fixed = registry::by_name("openmpi-fixed").expect("built-in backend");
    for p in [8usize, 64, 512] {
        let r = fig5::run_point(&machine, &fixed, 20_160, p, false);
        let ts = analysis::ts_n3(r.n, &fig5::model(&machine));
        println!(
            "  p={p:>3}: T_P={:.2}s  S={:.1}  E={:.1}%",
            r.t_parallel,
            analysis::speedup(ts, r.t_parallel),
            r.efficiency * 100.0
        );
    }
    println!("matmul_dns OK");
}
