//! Failure path of a real multi-process run: a worker **dying
//! mid-collective** must fail the run promptly — with the dead rank's
//! exit status and the stranded receive's (rank, src, tag) — instead of
//! every surviving process burning the 60 s deadlock oracle.
//!
//! Run with:  cargo run --release --example tcp_failfast
//!
//! Like every `transport("tcp")` program, workers re-exec this `main`
//! (see `comm::transport::launch`).  Rank 2 exits between frames — a
//! clean socket close, the hard case no torn-frame detector can see —
//! while every other rank blocks in an allreduce that can never
//! complete.  The parent's liveness watchdog must (a) poison the local
//! transport so rank 0's blocked `wait()` panics with the root cause,
//! and (b) reap the surviving workers so they don't hang as orphans.

use std::time::{Duration, Instant};

use foopar::comm::cost::CostParams;
use foopar::comm::group::Group;
use foopar::comm::transport::launch;
use foopar::Runtime;

const WORLD: usize = 4;

fn main() {
    let is_worker = launch::child_rank().is_some();
    let t0 = Instant::now();
    let r = std::panic::catch_unwind(|| {
        Runtime::builder()
            .world(WORLD)
            .cost(CostParams::free())
            .transport("tcp")
            .run(|ctx| {
                let g = Group::world(ctx);
                if ctx.rank == 2 {
                    // die mid-collective with a clean socket close
                    std::process::exit(3);
                }
                g.allreduce(ctx.rank as u64, |a, b| a + b)
            })
    });

    if is_worker {
        // Surviving workers normally never get here — the parent's
        // watchdog kills them once rank 2's death is detected.  If one
        // does unwind (or its run returns Err) on its own, exit non-zero
        // so the parent's accounting stays truthful.
        let clean = matches!(&r, Ok(run) if run.is_ok());
        std::process::exit(if clean { 0 } else { 101 });
    }

    // Parent (rank 0): the run must have failed, promptly, blaming rank 2.
    let elapsed = t0.elapsed();
    let msg = match r {
        Ok(Ok(_)) => panic!("run succeeded despite rank 2 dying mid-collective"),
        Ok(Err(e)) => format!("{e:#}"),
        Err(e) => e
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into()),
    };
    // The watchdog pins the root cause before reaping the survivors, so
    // the failure must name rank 2 — never a killed sibling.
    assert!(msg.contains("rank 2"), "failure does not name the dead worker: {msg}");
    assert!(
        elapsed < Duration::from_secs(30),
        "failure was not prompt: {elapsed:?} (deadlock oracle is 60 s)"
    );
    println!(
        "worker death surfaced in {:.2}s with: {}",
        elapsed.as_secs_f64(),
        msg.lines().next().unwrap_or("")
    );
    println!("tcp_failfast OK");
}
