//! The hierarchical hybrid transport, end to end: a world of threads
//! grouped into "nodes" — same-node messages cross shared-memory
//! mailboxes, cross-node messages cross TCP loopback — with the `hier`
//! backend upgrading collectives to two-level (leader-staged) schedules
//! whenever the virtual-clock cost model prices them cheaper.
//!
//! Three claims, demonstrated in order:
//!
//! 1. the cost model picks flat vs two-level per world *shape*, from
//!    topology alone (no negotiation messages);
//! 2. unchanged algorithm code (Algorithm 2, DNS matrix multiplication)
//!    runs on the hybrid transport + `hier` backend bit-correct — the
//!    paper's FooPar-X portability claim extended to a transport the
//!    original never had;
//! 3. the two-level allgather's modeled T_P beats the flat ring on a
//!    hierarchical world.
//!
//! CLI equivalent:  repro mmm --p 8 --transport hybrid --ranks-per-node 4 --backend hier
//!
//! Run with:  cargo run --release --example hybrid_hierarchy

use foopar::algos::{collect_c, matmul, seq, MatmulSpec, PlanMode, Schedule};
use foopar::comm::cost::{CostParams, HierCost};
use foopar::comm::group::Group;
use foopar::matrix::block::BlockSource;
use foopar::runtime::compute::Compute;
use foopar::Runtime;

fn main() {
    // 1. The strategy choice is a pure function of (link params, world
    //    shape): every rank computes it locally and agrees.
    let link = HierCost::hierarchical(CostParams::qdr_infiniband());
    for (p, nodes, max_node) in [(8usize, 2usize, 4usize), (8, 8, 1), (8, 1, 8)] {
        println!(
            "model (p={p}, {nodes} nodes, largest {max_node}): two-level tree {}, \
             allgather {}, barrier {}",
            link.prefer_two_level_tree(p, nodes, max_node),
            link.prefer_two_level_allgather(p, nodes, max_node),
            link.prefer_two_level_barrier(p, nodes, max_node),
        );
    }

    // 2. Real-mode DNS MMM (q=2 grid, 16x16 blocks) on the hybrid
    //    transport, verified against the sequential oracle.
    let (q, b) = (2usize, 16usize);
    let a = BlockSource::real(b, 7);
    let bm = BlockSource::real(b, 8);
    let res = Runtime::builder()
        .world(q * q * q)
        .transport("hybrid")
        .ranks_per_node(4)
        .backend("hier")
        .cost(CostParams::qdr_infiniband())
        .run(|ctx| {
            if ctx.rank == 0 {
                let t = ctx.topology();
                println!(
                    "topology: {} ranks on {} nodes {:?} — shmem within, TCP across",
                    t.world(),
                    t.num_nodes(),
                    t.node_sizes()
                );
            }
            let spec = MatmulSpec::new(&Compute::Native, q, &a, &bm)
                .mode(PlanMode::Forced(Schedule::DnsBlocking));
            matmul(ctx, spec)
        })
        .expect("hybrid runtime");
    let c = collect_c(&res.results, q, b);
    let want = seq::matmul_seq(&a.assemble(q), &bm.assemble(q));
    let diff = c.max_abs_diff(&want);
    println!("hybrid DNS (real, q={q}): max|Δ| vs sequential = {diff:.2e}");
    assert!(diff < 1e-3, "hybrid transport changed results");

    // 3. Modeled T_P: the flat ring pays an inter-node hop on (nearly)
    //    every round; the two-level schedule crosses nodes once.
    let t_p = |backend: &str| {
        Runtime::builder()
            .world(8)
            .ranks_per_node(4)
            .backend(backend)
            .cost(CostParams::qdr_infiniband())
            .run(|ctx| {
                let g = Group::world(ctx);
                let got = g.allgather(vec![g.index() as u8; 1024]);
                assert_eq!(got.len(), 8);
            })
            .expect("modeled runtime")
            .t_parallel
    };
    let flat = t_p("openmpi-fixed");
    let hier = t_p("hier");
    println!(
        "modeled 1 KiB allgather, world 8 on 2x4:  flat ring T_P={:.2} µs  \
         two-level T_P={:.2} µs  ({:.2}x)",
        flat * 1e6,
        hier * 1e6,
        flat / hier
    );
    assert!(hier < flat, "two-level allgather must win on a hierarchical world");

    println!("hybrid_hierarchy OK");
}
