//! A user-defined communication backend, end to end: implement
//! [`Backend`] (and optionally [`Collectives`]), register it under a
//! name, select it with `Runtime::builder().backend("…")`, and run
//! Algorithm 2 (DNS matrix multiplication) on it — **zero changes** to
//! the algorithm, which is exactly the paper's FooPar-X portability
//! claim, now open to backends the framework has never heard of.
//!
//! The example backend models an RDMA-style interconnect module:
//! recursive-doubling all-gathers, tree reductions, and a software stack
//! that halves start-up overhead but pays a small per-byte registration
//! cost.
//!
//! Run with:  cargo run --release --example custom_backend

use std::sync::Arc;

use foopar::algos::{collect_c, matmul, seq, MatmulSpec, PlanMode, Schedule};
use foopar::comm::backend::{registry, AllGatherAlgo, BcastAlgo, ReduceAlgo};
use foopar::comm::collectives::StandardCollectives;
use foopar::comm::cost::CostParams;
use foopar::matrix::block::BlockSource;
use foopar::runtime::compute::Compute;
use foopar::{Backend, Collectives, Runtime};

/// An RDMA-flavoured backend: different collective algorithms *and*
/// different cost shaping than any built-in profile.
struct RdmaSim;

impl Backend for RdmaSim {
    fn name(&self) -> &str {
        "rdma-sim"
    }

    fn collectives(&self) -> Arc<dyn Collectives> {
        // Reuse the standard strategy set with a non-default algorithm
        // mix; a backend could equally return a hand-written
        // `impl Collectives`.
        Arc::new(StandardCollectives {
            bcast: BcastAlgo::Binomial,
            reduce: ReduceAlgo::Binomial,
            allgather: AllGatherAlgo::RecursiveDoubling,
        })
    }

    fn cost(&self, machine: CostParams) -> CostParams {
        // kernel-bypass start-up, zero-copy transfers
        CostParams::new(machine.ts * 0.5, machine.tw * 0.9)
    }
}

fn main() {
    // 1. Register the backend — from here on it is addressable by name
    //    anywhere in the process, exactly like the built-ins.
    registry::register(Arc::new(RdmaSim));
    println!("registered backends: {}", registry::names().join(", "));
    assert!(registry::by_name("rdma-sim").is_some());

    // 2. Real-mode DNS MMM on the custom backend, verified against the
    //    sequential oracle (q=2 grid, 16x16 blocks, native gemm).
    let (q, b) = (2, 16);
    let a = BlockSource::real(b, 7);
    let bm = BlockSource::real(b, 8);
    let res = Runtime::builder()
        .world(q * q * q)
        .backend("rdma-sim")
        .machine("local")
        .run(|ctx| {
            let spec = MatmulSpec::new(&Compute::Native, q, &a, &bm)
                .mode(PlanMode::Forced(Schedule::DnsBlocking));
            matmul(ctx, spec)
        })
        .expect("custom backend runtime");
    let c = collect_c(&res.results, q, b);
    let want = seq::matmul_seq(&a.assemble(q), &bm.assemble(q));
    let diff = c.max_abs_diff(&want);
    println!("rdma-sim DNS (real, q={q}): max|Δ| vs sequential = {diff:.2e}");
    assert!(diff < 1e-3, "custom backend changed results");

    // 3. Modeled comparison at scale: same algorithm, two backends — the
    //    lower start-up overhead must show up in virtual time.
    let (n, p, qq) = (20_160usize, 512usize, 8usize);
    let pa = BlockSource::proxy(n / qq, 1);
    let pb = BlockSource::proxy(n / qq, 2);
    let comp = Compute::Modeled { rate: 1e10 };
    let t = |backend: &str| {
        Runtime::builder()
            .world(p)
            .backend(backend)
            .machine("carver")
            .run(|ctx| {
                let spec = MatmulSpec::new(&comp, qq, &pa, &pb)
                    .mode(PlanMode::Forced(Schedule::DnsBlocking));
                matmul(ctx, spec).t_local
            })
            .expect("modeled runtime")
            .t_parallel
    };
    let t_rdma = t("rdma-sim");
    let t_fixed = t("openmpi-fixed");
    println!("modeled DNS n={n} p={p}:  rdma-sim T_P={t_rdma:.4}s  openmpi-fixed T_P={t_fixed:.4}s");
    assert!(t_rdma < t_fixed, "halved t_s must win at this scale");

    println!("custom_backend OK");
}
