//! Algorithm 2 (DNS matrix multiplication) across **OS processes**: the
//! same DNS plan that runs on in-process shared memory runs here
//! over the TCP transport — 8 processes (q=2 grid) on loopback, spawned
//! by the re-exec launcher, with zero changes to algorithm or collective
//! code.  That is the paper's distributed-memory portability claim,
//! demonstrated end to end.
//!
//! Run with:  cargo run --release --example matmul_dns_tcp
//!
//! The parent process becomes rank 0 and re-execs this binary once per
//! remaining rank (`FOOPAR_TCP_RANK` set); worker processes re-run
//! `main`, skip the parent-only baseline, meet the parent at the
//! rendezvous socket, compute their grid cell, and exit.  Rank 0 gathers
//! the C blocks with an ordinary group collective and verifies the
//! product against (a) the sequential oracle and (b) the in-process
//! shmem run — bit for bit.

use foopar::algos::{collect_c, matmul, seq, MatmulSpec, PlanMode, Schedule};
use foopar::comm::group::Group;
use foopar::comm::transport::launch;
use foopar::matrix::block::{Block, BlockSource};
use foopar::matrix::dense::Mat;
use foopar::runtime::compute::Compute;
use foopar::Runtime;

fn main() {
    let q = 2usize;
    let b = 32usize;
    let world = q * q * q; // 8 ranks -> 8 OS processes over TCP loopback
    let child = launch::child_rank();

    let a = BlockSource::real(b, 0xA);
    let bm = BlockSource::real(b, 0xB);

    // ---- in-process shmem baseline (parent only) ----
    let baseline = if child.is_none() {
        println!("shmem baseline: n={}, p={world}, threads over shared memory", q * b);
        let res = Runtime::builder()
            .world(world)
            .backend("openmpi-fixed")
            .machine("local")
            .run(|ctx| {
                let spec = MatmulSpec::new(&Compute::Native, q, &a, &bm)
                    .mode(PlanMode::Forced(Schedule::DnsBlocking));
                matmul(ctx, spec)
            })
            .expect("shmem baseline");
        Some(collect_c(&res.results, q, b))
    } else {
        None
    };

    // ---- the same algorithm, unchanged, across OS processes ----
    if child.is_none() {
        println!("tcp run: spawning {} worker processes (rank 0 = this process)", world - 1);
    }
    let res = Runtime::builder()
        .world(world)
        .backend("openmpi-fixed")
        .machine("local")
        .transport("tcp")
        .run(|ctx| {
            let spec = MatmulSpec::new(&Compute::Native, q, &a, &bm)
                .mode(PlanMode::Forced(Schedule::DnsBlocking));
            let out = matmul(ctx, spec);
            // each process holds only its own C block; gather them to
            // world rank 0 with an ordinary collective for verification
            let g = Group::world(ctx);
            g.gather(0, out.c_block)
        })
        .expect("tcp multi-process run");

    if child.is_some() {
        // worker processes are done once the run completes
        return;
    }

    // ---- rank 0 (the parent): assemble and verify ----
    let gathered: Vec<Option<(usize, usize, Block)>> = res
        .results
        .into_iter()
        .next()
        .expect("rank 0 result")
        .expect("rank 0 is the gather root");
    let mut c = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for (i, j, blk) in gathered.into_iter().flatten() {
        c.set_block(i, j, &blk.materialize());
        seen += 1;
    }
    assert_eq!(seen, q * q, "expected one C block per (i, j)");

    let want = seq::matmul_seq(&a.assemble(q), &bm.assemble(q));
    let vs_oracle = c.max_abs_diff(&want);
    let vs_shmem = c.max_abs_diff(&baseline.expect("parent computed baseline"));
    println!(
        "tcp ({} processes): max|Δ| vs sequential oracle = {vs_oracle:.2e}, \
         vs shmem run = {vs_shmem:.2e}, wall = {:.3}s, virtual T_P = {:.6}s",
        world,
        res.wall.as_secs_f64(),
        res.t_parallel
    );
    assert!(vs_oracle < 1e-2, "tcp product diverged from the oracle");
    assert_eq!(vs_shmem, 0.0, "tcp product must match the shmem run bit for bit");
    println!("matmul_dns_tcp OK");
}
