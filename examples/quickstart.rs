//! Quickstart: the FooPar programming model in one file.
//!
//! Run with:  cargo run --release --example quickstart
//!
//! Demonstrates the SPMD + distributed-collections style of §3: every
//! rank runs this same code; all communication happens through group
//! operations on `DistSeq` — no sends, no receives, no locks.  The
//! world is configured through the `Runtime` builder: pick a world
//! size, a communication backend (by registry name), and a machine.

use foopar::data::dseq::DistSeq;
use foopar::Runtime;

fn main() {
    let p = 8;

    // Runtime::builder() configures the SPMD world: `world` ranks over an
    // in-process fabric, collectives dispatched through the named
    // backend, message costs from the named machine.  The closure is the
    // SPMD program.
    let result = Runtime::builder()
        .world(p)
        .backend("shmem")
        .machine("local")
        .run(|ctx| {
            // A distributed sequence: element i lives on rank i (lazy: the
            // generator runs only on the owner).
            let seq = DistSeq::range(ctx, ctx.world, |i| (i + 1) as i64);

            // map, then reduce with an associative operator: the classic
            // chained functional style, fully parallel.
            let sum_of_squares = seq.map_d(|v| v * v).all_reduce_d(|a, b| a + b);

            // every rank got the result (allReduce); do a rank-local check
            let expect: i64 = (1..=ctx.world as i64).map(|v| v * v).sum();
            assert_eq!(sum_of_squares, Some(expect));
            sum_of_squares.unwrap()
        })
        .expect("quickstart runtime");

    println!("sum of squares over {p} ranks: {}", result.results[0]);
    println!("virtual parallel time: {:.2} µs", result.t_parallel * 1e6);
    println!(
        "messages on the fabric: {}",
        result.metrics.iter().map(|m| m.msgs_sent).sum::<u64>()
    );

    // Second pattern: a cyclic shift pipeline (Table 1's shiftD).  A
    // built runtime is reusable across runs.
    let rt = Runtime::builder()
        .world(p)
        .backend("shmem")
        .machine("local")
        .build()
        .expect("quickstart runtime");
    let shifted = rt.run(|ctx| {
        DistSeq::range(ctx, ctx.world, |i| i as u64)
            .shift_d(3)
            .into_local()
            .unwrap()
    });
    println!("after shiftD(3): {:?}", shifted.results);
    assert_eq!(shifted.results[3], 0);

    println!("quickstart OK");
}
