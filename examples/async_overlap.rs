//! Non-blocking collectives in action: handle-based `*_start` forms and
//! the overlap-aware clock rule (`max(T_comm, T_comp)` per region).
//!
//! Run with:  cargo run --release --example async_overlap
//!
//! Part 1 shows the primitive: a `shift_start` whose wire time hides
//! under interleaved compute.  Part 2 runs blocking vs pipelined Cannon
//! and DNS (modeled, comm-visible network) and prints the virtual `T_P`
//! drop plus the comm time the pipeline hid.

use foopar::algos::{matmul, MatmulSpec, PlanMode, Schedule};
use foopar::comm::cost::CostParams;
use foopar::comm::group::Group;
use foopar::matrix::block::BlockSource;
use foopar::runtime::compute::Compute;
use foopar::Runtime;

fn main() -> foopar::Result<()> {
    // ---- Part 1: the primitive -------------------------------------
    // ts = 1 ms, tw = 0: a shift costs 1 ms of virtual time; the rank
    // computes 3 ms while it is in flight.
    let cost = CostParams::new(1.0e-3, 0.0);
    let blocking = Runtime::builder().world(4).cost(cost).run(|ctx| {
        let g = Group::world(ctx);
        let _v = g.shift(1, ctx.rank as u64);
        ctx.advance_compute(3.0e-3, 0.0);
        ctx.now()
    })?;
    let overlapped = Runtime::builder().world(4).cost(cost).run(|ctx| {
        let g = Group::world(ctx);
        let h = g.shift_start(1, ctx.rank as u64); // posted immediately
        ctx.advance_compute(3.0e-3, 0.0); // overlaps the wire time
        let _v = h.wait(); // clock = max(comp, comm)
        ctx.now()
    })?;
    println!("shift + 3ms compute, blocking:   T_P = {:.3} ms", blocking.t_parallel * 1e3);
    println!("shift_start … wait, overlapped:  T_P = {:.3} ms", overlapped.t_parallel * 1e3);

    // ---- Part 2: pipelined Cannon and DNS --------------------------
    // Modeled mode on a gigabit-class network where block transfers are
    // clearly visible next to the GEMM.
    let machine = CostParams::new(5.0e-5, 1.0e-8);
    let comp = Compute::Modeled { rate: 1e10 };

    let (q2, b2) = (4usize, 256usize);
    let a = BlockSource::proxy(b2, 1);
    let b = BlockSource::proxy(b2, 2);
    let run_cannon = |pipelined: bool| {
        Runtime::builder().world(q2 * q2).cost(machine).run(|ctx| {
            let schedule =
                if pipelined { Schedule::CannonPipelined } else { Schedule::CannonBlocking };
            let spec =
                MatmulSpec::new(&comp, q2, &a, &b).mode(PlanMode::Forced(schedule));
            matmul(ctx, spec).t_local
        })
    };
    let cb = run_cannon(false)?;
    let cp = run_cannon(true)?;
    let hidden = cp.metrics.iter().map(|m| m.overlap_hidden).fold(0.0, f64::max);
    println!(
        "\ncannon {q2}x{q2}, b={b2}:  blocking T_P = {:.3} ms, pipelined T_P = {:.3} ms \
         ({:.2}x, hid {:.3} ms of comm)",
        cb.t_parallel * 1e3,
        cp.t_parallel * 1e3,
        cb.t_parallel / cp.t_parallel,
        hidden * 1e3
    );

    let (q3, b3, chunks) = (2usize, 256usize, 4usize);
    let a3 = BlockSource::proxy(b3, 3);
    let b3s = BlockSource::proxy(b3, 4);
    let run_dns = |pipelined: bool| {
        Runtime::builder().world(q3 * q3 * q3).cost(machine).run(|ctx| {
            let schedule = if pipelined { Schedule::DnsPipelined } else { Schedule::DnsBlocking };
            let spec = MatmulSpec::new(&comp, q3, &a3, &b3s)
                .chunks(chunks)
                .mode(PlanMode::Forced(schedule));
            matmul(ctx, spec).t_local
        })
    };
    let db = run_dns(false)?;
    let dp = run_dns(true)?;
    println!(
        "dns {q3}x{q3}x{q3}, b={b3}, {chunks} panels:  blocking T_P = {:.3} ms, \
         pipelined T_P = {:.3} ms ({:.2}x)",
        db.t_parallel * 1e3,
        dp.t_parallel * 1e3,
        db.t_parallel / dp.t_parallel
    );
    Ok(())
}
