//! All-pairs shortest paths: Algorithm 3 (parallel Floyd-Warshall on a
//! 2-d grid) and the repeated-squaring extension, both verified against
//! the sequential oracle — then a modeled scaling sweep.
//!
//! Run with:  cargo run --release --example floyd_warshall

use std::sync::Arc;

use foopar::algos::{apsp, apsp_squaring, collect_d, floyd_warshall, seq, FwSpec};
use foopar::analysis;
use foopar::config::MachineConfig;
use foopar::graph::{floyd_warshall_seq, Graph};
use foopar::runtime::compute::Compute;
use foopar::runtime::engine::EngineServer;
use foopar::Runtime;

fn main() {
    let q = 2;
    let n = 64;
    let density = 0.25;
    let seed = 2024;
    let src = floyd_warshall::FwSource::Real { n, density, seed };

    let (comp, path) = match EngineServer::start_default() {
        Ok(srv) => {
            let h = Arc::new(srv.handle());
            std::mem::forget(srv);
            (Compute::Pjrt(h), "pjrt (AOT pallas fw_update kernel)")
        }
        Err(e) => {
            eprintln!("note: PJRT unavailable ({e:#}), using native");
            (Compute::Native, "native")
        }
    };

    let local = Runtime::builder()
        .world(q * q)
        .backend("shmem")
        .machine("local")
        .build()
        .expect("floyd_warshall runtime");

    // ---------- Algorithm 3 ----------
    println!("Floyd-Warshall (Alg. 3): n={n}, p={}, path: {path}", q * q);
    let res = local.run(|ctx| apsp(ctx, FwSpec::new(&comp, q, &src)));
    let d = collect_d(&res.results, q, n / q);
    let want = floyd_warshall_seq(&Graph::random(n, density, seed));
    println!("  verified vs sequential: max|Δ| = {:.2e}", d.max_abs_diff(&want));
    assert!(d.max_abs_diff(&want) < 1e-2);

    // ---------- repeated squaring extension ----------
    println!("APSP by min-plus squaring (extension): n={n}, p={}", q * q);
    let res2 = local.run(|ctx| apsp_squaring::apsp_squaring_par(ctx, &comp, q, &src));
    let d2 = apsp_squaring::saturate(apsp_squaring::collect_d(&res2.results, q, n / q));
    println!("  verified vs sequential: max|Δ| = {:.2e}", d2.max_abs_diff(&want));
    assert!(d2.max_abs_diff(&want) < 1e-2);
    println!(
        "  FW virtual T_P {:.4}s vs squaring {:.4}s (squaring trades flops for latency)",
        res.t_parallel, res2.t_parallel
    );

    // ---------- modeled scaling (§5's isoefficiency Θ((√p log p)³)) ----------
    let machine = MachineConfig::carver();
    println!("\nmodeled FW scaling on Carver (n = 8192):");
    for p in [4usize, 16, 64, 256] {
        let qq = (p as f64).sqrt() as usize;
        let msrc = floyd_warshall::FwSource::Proxy { n: 8192 };
        let comp = Compute::Modeled { rate: machine.rate };
        let r = Runtime::builder()
            .world(p)
            .machine_config(&machine)
            .run(|ctx| apsp(ctx, FwSpec::new(&comp, qq, &msrc)))
            .expect("floyd_warshall runtime");
        let ts = seq::fw_ts(8192, machine.rate);
        println!(
            "  p={p:>3}: T_P={:.3}s  E={:.1}%",
            r.t_parallel,
            analysis::efficiency(ts, r.t_parallel, p) * 100.0
        );
    }
    println!("floyd_warshall OK");
}
