//! Serving-mode acceptance demo: a resident pool multiplexing nine
//! concurrent mixed jobs (Cannon matmul + Floyd-Warshall, several grid
//! shapes), each verified **bit-identical** to a dedicated single-job
//! oracle run.
//!
//! ```text
//! cargo run --example serving
//! ```
//!
//! The world comes up once (`Runtime::serve`); the driver floods the
//! job queue up front, so jobs run concurrently on disjoint rank
//! subsets — a 2×2 grid next to single-rank GEMMs — each inside its own
//! derived tag namespace.  CI runs this to hold the acceptance bar:
//! multiplexing must not perturb a single bit of any result.

use foopar::algos::floyd_warshall::FwSource;
use foopar::algos::{apsp, collect_c, collect_d, matmul, FwSpec, MatmulSpec};
use foopar::matrix::block::BlockSource;
use foopar::matrix::dense::Mat;
use foopar::runtime::compute::Compute;
use foopar::serve::{JobSpec, ServeOptions};
use foopar::Runtime;

/// Re-run one job in a fresh, dedicated q×q world — the oracle the
/// served result must match exactly.
fn oracle(spec: &JobSpec) -> foopar::Result<Mat> {
    Ok(match *spec {
        JobSpec::Matmul { q, b, seed_a, seed_b } => {
            let res = Runtime::builder().world(q * q).build()?.run(move |ctx| {
                let a = BlockSource::real(b, seed_a);
                let bb = BlockSource::real(b, seed_b);
                matmul(ctx, MatmulSpec::new(&Compute::Native, q, &a, &bb))
            });
            collect_c(&res.results, q, b)
        }
        JobSpec::FloydWarshall { q, n, density, seed } => {
            let res = Runtime::builder().world(q * q).build()?.run(move |ctx| {
                let src = FwSource::Real { n, density, seed };
                apsp(ctx, FwSpec::new(&Compute::Native, q, &src))
            });
            collect_d(&res.results, q, n / q)
        }
        ref other => anyhow::bail!("no oracle for {}", other.kind()),
    })
}

fn main() -> foopar::Result<()> {
    // dispatcher + pool of 5: one 2×2 job and single-rank jobs coexist
    let rt = Runtime::builder().world(6).build()?;

    let specs = vec![
        JobSpec::Matmul { q: 2, b: 8, seed_a: 11, seed_b: 12 },
        JobSpec::FloydWarshall { q: 2, n: 8, density: 0.45, seed: 7 },
        JobSpec::Matmul { q: 1, b: 12, seed_a: 21, seed_b: 22 },
        JobSpec::Matmul { q: 1, b: 12, seed_a: 31, seed_b: 32 },
        JobSpec::FloydWarshall { q: 1, n: 6, density: 0.5, seed: 9 },
        JobSpec::Matmul { q: 2, b: 6, seed_a: 41, seed_b: 42 },
        JobSpec::Matmul { q: 1, b: 12, seed_a: 51, seed_b: 52 },
        JobSpec::FloydWarshall { q: 2, n: 12, density: 0.3, seed: 13 },
        JobSpec::Matmul { q: 1, b: 12, seed_a: 61, seed_b: 62 },
    ];

    let (results, report) = rt.serve(ServeOptions::default(), |h| {
        // flood the queue up front so the jobs are genuinely concurrent
        let ids: Vec<u64> = specs.iter().map(|s| h.submit(s.clone())).collect();
        ids.into_iter().map(|id| h.wait(id)).collect::<Vec<_>>()
    })?;

    for (k, (spec, res)) in specs.iter().zip(results).enumerate() {
        let got = match res {
            Ok(out) => out.into_mat(),
            Err(e) => anyhow::bail!("job {k} ({}) failed: {e}", spec.kind()),
        };
        let want = oracle(spec)?;
        anyhow::ensure!(
            got == want,
            "job {k} ({}) diverges from its single-job oracle (max |Δ| = {:.3e})",
            spec.kind(),
            got.max_abs_diff(&want)
        );
        println!(
            "job {k}: {:>6} {}x{}  bit-identical to oracle",
            spec.kind(),
            got.rows,
            got.cols
        );
    }

    anyhow::ensure!(report.done == specs.len() as u64, "all jobs must complete");
    println!(
        "serving example: {} jobs over a pool of 5 in {} assignments; \
         latency p50 {:.2} ms, p99 {:.2} ms",
        report.done,
        report.assignments,
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3
    );
    Ok(())
}
