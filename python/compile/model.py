"""L2: the per-rank compute graph of the FooPar reproduction.

The paper's "model" is the block linear algebra each rank performs inside
distributed-collection operations: sub-matrix GEMM (mapD / zipWithD of
Alg. 1 and 2), block summation (reduceD combine), and the Floyd-Warshall
pivot update (Alg. 3).  Each is a jitted jax function calling the L1
Pallas kernels so that kernel + surrounding graph lower into a single HLO
module per (operation, block-size) pair.

These functions are lowered once by ``aot.py``; Python never runs on the
rust request path.
"""

import jax
import jax.numpy as jnp

from .kernels import matmul as mmk
from .kernels import minplus as mpk


def block_matmul(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """C = A @ B on (b, b) f32 blocks (the mapD multiply of Alg. 1/2)."""
    return (mmk.matmul(a, b),)


def block_matmul_acc(c: jax.Array, a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """C + A @ B — fused local multiply + partial-sum accumulate."""
    return (mmk.matmul_acc(c, a, b),)


def block_add(x: jax.Array, y: jax.Array) -> tuple[jax.Array]:
    """X + Y — the associative ``reduceD (_ + _)`` combine operator."""
    return (mmk.add(x, y),)


def fw_update(d: jax.Array, ik: jax.Array, kj: jax.Array) -> tuple[jax.Array]:
    """Floyd-Warshall pivot update on a block (Alg. 3 lines 9-14)."""
    return (mpk.fw_update(d, ik, kj),)


def minplus_matmul(a: jax.Array, b: jax.Array) -> tuple[jax.Array]:
    """Tropical GEMM for the repeated-squaring APSP extension."""
    return (mpk.minplus_matmul(a, b),)


def f32(*shape: int) -> jax.ShapeDtypeStruct:
    """Shorthand for an f32 ShapeDtypeStruct used as a lowering spec."""
    return jax.ShapeDtypeStruct(shape, jnp.float32)


#: Registry of everything the AOT pipeline emits, keyed by artifact name
#: pattern.  ``{b}`` is substituted with each block size.  The rust
#: runtime (rust/src/runtime/artifacts.rs) parses these names back.
def entries(block_sizes):
    out = []
    for b in block_sizes:
        out.append((f"matmul_b{b}", block_matmul, (f32(b, b), f32(b, b))))
        out.append(
            (f"matmul_acc_b{b}", block_matmul_acc, (f32(b, b), f32(b, b), f32(b, b)))
        )
        out.append((f"add_b{b}", block_add, (f32(b, b), f32(b, b))))
        out.append(
            (f"fw_update_b{b}", fw_update, (f32(b, b), f32(1, b), f32(b, 1)))
        )
        out.append(
            (f"minplus_b{b}", minplus_matmul, (f32(b, b), f32(b, b)))
        )
    return out
