"""AOT pipeline: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``).  The HLO text
parser reassigns ids, so text round-trips cleanly — see
/opt/xla-example/README.md.

Usage (from python/):  ``python -m compile.aot --out-dir ../artifacts``
Emits one ``<name>.hlo.txt`` per (operation, block size) plus a
``manifest.json`` the rust runtime consumes.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

#: Block sizes baked into the artifact set.  The rust side picks the
#: artifact matching its configured block edge and falls back to native
#: gemm otherwise.  Powers of two keep the Pallas tiling exact.
BLOCK_SIZES = (32, 64, 128, 256)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--block-sizes",
        default=",".join(str(b) for b in BLOCK_SIZES),
        help="comma-separated block edges to emit artifacts for",
    )
    args = ap.parse_args()
    blocks = tuple(int(b) for b in args.block_sizes.split(",") if b)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "entries": []}
    for name, fn, specs in model.entries(blocks):
        text = lower_entry(fn, specs)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": f"{name}.hlo.txt",
                "inputs": [list(s.shape) for s in specs],
                "dtype": "f32",
            }
        )
        print(f"  wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(manifest['entries'])} entries -> {args.out_dir}")


if __name__ == "__main__":
    main()
