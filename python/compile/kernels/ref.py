"""Pure-jnp correctness oracles for the L1 Pallas kernels.

Every kernel in this package must agree with the corresponding function
here (see python/tests/).  These are also the functions whose lowered HLO
would be used if Pallas were unavailable — they define the semantics.
"""

import jax.numpy as jnp


def matmul(a, b):
    """Plain block GEMM: ``a @ b`` in f32."""
    return jnp.dot(a, b, preferred_element_type=jnp.float32)


def matmul_acc(c, a, b):
    """Fused multiply-accumulate on blocks: ``c + a @ b``.

    This is the local-multiply + partial-sum hot spot of the DNS
    algorithm (Alg. 2 in the paper): each rank multiplies its sub-blocks
    and partial sums are combined along the z-dimension.
    """
    return c + jnp.dot(a, b, preferred_element_type=jnp.float32)


def add(x, y):
    """Block summation — the associative ``reduceD (_ + _)`` operator."""
    return x + y


def fw_update(d, ik, kj):
    """One Floyd-Warshall pivot update on a block (Alg. 3, lines 9-14).

    ``d``  : (b, b) block of the distance matrix
    ``ik`` : (1, b) pivot-row segment  (the ``ik`` value in Alg. 3)
    ``kj`` : (b, 1) pivot-column segment (the ``kj`` value in Alg. 3)

    Returns ``min(d[i,j], kj[i] + ik[j])`` elementwise.
    """
    return jnp.minimum(d, kj + ik)


#: "No edge" sentinel of the (min, +) semiring; results saturate here so
#: that INF + INF does not escape the semiring (kept in sync with
#: ``minplus.INF`` and rust/src/graph).
INF = 1e30


def minplus_matmul(a, b):
    """Tropical (min-plus) matrix product: ``out[i,j] = min_k a[i,k]+b[k,j]``,
    saturated at ``INF`` (INF is absorbing: INF + x = INF).

    Used by the repeated-squaring APSP extension.  O(b^3) like GEMM but in
    the (min, +) semiring.
    """
    return jnp.minimum(jnp.min(a[:, :, None] + b[None, :, :], axis=1), INF)
