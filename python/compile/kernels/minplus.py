"""L1 Pallas kernels for the Floyd-Warshall block updates (Alg. 3).

Two kernels:

* ``fw_update``   — one pivot-step update of a distance block:
                    ``d[i,j] = min(d[i,j], kj[i] + ik[j])`` (lines 9-14 of
                    Alg. 3, vectorized over the whole block).
* ``minplus_matmul`` — tropical GEMM ``min_k (a[i,k] + b[k,j])`` used by
                    the repeated-squaring APSP extension.  Same tiling
                    discipline as the f32 GEMM kernel: the VPU has no
                    (min,+) systolic array, so this runs on the vector
                    unit with an output-stationary k-loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_tile

#: Value standing in for "no edge"; finite so that +/min arithmetic stays
#: NaN-free (matches rust/src/graph INF).  Plain python float: a traced
#: jnp scalar would be captured as a constant, which pallas_call rejects.
INF = 1e30


def _fw_update_kernel(d_ref, ik_ref, kj_ref, o_ref):
    """o = min(d, kj ⊕ ik): rank-1 outer min-plus against the pivot row/col."""
    o_ref[...] = jnp.minimum(d_ref[...], kj_ref[...] + ik_ref[...])


def fw_update(d: jax.Array, ik: jax.Array, kj: jax.Array) -> jax.Array:
    """Pivot update of a (b, b) block; ik is (1, b), kj is (b, 1).

    Tiled so each VMEM-resident (t, t) tile of ``d`` reads only the
    matching (1, t) / (t, 1) pivot slivers.
    """
    b, b2 = d.shape
    assert b == b2 and ik.shape == (1, b) and kj.shape == (b, 1)
    t = _pick_tile(b)
    return pl.pallas_call(
        _fw_update_kernel,
        grid=(b // t, b // t),
        in_specs=[
            pl.BlockSpec((t, t), lambda i, j: (i, j)),
            pl.BlockSpec((1, t), lambda i, j: (0, j)),
            pl.BlockSpec((t, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((t, t), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, b), jnp.float32),
        interpret=True,
    )(d, ik, kj)


def _minplus_kernel(x_ref, y_ref, o_ref, *, tk: int):
    """Grid point (i, j, s): o[i,j] = min(o[i,j], minplus(x[i,s], y[s,j]))."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, INF)

    x = x_ref[...]
    y = y_ref[...]
    # (t, tk, 1) + (1, tk, t) -> reduce over k. Materializes a (t, tk, t)
    # cube in VMEM; tiles are picked small enough that this fits.
    cube = x[:, :, None] + y[None, :, :]
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(cube, axis=1))


def minplus_matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Tropical GEMM over (b, b) blocks (APSP by repeated squaring)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    # The (t, tk, t) broadcast cube costs t*t*tk*4 bytes of VMEM: cap the
    # tile edge at 32 so 32*32*32*4 = 128 KiB stays scratch-friendly.
    tm = min(_pick_tile(m), 32)
    tn = min(_pick_tile(n), 32)
    tk = min(_pick_tile(k), 32)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        functools.partial(_minplus_kernel, tk=tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tk, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
