"""L1 Pallas kernels: tiled block GEMM (+ accumulate, + add).

The per-rank compute hot spot of the paper is the sub-matrix product that
JBLAS/MKL performed on each core.  Here it is a Pallas kernel shaped for
the TPU MXU: C is tiled into ``TILE x TILE`` VMEM blocks and a k-loop of
``TILE``-wide panels streams through the systolic array, accumulating in
f32.  BlockSpecs express the HBM->VMEM schedule (see DESIGN.md
section "Hardware adaptation").

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so kernels are lowered to plain HLO ops.  TPU performance
is *estimated* from the VMEM footprint / MXU shape in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The MXU is a 128x128 systolic array; 128 is the natural tile edge.
MXU_TILE = 128


def _pick_tile(n: int) -> int:
    """Largest power-of-two tile <= min(n, MXU_TILE) that divides n."""
    t = min(n, MXU_TILE)
    while n % t:
        t //= 2
    return max(t, 1)


def _matmul_kernel(x_ref, y_ref, o_ref, *, nsteps: int):
    """Grid point (i, j, k): o[i,j] (+)= x[i,k] @ y[k,j].

    The k axis is the innermost grid dimension, so for a fixed (i, j) the
    output tile stays resident in VMEM while ``nsteps`` input panels are
    streamed past it — the classic output-stationary MXU schedule.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul(a: jax.Array, b: jax.Array) -> jax.Array:
    """Block GEMM ``a @ b`` as a tiled Pallas call.

    Shapes: a (m, k), b (k, n) -> (m, n); all dims must be tileable (they
    are powers of two in this library).
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {b.shape}"
    tm, tn, tk = _pick_tile(m), _pick_tile(n), _pick_tile(k)
    nsteps = k // tk
    grid = (m // tm, n // tn, nsteps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nsteps=nsteps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tk, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _matmul_acc_kernel(c_ref, x_ref, y_ref, o_ref):
    """Grid point (i, j, k): o[i,j] = c[i,j] + sum_k x[i,k] @ y[k,j]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = c_ref[...]

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def matmul_acc(c: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """Fused multiply-accumulate ``c + a @ b`` (DNS partial-sum hot spot)."""
    m, k = a.shape
    _, n = b.shape
    assert c.shape == (m, n)
    tm, tn, tk = _pick_tile(m), _pick_tile(n), _pick_tile(k)
    grid = (m // tm, n // tn, k // tk)
    return pl.pallas_call(
        _matmul_acc_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tk, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(c, a, b)


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def add(x: jax.Array, y: jax.Array) -> jax.Array:
    """Elementwise block sum — the ``reduceD (_ + _)`` combine operator."""
    m, n = x.shape
    tm, tn = _pick_tile(m), _pick_tile(n)
    return pl.pallas_call(
        _add_kernel,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
