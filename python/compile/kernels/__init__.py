# L1: Pallas kernels for the paper's compute hot-spots.
from . import matmul, minplus, ref  # noqa: F401
