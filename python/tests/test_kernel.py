"""Kernel-vs-ref correctness: the CORE L1 signal.

Hypothesis sweeps shapes (powers of two, including non-square and
non-tile-divisible-by-128 cases) and value distributions; every Pallas
kernel must match the pure-jnp oracle in ``ref.py`` to f32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mmk
from compile.kernels import minplus as mpk
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

# Power-of-two edges exercise tile == edge, tile < 128, and multi-tile.
EDGES = [1, 2, 4, 8, 16, 32, 64, 128, 256]
SMALL_EDGES = [1, 2, 4, 8, 16, 32, 64]


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def keys(seed, n):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# ----------------------------------------------------------------- matmul


@settings(max_examples=40, deadline=None)
@given(
    m=st.sampled_from(SMALL_EDGES),
    k=st.sampled_from(SMALL_EDGES),
    n=st.sampled_from(SMALL_EDGES),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, seed):
    ka, kb = keys(seed, 2)
    a, b = rand(ka, m, k), rand(kb, k, n)
    got = mmk.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("b", EDGES)
def test_matmul_square_blocks(b):
    ka, kb = keys(b, 2)
    x, y = rand(ka, b, b), rand(kb, b, b)
    np.testing.assert_allclose(
        mmk.matmul(x, y), ref.matmul(x, y), rtol=1e-4, atol=1e-4
    )


def test_matmul_identity():
    x = rand(keys(7, 1)[0], 64, 64)
    eye = jnp.eye(64, dtype=jnp.float32)
    np.testing.assert_allclose(mmk.matmul(x, eye), x, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(mmk.matmul(eye, x), x, rtol=1e-6, atol=1e-6)


def test_matmul_zeros():
    z = jnp.zeros((128, 128), jnp.float32)
    x = rand(keys(9, 1)[0], 128, 128)
    assert jnp.all(mmk.matmul(x, z) == 0)


# ------------------------------------------------------------- matmul_acc


@settings(max_examples=30, deadline=None)
@given(b=st.sampled_from(SMALL_EDGES), seed=st.integers(0, 2**31 - 1))
def test_matmul_acc_matches_ref(b, seed):
    kc, ka, kb = keys(seed, 3)
    c, a, x = rand(kc, b, b), rand(ka, b, b), rand(kb, b, b)
    got = mmk.matmul_acc(c, a, x)
    want = ref.matmul_acc(c, a, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_acc_zero_c_equals_matmul():
    ka, kb = keys(11, 2)
    a, b = rand(ka, 64, 64), rand(kb, 64, 64)
    z = jnp.zeros((64, 64), jnp.float32)
    np.testing.assert_allclose(
        mmk.matmul_acc(z, a, b), mmk.matmul(a, b), rtol=1e-6, atol=1e-6
    )


# -------------------------------------------------------------------- add


@settings(max_examples=20, deadline=None)
@given(b=st.sampled_from(EDGES), seed=st.integers(0, 2**31 - 1))
def test_add_matches_ref(b, seed):
    ka, kb = keys(seed, 2)
    x, y = rand(ka, b, b), rand(kb, b, b)
    np.testing.assert_allclose(mmk.add(x, y), ref.add(x, y), rtol=0, atol=0)


def test_add_commutative():
    ka, kb = keys(3, 2)
    x, y = rand(ka, 32, 32), rand(kb, 32, 32)
    np.testing.assert_allclose(mmk.add(x, y), mmk.add(y, x))


# -------------------------------------------------------------- fw_update


def rand_dist(key, *shape):
    """Distance-like values: non-negative with a sprinkle of INF."""
    ka, kb = jax.random.split(key)
    vals = jax.random.uniform(ka, shape, jnp.float32, 0.0, 100.0)
    mask = jax.random.bernoulli(kb, 0.1, shape)
    return jnp.where(mask, jnp.float32(mpk.INF), vals)


@settings(max_examples=30, deadline=None)
@given(b=st.sampled_from(SMALL_EDGES), seed=st.integers(0, 2**31 - 1))
def test_fw_update_matches_ref(b, seed):
    kd, ki, kj = keys(seed, 3)
    d = rand_dist(kd, b, b)
    ik = rand_dist(ki, 1, b)
    kj = rand_dist(kj, b, 1)
    got = mpk.fw_update(d, ik, kj)
    want = ref.fw_update(d, ik, kj)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_fw_update_never_increases():
    kd, ki, kj = keys(21, 3)
    d = rand_dist(kd, 64, 64)
    ik, kj = rand_dist(ki, 1, 64), rand_dist(kj, 64, 1)
    assert jnp.all(mpk.fw_update(d, ik, kj) <= d)


def test_fw_update_inf_pivot_is_noop():
    d = rand_dist(keys(22, 1)[0], 32, 32)
    inf_row = jnp.full((1, 32), jnp.float32(mpk.INF))
    inf_col = jnp.full((32, 1), jnp.float32(mpk.INF))
    np.testing.assert_allclose(mpk.fw_update(d, inf_row, inf_col), d)


# --------------------------------------------------------- minplus_matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([1, 2, 4, 8, 16, 32]),
    k=st.sampled_from([1, 2, 4, 8, 16, 32]),
    n=st.sampled_from([1, 2, 4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_minplus_matches_ref(m, k, n, seed):
    ka, kb = keys(seed, 2)
    a = rand_dist(ka, m, k)
    b = rand_dist(kb, k, n)
    got = mpk.minplus_matmul(a, b)
    want = ref.minplus_matmul(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-6)


@pytest.mark.parametrize("b", [64, 128])
def test_minplus_multi_tile(b):
    ka, kb = keys(b, 2)
    x, y = rand_dist(ka, b, b), rand_dist(kb, b, b)
    np.testing.assert_allclose(
        mpk.minplus_matmul(x, y), ref.minplus_matmul(x, y), rtol=1e-6
    )


def test_minplus_zero_diag_identity():
    """A min-plus identity matrix (0 diag, INF off-diag) is a no-op."""
    x = rand_dist(keys(5, 1)[0], 32, 32)
    ident = jnp.full((32, 32), jnp.float32(mpk.INF)).at[
        jnp.arange(32), jnp.arange(32)
    ].set(0.0)
    got = mpk.minplus_matmul(x, ident)
    np.testing.assert_allclose(got, jnp.minimum(x, mpk.INF), rtol=1e-6)
