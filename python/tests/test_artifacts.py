"""Artifact-level guarantees the rust runtime depends on.

The PJRT CPU client can only execute plain HLO ops: a Pallas kernel
accidentally lowered without ``interpret=True`` would emit a Mosaic
``custom-call`` the loader cannot run.  These tests pin the contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def lowered():
    """Lower every entry once at a small block size."""
    return {
        name: aot.lower_entry(fn, specs) for name, fn, specs in model.entries((16,))
    }


def test_no_custom_calls(lowered):
    for name, text in lowered.items():
        assert "custom-call" not in text, (
            f"{name}: artifact contains a custom-call — was the Pallas kernel "
            "lowered without interpret=True?"
        )


def test_single_entry_computation(lowered):
    for name, text in lowered.items():
        assert text.count("ENTRY") == 1, f"{name}: expected exactly one ENTRY"


def test_output_is_tuple(lowered):
    # aot lowers with return_tuple=True; the rust loader calls to_tuple1()
    for name, text in lowered.items():
        root_lines = [l for l in text.splitlines() if "ROOT" in l and "ENTRY" not in l]
        assert any("tuple" in l or "(f32" in l for l in root_lines), (
            f"{name}: root does not look like a tuple: {root_lines}"
        )


def test_f32_only(lowered):
    # The rust Mat type is f32; any f64/bf16 creeping in would break the
    # literal round-trip.
    for name, text in lowered.items():
        assert "f64[" not in text, f"{name}: unexpected f64"
        assert "bf16[" not in text, f"{name}: unexpected bf16"


def test_artifact_numerics_through_lowered_path():
    """Execute the lowered HLO via jax itself and compare to direct eval —
    guards against lowering-time constant folding bugs."""
    b = 16
    a = jax.random.normal(jax.random.PRNGKey(0), (b, b), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, b), jnp.float32)
    (direct,) = model.block_matmul(a, x)
    compiled = jax.jit(model.block_matmul).lower(a, x).compile()
    (via_lowered,) = compiled(a, x)
    np.testing.assert_allclose(direct, via_lowered, rtol=1e-6)
