"""L2 model + AOT pipeline tests: entry registry, shapes, HLO text output.

Checks that every registered entry lowers to parseable HLO text with the
expected parameter shapes, and that executing the jitted entry matches
the ref oracle (model functions are thin wrappers, but a wiring bug here
would poison every artifact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def test_entries_cover_all_ops():
    names = [n for n, _, _ in model.entries((32, 64))]
    for op in ("matmul", "matmul_acc", "add", "fw_update", "minplus"):
        for b in (32, 64):
            assert f"{op}_b{b}" in names
    assert len(names) == 10


def test_entry_specs_are_f32():
    for _, _, specs in model.entries((32,)):
        for s in specs:
            assert s.dtype == jnp.float32


@pytest.mark.parametrize("name,fn,specs", model.entries((32,)))
def test_lowering_produces_hlo_text(name, fn, specs):
    text = aot.lower_entry(fn, specs)
    assert "HloModule" in text
    assert "ENTRY" in text
    # every input shape appears as a parameter
    for s in specs:
        dims = ",".join(str(d) for d in s.shape)
        assert f"f32[{dims}]" in text, f"{name}: missing param f32[{dims}]"


def _rand(seed, *shape):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_model_matmul_matches_ref():
    a, b = _rand(0, 64, 64), _rand(1, 64, 64)
    (got,) = model.block_matmul(a, b)
    np.testing.assert_allclose(got, ref.matmul(a, b), rtol=1e-5, atol=1e-5)


def test_model_matmul_acc_matches_ref():
    c, a, b = _rand(2, 32, 32), _rand(3, 32, 32), _rand(4, 32, 32)
    (got,) = model.block_matmul_acc(c, a, b)
    np.testing.assert_allclose(got, ref.matmul_acc(c, a, b), rtol=1e-5, atol=1e-5)


def test_model_fw_update_matches_ref():
    d = jnp.abs(_rand(5, 32, 32)) * 10
    ik = jnp.abs(_rand(6, 1, 32)) * 10
    kj = jnp.abs(_rand(7, 32, 1)) * 10
    (got,) = model.fw_update(d, ik, kj)
    np.testing.assert_allclose(got, ref.fw_update(d, ik, kj), rtol=1e-6)


def test_manifest_roundtrip(tmp_path):
    """End-to-end: aot main() writes artifacts + manifest for one size."""
    import json
    import sys
    from unittest import mock

    argv = ["aot", "--out-dir", str(tmp_path), "--block-sizes", "8"]
    with mock.patch.object(sys, "argv", argv):
        aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == "hlo-text"
    assert len(manifest["entries"]) == 5
    for e in manifest["entries"]:
        text = (tmp_path / e["file"]).read_text()
        assert text.startswith("HloModule")
