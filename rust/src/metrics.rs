//! Per-rank metrics: message/byte/flop counters and virtual-time split.
//!
//! Every [`crate::spmd::Ctx`] owns a `RankMetrics`; the SPMD launcher
//! collects them at join and [`Report`] aggregates across ranks.  These
//! counters are what the bench harness prints next to the paper's numbers
//! (e.g. bytes on the wire per reduceD at p ranks — directly comparable to
//! the `t_w·m·f(p)` terms in Table 1).

use std::cell::Cell;

use crate::matrix::params::BlockParams;

/// Compact attribution of the GEMM blocking profile a rank ran under,
/// carried in every [`MetricsSnapshot`] so a quoted GFlop/s figure is
/// always attributable to the `BlockParams` that produced it (bench
/// provenance; the tune sweep's whole point is that the same host gives
/// different rates under different profiles).  A zero `kc` means "no
/// profile recorded" — e.g. a snapshot that never passed through a
/// [`crate::spmd::Ctx`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProfileTag {
    pub kc: u32,
    pub mc: u32,
    pub nc: u32,
    pub mr: u8,
    pub nr: u8,
}

impl ProfileTag {
    /// Tag the active blocking profile.
    pub fn of(p: &BlockParams) -> ProfileTag {
        ProfileTag {
            kc: p.kc as u32,
            mc: p.mc as u32,
            nc: p.nc as u32,
            mr: p.micro.mr() as u8,
            nr: p.micro.nr() as u8,
        }
    }

    /// Whether a profile was recorded at all.
    pub fn is_set(&self) -> bool {
        self.kc != 0
    }

    /// Human-readable form for report rows ("kc256 mc64 nc128 8x8").
    pub fn label(&self) -> String {
        format!("kc{} mc{} nc{} {}x{}", self.kc, self.mc, self.nc, self.mr, self.nr)
    }
}

/// Counters owned by one rank.  `Cell`-based: ranks are single threads, the
/// struct is never shared, but ops take `&Ctx`.
#[derive(Debug, Default)]
pub struct RankMetrics {
    pub msgs_sent: Cell<u64>,
    pub bytes_sent: Cell<u64>,
    pub msgs_recv: Cell<u64>,
    pub bytes_recv: Cell<u64>,
    /// Floating-point operations this rank performed (modeled or real).
    pub flops: Cell<f64>,
    /// Virtual seconds spent in communication (send + recv wait).
    pub comm_time: Cell<f64>,
    /// Virtual seconds spent computing.
    pub compute_time: Cell<f64>,
    /// Collective operations entered.
    pub collectives: Cell<u64>,
    /// Sub-counter of `flops`: floating-point operations performed by
    /// the bandwidth-bound *elementwise* kernels (add, fw_update, min)
    /// — real modes only; modeled mode charges everything as plain
    /// compute.  Lets reports quote an elementwise GFlop/s next to the
    /// GEMM rate (two very different "peaks": flops/s vs bytes/s).
    pub ew_flops: Cell<f64>,
    /// Sub-counter of `compute_time`: virtual seconds inside the
    /// elementwise kernels.
    pub ew_time: Cell<f64>,
    /// Virtual seconds of communication hidden by non-blocking group
    /// operations — comm time that did not extend the rank's clock
    /// because the main timeline had already advanced past it (compute,
    /// or other operations merged earlier; the `max(T_comm, T_comp)`
    /// overlap rule).  Per region: `min(comm elapsed, main elapsed)` —
    /// i.e. the clock savings versus running the operation blocking.
    pub overlap_hidden: Cell<f64>,
    /// The GEMM blocking profile this rank runs under (set once by the
    /// launcher from the rank's `Ctx`; carried into every snapshot).
    pub profile: Cell<ProfileTag>,
}

impl RankMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the active blocking profile (launcher only).
    pub fn set_profile(&self, tag: ProfileTag) {
        self.profile.set(tag);
    }

    #[inline]
    pub fn on_send(&self, bytes: usize, secs: f64) {
        self.msgs_sent.set(self.msgs_sent.get() + 1);
        self.bytes_sent.set(self.bytes_sent.get() + bytes as u64);
        self.comm_time.set(self.comm_time.get() + secs);
    }

    #[inline]
    pub fn on_recv(&self, bytes: usize, wait_secs: f64) {
        self.msgs_recv.set(self.msgs_recv.get() + 1);
        self.bytes_recv.set(self.bytes_recv.get() + bytes as u64);
        self.comm_time.set(self.comm_time.get() + wait_secs);
    }

    #[inline]
    pub fn on_compute(&self, flops: f64, secs: f64) {
        self.flops.set(self.flops.get() + flops);
        self.compute_time.set(self.compute_time.get() + secs);
    }

    #[inline]
    pub fn on_collective(&self) {
        self.collectives.set(self.collectives.get() + 1);
    }

    /// Attribute already-charged compute to the elementwise sub-counters
    /// (callers charge [`RankMetrics::on_compute`] too — see
    /// [`Ctx::timed_elementwise`](crate::spmd::Ctx::timed_elementwise)).
    #[inline]
    pub fn on_elementwise(&self, flops: f64, secs: f64) {
        self.ew_flops.set(self.ew_flops.get() + flops);
        self.ew_time.set(self.ew_time.get() + secs);
    }

    #[inline]
    pub fn on_overlap(&self, hidden_secs: f64) {
        self.overlap_hidden.set(self.overlap_hidden.get() + hidden_secs);
    }

    /// Snapshot into a plain (Send) summary for cross-thread collection.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            msgs_sent: self.msgs_sent.get(),
            bytes_sent: self.bytes_sent.get(),
            msgs_recv: self.msgs_recv.get(),
            bytes_recv: self.bytes_recv.get(),
            flops: self.flops.get(),
            comm_time: self.comm_time.get(),
            compute_time: self.compute_time.get(),
            collectives: self.collectives.get(),
            ew_flops: self.ew_flops.get(),
            ew_time: self.ew_time.get(),
            overlap_hidden: self.overlap_hidden.get(),
            profile: self.profile.get(),
        }
    }
}

/// Plain-old-data snapshot of one rank's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub msgs_sent: u64,
    pub bytes_sent: u64,
    pub msgs_recv: u64,
    pub bytes_recv: u64,
    pub flops: f64,
    pub comm_time: f64,
    pub compute_time: f64,
    pub collectives: u64,
    pub ew_flops: f64,
    pub ew_time: f64,
    pub overlap_hidden: f64,
    /// Blocking-profile attribution (not a counter; survives `scoped`).
    pub profile: ProfileTag,
}

impl MetricsSnapshot {
    /// Achieved compute rate of this rank in GFlop/s: flops over the
    /// rank's in-kernel time.  In real modes the kernels are wall-timed,
    /// so this is the §6 "measured performance" a rank delivered —
    /// compare against the machine's `rate` (empirical peak) and `peak`
    /// (theoretical) exactly like the paper's efficiency columns.  With
    /// `threads_per_rank > 1` the flops of a multi-threaded kernel land
    /// on one rank clock, so the figure is the whole rank's rate, not
    /// per core.
    pub fn gflops(&self) -> f64 {
        if self.compute_time > 0.0 {
            self.flops / self.compute_time / 1e9
        } else {
            0.0
        }
    }

    /// Achieved rate of the elementwise kernels alone (GFlop/s).  These
    /// kernels are bandwidth-bound (≈ one flop per 4-byte element), so
    /// this figure tracks memory throughput, not the ALU peak — compare
    /// it against other elementwise rows, never against the GEMM rate.
    pub fn ew_gflops(&self) -> f64 {
        if self.ew_time > 0.0 {
            self.ew_flops / self.ew_time / 1e9
        } else {
            0.0
        }
    }

    /// The delta `self − baseline`: activity **since** `baseline` was
    /// snapshot from the same rank's counters.
    ///
    /// A long-lived serving rank's `RankMetrics` accumulate across every
    /// job it ever ran, so quoting `snapshot().gflops()` for one job
    /// silently blends in its predecessors' flops and comm time.  The
    /// scoped form brackets a job — snapshot at assignment, `scoped` at
    /// completion — so per-job rates and byte counts never bleed between
    /// jobs multiplexed on the same rank.  (`Report::aggregate` over the
    /// members' scoped snapshots then gives the per-job report.)
    pub fn scoped(&self, baseline: &MetricsSnapshot) -> MetricsSnapshot {
        MetricsSnapshot {
            msgs_sent: self.msgs_sent - baseline.msgs_sent,
            bytes_sent: self.bytes_sent - baseline.bytes_sent,
            msgs_recv: self.msgs_recv - baseline.msgs_recv,
            bytes_recv: self.bytes_recv - baseline.bytes_recv,
            flops: self.flops - baseline.flops,
            comm_time: self.comm_time - baseline.comm_time,
            compute_time: self.compute_time - baseline.compute_time,
            collectives: self.collectives - baseline.collectives,
            ew_flops: self.ew_flops - baseline.ew_flops,
            ew_time: self.ew_time - baseline.ew_time,
            overlap_hidden: self.overlap_hidden - baseline.overlap_hidden,
            profile: self.profile,
        }
    }
}

/// Fixed-bucket latency histogram with quantile estimates — the serving
/// plane's p50/p99 instrument.
///
/// Buckets are log-spaced from 1 µs to ~100 s (5 per decade), so the
/// quantile error is bounded by the bucket ratio (~58%) worst-case and
/// the memory cost is a flat 41 counters — no per-sample storage, O(1)
/// record, mergeable across ranks by addition.  Quantiles interpolate
/// linearly inside the winning bucket.
#[derive(Clone, Debug)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum_secs: f64,
    /// Smallest / largest recorded sample — quantiles clamp to this
    /// range, so a single-sample histogram reports the sample itself
    /// (not its bucket's upper edge) at every quantile.
    min_secs: f64,
    max_secs: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    const BUCKETS: usize = 41; // 8 decades × 5 + 1 overflow
    const MIN_SECS: f64 = 1e-6;
    const PER_DECADE: f64 = 5.0;

    pub fn new() -> Self {
        Histogram {
            counts: vec![0; Self::BUCKETS],
            total: 0,
            sum_secs: 0.0,
            min_secs: f64::INFINITY,
            max_secs: 0.0,
        }
    }

    fn bucket_of(secs: f64) -> usize {
        if secs <= Self::MIN_SECS {
            return 0;
        }
        let b = ((secs / Self::MIN_SECS).log10() * Self::PER_DECADE).floor() as usize + 1;
        b.min(Self::BUCKETS - 1)
    }

    /// Lower edge of bucket `b` in seconds.
    fn edge(b: usize) -> f64 {
        if b == 0 {
            0.0
        } else {
            Self::MIN_SECS * 10f64.powf((b - 1) as f64 / Self::PER_DECADE)
        }
    }

    pub fn record(&mut self, secs: f64) {
        self.counts[Self::bucket_of(secs)] += 1;
        self.total += 1;
        self.sum_secs += secs;
        self.min_secs = self.min_secs.min(secs);
        self.max_secs = self.max_secs.max(secs);
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total > 0 {
            self.sum_secs / self.total as f64
        } else {
            0.0
        }
    }

    /// Quantile estimate in seconds, `q` in [0, 1].  Linear interpolation
    /// within the winning bucket, clamped to the recorded sample range
    /// (so a single-sample histogram reports the sample at every
    /// quantile).  **An empty histogram returns 0.0** — callers that need
    /// to distinguish "no samples" from "all samples ≤ 1 µs" must check
    /// [`Histogram::count`] first.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (b, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = Self::edge(b);
                let hi = if b + 1 < Self::BUCKETS { Self::edge(b + 1) } else { lo * 10.0 };
                let frac = (target - seen) as f64 / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min_secs, self.max_secs);
            }
            seen += c;
        }
        Self::edge(Self::BUCKETS - 1).clamp(self.min_secs, self.max_secs)
    }

    pub fn p50(&self) -> f64 {
        self.quantile(0.50)
    }

    pub fn p99(&self) -> f64 {
        self.quantile(0.99)
    }

    /// Merge another histogram into this one (cross-rank aggregation).
    /// The sample range merges too, so quantile clamping stays exact:
    /// merging then querying agrees with recording every sample into one
    /// histogram.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum_secs += other.sum_secs;
        self.min_secs = self.min_secs.min(other.min_secs);
        self.max_secs = self.max_secs.max(other.max_secs);
    }
}

/// Aggregate over all ranks of a run.
#[derive(Clone, Debug, Default)]
pub struct Report {
    pub ranks: usize,
    pub total: MetricsSnapshot,
    pub max_comm_time: f64,
    pub max_compute_time: f64,
    /// Highest achieved per-rank compute rate (GFlop/s) — the §6
    /// efficiency numerator for the best rank.
    pub max_gflops: f64,
    /// Highest achieved per-rank *elementwise* rate (GFlop/s) — the
    /// bandwidth-bound kernels' figure, reported next to `max_gflops`.
    pub max_ew_gflops: f64,
}

impl Report {
    pub fn aggregate(per_rank: &[MetricsSnapshot]) -> Self {
        let mut total = MetricsSnapshot::default();
        let mut max_comm = 0.0f64;
        let mut max_comp = 0.0f64;
        let mut max_gflops = 0.0f64;
        let mut max_ew_gflops = 0.0f64;
        for m in per_rank {
            total.msgs_sent += m.msgs_sent;
            total.bytes_sent += m.bytes_sent;
            total.msgs_recv += m.msgs_recv;
            total.bytes_recv += m.bytes_recv;
            total.flops += m.flops;
            total.comm_time += m.comm_time;
            total.compute_time += m.compute_time;
            total.collectives += m.collectives;
            total.ew_flops += m.ew_flops;
            total.ew_time += m.ew_time;
            total.overlap_hidden += m.overlap_hidden;
            max_comm = max_comm.max(m.comm_time);
            max_comp = max_comp.max(m.compute_time);
            max_gflops = max_gflops.max(m.gflops());
            max_ew_gflops = max_ew_gflops.max(m.ew_gflops());
            if !total.profile.is_set() {
                total.profile = m.profile;
            }
        }
        Report {
            ranks: per_rank.len(),
            total,
            max_comm_time: max_comm,
            max_compute_time: max_comp,
            max_gflops,
            max_ew_gflops,
        }
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "p={} msgs={} bytes={} flops={:.3e} comm(max)={:.3}ms compute(max)={:.3}ms \
             rate(max)={:.2}GF/s ew(max)={:.2}GF/s",
            self.ranks,
            self.total.msgs_sent,
            self.total.bytes_sent,
            self.total.flops,
            self.max_comm_time * 1e3,
            self.max_compute_time * 1e3,
            self.max_gflops,
            self.max_ew_gflops,
        )
    }
}

/// Render an aligned text table (used by the CLI and bench harnesses to
/// print paper-style tables).
pub fn render_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let fmt_row = |cells: &[String], widths: &[usize]| -> String {
        let mut line = String::new();
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                line.push_str("  ");
            }
            line.push_str(&format!("{:>width$}", c, width = widths[i]));
        }
        line
    };
    let hdr: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    out.push_str(&fmt_row(&hdr, &widths));
    out.push('\n');
    out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
    out.push('\n');
    for row in rows {
        out.push_str(&fmt_row(row, &widths));
        out.push('\n');
    }
    out
}

/// Minimal push-based JSON writer — [`render_table`]'s sibling for
/// machine-readable output (trace export, `repro submit --json`,
/// `repro stats --json`) without a serialization dependency.
///
/// Structure is caller-managed: `begin_obj`/`end_obj`,
/// `begin_arr`/`end_arr`, `key` inside objects, then one value call
/// (`str_val`/`num`/`uint`/`int`/`boolean`/`begin_*`).  Commas and
/// string escaping are handled here; mismatched begin/end pairs are the
/// caller's bug and surface as invalid JSON downstream.
#[derive(Default)]
pub struct JsonWriter {
    buf: String,
    /// Nesting stack: (is_array, item_count, key_pending).
    stack: Vec<(bool, usize, bool)>,
}

impl JsonWriter {
    pub fn new() -> Self {
        Self::default()
    }

    /// Comma bookkeeping before a key (objects) or a value (arrays /
    /// top level).
    fn sep(&mut self, is_key: bool) {
        if let Some((is_arr, count, key_pending)) = self.stack.last_mut() {
            if *is_arr || is_key {
                if *count > 0 {
                    self.buf.push(',');
                }
                *count += 1;
            } else {
                // value inside an object: the key already wrote `:`
                debug_assert!(*key_pending, "object value without a key");
                *key_pending = false;
            }
        }
    }

    pub fn begin_obj(&mut self) -> &mut Self {
        self.sep(false);
        self.buf.push('{');
        self.stack.push((false, 0, false));
        self
    }

    pub fn end_obj(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push('}');
        self
    }

    pub fn begin_arr(&mut self) -> &mut Self {
        self.sep(false);
        self.buf.push('[');
        self.stack.push((true, 0, false));
        self
    }

    pub fn end_arr(&mut self) -> &mut Self {
        self.stack.pop();
        self.buf.push(']');
        self
    }

    pub fn key(&mut self, k: &str) -> &mut Self {
        self.sep(true);
        Self::escape_into(&mut self.buf, k);
        self.buf.push(':');
        if let Some((_, _, key_pending)) = self.stack.last_mut() {
            *key_pending = true;
        }
        self
    }

    pub fn str_val(&mut self, s: &str) -> &mut Self {
        self.sep(false);
        Self::escape_into(&mut self.buf, s);
        self
    }

    /// Finite floats only; NaN/∞ (not representable in JSON) emit
    /// `null`.  Integral values print without a fraction.
    pub fn num(&mut self, v: f64) -> &mut Self {
        self.sep(false);
        if !v.is_finite() {
            self.buf.push_str("null");
        } else if v == v.trunc() && v.abs() < 9e15 {
            self.buf.push_str(&format!("{}", v as i64));
        } else {
            self.buf.push_str(&format!("{v}"));
        }
        self
    }

    pub fn uint(&mut self, v: u64) -> &mut Self {
        self.sep(false);
        self.buf.push_str(&format!("{v}"));
        self
    }

    pub fn int(&mut self, v: i64) -> &mut Self {
        self.sep(false);
        self.buf.push_str(&format!("{v}"));
        self
    }

    pub fn boolean(&mut self, v: bool) -> &mut Self {
        self.sep(false);
        self.buf.push_str(if v { "true" } else { "false" });
        self
    }

    pub fn finish(self) -> String {
        debug_assert!(self.stack.is_empty(), "unclosed JSON container");
        self.buf
    }

    fn escape_into(buf: &mut String, s: &str) {
        buf.push('"');
        for c in s.chars() {
            match c {
                '"' => buf.push_str("\\\""),
                '\\' => buf.push_str("\\\\"),
                '\n' => buf.push_str("\\n"),
                '\r' => buf.push_str("\\r"),
                '\t' => buf.push_str("\\t"),
                c if (c as u32) < 0x20 => {
                    buf.push_str(&format!("\\u{:04x}", c as u32));
                }
                c => buf.push(c),
            }
        }
        buf.push('"');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = RankMetrics::new();
        m.on_send(100, 1e-6);
        m.on_send(50, 1e-6);
        m.on_recv(100, 2e-6);
        m.on_compute(1e6, 1e-3);
        let s = m.snapshot();
        assert_eq!(s.msgs_sent, 2);
        assert_eq!(s.bytes_sent, 150);
        assert_eq!(s.msgs_recv, 1);
        assert!((s.comm_time - 4e-6).abs() < 1e-12);
        assert_eq!(s.flops, 1e6);
    }

    #[test]
    fn report_aggregates_and_maxes() {
        let a = MetricsSnapshot { comm_time: 1.0, msgs_sent: 3, ..Default::default() };
        let b = MetricsSnapshot { comm_time: 2.0, msgs_sent: 4, ..Default::default() };
        let r = Report::aggregate(&[a, b]);
        assert_eq!(r.ranks, 2);
        assert_eq!(r.total.msgs_sent, 7);
        assert_eq!(r.max_comm_time, 2.0);
    }

    #[test]
    fn gflops_is_flops_over_compute_time() {
        let m = MetricsSnapshot { flops: 2e9, compute_time: 0.5, ..Default::default() };
        assert!((m.gflops() - 4.0).abs() < 1e-12);
        // no compute: defined as 0, not NaN
        assert_eq!(MetricsSnapshot::default().gflops(), 0.0);
        let r = Report::aggregate(&[m, MetricsSnapshot::default()]);
        assert!((r.max_gflops - 4.0).abs() < 1e-12);
    }

    #[test]
    fn elementwise_subcounters_aggregate() {
        let m = RankMetrics::new();
        m.on_compute(1e6, 1e-3); // the caller charges total compute...
        m.on_elementwise(1e6, 1e-3); // ...and attributes it elementwise
        let s = m.snapshot();
        assert_eq!(s.ew_flops, 1e6);
        // 1e6 flops / 1e-3 s = 1 GFlop/s
        assert!((s.ew_gflops() - 1.0).abs() < 1e-12);
        // no elementwise work: defined as 0, not NaN
        assert_eq!(MetricsSnapshot::default().ew_gflops(), 0.0);
        let r = Report::aggregate(&[s, MetricsSnapshot::default()]);
        assert!((r.max_ew_gflops - s.ew_gflops()).abs() < 1e-12);
        assert_eq!(r.total.ew_flops, 1e6);
        assert!(r.summary().contains("ew(max)"));
    }

    #[test]
    fn scoped_snapshot_isolates_per_job_counters() {
        // Regression for the serving runtime: a rank runs job A, then
        // job B.  B's report must reflect only B's activity — before
        // `scoped()`, quoting the raw snapshot blended A's flops into
        // B's rate.
        let m = RankMetrics::new();
        // job A: heavy
        m.on_compute(8e9, 1.0);
        m.on_send(1000, 1e-3);
        let after_a = m.snapshot();
        // job B: light
        m.on_compute(1e9, 1.0);
        m.on_recv(64, 1e-4);
        let b = m.snapshot().scoped(&after_a);
        assert_eq!(b.flops, 1e9);
        assert_eq!(b.msgs_sent, 0, "job A's send leaked into job B");
        assert_eq!(b.msgs_recv, 1);
        assert_eq!(b.bytes_recv, 64);
        assert!((b.gflops() - 1.0).abs() < 1e-9, "rate blended: {}", b.gflops());
        // the raw cumulative snapshot would have blended to 4.5 GF/s
        assert!((m.snapshot().gflops() - 4.5).abs() < 1e-9);
        // scoping against a fresh baseline is the identity
        let all = m.snapshot().scoped(&MetricsSnapshot::default());
        assert_eq!(all, m.snapshot());
    }

    #[test]
    fn profile_tag_threads_through_snapshots() {
        use crate::matrix::params::{BlockParams, MicroKernel};
        let m = RankMetrics::new();
        assert!(!m.snapshot().profile.is_set());
        let p = BlockParams { micro: MicroKernel::Mr8Nr4, ..BlockParams::default() };
        m.set_profile(ProfileTag::of(&p));
        let s = m.snapshot();
        assert!(s.profile.is_set());
        assert_eq!(s.profile.label(), "kc256 mc64 nc128 8x4");
        // attribution survives job scoping and cross-rank aggregation
        assert_eq!(s.scoped(&MetricsSnapshot::default()).profile, s.profile);
        let r = Report::aggregate(&[MetricsSnapshot::default(), s]);
        assert_eq!(r.total.profile, s.profile);
    }

    #[test]
    fn histogram_quantiles_bracket_samples() {
        let mut h = Histogram::new();
        for _ in 0..99 {
            h.record(1e-3); // 99 samples at 1 ms
        }
        h.record(1.0); // one outlier at 1 s
        assert_eq!(h.count(), 100);
        let p50 = h.p50();
        assert!(
            (0.5e-3..4e-3).contains(&p50),
            "p50 {p50} should bracket 1ms"
        );
        let p99 = h.p99();
        assert!(p99 < 0.5, "p99 {p99} should not be pulled to the outlier");
        assert!(h.quantile(1.0) >= 0.5, "max quantile must see the outlier");
        assert!((h.mean() - (99.0 * 1e-3 + 1.0) / 100.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_empty_and_merge() {
        let h = Histogram::new();
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.mean(), 0.0);
        let mut a = Histogram::new();
        a.record(1e-3);
        let mut b = Histogram::new();
        b.record(1e-3);
        b.record(2.0);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert!(a.quantile(1.0) > 1.0);
    }

    #[test]
    fn histogram_monotone_quantiles() {
        let mut h = Histogram::new();
        for i in 1..=1000u64 {
            h.record(i as f64 * 1e-5); // 10 µs .. 10 ms
        }
        let (q10, q50, q90) = (h.quantile(0.1), h.quantile(0.5), h.quantile(0.9));
        assert!(q10 <= q50 && q50 <= q90, "{q10} {q50} {q90}");
        assert!(q50 > 1e-4 && q50 < 2e-2);
    }

    #[test]
    fn histogram_empty_quantile_is_zero() {
        // Documented contract: no samples → every quantile is 0.0.
        let h = Histogram::new();
        assert_eq!(h.quantile(0.0), 0.0);
        assert_eq!(h.p50(), 0.0);
        assert_eq!(h.p99(), 0.0);
        assert_eq!(h.quantile(1.0), 0.0);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn histogram_single_sample_quantiles_are_the_sample() {
        // Regression: interpolation used to return the winning bucket's
        // *upper edge* for a lone sample (frac = 1/1), inflating p50/p99
        // of a one-job histogram by up to the bucket ratio (~58%).
        for &s in &[1e-6, 5.3e-3, 0.77, 12.0] {
            let mut h = Histogram::new();
            h.record(s);
            assert_eq!(h.p50(), s, "p50 of single sample {s}");
            assert_eq!(h.p99(), s, "p99 of single sample {s}");
            assert_eq!(h.quantile(0.0), s);
            assert_eq!(h.quantile(1.0), s);
        }
    }

    #[test]
    fn histogram_merge_then_quantile_matches_direct_recording() {
        // Merging two histograms then querying must agree exactly with
        // recording every sample into one histogram (counts AND the
        // min/max clamp range both merge).
        let samples_a = [1e-4, 2e-4, 5e-4, 1e-3];
        let samples_b = [8e-3, 2e-2, 0.4];
        let mut a = Histogram::new();
        for &s in &samples_a {
            a.record(s);
        }
        let mut b = Histogram::new();
        for &s in &samples_b {
            b.record(s);
        }
        let mut direct = Histogram::new();
        for &s in samples_a.iter().chain(&samples_b) {
            direct.record(s);
        }
        a.merge(&b);
        assert_eq!(a.count(), direct.count());
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(a.quantile(q), direct.quantile(q), "q={q}");
        }
        // merging an empty histogram is the identity
        let before = (a.p50(), a.p99(), a.quantile(1.0));
        a.merge(&Histogram::new());
        assert_eq!((a.p50(), a.p99(), a.quantile(1.0)), before);
    }

    #[test]
    fn json_writer_builds_nested_structures() {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("name").str_val("he said \"hi\"\n");
        w.key("n").uint(42);
        w.key("rate").num(1.5);
        w.key("whole").num(3.0);
        w.key("bad").num(f64::NAN);
        w.key("neg").int(-7);
        w.key("ok").boolean(true);
        w.key("items").begin_arr();
        w.num(1.0);
        w.begin_obj();
        w.key("x").uint(0);
        w.end_obj();
        w.str_val("z");
        w.end_arr();
        w.end_obj();
        let s = w.finish();
        assert_eq!(
            s,
            "{\"name\":\"he said \\\"hi\\\"\\n\",\"n\":42,\"rate\":1.5,\"whole\":3,\
             \"bad\":null,\"neg\":-7,\"ok\":true,\"items\":[1,{\"x\":0},\"z\"]}"
        );
    }

    #[test]
    fn table_renders_aligned() {
        let t = render_table(
            &["p", "time"],
            &[vec!["8".into(), "1.5".into()], vec!["512".into(), "2.25".into()]],
        );
        assert!(t.contains("p"));
        assert!(t.lines().count() == 4);
    }
}
