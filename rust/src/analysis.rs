//! Scalability analysis: efficiency, overhead, and the paper's
//! closed-form runtime models + isoefficiency solvers (§2, §4, §5).
//!
//! The simulator *measures* `T_P`; this module supplies the analytic
//! side: `T_S` models, predicted `T_P` from the paper's formulas, and
//! solvers that invert the models ("what n keeps efficiency E at p
//! cores?") so the isoefficiency benches can verify that measured
//! efficiency stays flat along the predicted isoefficiency curve.

use crate::algos::mmm_generic::NOP_COST;

/// Efficiency `E = T_S / (p · T_P)` (§2).
pub fn efficiency(ts: f64, tp: f64, p: usize) -> f64 {
    ts / (p as f64 * tp)
}

/// Speedup `S = T_S / T_P`.
pub fn speedup(ts: f64, tp: f64) -> f64 {
    ts / tp
}

/// Overhead function `T_o(W, p) = p·T_P − T_S` (§2).
pub fn overhead(ts: f64, tp: f64, p: usize) -> f64 {
    p as f64 * tp - ts
}

/// Achieved flop rate `2n³ / T_P` of an n×n MMM, in flop/s.
pub fn mmm_rate(n: usize, tp: f64) -> f64 {
    2.0 * (n as f64).powi(3) / tp
}

fn log2c(x: usize) -> f64 {
    (x.max(1) as f64).log2().ceil().max(0.0)
}

/// Model parameters shared by all predictions.
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Message start-up (s).
    pub ts: f64,
    /// Per-byte time (s/B).
    pub tw: f64,
    /// Per-core flop rate (flop/s).
    pub rate: f64,
}

impl ModelParams {
    /// Cost of transferring an m-float block (both endpoints occupied).
    fn msg(&self, floats: f64) -> f64 {
        self.ts + self.tw * 4.0 * floats
    }
}

/// Predicted `T_P` of Algorithm 2 (Grid3D/DNS MMM, §4.3) at p = q³:
/// local (n/q)³ multiply (at the block-size-dependent effective GEMM
/// rate, see [`crate::runtime::compute::gemm_efficiency`]) + log q rounds
/// of block-sum reduction.
pub fn tp_dns(n: usize, p: usize, m: &ModelParams) -> f64 {
    let q = (p as f64).cbrt().round().max(1.0);
    let b = n as f64 / q;
    let eff = crate::runtime::compute::gemm_efficiency(b as usize);
    let mult = 2.0 * b.powi(3) / (m.rate * eff);
    let rounds = log2c(q as usize);
    let reduce = rounds * (m.msg(b * b) + b * b / m.rate);
    mult + reduce
}

/// Predicted `T_P` of Algorithm 1 (generic MMM, §4.2.1) at p = q³:
/// the DNS cost plus the q² sequential ∀-loop overhead (the `4p^{2/3}`
/// term of the paper, with our calibrated per-iteration nop cost).
pub fn tp_generic(n: usize, p: usize, m: &ModelParams) -> f64 {
    let q = (p as f64).cbrt().round().max(1.0);
    tp_dns(n, p, m) + (q * q - 1.0) * NOP_COST
}

/// Predicted `T_P` of Algorithm 3 (parallel Floyd-Warshall, §5) at
/// p = q²: n pivots × (segment extract + 2 line-broadcasts + block
/// update).
pub fn tp_fw(n: usize, p: usize, m: &ModelParams) -> f64 {
    let q = (p as f64).sqrt().round().max(1.0);
    let b = n as f64 / q;
    let rounds = log2c(q as usize);
    let per_pivot = 2.0 * b / m.rate            // row+col extraction Θ(B)
        + 2.0 * rounds * m.msg(b)                // two line broadcasts
        + 2.0 * b * b / m.rate; // block update Θ(B²)
    n as f64 * per_pivot
}

/// Sequential model `T_S = 2n³/rate` (MMM and FW alike).
pub fn ts_n3(n: usize, m: &ModelParams) -> f64 {
    2.0 * (n as f64).powi(3) / m.rate
}

/// Predicted efficiency of a (model, n, p) triple.
pub fn model_efficiency(
    tp: impl Fn(usize, usize, &ModelParams) -> f64,
    n: usize,
    p: usize,
    m: &ModelParams,
) -> f64 {
    efficiency(ts_n3(n, m), tp(n, p, m), p)
}

/// Invert a `T_P` model: smallest n (multiple of `step`) whose modeled
/// efficiency at p cores reaches `target`.  Returns `None` if not
/// reached below `n_max` (the system is not scalable to that point).
pub fn isoefficiency_n(
    tp: impl Fn(usize, usize, &ModelParams) -> f64,
    p: usize,
    target: f64,
    m: &ModelParams,
    step: usize,
    n_max: usize,
) -> Option<usize> {
    let mut n = step;
    while n <= n_max {
        if model_efficiency(&tp, n, p, m) >= target {
            return Some(n);
        }
        // efficiency grows with n; exponential-then-linear probe
        n += step.max(n / 2 / step * step);
    }
    None
}

/// The paper's asymptotic isoefficiency functions, for report labels.
pub mod iso {
    /// Generic algorithm (§4.2.1): `W ∈ Θ(p^{5/3})`.
    pub fn generic(p: f64) -> f64 {
        p.powf(5.0 / 3.0)
    }

    /// Grid/DNS algorithm (§4.3): `W ∈ Θ(p log p)`.
    pub fn dns(p: f64) -> f64 {
        p * p.log2().max(1.0)
    }

    /// Parallel Floyd-Warshall (§5): `W ∈ Θ((√p log p)³)`.
    pub fn fw(p: f64) -> f64 {
        (p.sqrt() * p.log2().max(1.0)).powi(3)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m() -> ModelParams {
        ModelParams { ts: 2e-6, tw: 2.5e-10, rate: 1e10 }
    }

    #[test]
    fn efficiency_bounds() {
        let e = efficiency(100.0, 100.0 / 8.0, 8);
        assert!((e - 1.0).abs() < 1e-12);
        assert!(efficiency(100.0, 30.0, 8) < 0.5);
    }

    #[test]
    fn overhead_zero_iff_perfect() {
        assert_eq!(overhead(10.0, 10.0 / 4.0, 4), 0.0);
        assert!(overhead(10.0, 4.0, 4) > 0.0);
    }

    #[test]
    fn dns_model_efficiency_increases_with_n() {
        let p = 64;
        let e1 = model_efficiency(tp_dns, 512, p, &m());
        let e2 = model_efficiency(tp_dns, 4096, p, &m());
        let e3 = model_efficiency(tp_dns, 16384, p, &m());
        assert!(e1 < e2 && e2 < e3, "{e1} {e2} {e3}");
        assert!(e3 > 0.9, "large-n efficiency should approach 1: {e3}");
    }

    #[test]
    fn dns_model_efficiency_decreases_with_p() {
        let n = 4096;
        let e1 = model_efficiency(tp_dns, n, 8, &m());
        let e2 = model_efficiency(tp_dns, n, 512, &m());
        assert!(e1 > e2, "{e1} vs {e2}");
    }

    #[test]
    fn generic_worse_than_dns_at_large_p() {
        let n = 8192;
        let p = 512;
        assert!(tp_generic(n, p, &m()) > tp_dns(n, p, &m()));
    }

    #[test]
    fn isoefficiency_solver_finds_flat_curve() {
        let mp = m();
        let target = 0.8;
        for p in [8usize, 64, 512] {
            let n = isoefficiency_n(tp_dns, p, target, &mp, 64, 1 << 20).unwrap();
            let e = model_efficiency(tp_dns, n, p, &mp);
            assert!(e >= target, "p={p} n={n} e={e}");
            // not wildly overshooting either (solver probes coarsely)
            assert!(e <= 1.0);
        }
    }

    #[test]
    fn iso_curves_ordered() {
        // generic grows strictly faster than dns asymptotically
        assert!(iso::generic(4096.0) / iso::dns(4096.0) > iso::generic(64.0) / iso::dns(64.0));
    }

    #[test]
    fn fw_model_scales() {
        let mp = m();
        // fixed n: more cores help until comm dominates
        let e_small = model_efficiency(tp_fw, 4096, 4, &mp);
        let e_big = model_efficiency(tp_fw, 4096, 1024, &mp);
        assert!(e_small > e_big);
    }
}
