//! Intra-rank data parallelism: a rayon-free work-stealing scheduler
//! that fans independent chunks of one rank's block kernel out over the
//! persistent process-wide worker pool.
//!
//! The paper pairs FooPar's collectives with a real BLAS per core; our
//! analogue gives `Compute::Native` a `threads_per_rank` knob (see
//! [`Runtime::builder`](crate::spmd::Runtime::builder)) and splits the
//! (mc row-band × nc column-panel) tiles of the packed GEMM — the band
//! and panel edges come from the active
//! [`BlockParams`](crate::matrix::params::BlockParams) profile — and the
//! chunks of the threaded elementwise kernels — across that many cores.
//! Workers are the same reusable pool threads the SPMD launcher runs
//! ranks on ([`crate::spmd::pool`]) — checked out for the duration of
//! one parallel region, returned to the free list afterwards — so
//! repeated block products pay zero thread spawn/join cost.
//!
//! **Scheduling.**  The task index space is split into one contiguous
//! *deque* per worker (locality: adjacent GEMM tiles share packed
//! panels in cache).  A worker drains its own deque from the front with
//! a single `fetch_add`, then falls back to *stealing*: it scans the
//! other workers' deques — starting at its right neighbour so thieves
//! spread out — and claims from whichever still has work, with the same
//! atomic claim.  A full empty scan means every task is claimed and the
//! worker retires.  Each index is handed out exactly once (the
//! `fetch_add` is the claim), and the per-deque cursor overshoots its
//! end by at most one probe per worker, so the scheme is lock-free and
//! allocation-free after the initial deque vector.
//!
//! This replaces the PR-4 single global counter: handing out whole MC
//! bands from one counter left cores idle whenever `nbands` was small
//! or one band ran long (tail imbalance).  With 2D tiles + stealing,
//! a worker stuck on a heavy tile loses only that tile — the rest of
//! its deque is drained by the others.
//!
//! **Determinism.**  Chunks must write **disjoint** output (the GEMM
//! hands each tile its own row-band × column-panel rectangle of C;
//! the elementwise kernels hand out disjoint element ranges), and every
//! output element is accumulated in a fixed order *within* its chunk.
//! That is what makes the dynamic chunk→worker assignment
//! bit-deterministic: any schedule produces the same bytes.

use std::marker::PhantomData;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::spmd::pool;
use crate::trace;

/// One worker's run of the task index space: claims come off the front
/// (`next.fetch_add(1)`), by the owner or by a thief — the fetch_add
/// *is* the claim, so each index runs exactly once.
struct Deque {
    next: AtomicUsize,
    end: usize,
}

/// Run `f(task)` for every `task in 0..ntasks` with up to `threads`
/// pool workers claiming tasks via the work-stealing scheduler (module
/// docs).  Returns when every task completed.
///
/// Fast path: `threads <= 1` — or a region of one or zero tasks —
/// runs inline on the caller with **no pool traffic** (a 0-task region
/// must not check out workers just to discover there is nothing to do;
/// see the regression test below).
///
/// `threads` is the number of *compute* threads: all tasks run on pool
/// workers while the calling rank thread blocks on the completion
/// barrier.  The parked caller costs a condvar wait, not a core — it is
/// not runnable, so `world × threads_per_rank` active workers is the
/// whole CPU footprint.
pub fn run_chunks(threads: usize, ntasks: usize, f: &(dyn Fn(usize) + Sync)) {
    if threads <= 1 || ntasks <= 1 {
        // inline fast path: covers ntasks == 0 (no pool checkout)
        for task in 0..ntasks {
            let mut sp = trace::span("tile", trace::Category::Kernel);
            sp.arg("task", task as f64);
            f(task);
            drop(sp);
        }
        return;
    }
    let workers = threads.min(ntasks);
    // One contiguous deque per worker, sizes differing by at most one.
    let deques: Vec<Deque> = (0..workers)
        .map(|w| Deque {
            next: AtomicUsize::new(w * ntasks / workers),
            end: (w + 1) * ntasks / workers,
        })
        .collect();
    // Pool threads carry no tracing identity of their own — capture the
    // launching rank's here and activate it per worker below.
    let attr = trace::parallel_attr();
    pool::scoped_run(workers, &|w| {
        let _ws = attr.map(|a| trace::worker_scope(a, w));
        'claim: loop {
            // own deque first, then steal from the right neighbour onwards
            for v in 0..workers {
                let d = &deques[(w + v) % workers];
                let task = d.next.fetch_add(1, Ordering::Relaxed);
                if task < d.end {
                    let mut sp = trace::span("tile", trace::Category::Kernel);
                    sp.arg("task", task as f64);
                    f(task);
                    drop(sp);
                    continue 'claim;
                }
            }
            // a full scan found nothing left to claim anywhere
            break;
        }
    });
}

/// A shared mutable output region for disjoint parallel writes.
///
/// The scheduler's chunks write **disjoint** windows of one output
/// buffer (GEMM tiles own row-band × column-panel rectangles of C;
/// elementwise chunks own contiguous element ranges).  Rust cannot
/// express "these `&mut` windows are pairwise disjoint" across a shared
/// `Fn` closure, so this wrapper launders the exclusivity through a raw
/// pointer under an explicit contract — the same role the per-band
/// `Mutex<&mut [f32]>` vector played in PR-4, without a lock per access
/// and without requiring windows to be whole `chunks_mut` pieces.
pub(crate) struct DisjointOut<'a> {
    ptr: *mut f32,
    len: usize,
    _life: PhantomData<&'a mut f32>,
}

// SAFETY: handing the pointer to pool workers is sound because `window`
// callers guarantee disjointness (see its contract) and `run_chunks`
// does not return until every worker finished.
unsafe impl Sync for DisjointOut<'_> {}

impl<'a> DisjointOut<'a> {
    /// Wrap an exclusively-borrowed buffer for the duration of one
    /// parallel region.
    pub(crate) fn new(data: &'a mut [f32]) -> Self {
        DisjointOut { ptr: data.as_mut_ptr(), len: data.len(), _life: PhantomData }
    }

    /// Wrap `len` elements of raw (possibly uninitialized) storage.
    ///
    /// # Safety
    /// `ptr` must be valid for writes of `len` `f32`s for the lifetime
    /// `'a`.  Reading through a window is only sound for elements that
    /// were already written.
    pub(crate) unsafe fn from_raw(ptr: *mut f32, len: usize) -> Self {
        DisjointOut { ptr, len, _life: PhantomData }
    }

    /// The window `[offset, offset + len)` as a mutable slice.
    ///
    /// # Safety
    /// Concurrent callers must hand out pairwise **disjoint** windows:
    /// no two windows alive at the same time may overlap.  The window's
    /// memory must be **initialized** (a slice over uninitialized
    /// storage is undefined behavior — use [`DisjointOut::write_window`]
    /// for [`DisjointOut::from_raw`] regions).  Bounds are
    /// debug-asserted.
    #[allow(clippy::mut_from_ref)]
    pub(crate) unsafe fn window(&self, offset: usize, len: usize) -> &'a mut [f32] {
        debug_assert!(
            offset.checked_add(len).is_some_and(|hi| hi <= self.len),
            "window [{offset}, {offset}+{len}) out of bounds (len {})",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(offset), len)
    }

    /// Fill the window `[offset, offset + len)` with `gen(i)` (the
    /// window-local index), through raw pointer writes — sound over
    /// **uninitialized** storage, unlike [`DisjointOut::window`], so
    /// this is the writer for [`DisjointOut::from_raw`] output buffers.
    ///
    /// # Safety
    /// Concurrent callers must hand out pairwise disjoint windows, as
    /// for [`DisjointOut::window`].  Bounds are debug-asserted.
    pub(crate) unsafe fn write_window(
        &self,
        offset: usize,
        len: usize,
        mut gen: impl FnMut(usize) -> f32,
    ) {
        debug_assert!(
            offset.checked_add(len).is_some_and(|hi| hi <= self.len),
            "window [{offset}, {offset}+{len}) out of bounds (len {})",
            self.len
        );
        let base = self.ptr.add(offset);
        for i in 0..len {
            base.add(i).write(gen(i));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;
    use std::sync::Mutex;
    use std::thread::ThreadId;
    use std::time::Duration;

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 4] {
            let hits = AtomicU64::new(0);
            run_chunks(threads, 10, &|c| {
                hits.fetch_add(1 << c, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), (1 << 10) - 1, "threads={threads}");
        }
    }

    #[test]
    fn more_chunks_than_threads() {
        let sum = AtomicU64::new(0);
        run_chunks(2, 37, &|c| {
            sum.fetch_add(c as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..37).sum::<u64>());
    }

    #[test]
    fn more_threads_than_chunks_claims_each_once() {
        // workers = threads.min(ntasks): 8 threads, 3 chunks
        let hits = AtomicU64::new(0);
        run_chunks(8, 3, &|c| {
            hits.fetch_add(1 << c, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 0b111);
    }

    #[test]
    fn zero_chunks_is_a_noop_even_multithreaded() {
        // regression: a 0-chunk region with threads > 1 must take the
        // inline fast path, not check pool workers out and back in
        run_chunks(4, 0, &|_| panic!("no chunks to run"));
    }

    #[test]
    fn single_chunk_runs_inline_on_the_caller() {
        let caller = std::thread::current().id();
        let ran_on: Mutex<Option<ThreadId>> = Mutex::new(None);
        run_chunks(4, 1, &|_| {
            *ran_on.lock().unwrap() = Some(std::thread::current().id());
        });
        assert_eq!(*ran_on.lock().unwrap(), Some(caller), "1 chunk must not hit the pool");
    }

    #[test]
    fn disjoint_writes_through_mutexes() {
        let out: Vec<std::sync::Mutex<u64>> = (0..16).map(|_| std::sync::Mutex::new(0)).collect();
        run_chunks(4, 16, &|c| {
            *out[c].lock().unwrap() = c as u64 * 3;
        });
        for (i, slot) in out.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), i as u64 * 3);
        }
    }

    #[test]
    fn adversarial_skew_steals_the_stuck_workers_deque() {
        // One huge task at index 0 (worker 0's deque) + many tiny ones.
        // While worker 0 is stuck on it, the rest of its deque must be
        // drained by thieves — the tail-imbalance fix this scheduler
        // exists for.
        const NTASKS: usize = 16;
        const WORKERS: usize = 4; // worker 0 owns [0, 4)
        let ran_on: Vec<Mutex<Option<ThreadId>>> =
            (0..NTASKS).map(|_| Mutex::new(None)).collect();
        run_chunks(WORKERS, NTASKS, &|c| {
            if c == 0 {
                std::thread::sleep(Duration::from_millis(120));
            }
            *ran_on[c].lock().unwrap() = Some(std::thread::current().id());
        });
        let big = ran_on[0].lock().unwrap().expect("task 0 ran");
        for (c, slot) in ran_on.iter().enumerate() {
            let tid = slot.lock().unwrap().expect("every task ran");
            if (1..4).contains(&c) {
                assert_ne!(
                    tid, big,
                    "task {c} in the stuck worker's deque was not stolen"
                );
            }
        }
    }

    #[test]
    fn write_window_fills_uninitialized_storage() {
        let len = 1000usize;
        let mut out: Vec<f32> = Vec::with_capacity(len);
        {
            // SAFETY: capacity reserved above; chunks cover [0, len)
            let dst = unsafe { DisjointOut::from_raw(out.as_mut_ptr(), len) };
            run_chunks(4, 10, &|c| {
                let lo = c * 100;
                // SAFETY: disjoint 100-element windows
                unsafe { dst.write_window(lo, 100, |i| (lo + i) as f32) };
            });
        }
        // SAFETY: all elements written above
        unsafe { out.set_len(len) };
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn disjoint_out_windows_are_independent() {
        let mut buf = vec![0.0f32; 64];
        {
            let out = DisjointOut::new(&mut buf);
            run_chunks(4, 8, &|c| {
                // SAFETY: disjoint 8-element windows
                let w = unsafe { out.window(c * 8, 8) };
                for (i, v) in w.iter_mut().enumerate() {
                    *v = (c * 8 + i) as f32;
                }
            });
        }
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }
}
