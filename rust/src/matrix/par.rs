//! Intra-rank data parallelism: fan independent chunks of one rank's
//! block kernel out over the persistent process-wide worker pool.
//!
//! The paper pairs FooPar's collectives with a real BLAS per core; our
//! analogue gives `Compute::Native` a `threads_per_rank` knob (see
//! [`Runtime::builder`](crate::spmd::Runtime::builder)) and splits the
//! MC row-panels of the packed GEMM across that many cores.  Workers are
//! the same reusable pool threads the SPMD launcher runs ranks on
//! ([`crate::spmd::pool`]) — checked out for the duration of one
//! parallel region, returned to the free list afterwards — so repeated
//! block products pay zero thread spawn/join cost.
//!
//! Chunks must write **disjoint** output (the GEMM hands each chunk its
//! own row band), which is what makes the dynamic chunk→worker
//! assignment below bit-deterministic: any schedule produces the same
//! bytes.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::spmd::pool;

/// Run `f(chunk)` for every `chunk in 0..nchunks` with up to `threads`
/// pool workers claiming chunks dynamically.  Returns when every chunk
/// completed.  `threads <= 1` (or a single chunk) runs inline on the
/// caller with no pool traffic.
///
/// `threads` is the number of *compute* threads: all chunks run on pool
/// workers while the calling rank thread blocks on the completion
/// barrier.  The parked caller costs a condvar wait, not a core — it is
/// not runnable, so `world × threads_per_rank` active workers is the
/// whole CPU footprint.
pub fn run_chunks(threads: usize, nchunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if threads <= 1 || nchunks <= 1 {
        for chunk in 0..nchunks {
            f(chunk);
        }
        return;
    }
    let workers = threads.min(nchunks);
    let next = AtomicUsize::new(0);
    pool::scoped_run(workers, &|_worker| loop {
        let chunk = next.fetch_add(1, Ordering::Relaxed);
        if chunk >= nchunks {
            break;
        }
        f(chunk);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_chunk_runs_exactly_once() {
        for threads in [1usize, 2, 4] {
            let hits = AtomicU64::new(0);
            run_chunks(threads, 10, &|c| {
                hits.fetch_add(1 << c, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), (1 << 10) - 1, "threads={threads}");
        }
    }

    #[test]
    fn more_chunks_than_threads() {
        let sum = AtomicU64::new(0);
        run_chunks(2, 37, &|c| {
            sum.fetch_add(c as u64, Ordering::SeqCst);
        });
        assert_eq!(sum.load(Ordering::SeqCst), (0..37).sum::<u64>());
    }

    #[test]
    fn zero_chunks_is_a_noop() {
        run_chunks(4, 0, &|_| panic!("no chunks to run"));
    }

    #[test]
    fn disjoint_writes_through_mutexes() {
        let out: Vec<std::sync::Mutex<u64>> = (0..16).map(|_| std::sync::Mutex::new(0)).collect();
        run_chunks(4, 16, &|c| {
            *out[c].lock().unwrap() = c as u64 * 3;
        });
        for (i, slot) in out.iter().enumerate() {
            assert_eq!(*slot.lock().unwrap(), i as u64 * 3);
        }
    }
}
