//! Runtime blocking parameters for the packed GEMM kernel.
//!
//! The BLIS-style kernel in [`super::gemm`] historically hardcoded its cache
//! blocking (`KC=256/MC=64/NC=128`) and the elementwise parallel threshold at
//! compile time. [`BlockParams`] lifts those into a runtime value so a
//! per-host tune profile (see [`crate::tune`]) can drive the kernel: `kc`,
//! `mc`, `nc` and `ew_par_threshold` are plain fields, while the register
//! microkernel shape stays monomorphized — [`MicroKernel`] selects one of a
//! small set of compiled MR×NR variants, so the hot loop never pays a
//! dynamic dispatch per tile.
//!
//! Determinism contract: for a **fixed** `BlockParams`, results are
//! bit-identical across thread counts and transports (each output element
//! accumulates k-ascending within each KC block, KC blocks ascending).
//! Changing `kc` regroups the dense (+,×) sum and may legitimately change
//! low-order bits; `mc`/`nc`/`micro` never do (they only re-tile the same
//! accumulation order), and the tropical (min,+) semiring is exact under any
//! blocking.

/// Default KC (k-dimension cache block, sized for L1-resident packed strips).
pub const DEFAULT_KC: usize = 256;
/// Default MC (row band height, A-panel L2 residency).
pub const DEFAULT_MC: usize = 64;
/// Default NC (column panel width — the unit of cross-thread work stealing).
pub const DEFAULT_NC: usize = 128;
/// Default minimum element count before elementwise kernels go parallel.
pub const DEFAULT_EW_PAR_THRESHOLD: usize = 1 << 20;

/// Register microkernel shape: one of the monomorphized MR×NR variants
/// compiled into the binary. The profile picks a variant; the kernel
/// dispatches once per `banded_product` call, not per tile.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MicroKernel {
    /// 8×8 — the historical default; widest accumulator tile.
    #[default]
    Mr8Nr8,
    /// 8×4 — narrower N, for hosts where 8×8 spills registers.
    Mr8Nr4,
    /// 4×8 — shorter M, favours wide rows with few of them.
    Mr4Nr8,
}

impl MicroKernel {
    /// All compiled variants, in sweep order.
    pub const ALL: [MicroKernel; 3] =
        [MicroKernel::Mr8Nr8, MicroKernel::Mr8Nr4, MicroKernel::Mr4Nr8];

    /// Rows of the register tile.
    pub fn mr(self) -> usize {
        match self {
            MicroKernel::Mr8Nr8 | MicroKernel::Mr8Nr4 => 8,
            MicroKernel::Mr4Nr8 => 4,
        }
    }

    /// Columns of the register tile.
    pub fn nr(self) -> usize {
        match self {
            MicroKernel::Mr8Nr8 | MicroKernel::Mr4Nr8 => 8,
            MicroKernel::Mr8Nr4 => 4,
        }
    }

    /// Stable textual name used in profiles and reports ("8x8", "8x4", "4x8").
    pub fn name(self) -> &'static str {
        match self {
            MicroKernel::Mr8Nr8 => "8x8",
            MicroKernel::Mr8Nr4 => "8x4",
            MicroKernel::Mr4Nr8 => "4x8",
        }
    }

    /// Inverse of [`MicroKernel::name`].
    pub fn by_name(name: &str) -> Option<MicroKernel> {
        MicroKernel::ALL.into_iter().find(|m| m.name() == name)
    }
}

/// Runtime cache-blocking parameters for the packed GEMM kernel plus the
/// elementwise parallel threshold. Threaded from `Runtime::builder()` /
/// `MachineConfig` through `Ctx` into every `Compute::Native` kernel call.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockParams {
    /// k-dimension cache block depth.
    pub kc: usize,
    /// Row band height (must be a multiple of `micro.mr()`).
    pub mc: usize,
    /// Column panel width (must be a multiple of `micro.nr()`).
    pub nc: usize,
    /// Register microkernel variant.
    pub micro: MicroKernel,
    /// Minimum element count before elementwise kernels use threads.
    pub ew_par_threshold: usize,
}

impl Default for BlockParams {
    fn default() -> Self {
        BlockParams {
            kc: DEFAULT_KC,
            mc: DEFAULT_MC,
            nc: DEFAULT_NC,
            micro: MicroKernel::default(),
            ew_par_threshold: DEFAULT_EW_PAR_THRESHOLD,
        }
    }
}

impl BlockParams {
    /// Check the structural invariants the kernel relies on: positive blocks,
    /// `mc` a multiple of MR and `nc` a multiple of NR (pack strips and the
    /// work-stealing tile grid both assume whole register tiles per band).
    pub fn validate(&self) -> Result<(), String> {
        let (mr, nr) = (self.micro.mr(), self.micro.nr());
        if self.kc == 0 {
            return Err("kc must be positive".into());
        }
        if self.mc == 0 || self.mc % mr != 0 {
            return Err(format!(
                "mc={} must be a positive multiple of MR={mr} ({})",
                self.mc,
                self.micro.name()
            ));
        }
        if self.nc == 0 || self.nc % nr != 0 {
            return Err(format!(
                "nc={} must be a positive multiple of NR={nr} ({})",
                self.nc,
                self.micro.name()
            ));
        }
        if self.ew_par_threshold == 0 {
            return Err("ew_par_threshold must be positive".into());
        }
        Ok(())
    }

    /// Compact human-readable label ("kc256 mc64 nc128 8x8"), used for bench
    /// provenance and report headers.
    pub fn label(&self) -> String {
        format!(
            "kc{} mc{} nc{} {}",
            self.kc,
            self.mc,
            self.nc,
            self.micro.name()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_historical_constants() {
        let p = BlockParams::default();
        assert_eq!((p.kc, p.mc, p.nc), (256, 64, 128));
        assert_eq!((p.micro.mr(), p.micro.nr()), (8, 8));
        assert_eq!(p.ew_par_threshold, 1 << 20);
        assert!(p.validate().is_ok());
    }

    #[test]
    fn micro_names_round_trip() {
        for m in MicroKernel::ALL {
            assert_eq!(MicroKernel::by_name(m.name()), Some(m));
        }
        assert_eq!(MicroKernel::by_name("16x1"), None);
    }

    #[test]
    fn validate_rejects_misaligned_bands() {
        let bad_mc = BlockParams {
            mc: 12,
            ..BlockParams::default()
        };
        assert!(bad_mc.validate().is_err());
        let bad_nc = BlockParams {
            nc: 100,
            micro: MicroKernel::Mr8Nr8,
            ..BlockParams::default()
        };
        assert!(bad_nc.validate().is_err());
        let ok_nc_for_4 = BlockParams {
            nc: 100,
            micro: MicroKernel::Mr8Nr4,
            ..BlockParams::default()
        };
        assert!(ok_nc_for_4.validate().is_ok());
        assert!(BlockParams {
            kc: 0,
            ..BlockParams::default()
        }
        .validate()
        .is_err());
    }

    #[test]
    fn label_is_compact() {
        assert_eq!(BlockParams::default().label(), "kc256 mc64 nc128 8x8");
    }
}
