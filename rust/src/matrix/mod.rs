pub mod dense;
pub mod gemm;
pub mod block;
