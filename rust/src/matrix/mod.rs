pub mod block;
pub mod buf;
pub mod dense;
pub mod gemm;
pub mod par;
pub mod params;
