//! Dense row-major f32 matrices — the substrate the paper gets from
//! JBLAS/MKL.  Blocks of the distributed matrices are `Mat`s; the heavy
//! products go through [`crate::matrix::gemm`] (native) or the PJRT
//! engine ([`crate::runtime`]).
//!
//! Elements live in a shared copy-on-write [`Buf`], so cloning a `Mat`
//! (and moving it through shmem collectives) is a reference-count bump —
//! see [`crate::matrix::buf`] for the zero-copy story.

use super::buf::Buf;
use crate::data::value::Data;
use crate::testing::Rng;

/// Dense row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    /// Row-major elements in a shared copy-on-write buffer.  Read access
    /// derefs straight to the `Vec`; the first `&mut` access after a
    /// clone pays the deep copy (`Arc::make_mut`).
    pub data: Buf,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols].into() }
    }

    pub fn filled(rows: usize, cols: usize, v: f32) -> Self {
        Mat { rows, cols, data: vec![v; rows * cols].into() }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data: data.into() }
    }

    /// Do `self` and `other` share one element allocation?  True after a
    /// clone (or a shmem collective hop) until either side mutates — the
    /// zero-copy assertion used by the data-plane tests.
    pub fn shares_buffer(&self, other: &Mat) -> bool {
        Buf::shares_allocation(&self.data, &other.data)
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Deterministic pseudo-random matrix in [-1, 1) — the analogue of the
    /// paper's `MJBLProxy(SEED, b)` lazily-materialized random blocks.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let data = (0..rows * cols)
            .map(|_| rng.gen_f32_range(-1.0, 1.0))
            .collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c` as a fresh vector.
    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    /// Columns `[lo, hi)` as a fresh `rows × (hi−lo)` matrix (the column
    /// panels of the pipelined DNS variant).
    pub fn col_slice(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.cols, "col_slice [{lo}, {hi}) of {} cols", self.cols);
        let w = hi - lo;
        let mut data = Vec::with_capacity(self.rows * w);
        for r in 0..self.rows {
            data.extend_from_slice(&self.data[r * self.cols + lo..r * self.cols + hi]);
        }
        Mat { rows: self.rows, cols: w, data: data.into() }
    }

    /// Horizontal concatenation of equal-height matrices (reassembling
    /// the pipelined DNS column panels).
    pub fn hstack(parts: &[&Mat]) -> Mat {
        assert!(!parts.is_empty(), "hstack of zero matrices");
        let rows = parts[0].rows;
        assert!(parts.iter().all(|m| m.rows == rows), "hstack needs equal row counts");
        let cols: usize = parts.iter().map(|m| m.cols).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for m in parts {
                data.extend_from_slice(m.row(r));
            }
        }
        Mat { rows, cols, data: data.into() }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                t.set(c, r, self.at(r, c));
            }
        }
        t
    }

    /// Extract the (bi, bj) block of edge `b` (matrix dims must be
    /// divisible by `b`).  This is the "user partitions the input" step
    /// FooPar deliberately leaves to the caller (§3.3).
    pub fn block(&self, bi: usize, bj: usize, b: usize) -> Mat {
        assert!(self.rows % b == 0 && self.cols % b == 0);
        let mut out = Mat::zeros(b, b);
        for r in 0..b {
            let src = (bi * b + r) * self.cols + bj * b;
            out.data[r * b..(r + 1) * b].copy_from_slice(&self.data[src..src + b]);
        }
        out
    }

    /// Write `blk` into position (bi, bj) of the block decomposition.
    pub fn set_block(&mut self, bi: usize, bj: usize, blk: &Mat) {
        let b = blk.rows;
        assert_eq!(blk.cols, b);
        for r in 0..b {
            let dst = (bi * b + r) * self.cols + bj * b;
            self.data[dst..dst + b].copy_from_slice(blk.row(r));
        }
    }

    /// Frobenius norm (test diagnostics).
    pub fn frob(&self) -> f64 {
        self.data.iter().map(|&v| (v as f64) * (v as f64)).sum::<f64>().sqrt()
    }

    /// Maximum absolute elementwise difference.
    pub fn max_abs_diff(&self, other: &Mat) -> f32 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f32 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }
}

impl Data for Mat {
    fn byte_size(&self) -> usize {
        self.data.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_indexing() {
        let mut m = Mat::zeros(2, 3);
        m[(1, 2)] = 5.0;
        assert_eq!(m.at(1, 2), 5.0);
        assert_eq!(m.row(1), &[0.0, 0.0, 5.0]);
        assert_eq!(m.col(2), vec![0.0, 5.0]);
    }

    #[test]
    fn col_slice_then_hstack_roundtrips() {
        let m = Mat::random(5, 9, 11);
        let a = m.col_slice(0, 3);
        let b = m.col_slice(3, 4);
        let c = m.col_slice(4, 9);
        assert_eq!(a.cols, 3);
        assert_eq!(Mat::hstack(&[&a, &b, &c]), m);
    }

    #[test]
    fn eye_and_transpose() {
        let e = Mat::eye(3);
        assert_eq!(e.transpose(), e);
        let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.at(2, 1), 6.0);
    }

    #[test]
    fn random_is_deterministic_per_seed() {
        let a = Mat::random(4, 4, 7);
        let b = Mat::random(4, 4, 7);
        let c = Mat::random(4, 4, 8);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert!(a.data.iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn block_roundtrip() {
        let m = Mat::random(8, 8, 1);
        let blk = m.block(1, 0, 4);
        assert_eq!(blk.at(0, 0), m.at(4, 0));
        assert_eq!(blk.at(3, 3), m.at(7, 3));
        let mut m2 = Mat::zeros(8, 8);
        for bi in 0..2 {
            for bj in 0..2 {
                m2.set_block(bi, bj, &m.block(bi, bj, 4));
            }
        }
        assert_eq!(m, m2);
    }

    #[test]
    fn byte_size_is_4_per_element() {
        assert_eq!(Mat::zeros(10, 3).byte_size(), 120);
    }

    #[test]
    fn clone_is_zero_copy_until_mutation() {
        let a = Mat::random(16, 16, 3);
        let mut b = a.clone();
        assert!(a.shares_buffer(&b));
        b.set(0, 0, 42.0); // copy-on-write kicks in here
        assert!(!a.shares_buffer(&b));
        assert_ne!(a.at(0, 0), 42.0);
        assert_eq!(b.at(0, 0), 42.0);
    }

    #[test]
    fn max_abs_diff_and_frob() {
        let a = Mat::filled(2, 2, 1.0);
        let b = Mat::filled(2, 2, 1.5);
        assert_eq!(a.max_abs_diff(&b), 0.5);
        assert!((a.frob() - 2.0).abs() < 1e-9);
    }
}
