//! Shared copy-on-write float buffers — the zero-copy substrate of the
//! data plane.
//!
//! Every [`Mat`](super::dense::Mat) owns its elements through a [`Buf`]:
//! an `Arc<Vec<f32>>` behind `Deref`/`DerefMut`.  Cloning a `Buf` (and
//! therefore a `Mat`, a `Block::Real`, or any message payload built from
//! them) is a reference-count bump, so shared-memory collectives move
//! blocks **by reference**: a `bcast` fans the same allocation out to
//! every rank, a `shift` hands ownership over, and the pipelined
//! algorithms' per-step block clones cost nothing.  The first mutable
//! access through `DerefMut` triggers `Arc::make_mut` — a deep copy *only
//! if* the allocation is still shared (copy-on-write), so single-owner
//! hot loops pay one atomic check, not a copy.
//!
//! The paper gets this for free from the JVM (JBLAS matrices travel as
//! references between threads); reproducing it here is what keeps the
//! measured data path at memory-bandwidth speed instead of `memcpy`
//! speed.

use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// A shared, copy-on-write `Vec<f32>`.  See the module docs.
#[derive(Clone, Debug)]
pub struct Buf(Arc<Vec<f32>>);

impl Buf {
    /// Wrap a vector (no copy).
    pub fn from_vec(v: Vec<f32>) -> Self {
        Buf(Arc::new(v))
    }

    /// Do `a` and `b` share one allocation?  The zero-copy assertion used
    /// by tests: after a shmem `bcast`, every rank's block satisfies
    /// `Buf::shares_allocation(root, mine)`.
    pub fn shares_allocation(a: &Buf, b: &Buf) -> bool {
        Arc::ptr_eq(&a.0, &b.0)
    }

    /// How many owners this allocation currently has (diagnostics).
    pub fn owners(&self) -> usize {
        Arc::strong_count(&self.0)
    }

    /// Iterate the elements (no copy, no ownership change).
    pub fn iter(&self) -> std::slice::Iter<'_, f32> {
        self.0.iter()
    }
}

impl From<Vec<f32>> for Buf {
    fn from(v: Vec<f32>) -> Self {
        Buf::from_vec(v)
    }
}

impl FromIterator<f32> for Buf {
    fn from_iter<I: IntoIterator<Item = f32>>(iter: I) -> Self {
        Buf::from_vec(iter.into_iter().collect())
    }
}

impl Deref for Buf {
    type Target = Vec<f32>;
    #[inline]
    fn deref(&self) -> &Vec<f32> {
        &self.0
    }
}

impl DerefMut for Buf {
    /// Copy-on-write: clones the allocation iff it is shared.
    #[inline]
    fn deref_mut(&mut self) -> &mut Vec<f32> {
        Arc::make_mut(&mut self.0)
    }
}

impl PartialEq for Buf {
    fn eq(&self, other: &Self) -> bool {
        // same allocation short-circuit, then contents
        Arc::ptr_eq(&self.0, &other.0) || *self.0 == *other.0
    }
}

impl<'a> IntoIterator for &'a Buf {
    type Item = &'a f32;
    type IntoIter = std::slice::Iter<'a, f32>;
    fn into_iter(self) -> Self::IntoIter {
        self.0.iter()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clone_shares_allocation() {
        let a = Buf::from_vec(vec![1.0, 2.0, 3.0]);
        let b = a.clone();
        assert!(Buf::shares_allocation(&a, &b));
        assert_eq!(a.owners(), 2);
        assert_eq!(a, b);
    }

    #[test]
    fn mutation_unshares_and_preserves_original() {
        let a = Buf::from_vec(vec![1.0, 2.0]);
        let mut b = a.clone();
        b[0] = 9.0; // copy-on-write: b gets its own allocation here
        assert!(!Buf::shares_allocation(&a, &b));
        assert_eq!(a[0], 1.0);
        assert_eq!(b[0], 9.0);
    }

    #[test]
    fn unique_mutation_keeps_allocation() {
        let mut a = Buf::from_vec(vec![0.0; 4]);
        let before = a.as_ptr();
        a[2] = 5.0; // sole owner: in-place, no copy
        assert_eq!(a.as_ptr(), before);
        assert_eq!(a[2], 5.0);
    }

    #[test]
    fn equality_is_by_contents_across_allocations() {
        let a = Buf::from_vec(vec![1.0, 2.0]);
        let b = Buf::from_vec(vec![1.0, 2.0]);
        assert!(!Buf::shares_allocation(&a, &b));
        assert_eq!(a, b);
        assert_ne!(a, Buf::from_vec(vec![1.0, 3.0]));
    }

    #[test]
    fn collect_and_iterate() {
        let b: Buf = (0..3).map(|i| i as f32).collect();
        let sum: f32 = (&b).into_iter().sum();
        assert_eq!(sum, 3.0);
        assert_eq!(b.len(), 3);
    }
}
