//! Lazy matrix blocks — FooPar's `MJBLProxy` idea.
//!
//! Algorithm 1 of the paper fills the distributed matrices with
//! `MJBLProxy(SEED, b)` objects: *lazy* blocks that know their size and
//! seed but materialize data only when touched.  This is what lets an
//! SPMD program "generate" the whole input on every rank with no space
//! or time overhead (§3.2), and what lets our *modeled* mode run the
//! paper's n=40000, p=512 configuration on a laptop: proxies flow
//! through the full communication machinery with correct wire sizes,
//! but no floats are ever allocated.

use super::dense::Mat;
use crate::data::value::Data;

/// A block of a distributed matrix: materialized data or a lazy proxy.
#[derive(Clone, Debug, PartialEq)]
pub enum Block {
    /// Materialized data (real mode).
    Real(Mat),
    /// Lazy block: dimensions + generation seed (modeled mode, and the
    /// deferred-generation trick of Alg. 1's `MJBLProxy`).
    Proxy { rows: usize, cols: usize, seed: u64 },
}

impl Block {
    pub fn real(m: Mat) -> Self {
        Block::Real(m)
    }

    /// A lazy random block of edge `b` (square), like `MJBLProxy(seed, b)`.
    pub fn proxy(b: usize, seed: u64) -> Self {
        Block::Proxy { rows: b, cols: b, seed }
    }

    pub fn rows(&self) -> usize {
        match self {
            Block::Real(m) => m.rows,
            Block::Proxy { rows, .. } => *rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Block::Real(m) => m.cols,
            Block::Proxy { cols, .. } => *cols,
        }
    }

    pub fn is_proxy(&self) -> bool {
        matches!(self, Block::Proxy { .. })
    }

    /// Materialize: proxies generate their deterministic random content.
    pub fn materialize(&self) -> Mat {
        match self {
            Block::Real(m) => m.clone(),
            Block::Proxy { rows, cols, seed } => Mat::random(*rows, *cols, *seed),
        }
    }

    /// Borrow the data if real (panics on proxies — modeled-mode code
    /// paths must never touch element data).
    pub fn as_mat(&self) -> &Mat {
        match self {
            Block::Real(m) => m,
            Block::Proxy { .. } => panic!("attempted to read data of a proxy block"),
        }
    }

    /// Take ownership of the data if real (panics on proxies).  The
    /// in-place accumulate paths use this so a uniquely-owned block is
    /// mutated with **zero copies** — `as_mat().clone()` would leave a
    /// second owner behind and force the copy-on-write.
    pub fn into_mat(self) -> Mat {
        match self {
            Block::Real(m) => m,
            Block::Proxy { .. } => panic!("attempted to read data of a proxy block"),
        }
    }

    /// Horizontal concatenation of column panels — reassembling a block
    /// computed panel-by-panel (the pipelined DNS variant).  Real panels
    /// concatenate data; proxy panels merge into a proxy of the combined
    /// width with the derived seed 0, exactly like every modeled-mode
    /// product block — so a panel-wise modeled run reassembles to the
    /// same block metadata as the blocking one.
    pub fn hstack(parts: Vec<Block>) -> Block {
        assert!(!parts.is_empty(), "hstack of zero blocks");
        if parts.len() == 1 {
            return parts.into_iter().next().unwrap();
        }
        let rows = parts[0].rows();
        assert!(parts.iter().all(|b| b.rows() == rows), "hstack needs equal row counts");
        if parts.iter().any(Block::is_proxy) {
            assert!(
                parts.iter().all(Block::is_proxy),
                "hstack of mixed real/proxy panels is a mode-confusion bug"
            );
            let cols = parts.iter().map(Block::cols).sum();
            return Block::Proxy { rows, cols, seed: 0 };
        }
        let mats: Vec<&Mat> = parts.iter().map(Block::as_mat).collect();
        Block::Real(Mat::hstack(&mats))
    }
}

/// A lazily-evaluated distributed matrix: hands out the (i, j) block of a
/// conceptual (q·b)×(q·b) matrix.  Every rank constructs the source (it
/// is just a seed), but only owners materialize blocks — the exact
/// semantics of Alg. 1's `Array.fill(M, M)(MJBLProxy(SEED, b))`.
#[derive(Clone, Copy, Debug)]
pub struct BlockSource {
    /// Block edge.
    pub b: usize,
    /// Base seed of the whole matrix.
    pub seed: u64,
    /// If true, blocks stay proxies (modeled mode).
    pub proxy: bool,
}

impl BlockSource {
    pub fn real(b: usize, seed: u64) -> Self {
        BlockSource { b, seed, proxy: false }
    }

    pub fn proxy(b: usize, seed: u64) -> Self {
        BlockSource { b, seed, proxy: true }
    }

    /// Per-block seed: mixes (base, i, j) so blocks are independent but
    /// reproducible from any rank.
    pub fn block_seed(&self, i: usize, j: usize) -> u64 {
        let mut s = self.seed ^ 0x51_7c_c1_b7_27_22_0a_95;
        for v in [i as u64, j as u64] {
            s ^= v.wrapping_add(0x9E37_79B9_7F4A_7C15);
            s = s.rotate_left(23).wrapping_mul(0x2545_F491_4F6C_DD1D);
        }
        s
    }

    /// The (i, j) block.
    pub fn block(&self, i: usize, j: usize) -> Block {
        let s = self.block_seed(i, j);
        if self.proxy {
            Block::proxy(self.b, s)
        } else {
            Block::Real(Mat::random(self.b, self.b, s))
        }
    }

    /// Materialize the full q×q-block matrix (verification in real mode).
    pub fn assemble(&self, q: usize) -> Mat {
        let n = q * self.b;
        let mut m = Mat::zeros(n, n);
        for i in 0..q {
            for j in 0..q {
                m.set_block(i, j, &self.block(i, j).materialize());
            }
        }
        m
    }
}

impl Data for Block {
    /// Wire size: proxies *cost* what their materialized form would —
    /// the whole point of the modeled mode is that communication is
    /// charged as if the data were real.
    fn byte_size(&self) -> usize {
        self.rows() * self.cols() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn proxy_materializes_deterministically() {
        let p = Block::proxy(8, 42);
        assert_eq!(p.materialize(), Mat::random(8, 8, 42));
        assert_eq!(p.materialize(), p.materialize());
    }

    #[test]
    fn proxy_costs_like_real() {
        let p = Block::proxy(16, 1);
        let r = Block::real(Mat::zeros(16, 16));
        assert_eq!(p.byte_size(), r.byte_size());
        assert_eq!(p.byte_size(), 16 * 16 * 4);
    }

    #[test]
    fn real_roundtrip() {
        let m = Mat::random(4, 4, 3);
        let b = Block::real(m.clone());
        assert!(!b.is_proxy());
        assert_eq!(b.as_mat(), &m);
        assert_eq!(b.materialize(), m);
    }

    #[test]
    #[should_panic(expected = "proxy")]
    fn as_mat_panics_on_proxy() {
        Block::proxy(4, 0).as_mat();
    }

    #[test]
    fn source_blocks_reproducible_and_distinct() {
        let s = BlockSource::real(8, 42);
        assert_eq!(s.block(1, 2), s.block(1, 2));
        assert_ne!(s.block(1, 2), s.block(2, 1));
        assert_ne!(s.block(0, 0), BlockSource::real(8, 43).block(0, 0));
    }

    #[test]
    fn proxy_source_matches_real_when_materialized() {
        let r = BlockSource::real(4, 9);
        let p = BlockSource::proxy(4, 9);
        assert!(p.block(2, 3).is_proxy());
        assert_eq!(p.block(2, 3).materialize(), r.block(2, 3).materialize());
    }

    #[test]
    fn assemble_places_blocks() {
        let s = BlockSource::real(4, 5);
        let full = s.assemble(3);
        assert_eq!(full.rows, 12);
        assert_eq!(full.block(1, 2, 4), s.block(1, 2).materialize());
    }
}
