//! Native block linear algebra — the "BLAS" substitute.
//!
//! The paper runs MKL/JBLAS on each core; here the native fallback is a
//! cache-blocked ikj GEMM.  It is used (a) when no PJRT artifact matches
//! the block size, (b) as the baseline the PJRT path is compared against,
//! and (c) for the (min,+) semiring where BLAS does not apply.

use super::dense::Mat;

/// Tile edge for the register/cache blocking of the native GEMM.
const TILE: usize = 64;

/// `C = A · B` (native, cache-blocked ikj).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_acc_into(&mut c, a, b);
    c
}

/// `C += A · B` — the DNS partial-sum hot spot, accumulating in place.
pub fn matmul_acc_into(c: &mut Mat, a: &Mat, b: &Mat) {
    assert_eq!(a.cols, b.rows, "inner dims: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let (m, k, n) = (a.rows, a.cols, b.cols);
    // Tiled over (i, k) so each inner loop is a saxpy over a contiguous
    // row of B — vectorizer-friendly, no transposes needed.
    for it in (0..m).step_by(TILE) {
        let ie = (it + TILE).min(m);
        for kt in (0..k).step_by(TILE) {
            let ke = (kt + TILE).min(k);
            for i in it..ie {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut c.data[i * n..(i + 1) * n];
                for kk in kt..ke {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
}

/// `A + B` elementwise (the reduceD combine).
pub fn add(a: &Mat, b: &Mat) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let data = a.data.iter().zip(&b.data).map(|(x, y)| x + y).collect();
    Mat { rows: a.rows, cols: a.cols, data }
}

/// "No edge" sentinel of the (min,+) semiring — kept in sync with
/// python/compile/kernels/ref.py::INF.
pub const INF: f32 = 1e30;

/// Tropical product `out[i,j] = min(INF, min_k a[i,k] + b[k,j])`.
pub fn minplus_matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut out = Mat::filled(m, n, INF);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        let orow = &mut out.data[i * n..(i + 1) * n];
        for kk in 0..k {
            let aik = arow[kk];
            if aik >= INF {
                continue;
            }
            let brow = &b.data[kk * n..(kk + 1) * n];
            for (ov, bv) in orow.iter_mut().zip(brow) {
                let cand = aik + bv;
                if cand < *ov {
                    *ov = cand;
                }
            }
        }
    }
    out
}

/// Floyd-Warshall pivot update on a block (Alg. 3 lines 9-14):
/// `d[i,j] = min(d[i,j], kj[i] + ik[j])`, where `ik` is the pivot-row
/// segment and `kj` the pivot-column segment.
pub fn fw_update_into(d: &mut Mat, ik: &[f32], kj: &[f32]) {
    assert_eq!(ik.len(), d.cols);
    assert_eq!(kj.len(), d.rows);
    for i in 0..d.rows {
        let base = kj[i];
        if base >= INF {
            continue;
        }
        let row = &mut d.data[i * d.cols..(i + 1) * d.cols];
        for (dv, &ikv) in row.iter_mut().zip(ik) {
            let cand = base + ikv;
            if cand < *dv {
                *dv = cand;
            }
        }
    }
}

/// FLOP count of an (m,k)x(k,n) GEMM (2 flops per MAC) — used by the
/// modeled-compute mode and the efficiency reports.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, prop_check, Rng};

    /// Triple-loop reference for the blocked implementation.
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f32;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        prop_check("gemm vs naive", 25, |rng: &mut Rng| {
            let m = 1 + rng.gen_range(40);
            let k = 1 + rng.gen_range(40);
            let n = 1 + rng.gen_range(40);
            let a = Mat::random(m, k, rng.next_u64());
            let b = Mat::random(k, n, rng.next_u64());
            let got = matmul(&a, &b);
            let want = matmul_naive(&a, &b);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::random(65, 65, 3); // crosses the TILE boundary
        let got = matmul(&a, &Mat::eye(65));
        assert_allclose(&got.data, &a.data, 1e-6, 1e-7);
    }

    #[test]
    fn acc_accumulates() {
        let a = Mat::random(8, 8, 1);
        let b = Mat::random(8, 8, 2);
        let mut c = matmul(&a, &b);
        matmul_acc_into(&mut c, &a, &b);
        let twice = matmul(&a, &b);
        let want: Vec<f32> = twice.data.iter().map(|v| v * 2.0).collect();
        assert_allclose(&c.data, &want, 1e-5, 1e-6);
    }

    #[test]
    fn add_elementwise() {
        let a = Mat::filled(3, 3, 1.0);
        let b = Mat::filled(3, 3, 2.5);
        assert_eq!(add(&a, &b), Mat::filled(3, 3, 3.5));
    }

    #[test]
    fn minplus_identity_and_saturation() {
        // min-plus identity: 0 diagonal, INF elsewhere
        let mut ident = Mat::filled(4, 4, INF);
        for i in 0..4 {
            ident[(i, i)] = 0.0;
        }
        let a = Mat::random(4, 4, 9);
        let got = minplus_matmul(&a, &ident);
        assert_allclose(&got.data, &a.data, 1e-6, 1e-7);
        // all-INF inputs stay INF (saturation, no overflow)
        let inf = Mat::filled(4, 4, INF);
        let out = minplus_matmul(&inf, &inf);
        assert!(out.data.iter().all(|&v| v == INF));
    }

    #[test]
    fn minplus_small_example() {
        // 2x2: out[0,0] = min(a00+b00, a01+b10)
        let a = Mat::from_vec(2, 2, vec![1., 5., 2., 1.]);
        let b = Mat::from_vec(2, 2, vec![3., 9., 1., 1.]);
        let out = minplus_matmul(&a, &b);
        assert_eq!(out.at(0, 0), 4.0); // min(1+3, 5+1) = 4
        assert_eq!(out.at(0, 1), 6.0); // min(1+9, 5+1) = 6
        assert_eq!(out.at(1, 0), 2.0); // min(2+3, 1+1) = 2
    }

    #[test]
    fn fw_update_improves_paths() {
        let mut d = Mat::from_vec(2, 2, vec![0., 10., 10., 0.]);
        // pivot row segment ik = [0, 1], pivot col segment kj = [1, 0]
        fw_update_into(&mut d, &[0., 1.], &[1., 0.]);
        assert_eq!(d.at(0, 1), 2.0); // 10 -> kj[0]+ik[1] = 1+1 = 2
        assert_eq!(d.at(0, 0), 0.0);
    }

    #[test]
    fn fw_update_never_increases() {
        prop_check("fw monotone", 20, |rng: &mut Rng| {
            let b = 1 + rng.gen_range(20);
            let before = Mat::random(b, b, rng.next_u64());
            let ik: Vec<f32> = (0..b).map(|_| rng.gen_f32()).collect();
            let kj: Vec<f32> = (0..b).map(|_| rng.gen_f32()).collect();
            let mut after = before.clone();
            fw_update_into(&mut after, &ik, &kj);
            for (a, bv) in after.data.iter().zip(&before.data) {
                assert!(a <= bv);
            }
        });
    }

    #[test]
    fn flop_count() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }
}
