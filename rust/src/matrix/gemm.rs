//! Native block linear algebra — the "BLAS" substitute.
//!
//! The paper runs MKL/JBLAS on each core; this module is the in-process
//! analogue: a BLIS-style **packed register-tiled GEMM** for the dense
//! `(+,×)` semiring and the tropical `(min,+)` semiring, optionally
//! split across a per-rank worker pool (see [`crate::matrix::par`]).
//!
//! Kernel structure (the classical GotoBLAS/BLIS decomposition):
//!
//! * **Microkernel** — an MR×NR register tile of C accumulators
//!   held in fixed-size arrays; the k-loop streams one packed A column
//!   and one packed B row per step and performs MR·NR multiply-adds with
//!   **no C loads or stores** (the seed ikj kernel re-streamed the C row
//!   every k step — that traffic is where its 4× went).  Fixed-size
//!   arrays autovectorize; no intrinsics, no `unsafe`.  The tile shape
//!   is a compile-time constant per variant — [`MicroKernel`] selects
//!   one of the monomorphized shapes (8×8, 8×4, 4×8) at the top of a
//!   product, so the hot loop never pays a dynamic dispatch.
//! * **Cache blocking** — now runtime [`BlockParams`] rather than
//!   compile-time constants, so a per-host tune profile can drive them:
//!   `kc`-deep panels keep the packed A strip in L1/L2 across the whole
//!   row of microtiles; `mc`-row bands bound the packed-A working set.
//!   Multi-threaded products are cut into (`mc` band × `nc` column-panel)
//!   tiles and scheduled through the work-stealing scheduler in
//!   [`crate::matrix::par`], so small band counts still occupy every core
//!   and a slow tile is isolated from the rest of its band.  The legacy
//!   constants [`KC`]/[`MC`]/[`NC`] are the defaults.
//! * **Packing** — A bands and the whole of B are copied once into
//!   contiguous, zero-padded panels from a process-wide **scratch pool**
//!   (buffers are reused across calls, so steady-state products allocate
//!   nothing).  The pool sizes buffers from the *active* params — a
//!   profile with larger panels than a previous call's simply grows the
//!   pooled buffer on checkout.
//!
//! **Determinism.** Every `c[i][j]` accumulates over `k` in ascending
//! order within each KC block, KC blocks ascending, one register
//! accumulator per element.  For a fixed [`BlockParams`] that order is
//! independent of the number of threads (threads own disjoint row
//! bands), of the column split (a [`matmul`] equals the hstack of its
//! `Compute::matmul_panel` pieces bit-for-bit), and of the transport
//! that delivered the operands — the guarantees the data-plane
//! integration tests pin down.  Changing `kc` regroups the dense sum
//! and may change low-order bits; `mc`/`nc`/microkernel shape never do,
//! and the tropical kernel is exact under any blocking.
//!
//! **Semantics.** The dense kernel has no zero-skip: `0·NaN` and `0·∞`
//! propagate as IEEE prescribes (the seed kernel's `aik == 0.0` fast
//! path silently dropped them).  The tropical kernel keeps the analogous
//! skip — for `(min,+)`, [`INF`] *is* the semiring identity, so skipping
//! an all-INF pivot column is algebra, not a shortcut.

use super::dense::Mat;
use super::par;
use super::params;
use crate::trace;

pub use super::params::{BlockParams, MicroKernel};

/// Default microkernel tile rows (register blocking of [`MicroKernel::Mr8Nr8`]).
pub const MR: usize = 8;
/// Default microkernel tile columns (one/two SIMD vectors).
pub const NR: usize = 8;
/// Default k-dimension cache-block depth: a packed A strip is `MR·KC`
/// floats (8 KiB) — resident in L1 across a row of microtiles.
pub const KC: usize = params::DEFAULT_KC;
/// Default row-band height: the packed-A granularity and the row edge of
/// a scheduler tile (`MC·KC` floats = 64 KiB per band panel).
pub const MC: usize = params::DEFAULT_MC;
/// Default column-panel width of one scheduler tile (multiple of [`NR`]).
/// A multi-threaded product is tiled (mc band × nc panel) so small band
/// counts still produce enough tiles to feed every core — the PR-4
/// whole-band counter left cores idle below `threads` bands.  Each tile
/// re-packs its band's A strip per KC block, which costs `njp/(2n)` of
/// the multiply work (< 1% at n ≥ 128) and buys full occupancy;
/// single-threaded runs keep one panel spanning all of n and skip the
/// re-pack entirely.
pub const NC: usize = params::DEFAULT_NC;

/// Process-wide pool of packing scratch buffers (see module docs).
mod scratch {
    use std::sync::{Mutex, OnceLock};

    /// Retention cap: the pool amortizes steady-state packing, it does
    /// not pin peak memory.
    const POOL_MAX: usize = 32;

    fn pool() -> &'static Mutex<Vec<Vec<f32>>> {
        static POOL: OnceLock<Mutex<Vec<Vec<f32>>>> = OnceLock::new();
        POOL.get_or_init(|| Mutex::new(Vec::new()))
    }

    /// Check out a buffer of exactly `len` elements (contents
    /// unspecified — packing writes every slot, so no clear/zero-fill:
    /// `resize` truncates for free or zero-fills only the grown tail).
    ///
    /// `unit` is the packed-strip width (MR for A panels, NR for B
    /// panels): every legal request is a whole number of strips, and the
    /// assert catches a caller whose panel arithmetic drifted from the
    /// active [`super::BlockParams`].  Pooled buffers carry no size —
    /// a profile asking for larger panels than any previous call simply
    /// grows the buffer here.
    pub fn take(len: usize, unit: usize) -> Vec<f32> {
        assert!(
            unit > 0 && len % unit == 0,
            "pack scratch request of {len} floats is not a whole number of {unit}-wide strips"
        );
        let mut v = pool().lock().unwrap().pop().unwrap_or_default();
        v.resize(len, 0.0);
        v
    }

    /// Return a buffer to the pool.
    pub fn give(v: Vec<f32>) {
        let mut p = pool().lock().unwrap();
        if p.len() < POOL_MAX {
            p.push(v);
        }
    }
}

// ------------------------------------------------------------- packing

/// Pack rows `[row0, row0+mc)` × cols `[k0, k0+kc)` of `a` into
/// MR-strip-major layout: `out[strip][k][i]`, edge strips padded with
/// `pad` (0 for dense — padded rows are never stored; [`INF`] for
/// tropical so the all-INF column skip still fires on edge strips).
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
fn pack_a<const MR_: usize>(
    a: &Mat,
    row0: usize,
    mc: usize,
    k0: usize,
    kc: usize,
    pad: f32,
    out: &mut [f32],
) {
    let ad: &[f32] = &a.data;
    let lda = a.cols;
    let mut idx = 0;
    for i0 in (0..mc).step_by(MR_) {
        for k in 0..kc {
            let col = k0 + k;
            for i in 0..MR_ {
                out[idx] = if i0 + i < mc {
                    ad[(row0 + i0 + i) * lda + col]
                } else {
                    pad
                };
                idx += 1;
            }
        }
    }
}

/// Pack all of `b` into NR-strip-major `kc`-blocked layout:
/// `out[kc_block][strip][k][j]`, edge strips zero-padded (padded columns
/// are never stored).  The block starting at depth `k0` begins at offset
/// `ceil(n/NR)·NR·k0` — packing the whole of B once lets every row band
/// (and every thread) reuse it.
#[allow(clippy::needless_range_loop)]
fn pack_b<const NR_: usize>(b: &Mat, kc_blk: usize, out: &mut [f32]) {
    let bd: &[f32] = &b.data;
    let (k, n) = (b.rows, b.cols);
    let mut idx = 0;
    for k0 in (0..k).step_by(kc_blk) {
        let kc = kc_blk.min(k - k0);
        for j0 in (0..n).step_by(NR_) {
            for kk in 0..kc {
                let row = (k0 + kk) * n;
                for j in 0..NR_ {
                    out[idx] = if j0 + j < n { bd[row + j0 + j] } else { 0.0 };
                    idx += 1;
                }
            }
        }
    }
}

// -------------------------------------------------------- microkernels

/// Dense `(+,×)` microkernel: `acc[i][j] += Σ_k pa[k][i] · pb[k][j]`,
/// k ascending, one accumulator per element (see module docs on
/// determinism).  No zero-skip: NaN/Inf propagate.
#[inline(always)]
#[allow(clippy::needless_range_loop)]
fn micro_dense<const MR_: usize, const NR_: usize>(
    kc: usize,
    pa: &[f32],
    pb: &[f32],
    acc: &mut [[f32; NR_]; MR_],
) {
    for k in 0..kc {
        let a: &[f32; MR_] = pa[k * MR_..k * MR_ + MR_].try_into().unwrap();
        let b: &[f32; NR_] = pb[k * NR_..k * NR_ + NR_].try_into().unwrap();
        for i in 0..MR_ {
            let aik = a[i];
            for j in 0..NR_ {
                acc[i][j] += aik * b[j];
            }
        }
    }
}

/// Tropical `(min,+)` microkernel:
/// `acc[i][j] = min(acc[i][j], pa[k][i] + pb[k][j])`.  A k-step whose
/// whole A column is at/above [`INF`] contributes only the semiring
/// identity and is skipped — the one fast path the satellite audit kept.
#[inline(always)]
#[allow(clippy::needless_range_loop)]
fn micro_tropical<const MR_: usize, const NR_: usize>(
    kc: usize,
    pa: &[f32],
    pb: &[f32],
    acc: &mut [[f32; NR_]; MR_],
) {
    for k in 0..kc {
        let a: &[f32; MR_] = pa[k * MR_..k * MR_ + MR_].try_into().unwrap();
        if a.iter().all(|&v| v >= INF) {
            continue; // the (min,+) identity annihilates this step
        }
        let b: &[f32; NR_] = pb[k * NR_..k * NR_ + NR_].try_into().unwrap();
        for i in 0..MR_ {
            let aik = a[i];
            for j in 0..NR_ {
                let cand = aik + b[j];
                if cand < acc[i][j] {
                    acc[i][j] = cand;
                }
            }
        }
    }
}

// ------------------------------------------------------- band kernels

/// Which semiring a band computes in (selects microkernel, A padding,
/// accumulator identity, and the C merge).
#[derive(Clone, Copy)]
enum Semiring {
    Dense,
    Tropical,
}

/// Compute one scheduler tile `c[row0.., jlo..jhi) ⊕= A[row0.., :] ⊗
/// B[:, jlo..jhi)` against the pre-packed whole-B panel `pb`.  Output
/// goes through `out` windows (global row-major offsets); `pa` is this
/// tile's packing scratch.  `jlo` must be NR-aligned (tiles are cut at
/// `nc` boundaries, a multiple of NR) so the tile's column strips line
/// up with the packed-B strips.  `kc_blk` is the active KC depth — it
/// must match the depth `pb` was packed with.
#[allow(clippy::too_many_arguments, clippy::needless_range_loop)]
fn band_kernel<const MR_: usize, const NR_: usize>(
    semiring: Semiring,
    out: &par::DisjointOut<'_>,
    a: &Mat,
    pb: &[f32],
    row0: usize,
    mc: usize,
    jlo: usize,
    jhi: usize,
    n: usize,
    kc_blk: usize,
    pa: &mut [f32],
) {
    debug_assert_eq!(jlo % NR_, 0, "tile column panels must be NR-aligned");
    let k = a.cols;
    let nstrips = n.div_ceil(NR_);
    let (pad, identity) = match semiring {
        Semiring::Dense => (0.0f32, 0.0f32),
        Semiring::Tropical => (INF, f32::INFINITY),
    };
    for k0 in (0..k).step_by(kc_blk) {
        let kc = kc_blk.min(k - k0);
        let pa_len = mc.div_ceil(MR_) * MR_ * kc;
        pack_a::<MR_>(a, row0, mc, k0, kc, pad, &mut pa[..pa_len]);
        let pb_block = &pb[nstrips * NR_ * k0..nstrips * NR_ * (k0 + kc)];
        for j0 in (jlo..jhi).step_by(NR_) {
            let jsi = j0 / NR_; // global strip index into the packed B
            let nr_eff = NR_.min(jhi - j0);
            let pbs = &pb_block[jsi * kc * NR_..(jsi + 1) * kc * NR_];
            for (isi, i0) in (0..mc).step_by(MR_).enumerate() {
                let mr_eff = MR_.min(mc - i0);
                let pas = &pa[isi * kc * MR_..(isi + 1) * kc * MR_];
                let mut acc = [[identity; NR_]; MR_];
                match semiring {
                    Semiring::Dense => micro_dense::<MR_, NR_>(kc, pas, pbs, &mut acc),
                    Semiring::Tropical => micro_tropical::<MR_, NR_>(kc, pas, pbs, &mut acc),
                }
                for i in 0..mr_eff {
                    let base = (row0 + i0 + i) * n + j0;
                    // SAFETY: rows of this tile's (band × panel)
                    // rectangle — disjoint across tiles by construction.
                    let crow = unsafe { out.window(base, nr_eff) };
                    match semiring {
                        Semiring::Dense => {
                            for (cv, av) in crow.iter_mut().zip(&acc[i][..nr_eff]) {
                                *cv += *av;
                            }
                        }
                        Semiring::Tropical => {
                            for (cv, av) in crow.iter_mut().zip(&acc[i][..nr_eff]) {
                                if *av < *cv {
                                    *cv = *av;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
}

/// [`banded_product`] monomorphized for one microkernel shape.
fn banded_product_g<const MR_: usize, const NR_: usize>(
    semiring: Semiring,
    c: &mut Mat,
    a: &Mat,
    b: &Mat,
    threads: usize,
    p: &BlockParams,
) {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    let (kc_blk, mc_band, nc_panel) = (p.kc, p.mc, p.nc);
    let mut pb = scratch::take(n.div_ceil(NR_) * NR_ * k, NR_);
    pack_b::<NR_>(b, kc_blk, &mut pb);
    let nbands = m.div_ceil(mc_band);
    // Column split only when there are cores to feed (see [`NC`]).
    let njp = if threads <= 1 { 1 } else { n.div_ceil(nc_panel) };
    let ntiles = nbands * njp;
    {
        let cd: &mut [f32] = c.data.as_mut_slice();
        let out = par::DisjointOut::new(cd);
        let pb_ref: &[f32] = &pb;
        par::run_chunks(threads, ntiles, &|tile| {
            let (band, jp) = (tile / njp, tile % njp);
            let row0 = band * mc_band;
            let mc = mc_band.min(m - row0);
            let (jlo, jhi) = if njp == 1 {
                (0, n)
            } else {
                (jp * nc_panel, n.min((jp + 1) * nc_panel))
            };
            let mut pa = scratch::take(mc.div_ceil(MR_) * MR_ * kc_blk.min(k), MR_);
            band_kernel::<MR_, NR_>(
                semiring, &out, a, pb_ref, row0, mc, jlo, jhi, n, kc_blk, &mut pa,
            );
            scratch::give(pa);
        });
    }
    scratch::give(pb);
}

/// Shared driver: pack B once, then compute (mc row band × nc column
/// panel) tiles — through the work-stealing scheduler over the per-rank
/// worker pool when `threads > 1`.  Tiles write disjoint rectangles of
/// C and every `c[i][j]` accumulates over `k` in the same order under
/// any tiling, so the result is bit-identical for every thread count
/// (and identical to the single-panel single-thread run).  Dispatches
/// once to the monomorphized variant the profile selects.
fn banded_product(
    semiring: Semiring,
    c: &mut Mat,
    a: &Mat,
    b: &Mat,
    threads: usize,
    p: &BlockParams,
) {
    debug_assert!(p.validate().is_ok(), "invalid BlockParams: {:?}", p.validate());
    match p.micro {
        MicroKernel::Mr8Nr8 => banded_product_g::<8, 8>(semiring, c, a, b, threads, p),
        MicroKernel::Mr8Nr4 => banded_product_g::<8, 4>(semiring, c, a, b, threads, p),
        MicroKernel::Mr4Nr8 => banded_product_g::<4, 8>(semiring, c, a, b, threads, p),
    }
}

// ---------------------------------------------------------- public API

/// `C = A · B` (packed kernel, single-threaded, default blocking).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    matmul_mt(a, b, 1)
}

/// `C = A · B` with up to `threads` cores from the per-rank pool.
pub fn matmul_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    matmul_mt_with(a, b, threads, &BlockParams::default())
}

/// [`matmul_mt`] under an explicit blocking profile.
pub fn matmul_mt_with(a: &Mat, b: &Mat, threads: usize, p: &BlockParams) -> Mat {
    let mut c = Mat::zeros(a.rows, b.cols);
    matmul_acc_into_mt_with(&mut c, a, b, threads, p);
    c
}

/// `C += A · B` — the DNS partial-sum hot spot, accumulating in place.
pub fn matmul_acc_into(c: &mut Mat, a: &Mat, b: &Mat) {
    matmul_acc_into_mt(c, a, b, 1);
}

/// `C += A · B` with up to `threads` cores.  Bit-identical for every
/// thread count (see module docs).
pub fn matmul_acc_into_mt(c: &mut Mat, a: &Mat, b: &Mat, threads: usize) {
    matmul_acc_into_mt_with(c, a, b, threads, &BlockParams::default());
}

/// [`matmul_acc_into_mt`] under an explicit blocking profile.
pub fn matmul_acc_into_mt_with(c: &mut Mat, a: &Mat, b: &Mat, threads: usize, p: &BlockParams) {
    assert_eq!(a.cols, b.rows, "inner dims: {}x{} @ {}x{}", a.rows, a.cols, b.rows, b.cols);
    assert_eq!((c.rows, c.cols), (a.rows, b.cols));
    let mut sp = trace::span("gemm", trace::Category::Kernel);
    if sp.is_active() {
        sp.arg("m", a.rows as f64);
        sp.arg("k", a.cols as f64);
        sp.arg("n", b.cols as f64);
        sp.arg("kc", p.kc as f64);
    }
    banded_product(Semiring::Dense, c, a, b, threads, p);
}

// ------------------------------------------------- elementwise kernels

/// Default minimum element count before an elementwise kernel goes
/// parallel (~1024²); the runtime value lives in
/// [`BlockParams::ew_par_threshold`].  Elementwise kernels are
/// **bandwidth-bound** — one or two flops per 4-byte element — so extra
/// cores only pay once the operands outgrow the shared cache and the
/// loop is genuinely streaming from DRAM; under the threshold the pool
/// handoff (~µs) costs more than the whole memcpy-speed loop, and a
/// single core already saturates the cache bandwidth.  GEMM has no such
/// threshold: at O(n³/n²) flops per byte it is compute-bound at every
/// size worth blocking.
pub const EW_PAR_THRESHOLD: usize = params::DEFAULT_EW_PAR_THRESHOLD;

/// Elements handed to one scheduler chunk of an elementwise kernel:
/// 1 MiB of f32 — big enough to amortize a claim, small enough that
/// `threads` cores stay balanced on 2048² blocks.
const EW_CHUNK: usize = 1 << 18;

/// Effective thread count for an elementwise kernel over `len` elements
/// against the active profile's threshold.
#[inline]
fn ew_threads(len: usize, threads: usize, threshold: usize) -> usize {
    if len < threshold {
        1
    } else {
        threads
    }
}

/// Shared elementwise driver: `out[i] = op(a[i], b[i])`, chunked over
/// the work-stealing scheduler past the bandwidth threshold.  Element
/// order within a chunk is ascending and chunks are disjoint, so the
/// result is bit-identical for every thread count.
#[allow(clippy::uninit_vec)] // chunks below write every slot before set_len
fn ew_binary_mt(
    a: &Mat,
    b: &Mat,
    threads: usize,
    threshold: usize,
    op: impl Fn(f32, f32) -> f32 + Sync,
) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut sp = trace::span("elementwise", trace::Category::Kernel);
    if sp.is_active() {
        sp.arg("elems", (a.rows * a.cols) as f64);
    }
    let len = a.data.len();
    if ew_threads(len, threads, threshold) <= 1 {
        let data = a.data.iter().zip(&b.data).map(|(x, y)| op(*x, *y)).collect();
        return Mat { rows: a.rows, cols: a.cols, data };
    }
    // Parallel path writes every slot exactly once, so the output is
    // allocated uninitialized — a zero-fill would add a full extra
    // write pass to a kernel whose cost *is* its memory traffic.  The
    // chunks write through raw pointers (`write_window`), never forming
    // a slice over the uninitialized storage.
    let mut out: Vec<f32> = Vec::with_capacity(len);
    let nchunks = len.div_ceil(EW_CHUNK);
    {
        // SAFETY: capacity `len` was just reserved; chunks below cover
        // [0, len) exactly once.
        let dst = unsafe { par::DisjointOut::from_raw(out.as_mut_ptr(), len) };
        let (ad, bd): (&[f32], &[f32]) = (&a.data, &b.data);
        par::run_chunks(threads, nchunks, &|ci| {
            let lo = ci * EW_CHUNK;
            let hi = len.min(lo + EW_CHUNK);
            // SAFETY: disjoint contiguous windows, raw writes only.
            unsafe { dst.write_window(lo, hi - lo, |i| op(ad[lo + i], bd[lo + i])) };
        });
    }
    // SAFETY: all `len` elements were initialized by the chunks above.
    unsafe { out.set_len(len) };
    Mat { rows: a.rows, cols: a.cols, data: out.into() }
}

/// Elementwise ⊕ selector for the fusible block combines: dense `+`
/// (the reduceD accumulate) and the tropical `min` (the APSP combine).
/// The plan layer's fuse pass folds chains of these into one
/// [`ew_chain_mt_with`] pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EwKind {
    Add,
    Min,
}

impl EwKind {
    #[inline(always)]
    pub fn apply(self, x: f32, y: f32) -> f32 {
        match self {
            EwKind::Add => x + y,
            EwKind::Min => x.min(y),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EwKind::Add => "add",
            EwKind::Min => "min",
        }
    }
}

/// Fused elementwise chain: `out[i] = fold(base[i], ops, |v, (⊕, m)| v ⊕ m[i])`
/// in **one** pass over memory.  The per-element fold order is exactly
/// the order of `ops`, so the result is bit-identical to applying the
/// ops as separate [`ew_binary_mt`] passes — fusion only removes the
/// intermediate materializations, not reassociates.  Chunking follows
/// the same bandwidth threshold and disjoint-window discipline, so it
/// is also bit-identical for every thread count.
#[allow(clippy::uninit_vec)] // chunks below write every slot before set_len
pub fn ew_chain_mt_with(base: &Mat, ops: &[(EwKind, &Mat)], threads: usize, p: &BlockParams) -> Mat {
    for (_, m) in ops {
        assert_eq!((m.rows, m.cols), (base.rows, base.cols), "fused chain shape mismatch");
    }
    let mut sp = trace::span("elementwise", trace::Category::Kernel);
    if sp.is_active() {
        sp.arg("elems", (base.rows * base.cols) as f64);
        sp.arg("fused", ops.len() as f64);
    }
    let len = base.data.len();
    let fold = |i: usize| {
        let mut v = base.data[i];
        for (op, m) in ops {
            v = op.apply(v, m.data[i]);
        }
        v
    };
    if ew_threads(len, threads, p.ew_par_threshold) <= 1 {
        let data = (0..len).map(fold).collect();
        return Mat { rows: base.rows, cols: base.cols, data };
    }
    let mut out: Vec<f32> = Vec::with_capacity(len);
    let nchunks = len.div_ceil(EW_CHUNK);
    {
        // SAFETY: capacity `len` was just reserved; chunks below cover
        // [0, len) exactly once.
        let dst = unsafe { par::DisjointOut::from_raw(out.as_mut_ptr(), len) };
        par::run_chunks(threads, nchunks, &|ci| {
            let lo = ci * EW_CHUNK;
            let hi = len.min(lo + EW_CHUNK);
            // SAFETY: disjoint contiguous windows, raw writes only.
            unsafe { dst.write_window(lo, hi - lo, |i| fold(lo + i)) };
        });
    }
    // SAFETY: all `len` elements were initialized by the chunks above.
    unsafe { out.set_len(len) };
    Mat { rows: base.rows, cols: base.cols, data: out.into() }
}

/// `A + B` elementwise (the reduceD combine), single-threaded.
pub fn add(a: &Mat, b: &Mat) -> Mat {
    add_mt(a, b, 1)
}

/// `A + B` elementwise with up to `threads` cores past the bandwidth
/// threshold.  Bit-identical for every thread count.
pub fn add_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    add_mt_with(a, b, threads, &BlockParams::default())
}

/// [`add_mt`] under an explicit profile (only `ew_par_threshold` applies).
pub fn add_mt_with(a: &Mat, b: &Mat, threads: usize, p: &BlockParams) -> Mat {
    ew_binary_mt(a, b, threads, p.ew_par_threshold, |x, y| x + y)
}

/// Elementwise `min(A, B)` — the tropical semiring's ⊕ at block level
/// (the APSP-by-squaring combine), single-threaded.
pub fn min_mat(a: &Mat, b: &Mat) -> Mat {
    min_mat_mt(a, b, 1)
}

/// Elementwise min with up to `threads` cores past the bandwidth
/// threshold.  `min` is exact in floating point, so the result is
/// bit-identical for every thread count by construction.
pub fn min_mat_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    min_mat_mt_with(a, b, threads, &BlockParams::default())
}

/// [`min_mat_mt`] under an explicit profile (only `ew_par_threshold` applies).
pub fn min_mat_mt_with(a: &Mat, b: &Mat, threads: usize, p: &BlockParams) -> Mat {
    ew_binary_mt(a, b, threads, p.ew_par_threshold, f32::min)
}

/// "No edge" sentinel of the (min,+) semiring — kept in sync with
/// python/compile/kernels/ref.py::INF.
pub const INF: f32 = 1e30;

/// Tropical product `out[i,j] = min(INF, min_k a[i,k] + b[k,j])`
/// (packed kernel, single-threaded).
pub fn minplus_matmul(a: &Mat, b: &Mat) -> Mat {
    minplus_matmul_mt(a, b, 1)
}

/// Tropical product with up to `threads` cores.  `min` is exact in
/// floating point, so the result is bit-identical for every thread count
/// and blocking by construction.
pub fn minplus_matmul_mt(a: &Mat, b: &Mat, threads: usize) -> Mat {
    minplus_matmul_mt_with(a, b, threads, &BlockParams::default())
}

/// [`minplus_matmul_mt`] under an explicit blocking profile.
pub fn minplus_matmul_mt_with(a: &Mat, b: &Mat, threads: usize, p: &BlockParams) -> Mat {
    assert_eq!(a.cols, b.rows);
    let mut sp = trace::span("gemm_tropical", trace::Category::Kernel);
    if sp.is_active() {
        sp.arg("m", a.rows as f64);
        sp.arg("k", a.cols as f64);
        sp.arg("n", b.cols as f64);
        sp.arg("kc", p.kc as f64);
    }
    let mut out = Mat::filled(a.rows, b.cols, INF);
    banded_product(Semiring::Tropical, &mut out, a, b, threads, p);
    out
}

/// Floyd-Warshall pivot update on a block (Alg. 3 lines 9-14):
/// `d[i,j] = min(d[i,j], kj[i] + ik[j])`, where `ik` is the pivot-row
/// segment and `kj` the pivot-column segment.  Single-threaded.
pub fn fw_update_into(d: &mut Mat, ik: &[f32], kj: &[f32]) {
    fw_update_into_mt(d, ik, kj, 1);
}

/// Floyd-Warshall pivot update with up to `threads` cores past the
/// bandwidth threshold (row ranges are disjoint and each element's
/// update is a single min — bit-identical for every thread count).
pub fn fw_update_into_mt(d: &mut Mat, ik: &[f32], kj: &[f32], threads: usize) {
    fw_update_into_mt_with(d, ik, kj, threads, &BlockParams::default());
}

/// [`fw_update_into_mt`] under an explicit profile (only
/// `ew_par_threshold` applies).
pub fn fw_update_into_mt_with(
    d: &mut Mat,
    ik: &[f32],
    kj: &[f32],
    threads: usize,
    p: &BlockParams,
) {
    assert_eq!(ik.len(), d.cols);
    assert_eq!(kj.len(), d.rows);
    let mut sp = trace::span("fw_update", trace::Category::Kernel);
    if sp.is_active() {
        sp.arg("rows", d.rows as f64);
        sp.arg("cols", d.cols as f64);
    }
    let (rows, cols) = (d.rows, d.cols);
    if rows == 0 || cols == 0 {
        return;
    }
    let dd: &mut [f32] = d.data.as_mut_slice();
    if ew_threads(rows * cols, threads, p.ew_par_threshold) <= 1 {
        fw_update_rows(dd, cols, ik, kj);
        return;
    }
    // ~EW_CHUNK elements per chunk, cut on row boundaries
    let rows_per = EW_CHUNK.div_ceil(cols).max(1).min(rows);
    let nchunks = rows.div_ceil(rows_per);
    let out = par::DisjointOut::new(dd);
    par::run_chunks(threads, nchunks, &|ci| {
        let r0 = ci * rows_per;
        let r1 = rows.min(r0 + rows_per);
        // SAFETY: disjoint row ranges.
        let span = unsafe { out.window(r0 * cols, (r1 - r0) * cols) };
        fw_update_rows(span, cols, ik, &kj[r0..r1]);
    });
}

/// The FW update over one contiguous run of rows: `dd` covers the rows
/// `kj` describes, `ik` spans all columns.
fn fw_update_rows(dd: &mut [f32], cols: usize, ik: &[f32], kj: &[f32]) {
    for (row, &base) in dd.chunks_mut(cols).zip(kj) {
        if base >= INF {
            continue;
        }
        for (dv, &ikv) in row.iter_mut().zip(ik) {
            let cand = base + ikv;
            if cand < *dv {
                *dv = cand;
            }
        }
    }
}

/// FLOP count of an (m,k)x(k,n) GEMM (2 flops per MAC) — used by the
/// modeled-compute mode and the efficiency reports.
pub fn gemm_flops(m: usize, k: usize, n: usize) -> f64 {
    2.0 * m as f64 * k as f64 * n as f64
}

// ------------------------------------------------------- seed baseline

/// Tile edge of the frozen seed kernel's (i, k) blocking.
const SEED_TILE: usize = 64;

/// The PR-0 seed GEMM, **frozen verbatim** as the baseline of the perf
/// trajectory: `benches/gemm_kernel.rs` measures the packed kernel's
/// speedup against this exact loop, so the committed BENCH_gemm.json
/// numbers stay comparable forever.  Scalar cache-blocked ikj, including
/// the then-current `aik == 0.0` fast path with its semantic flaw
/// (`0·NaN` fails to propagate) that the packed kernel removed.  Not
/// called by any compute path — benches and regression tests only.
pub fn matmul_seed_ikj(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Mat::zeros(m, n);
    let cd = c.data.as_mut_slice();
    for it in (0..m).step_by(SEED_TILE) {
        let ie = (it + SEED_TILE).min(m);
        for kt in (0..k).step_by(SEED_TILE) {
            let ke = (kt + SEED_TILE).min(k);
            for i in it..ie {
                let arow = &a.data[i * k..(i + 1) * k];
                let crow = &mut cd[i * n..(i + 1) * n];
                for kk in kt..ke {
                    let aik = arow[kk];
                    if aik == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for (cv, bv) in crow.iter_mut().zip(brow) {
                        *cv += aik * bv;
                    }
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_allclose, prop_check, Rng};

    /// Triple-loop reference for the blocked implementation.
    fn matmul_naive(a: &Mat, b: &Mat) -> Mat {
        let mut c = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0f32;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive_various_shapes() {
        prop_check("gemm vs naive", 25, |rng: &mut Rng| {
            let m = 1 + rng.gen_range(40);
            let k = 1 + rng.gen_range(40);
            let n = 1 + rng.gen_range(40);
            let a = Mat::random(m, k, rng.next_u64());
            let b = Mat::random(k, n, rng.next_u64());
            let got = matmul(&a, &b);
            let want = matmul_naive(&a, &b);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        });
    }

    #[test]
    fn matmul_matches_naive_at_tile_boundaries() {
        // every microkernel/cache-block edge: MR/NR ± 1 and KC ± 1
        let dims_mn = [MR - 1, MR, MR + 1, NR - 1, NR, NR + 1, 2 * MR + 3];
        let dims_k = [1, MR - 1, NR + 1, KC - 1, KC, KC + 1];
        let mut seed = 1u64;
        for &m in &dims_mn {
            for &n in &dims_mn {
                for &k in &dims_k {
                    seed += 1;
                    let a = Mat::random(m, k, seed);
                    let b = Mat::random(k, n, seed + 1000);
                    let got = matmul(&a, &b);
                    let want = matmul_naive(&a, &b);
                    assert_allclose(&got.data, &want.data, 1e-3, 1e-5);
                }
            }
        }
    }

    #[test]
    fn all_microkernel_variants_match_naive() {
        // each compiled MR×NR shape, at shapes crossing its own edges
        for micro in MicroKernel::ALL {
            let (mr, nr) = (micro.mr(), micro.nr());
            let p = BlockParams {
                micro,
                mc: 4 * mr,
                nc: 8 * nr,
                ..BlockParams::default()
            };
            p.validate().unwrap();
            let mut seed = 100u64;
            for &(m, k, n) in &[
                (mr - 1, 13, nr - 1),
                (mr + 1, 37, nr + 1),
                (4 * mr + 3, 9, 8 * nr + 5),
            ] {
                seed += 1;
                let a = Mat::random(m, k, seed);
                let b = Mat::random(k, n, seed + 1);
                for threads in [1usize, 3] {
                    let got = matmul_mt_with(&a, &b, threads, &p);
                    let want = matmul_naive(&a, &b);
                    assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
                }
            }
        }
    }

    #[test]
    fn matmul_crosses_band_boundaries() {
        // MC ± 1 rows: exercises the multi-band path single-threaded
        for m in [MC - 1, MC, MC + 1, 2 * MC + 5] {
            let a = Mat::random(m, 33, m as u64);
            let b = Mat::random(33, 17, m as u64 + 7);
            assert_allclose(&matmul(&a, &b).data, &matmul_naive(&a, &b).data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn multithreaded_matmul_is_bit_identical() {
        // determinism contract: any thread count, same bytes — including
        // shapes where the 2D tiling splits columns (n > NC) and where
        // it does not (n < NC)
        for (m, k, n) in [
            (130usize, 70usize, 65usize),
            (64, 256, 64),
            (3, 5, 2),
            (64, 100, 2 * NC + 44),
            (2 * MC + 5, 33, NC + 1),
        ] {
            let a = Mat::random(m, k, 9);
            let b = Mat::random(k, n, 10);
            let base = matmul_mt(&a, &b, 1);
            for threads in [2usize, 4] {
                let got = matmul_mt(&a, &b, threads);
                assert_eq!(base.data, got.data, "threads={threads} ({m}x{k}x{n})");
            }
        }
    }

    #[test]
    fn nondefault_profile_is_bit_identical_across_threads() {
        // the per-profile determinism contract: a fixed non-default
        // profile gives the same bytes at every thread count, and
        // mc/nc/micro re-tiling never changes bits vs default at same kc
        let a = Mat::random(100, 300, 41);
        let b = Mat::random(300, 150, 42);
        let small_kc = BlockParams {
            kc: 64,
            mc: 32,
            nc: 64,
            micro: MicroKernel::Mr8Nr4,
            ..BlockParams::default()
        };
        let base = matmul_mt_with(&a, &b, 1, &small_kc);
        for threads in [2usize, 4] {
            assert_eq!(base.data, matmul_mt_with(&a, &b, threads, &small_kc).data);
        }
        // same kc as default, different tiling: bits match the default
        // profile exactly (accumulation order is kc-determined)
        let retiled = BlockParams {
            mc: 32,
            nc: 64,
            micro: MicroKernel::Mr4Nr8,
            ..BlockParams::default()
        };
        let default = matmul_mt(&a, &b, 4);
        assert_eq!(default.data, matmul_mt_with(&a, &b, 4, &retiled).data);
        // while a different kc legitimately regroups the dense sum
        let close = matmul_mt_with(&a, &b, 2, &small_kc);
        assert_allclose(&default.data, &close.data, 1e-4, 1e-5);
    }

    #[test]
    fn scratch_pool_resizes_for_larger_profiles() {
        // regression: the pool must serve a profile with larger panels
        // than any earlier call sized its buffers for.  Prime the pool
        // with default-blocking runs, then run a big-panel profile and
        // check against the naive reference — a stale-capacity bug
        // would read/write out of the packed panels' bounds.
        let a = Mat::random(150, 600, 51);
        let b = Mat::random(600, 200, 52);
        let _ = matmul_mt(&a, &b, 2); // pool now holds default-sized buffers
        let big = BlockParams {
            kc: 512,
            mc: 128,
            nc: 256,
            ..BlockParams::default()
        };
        let got = matmul_mt_with(&a, &b, 2, &big);
        assert_allclose(&got.data, &matmul_naive(&a, &b).data, 1e-3, 1e-5);
        // and tropical under the same oversized panels stays exact
        let t_default = minplus_matmul_mt(&a, &b, 1);
        let t_big = minplus_matmul_mt_with(&a, &b, 2, &big);
        assert_eq!(t_default.data, t_big.data);
    }

    #[test]
    #[should_panic(expected = "whole number")]
    fn scratch_rejects_misaligned_requests() {
        // a request that is not a whole number of packed strips means
        // the caller's panel arithmetic drifted from the active params
        let _ = super::scratch::take(100, 8);
    }

    #[test]
    fn ew_threshold_comes_from_profile() {
        // a tiny threshold forces the parallel path on small operands;
        // results stay bit-identical to the serial path
        let p = BlockParams {
            ew_par_threshold: 1,
            ..BlockParams::default()
        };
        let a = Mat::random(100, 50, 61);
        let b = Mat::random(100, 50, 62);
        assert_eq!(add(&a, &b).data, add_mt_with(&a, &b, 4, &p).data);
        assert_eq!(min_mat(&a, &b).data, min_mat_mt_with(&a, &b, 4, &p).data);
        let ik: Vec<f32> = (0..50).map(|i| i as f32).collect();
        let kj: Vec<f32> = (0..100).map(|i| (i % 7) as f32).collect();
        let mut want = Mat::random(100, 50, 63);
        let mut got = want.clone();
        fw_update_into(&mut want, &ik, &kj);
        fw_update_into_mt_with(&mut got, &ik, &kj, 4, &p);
        assert_eq!(want.data, got.data);
    }

    #[test]
    fn multithreaded_matmul_crosses_panel_boundaries_correctly() {
        // NC ± 1 columns at threads = 2: exercises the tile column split
        // against the naive reference, not just against itself
        for n in [NC - 1, NC, NC + 1, 2 * NC + 3] {
            let a = Mat::random(70, 41, n as u64);
            let b = Mat::random(41, n, n as u64 + 1);
            let got = matmul_mt(&a, &b, 2);
            let want = matmul_naive(&a, &b);
            assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn multithreaded_minplus_is_bit_identical() {
        let a = Mat::random(130, 70, 21);
        let b = Mat::random(70, 90, 22);
        let base = minplus_matmul_mt(&a, &b, 1);
        for threads in [2usize, 4] {
            assert_eq!(base.data, minplus_matmul_mt(&a, &b, threads).data);
        }
    }

    #[test]
    fn dense_kernel_propagates_nan_and_inf() {
        // 0·NaN must be NaN, 0·∞ must be NaN — the seed kernel's
        // zero-skip dropped both (regression test for the fixed flaw)
        let a = Mat::zeros(9, 9);
        let mut b = Mat::filled(9, 9, 1.0);
        b.set(0, 0, f32::NAN);
        b.set(0, 1, f32::INFINITY);
        let c = matmul(&a, &b);
        assert!(c.at(0, 0).is_nan(), "0·NaN lost");
        assert!(c.at(0, 1).is_nan(), "0·∞ lost");
        // the frozen seed kernel exhibits the old behaviour
        let seed = matmul_seed_ikj(&a, &b);
        assert_eq!(seed.at(0, 0), 0.0);
    }

    #[test]
    fn seed_kernel_matches_packed_on_regular_data() {
        let a = Mat::random(65, 65, 3);
        let b = Mat::random(65, 65, 4);
        let packed = matmul(&a, &b);
        let seed = matmul_seed_ikj(&a, &b);
        assert_allclose(&packed.data, &seed.data, 1e-4, 1e-5);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::random(65, 65, 3); // crosses the MC band boundary
        let got = matmul(&a, &Mat::eye(65));
        assert_allclose(&got.data, &a.data, 1e-6, 1e-7);
    }

    #[test]
    fn acc_accumulates() {
        let a = Mat::random(8, 8, 1);
        let b = Mat::random(8, 8, 2);
        let mut c = matmul(&a, &b);
        matmul_acc_into(&mut c, &a, &b);
        let twice = matmul(&a, &b);
        let want: Vec<f32> = twice.data.iter().map(|v| v * 2.0).collect();
        assert_allclose(&c.data, &want, 1e-5, 1e-6);
    }

    #[test]
    fn add_elementwise() {
        let a = Mat::filled(3, 3, 1.0);
        let b = Mat::filled(3, 3, 2.5);
        assert_eq!(add(&a, &b), Mat::filled(3, 3, 3.5));
    }

    #[test]
    fn threaded_elementwise_bit_identical_past_threshold() {
        // 1024² = EW_PAR_THRESHOLD exactly: the parallel path engages
        let a = Mat::random(1024, 1024, 31);
        let b = Mat::random(1024, 1024, 32);
        let add1 = add_mt(&a, &b, 1);
        let min1 = min_mat_mt(&a, &b, 1);
        for threads in [2usize, 4] {
            assert_eq!(add1.data, add_mt(&a, &b, threads).data, "add threads={threads}");
            assert_eq!(min1.data, min_mat_mt(&a, &b, threads).data, "min threads={threads}");
        }
        // under the threshold the knob is ignored but results still match
        let sa = Mat::random(37, 19, 1);
        let sb = Mat::random(37, 19, 2);
        assert_eq!(add_mt(&sa, &sb, 4).data, add(&sa, &sb).data);
        assert_eq!(min_mat_mt(&sa, &sb, 4).data, min_mat(&sa, &sb).data);
    }

    #[test]
    fn threaded_fw_update_bit_identical_past_threshold() {
        let b = 1024usize;
        let ik: Vec<f32> = (0..b).map(|i| ((i * 7) % 23) as f32 * 0.5).collect();
        let mut kj: Vec<f32> = (0..b).map(|i| ((i * 5) % 19) as f32 * 0.25).collect();
        kj[3] = INF; // exercise the INF row skip on both paths
        let base = {
            let mut d = Mat::random(b, b, 77);
            fw_update_into_mt(&mut d, &ik, &kj, 1);
            d
        };
        for threads in [2usize, 4] {
            let mut d = Mat::random(b, b, 77);
            fw_update_into_mt(&mut d, &ik, &kj, threads);
            assert_eq!(base.data, d.data, "fw_update threads={threads}");
        }
    }

    #[test]
    fn min_mat_small_example() {
        let a = Mat::from_vec(2, 2, vec![1., 5., 2., 1.]);
        let b = Mat::from_vec(2, 2, vec![3., 0., 1., 4.]);
        assert_eq!(min_mat(&a, &b), Mat::from_vec(2, 2, vec![1., 0., 1., 1.]));
    }

    #[test]
    fn minplus_identity_and_saturation() {
        // min-plus identity: 0 diagonal, INF elsewhere
        let mut ident = Mat::filled(4, 4, INF);
        for i in 0..4 {
            ident[(i, i)] = 0.0;
        }
        let a = Mat::random(4, 4, 9);
        let got = minplus_matmul(&a, &ident);
        assert_allclose(&got.data, &a.data, 1e-6, 1e-7);
        // all-INF inputs stay INF (saturation, no overflow)
        let inf = Mat::filled(4, 4, INF);
        let out = minplus_matmul(&inf, &inf);
        assert!(out.data.iter().all(|&v| v == INF));
    }

    #[test]
    fn minplus_small_example() {
        // 2x2: out[0,0] = min(a00+b00, a01+b10)
        let a = Mat::from_vec(2, 2, vec![1., 5., 2., 1.]);
        let b = Mat::from_vec(2, 2, vec![3., 9., 1., 1.]);
        let out = minplus_matmul(&a, &b);
        assert_eq!(out.at(0, 0), 4.0); // min(1+3, 5+1) = 4
        assert_eq!(out.at(0, 1), 6.0); // min(1+9, 5+1) = 6
        assert_eq!(out.at(1, 0), 2.0); // min(2+3, 1+1) = 2
    }

    #[test]
    fn minplus_matches_naive_at_tile_boundaries() {
        fn minplus_naive(a: &Mat, b: &Mat) -> Mat {
            let mut out = Mat::filled(a.rows, b.cols, INF);
            for i in 0..a.rows {
                for j in 0..b.cols {
                    for k in 0..a.cols {
                        let cand = a.at(i, k) + b.at(k, j);
                        if cand < out.at(i, j) {
                            out.set(i, j, cand);
                        }
                    }
                }
            }
            out
        }
        let mut seed = 77u64;
        for &(m, k, n) in &[(MR + 1, KC + 1, NR - 1), (MR - 1, 3, NR + 1), (17, 9, 13)] {
            seed += 1;
            let mut a = Mat::random(m, k, seed);
            let b = Mat::random(k, n, seed + 1);
            // sprinkle INF entries so the identity skip gets exercised
            for i in 0..m {
                a.set(i, i % k, INF);
            }
            let got = minplus_matmul(&a, &b);
            let want = minplus_naive(&a, &b);
            assert_eq!(got.data, want.data, "{m}x{k}x{n}");
        }
    }

    #[test]
    fn minplus_bit_identical_under_any_blocking() {
        // min is exact: every profile gives the same bytes, even across
        // kc (unlike dense, where kc regroups the sum)
        let a = Mat::random(90, 260, 81);
        let b = Mat::random(260, 70, 82);
        let base = minplus_matmul_mt(&a, &b, 1);
        for micro in MicroKernel::ALL {
            let p = BlockParams {
                kc: 96,
                mc: 2 * micro.mr(),
                nc: 4 * micro.nr(),
                micro,
                ..BlockParams::default()
            };
            assert_eq!(base.data, minplus_matmul_mt_with(&a, &b, 4, &p).data, "{}", micro.name());
        }
    }

    #[test]
    fn fw_update_improves_paths() {
        let mut d = Mat::from_vec(2, 2, vec![0., 10., 10., 0.]);
        // pivot row segment ik = [0, 1], pivot col segment kj = [1, 0]
        fw_update_into(&mut d, &[0., 1.], &[1., 0.]);
        assert_eq!(d.at(0, 1), 2.0); // 10 -> kj[0]+ik[1] = 1+1 = 2
        assert_eq!(d.at(0, 0), 0.0);
    }

    #[test]
    fn fw_update_never_increases() {
        prop_check("fw monotone", 20, |rng: &mut Rng| {
            let b = 1 + rng.gen_range(20);
            let before = Mat::random(b, b, rng.next_u64());
            let ik: Vec<f32> = (0..b).map(|_| rng.gen_f32()).collect();
            let kj: Vec<f32> = (0..b).map(|_| rng.gen_f32()).collect();
            let mut after = before.clone();
            fw_update_into(&mut after, &ik, &kj);
            for (a, bv) in after.data.iter().zip(&before.data) {
                assert!(a <= bv);
            }
        });
    }

    #[test]
    fn flop_count() {
        assert_eq!(gemm_flops(2, 3, 4), 48.0);
    }
}
