//! Per-host empirical tune profiles.
//!
//! The paper's close-to-peak efficiency story (§6) depends on kernel and
//! machine parameters matched to the host: MKL's blocking is tuned per
//! CPU, and the `t_s`/`t_w` cost parameters are *measured*, not guessed.
//! This module is the persistence layer of our analogue: `repro tune`
//! (see [`crate::experiments::tune`]) sweeps the packed GEMM's blocking
//! on the real native path and ping-pongs messages to measure intra- and
//! inter-node link costs, then writes the result here as a small JSON
//! profile — `~/.foopar/tune-<host>.json` by default.
//!
//! A profile is consumed by `Runtime::builder().tune_profile(..)` (or
//! the `tune_profile` machine-config key, or the CLI `--profile` flag):
//! the [`BlockParams`] drive every `Compute::Native` kernel call and the
//! [`LinkCalibration`] replaces the *hardcoded* intra/inter link prices
//! of [`HierCost`] on hierarchical worlds — so `prefer_two_level_*`
//! decisions and the virtual clock are priced from this host's measured
//! links rather than defaults.
//!
//! The JSON layout is deliberately bench-gate compatible: scalar params
//! first, then a `"results"` array of swept (kernel, b, threads, gflops)
//! cells in the same entry shape as `BENCH_*.json`, so
//! `bench_gate --check` validates an emitted profile with the exact
//! parser the CI bench gate trusts.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::cost::{CostParams, HierCost};
use crate::matrix::params::{BlockParams, MicroKernel};
use crate::metrics::JsonWriter;

/// Measured link costs from the ping-pong microbench: one `(ts, tw)`
/// pair per hierarchy level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkCalibration {
    /// Same-node (shared-memory transport) link parameters.
    pub intra: CostParams,
    /// Cross-node (TCP transport) link parameters.
    pub inter: CostParams,
}

impl LinkCalibration {
    /// The two-level link pricing this calibration induces.
    pub fn hier(&self) -> HierCost {
        HierCost::new(self.intra, self.inter)
    }
}

/// One swept (configuration, shape, threads) measurement, persisted in
/// the profile's `"results"` array.  `kernel` is `"default"` for the
/// built-in constants and `"tuned"` for the winning point, so the bench
/// gate's identity key (kernel, b, threads) stays unique per entry.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneCell {
    pub kernel: String,
    pub b: usize,
    pub threads: usize,
    pub gflops: f64,
}

/// A persisted per-host autotune result: the winning GEMM blocking, the
/// thread count and rate it won at, optional measured link costs, and
/// the swept cells it was chosen from.
#[derive(Clone, Debug, PartialEq)]
pub struct TuneProfile {
    /// Hostname the sweep ran on (profiles are per-host by design).
    pub host: String,
    /// The winning blocking parameters.
    pub block: BlockParams,
    /// Thread count of the best swept cell (informational; runs still
    /// choose their own `threads_per_rank`).
    pub threads: usize,
    /// GFlop/s of the best swept cell.
    pub gflops: f64,
    /// Measured intra/inter link costs, when a calibration run was done.
    pub link: Option<LinkCalibration>,
    /// Swept measurements backing this profile (bench-gate entry shape).
    pub cells: Vec<TuneCell>,
    /// Where this profile was loaded from (`None` for in-memory ones).
    pub source: Option<PathBuf>,
}

impl TuneProfile {
    /// Format version written as the `tune_profile` marker key.
    const VERSION: u64 = 1;

    /// Hostname for per-host profile naming: `/proc/sys/kernel/hostname`
    /// (Linux), then `$HOSTNAME`, then `"localhost"`.
    pub fn host_name() -> String {
        std::fs::read_to_string("/proc/sys/kernel/hostname")
            .ok()
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .or_else(|| std::env::var("HOSTNAME").ok().filter(|s| !s.is_empty()))
            .unwrap_or_else(|| "localhost".into())
    }

    /// The default per-host profile path: `~/.foopar/tune-<host>.json`.
    /// `None` when `$HOME` is unset.
    pub fn default_path() -> Option<PathBuf> {
        let home = std::env::var_os("HOME")?;
        Some(
            PathBuf::from(home)
                .join(".foopar")
                .join(format!("tune-{}.json", Self::host_name())),
        )
    }

    /// Display label for report headers: the source path, or "(inline)".
    pub fn source_label(&self) -> String {
        match &self.source {
            Some(p) => p.display().to_string(),
            None => "(inline)".into(),
        }
    }

    /// Serialize (see module docs for the layout contract: scalar keys
    /// strictly before the `"results"` array, since the reader scans
    /// flat keys only in that prefix).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("tune_profile").uint(Self::VERSION);
        w.key("host").str_val(&self.host);
        w.key("kc").uint(self.block.kc as u64);
        w.key("mc").uint(self.block.mc as u64);
        w.key("nc").uint(self.block.nc as u64);
        w.key("micro").str_val(self.block.micro.name());
        w.key("ew_par_threshold").uint(self.block.ew_par_threshold as u64);
        w.key("best_threads").uint(self.threads as u64);
        w.key("best_gflops").num(self.gflops);
        w.key("link_calibrated").boolean(self.link.is_some());
        if let Some(link) = &self.link {
            w.key("link_intra_ts").num(link.intra.ts);
            w.key("link_intra_tw").num(link.intra.tw);
            w.key("link_inter_ts").num(link.inter.ts);
            w.key("link_inter_tw").num(link.inter.tw);
        }
        w.key("results").begin_arr();
        for c in &self.cells {
            w.begin_obj();
            w.key("kernel").str_val(&c.kernel);
            w.key("b").uint(c.b as u64);
            w.key("threads").uint(c.threads as u64);
            w.key("gflops").num(c.gflops);
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }

    /// Parse a profile from its JSON text (the hand-rolled counterpart
    /// of [`TuneProfile::to_json`] — the image has no serde).
    pub fn from_json(text: &str) -> Result<TuneProfile> {
        let head = match text.find("\"results\"") {
            Some(at) => &text[..at],
            None => text,
        };
        match scan_num(head, "tune_profile") {
            Some(v) if v == Self::VERSION as f64 => {}
            Some(v) => bail!("unsupported tune profile version {v}"),
            None => bail!("not a tune profile (missing \"tune_profile\" version key)"),
        }
        let num = |k: &str| scan_num(head, k).ok_or_else(|| anyhow!("missing numeric key '{k}'"));
        let micro_name =
            scan_str(head, "micro").ok_or_else(|| anyhow!("missing string key 'micro'"))?;
        let micro = MicroKernel::by_name(&micro_name)
            .ok_or_else(|| anyhow!("unknown microkernel '{micro_name}' (have 8x8, 8x4, 4x8)"))?;
        let block = BlockParams {
            kc: num("kc")? as usize,
            mc: num("mc")? as usize,
            nc: num("nc")? as usize,
            micro,
            ew_par_threshold: num("ew_par_threshold")? as usize,
        };
        block.validate().map_err(|e| anyhow!("invalid tune profile params: {e}"))?;
        let link = match (
            scan_num(head, "link_intra_ts"),
            scan_num(head, "link_intra_tw"),
            scan_num(head, "link_inter_ts"),
            scan_num(head, "link_inter_tw"),
        ) {
            (Some(its), Some(itw), Some(ets), Some(etw)) => Some(LinkCalibration {
                intra: CostParams::new(its, itw),
                inter: CostParams::new(ets, etw),
            }),
            _ => None,
        };
        let cells = match text.find("\"results\"") {
            Some(at) => parse_cells(&text[at..])?,
            None => Vec::new(),
        };
        Ok(TuneProfile {
            host: scan_str(head, "host").unwrap_or_else(|| "unknown".into()),
            block,
            threads: num("best_threads")? as usize,
            gflops: num("best_gflops")?,
            link,
            cells,
            source: None,
        })
    }

    /// Load from disk, remembering the source path for report headers.
    pub fn load(path: &Path) -> Result<TuneProfile> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading tune profile {}", path.display()))?;
        let mut p = Self::from_json(&text)
            .with_context(|| format!("parsing tune profile {}", path.display()))?;
        p.source = Some(path.to_path_buf());
        Ok(p)
    }

    /// Write to disk (creating parent directories), and remember the
    /// path as this profile's source.
    pub fn save(&mut self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_json())
            .with_context(|| format!("writing tune profile {}", path.display()))?;
        self.source = Some(path.to_path_buf());
        Ok(())
    }
}

/// Scan `"key": <number>` in `head` (flat scalar region of a profile).
fn scan_num(head: &str, key: &str) -> Option<f64> {
    let rest = after_key(head, key)?;
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse::<f64>().ok()
}

/// Scan `"key": "<string>"` in `head` (values contain no escapes).
fn scan_str(head: &str, key: &str) -> Option<String> {
    let rest = after_key(head, key)?;
    let inner = rest.strip_prefix('"')?;
    Some(inner[..inner.find('"')?].to_string())
}

/// Position just past `"key":` plus whitespace, or None if absent.
fn after_key<'a>(head: &'a str, key: &str) -> Option<&'a str> {
    let pat = format!("\"{key}\"");
    let at = head.find(&pat)?;
    let rest = head[at + pat.len()..].trim_start();
    Some(rest.strip_prefix(':')?.trim_start())
}

/// Parse the `"results"` array entries (same splitting discipline as the
/// bench gate's parser: entries keyed by scanning each `{..}` segment).
fn parse_cells(tail: &str) -> Result<Vec<TuneCell>> {
    let open = tail.find('[').ok_or_else(|| anyhow!("results is not an array"))?;
    let close = tail.rfind(']').ok_or_else(|| anyhow!("unterminated results array"))?;
    let body = &tail[open + 1..close];
    let mut cells = Vec::new();
    for seg in body.split('}') {
        let Some(at) = seg.find('{') else { continue };
        let entry = &seg[at + 1..];
        if entry.trim().is_empty() {
            continue;
        }
        cells.push(TuneCell {
            kernel: scan_str(entry, "kernel")
                .ok_or_else(|| anyhow!("results entry missing 'kernel'"))?,
            b: scan_num(entry, "b").ok_or_else(|| anyhow!("results entry missing 'b'"))? as usize,
            threads: scan_num(entry, "threads")
                .ok_or_else(|| anyhow!("results entry missing 'threads'"))?
                as usize,
            gflops: scan_num(entry, "gflops")
                .ok_or_else(|| anyhow!("results entry missing 'gflops'"))?,
        });
    }
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TuneProfile {
        TuneProfile {
            host: "testhost".into(),
            block: BlockParams {
                kc: 384,
                mc: 96,
                nc: 256,
                micro: MicroKernel::Mr8Nr4,
                ew_par_threshold: 1 << 19,
            },
            threads: 4,
            gflops: 37.25,
            link: Some(LinkCalibration {
                intra: CostParams::new(2.1e-7, 9.0e-11),
                inter: CostParams::new(1.4e-5, 3.1e-10),
            }),
            cells: vec![
                TuneCell { kernel: "default".into(), b: 256, threads: 4, gflops: 33.5 },
                TuneCell { kernel: "tuned".into(), b: 256, threads: 4, gflops: 37.25 },
            ],
            source: None,
        }
    }

    #[test]
    fn json_round_trip_preserves_params() {
        let p = sample();
        let back = TuneProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back, p);
    }

    #[test]
    fn round_trip_without_link_calibration() {
        let mut p = sample();
        p.link = None;
        let json = p.to_json();
        assert!(json.contains("\"link_calibrated\":false"));
        assert!(!json.contains("link_intra_ts"));
        let back = TuneProfile::from_json(&json).unwrap();
        assert_eq!(back.link, None);
        assert_eq!(back, p);
    }

    #[test]
    fn file_round_trip_records_source() {
        let dir = std::env::temp_dir().join("foopar_tune_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tune-roundtrip.json");
        let mut p = sample();
        p.save(&path).unwrap();
        assert_eq!(p.source.as_deref(), Some(path.as_path()));
        let back = TuneProfile::load(&path).unwrap();
        assert_eq!(back.block, p.block);
        assert_eq!(back.link, p.link);
        assert_eq!(back.cells, p.cells);
        assert_eq!(back.source_label(), path.display().to_string());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_non_profiles_and_bad_params() {
        assert!(TuneProfile::from_json("{}").is_err());
        assert!(TuneProfile::from_json("{\"bench\":\"gemm\"}").is_err());
        // mc not a multiple of MR
        let bad = sample().to_json().replace("\"mc\":96", "\"mc\":97");
        assert!(TuneProfile::from_json(&bad).is_err());
        // unknown microkernel shape
        let bad = sample().to_json().replace("\"micro\":\"8x4\"", "\"micro\":\"3x3\"");
        assert!(TuneProfile::from_json(&bad).is_err());
    }

    #[test]
    fn default_path_is_per_host() {
        if std::env::var_os("HOME").is_some() {
            let p = TuneProfile::default_path().unwrap();
            let name = p.file_name().unwrap().to_string_lossy().into_owned();
            assert!(name.starts_with("tune-") && name.ends_with(".json"), "{name}");
            assert!(p.parent().unwrap().ends_with(".foopar"));
        }
    }

    #[test]
    fn calibration_prices_hierarchy() {
        let cal = sample().link.unwrap();
        let h = cal.hier();
        assert_eq!(h.intra, cal.intra);
        assert_eq!(h.inter, cal.inter);
        assert!(h.msg(true, 1024) < h.msg(false, 1024));
    }
}
