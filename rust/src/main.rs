//! `repro` — the FooPar-reproduction leader binary.
//!
//! Subcommands map 1:1 onto the paper's evaluation (see DESIGN.md §5):
//!
//! ```text
//! repro selftest                        end-to-end real-mode sanity (PJRT + algos)
//! repro peak   [--iters N]              single-core empirical peak (§6 calibration)
//! repro mmm    --p P [--plan | --algo SCHEDULE] --n N [--mode real|modeled] [--machine M]
//! repro apsp   --n N --p P [--algo fw|squaring] [--mode real|modeled]
//! repro plan   --explain [--what matmul|apsp] [--p P] [--n N]   planner candidate table
//! repro table1 [--machine M]            Table 1: op runtimes vs formulas
//! repro fig5   --machine carver|horseshoe6   Fig. 5 efficiency curves
//! repro isoeff [--algo generic|dns|fw]  isoefficiency verification
//! repro overhead [--machine M]          §6 framework-overhead comparison
//! ```

use anyhow::{bail, Result};

use foopar::algos::{
    apsp, apsp_squaring, collect_c, collect_d, dns_baseline, explain_apsp, explain_matmul,
    floyd_warshall, matmul, mmm_generic, seq, FwSpec, MatmulSpec, PlanMode, Schedule,
};
use foopar::analysis;
use foopar::cli::Args;
use foopar::comm::backend::registry;
use foopar::config::MachineConfig;
use foopar::experiments::{fig5, isoeff, overhead, peak, table1, tune};
use foopar::graph::{floyd_warshall_seq, Graph};
use foopar::matrix::block::BlockSource;
use foopar::metrics::JsonWriter;
use foopar::runtime::compute::Compute;
use foopar::runtime::engine::EngineServer;
use foopar::serve::{JobOutput, JobSpec, ServeClient, ServeOptions};
use foopar::{Runtime, TuneProfile};

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("help") | None => {
            println!("{}", HELP);
            Ok(())
        }
        Some("selftest") => selftest(),
        Some("backends") => {
            println!("registered communication backends:");
            for name in registry::names() {
                println!("  {name}");
            }
            Ok(())
        }
        Some("peak") => cmd_peak(args),
        Some("tune") => cmd_tune(args),
        Some("mmm") => cmd_mmm(args),
        Some("apsp") => cmd_apsp(args),
        Some("plan") => cmd_plan(args),
        Some("table1") => cmd_table1(args),
        Some("fig5") => cmd_fig5(args),
        Some("isoeff") => cmd_isoeff(args),
        Some("overhead") => cmd_overhead(args),
        Some("serve") => cmd_serve(args),
        Some("submit") => cmd_submit(args),
        Some("stats") => cmd_stats(args),
        _ => args.unknown(),
    }
}

const HELP: &str = "\
repro — FooPar reproduction (rust + JAX/Pallas AOT via PJRT)

  selftest                          end-to-end real-mode sanity
  peak     [--iters N] [--machine M] [--profile PATH]
                                    single-rank empirical peak: seed vs packed
                                    kernel at 1/2/4 threads, efficiency vs peak
  tune     [--quick] [--iters N] [--out PATH] [--no-link]
                                    per-host autotune: hill-climb the GEMM
                                    blocking (kc/mc/nc/microkernel/threads) on
                                    the native path and ping-pong the intra/
                                    inter-node link costs; writes
                                    ~/.foopar/tune-<host>.json (or --out)
  mmm      --p P [--n N] [--plan | --algo dns|dns-pipelined|cannon|cannon-pipelined|
           generic|baseline] [--mode real|modeled] [--machine M]
           [--transport local|tcp-loopback|hybrid] [--ranks-per-node N] [--backend B]
           [--threads T] [--trace OUT.json]
                                    --plan: cost-model-driven schedule choice
                                    (--algo forces one schedule through the
                                    same planner; baseline bypasses it)
  apsp     --p P [--n N] [--algo fw|squaring] [--mode real|modeled] [--threads T]
           [--transport local|tcp-loopback|hybrid] [--ranks-per-node N] [--backend B]
           [--trace OUT.json]
  plan     --explain [--what matmul|apsp] [--p P] [--n N] [--machine M]
           [--transport local|tcp-loopback|hybrid] [--ranks-per-node N] [--backend B]
                                    dry-run every candidate schedule on the
                                    cost model and print the table; nothing
                                    executes, no data moves
  table1   [--machine M]            Table 1: measured op runtimes vs formulas
  fig5     [--machine carver|horseshoe6]   Fig. 5 efficiency curves
  isoeff   [--algo generic|dns|fw] [--target E]   isoefficiency verification
  overhead [--machine M]            framework vs hand-coded DNS
  serve    [--world N] [--listen H:P] [--transport local|tcp-loopback|hybrid]
           [--ranks-per-node N] [--threads T] [--no-batch] [--max-batch K] [--trace OUT.json]
                                    resident serving pool + TCP submit endpoint
  submit   [--addr H:P] [--job matmul|fw] [--q Q] [--b B] [--n N] [--density D]
           [--seed-a S] [--seed-b S] [--seed S] [--count K] [--verify] [--json]
           [--shutdown]             submit jobs to (and optionally stop) a resident pool
  stats    [--addr H:P] [--json]    live pool statistics: occupancy, queue depth,
                                    latency/queue-wait quantiles, per-job gflops
  backends                          list registered communication backends

Tracing: any command also honours FOOPAR_TRACE=out.json; --trace writes a
Chrome-trace/Perfetto JSON plus a critical-path report at teardown.

Topology: --transport hybrid routes same-node envelopes over shared-memory
mailboxes and cross-node envelopes over TCP loopback; nodes are groups of
--ranks-per-node consecutive ranks (also settable via a machine-config
`ranks_per_node` key or FOOPAR_RANKS_PER_NODE).  Pair with --backend hier
for topology-aware two-level collectives on any transport.

Tuning: peak/mmm/apsp/serve load a per-host tune profile written by
`repro tune` — precedence: --profile PATH, then FOOPAR_TUNE_PROFILE, then
~/.foopar/tune-<host>.json if present, then a machine config's
`tune_profile` key, then built-in defaults.  The profile's block
parameters drive every native kernel; its measured link costs price the
hierarchical cost model on non-flat topologies.";

/// CLI tune-profile resolution (highest priority first): `--profile
/// PATH` (an unreadable path is an error, not a fallback), the
/// `FOOPAR_TUNE_PROFILE` env variable, then the default per-host path
/// when it exists.  `None` defers to the machine config / defaults.
fn resolve_profile(args: &Args) -> Result<Option<TuneProfile>> {
    if let Some(path) = args.get("profile") {
        return Ok(Some(TuneProfile::load(std::path::Path::new(path))?));
    }
    if let Ok(path) = std::env::var("FOOPAR_TUNE_PROFILE") {
        if !path.is_empty() {
            return Ok(Some(TuneProfile::load(std::path::Path::new(&path))?));
        }
    }
    if let Some(path) = TuneProfile::default_path() {
        if path.exists() {
            return Ok(Some(TuneProfile::load(&path)?));
        }
    }
    Ok(None)
}

/// The optional `--ranks-per-node` flag (absent ⇒ the builder falls back
/// to the machine config and then `FOOPAR_RANKS_PER_NODE`).
fn opt_ranks_per_node(args: &Args) -> Result<Option<usize>> {
    match args.get("ranks-per-node") {
        None => Ok(None),
        Some(_) => Ok(Some(args.get_usize("ranks-per-node", 1)?.max(1))),
    }
}

/// Parse a `--mode` flag into a Compute (PJRT-real prefers artifacts).
fn compute_for(mode: &str, machine: &MachineConfig) -> Result<Compute> {
    Ok(match mode {
        "modeled" => Compute::Modeled { rate: machine.rate },
        "real" => match EngineServer::start_default() {
            Ok(srv) => {
                // Leak the server: lives for the process (CLI runs one cmd).
                let handle = srv.handle();
                std::mem::forget(srv);
                Compute::Pjrt(std::sync::Arc::new(handle))
            }
            Err(e) => {
                eprintln!("note: PJRT unavailable ({e:#}); using native gemm");
                Compute::Native
            }
        },
        "native" => Compute::Native,
        other => bail!("--mode must be real|modeled|native, got '{other}'"),
    })
}

fn selftest() -> Result<()> {
    println!("== selftest: PJRT engine ==");
    match EngineServer::start_default() {
        Ok(srv) => {
            let h = srv.handle();
            let a = foopar::matrix::dense::Mat::random(32, 32, 1);
            let b = foopar::matrix::dense::Mat::random(32, 32, 2);
            let (got, secs) = h.matmul(a.clone(), b.clone())?;
            let want = foopar::matrix::gemm::matmul(&a, &b);
            let diff = got.max_abs_diff(&want);
            println!("  pallas matmul_b32 vs native: max|Δ| = {diff:.2e} ({secs:.4}s)  OK");
            assert!(diff < 1e-3);
        }
        Err(e) => println!("  skipped (no artifacts): {e:#}"),
    }

    println!("== selftest: planned MMM (real, q=2) ==");
    let a = BlockSource::real(16, 11);
    let b = BlockSource::real(16, 22);
    let res = Runtime::builder()
        .world(8)
        .machine("local")
        .run(|ctx| matmul(ctx, MatmulSpec::new(&Compute::Native, 2, &a, &b)))?;
    println!("  planner chose: {}", res.results[0].schedule.name());
    let c = collect_c(&res.results, 2, 16);
    let want = seq::matmul_seq(&a.assemble(2), &b.assemble(2));
    let diff = c.max_abs_diff(&want);
    println!("  parallel vs sequential: max|Δ| = {diff:.2e}  OK");
    assert!(diff < 1e-3);

    println!("== selftest: Floyd-Warshall (real, q=2) ==");
    let src = floyd_warshall::FwSource::Real { n: 16, density: 0.3, seed: 3 };
    let res = Runtime::builder()
        .world(4)
        .machine("local")
        .run(|ctx| apsp(ctx, FwSpec::new(&Compute::Native, 2, &src)))?;
    let d = collect_d(&res.results, 2, 8);
    let g = Graph::random(16, 0.3, 3);
    let want = floyd_warshall_seq(&g);
    let diff = d.max_abs_diff(&want);
    println!("  parallel vs sequential: max|Δ| = {diff:.2e}  OK");
    assert!(diff < 1e-3);

    println!("== selftest: modeled Fig5 headline ==");
    let (row, vs_peak) = fig5::headline(&MachineConfig::carver());
    println!(
        "  carver n={} p={}: E={:.1}% (vs theoretical peak {:.1}%; paper: 93.7%/88.8%)",
        row.n,
        row.p,
        row.efficiency * 100.0,
        vs_peak * 100.0
    );
    println!("selftest OK");
    Ok(())
}

fn cmd_peak(args: &Args) -> Result<()> {
    let iters = args.get_usize("iters", 10)?;
    let machine = MachineConfig::resolve(args.get_str("machine", "local"))?;
    let profile = resolve_profile(args)?;
    let block = profile.as_ref().map(|p| p.block).unwrap_or_default();
    let rows = peak::sweep_with(iters, &block);
    println!("{}", peak::render(&rows));
    match &profile {
        Some(p) => println!(
            "tune profile: {} — {} (swept best {:.2} GF/s at {} threads)",
            p.source_label(),
            p.block.label(),
            p.gflops,
            p.threads
        ),
        None => println!(
            "tune profile: none — defaults {} (run `repro tune` to calibrate this host)",
            block.label()
        ),
    }
    print!("{}", peak::efficiency_report(&rows, &machine));
    println!(
        "\n== elementwise kernels (bandwidth-bound; threaded past 1024² elements) ==\n"
    );
    let ew = peak::elementwise_sweep(iters.min(6));
    println!("{}", peak::render(&ew));
    if let Some(best) = rows
        .iter()
        .filter(|r| r.path == "pjrt")
        .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
    {
        println!(
            "empirical peak (pjrt, b={}): {:.2} GFlop/s — set `rate` in your machine config",
            best.b, best.gflops
        );
    }
    Ok(())
}

/// `repro tune` — run the autotuning sweep (and link calibration) and
/// persist the winning profile for later runs to load.
fn cmd_tune(args: &Args) -> Result<()> {
    let quick = args.has("quick");
    let mut cfg = if quick { tune::SweepConfig::quick() } else { tune::SweepConfig::full() };
    if args.get("iters").is_some() {
        cfg.iters = args.get_usize("iters", cfg.iters)?;
    }
    let calibrate = !args.has("no-link");
    let link_reps = if quick { 20 } else { 200 };
    println!(
        "tuning: sweeping kc/mc/nc/microkernel/threads at b={} ({} iters per cell){}",
        cfg.b,
        cfg.iters,
        if calibrate { ", then ping-pong link calibration" } else { "" }
    );
    let mut profile = tune::run(&cfg, calibrate, link_reps)?;
    print!("{}", tune::render(&profile));
    let out = match args.get("out") {
        Some(p) => std::path::PathBuf::from(p),
        None => TuneProfile::default_path().ok_or_else(|| {
            anyhow::anyhow!("no $HOME to derive the default profile path; pass --out PATH")
        })?,
    };
    profile.save(&out)?;
    println!("wrote {}", out.display());
    Ok(())
}

/// Matrix decomposition edge q for a `--p P` rank budget.  Cannon runs
/// on q² ranks, DNS/generic on q³; with `--plan` the planner needs one
/// q up front, so prefer the cube root (every candidate feasible) and
/// fall back to the square root (Cannon-only candidates).
fn mmm_grid_edge(p: usize, algo: &str, plan_auto: bool) -> Result<usize> {
    let sq = (p as f64).sqrt().round() as usize;
    let cb = (p as f64).cbrt().round() as usize;
    let is_square = sq * sq == p;
    let is_cube = cb * cb * cb == p;
    if plan_auto {
        if is_cube {
            return Ok(cb);
        }
        if is_square {
            return Ok(sq);
        }
        bail!("--plan needs --p to be a perfect cube or square, got {p}");
    }
    if algo.starts_with("cannon") {
        if !is_square {
            bail!("--p must be a square for cannon (4, 16, 64, 256), got {p}");
        }
        Ok(sq)
    } else {
        if !is_cube {
            bail!("--p must be a cube (8, 27, 64, 125, 216, 343, 512), got {p}");
        }
        Ok(cb)
    }
}

fn cmd_mmm(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "local"))?;
    let plan_auto = args.has("plan");
    let algo = args.get_str("algo", "dns");
    let p = args.get_usize("p", 8)?;
    let q = mmm_grid_edge(p, algo, plan_auto)?;
    let mode = args.get_str("mode", "modeled");
    let default_n = if mode == "modeled" { 40_320 } else { 16 * q };
    let n = args.get_usize("n", default_n)?;
    if n % q != 0 {
        bail!("--n must be divisible by q={q}");
    }
    let comp = compute_for(mode, &machine)?;
    let proxy = comp.is_modeled();
    let a = BlockSource { b: n / q, seed: 1, proxy };
    let b = BlockSource { b: n / q, seed: 2, proxy };
    let transport = args.get_str("transport", "local");
    if transport == "tcp" {
        // multi-process tcp re-execs the binary and returns local-only
        // results; this driver verifies by indexing all ranks, so only
        // the in-process transports are supported here
        bail!(
            "repro mmm supports --transport local|tcp-loopback; for the multi-process \
             tcp transport see `cargo run --release --example matmul_dns_tcp`"
        );
    }
    let threads = args.get_usize("threads", machine.threads_per_rank)?;
    let mut builder = Runtime::builder()
        .world(p)
        .backend(args.get_str("backend", "openmpi-fixed"))
        .transport(transport)
        .machine_config(&machine)
        .threads_per_rank(threads);
    if let Some(p) = resolve_profile(args)? {
        builder = builder.tune_profile(&p);
    }
    if let Some(rpn) = opt_ranks_per_node(args)? {
        builder = builder.ranks_per_node(rpn);
    }
    if let Some(path) = args.get("trace") {
        builder = builder.trace(path);
    }
    let rt = builder.build()?;

    let (t_parallel, wall, label) = if !plan_auto && algo == "baseline" {
        let r = rt.run(|ctx| dns_baseline::dns_baseline(ctx, &comp, q, &a, &b));
        (r.t_parallel, r.wall, "c-baseline".to_string())
    } else {
        let mode = if plan_auto {
            PlanMode::Auto
        } else {
            match Schedule::parse(algo) {
                Some(s) if s != Schedule::FwBlocking => PlanMode::Forced(s),
                _ => bail!(
                    "--algo must be dns|dns-pipelined|cannon|cannon-pipelined|generic|baseline, \
                     got '{algo}'"
                ),
            }
        };
        let r = rt.run(|ctx| matmul(ctx, MatmulSpec::new(&comp, q, &a, &b).mode(mode)));
        let schedule = r.results[0].schedule;
        if !proxy {
            let c = collect_c(&r.results, q, n / q);
            let want = seq::matmul_seq(&a.assemble(q), &b.assemble(q));
            println!("verified: max|Δ| = {:.2e}", c.max_abs_diff(&want));
        }
        if plan_auto {
            println!("planner chose: {}", schedule.name());
        }
        (r.t_parallel, r.wall, format!("foopar-{}", schedule.name()))
    };

    let ts = analysis::ts_n3(n, &fig5::model(&machine));
    println!(
        "{label}: n={n} p={p} mode={mode}  T_P={t_parallel:.4}s  E={:.1}%  ({:.2} TFlop/s)  wall={:.2}s",
        analysis::efficiency(ts, t_parallel, p) * 100.0,
        analysis::mmm_rate(n, t_parallel) / 1e12,
        wall.as_secs_f64()
    );
    Ok(())
}

fn cmd_apsp(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "local"))?;
    let p = args.get_usize("p", 4)?;
    let q = (p as f64).sqrt().round() as usize;
    if q * q != p {
        bail!("--p must be a square (4, 16, 64, 256), got {p}");
    }
    let mode = args.get_str("mode", "real");
    let n = args.get_usize("n", if mode == "modeled" { 8192 } else { 16 * q })?;
    if n % q != 0 {
        bail!("--n must be divisible by q={q}");
    }
    let comp = compute_for(mode, &machine)?;
    let src = if comp.is_modeled() {
        floyd_warshall::FwSource::Proxy { n }
    } else {
        floyd_warshall::FwSource::Real { n, density: 0.3, seed: 42 }
    };
    let algo = args.get_str("algo", "fw");
    let transport = args.get_str("transport", "local");
    if transport == "tcp" {
        bail!("repro apsp supports --transport local|tcp-loopback|hybrid");
    }
    let threads = args.get_usize("threads", machine.threads_per_rank)?;
    let mut builder = Runtime::builder()
        .world(p)
        .backend(args.get_str("backend", "openmpi-fixed"))
        .transport(transport)
        .machine_config(&machine)
        .threads_per_rank(threads);
    if let Some(p) = resolve_profile(args)? {
        builder = builder.tune_profile(&p);
    }
    if let Some(rpn) = opt_ranks_per_node(args)? {
        builder = builder.ranks_per_node(rpn);
    }
    if let Some(path) = args.get("trace") {
        builder = builder.trace(path);
    }
    let rt = builder.build()?;

    let t_parallel = match algo {
        "fw" => {
            let r = rt.run(|ctx| apsp(ctx, FwSpec::new(&comp, q, &src)));
            if let floyd_warshall::FwSource::Real { n, density, seed } = src {
                let d = collect_d(&r.results, q, n / q);
                let want = floyd_warshall_seq(&Graph::random(n, density, seed));
                println!("verified: max|Δ| = {:.2e}", d.max_abs_diff(&want));
            }
            r.t_parallel
        }
        "squaring" => {
            let r = rt.run(|ctx| apsp_squaring::apsp_squaring_par(ctx, &comp, q, &src));
            if let floyd_warshall::FwSource::Real { n, density, seed } = src {
                let d = apsp_squaring::saturate(apsp_squaring::collect_d(&r.results, q, n / q));
                let want = floyd_warshall_seq(&Graph::random(n, density, seed));
                println!("verified: max|Δ| = {:.2e}", d.max_abs_diff(&want));
            }
            r.t_parallel
        }
        other => bail!("--algo must be fw|squaring, got '{other}'"),
    };

    let ts = seq::fw_ts(n, machine.rate);
    println!(
        "apsp-{algo}: n={n} p={p} mode={mode}  T_P={t_parallel:.4}s  E={:.1}%",
        analysis::efficiency(ts, t_parallel, p) * 100.0
    );
    Ok(())
}

/// `repro plan --explain`: print the planner's candidate table — every
/// feasible schedule with its dry-run modeled `T_P`, the cheapest
/// marked — without executing anything.
fn cmd_plan(args: &Args) -> Result<()> {
    if !args.has("explain") {
        bail!("usage: repro plan --explain [--what matmul|apsp] [--p P] [--n N] [--machine M]");
    }
    let machine = MachineConfig::resolve(args.get_str("machine", "local"))?;
    let what = args.get_str("what", "matmul");
    let p = args.get_usize("p", 8)?;
    let comp = compute_for(args.get_str("mode", "modeled"), &machine)?;
    let transport = args.get_str("transport", "local");
    if transport == "tcp" {
        bail!("repro plan supports --transport local|tcp-loopback|hybrid");
    }
    let mut builder = Runtime::builder()
        .world(p)
        .backend(args.get_str("backend", "openmpi-fixed"))
        .transport(transport)
        .machine_config(&machine);
    if let Some(rpn) = opt_ranks_per_node(args)? {
        builder = builder.ranks_per_node(rpn);
    }
    let rt = builder.build()?;

    let rendered = match what {
        "matmul" => {
            let q = mmm_grid_edge(p, "", true)?;
            let n = args.get_usize("n", 40_320)?;
            if n % q != 0 {
                bail!("--n must be divisible by q={q}");
            }
            let a = BlockSource { b: n / q, seed: 1, proxy: true };
            let b = BlockSource { b: n / q, seed: 2, proxy: true };
            let rate = machine.rate;
            let r = rt.run(|ctx| {
                explain_matmul(ctx, MatmulSpec::new(&comp, q, &a, &b).rate_hint(rate)).render()
            });
            r.results.into_iter().next().expect("world is non-empty")
        }
        "apsp" => {
            let q = (p as f64).sqrt().round() as usize;
            if q * q != p {
                bail!("--p must be a square for apsp (4, 16, 64, 256), got {p}");
            }
            let n = args.get_usize("n", 8192)?;
            if n % q != 0 {
                bail!("--n must be divisible by q={q}");
            }
            let src = floyd_warshall::FwSource::Proxy { n };
            let r = rt.run(|ctx| explain_apsp(ctx, FwSpec::new(&comp, q, &src)).render());
            r.results.into_iter().next().expect("world is non-empty")
        }
        other => bail!("--what must be matmul|apsp, got '{other}'"),
    };
    print!("{rendered}");
    Ok(())
}

fn cmd_table1(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "carver"))?;
    let rows = table1::sweep(&machine);
    println!("{}", table1::render(&rows));
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "carver"))?;
    let with_baseline = machine.name == "carver";
    let rows = fig5::sweep(&machine, with_baseline);
    println!("{}", fig5::render(&rows));
    if machine.name == "carver" {
        let (row, vs_peak) = fig5::headline(&machine);
        println!(
            "headline: n={} p={}: {:.1}% of empirical peak, {:.1}% of theoretical (paper: 93.7% / 88.8%)",
            row.n, row.p, row.efficiency * 100.0, vs_peak * 100.0
        );
    }
    Ok(())
}

fn cmd_isoeff(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "carver"))?;
    let algos: Vec<isoeff::Algo> = match args.get("algo") {
        Some(s) => vec![isoeff::Algo::by_name(s)
            .ok_or_else(|| anyhow::anyhow!("unknown --algo '{s}'"))?],
        None => vec![isoeff::Algo::Generic, isoeff::Algo::Dns, isoeff::Algo::Fw],
    };
    for algo in algos {
        println!("== isoefficiency curve: {} (target E = {:.0}%) ==", algo.name(), isoeff::TARGET * 100.0);
        let rows = isoeff::iso_curve(&machine, algo);
        println!("{}", isoeff::render(&rows, algo.iso_label()));
    }
    Ok(())
}

fn cmd_overhead(args: &Args) -> Result<()> {
    let machine = MachineConfig::resolve(args.get_str("machine", "carver"))?;
    let rows = overhead::sweep(&machine);
    println!("{}", overhead::render(&rows));
    Ok(())
}

/// `repro serve` — bring up a resident pool and serve TCP submitters
/// until one of them requests shutdown.
fn cmd_serve(args: &Args) -> Result<()> {
    let world = args.get_usize("world", 5)?;
    let transport = args.get_str("transport", "local");
    let threads = args.get_usize("threads", 1)?;
    let mut opts = ServeOptions {
        listen: Some(args.get_str("listen", "127.0.0.1:7199").to_string()),
        ..ServeOptions::default()
    };
    if args.has("no-batch") {
        opts.batching = false;
    }
    opts.max_batch = args.get_usize("max-batch", opts.max_batch)?;

    let mut builder = Runtime::builder()
        .world(world)
        .transport(transport)
        .threads_per_rank(threads);
    if let Some(p) = resolve_profile(args)? {
        builder = builder.tune_profile(&p);
    }
    if let Some(rpn) = opt_ranks_per_node(args)? {
        builder = builder.ranks_per_node(rpn);
    }
    if let Some(path) = args.get("trace") {
        builder = builder.trace(path);
    }
    let rt = builder.build()?;
    println!(
        "serving: world {world} (pool of {}), transport {transport}, batching {}",
        world - 1,
        if opts.batching { "on" } else { "off" }
    );
    let ((), report) = rt.serve(opts, |h| {
        if let Some(addr) = h.listen_addr() {
            println!("serving: listening on {addr}");
        }
        h.wait_shutdown();
    })?;
    println!(
        "serving: drained — {} submitted, {} done, {} failed, {} rejected, {} assignments",
        report.submitted, report.done, report.failed, report.rejected, report.assignments
    );
    println!(
        "serving: latency p50 {:.2} ms, p99 {:.2} ms, mean {:.2} ms",
        report.latency.p50() * 1e3,
        report.latency.p99() * 1e3,
        report.latency.mean() * 1e3
    );
    println!(
        "serving: queue-wait p50 {:.2} ms, p99 {:.2} ms, mean {:.2} ms",
        report.queue_wait.p50() * 1e3,
        report.queue_wait.p99() * 1e3,
        report.queue_wait.mean() * 1e3
    );
    Ok(())
}

/// `repro stats` — query a live pool for occupancy, queue depth,
/// latency/queue-wait quantiles, and the per-job roster.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7199");
    let mut client = ServeClient::connect(addr)?;
    let snap = client.stats()?;
    if args.has("json") {
        println!("{}", snap.to_json());
    } else {
        print!("{}", snap.render());
    }
    Ok(())
}

/// `repro submit` — submit jobs to a resident pool over TCP, await
/// their results (optionally verifying each against a fresh in-process
/// single-job oracle run), and/or request shutdown.
fn cmd_submit(args: &Args) -> Result<()> {
    let addr = args.get_str("addr", "127.0.0.1:7199");
    let mut client = ServeClient::connect(addr)?;
    if let Some(kind) = args.get("job") {
        let count = args.get_usize("count", 1)? as u64;
        let verify = args.has("verify");
        let json = args.has("json");
        let q = args.get_usize("q", 2)?;
        let mut ids = Vec::new();
        for k in 0..count {
            let spec = match kind {
                "matmul" => JobSpec::Matmul {
                    q,
                    b: args.get_usize("b", 16)?,
                    seed_a: args.get_usize("seed-a", 1)? as u64 + 2 * k,
                    seed_b: args.get_usize("seed-b", 2)? as u64 + 2 * k,
                },
                "fw" => JobSpec::FloydWarshall {
                    q,
                    n: args.get_usize("n", 16)?,
                    density: args.get_f64("density", 0.4)?,
                    seed: args.get_usize("seed", 7)? as u64 + k,
                },
                other => bail!("--job must be matmul|fw, got '{other}'"),
            };
            let id = client.submit(spec.clone())?;
            ids.push((id, spec));
        }
        let mut outcomes = Vec::new();
        for (id, spec) in ids {
            let res = client.wait(id)?;
            if let Ok(out) = &res {
                if verify {
                    verify_against_oracle(&spec, out)?;
                }
            }
            if !json {
                match &res {
                    Ok(_) if verify => println!(
                        "job {id} ({}): OK, bit-identical to single-job oracle",
                        spec.kind()
                    ),
                    Ok(_) => println!("job {id} ({}): OK", spec.kind()),
                    Err(e) => bail!("job {id} ({}) failed: {e}", spec.kind()),
                }
            }
            outcomes.push((id, spec, res.err()));
        }
        if json {
            // enrich each outcome with the server's roster row — the
            // scoped per-job gflops/queue-wait only the dispatcher knows
            let snap = client.stats()?;
            let mut w = JsonWriter::new();
            w.begin_arr();
            for (id, spec, err) in &outcomes {
                w.begin_obj();
                w.key("id").uint(*id);
                w.key("kind").str_val(spec.kind());
                w.key("ok").boolean(err.is_none());
                if let Some(e) = err {
                    w.key("error").str_val(e);
                }
                if let Some(row) = snap.jobs.iter().find(|j| j.id == *id) {
                    w.key("status").str_val(&row.status);
                    w.key("schedule").str_val(&row.schedule);
                    w.key("gflops").num(row.gflops);
                    w.key("queue_wait_secs").num(if row.queue_wait_secs < 0.0 {
                        f64::NAN // → null
                    } else {
                        row.queue_wait_secs
                    });
                }
                w.end_obj();
            }
            w.end_arr();
            println!("{}", w.finish());
            if let Some((id, spec, Some(e))) = outcomes.iter().find(|(_, _, err)| err.is_some()) {
                bail!("job {id} ({}) failed: {e}", spec.kind());
            }
        }
    }
    if args.has("shutdown") {
        client.shutdown()?;
        println!("shutdown requested");
    }
    Ok(())
}

/// Re-run the job standalone (its own dedicated q×q world) and demand
/// bit-identical output — the serving path must not perturb results.
fn verify_against_oracle(spec: &JobSpec, got: &JobOutput) -> Result<()> {
    let JobOutput::Mat(got) = got else {
        bail!("unexpected batch output for a single job");
    };
    let want = match spec {
        JobSpec::Matmul { q, b, seed_a, seed_b } => {
            let (q, b, sa, sb) = (*q, *b, *seed_a, *seed_b);
            let res = Runtime::builder().world(q * q).build()?.run(move |ctx| {
                let a = BlockSource::real(b, sa);
                let bb = BlockSource::real(b, sb);
                matmul(ctx, MatmulSpec::new(&Compute::Native, q, &a, &bb))
            });
            collect_c(&res.results, q, b)
        }
        JobSpec::FloydWarshall { q, n, density, seed } => {
            let (q, n, density, seed) = (*q, *n, *density, *seed);
            let res = Runtime::builder().world(q * q).build()?.run(move |ctx| {
                let src = floyd_warshall::FwSource::Real { n, density, seed };
                apsp(ctx, FwSpec::new(&Compute::Native, q, &src))
            });
            collect_d(&res.results, q, n / q)
        }
        other => bail!("--verify supports matmul and fw, not {}", other.kind()),
    };
    if *got != want {
        bail!(
            "served result diverges from the single-job oracle (max |Δ| = {:.3e})",
            got.max_abs_diff(&want)
        );
    }
    Ok(())
}
