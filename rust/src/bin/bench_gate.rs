//! `bench_gate` — the CI bench-regression gate.
//!
//! Compares a freshly produced `BENCH_*.json` against the committed
//! baseline and exits non-zero if any configuration regressed by more
//! than the tolerance (default 10% GFlop/s), or vanished from the fresh
//! results entirely.  Driven by `scripts/bench_gate`, which stashes the
//! committed baselines before the benches overwrite them in place.
//!
//! ```text
//! bench_gate --baseline <committed.json> --fresh <fresh.json> [--tolerance 0.10]
//! bench_gate --check <any.json>
//! ```
//!
//! `--check` runs the same parser over a single file and exits 0 iff it
//! holds a well-formed `"results"` array — the CI `tune-smoke` job
//! validates `repro tune` output with it, so a profile that the gate's
//! own parser couldn't read never gets persisted as a CI artifact.
//!
//! The parser is deliberately minimal: it understands exactly the flat
//! `"results": [ {..}, {..} ]` layout our bench drivers emit (the
//! image's crate cache has no serde).  Entries are keyed by their
//! identity fields (`kernel`/`op`, `b`, `threads`) and compared on
//! `gflops`.  Higher is better; improvements always pass — blessing a
//! faster baseline is a deliberate act (see README § bench gate), not
//! something CI does implicitly.

use std::collections::HashMap;
use std::process::ExitCode;

use foopar::cli::Args;

/// Default allowed fractional GFlop/s drop before the gate trips.
const DEFAULT_TOLERANCE: f64 = 0.10;

/// One bench configuration: identity key + its measured rate.
#[derive(Clone, Debug, PartialEq)]
struct Entry {
    key: String,
    gflops: f64,
}

/// Extract the entries of a bench JSON's `"results"` array.  Tolerant of
/// whitespace/ordering, strict about the fields: every entry must carry
/// a `gflops` number, and identity is the concatenation of the known
/// identity fields in file order.
fn parse_entries(json: &str) -> Result<Vec<Entry>, String> {
    let at = json
        .find("\"results\"")
        .ok_or_else(|| "no \"results\" key".to_string())?;
    let rest = &json[at..];
    let lb = rest.find('[').ok_or_else(|| "no results array".to_string())?;
    let rb = rest
        .rfind(']')
        .filter(|&i| i > lb)
        .ok_or_else(|| "unterminated results array".to_string())?;
    let body = &rest[lb + 1..rb];

    let mut entries = Vec::new();
    for chunk in body.split('}') {
        let Some(ob) = chunk.find('{') else { continue };
        let fields = &chunk[ob + 1..];
        let mut id: Vec<String> = Vec::new();
        let mut gflops: Option<f64> = None;
        for kv in fields.split(',') {
            let Some((k, v)) = kv.split_once(':') else { continue };
            let k = k.trim().trim_matches('"');
            let v = v.trim().trim_matches('"');
            match k {
                "gflops" => {
                    gflops =
                        Some(v.parse::<f64>().map_err(|_| format!("bad gflops value '{v}'"))?);
                }
                "kernel" | "op" | "b" | "threads" => id.push(format!("{k}={v}")),
                _ => {}
            }
        }
        if id.is_empty() && gflops.is_none() {
            continue; // stray separator noise, not an entry
        }
        let g = gflops.ok_or_else(|| format!("entry without gflops: {{{fields}}}"))?;
        if id.is_empty() {
            return Err(format!("entry without identity fields: {{{fields}}}"));
        }
        entries.push(Entry { key: id.join(" "), gflops: g });
    }
    if entries.is_empty() {
        return Err("results array holds no entries".to_string());
    }
    Ok(entries)
}

/// Diff fresh against baseline: every baseline configuration must still
/// exist and hold ≥ `(1 - tolerance) ×` its baseline GFlop/s.  Returns
/// the human-readable failures (empty = gate passes).
fn compare(baseline: &[Entry], fresh: &[Entry], tolerance: f64) -> Vec<String> {
    let fresh_by_key: HashMap<&str, f64> =
        fresh.iter().map(|e| (e.key.as_str(), e.gflops)).collect();
    let mut failures = Vec::new();
    for b in baseline {
        match fresh_by_key.get(b.key.as_str()) {
            None => failures.push(format!("missing from fresh results: {}", b.key)),
            Some(&g) if g < b.gflops * (1.0 - tolerance) => failures.push(format!(
                "regression: {} — {:.2} GFlop/s vs baseline {:.2} ({:+.1}%, tolerance -{:.0}%)",
                b.key,
                g,
                b.gflops,
                (g / b.gflops - 1.0) * 100.0,
                tolerance * 100.0
            )),
            _ => {}
        }
    }
    failures
}

/// The gate proper, separated from `main` so the unit tests below can
/// drive it on doctored JSON without touching the filesystem.
fn gate(baseline_json: &str, fresh_json: &str, tolerance: f64) -> Result<(), Vec<String>> {
    let baseline = parse_entries(baseline_json).map_err(|e| vec![format!("baseline: {e}")])?;
    let fresh = parse_entries(fresh_json).map_err(|e| vec![format!("fresh: {e}")])?;
    let failures = compare(&baseline, &fresh, tolerance);
    if failures.is_empty() {
        Ok(())
    } else {
        Err(failures)
    }
}

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("bench_gate: {e:#}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = args.get("check") {
        return match std::fs::read_to_string(path)
            .map_err(|e| format!("read {path}: {e}"))
            .and_then(|text| parse_entries(&text))
        {
            Ok(entries) => {
                println!("bench_gate check PASS: {path} holds {} entries", entries.len());
                ExitCode::SUCCESS
            }
            Err(e) => {
                eprintln!("bench_gate check FAIL: {path}: {e}");
                ExitCode::FAILURE
            }
        };
    }
    let run = || -> Result<(String, String, f64), String> {
        let baseline_path = args
            .get("baseline")
            .ok_or("missing required --baseline <committed.json>")?;
        let fresh_path = args.get("fresh").ok_or("missing required --fresh <fresh.json>")?;
        let tolerance = args
            .get_f64("tolerance", DEFAULT_TOLERANCE)
            .map_err(|e| e.to_string())?;
        let baseline = std::fs::read_to_string(baseline_path)
            .map_err(|e| format!("read {baseline_path}: {e}"))?;
        let fresh = std::fs::read_to_string(fresh_path)
            .map_err(|e| format!("read {fresh_path}: {e}"))?;
        Ok((baseline, fresh, tolerance))
    };
    let (baseline, fresh, tolerance) = match run() {
        Ok(t) => t,
        Err(e) => {
            eprintln!("bench_gate: {e}");
            return ExitCode::from(2);
        }
    };
    match gate(&baseline, &fresh, tolerance) {
        Ok(()) => {
            println!(
                "bench gate PASS: no configuration regressed beyond {:.0}%",
                tolerance * 100.0
            );
            ExitCode::SUCCESS
        }
        Err(failures) => {
            for f in &failures {
                eprintln!("bench gate FAIL: {f}");
            }
            ExitCode::FAILURE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(gflops_b512_t4: f64) -> String {
        format!(
            "{{\n\"bench\": \"gemm_kernel\",\n\"results\": [\n  \
             {{\"kernel\": \"seed\", \"b\": 512, \"threads\": 1, \"iters\": 6, \
             \"secs_per_iter\": 1.0e-01, \"gflops\": 2.63, \"speedup_vs_seed\": 1.0}},\n  \
             {{\"kernel\": \"packed\", \"b\": 512, \"threads\": 4, \"iters\": 6, \
             \"secs_per_iter\": 7.0e-03, \"gflops\": {gflops_b512_t4}, \
             \"speedup_vs_seed\": 14.49}}\n]\n}}\n"
        )
    }

    #[test]
    fn parses_identity_and_gflops() {
        let entries = parse_entries(&sample(38.12)).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].key, "kernel=seed b=512 threads=1");
        assert_eq!(entries[1].key, "kernel=packed b=512 threads=4");
        assert!((entries[1].gflops - 38.12).abs() < 1e-9);
    }

    #[test]
    fn parses_op_keyed_entries_too() {
        let json = "{\"results\": [ {\"op\": \"add\", \"b\": 2048, \"threads\": 4, \
                    \"gflops\": 2.5} ]}";
        let entries = parse_entries(json).unwrap();
        assert_eq!(entries[0].key, "op=add b=2048 threads=4");
    }

    #[test]
    fn identical_results_pass() {
        assert!(gate(&sample(38.12), &sample(38.12), 0.10).is_ok());
    }

    #[test]
    fn improvement_and_small_noise_pass() {
        // faster than baseline: fine
        assert!(gate(&sample(38.12), &sample(44.0), 0.10).is_ok());
        // 5% down: inside the 10% tolerance
        assert!(gate(&sample(38.12), &sample(36.2), 0.10).is_ok());
    }

    #[test]
    fn doctored_regressing_json_fails_the_gate() {
        // the negative test of the acceptance criteria: feed the gate a
        // fresh file whose b=512 t=4 rate dropped ~20% — it must FAIL
        let failures = gate(&sample(38.12), &sample(30.5), 0.10).unwrap_err();
        assert_eq!(failures.len(), 1, "{failures:?}");
        assert!(failures[0].contains("regression"), "{failures:?}");
        assert!(failures[0].contains("kernel=packed b=512 threads=4"), "{failures:?}");
    }

    #[test]
    fn missing_configuration_fails_the_gate() {
        let fresh = "{\"results\": [ {\"kernel\": \"seed\", \"b\": 512, \"threads\": 1, \
                     \"gflops\": 2.63} ]}";
        let failures = gate(&sample(38.12), fresh, 0.10).unwrap_err();
        assert!(failures.iter().any(|f| f.contains("missing")), "{failures:?}");
    }

    #[test]
    fn malformed_json_is_an_error_not_a_pass() {
        assert!(gate("{}", &sample(38.12), 0.10).is_err());
        assert!(gate(&sample(38.12), "{\"results\": []}", 0.10).is_err());
        assert!(parse_entries("{\"results\": [ {\"kernel\": \"x\", \"b\": 1} ]}").is_err());
    }

    #[test]
    fn tune_profile_shape_parses_for_check_mode() {
        // what `bench_gate --check` sees from `repro tune`: scalar params
        // before the results array, cells keyed kernel/b/threads/gflops
        // (kernel values may contain spaces — the blocking label)
        let json = "{\"tune_profile\":1,\"host\":\"h\",\"kc\":256,\"mc\":64,\"nc\":128,\
                    \"micro\":\"8x8\",\"ew_par_threshold\":1048576,\"best_threads\":2,\
                    \"best_gflops\":21.5,\"link_calibrated\":false,\"results\":[\
                    {\"kernel\":\"default\",\"b\":128,\"threads\":1,\"gflops\":18.0},\
                    {\"kernel\":\"kc128 mc64 nc128 8x8 t1\",\"b\":128,\"threads\":1,\
                    \"gflops\":19.2},\
                    {\"kernel\":\"tuned\",\"b\":128,\"threads\":2,\"gflops\":21.5}]}";
        let entries = parse_entries(json).unwrap();
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[1].key, "kernel=kc128 mc64 nc128 8x8 t1 b=128 threads=1");
        assert!((entries[2].gflops - 21.5).abs() < 1e-9);
    }

    #[test]
    fn baseline_without_profile_field_still_gates_against_fresh_with_it() {
        // provenance field is new; committed baselines predate it and
        // must keep gating fresh files that carry it
        let fresh = sample(38.12).replacen(
            "\"bench\": \"gemm_kernel\",",
            "\"bench\": \"gemm_kernel\",\n\"profile\": \"kc256 mc64 nc128 8x8\",",
            1,
        );
        assert!(gate(&sample(38.12), &fresh, 0.10).is_ok());
        assert!(gate(&fresh, &sample(38.12), 0.10).is_ok());
    }

    #[test]
    fn tolerance_is_respected() {
        // 20% down passes a 25% tolerance, fails a 10% one
        assert!(gate(&sample(40.0), &sample(32.0), 0.25).is_ok());
        assert!(gate(&sample(40.0), &sample(32.0), 0.10).is_err());
    }
}
