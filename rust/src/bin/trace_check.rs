//! `trace_check` — the CI trace-smoke validator.
//!
//! Structurally validates one or more Chrome-trace JSON files produced
//! by `repro ... --trace out.json` (or `FOOPAR_TRACE=out.json`) and
//! prints what it found.  Exits non-zero if any file fails, so the CI
//! trace-smoke job trips on malformed exports the same way the bench
//! gate trips on regressions.  Driven by `scripts/trace_check`.
//!
//! ```text
//! trace_check <trace.json>... [--strict] [--min-ranks N]
//! ```
//!
//! `--strict` additionally requires every flow send to pair with a
//! receive — correct for whole-world traces, too strict for partial
//! ones.  `--min-ranks` asserts the export covers at least N Perfetto
//! processes (CI passes the run's world size).

use std::process::ExitCode;

use foopar::cli::Args;
use foopar::trace::validate_chrome;

/// Validate one file; returns the human-readable summary line.
fn check(path: &str, strict: bool, min_ranks: usize) -> Result<String, String> {
    let json = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
    let s = validate_chrome(&json, strict).map_err(|e| format!("{path}: {e}"))?;
    if s.x_events == 0 {
        return Err(format!("{path}: no complete (ph:X) span events"));
    }
    if s.ranks < min_ranks {
        return Err(format!(
            "{path}: trace covers {} rank(s), expected at least {min_ranks}",
            s.ranks
        ));
    }
    Ok(format!(
        "{path}: OK — {} events ({} spans), {} ranks, {} threads, {} flow pairs{}",
        s.events,
        s.x_events,
        s.ranks,
        s.threads,
        s.flow_pairs,
        if s.unmatched_send > 0 {
            format!(", {} unmatched sends", s.unmatched_send)
        } else {
            String::new()
        }
    ))
}

fn main() -> ExitCode {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("trace_check: {e:#}");
            return ExitCode::from(2);
        }
    };
    let strict = args.has("strict");
    let min_ranks = match args.get_usize("min-ranks", 1) {
        Ok(n) => n,
        Err(e) => {
            eprintln!("trace_check: {e:#}");
            return ExitCode::from(2);
        }
    };
    // the flag grammar files the first bare argument under `subcommand`
    let mut paths = args.positional.clone();
    if let Some(first) = args.subcommand.clone() {
        paths.insert(0, first);
    }
    if paths.is_empty() {
        eprintln!("usage: trace_check <trace.json>... [--strict] [--min-ranks N]");
        return ExitCode::from(2);
    }

    let mut failed = false;
    for path in &paths {
        match check(path, strict, min_ranks) {
            Ok(line) => println!("{line}"),
            Err(e) => {
                eprintln!("trace_check FAIL: {e}");
                failed = true;
            }
        }
    }
    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
