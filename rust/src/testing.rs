//! In-tree test/bench support: deterministic RNG and a tiny property-based
//! testing harness.
//!
//! The image's crate cache has neither `proptest` nor `rand`, so this module
//! provides the minimum machinery the test suite needs: a fast, seedable
//! xorshift generator and a [`prop_check`] driver that runs a closure over
//! many generated cases and reports the failing seed (so failures are
//! reproducible by construction).

use crate::comm::backend::BackendProfile;
use crate::comm::cost::CostParams;
use crate::spmd::{Ctx, RunResult, Runtime};

/// Per-rank kernel thread count used by the test suite: the
/// `FOOPAR_TEST_THREADS` env var, clamped to ≥ 1 (default 1).  CI runs
/// the whole suite in a {1, 4} matrix so the data plane's bit-identity
/// guarantees are exercised by *every* test touching `Compute::Native`
/// on every push — not only by the dedicated dataplane tests.
pub fn test_threads() -> usize {
    std::env::var("FOOPAR_TEST_THREADS")
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(1)
        .max(1)
}

/// Launch an SPMD world for a test: positional convenience over
/// [`Runtime::builder`] with an explicit profile and raw cost
/// parameters, honoring [`test_threads`].  This is the test-suite entry
/// point (the deprecated positional `spmd::run` shim was removed once
/// callers migrated to the builder).
pub fn spmd_run<R, F>(
    world: usize,
    backend: BackendProfile,
    machine: CostParams,
    f: F,
) -> RunResult<R>
where
    R: Send,
    F: Fn(&Ctx) -> R + Sync,
{
    Runtime::builder()
        .world(world)
        .backend_profile(backend)
        .cost(machine)
        .threads_per_rank(test_threads())
        .build()
        .expect("invalid SPMD configuration (world size must be positive)")
        .run(f)
}

/// xorshift64* — tiny, fast, good-enough statistical quality for test-case
/// generation and synthetic workloads (not cryptographic).
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded constructor; seed 0 is remapped (xorshift fixed point).
    pub fn new(seed: u64) -> Self {
        Rng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn gen_f32_range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.gen_f32() * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_range(xs.len())]
    }

    pub fn gen_bool(&mut self, p_true: f64) -> bool {
        self.gen_f64() < p_true
    }
}

/// Run `f` over `cases` generated cases. Each case gets an [`Rng`] derived
/// from a fixed base seed + case index; on panic the failing seed is
/// reported so the case can be replayed with `Rng::new(seed)`.
pub fn prop_check<F: Fn(&mut Rng)>(name: &str, cases: u64, f: F) {
    let base_seed: u64 = 0xF00_BA5;
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9));
        let mut rng = Rng::new(seed);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = res {
            panic!("property '{name}' failed on case {i} (seed={seed:#x}): {e:?}");
        }
    }
}

/// Assert two f32 slices are elementwise close (abs + rel tolerance).
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (&g, &w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at {i}: got {g}, want {w} (tol {tol})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_threads_defaults_to_one_and_clamps() {
        // NOTE: reads the ambient env — when CI sets FOOPAR_TEST_THREADS
        // the parsed value must be ≥ 1 either way
        assert!(test_threads() >= 1);
    }

    #[test]
    fn rng_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_range_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let v = r.gen_range(13);
            assert!(v < 13);
            let f = r.gen_f32();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rng_distribution_roughly_uniform() {
        let mut r = Rng::new(99);
        let mut buckets = [0usize; 8];
        for _ in 0..80_000 {
            buckets[r.gen_range(8)] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket count {b} out of range");
        }
    }

    #[test]
    fn allclose_passes_and_fails() {
        assert_allclose(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-5, 1e-6);
        let r = std::panic::catch_unwind(|| assert_allclose(&[1.0], &[2.0], 1e-5, 1e-6));
        assert!(r.is_err());
    }

    #[test]
    fn prop_check_reports_seed() {
        let r = std::panic::catch_unwind(|| prop_check("always-fails", 1, |_| panic!("boom")));
        assert!(r.is_err());
    }
}
