//! The external submitter API: a framed TCP protocol in front of
//! [`ServeHandle`](super::ServeHandle).
//!
//! The resident pool lives in one process (in-process transport); other
//! processes reach it through a tiny request/response protocol carried
//! as length-prefixed frames (`u32` LE length + body) encoded with the
//! **same wire codec the rank transport uses**
//! ([`crate::comm::wire`]) — one serialization story end to end.
//! `repro serve --listen` starts the listener, `repro submit` is a
//! stock client, and [`ServeClient`] is the programmatic one.
//!
//! Each connection is served by its own thread and handles requests
//! strictly in order — a `Wait` blocks that connection (not the pool)
//! until the job is terminal.  Concurrency comes from opening multiple
//! connections, exactly like submitting from multiple threads.

use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Context;

use super::server::{ServeHandle, ServeShared};
use super::stats::StatsSnapshot;
use super::{JobOutput, JobSpec, JobStatus};
use crate::comm::wire::{WireData, WireError, WireReader};
use crate::data::value::Data;

/// Client → server requests.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Submit(JobSpec),
    Status(u64),
    /// Block (this connection) until the job is terminal.
    Wait(u64),
    Shutdown,
    /// Live pool statistics: occupancy, queue depth, latency and
    /// queue-wait quantiles, per-job roster (`repro stats`).
    Stats,
}

/// Server → client responses, one per request.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    Submitted(u64),
    Status(Option<JobStatus>),
    /// Terminal outcome of a `Wait`: the output on success, the
    /// failure/rejection reason otherwise.
    Outcome { output: Option<JobOutput>, err: Option<String> },
    ShuttingDown,
    Stats(StatsSnapshot),
}

impl Data for Request {
    fn byte_size(&self) -> usize {
        1 + match self {
            Request::Submit(spec) => spec.byte_size(),
            Request::Status(_) | Request::Wait(_) => 8,
            Request::Shutdown | Request::Stats => 0,
        }
    }
}

impl WireData for Request {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Request::Submit(spec) => {
                out.push(0);
                spec.encode(out);
            }
            Request::Status(id) => {
                out.push(1);
                id.encode(out);
            }
            Request::Wait(id) => {
                out.push(2);
                id.encode(out);
            }
            Request::Shutdown => out.push(3),
            Request::Stats => out.push(4),
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => Request::Submit(JobSpec::decode(r)?),
            1 => Request::Status(r.u64()?),
            2 => Request::Wait(r.u64()?),
            3 => Request::Shutdown,
            4 => Request::Stats,
            _ => return Err(WireError::Malformed("unknown Request tag")),
        })
    }
}

impl Data for Response {
    fn byte_size(&self) -> usize {
        1 + match self {
            Response::Submitted(_) => 8,
            Response::Status(s) => 1 + s.as_ref().map_or(0, |s| s.byte_size()),
            Response::Outcome { output, err } => {
                output.as_ref().map_or(1, |o| 1 + o.byte_size())
                    + err.as_ref().map_or(1, |e| 9 + e.len())
            }
            Response::ShuttingDown => 0,
            Response::Stats(s) => s.byte_size(),
        }
    }
}

impl WireData for Response {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Response::Submitted(id) => {
                out.push(0);
                id.encode(out);
            }
            Response::Status(s) => {
                out.push(1);
                s.encode(out);
            }
            Response::Outcome { output, err } => {
                out.push(2);
                output.encode(out);
                err.encode(out);
            }
            Response::ShuttingDown => out.push(3),
            Response::Stats(s) => {
                out.push(4);
                s.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => Response::Submitted(r.u64()?),
            1 => Response::Status(Option::decode(r)?),
            2 => Response::Outcome { output: Option::decode(r)?, err: Option::decode(r)? },
            3 => Response::ShuttingDown,
            4 => Response::Stats(StatsSnapshot::decode(r)?),
            _ => return Err(WireError::Malformed("unknown Response tag")),
        })
    }
}

/// Frames over 256 MiB are protocol corruption, not real traffic.
const FRAME_MAX: usize = 256 << 20;

fn write_frame<T: WireData>(stream: &mut TcpStream, v: &T) -> std::io::Result<()> {
    let mut body = Vec::with_capacity(v.byte_size() + 4);
    body.extend_from_slice(&[0u8; 4]);
    v.encode(&mut body);
    let len = u32::try_from(body.len() - 4).expect("frame over 4 GiB");
    body[0..4].copy_from_slice(&len.to_le_bytes());
    stream.write_all(&body)?;
    stream.flush()
}

/// Read one frame; `Ok(None)` on a clean between-frames EOF.
fn read_frame<T: WireData>(stream: &mut TcpStream) -> std::io::Result<Option<T>> {
    let mut len4 = [0u8; 4];
    match stream.read(&mut len4[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(e),
    }
    stream.read_exact(&mut len4[1..])?;
    let len = u32::from_le_bytes(len4) as usize;
    if len > FRAME_MAX {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("serve frame of {len} bytes exceeds the {FRAME_MAX} B cap"),
        ));
    }
    let mut buf = vec![0u8; len];
    stream.read_exact(&mut buf)?;
    let mut r = WireReader::new(&buf);
    let v = T::decode(&mut r)
        .map_err(|e| std::io::Error::new(ErrorKind::InvalidData, format!("{e:?}")))?;
    if r.remaining() != 0 {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            "trailing bytes in serve frame",
        ));
    }
    Ok(Some(v))
}

/// Bind the client endpoint, record the bound address in the shared
/// state, and accept connections until shutdown.  Each connection gets
/// its own handler thread over a cloned [`ServeHandle`].
pub(crate) fn spawn_listener(
    addr: &str,
    handle: ServeHandle,
    shared: Arc<ServeShared>,
) -> crate::Result<JoinHandle<()>> {
    let listener =
        TcpListener::bind(addr).with_context(|| format!("bind serve listener on {addr}"))?;
    listener
        .set_nonblocking(true)
        .context("serve listener nonblocking mode")?;
    let bound = listener.local_addr().context("serve listener local addr")?;
    shared.set_listen_addr(bound);
    Ok(std::thread::spawn(move || {
        loop {
            if handle.is_shutdown() {
                return;
            }
            match listener.accept() {
                Ok((stream, _)) => {
                    // handlers block in wait(); the accept loop stays
                    // nonblocking so shutdown is always observed
                    let _ = stream.set_nonblocking(false);
                    let h = handle.clone();
                    std::thread::spawn(move || {
                        let _ = serve_conn(stream, h);
                    });
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(_) => return,
            }
        }
    }))
}

fn serve_conn(mut stream: TcpStream, handle: ServeHandle) -> std::io::Result<()> {
    while let Some(req) = read_frame::<Request>(&mut stream)? {
        let resp = match req {
            Request::Submit(spec) => Response::Submitted(handle.submit(spec)),
            Request::Status(id) => Response::Status(handle.status(id)),
            Request::Wait(id) => match handle.wait(id) {
                Ok(output) => Response::Outcome { output: Some(output), err: None },
                Err(e) => Response::Outcome { output: None, err: Some(e) },
            },
            Request::Shutdown => {
                handle.shutdown();
                Response::ShuttingDown
            }
            Request::Stats => Response::Stats(handle.stats()),
        };
        write_frame(&mut stream, &resp)?;
    }
    Ok(())
}

/// Programmatic submitter for an external process (also what
/// `repro submit` uses).  One synchronous request/response channel;
/// open several clients for concurrent waits.
pub struct ServeClient {
    stream: TcpStream,
}

impl ServeClient {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> crate::Result<Self> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connect to serving runtime at {addr:?}"))?;
        stream.set_nodelay(true).ok();
        Ok(ServeClient { stream })
    }

    fn call(&mut self, req: &Request) -> crate::Result<Response> {
        write_frame(&mut self.stream, req).context("send request to serving runtime")?;
        read_frame::<Response>(&mut self.stream)
            .context("read response from serving runtime")?
            .context("serving runtime closed the connection")
    }

    /// Submit a job, returning its id.
    pub fn submit(&mut self, spec: JobSpec) -> crate::Result<u64> {
        match self.call(&Request::Submit(spec))? {
            Response::Submitted(id) => Ok(id),
            other => anyhow::bail!("protocol error: unexpected response {other:?}"),
        }
    }

    /// Current status of a job.
    pub fn status(&mut self, id: u64) -> crate::Result<Option<JobStatus>> {
        match self.call(&Request::Status(id))? {
            Response::Status(s) => Ok(s),
            other => anyhow::bail!("protocol error: unexpected response {other:?}"),
        }
    }

    /// Block until the job is terminal; inner `Err` carries the
    /// failure/rejection reason.
    pub fn wait(&mut self, id: u64) -> crate::Result<Result<JobOutput, String>> {
        match self.call(&Request::Wait(id))? {
            Response::Outcome { output: Some(out), err: None } => Ok(Ok(out)),
            Response::Outcome { err: Some(e), .. } => Ok(Err(e)),
            other => anyhow::bail!("protocol error: unexpected response {other:?}"),
        }
    }

    /// Ask the pool to drain and exit.
    pub fn shutdown(&mut self) -> crate::Result<()> {
        match self.call(&Request::Shutdown)? {
            Response::ShuttingDown => Ok(()),
            other => anyhow::bail!("protocol error: unexpected response {other:?}"),
        }
    }

    /// Live pool statistics (what `repro stats` prints).
    pub fn stats(&mut self) -> crate::Result<StatsSnapshot> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => anyhow::bail!("protocol error: unexpected response {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: WireData + PartialEq + std::fmt::Debug>(v: &T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        assert_eq!(&T::decode(&mut r).expect("decode"), v);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn request_wire_roundtrip() {
        roundtrip(&Request::Submit(JobSpec::Matmul { q: 2, b: 8, seed_a: 1, seed_b: 2 }));
        roundtrip(&Request::Status(9));
        roundtrip(&Request::Wait(11));
        roundtrip(&Request::Shutdown);
        roundtrip(&Request::Stats);
    }

    #[test]
    fn response_wire_roundtrip() {
        use crate::matrix::dense::Mat;
        roundtrip(&Response::Submitted(4));
        roundtrip(&Response::Status(Some(JobStatus::Running)));
        roundtrip(&Response::Status(None));
        roundtrip(&Response::Outcome {
            output: Some(JobOutput::Mat(Mat::from_vec(1, 2, vec![1.0, 2.0]))),
            err: None,
        });
        roundtrip(&Response::Outcome { output: None, err: Some("died".into()) });
        roundtrip(&Response::ShuttingDown);
        roundtrip(&Response::Stats(StatsSnapshot {
            capacity: 4,
            busy: 2,
            queue_depth: 1,
            submitted: 3,
            jobs: vec![super::super::stats::JobStat {
                id: 1,
                kind: "matmul".into(),
                status: "running".into(),
                gflops: 0.0,
                queue_wait_secs: 0.002,
                schedule: "-".into(),
            }],
            ..Default::default()
        }));
    }
}
