//! The resident serving runtime: dispatcher, workers, and the
//! [`Runtime::serve`] entry point.
//!
//! `Runtime::serve(opts, driver)` brings the world up **once** and
//! keeps it up: rank 0 becomes the *dispatcher*, ranks `1..world` park
//! in a *worker* loop, and a driver closure (plus, optionally, external
//! TCP clients — see [`super::client`]) submits jobs through a
//! [`ServeHandle`].  The dispatcher multiplexes jobs over the pool:
//!
//! * **admission** — first queued job whose grid fits the free ranks
//!   runs; jobs that can never fit are rejected at submit
//!   ([`scheduler`](super::scheduler));
//! * **assignment** — members get a [`Control::Assign`] carrying the
//!   spec, the rank subset, and a fresh **tag scope** derived from the
//!   job id, so every group the job builds lives in its own namespace
//!   and concurrent jobs never cross-match (satellite of
//!   [`Group::partition`](crate::comm::group::Group::partition));
//! * **completion** — each member reports a [`MemberDone`] with its
//!   *scoped* metrics delta; the job root's report carries the output;
//! * **scoped failure** — when a member reports a panic, the
//!   dispatcher poisons only that job's still-unreported members
//!   ([`Transport::fail_ranks`]); they unwind promptly, the job is
//!   marked failed with the root cause, and the ranks rejoin the pool
//!   after a [`Transport::clear_fail`] on their next assignment.
//!   In-flight jobs on disjoint rank subsets never notice.
//!
//! Control traffic rides reserved high tags ([`CONTROL_TAG`],
//! [`DONE_TAG`]) just below the runtime's clock-gather tag; job traffic
//! cannot collide with either.  Workers *poll* for control messages
//! (probe + short sleep) instead of blocking in `take`, so an idle pool
//! never trips the transport's deadlock oracle.
//!
//! Job latency (submit → terminal) is wall-clock time on the serving
//! plane — the §2 virtual-time cost model still governs each job's
//! *internal* communication, but queueing and multiplexing are real.
//!
//! [`Transport::fail_ranks`]: crate::comm::transport::Transport::fail_ranks
//! [`Transport::clear_fail`]: crate::comm::transport::Transport::clear_fail

use std::collections::{HashMap, VecDeque};
use std::net::SocketAddr;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::scheduler::{plan_next, Pool};
use super::stats::{JobStat, QuantileSummary, StatsSnapshot};
use super::{client, Control, JobOutput, JobSpec, JobStatus, MemberDone, CONTROL_TAG, DONE_TAG};
use crate::algos::floyd_warshall::FwSource;
use crate::comm::group::Group;
use crate::matrix::block::{Block, BlockSource};
use crate::matrix::dense::Mat;
use crate::metrics::{Histogram, JsonWriter, MetricsSnapshot, Report};
use crate::plan::{self, FwSpec, MatmulSpec, Schedule};
use crate::runtime::compute::Compute;
use crate::spmd::{Ctx, Runtime};
use crate::trace;

/// Serving-plane configuration.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Coalesce queued same-shape single-rank GEMMs into one
    /// assignment (see [`super::scheduler::plan_next`]).
    pub batching: bool,
    /// Max jobs per coalesced assignment.
    pub max_batch: usize,
    /// When set, serve a TCP client endpoint on this address
    /// (e.g. `"127.0.0.1:0"` for an ephemeral port); external
    /// processes then submit via [`super::ServeClient`].
    pub listen: Option<String>,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions { batching: true, max_batch: 8, listen: None }
    }
}

impl ServeOptions {
    /// Batching disabled — the serving-throughput bench's control arm.
    pub fn unbatched() -> Self {
        ServeOptions { batching: false, ..ServeOptions::default() }
    }
}

/// End-of-serve accounting, returned by [`Runtime::serve`].
#[derive(Clone, Debug, Default)]
pub struct ServeReport {
    pub submitted: u64,
    pub done: u64,
    pub failed: u64,
    pub rejected: u64,
    /// Assignments the dispatcher issued; `assignments < done` proves
    /// the batcher coalesced (each assignment covers ≥ 1 job).
    pub assignments: u64,
    /// Per-job submit → terminal latency (wall clock).
    pub latency: Histogram,
    /// Per-job submit → assign queue wait (wall clock) — the
    /// dispatcher-side admission stall that `latency` folds in but
    /// doesn't isolate.  Rejected jobs never enter it.
    pub queue_wait: Histogram,
}

/// One job's bookkeeping in the table.
struct JobEntry {
    spec: JobSpec,
    status: JobStatus,
    output: Option<JobOutput>,
    /// Scoped per-member metrics deltas (a batched job shares its
    /// assignment's measurement).
    member_metrics: Vec<MetricsSnapshot>,
    submitted: Instant,
    /// Submit → assign wait, set at the Queued → Running transition.
    queue_wait_secs: Option<f64>,
    /// The planner's chosen schedule code, reported with the members'
    /// completion (`None` until the job finishes, or for faults).
    schedule: Option<u8>,
}

struct SharedInner {
    next_id: u64,
    queue: VecDeque<u64>,
    jobs: HashMap<u64, JobEntry>,
    shutdown: bool,
    /// Set when the SPMD runtime itself died — every wait unblocks
    /// with this as the error.
    dead: Option<String>,
    listen_enabled: bool,
    listen_addr: Option<SocketAddr>,
    report: ServeReport,
    /// Ranks currently occupied by assignments — published by the
    /// dispatcher (which owns the [`Pool`]) so `stats()` can report
    /// occupancy without touching dispatcher-local state.
    busy: usize,
}

/// State shared between the driver thread, the dispatcher rank, and
/// TCP client connections.
pub(crate) struct ServeShared {
    inner: Mutex<SharedInner>,
    cv: Condvar,
}

impl ServeShared {
    fn new(listen_enabled: bool) -> Self {
        ServeShared {
            inner: Mutex::new(SharedInner {
                next_id: 1,
                queue: VecDeque::new(),
                jobs: HashMap::new(),
                shutdown: false,
                dead: None,
                listen_enabled,
                listen_addr: None,
                report: ServeReport::default(),
                busy: 0,
            }),
            cv: Condvar::new(),
        }
    }

    pub(crate) fn set_dead(&self, msg: &str) {
        let mut inner = self.inner.lock().unwrap();
        if inner.dead.is_none() {
            inner.dead = Some(msg.to_string());
        }
        self.cv.notify_all();
    }

    pub(crate) fn set_listen_addr(&self, addr: SocketAddr) {
        let mut inner = self.inner.lock().unwrap();
        inner.listen_addr = Some(addr);
        self.cv.notify_all();
    }

    fn final_report(&self) -> ServeReport {
        self.inner.lock().unwrap().report.clone()
    }
}

/// Submitter's view of the resident pool: submit, poll, wait, shut
/// down.  Cheap to clone; every clone addresses the same job table.
#[derive(Clone)]
pub struct ServeHandle {
    shared: Arc<ServeShared>,
    capacity: usize,
}

const WAIT_POLL: Duration = Duration::from_millis(25);

impl ServeHandle {
    /// Pool capacity in ranks (world minus the dispatcher).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Submit a job; returns its id immediately.  Malformed jobs and
    /// jobs whose grid can never fit the pool are rejected here (the
    /// id still resolves, with [`JobStatus::Rejected`]).
    pub fn submit(&self, spec: JobSpec) -> u64 {
        let mut inner = self.shared.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.report.submitted += 1;
        let reject = spec.invalid_reason().or_else(|| {
            let need = spec.ranks_needed();
            if need > self.capacity {
                Some(format!(
                    "job needs {need} ranks but the pool has {}",
                    self.capacity
                ))
            } else if inner.shutdown {
                Some("serving runtime is shutting down".into())
            } else {
                inner.dead.as_ref().map(|d| format!("serving runtime died: {d}"))
            }
        });
        let status = match reject {
            Some(reason) => {
                inner.report.rejected += 1;
                JobStatus::Rejected(reason)
            }
            None => {
                inner.queue.push_back(id);
                JobStatus::Queued
            }
        };
        inner.jobs.insert(
            id,
            JobEntry {
                spec,
                status,
                output: None,
                member_metrics: Vec::new(),
                schedule: None,
                submitted: Instant::now(),
                queue_wait_secs: None,
            },
        );
        self.shared.cv.notify_all();
        id
    }

    /// Current lifecycle state, or `None` for an unknown id.
    pub fn status(&self, id: u64) -> Option<JobStatus> {
        self.shared.inner.lock().unwrap().jobs.get(&id).map(|e| e.status.clone())
    }

    /// Block until the job is terminal; `Ok(output)` on success, the
    /// failure/rejection reason otherwise.  The output is handed over
    /// exactly once — a second wait on a done job errors.
    pub fn wait(&self, id: u64) -> Result<JobOutput, String> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if let Some(dead) = &inner.dead {
                return Err(format!("serving runtime died: {dead}"));
            }
            let Some(entry) = inner.jobs.get(&id) else {
                return Err(format!("unknown job id {id}"));
            };
            match &entry.status {
                JobStatus::Done => {
                    let entry = inner.jobs.get_mut(&id).unwrap();
                    return entry
                        .output
                        .take()
                        .ok_or_else(|| format!("job {id} output already consumed"));
                }
                JobStatus::Failed(m) | JobStatus::Rejected(m) => return Err(m.clone()),
                JobStatus::Queued | JobStatus::Running => {
                    inner = self.shared.cv.wait_timeout(inner, WAIT_POLL).unwrap().0;
                }
            }
        }
    }

    /// Aggregate of the job's **scoped** per-member metrics deltas —
    /// per-job gflops/latency that don't bleed between jobs
    /// multiplexed on the same ranks (complete once terminal).
    pub fn job_report(&self, id: u64) -> Option<Report> {
        let inner = self.shared.inner.lock().unwrap();
        inner.jobs.get(&id).map(|e| Report::aggregate(&e.member_metrics))
    }

    /// Serving-plane counters so far (final version returned by
    /// [`Runtime::serve`]).
    pub fn report(&self) -> ServeReport {
        self.shared.inner.lock().unwrap().report.clone()
    }

    /// Point-in-time snapshot of the pool: occupancy, queue depth, the
    /// serving counters, latency/queue-wait quantiles, and a per-job
    /// roster — the payload behind [`Request::Stats`] and `repro stats`.
    ///
    /// [`Request::Stats`]: super::client::Request::Stats
    pub fn stats(&self) -> StatsSnapshot {
        let inner = self.shared.inner.lock().unwrap();
        let mut jobs: Vec<JobStat> = inner
            .jobs
            .iter()
            .map(|(&id, e)| JobStat {
                id,
                kind: e.spec.kind().to_string(),
                status: e.status.label().to_string(),
                gflops: Report::aggregate(&e.member_metrics).max_gflops,
                queue_wait_secs: e.queue_wait_secs.unwrap_or(-1.0),
                schedule: schedule_label(e.schedule),
            })
            .collect();
        jobs.sort_by_key(|j| j.id);
        StatsSnapshot {
            capacity: self.capacity as u64,
            busy: inner.busy as u64,
            queue_depth: inner.queue.len() as u64,
            submitted: inner.report.submitted,
            done: inner.report.done,
            failed: inner.report.failed,
            rejected: inner.report.rejected,
            assignments: inner.report.assignments,
            latency: QuantileSummary::of(&inner.report.latency),
            queue_wait: QuantileSummary::of(&inner.report.queue_wait),
            jobs,
        }
    }

    /// JSON rendering of [`job_report`](Self::job_report) plus the
    /// job's lifecycle fields — what `repro submit --json` prints.
    /// `None` for an unknown id.
    pub fn job_report_json(&self, id: u64) -> Option<String> {
        let inner = self.shared.inner.lock().unwrap();
        let e = inner.jobs.get(&id)?;
        let r = Report::aggregate(&e.member_metrics);
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("id").uint(id);
        w.key("kind").str_val(e.spec.kind());
        w.key("status").str_val(e.status.label());
        match &e.status {
            JobStatus::Failed(m) | JobStatus::Rejected(m) => {
                w.key("error").str_val(m);
            }
            _ => {}
        }
        match e.queue_wait_secs {
            Some(s) => {
                w.key("queue_wait_secs").num(s);
            }
            None => {
                w.key("queue_wait_secs").num(f64::NAN); // → null
            }
        }
        if let Some(s) = e.schedule.and_then(Schedule::from_code) {
            w.key("schedule").str_val(s.name());
        }
        w.key("ranks").uint(r.ranks as u64);
        w.key("msgs_sent").uint(r.total.msgs_sent);
        w.key("bytes_sent").uint(r.total.bytes_sent);
        w.key("collectives").uint(r.total.collectives);
        w.key("flops").num(r.total.flops);
        w.key("comm_time_max").num(r.max_comm_time);
        w.key("compute_time_max").num(r.max_compute_time);
        w.key("gflops_max").num(r.max_gflops);
        w.key("ew_gflops_max").num(r.max_ew_gflops);
        w.end_obj();
        Some(w.finish())
    }

    /// Request shutdown: new submits are refused, queued and running
    /// jobs drain, then the pool exits.
    pub fn shutdown(&self) {
        let mut inner = self.shared.inner.lock().unwrap();
        inner.shutdown = true;
        self.shared.cv.notify_all();
    }

    /// Block until someone (a TCP client, another handle clone)
    /// requested shutdown — the driver body of `repro serve`.
    pub fn wait_shutdown(&self) {
        let mut inner = self.shared.inner.lock().unwrap();
        while !inner.shutdown && inner.dead.is_none() {
            inner = self.shared.cv.wait_timeout(inner, WAIT_POLL).unwrap().0;
        }
    }

    /// The bound TCP client endpoint.  Blocks until the listener is up;
    /// `None` when no listener was configured (or the runtime died
    /// first).
    pub fn listen_addr(&self) -> Option<SocketAddr> {
        let mut inner = self.shared.inner.lock().unwrap();
        loop {
            if !inner.listen_enabled || inner.dead.is_some() {
                return None;
            }
            if let Some(addr) = inner.listen_addr {
                return Some(addr);
            }
            inner = self.shared.cv.wait_timeout(inner, WAIT_POLL).unwrap().0;
        }
    }

    pub(crate) fn is_shutdown(&self) -> bool {
        let inner = self.shared.inner.lock().unwrap();
        inner.shutdown || inner.dead.is_some()
    }
}

impl Runtime {
    /// Bring the world up **resident**: rank 0 dispatches, ranks
    /// `1..world` serve, and `driver` runs on a separate thread with a
    /// [`ServeHandle`] to submit concurrent jobs.  Returns the driver's
    /// result plus the serving-plane accounting once the pool has
    /// drained and shut down (the driver returning implies shutdown).
    ///
    /// Requires an in-process transport (`"local"` or
    /// `"tcp-loopback"`) and `world ≥ 2`; external processes submit
    /// over the TCP client API (`ServeOptions::listen`) instead of
    /// joining the world.
    pub fn serve<R, F>(&self, opts: ServeOptions, driver: F) -> crate::Result<(R, ServeReport)>
    where
        R: Send,
        F: FnOnce(&ServeHandle) -> R + Send,
    {
        if self.world() < 2 {
            anyhow::bail!("serving needs world >= 2 (a dispatcher plus at least one pool rank)");
        }
        if self.is_multiprocess() {
            anyhow::bail!(
                "serving needs an in-process transport (\"local\" or \"tcp-loopback\"); \
                 external submitters connect over the TCP client API instead"
            );
        }
        let shared = Arc::new(ServeShared::new(opts.listen.is_some()));
        let handle = ServeHandle { shared: Arc::clone(&shared), capacity: self.world() - 1 };

        let listener = match &opts.listen {
            Some(addr) => Some(client::spawn_listener(addr, handle.clone(), Arc::clone(&shared))?),
            None => None,
        };

        let (run_res, driver_res) = std::thread::scope(|s| {
            let h2 = handle.clone();
            let dh = s.spawn(move || {
                let r = catch_unwind(AssertUnwindSafe(|| driver(&h2)));
                // driver done (or dead): drain and release the pool
                h2.shutdown();
                r
            });
            let sh: &ServeShared = &shared;
            let o = &opts;
            let rr = catch_unwind(AssertUnwindSafe(|| {
                self.run(|ctx| {
                    if ctx.rank == 0 {
                        dispatcher(ctx, sh, o);
                    } else {
                        worker(ctx);
                    }
                })
            }));
            if let Err(e) = &rr {
                // unblock every wait with the root cause before the
                // scope tries to join the driver
                sh.set_dead(&panic_text(e.as_ref()));
            }
            let dr = dh.join().expect("serving driver thread");
            (rr, dr)
        });
        if let Some(l) = listener {
            let _ = l.join();
        }
        let report = shared.final_report();
        if let Err(e) = run_res {
            resume_unwind(e);
        }
        match driver_res {
            Ok(r) => Ok((r, report)),
            Err(e) => resume_unwind(e),
        }
    }
}

/// Tag-scope seed for an assignment: unique per (job, assignment) and
/// never 0 (0 means "no scope").
fn job_scope(job: u64, assign: u64) -> u64 {
    let s = Group::derive_id(job.wrapping_add(0x5E4E_1D), assign);
    if s == 0 {
        1
    } else {
        s
    }
}

fn panic_text(e: &(dyn std::any::Any + Send)) -> String {
    e.downcast_ref::<String>()
        .cloned()
        .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "<non-string panic>".into())
}

/// One in-flight assignment, tracked dispatcher-side.
struct AssignState {
    jobs: Vec<u64>,
    ranks: Vec<usize>,
    unreported: Vec<usize>,
    poisoned: bool,
    err: Option<String>,
    output: Option<JobOutput>,
    member_metrics: Vec<MetricsSnapshot>,
    schedule: Option<u8>,
}

const IDLE_POLL: Duration = Duration::from_micros(300);

fn dispatcher(ctx: &Ctx, shared: &ServeShared, opts: &ServeOptions) {
    let mut pool = Pool::new(ctx.world);
    let mut running: HashMap<u64, AssignState> = HashMap::new();
    let mut next_assign: u64 = 1;
    loop {
        let mut progress = false;

        // 1. drain completion reports
        for src in 1..ctx.world {
            while ctx.transport().probe(0, src, DONE_TAG) {
                let done: MemberDone = ctx.recv(src, DONE_TAG);
                progress = true;
                let finished = {
                    let st = running
                        .get_mut(&done.assign)
                        .expect("completion report for unknown assignment");
                    st.unreported.retain(|&r| r != src);
                    st.member_metrics.push(done.metrics);
                    st.schedule = st.schedule.or(done.schedule);
                    if let Some(out) = done.output {
                        st.output = Some(out);
                    }
                    if !done.ok {
                        if st.err.is_none() {
                            st.err =
                                Some(done.err.unwrap_or_else(|| "job member failed".into()));
                        }
                        if !st.poisoned && !st.unreported.is_empty() {
                            // scoped abort: only this job's members that
                            // haven't reported yet — a member whose ok
                            // report is merely in flight gets poisoned
                            // too, which is benign (clear_fail precedes
                            // its next assignment)
                            st.poisoned = true;
                            let reason = format!(
                                "serving: job {} aborted: {}",
                                st.jobs[0],
                                st.err.as_deref().unwrap_or("member failed")
                            );
                            ctx.transport().fail_ranks(&st.unreported, &reason);
                        }
                    }
                    st.unreported.is_empty()
                };
                if finished {
                    let st = running.remove(&done.assign).unwrap();
                    pool.release(&st.ranks);
                    finish_assignment(shared, st);
                }
            }
        }

        // 2. admit queued jobs onto free ranks
        loop {
            let planned = {
                let mut inner = shared.inner.lock().unwrap();
                let mut snapshot: VecDeque<(u64, JobSpec)> = inner
                    .queue
                    .iter()
                    .map(|&id| (id, inner.jobs[&id].spec.clone()))
                    .collect();
                match plan_next(&mut snapshot, pool.available(), opts.batching, opts.max_batch)
                {
                    None => None,
                    Some(adm) => {
                        inner.queue.retain(|id| !adm.jobs.contains(id));
                        for id in &adm.jobs {
                            let entry = inner.jobs.get_mut(id).unwrap();
                            entry.status = JobStatus::Running;
                            let wait = entry.submitted.elapsed().as_secs_f64();
                            entry.queue_wait_secs = Some(wait);
                            inner.report.queue_wait.record(wait);
                        }
                        inner.report.assignments += 1;
                        // the planner guarantees the take below succeeds,
                        // so occupancy can be published while still locked
                        inner.busy += adm.need;
                        Some(adm)
                    }
                }
            };
            let Some(adm) = planned else { break };
            shared.cv.notify_all();
            let ranks = pool.take(adm.need).expect("planner checked the fit");
            let assign = next_assign;
            next_assign += 1;
            let scope = job_scope(adm.jobs[0], assign);
            let mut sp = trace::span("assign", trace::Category::Serve);
            if sp.is_active() {
                sp.arg("assign", assign as f64);
                sp.arg("jobs", adm.jobs.len() as f64);
                sp.arg("ranks", ranks.len() as f64);
            }
            for &r in &ranks {
                ctx.send(
                    r,
                    CONTROL_TAG,
                    Control::Assign {
                        assign,
                        jobs: adm.jobs.clone(),
                        spec: adm.spec.clone(),
                        ranks: ranks.clone(),
                        scope,
                    },
                );
            }
            drop(sp);
            running.insert(
                assign,
                AssignState {
                    jobs: adm.jobs,
                    ranks: ranks.clone(),
                    unreported: ranks,
                    poisoned: false,
                    err: None,
                    output: None,
                    member_metrics: Vec::new(),
                    schedule: None,
                },
            );
            progress = true;
        }

        // 3. drain-and-exit once shutdown is requested and the pool is idle
        if running.is_empty() {
            let idle_and_done = {
                let inner = shared.inner.lock().unwrap();
                inner.shutdown && inner.queue.is_empty()
            };
            if idle_and_done {
                for r in 1..ctx.world {
                    ctx.send(r, CONTROL_TAG, Control::Shutdown);
                }
                return;
            }
        }

        if !progress {
            std::thread::sleep(IDLE_POLL);
        }
    }
}

/// Fold a fully-reported assignment into the job table: split outputs
/// across the covered jobs, mark them terminal, record latencies.
fn finish_assignment(shared: &ServeShared, st: AssignState) {
    let mut inner = shared.inner.lock().unwrap();
    inner.busy = inner.busy.saturating_sub(st.ranks.len());
    let n = st.jobs.len();
    let mut outputs: Vec<Option<JobOutput>> = vec![None; n];
    let mut err = st.err;
    if err.is_none() {
        match st.output {
            Some(JobOutput::Mats(mats)) if n > 1 => {
                if mats.len() == n {
                    for (slot, m) in outputs.iter_mut().zip(mats) {
                        *slot = Some(JobOutput::Mat(m));
                    }
                } else {
                    err = Some(format!(
                        "batch produced {} outputs for {} jobs",
                        mats.len(),
                        n
                    ));
                }
            }
            Some(single) if n == 1 => outputs[0] = Some(single),
            _ => err = Some("job completed without an output".into()),
        }
    }
    for (k, id) in st.jobs.iter().enumerate() {
        let entry = inner.jobs.get_mut(id).expect("finished job is in the table");
        entry.member_metrics = st.member_metrics.clone();
        entry.schedule = st.schedule;
        match &err {
            Some(e) => entry.status = JobStatus::Failed(e.clone()),
            None => {
                entry.output = outputs[k].take();
                entry.status = JobStatus::Done;
            }
        }
        let lat = entry.submitted.elapsed().as_secs_f64();
        match &err {
            Some(_) => inner.report.failed += 1,
            None => inner.report.done += 1,
        }
        inner.report.latency.record(lat);
    }
    shared.cv.notify_all();
}

fn worker(ctx: &Ctx) {
    loop {
        // poll, don't block: an idle pool must not trip the transport's
        // deadlock oracle
        while !ctx.transport().probe(ctx.rank, 0, CONTROL_TAG) {
            std::thread::sleep(IDLE_POLL);
        }
        match ctx.recv::<Control>(0, CONTROL_TAG) {
            Control::Shutdown => return,
            Control::Assign { assign, spec, ranks, scope, .. } => {
                // recover from a previous job's scoped poison (stale
                // envelopes from its namespace are dropped with it);
                // safe because the dispatcher never queues a second
                // control message before our MemberDone
                ctx.transport().clear_fail(ctx.rank);
                let baseline = ctx.metrics.snapshot();
                let mut sp = trace::span("job", trace::Category::Serve);
                if sp.is_active() {
                    sp.arg("assign", assign as f64);
                    sp.arg("width", ranks.len() as f64);
                }
                let result = catch_unwind(AssertUnwindSafe(|| {
                    ctx.with_tag_scope(scope, || run_job(ctx, &spec, &ranks))
                }));
                drop(sp);
                let metrics = ctx.metrics.snapshot().scoped(&baseline);
                let done = match result {
                    Ok((output, schedule)) => MemberDone {
                        assign,
                        ok: true,
                        err: None,
                        output,
                        metrics,
                        schedule: schedule.map(Schedule::code),
                    },
                    Err(e) => MemberDone {
                        assign,
                        ok: false,
                        err: Some(panic_text(e.as_ref())),
                        output: None,
                        metrics,
                        schedule: None,
                    },
                };
                ctx.send(0, DONE_TAG, done);
            }
        }
    }
}

/// Execute one assignment on this member.  Returns the job output on
/// the job root (`ranks[0]`, `None` elsewhere) plus the planner's
/// chosen schedule code (`None` for fault injections).
fn run_job(ctx: &Ctx, spec: &JobSpec, ranks: &[usize]) -> (Option<JobOutput>, Option<Schedule>) {
    let root = ctx.rank == ranks[0];
    match spec {
        JobSpec::Matmul { q, b, seed_a, seed_b } => {
            let a = BlockSource::real(*b, *seed_a);
            let bb = BlockSource::real(*b, *seed_b);
            let out = plan::matmul(ctx, MatmulSpec::new(&Compute::Native, *q, &a, &bb).on(ranks));
            (
                gather_result(ctx, ranks, *q, *b, out.c_block).map(JobOutput::Mat),
                Some(out.schedule),
            )
        }
        JobSpec::MatmulBatch { q, b, pairs } => {
            let mut mats = Vec::with_capacity(pairs.len());
            let mut schedule = None;
            for &(sa, sb) in pairs {
                let a = BlockSource::real(*b, sa);
                let bb = BlockSource::real(*b, sb);
                let out =
                    plan::matmul(ctx, MatmulSpec::new(&Compute::Native, *q, &a, &bb).on(ranks));
                schedule = Some(out.schedule);
                if let Some(m) = gather_result(ctx, ranks, *q, *b, out.c_block) {
                    mats.push(m);
                }
            }
            (if root { Some(JobOutput::Mats(mats)) } else { None }, schedule)
        }
        JobSpec::FloydWarshall { q, n, density, seed } => {
            let src = FwSource::Real { n: *n, density: *density, seed: *seed };
            let out = plan::apsp(ctx, FwSpec::new(&Compute::Native, *q, &src).on(ranks));
            (
                gather_result(ctx, ranks, *q, *n / *q, out.d_block).map(JobOutput::Mat),
                Some(out.schedule),
            )
        }
        JobSpec::Fault { msg, .. } => {
            let g = Group::new(ctx, ranks.to_vec());
            let tag = g.next_tag();
            if g.index() == 0 {
                panic!("injected fault: {msg}");
            }
            // block on a message the dead root will never send; the
            // dispatcher's scoped poison fails us promptly instead of
            // burning the 60 s deadlock oracle
            let _: u64 = ctx.recv(ranks[0], tag);
            (None, None)
        }
    }
}

/// Human label for a recorded schedule code (`"-"` until known).
fn schedule_label(code: Option<u8>) -> String {
    code.and_then(Schedule::from_code)
        .map_or_else(|| "-".to_string(), |s| s.name().to_string())
}

/// Gather every member's result block to the job root and assemble the
/// full matrix there.
fn gather_result(
    ctx: &Ctx,
    ranks: &[usize],
    q: usize,
    b: usize,
    my_block: Option<(usize, usize, Block)>,
) -> Option<Mat> {
    let g = Group::new(ctx, ranks.to_vec());
    let (i, j, blk) = my_block.expect("job member without a result block");
    g.gather(0, (i as u64, j as u64, blk.materialize())).map(|entries| {
        let mut out = Mat::zeros(q * b, q * b);
        for (bi, bj, m) in entries {
            out.set_block(bi as usize, bj as usize, &m);
        }
        out
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::plan::{collect_c, collect_d};
    use crate::testing::{spmd_run, test_threads};

    fn serving_rt(world: usize) -> Runtime {
        Runtime::builder()
            .world(world)
            .backend_profile(BackendProfile::openmpi_fixed())
            .cost(CostParams::free())
            .threads_per_rank(test_threads())
            .build()
            .expect("serving runtime config")
    }

    fn oracle_matmul(q: usize, b: usize, seed_a: u64, seed_b: u64) -> Mat {
        let res = spmd_run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let a = BlockSource::real(b, seed_a);
            let bb = BlockSource::real(b, seed_b);
            plan::matmul(ctx, MatmulSpec::new(&Compute::Native, q, &a, &bb))
        });
        collect_c(&res.results, q, b)
    }

    fn oracle_fw(q: usize, n: usize, density: f64, seed: u64) -> Mat {
        let res = spmd_run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let src = FwSource::Real { n, density, seed };
            plan::apsp(ctx, FwSpec::new(&Compute::Native, q, &src))
        });
        collect_d(&res.results, q, n / q)
    }

    #[test]
    fn serve_matmul_and_fw_match_single_job_oracles() {
        let rt = serving_rt(5);
        let ((c1, d2, c3), report) = rt
            .serve(ServeOptions::default(), |h| {
                let j1 = h.submit(JobSpec::Matmul { q: 2, b: 8, seed_a: 11, seed_b: 12 });
                let j2 =
                    h.submit(JobSpec::FloydWarshall { q: 2, n: 8, density: 0.45, seed: 7 });
                let j3 = h.submit(JobSpec::Matmul { q: 1, b: 6, seed_a: 3, seed_b: 4 });
                let c1 = h.wait(j1).expect("matmul").into_mat();
                let d2 = h.wait(j2).expect("fw").into_mat();
                let c3 = h.wait(j3).expect("small matmul").into_mat();
                (c1, d2, c3)
            })
            .expect("serve");
        // bit-identical to dedicated single-job runs (same seeds, same
        // deterministic kernels, same grid shape)
        assert_eq!(c1.data, oracle_matmul(2, 8, 11, 12).data);
        assert_eq!(d2.data, oracle_fw(2, 8, 0.45, 7).data);
        assert_eq!(c3.data, oracle_matmul(1, 6, 3, 4).data);
        assert_eq!(report.submitted, 3);
        assert_eq!(report.done, 3);
        assert_eq!(report.failed, 0);
        assert_eq!(report.latency.count(), 3);
    }

    #[test]
    fn admission_rejects_oversized_and_malformed_jobs() {
        let rt = serving_rt(2); // pool of one rank
        let ((wide, bad, ok), report) = rt
            .serve(ServeOptions::default(), |h| {
                let wide = h.submit(JobSpec::Matmul { q: 2, b: 4, seed_a: 0, seed_b: 1 });
                let bad = h.submit(JobSpec::Matmul { q: 0, b: 4, seed_a: 0, seed_b: 1 });
                let ok = h.submit(JobSpec::Matmul { q: 1, b: 4, seed_a: 5, seed_b: 6 });
                assert!(matches!(h.status(wide), Some(JobStatus::Rejected(_))));
                (h.wait(wide), h.wait(bad), h.wait(ok).map(JobOutput::into_mat))
            })
            .expect("serve");
        let wide_err = wide.expect_err("4-rank job cannot fit a 1-rank pool");
        assert!(wide_err.contains("pool has 1"), "{wide_err}");
        let bad_err = bad.expect_err("q=0 is malformed");
        assert!(bad_err.contains("q > 0"), "{bad_err}");
        assert_eq!(ok.expect("fitting job runs").data, oracle_matmul(1, 4, 5, 6).data);
        assert_eq!(report.rejected, 2);
        assert_eq!(report.done, 1);
    }

    #[test]
    fn batching_coalesces_queued_small_gemms() {
        let rt = serving_rt(2); // single pool rank forces queueing behind the blocker
        let (outs, report) = rt
            .serve(ServeOptions::default(), |h| {
                // the blocker occupies the only rank for ~milliseconds,
                // so the five small same-shape jobs all queue — the
                // planner must coalesce them into one assignment
                let blocker =
                    h.submit(JobSpec::Matmul { q: 1, b: 128, seed_a: 1, seed_b: 2 });
                let ids: Vec<u64> = (0..5)
                    .map(|k| {
                        h.submit(JobSpec::Matmul {
                            q: 1,
                            b: 8,
                            seed_a: 100 + k,
                            seed_b: 200 + k,
                        })
                    })
                    .collect();
                let _ = h.wait(blocker).expect("blocker");
                ids.iter().map(|&id| h.wait(id).expect("batched job").into_mat()).collect::<Vec<_>>()
            })
            .expect("serve");
        for (k, m) in outs.iter().enumerate() {
            let k = k as u64;
            assert_eq!(
                m.data,
                oracle_matmul(1, 8, 100 + k, 200 + k).data,
                "batched job {k} must stay bit-identical to its solo oracle"
            );
        }
        assert_eq!(report.done, 6);
        assert!(
            report.assignments < 6,
            "6 jobs in {} assignments — batching never coalesced",
            report.assignments
        );
    }

    #[test]
    fn member_death_fails_only_the_owning_job() {
        let rt = serving_rt(4); // pool of 3: fault takes 2 ranks, a live job the third
        let ((bad, good, after), report) = rt
            .serve(ServeOptions::default(), |h| {
                let bad =
                    h.submit(JobSpec::Fault { width: 2, msg: "injected-crash".into() });
                let good = h.submit(JobSpec::Matmul { q: 1, b: 8, seed_a: 1, seed_b: 2 });
                let bad_res = h.wait(bad);
                let good_res = h.wait(good).map(JobOutput::into_mat);
                // the fault's ranks must rejoin the pool and serve again
                let after = h.submit(JobSpec::Matmul { q: 1, b: 8, seed_a: 3, seed_b: 4 });
                let after_res = h.wait(after).map(JobOutput::into_mat);
                (bad_res, good_res, after_res)
            })
            .expect("serve");
        let err = bad.expect_err("fault job must fail");
        assert!(err.contains("injected-crash"), "root cause not surfaced: {err}");
        assert_eq!(
            good.expect("disjoint in-flight job must complete").data,
            oracle_matmul(1, 8, 1, 2).data
        );
        assert_eq!(
            after.expect("pool must recover after a failed job").data,
            oracle_matmul(1, 8, 3, 4).data
        );
        assert_eq!(report.failed, 1);
        assert_eq!(report.done, 2);
    }

    #[test]
    fn stats_and_queue_wait_track_the_pool() {
        let rt = serving_rt(3);
        let (json, report) = rt
            .serve(ServeOptions::default(), |h| {
                let j = h.submit(JobSpec::Matmul { q: 1, b: 8, seed_a: 9, seed_b: 10 });
                let _ = h.wait(j).expect("matmul");
                let snap = h.stats();
                assert_eq!(snap.capacity, 2);
                assert_eq!(snap.busy, 0, "drained pool must be idle");
                assert_eq!(snap.queue_depth, 0);
                assert_eq!(snap.done, 1);
                assert_eq!(snap.latency.count, 1);
                assert_eq!(snap.queue_wait.count, 1);
                let row = snap.jobs.iter().find(|r| r.id == j).expect("job in roster");
                assert_eq!(row.status, "done");
                assert!(row.queue_wait_secs >= 0.0, "assigned job has a recorded wait");
                let jr = h.job_report(j).expect("job report");
                assert_eq!(
                    row.gflops, jr.max_gflops,
                    "stats roster gflops must match job_report"
                );
                h.job_report_json(j).expect("json report")
            })
            .expect("serve");
        assert!(json.contains("\"status\":\"done\""), "{json}");
        assert!(json.contains("\"queue_wait_secs\":"), "{json}");
        assert!(json.contains("\"gflops_max\":"), "{json}");
        assert_eq!(report.queue_wait.count(), 1, "final report keeps the histogram");
    }

    #[test]
    fn serve_refuses_multiprocess_and_tiny_worlds() {
        let rt = serving_rt(1);
        let err = rt.serve(ServeOptions::default(), |_| ()).unwrap_err();
        assert!(err.to_string().contains("world >= 2"), "{err}");
    }
}
