//! The persistent serving runtime: concurrent jobs multiplexed over a
//! resident rank pool.
//!
//! Every other entry point in this crate is a *batch* SPMD run — spawn a
//! world, run one algorithm, tear everything down.  This subsystem keeps
//! the world resident: [`Runtime::serve`](crate::spmd::Runtime) parks
//! rank 0 as a **dispatcher** and every other rank as a **worker**, and
//! a job queue on the dispatcher multiplexes many concurrent matmul /
//! Floyd-Warshall requests over the pool (the object-as-server model of
//! Givelberg's *Object-Oriented Parallel Programming*, with the group
//! machinery of Hargreaves et al. providing the isolation):
//!
//! ```text
//!   client procs ──TCP──▸ listener ─┐
//!                                   ▼
//!   driver thread ──ServeHandle──▸ ServeShared (queue + job table)
//!                                   │
//!            rank 0 ── dispatcher ──┤ admission · batching · lifecycle
//!                                   │       Assign / MemberDone
//!            ranks 1..w ── workers ◀┴──▸ per-job Group partition
//! ```
//!
//! The isolation story, layer by layer:
//! * each admitted job gets a **per-job communicator**: its members run
//!   inside [`Ctx::with_tag_scope`](crate::spmd::Ctx::with_tag_scope),
//!   so every `Group` they build lives in a namespace derived from the
//!   job id — concurrent jobs on disjoint rank subsets never
//!   cross-match messages (see [`Group::partition`]);
//! * grids place themselves on the job's rank subset via
//!   [`GridN::new_on`](crate::data::grid::GridN::new_on) and the
//!   `*_on` algorithm variants;
//! * per-job metrics are **scoped** deltas
//!   ([`MetricsSnapshot::scoped`]) of each member's counters, so rates
//!   never bleed between jobs multiplexed on one rank;
//! * a member death is scoped to its job: the dying member reports, the
//!   dispatcher poisons only the job's unreported members
//!   ([`Transport::fail_ranks`](crate::comm::transport::Transport)),
//!   they unwind and report, the job is marked failed with the root
//!   cause, and the ranks rejoin the pool
//!   ([`Transport::clear_fail`](crate::comm::transport::Transport)).
//!
//! The scheduler handles **admission control** (a job whose grid cannot
//! ever fit the pool is rejected at submit; one that fits but not *now*
//! queues) and **request batching** (queued same-shape single-rank
//! GEMMs coalesce into one [`JobSpec::MatmulBatch`] assignment — one
//! admission/assignment/report round-trip for the whole flood).
//!
//! [`Group::partition`]: crate::comm::group::Group::partition
//! [`MetricsSnapshot::scoped`]: crate::metrics::MetricsSnapshot::scoped

use crate::comm::wire::{WireData, WireError, WireReader};
use crate::data::value::Data;
use crate::matrix::dense::Mat;
use crate::metrics::MetricsSnapshot;

pub mod client;
pub mod scheduler;
pub mod server;
pub mod stats;

pub use client::ServeClient;
pub use server::{ServeHandle, ServeOptions, ServeReport};
pub use stats::{JobStat, QuantileSummary, StatsSnapshot};

/// What a submitter asks the pool to run.
#[derive(Clone, Debug, PartialEq)]
pub enum JobSpec {
    /// Cannon's algorithm on a q×q subgrid: `C = A·B` with n = q·b,
    /// blocks generated from the seeds (deterministic, so any oracle
    /// re-run is bit-identical).
    Matmul { q: usize, b: usize, seed_a: u64, seed_b: u64 },
    /// A coalesced flood of same-shape multiplies: one assignment runs
    /// every `(seed_a, seed_b)` pair back-to-back on one subgrid.
    /// Usually produced by the batcher, but submittable directly.
    MatmulBatch { q: usize, b: usize, pairs: Vec<(u64, u64)> },
    /// Parallel Floyd-Warshall (Alg. 3) on a q×q subgrid over the
    /// deterministic random graph `(n, density, seed)`.
    FloydWarshall { q: usize, n: usize, density: f64, seed: u64 },
    /// Failure injection for tests: member 0 of the job panics, the
    /// remaining `width − 1` members block on a message it will never
    /// send — exercising the dispatcher's scoped poison path end to end.
    Fault { width: usize, msg: String },
}

impl JobSpec {
    /// Ranks a job's grid occupies (0 = malformed, rejected at submit).
    pub fn ranks_needed(&self) -> usize {
        match self {
            JobSpec::Matmul { q, .. } | JobSpec::MatmulBatch { q, .. } => q * q,
            JobSpec::FloydWarshall { q, .. } => q * q,
            JobSpec::Fault { width, .. } => *width,
        }
    }

    /// Short kind label for logs and reports.
    pub fn kind(&self) -> &'static str {
        match self {
            JobSpec::Matmul { .. } => "matmul",
            JobSpec::MatmulBatch { .. } => "matmul-batch",
            JobSpec::FloydWarshall { .. } => "fw",
            JobSpec::Fault { .. } => "fault",
        }
    }

    /// Submit-time validation: `Some(reason)` when malformed.
    pub fn invalid_reason(&self) -> Option<String> {
        match self {
            JobSpec::Matmul { q, b, .. } if *q == 0 || *b == 0 => {
                Some("matmul needs q > 0 and b > 0".into())
            }
            JobSpec::MatmulBatch { q, b, pairs } if *q == 0 || *b == 0 || pairs.is_empty() => {
                Some("matmul batch needs q > 0, b > 0, and at least one pair".into())
            }
            JobSpec::FloydWarshall { q, n, density, .. }
                if *q == 0 || *n == 0 || *n % *q != 0 || !(0.0..=1.0).contains(density) =>
            {
                Some("fw needs q > 0, n divisible by q, density in [0, 1]".into())
            }
            JobSpec::Fault { width, .. } if *width == 0 => Some("fault needs width > 0".into()),
            _ => None,
        }
    }
}

impl Data for JobSpec {
    fn byte_size(&self) -> usize {
        1 + match self {
            JobSpec::Matmul { .. } => 32,
            JobSpec::MatmulBatch { pairs, .. } => 16 + 8 + 16 * pairs.len(),
            JobSpec::FloydWarshall { .. } => 32,
            JobSpec::Fault { msg, .. } => 8 + 8 + msg.len(),
        }
    }
}

impl WireData for JobSpec {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            JobSpec::Matmul { q, b, seed_a, seed_b } => {
                out.push(0);
                q.encode(out);
                b.encode(out);
                seed_a.encode(out);
                seed_b.encode(out);
            }
            JobSpec::MatmulBatch { q, b, pairs } => {
                out.push(1);
                q.encode(out);
                b.encode(out);
                pairs.encode(out);
            }
            JobSpec::FloydWarshall { q, n, density, seed } => {
                out.push(2);
                q.encode(out);
                n.encode(out);
                density.encode(out);
                seed.encode(out);
            }
            JobSpec::Fault { width, msg } => {
                out.push(3);
                width.encode(out);
                msg.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => JobSpec::Matmul {
                q: r.len()?,
                b: r.len()?,
                seed_a: r.u64()?,
                seed_b: r.u64()?,
            },
            1 => JobSpec::MatmulBatch {
                q: r.len()?,
                b: r.len()?,
                pairs: Vec::decode(r)?,
            },
            2 => JobSpec::FloydWarshall {
                q: r.len()?,
                n: r.len()?,
                density: f64::decode(r)?,
                seed: r.u64()?,
            },
            3 => JobSpec::Fault { width: r.len()?, msg: String::decode(r)? },
            _ => return Err(WireError::Malformed("unknown JobSpec tag")),
        })
    }
}

/// What a completed job hands back to its submitter.
#[derive(Clone, Debug, PartialEq)]
pub enum JobOutput {
    /// The assembled result matrix (matmul C, Floyd-Warshall D).
    Mat(Mat),
    /// One matrix per pair of a [`JobSpec::MatmulBatch`].
    Mats(Vec<Mat>),
}

impl JobOutput {
    /// The single matrix of a non-batch job (panics on a batch output).
    pub fn into_mat(self) -> Mat {
        match self {
            JobOutput::Mat(m) => m,
            JobOutput::Mats(_) => panic!("batch output where a single matrix was expected"),
        }
    }
}

impl Data for JobOutput {
    fn byte_size(&self) -> usize {
        1 + match self {
            JobOutput::Mat(m) => m.byte_size(),
            JobOutput::Mats(v) => v.byte_size(),
        }
    }
}

impl WireData for JobOutput {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            JobOutput::Mat(m) => {
                out.push(0);
                m.encode(out);
            }
            JobOutput::Mats(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => JobOutput::Mat(Mat::decode(r)?),
            1 => JobOutput::Mats(Vec::decode(r)?),
            _ => return Err(WireError::Malformed("unknown JobOutput tag")),
        })
    }
}

/// Lifecycle of a submitted job: submit → (rejected | queued) → running
/// → (done | failed).
#[derive(Clone, Debug, PartialEq)]
pub enum JobStatus {
    /// Admitted, waiting for a subgrid to free up.
    Queued,
    /// Assigned to a rank subset and executing.
    Running,
    /// Completed; the output is (or was) available via `wait`.
    Done,
    /// A member died; the root cause is surfaced to the submitter.
    Failed(String),
    /// Refused at submit (malformed, or can never fit the pool).
    Rejected(String),
}

impl JobStatus {
    /// Terminal states release no further transitions.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobStatus::Done | JobStatus::Failed(_) | JobStatus::Rejected(_))
    }

    /// Short label for stats rosters and JSON (drops failure reasons —
    /// `status`/`wait` carry the full variant).
    pub fn label(&self) -> &'static str {
        match self {
            JobStatus::Queued => "queued",
            JobStatus::Running => "running",
            JobStatus::Done => "done",
            JobStatus::Failed(_) => "failed",
            JobStatus::Rejected(_) => "rejected",
        }
    }
}

impl Data for JobStatus {
    fn byte_size(&self) -> usize {
        1 + match self {
            JobStatus::Failed(m) | JobStatus::Rejected(m) => 8 + m.len(),
            _ => 0,
        }
    }
}

impl WireData for JobStatus {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            JobStatus::Queued => out.push(0),
            JobStatus::Running => out.push(1),
            JobStatus::Done => out.push(2),
            JobStatus::Failed(m) => {
                out.push(3);
                m.encode(out);
            }
            JobStatus::Rejected(m) => {
                out.push(4);
                m.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => JobStatus::Queued,
            1 => JobStatus::Running,
            2 => JobStatus::Done,
            3 => JobStatus::Failed(String::decode(r)?),
            4 => JobStatus::Rejected(String::decode(r)?),
            _ => return Err(WireError::Malformed("unknown JobStatus tag")),
        })
    }
}

// ------------------------------------------------- control-plane wire

/// Dispatcher → worker control tag (assignments and shutdown).
/// `u64::MAX` itself is the runtime's clock-gather tag; the serving
/// control plane sits just below it.  Job traffic can never collide:
/// its tags come from splitmix64-derived group namespaces.
pub(crate) const CONTROL_TAG: u64 = u64::MAX - 1;

/// Worker → dispatcher completion-report tag.
pub(crate) const DONE_TAG: u64 = u64::MAX - 2;

/// Dispatcher → worker control messages.
#[derive(Clone, Debug)]
pub(crate) enum Control {
    /// Run `spec` for the job ids `jobs` (one id, or a batched flood)
    /// on the subset `ranks` (grid placement order), inside tag scope
    /// `scope`.  `assign` keys the matching [`MemberDone`]s.
    Assign {
        assign: u64,
        jobs: Vec<u64>,
        spec: JobSpec,
        ranks: Vec<usize>,
        scope: u64,
    },
    /// Drain and exit the worker loop.
    Shutdown,
}

impl Data for Control {
    fn byte_size(&self) -> usize {
        1 + match self {
            Control::Assign { jobs, spec, ranks, .. } => {
                16 + jobs.byte_size() + spec.byte_size() + ranks.byte_size()
            }
            Control::Shutdown => 0,
        }
    }
}

impl WireData for Control {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Control::Assign { assign, jobs, spec, ranks, scope } => {
                out.push(0);
                assign.encode(out);
                jobs.encode(out);
                spec.encode(out);
                ranks.encode(out);
                scope.encode(out);
            }
            Control::Shutdown => out.push(1),
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(match r.u8()? {
            0 => Control::Assign {
                assign: r.u64()?,
                jobs: Vec::decode(r)?,
                spec: JobSpec::decode(r)?,
                ranks: Vec::decode(r)?,
                scope: r.u64()?,
            },
            1 => Control::Shutdown,
            _ => return Err(WireError::Malformed("unknown Control tag")),
        })
    }
}

/// One member's end-of-assignment report.
#[derive(Clone, Debug)]
pub(crate) struct MemberDone {
    pub assign: u64,
    pub ok: bool,
    /// Root cause when `!ok` (panic message, incl. scoped-poison text).
    pub err: Option<String>,
    /// The job output — present only on the job root (`ranks[0]`) of a
    /// successful assignment.
    pub output: Option<JobOutput>,
    /// This member's **scoped** counters for the assignment.
    pub metrics: MetricsSnapshot,
    /// The planner's chosen schedule for the job
    /// ([`Schedule::code`](crate::plan::Schedule::code)); `None` for
    /// assignments that don't go through the planner (faults).
    pub schedule: Option<u8>,
}

impl Data for MemberDone {
    fn byte_size(&self) -> usize {
        8 + 1
            + self.err.as_ref().map_or(1, |e| 9 + e.len())
            + self.output.as_ref().map_or(1, |o| 1 + o.byte_size())
            + 88
            + 40 // profile tag (kc/mc/nc/mr/nr as u64)
            + 9 // schedule (Option<u64>)
    }
}

impl WireData for MemberDone {
    fn encode(&self, out: &mut Vec<u8>) {
        self.assign.encode(out);
        self.ok.encode(out);
        self.err.encode(out);
        self.output.encode(out);
        let m = &self.metrics;
        m.msgs_sent.encode(out);
        m.bytes_sent.encode(out);
        m.msgs_recv.encode(out);
        m.bytes_recv.encode(out);
        m.flops.encode(out);
        m.comm_time.encode(out);
        m.compute_time.encode(out);
        m.collectives.encode(out);
        m.ew_flops.encode(out);
        m.ew_time.encode(out);
        m.overlap_hidden.encode(out);
        (m.profile.kc as u64).encode(out);
        (m.profile.mc as u64).encode(out);
        (m.profile.nc as u64).encode(out);
        (m.profile.mr as u64).encode(out);
        (m.profile.nr as u64).encode(out);
        self.schedule.map(u64::from).encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(MemberDone {
            assign: r.u64()?,
            ok: bool::decode(r)?,
            err: Option::decode(r)?,
            output: Option::decode(r)?,
            metrics: MetricsSnapshot {
                msgs_sent: r.u64()?,
                bytes_sent: r.u64()?,
                msgs_recv: r.u64()?,
                bytes_recv: r.u64()?,
                flops: f64::decode(r)?,
                comm_time: f64::decode(r)?,
                compute_time: f64::decode(r)?,
                collectives: r.u64()?,
                ew_flops: f64::decode(r)?,
                ew_time: f64::decode(r)?,
                overlap_hidden: f64::decode(r)?,
                profile: crate::metrics::ProfileTag {
                    kc: r.u64()? as u32,
                    mc: r.u64()? as u32,
                    nc: r.u64()? as u32,
                    mr: r.u64()? as u8,
                    nr: r.u64()? as u8,
                },
            },
            schedule: Option::<u64>::decode(r)?.map(|c| c as u8),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: WireData + PartialEq + std::fmt::Debug>(v: &T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        let back = T::decode(&mut r).expect("decode");
        assert_eq!(&back, v);
        assert_eq!(r.remaining(), 0, "trailing bytes");
    }

    #[test]
    fn job_spec_wire_roundtrip() {
        roundtrip(&JobSpec::Matmul { q: 2, b: 16, seed_a: 7, seed_b: 8 });
        roundtrip(&JobSpec::MatmulBatch { q: 1, b: 32, pairs: vec![(1, 2), (3, 4)] });
        roundtrip(&JobSpec::FloydWarshall { q: 2, n: 8, density: 0.4, seed: 5 });
        roundtrip(&JobSpec::Fault { width: 2, msg: "boom".into() });
    }

    #[test]
    fn job_status_wire_roundtrip() {
        roundtrip(&JobStatus::Queued);
        roundtrip(&JobStatus::Running);
        roundtrip(&JobStatus::Done);
        roundtrip(&JobStatus::Failed("rank 3 died".into()));
        roundtrip(&JobStatus::Rejected("too wide".into()));
    }

    #[test]
    fn job_output_wire_roundtrip() {
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        roundtrip(&JobOutput::Mat(m.clone()));
        roundtrip(&JobOutput::Mats(vec![m.clone(), m]));
    }

    #[test]
    fn ranks_needed_and_validation() {
        assert_eq!(
            JobSpec::Matmul { q: 3, b: 4, seed_a: 0, seed_b: 0 }.ranks_needed(),
            9
        );
        assert_eq!(JobSpec::Fault { width: 2, msg: String::new() }.ranks_needed(), 2);
        assert!(JobSpec::Matmul { q: 0, b: 4, seed_a: 0, seed_b: 0 }
            .invalid_reason()
            .is_some());
        assert!(JobSpec::FloydWarshall { q: 3, n: 8, density: 0.5, seed: 1 }
            .invalid_reason()
            .is_some());
        assert!(JobSpec::MatmulBatch { q: 1, b: 8, pairs: vec![] }
            .invalid_reason()
            .is_some());
        assert!(JobSpec::FloydWarshall { q: 2, n: 8, density: 0.5, seed: 1 }
            .invalid_reason()
            .is_none());
    }

    #[test]
    fn member_done_wire_roundtrip() {
        let d = MemberDone {
            assign: 42,
            ok: false,
            err: Some("injected".into()),
            output: Some(JobOutput::Mat(Mat::from_vec(1, 2, vec![5.0, 6.0]))),
            metrics: MetricsSnapshot {
                msgs_sent: 3,
                bytes_sent: 100,
                flops: 1e6,
                ..Default::default()
            },
            schedule: Some(crate::plan::Schedule::CannonBlocking.code()),
        };
        let mut buf = Vec::new();
        d.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        let back = MemberDone::decode(&mut r).unwrap();
        assert_eq!(back.assign, 42);
        assert!(!back.ok);
        assert_eq!(back.err.as_deref(), Some("injected"));
        assert_eq!(back.metrics.msgs_sent, 3);
        assert_eq!(back.metrics.flops, 1e6);
        assert!(matches!(back.output, Some(JobOutput::Mat(_))));
        assert_eq!(back.schedule, Some(crate::plan::Schedule::CannonBlocking.code()));
    }
}
