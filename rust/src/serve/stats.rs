//! Live serving-plane statistics: the payload behind `Request::Stats`
//! and the `repro stats` CLI — the first window into a resident pool
//! mid-flight.
//!
//! A [`StatsSnapshot`] is assembled under the job-table lock by
//! [`ServeHandle::stats`](super::ServeHandle::stats): pool occupancy
//! (published by the dispatcher on every take/release), queue depth,
//! the serving-plane counters, quantile summaries of the latency and
//! dispatcher-side **queue-wait** (submit → assign) histograms, and a
//! per-job roster with each job's *scoped* GFlop/s — the same figure
//! [`ServeHandle::job_report`](super::ServeHandle::job_report) quotes,
//! so an external `repro stats` can be asserted against the in-process
//! report.  The snapshot crosses the client TCP protocol with the same
//! wire codec every other frame uses.

use crate::comm::wire::{WireData, WireError, WireReader};
use crate::data::value::Data;
use crate::metrics::{render_table, Histogram, JsonWriter};

/// Count/mean/p50/p99 digest of a [`Histogram`] — what quantile state
/// crosses the wire (the full bucket vector stays server-side).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantileSummary {
    pub count: u64,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
}

impl QuantileSummary {
    pub fn of(h: &Histogram) -> Self {
        QuantileSummary {
            count: h.count(),
            mean_secs: h.mean(),
            p50_secs: h.p50(),
            p99_secs: h.p99(),
        }
    }

    fn render(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p99={:.3}ms",
            self.count,
            self.mean_secs * 1e3,
            self.p50_secs * 1e3,
            self.p99_secs * 1e3,
        )
    }

    fn write_json(&self, w: &mut JsonWriter) {
        w.begin_obj();
        w.key("count").uint(self.count);
        w.key("mean_secs").num(self.mean_secs);
        w.key("p50_secs").num(self.p50_secs);
        w.key("p99_secs").num(self.p99_secs);
        w.end_obj();
    }
}

impl Data for QuantileSummary {
    fn byte_size(&self) -> usize {
        32
    }
}

impl WireData for QuantileSummary {
    fn encode(&self, out: &mut Vec<u8>) {
        self.count.encode(out);
        self.mean_secs.encode(out);
        self.p50_secs.encode(out);
        self.p99_secs.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(QuantileSummary {
            count: r.u64()?,
            mean_secs: f64::decode(r)?,
            p50_secs: f64::decode(r)?,
            p99_secs: f64::decode(r)?,
        })
    }
}

/// One job's row in the live roster.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct JobStat {
    pub id: u64,
    /// [`JobSpec::kind`](super::JobSpec::kind) label.
    pub kind: String,
    /// [`JobStatus::label`](super::JobStatus::label).
    pub status: String,
    /// Best member rate over the job's **scoped** metrics deltas —
    /// identical to `job_report(id).max_gflops`.
    pub gflops: f64,
    /// Dispatcher-side submit → assign wait; negative while the job is
    /// still queued (or was rejected — it never gets assigned).
    pub queue_wait_secs: f64,
    /// The planner's chosen schedule name ("-" until the job finishes,
    /// or when the job kind bypasses the planner).
    pub schedule: String,
}

impl Data for JobStat {
    fn byte_size(&self) -> usize {
        8 + (8 + self.kind.len()) + (8 + self.status.len()) + 16 + (8 + self.schedule.len())
    }
}

impl WireData for JobStat {
    fn encode(&self, out: &mut Vec<u8>) {
        self.id.encode(out);
        self.kind.encode(out);
        self.status.encode(out);
        self.gflops.encode(out);
        self.queue_wait_secs.encode(out);
        self.schedule.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(JobStat {
            id: r.u64()?,
            kind: String::decode(r)?,
            status: String::decode(r)?,
            gflops: f64::decode(r)?,
            queue_wait_secs: f64::decode(r)?,
            schedule: String::decode(r)?,
        })
    }
}

/// A point-in-time view of the resident pool.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsSnapshot {
    /// Pool capacity in ranks (world minus the dispatcher).
    pub capacity: u64,
    /// Ranks currently occupied by assignments.
    pub busy: u64,
    /// Jobs admitted but not yet assigned.
    pub queue_depth: u64,
    pub submitted: u64,
    pub done: u64,
    pub failed: u64,
    pub rejected: u64,
    pub assignments: u64,
    /// Submit → terminal wall latency over finished jobs.
    pub latency: QuantileSummary,
    /// Submit → assign wall wait over assigned jobs (admission stalls
    /// that plain latency hides).
    pub queue_wait: QuantileSummary,
    /// Every job the table knows, ascending id.
    pub jobs: Vec<JobStat>,
}

impl StatsSnapshot {
    /// Pool occupancy in [0, 1] (0 for an empty pool).
    pub fn occupancy(&self) -> f64 {
        if self.capacity > 0 {
            self.busy as f64 / self.capacity as f64
        } else {
            0.0
        }
    }

    /// Human-readable multi-line rendering (the `repro stats` default).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "pool: {}/{} ranks busy ({:.0}%), queue depth {}\n",
            self.busy,
            self.capacity,
            self.occupancy() * 100.0,
            self.queue_depth,
        ));
        out.push_str(&format!(
            "jobs: submitted={} done={} failed={} rejected={} assignments={}\n",
            self.submitted, self.done, self.failed, self.rejected, self.assignments,
        ));
        out.push_str(&format!("latency:    {}\n", self.latency.render()));
        out.push_str(&format!("queue-wait: {}\n", self.queue_wait.render()));
        if !self.jobs.is_empty() {
            let rows: Vec<Vec<String>> = self
                .jobs
                .iter()
                .map(|j| {
                    vec![
                        j.id.to_string(),
                        j.kind.clone(),
                        j.status.clone(),
                        j.schedule.clone(),
                        format!("{:.2}", j.gflops),
                        if j.queue_wait_secs < 0.0 {
                            "-".into()
                        } else {
                            format!("{:.3}", j.queue_wait_secs * 1e3)
                        },
                    ]
                })
                .collect();
            out.push_str(&render_table(
                &["job", "kind", "status", "schedule", "gflops", "wait_ms"],
                &rows,
            ));
        }
        out
    }

    /// Machine-readable rendering (the `repro stats --json` form).
    pub fn to_json(&self) -> String {
        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("capacity").uint(self.capacity);
        w.key("busy").uint(self.busy);
        w.key("occupancy").num(self.occupancy());
        w.key("queue_depth").uint(self.queue_depth);
        w.key("submitted").uint(self.submitted);
        w.key("done").uint(self.done);
        w.key("failed").uint(self.failed);
        w.key("rejected").uint(self.rejected);
        w.key("assignments").uint(self.assignments);
        w.key("latency");
        self.latency.write_json(&mut w);
        w.key("queue_wait");
        self.queue_wait.write_json(&mut w);
        w.key("jobs").begin_arr();
        for j in &self.jobs {
            w.begin_obj();
            w.key("id").uint(j.id);
            w.key("kind").str_val(&j.kind);
            w.key("status").str_val(&j.status);
            w.key("schedule").str_val(&j.schedule);
            w.key("gflops").num(j.gflops);
            if j.queue_wait_secs < 0.0 {
                w.key("queue_wait_secs").num(f64::NAN); // → null
            } else {
                w.key("queue_wait_secs").num(j.queue_wait_secs);
            }
            w.end_obj();
        }
        w.end_arr();
        w.end_obj();
        w.finish()
    }
}

impl Data for StatsSnapshot {
    fn byte_size(&self) -> usize {
        8 * 8
            + self.latency.byte_size()
            + self.queue_wait.byte_size()
            + 8
            + self.jobs.iter().map(Data::byte_size).sum::<usize>()
    }
}

impl WireData for StatsSnapshot {
    fn encode(&self, out: &mut Vec<u8>) {
        self.capacity.encode(out);
        self.busy.encode(out);
        self.queue_depth.encode(out);
        self.submitted.encode(out);
        self.done.encode(out);
        self.failed.encode(out);
        self.rejected.encode(out);
        self.assignments.encode(out);
        self.latency.encode(out);
        self.queue_wait.encode(out);
        self.jobs.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(StatsSnapshot {
            capacity: r.u64()?,
            busy: r.u64()?,
            queue_depth: r.u64()?,
            submitted: r.u64()?,
            done: r.u64()?,
            failed: r.u64()?,
            rejected: r.u64()?,
            assignments: r.u64()?,
            latency: QuantileSummary::decode(r)?,
            queue_wait: QuantileSummary::decode(r)?,
            jobs: Vec::decode(r)?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatsSnapshot {
        let mut lat = Histogram::new();
        lat.record(0.010);
        lat.record(0.020);
        let mut qw = Histogram::new();
        qw.record(0.001);
        StatsSnapshot {
            capacity: 4,
            busy: 3,
            queue_depth: 2,
            submitted: 9,
            done: 6,
            failed: 1,
            rejected: 0,
            assignments: 5,
            latency: QuantileSummary::of(&lat),
            queue_wait: QuantileSummary::of(&qw),
            jobs: vec![
                JobStat {
                    id: 1,
                    kind: "matmul".into(),
                    status: "done".into(),
                    gflops: 2.5,
                    queue_wait_secs: 0.001,
                    schedule: "cannon".into(),
                },
                JobStat {
                    id: 2,
                    kind: "fw".into(),
                    status: "queued".into(),
                    gflops: 0.0,
                    queue_wait_secs: -1.0,
                    schedule: "-".into(),
                },
            ],
        }
    }

    #[test]
    fn stats_wire_roundtrip() {
        let s = sample();
        let mut buf = Vec::new();
        s.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        let back = StatsSnapshot::decode(&mut r).expect("decode");
        assert_eq!(back, s);
        assert_eq!(r.remaining(), 0, "trailing bytes");
    }

    #[test]
    fn quantile_summary_digests_histogram() {
        let mut h = Histogram::new();
        h.record(0.005);
        let q = QuantileSummary::of(&h);
        assert_eq!(q.count, 1);
        assert_eq!(q.p50_secs, 0.005, "single sample is its own quantile");
        assert_eq!(q.p99_secs, 0.005);
        assert!((q.mean_secs - 0.005).abs() < 1e-12);
    }

    #[test]
    fn occupancy_is_busy_over_capacity() {
        let s = sample();
        assert!((s.occupancy() - 0.75).abs() < 1e-12);
        let empty = StatsSnapshot::default();
        assert_eq!(empty.occupancy(), 0.0, "0-capacity pool must not NaN");
    }

    #[test]
    fn render_and_json_carry_the_counters() {
        let s = sample();
        let text = s.render();
        assert!(text.contains("3/4 ranks busy"), "{text}");
        assert!(text.contains("queue depth 2"), "{text}");
        assert!(text.contains("matmul"), "{text}");
        let json = s.to_json();
        assert!(json.contains("\"busy\":3"), "{json}");
        assert!(json.contains("\"queue_depth\":2"), "{json}");
        assert!(json.contains("\"occupancy\":0.75"), "{json}");
        // an unassigned job's queue wait serializes as null, not -1
        assert!(json.contains("\"queue_wait_secs\":null"), "{json}");
        assert!(!json.contains("-1"), "{json}");
    }
}
