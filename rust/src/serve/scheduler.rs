//! Admission control and request batching — the pure planning half of
//! the dispatcher, kept free of transports and locks so it unit-tests
//! in isolation.
//!
//! Policies:
//! * **first fit over FIFO order** — the oldest queued job whose grid
//!   fits the currently free ranks wins; a wide job at the head does
//!   not block narrower jobs behind it (and conversely keeps its queue
//!   position, so it runs as soon as enough ranks drain);
//! * **batching** — when the winner is a single-rank GEMM
//!   (`Matmul { q: 1, .. }`), every other queued single-rank GEMM with
//!   the same block edge coalesces into one
//!   [`JobSpec::MatmulBatch`](super::JobSpec::MatmulBatch) assignment,
//!   up to `max_batch` jobs.  A flood of small multiplies then costs
//!   one admission / assignment / report round-trip instead of one
//!   per job — the serving-throughput bench measures exactly this.

use std::collections::VecDeque;

use super::JobSpec;

/// One planned assignment: run `spec` for these job ids on `need` ranks.
#[derive(Debug, Clone, PartialEq)]
pub struct Admission {
    /// Job ids covered, in queue order (len > 1 only for a batch; the
    /// k-th output matrix belongs to the k-th id).
    pub jobs: Vec<u64>,
    /// What the members actually run (a coalesced batch spec when
    /// batching kicked in, otherwise the job's own spec).
    pub spec: JobSpec,
    /// Ranks the assignment occupies.
    pub need: usize,
}

/// The resident rank pool: rank 0 is the dispatcher and is never
/// handed out; ranks `1..world` serve jobs.
pub struct Pool {
    free: Vec<bool>,
}

impl Pool {
    pub fn new(world: usize) -> Self {
        assert!(world >= 2, "serving needs a dispatcher plus at least one pool rank");
        let mut free = vec![true; world];
        free[0] = false;
        Pool { free }
    }

    /// Pool capacity (world minus the dispatcher).
    pub fn capacity(&self) -> usize {
        self.free.len() - 1
    }

    /// Currently free ranks.
    pub fn available(&self) -> usize {
        self.free.iter().filter(|&&f| f).count()
    }

    /// Claim the `n` lowest-numbered free ranks, or `None` if fewer
    /// than `n` are free.
    pub fn take(&mut self, n: usize) -> Option<Vec<usize>> {
        if n == 0 || self.available() < n {
            return None;
        }
        let mut out = Vec::with_capacity(n);
        for (r, f) in self.free.iter_mut().enumerate() {
            if *f {
                *f = false;
                out.push(r);
                if out.len() == n {
                    break;
                }
            }
        }
        Some(out)
    }

    /// Return an assignment's ranks to the pool.
    pub fn release(&mut self, ranks: &[usize]) {
        for &r in ranks {
            debug_assert!(r != 0 && !self.free[r], "releasing rank {r} that was not taken");
            self.free[r] = true;
        }
    }
}

/// Plan the next assignment from the queue, or `None` when nothing
/// fits `avail` free ranks.  On `Some`, the planned ids have been
/// removed from `queue`; the caller owns marking them running and
/// claiming ranks from the pool.
///
/// `queue` pairs each queued id with its spec, FIFO order.
pub fn plan_next(
    queue: &mut VecDeque<(u64, JobSpec)>,
    avail: usize,
    batching: bool,
    max_batch: usize,
) -> Option<Admission> {
    let pos = queue
        .iter()
        .position(|(_, spec)| spec.ranks_needed() <= avail)?;
    let (id, spec) = queue.remove(pos).expect("position came from this queue");

    // Coalesce a single-rank GEMM with every same-shape sibling still
    // queued (they all need exactly the one rank the winner claimed).
    if batching && max_batch > 1 {
        if let JobSpec::Matmul { q: 1, b, seed_a, seed_b } = spec {
            let mut jobs = vec![id];
            let mut pairs = vec![(seed_a, seed_b)];
            while jobs.len() < max_batch {
                let sib = queue.iter().position(
                    |(_, s)| matches!(s, JobSpec::Matmul { q: 1, b: sb, .. } if *sb == b),
                );
                let Some(sib) = sib else { break };
                let (sid, sspec) = queue.remove(sib).expect("position came from this queue");
                let JobSpec::Matmul { seed_a, seed_b, .. } = sspec else { unreachable!() };
                jobs.push(sid);
                pairs.push((seed_a, seed_b));
            }
            if jobs.len() > 1 {
                return Some(Admission {
                    jobs,
                    spec: JobSpec::MatmulBatch { q: 1, b, pairs },
                    need: 1,
                });
            }
            return Some(Admission {
                jobs,
                spec: JobSpec::Matmul { q: 1, b, seed_a, seed_b },
                need: 1,
            });
        }
    }

    let need = spec.ranks_needed();
    Some(Admission { jobs: vec![id], spec, need })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(q: usize, b: usize, s: u64) -> JobSpec {
        JobSpec::Matmul { q, b, seed_a: s, seed_b: s + 1 }
    }

    #[test]
    fn pool_take_release_roundtrip() {
        let mut p = Pool::new(6);
        assert_eq!(p.capacity(), 5);
        assert_eq!(p.available(), 5);
        let a = p.take(4).unwrap();
        assert_eq!(a, vec![1, 2, 3, 4]);
        assert_eq!(p.available(), 1);
        assert!(p.take(2).is_none(), "only one rank left");
        let b = p.take(1).unwrap();
        assert_eq!(b, vec![5]);
        p.release(&a);
        assert_eq!(p.available(), 4);
        let c = p.take(2).unwrap();
        assert_eq!(c, vec![1, 2], "lowest free ranks first");
    }

    #[test]
    fn first_fit_skips_blocked_head() {
        // a 2x2 job heads the queue but only 2 ranks are free: the
        // narrow jobs behind it run, the wide one keeps its position
        let mut q: VecDeque<(u64, JobSpec)> =
            [(1, mm(2, 8, 0)), (2, mm(1, 8, 10)), (3, mm(2, 8, 20))]
                .into_iter()
                .collect();
        let adm = plan_next(&mut q, 2, false, 8).expect("job 2 fits");
        assert_eq!(adm.jobs, vec![2]);
        assert_eq!(adm.need, 1);
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].0, 1, "wide job keeps queue priority");
        // once 4 ranks free up, the wide head runs first
        let adm = plan_next(&mut q, 4, false, 8).expect("job 1 fits now");
        assert_eq!(adm.jobs, vec![1]);
        assert_eq!(adm.need, 4);
    }

    #[test]
    fn nothing_fits_returns_none_and_keeps_queue() {
        let mut q: VecDeque<(u64, JobSpec)> = [(1, mm(2, 8, 0))].into_iter().collect();
        assert!(plan_next(&mut q, 3, true, 8).is_none());
        assert_eq!(q.len(), 1, "unplanned jobs stay queued");
    }

    #[test]
    fn batching_coalesces_same_shape_gemms() {
        let mut q: VecDeque<(u64, JobSpec)> = [
            (1, mm(1, 16, 0)),
            (2, mm(2, 16, 10)), // different shape: left alone
            (3, mm(1, 16, 20)),
            (4, mm(1, 8, 30)), // different block edge: left alone
            (5, mm(1, 16, 40)),
        ]
        .into_iter()
        .collect();
        let adm = plan_next(&mut q, 1, true, 8).expect("singles fit one rank");
        assert_eq!(adm.jobs, vec![1, 3, 5], "same-shape singles coalesce in FIFO order");
        assert_eq!(adm.need, 1);
        match &adm.spec {
            JobSpec::MatmulBatch { q: 1, b: 16, pairs } => {
                assert_eq!(pairs, &vec![(0, 1), (20, 21), (40, 41)]);
            }
            other => panic!("expected a coalesced batch, got {other:?}"),
        }
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].0, 2);
        assert_eq!(q[1].0, 4);
    }

    #[test]
    fn batching_respects_max_batch() {
        let mut q: VecDeque<(u64, JobSpec)> =
            (0..5).map(|i| (i, mm(1, 16, 10 * i))).collect();
        let adm = plan_next(&mut q, 3, true, 2).unwrap();
        assert_eq!(adm.jobs.len(), 2, "capped at max_batch");
        assert_eq!(q.len(), 3, "overflow stays queued");
    }

    #[test]
    fn batching_disabled_takes_one_at_a_time() {
        let mut q: VecDeque<(u64, JobSpec)> =
            [(1, mm(1, 16, 0)), (2, mm(1, 16, 10))].into_iter().collect();
        let adm = plan_next(&mut q, 4, false, 8).unwrap();
        assert_eq!(adm.jobs, vec![1]);
        assert!(matches!(adm.spec, JobSpec::Matmul { .. }));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn lone_single_gemm_stays_unbatched_spec() {
        let mut q: VecDeque<(u64, JobSpec)> = [(7, mm(1, 16, 0))].into_iter().collect();
        let adm = plan_next(&mut q, 1, true, 8).unwrap();
        assert_eq!(adm.jobs, vec![7]);
        assert!(
            matches!(adm.spec, JobSpec::Matmul { .. }),
            "no siblings → no batch wrapper"
        );
    }
}
