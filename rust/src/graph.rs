//! Weighted digraphs and the sequential Floyd-Warshall reference (§5).
//!
//! The distance matrix representation is a dense [`Mat`] with
//! [`crate::matrix::gemm::INF`] marking "no edge" — the same convention
//! as the L1 kernels (python/compile/kernels/minplus.py).

use crate::matrix::dense::Mat;
use crate::matrix::gemm::INF;
use crate::testing::Rng;

/// A weighted digraph as a dense distance/adjacency matrix.
/// `w[(i,j)]` is the edge weight i→j, `INF` if absent, 0 on the diagonal.
#[derive(Clone, Debug, PartialEq)]
pub struct Graph {
    pub w: Mat,
}

impl Graph {
    pub fn n(&self) -> usize {
        self.w.rows
    }

    /// Random digraph: each off-diagonal edge present with probability
    /// `density`, weight uniform in [1, 10).  Deterministic per seed.
    pub fn random(n: usize, density: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut w = Mat::filled(n, n, INF);
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    w[(i, j)] = 0.0;
                } else if rng.gen_bool(density) {
                    w[(i, j)] = rng.gen_f32_range(1.0, 10.0);
                }
            }
        }
        Graph { w }
    }

    /// Build from an explicit weight matrix (diagonal forced to 0).
    pub fn from_weights(mut w: Mat) -> Self {
        assert_eq!(w.rows, w.cols);
        for i in 0..w.rows {
            w[(i, i)] = 0.0;
        }
        Graph { w }
    }
}

/// Sequential Floyd-Warshall: all-pairs shortest paths in Θ(n³).
/// This is the `T_S` reference of §5 and the correctness oracle for the
/// parallel version.
pub fn floyd_warshall_seq(g: &Graph) -> Mat {
    let n = g.n();
    let mut d = g.w.clone();
    for k in 0..n {
        // Hoist row k (it is invariant within the k-th sweep).
        let rowk: Vec<f32> = d.row(k).to_vec();
        for i in 0..n {
            let dik = d.at(i, k);
            if dik >= INF {
                continue;
            }
            let row = &mut d.data[i * n..(i + 1) * n];
            for (dv, &dkj) in row.iter_mut().zip(&rowk) {
                let cand = dik + dkj;
                if cand < *dv {
                    *dv = cand;
                }
            }
        }
    }
    d
}

/// Dijkstra from one source (binary-heap) — an independent APSP oracle
/// used to cross-check Floyd-Warshall on non-negative graphs.
pub fn dijkstra(g: &Graph, src: usize) -> Vec<f32> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    let n = g.n();
    let mut dist = vec![INF; n];
    dist[src] = 0.0;
    // BinaryHeap over (cost-as-ordered-bits, node)
    let key = |c: f32| c.to_bits();
    let mut heap = BinaryHeap::new();
    heap.push(Reverse((key(0.0), src)));
    while let Some(Reverse((kb, u))) = heap.pop() {
        let du = f32::from_bits(kb);
        if du > dist[u] {
            continue;
        }
        for v in 0..n {
            let w = g.w.at(u, v);
            if w >= INF {
                continue;
            }
            let cand = du + w;
            if cand < dist[v] {
                dist[v] = cand;
                heap.push(Reverse((key(cand), v)));
            }
        }
    }
    dist
}

/// All-pairs shortest paths via repeated min-plus squaring:
/// `D^(2k) = D^k ⊗ D^k`, ⌈log₂ n⌉ squarings — a third oracle, and the
/// sequential reference for the min-plus kernel extension.
pub fn apsp_repeated_squaring(g: &Graph) -> Mat {
    use crate::matrix::gemm::minplus_matmul;
    let n = g.n();
    let mut d = g.w.clone();
    let mut span = 1usize;
    while span < n {
        d = minplus_matmul(&d, &d);
        span *= 2;
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop_check;

    #[test]
    fn tiny_triangle() {
        // 0 -> 1 (5), 1 -> 2 (2), 0 -> 2 (9): shortest 0->2 is 7
        let mut w = Mat::filled(3, 3, INF);
        w[(0, 1)] = 5.0;
        w[(1, 2)] = 2.0;
        w[(0, 2)] = 9.0;
        let g = Graph::from_weights(w);
        let d = floyd_warshall_seq(&g);
        assert_eq!(d.at(0, 2), 7.0);
        assert_eq!(d.at(0, 1), 5.0);
        assert_eq!(d.at(2, 0), INF);
    }

    #[test]
    fn diagonal_zero_preserved() {
        let g = Graph::random(20, 0.3, 5);
        let d = floyd_warshall_seq(&g);
        for i in 0..20 {
            assert_eq!(d.at(i, i), 0.0);
        }
    }

    #[test]
    fn fw_matches_dijkstra() {
        prop_check("fw == dijkstra", 10, |rng| {
            let n = 4 + rng.gen_range(28);
            let g = Graph::random(n, 0.25, rng.next_u64());
            let d = floyd_warshall_seq(&g);
            for src in 0..n.min(5) {
                let dj = dijkstra(&g, src);
                for j in 0..n {
                    let a = d.at(src, j);
                    let b = dj[j];
                    if a >= INF || b >= INF {
                        assert!(a >= INF && b >= INF, "n={n} {src}->{j}: {a} vs {b}");
                    } else {
                        assert!((a - b).abs() <= 1e-3, "n={n} {src}->{j}: {a} vs {b}");
                    }
                }
            }
        });
    }

    #[test]
    fn fw_matches_repeated_squaring() {
        prop_check("fw == min-plus squaring", 8, |rng| {
            let n = 3 + rng.gen_range(20);
            let g = Graph::random(n, 0.3, rng.next_u64());
            let a = floyd_warshall_seq(&g);
            let b = apsp_repeated_squaring(&g);
            for i in 0..n {
                for j in 0..n {
                    let (x, y) = (a.at(i, j), b.at(i, j));
                    if x >= INF || y >= INF {
                        assert!(x >= INF && y >= INF);
                    } else {
                        assert!((x - y).abs() <= 1e-3);
                    }
                }
            }
        });
    }

    #[test]
    fn triangle_inequality_holds() {
        let g = Graph::random(25, 0.4, 11);
        let d = floyd_warshall_seq(&g);
        for i in 0..25 {
            for j in 0..25 {
                for k in 0..25 {
                    let (dij, dik, dkj) = (d.at(i, j), d.at(i, k), d.at(k, j));
                    if dik < INF && dkj < INF {
                        assert!(dij <= dik + dkj + 1e-3);
                    }
                }
            }
        }
    }

    #[test]
    fn density_extremes() {
        let empty = Graph::random(10, 0.0, 1);
        let d = floyd_warshall_seq(&empty);
        for i in 0..10 {
            for j in 0..10 {
                if i != j {
                    assert_eq!(d.at(i, j), INF);
                }
            }
        }
        let full = Graph::random(10, 1.0, 1);
        let d = floyd_warshall_seq(&full);
        assert!(d.data.iter().all(|&v| v < INF));
    }
}
