//! Single-core / single-rank "empirical peak" calibration (§6).
//!
//! The paper measures reference performance with a single-core C+MKL
//! matrix multiplication and normalizes every efficiency figure by it.
//! Our analogue sweeps three paths per block size:
//!
//! * **seed** — the frozen PR-0 scalar ikj kernel
//!   ([`gemm::matmul_seed_ikj`]), the fixed origin of the perf
//!   trajectory;
//! * **native** — the packed register-tiled kernel at 1/2/4
//!   `threads_per_rank`, measured through the real
//!   [`Compute::Native`](crate::runtime::compute::Compute) + metrics
//!   path, so the reported GFlop/s is read back from
//!   [`MetricsSnapshot::gflops`](crate::metrics::MetricsSnapshot::gflops)
//!   — exactly the figure real-mode runs surface per rank;
//! * **pjrt** — the AOT Pallas artifact, when available.
//!
//! The best native/pjrt number is what the `rate` field of a local
//! [`MachineConfig`] should be set to; [`efficiency_report`] renders the
//! achieved-vs-empirical-vs-theoretical comparison like the paper's
//! 93.7% / 88.8% headline.

use std::time::Instant;

use anyhow::Result;

use crate::comm::cost::CostParams;
use crate::config::MachineConfig;
use crate::matrix::block::Block;
use crate::matrix::dense::Mat;
use crate::matrix::gemm;
use crate::metrics::render_table;
use crate::runtime::compute::Compute;
use crate::runtime::engine::EngineServer;
use crate::Runtime;

#[derive(Clone, Debug)]
pub struct PeakRow {
    pub path: &'static str,
    pub b: usize,
    pub threads: usize,
    pub iters: usize,
    pub secs: f64,
    pub gflops: f64,
}

/// Measure the frozen seed kernel at block size `b` (the denominator of
/// the BENCH_gemm.json speedups).
pub fn seed_peak(b: usize, iters: usize) -> PeakRow {
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    // warmup
    let mut sink = gemm::matmul_seed_ikj(&x, &y);
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = gemm::matmul_seed_ikj(&x, &y);
    }
    let secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(&sink);
    let flops = gemm::gemm_flops(b, b, b) * iters as f64;
    PeakRow { path: "seed", b, threads: 1, iters, secs, gflops: flops / secs / 1e9 }
}

/// Measure the packed native kernel at block size `b` with `threads`
/// cores — through a real single-rank run, so the GFlop/s figure is the
/// rank's own [`MetricsSnapshot::gflops`](crate::metrics::MetricsSnapshot)
/// (what every real-mode experiment reports), not a side channel.
pub fn native_peak_mt(b: usize, iters: usize, threads: usize) -> PeakRow {
    native_peak_mt_with(b, iters, threads, &gemm::BlockParams::default())
}

/// [`native_peak_mt`] under an explicit blocking profile — what
/// `repro peak --profile` measures, so the reported rate is the one a
/// tuned run actually achieves.
pub fn native_peak_mt_with(
    b: usize,
    iters: usize,
    threads: usize,
    params: &gemm::BlockParams,
) -> PeakRow {
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    // warmup outside the measured context (also primes the scratch pool
    // and the per-rank workers)
    std::hint::black_box(gemm::matmul_mt_with(&x, &y, threads, params));
    let xb = Block::real(x);
    let yb = Block::real(y);
    let res = Runtime::builder()
        .world(1)
        .cost(CostParams::free())
        .threads_per_rank(threads)
        .block_params(*params)
        .build()
        .expect("peak runtime")
        .run(|ctx| {
            for _ in 0..iters {
                std::hint::black_box(Compute::Native.matmul(ctx, &xb, &yb));
            }
        });
    let m = res.metrics[0];
    PeakRow { path: "native", b, threads, iters, secs: m.compute_time, gflops: m.gflops() }
}

/// Single-threaded packed-kernel rate (calibration shorthand).
pub fn native_peak(b: usize, iters: usize) -> PeakRow {
    native_peak_mt(b, iters, 1)
}

/// Measure the PJRT path (AOT Pallas artifact) at block size `b`.
pub fn pjrt_peak(b: usize, iters: usize) -> Result<PeakRow> {
    let srv = EngineServer::start_default()?;
    let h = srv.handle();
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    let _ = h.matmul(x.clone(), y.clone())?; // warmup + compile
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = h.matmul(x.clone(), y.clone())?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = gemm::gemm_flops(b, b, b) * iters as f64;
    Ok(PeakRow { path: "pjrt", b, threads: 1, iters, secs, gflops: flops / secs / 1e9 })
}

/// Calibration sweep: seed baseline, packed kernel at 1/2/4 threads,
/// and PJRT rows when artifacts are available.
pub fn sweep(iters: usize) -> Vec<PeakRow> {
    sweep_with(iters, &gemm::BlockParams::default())
}

/// [`sweep`] with the native rows measured under an explicit blocking
/// profile (seed and PJRT rows are profile-oblivious by construction).
pub fn sweep_with(iters: usize, params: &gemm::BlockParams) -> Vec<PeakRow> {
    let mut rows = Vec::new();
    for &b in &[64usize, 128, 256, 512] {
        rows.push(seed_peak(b, iters));
        for &threads in &[1usize, 2, 4] {
            rows.push(native_peak_mt_with(b, iters, threads, params));
        }
        if let Ok(r) = pjrt_peak(b, iters) {
            rows.push(r);
        }
    }
    rows
}

/// Measure one threaded elementwise kernel (`"add"`, `"fw_update"` or
/// `"min"`) at block edge `b` with `threads` cores — through a real
/// single-rank run, so the reported GFlop/s is the rank's own
/// [`MetricsSnapshot::ew_gflops`](crate::metrics::MetricsSnapshot) —
/// exactly the elementwise figure real-mode runs surface.  These
/// kernels are bandwidth-bound (≈ one flop per 4-byte element), so the
/// numbers track memory throughput and only scale with threads past
/// [`gemm::EW_PAR_THRESHOLD`] elements (b ≥ 1024).
pub fn elementwise_peak_mt(op: &'static str, b: usize, iters: usize, threads: usize) -> PeakRow {
    use crate::runtime::compute::Seg;

    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    let ik: Vec<f32> = (0..b).map(|i| ((i * 7) % 23) as f32 * 0.5).collect();
    let kj: Vec<f32> = (0..b).map(|i| ((i * 5) % 19) as f32 * 0.25).collect();
    // warmup outside the measured context (primes the worker checkout)
    std::hint::black_box(gemm::add_mt(&x, &y, threads));
    let res = Runtime::builder()
        .world(1)
        .cost(CostParams::free())
        .threads_per_rank(threads)
        .build()
        .expect("peak runtime")
        .run(|ctx| {
            for _ in 0..iters {
                match op {
                    "add" => {
                        std::hint::black_box(Compute::Native.add(
                            ctx,
                            Block::real(x.clone()),
                            Block::real(y.clone()),
                        ));
                    }
                    "min" => {
                        std::hint::black_box(Compute::Native.min_blocks(
                            ctx,
                            Block::real(x.clone()),
                            Block::real(y.clone()),
                        ));
                    }
                    "fw_update" => {
                        // unshare outside the timed kernel: fw_update
                        // mutates in place, and measuring the CoW copy
                        // would understate the kernel's own rate
                        let mut d = x.clone();
                        let _ = d.data.as_mut_slice();
                        let ikseg = Seg::real(ik.clone());
                        let kjseg = Seg::real(kj.clone());
                        std::hint::black_box(Compute::Native.fw_update(
                            ctx,
                            Block::real(d),
                            &ikseg,
                            &kjseg,
                        ));
                    }
                    other => panic!("unknown elementwise op '{other}'"),
                }
            }
        });
    let m = res.metrics[0];
    PeakRow { path: op, b, threads, iters, secs: m.ew_time, gflops: m.ew_gflops() }
}

/// Elementwise calibration sweep: add / fw_update / min at 1/2/4
/// threads, below and above the threading threshold.
pub fn elementwise_sweep(iters: usize) -> Vec<PeakRow> {
    let mut rows = Vec::new();
    for &b in &[512usize, 1024, 2048] {
        for op in ["add", "fw_update", "min"] {
            for &threads in &[1usize, 2, 4] {
                rows.push(elementwise_peak_mt(op, b, iters, threads));
            }
        }
    }
    rows
}

pub fn render(rows: &[PeakRow]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.path.to_string(),
                r.b.to_string(),
                r.threads.to_string(),
                r.iters.to_string(),
                format!("{:.4}", r.secs),
                format!("{:.2}", r.gflops),
            ]
        })
        .collect();
    render_table(&["path", "block", "threads", "iters", "secs", "GFlop/s"], &table)
}

/// §6-style efficiency lines: the best measured rate per thread count
/// against the machine's empirical (`rate`) and theoretical (`peak`)
/// per-core figures — the same two percentages the paper quotes
/// (93.7% / 88.8% on Carver).
pub fn efficiency_report(rows: &[PeakRow], machine: &MachineConfig) -> String {
    let mut out = String::new();
    let mut threads_seen: Vec<usize> = rows
        .iter()
        .filter(|r| r.path == "native")
        .map(|r| r.threads)
        .collect();
    threads_seen.sort_unstable();
    threads_seen.dedup();
    for t in threads_seen {
        if let Some(best) = rows
            .iter()
            .filter(|r| r.path == "native" && r.threads == t)
            .max_by(|a, b| a.gflops.total_cmp(&b.gflops))
        {
            let cores = t as f64;
            let vs_rate = best.gflops * 1e9 / (machine.rate * cores) * 100.0;
            let vs_peak = best.gflops * 1e9 / (machine.peak * cores) * 100.0;
            out.push_str(&format!(
                "native b={} threads={}: {:.2} GF/s = {:.1}% of {} empirical peak, \
                 {:.1}% of theoretical\n",
                best.b, t, best.gflops, vs_rate, machine.name, vs_peak
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_peak_positive() {
        let r = native_peak(64, 3);
        assert!(r.gflops > 0.01, "{}", r.gflops);
        assert_eq!(r.threads, 1);
    }

    #[test]
    fn seed_peak_positive() {
        let r = seed_peak(64, 3);
        assert!(r.gflops > 0.01, "{}", r.gflops);
        assert_eq!(r.path, "seed");
    }

    #[test]
    fn elementwise_peak_positive_for_all_ops() {
        for op in ["add", "fw_update", "min"] {
            let r = elementwise_peak_mt(op, 64, 2, 1);
            assert!(r.gflops > 0.0, "{op}: {}", r.gflops);
            assert_eq!(r.path, op);
            assert_eq!(r.threads, 1);
        }
    }

    #[test]
    fn efficiency_report_mentions_machine() {
        let rows = vec![native_peak_mt(64, 2, 1)];
        let rep = efficiency_report(&rows, &MachineConfig::local());
        assert!(rep.contains("local"), "{rep}");
        assert!(rep.contains("threads=1"), "{rep}");
    }
}
