//! Single-core "empirical peak" calibration (§6).
//!
//! The paper measures reference performance with a single-core C+MKL
//! matrix multiplication; our analogue executes the AOT Pallas GEMM
//! artifact through PJRT on one rank and reports flop/s, alongside the
//! native-gemm rate.  The resulting number is what the `rate` field of a
//! local [`crate::config::MachineConfig`] should be set to when running
//! real-mode efficiency experiments on this host.

use std::time::Instant;

use anyhow::Result;

use crate::matrix::dense::Mat;
use crate::matrix::gemm;
use crate::metrics::render_table;
use crate::runtime::engine::EngineServer;

#[derive(Clone, Debug)]
pub struct PeakRow {
    pub path: &'static str,
    pub b: usize,
    pub iters: usize,
    pub secs: f64,
    pub gflops: f64,
}

/// Measure native gemm at block size `b`.
pub fn native_peak(b: usize, iters: usize) -> PeakRow {
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    // warmup
    let mut sink = gemm::matmul(&x, &y);
    let t0 = Instant::now();
    for _ in 0..iters {
        sink = gemm::matmul(&x, &y);
    }
    let secs = t0.elapsed().as_secs_f64();
    std::hint::black_box(&sink);
    let flops = gemm::gemm_flops(b, b, b) * iters as f64;
    PeakRow { path: "native", b, iters, secs, gflops: flops / secs / 1e9 }
}

/// Measure the PJRT path (AOT Pallas artifact) at block size `b`.
pub fn pjrt_peak(b: usize, iters: usize) -> Result<PeakRow> {
    let srv = EngineServer::start_default()?;
    let h = srv.handle();
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    let _ = h.matmul(x.clone(), y.clone())?; // warmup + compile
    let t0 = Instant::now();
    for _ in 0..iters {
        let _ = h.matmul(x.clone(), y.clone())?;
    }
    let secs = t0.elapsed().as_secs_f64();
    let flops = gemm::gemm_flops(b, b, b) * iters as f64;
    Ok(PeakRow { path: "pjrt", b, iters, secs, gflops: flops / secs / 1e9 })
}

/// Calibration sweep over block sizes; PJRT rows appear when artifacts
/// are available.
pub fn sweep(iters: usize) -> Vec<PeakRow> {
    let mut rows = Vec::new();
    for &b in &[32usize, 64, 128, 256] {
        rows.push(native_peak(b, iters));
        if let Ok(r) = pjrt_peak(b, iters) {
            rows.push(r);
        }
    }
    rows
}

pub fn render(rows: &[PeakRow]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.path.to_string(),
                r.b.to_string(),
                r.iters.to_string(),
                format!("{:.4}", r.secs),
                format!("{:.2}", r.gflops),
            ]
        })
        .collect();
    render_table(&["path", "block", "iters", "secs", "GFlop/s"], &table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_peak_positive() {
        let r = native_peak(64, 3);
        assert!(r.gflops > 0.01, "{}", r.gflops);
    }
}
