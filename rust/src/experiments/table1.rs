//! Table 1 reproduction: measured runtime of every distributed-sequence
//! operation vs. the paper's closed-form `T_P`.
//!
//! Protocol: for each op, sweep group size p and element size m (bytes);
//! run the op once on a fresh SPMD world with the machine's cost
//! parameters; report the measured virtual `T_P` next to the paper's
//! formula evaluated with the same `t_s`/`t_w` — and the ratio, which
//! should hover near 1 (binomial trees use ⌈log₂ p⌉, rings exactly p−1,
//! so small deviations from the idealized Θ-forms are expected and
//! printed rather than hidden).

use crate::comm::backend::BackendProfile;
use crate::comm::cost::CostParams;
use crate::config::MachineConfig;
use crate::data::dseq::DistSeq;
use crate::metrics::render_table;
use crate::spmd::{Ctx, Runtime};

/// One measurement row.
#[derive(Clone, Debug)]
pub struct Table1Row {
    pub op: &'static str,
    pub p: usize,
    pub m_bytes: usize,
    pub measured: f64,
    pub predicted: f64,
}

fn payload(m_bytes: usize) -> Vec<f32> {
    vec![1.0f32; (m_bytes.saturating_sub(8)) / 4]
}

fn msg(c: &CostParams, m_bytes: usize) -> f64 {
    c.ts + c.tw * m_bytes as f64
}

fn log2c(p: usize) -> f64 {
    (p.max(1) as f64).log2().ceil().max(0.0)
}

/// Run all Table-1 ops at one (p, m) point.
pub fn measure_point(machine: &MachineConfig, p: usize, m_bytes: usize) -> Vec<Table1Row> {
    let backend = BackendProfile::openmpi_fixed();
    let cost = machine.cost();
    let c = backend.cost(cost);
    let rt = Runtime::builder()
        .world(p)
        .backend_profile(backend)
        .cost(cost)
        .build()
        .expect("table1 runtime");
    let mut rows = Vec::new();

    let mut case = |op: &'static str,
                    predicted: f64,
                    f: &(dyn Fn(&Ctx) + Sync)| {
        let res = rt.run(|ctx| {
            f(ctx);
            ctx.now()
        });
        rows.push(Table1Row {
            op,
            p,
            m_bytes,
            measured: res.t_parallel,
            predicted,
        });
    };

    // mapD — non-communicating: T_P = T_λ(m) (here λ is free ⇒ 0)
    case("mapD", 0.0, &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| payload(m_bytes)).map_d(|v| v);
    });

    // zipWithD — non-communicating
    case("zipWithD", 0.0, &|ctx| {
        let a = DistSeq::range(ctx, p, |_| payload(m_bytes));
        let b = DistSeq::range(ctx, p, |_| payload(m_bytes));
        let _ = a.zip_with_d(b, |x, _| x);
    });

    // reduceD — Θ(log p (ts + tw m + T_λ)) with free λ
    case("reduceD", log2c(p) * msg(&c, m_bytes), &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| payload(m_bytes)).reduce_d(|a, _| a);
    });

    // shiftD — Θ(ts + tw m)
    case("shiftD", if p > 1 { msg(&c, m_bytes) } else { 0.0 }, &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| payload(m_bytes)).shift_d(1);
    });

    // allToAllD — pairwise: (p−1)(ts + tw m); paper quotes the hypercube
    // bound ts log p + tw m (p−1)
    case("allToAllD", (p as f64 - 1.0) * msg(&c, m_bytes), &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| {
            (0..p).map(|_| payload(m_bytes)).collect::<Vec<_>>()
        })
        .all_to_all_d();
    });

    // allGatherD — ring: (ts + tw m)(p−1)
    case("allGatherD", (p as f64 - 1.0) * msg(&c, m_bytes), &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| payload(m_bytes)).all_gather_d();
    });

    // apply(i) — one-to-all bcast: Θ(log p (ts + tw m))
    case("apply", log2c(p) * msg(&c, m_bytes), &|ctx| {
        let _ = DistSeq::range(ctx, p, |_| payload(m_bytes)).apply(p / 2);
    });

    rows
}

/// Full sweep: p ∈ powers of two, m ∈ {1 KiB, 64 KiB, 1 MiB}.
pub fn sweep(machine: &MachineConfig) -> Vec<Table1Row> {
    let mut rows = Vec::new();
    for &p in &[2usize, 4, 8, 16, 32, 64] {
        for &m in &[1 << 10, 64 << 10, 1 << 20] {
            rows.extend(measure_point(machine, p, m));
        }
    }
    rows
}

/// Paper formula labels (for the printed table).
pub fn paper_formula(op: &str) -> &'static str {
    match op {
        "mapD" | "zipWithD" => "Θ(T_λ(m))",
        "reduceD" => "Θ(log p (ts+tw m+T_λ))",
        "shiftD" => "Θ(ts + tw m)",
        "allToAllD" => "Θ(ts log p + tw m (p-1))",
        "allGatherD" => "Θ((ts + tw m)(p-1))",
        "apply" => "Θ(log p (ts + tw m))",
        _ => "?",
    }
}

pub fn render(rows: &[Table1Row]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            let ratio = if r.predicted > 0.0 { r.measured / r.predicted } else { 0.0 };
            vec![
                r.op.to_string(),
                r.p.to_string(),
                format!("{}", r.m_bytes),
                format!("{:.3e}", r.measured),
                format!("{:.3e}", r.predicted),
                if r.predicted > 0.0 { format!("{ratio:.2}") } else { "-".into() },
                paper_formula(r.op).to_string(),
            ]
        })
        .collect();
    render_table(
        &["op", "p", "m (B)", "measured T_P", "predicted", "ratio", "paper"],
        &table,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measured_matches_predicted_within_tolerance() {
        let m = MachineConfig::carver();
        for p in [4usize, 16] {
            for rows in [measure_point(&m, p, 64 << 10)] {
                for r in rows {
                    if r.predicted == 0.0 {
                        assert!(r.measured < 1e-9, "{}: nonzero {}", r.op, r.measured);
                        continue;
                    }
                    let ratio = r.measured / r.predicted;
                    assert!(
                        (0.5..=2.0).contains(&ratio),
                        "{} p={p}: measured {:.3e} predicted {:.3e}",
                        r.op,
                        r.measured,
                        r.predicted
                    );
                }
            }
        }
    }

    #[test]
    fn reduce_scales_logarithmically() {
        let m = MachineConfig::carver();
        let r4: f64 = measure_point(&m, 4, 1 << 20)
            .iter()
            .find(|r| r.op == "reduceD")
            .unwrap()
            .measured;
        let r64 = measure_point(&m, 64, 1 << 20)
            .iter()
            .find(|r| r.op == "reduceD")
            .unwrap()
            .measured;
        // log₂ 64 / log₂ 4 = 3: expect ≈3×, definitely not 16×
        let factor = r64 / r4;
        assert!((2.0..5.0).contains(&factor), "factor {factor}");
    }

    #[test]
    fn allgather_scales_linearly() {
        let m = MachineConfig::carver();
        let r4 = measure_point(&m, 4, 64 << 10)
            .iter()
            .find(|r| r.op == "allGatherD")
            .unwrap()
            .measured;
        let r32 = measure_point(&m, 32, 64 << 10)
            .iter()
            .find(|r| r.op == "allGatherD")
            .unwrap()
            .measured;
        let factor = r32 / r4;
        // (32-1)/(4-1) ≈ 10.3
        assert!((7.0..14.0).contains(&factor), "factor {factor}");
    }
}
