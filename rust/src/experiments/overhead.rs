//! §6 overhead experiment: FooPar (Alg. 2) vs the hand-coded DNS
//! baseline, same machine, same workload — "the computation and
//! communication overhead of using FooPar is neglectable".

use crate::algos::dns_baseline;
use crate::comm::backend::BackendProfile;
use crate::config::MachineConfig;
use crate::matrix::block::BlockSource;
use crate::metrics::render_table;
use crate::plan::{self, MatmulSpec, PlanMode, Schedule};
use crate::runtime::compute::Compute;
use crate::spmd::Runtime;

#[derive(Clone, Debug)]
pub struct OverheadRow {
    pub n: usize,
    pub p: usize,
    pub t_foopar: f64,
    pub t_baseline: f64,
    /// (T_foopar − T_baseline) / T_baseline.
    pub overhead: f64,
    /// Extra messages sent by the framework versus the baseline.
    pub msg_delta: i64,
}

pub fn measure(machine: &MachineConfig, n: usize, p: usize) -> OverheadRow {
    let q = (p as f64).cbrt().round() as usize;
    assert_eq!(q * q * q, p);
    assert_eq!(n % q, 0);
    let a = BlockSource::proxy(n / q, 1);
    let b = BlockSource::proxy(n / q, 2);
    let comp = Compute::Modeled { rate: machine.rate };
    let rt = Runtime::builder()
        .world(p)
        .backend_profile(BackendProfile::openmpi_fixed())
        .machine_config(machine)
        .build()
        .expect("overhead runtime");

    let foo = rt.run(|ctx| {
        let spec =
            MatmulSpec::new(&comp, q, &a, &b).mode(PlanMode::Forced(Schedule::DnsBlocking));
        plan::matmul(ctx, spec).t_local
    });
    let base = rt.run(|ctx| dns_baseline::dns_baseline(ctx, &comp, q, &a, &b).t_local);

    let foo_msgs: u64 = foo.metrics.iter().map(|m| m.msgs_sent).sum();
    let base_msgs: u64 = base.metrics.iter().map(|m| m.msgs_sent).sum();
    OverheadRow {
        n,
        p,
        t_foopar: foo.t_parallel,
        t_baseline: base.t_parallel,
        overhead: (foo.t_parallel - base.t_parallel) / base.t_parallel,
        msg_delta: foo_msgs as i64 - base_msgs as i64,
    }
}

pub fn sweep(machine: &MachineConfig) -> Vec<OverheadRow> {
    let mut rows = Vec::new();
    for &p in &[8usize, 64, 216, 512] {
        if p > machine.max_cores {
            continue;
        }
        rows.push(measure(machine, 20_160, p));
    }
    rows
}

pub fn render(rows: &[OverheadRow]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.n.to_string(),
                r.p.to_string(),
                format!("{:.4}", r.t_foopar),
                format!("{:.4}", r.t_baseline),
                format!("{:+.2}%", r.overhead * 100.0),
                r.msg_delta.to_string(),
            ]
        })
        .collect();
    render_table(
        &["n", "p", "T_P foopar", "T_P baseline", "overhead", "msg Δ"],
        &table,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overhead_is_negligible() {
        let m = MachineConfig::carver();
        for p in [8usize, 64] {
            let r = measure(&m, 20_160, p);
            assert!(
                r.overhead.abs() < 0.05,
                "p={p}: overhead {:.2}%",
                r.overhead * 100.0
            );
        }
    }

    #[test]
    fn same_message_pattern() {
        // Alg. 2 and the baseline implement the same DNS reduction: the
        // message counts must match exactly (the framework adds zero
        // communication).
        let m = MachineConfig::carver();
        let r = measure(&m, 20_160, 27);
        assert_eq!(r.msg_delta, 0, "framework sent {} extra messages", r.msg_delta);
    }
}
