//! Experiment drivers — one per table/figure of the paper's evaluation.
//!
//! Shared between the CLI (`repro <experiment>`) and the bench harnesses
//! (`cargo bench`), so a result can always be regenerated both ways.
//!
//! | module | reproduces |
//! |---|---|
//! | [`table1`] | Table 1 — runtimes of the distributed-sequence ops |
//! | [`fig5`] | Fig. 5 — MMM efficiency on Carver / Horseshoe-6 |
//! | [`isoeff`] | §4.2.1 / §4.3 / §5 — isoefficiency verification |
//! | [`overhead`] | §6 — FooPar vs hand-coded DNS overhead |
//! | [`peak`] | §6 — single-core "empirical peak" calibration |
//! | [`tune`] | §6 — per-host kernel/link autotuning (`repro tune`) |

pub mod fig5;
pub mod isoeff;
pub mod overhead;
pub mod peak;
pub mod table1;
pub mod tune;
