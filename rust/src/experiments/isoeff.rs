//! Isoefficiency verification (§4.2.1 generic, §4.3 grid/DNS, §5 FW).
//!
//! Two protocols per algorithm:
//!
//! 1. **Iso-curve**: for each p, solve the paper's runtime model for the
//!    n that should hold efficiency at `TARGET`; run the simulator at
//!    (n, p) and check measured efficiency stays flat.  The required
//!    problem growth `W(p) = n³` is printed next to the paper's
//!    asymptotic isoefficiency function.
//! 2. **Fixed-n decay**: hold n constant and grow p — efficiency must
//!    *fall*, faster for the generic algorithm than for DNS (the whole
//!    point of §4.3's grid abstraction).

use crate::algos::{floyd_warshall, mmm_generic};
use crate::analysis::{self, ModelParams};
use crate::comm::backend::BackendProfile;
use crate::config::MachineConfig;
use crate::matrix::block::BlockSource;
use crate::metrics::render_table;
use crate::plan::{self, FwSpec, MatmulSpec, PlanMode, Schedule};
use crate::runtime::compute::Compute;
use crate::spmd::Runtime;

pub const TARGET: f64 = 0.75;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Generic,
    Dns,
    Fw,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Generic => "generic",
            Algo::Dns => "dns",
            Algo::Fw => "floyd-warshall",
        }
    }

    pub fn by_name(s: &str) -> Option<Self> {
        Some(match s {
            "generic" => Algo::Generic,
            "dns" | "grid" => Algo::Dns,
            "fw" | "floyd-warshall" | "apsp" => Algo::Fw,
            _ => return None,
        })
    }

    /// Valid processor counts (cubes for MMM, squares for FW).
    pub fn ps(&self) -> Vec<usize> {
        match self {
            Algo::Generic | Algo::Dns => vec![8, 27, 64, 125, 216, 512],
            Algo::Fw => vec![4, 16, 64, 256],
        }
    }

    fn q(&self, p: usize) -> usize {
        match self {
            Algo::Generic | Algo::Dns => (p as f64).cbrt().round() as usize,
            Algo::Fw => (p as f64).sqrt().round() as usize,
        }
    }

    fn model(&self) -> fn(usize, usize, &ModelParams) -> f64 {
        match self {
            Algo::Generic => analysis::tp_generic,
            Algo::Dns => analysis::tp_dns,
            Algo::Fw => analysis::tp_fw,
        }
    }

    /// Paper's asymptotic isoefficiency for the report column.
    pub fn iso_label(&self) -> &'static str {
        match self {
            Algo::Generic => "Θ(p^{5/3})",
            Algo::Dns => "Θ(p log p)",
            Algo::Fw => "Θ((√p log p)³)",
        }
    }

    /// Run the algorithm modeled at (n, p); returns measured T_P.
    pub fn run(&self, machine: &MachineConfig, n: usize, p: usize) -> f64 {
        let q = self.q(p);
        let comp = Compute::Modeled { rate: machine.rate };
        let rt = Runtime::builder()
            .world(p)
            .backend_profile(BackendProfile::openmpi_fixed())
            .machine_config(machine)
            .build()
            .expect("isoeff runtime");
        match self {
            Algo::Generic => {
                let a = BlockSource::proxy(n / q, 1);
                let b = BlockSource::proxy(n / q, 2);
                rt.run(|ctx| mmm_generic::mmm_generic(ctx, &comp, q, &a, &b).t_local)
                    .t_parallel
            }
            Algo::Dns => {
                let a = BlockSource::proxy(n / q, 1);
                let b = BlockSource::proxy(n / q, 2);
                rt.run(|ctx| {
                    let spec = MatmulSpec::new(&comp, q, &a, &b)
                        .mode(PlanMode::Forced(Schedule::DnsBlocking));
                    plan::matmul(ctx, spec).t_local
                })
                    .t_parallel
            }
            Algo::Fw => {
                let src = floyd_warshall::FwSource::Proxy { n };
                rt.run(|ctx| plan::apsp(ctx, FwSpec::new(&comp, q, &src)).t_local)
                    .t_parallel
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct IsoRow {
    pub algo: &'static str,
    pub p: usize,
    pub n: usize,
    pub w: f64,
    pub measured_eff: f64,
    pub model_eff: f64,
}

/// Protocol 1: follow the isoefficiency curve.
pub fn iso_curve(machine: &MachineConfig, algo: Algo) -> Vec<IsoRow> {
    let mp = fig_model(machine);
    let mut rows = Vec::new();
    for p in algo.ps() {
        let q = algo.q(p);
        // n must be a multiple of q; cap the search to keep runs quick
        let n_max = match algo {
            Algo::Fw => 1 << 14, // FW simulates n pivot rounds: keep modest
            _ => 1 << 17,
        };
        let Some(n0) = analysis::isoefficiency_n(algo.model(), p, TARGET, &mp, q, n_max)
        else {
            continue;
        };
        let n = n0.div_ceil(q) * q;
        let tp = algo.run(machine, n, p);
        let ts = analysis::ts_n3(n, &mp);
        rows.push(IsoRow {
            algo: algo.name(),
            p,
            n,
            w: (n as f64).powi(3),
            measured_eff: analysis::efficiency(ts, tp, p),
            model_eff: analysis::model_efficiency(algo.model(), n, p, &mp),
        });
    }
    rows
}

/// Protocol 2: fixed n, growing p (efficiency decay).
pub fn fixed_n_decay(machine: &MachineConfig, algo: Algo, n: usize) -> Vec<IsoRow> {
    let mp = fig_model(machine);
    let mut rows = Vec::new();
    for p in algo.ps() {
        let q = algo.q(p);
        if n % q != 0 {
            continue;
        }
        let tp = algo.run(machine, n, p);
        let ts = analysis::ts_n3(n, &mp);
        rows.push(IsoRow {
            algo: algo.name(),
            p,
            n,
            w: (n as f64).powi(3),
            measured_eff: analysis::efficiency(ts, tp, p),
            model_eff: analysis::model_efficiency(algo.model(), n, p, &mp),
        });
    }
    rows
}

fn fig_model(machine: &MachineConfig) -> ModelParams {
    ModelParams { ts: machine.ts, tw: machine.tw, rate: machine.rate }
}

pub fn render(rows: &[IsoRow], iso_label: &str) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.algo.to_string(),
                r.p.to_string(),
                r.n.to_string(),
                format!("{:.2e}", r.w),
                format!("{:.1}%", r.measured_eff * 100.0),
                format!("{:.1}%", r.model_eff * 100.0),
                iso_label.to_string(),
            ]
        })
        .collect();
    render_table(
        &["algo", "p", "n(iso)", "W=n³", "measured E", "model E", "paper iso"],
        &table,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dns_iso_curve_holds_efficiency_flat() {
        let m = MachineConfig::carver();
        let rows = iso_curve(&m, Algo::Dns);
        assert!(rows.len() >= 4);
        for r in &rows {
            assert!(
                (r.measured_eff - TARGET).abs() < 0.15,
                "p={} n={} E={:.3}",
                r.p,
                r.n,
                r.measured_eff
            );
        }
    }

    #[test]
    fn generic_needs_larger_w_than_dns() {
        // §4.2.1 vs §4.3: at the same p and target E, the generic
        // algorithm requires a (much) larger problem
        let m = MachineConfig::carver();
        let gen = iso_curve(&m, Algo::Generic);
        let dns = iso_curve(&m, Algo::Dns);
        let gp: Vec<_> = gen.iter().filter(|r| r.p >= 216).collect();
        for g in gp {
            if let Some(d) = dns.iter().find(|d| d.p == g.p) {
                assert!(
                    g.w >= d.w,
                    "p={}: generic W {:.2e} < dns W {:.2e}",
                    g.p,
                    g.w,
                    d.w
                );
            }
        }
    }

    #[test]
    fn fixed_n_efficiency_decays_with_p() {
        let m = MachineConfig::carver();
        let rows = fixed_n_decay(&m, Algo::Dns, 4320); // 4320 = lcm-friendly
        assert!(rows.len() >= 3);
        for w in rows.windows(2) {
            assert!(
                w[1].measured_eff <= w[0].measured_eff + 0.02,
                "efficiency should decay: {:?}",
                rows.iter().map(|r| (r.p, r.measured_eff)).collect::<Vec<_>>()
            );
        }
    }
}
