//! Fig. 5 reproduction: MMM efficiency vs. core count.
//!
//! Left plot (Carver): Algorithm 2 with the patched OpenMPI backend for
//! n ∈ {~10000 … ~40000}, p ∈ {1, 8, …, 512}, plus the C/MPI baseline.
//! Right plot (Horseshoe-6): backend sweep (openmpi-fixed / stock /
//! mpj-express / fastmpj) showing the Θ(p)-reduction backends falling
//! behind.
//!
//! Efficiency is `T_S / (p · T_P)` with `T_S = 2n³/rate` — exactly the
//! paper's normalization against single-core empirical peak.  Runs are
//! *modeled* (proxy blocks, virtual clocks): the paper's matrix sizes on
//! a laptop.  Headline check: Carver @ (n≈40000, p=512) ⇒ ~88.8%
//! efficiency.

use std::sync::Arc;

use crate::algos::dns_baseline;
use crate::analysis;
use crate::comm::backend::{registry, Backend, BackendProfile};
use crate::config::MachineConfig;
use crate::matrix::block::BlockSource;
use crate::metrics::render_table;
use crate::plan::{self, MatmulSpec, PlanMode, Schedule};
use crate::runtime::compute::Compute;
use crate::spmd::Runtime;

/// One curve point.
#[derive(Clone, Debug)]
pub struct Fig5Row {
    pub algo: &'static str,
    pub backend: String,
    pub n: usize,
    pub p: usize,
    pub t_parallel: f64,
    pub efficiency: f64,
    pub tflops: f64,
}

/// Paper-scale matrix sizes, divisible by every q ≤ 8 (lcm(1..8)=840).
pub const NS_PAPER: [usize; 4] = [10_080, 20_160, 30_240, 40_320];

/// Smaller sizes used for the Horseshoe-6 backend comparison, where the
/// communication fraction (and hence the backend differences) is larger.
pub const NS_SMALL: [usize; 4] = [2_520, 5_040, 10_080, 20_160];

/// Cube core counts up to 512 (q = 1..8).
pub const PS_CUBES: [usize; 8] = [1, 8, 27, 64, 125, 216, 343, 512];

/// Matrix sizes for a machine's sweep (Fig. 5 legend).
pub fn ns_for(machine: &MachineConfig) -> &'static [usize] {
    if machine.backends.len() > 1 {
        &NS_SMALL
    } else {
        &NS_PAPER
    }
}

/// Run one modeled DNS point against any registered (or ad-hoc) backend.
pub fn run_point(
    machine: &MachineConfig,
    backend: &Arc<dyn Backend>,
    n: usize,
    p: usize,
    baseline: bool,
) -> Fig5Row {
    let q = (p as f64).cbrt().round() as usize;
    assert_eq!(q * q * q, p, "p must be a cube");
    assert_eq!(n % q, 0, "n must divide by q");
    let b = n / q;
    let a = BlockSource::proxy(b, 1);
    let bm = BlockSource::proxy(b, 2);
    let comp = Compute::Modeled { rate: machine.rate };
    let res = Runtime::builder()
        .world(p)
        .backend_obj(backend.clone())
        .machine_config(machine)
        .run(|ctx| {
            if baseline {
                dns_baseline::dns_baseline(ctx, &comp, q, &a, &bm).t_local
            } else {
                let spec = MatmulSpec::new(&comp, q, &a, &bm)
                    .mode(PlanMode::Forced(Schedule::DnsBlocking));
                plan::matmul(ctx, spec).t_local
            }
        })
        .expect("fig5 runtime");
    let ts = analysis::ts_n3(n, &model(machine));
    let eff = analysis::efficiency(ts, res.t_parallel, p);
    Fig5Row {
        algo: if baseline { "c-baseline" } else { "foopar-dns" },
        backend: backend.name().to_string(),
        n,
        p,
        t_parallel: res.t_parallel,
        efficiency: eff,
        tflops: analysis::mmm_rate(n, res.t_parallel) / 1e12,
    }
}

pub fn model(machine: &MachineConfig) -> analysis::ModelParams {
    analysis::ModelParams { ts: machine.ts, tw: machine.tw, rate: machine.rate }
}

/// Full sweep for one machine (the whole left or right plot).
pub fn sweep(machine: &MachineConfig, with_baseline: bool) -> Vec<Fig5Row> {
    let mut rows = Vec::new();
    for bname in &machine.backends {
        let backend = registry::by_name(bname)
            .unwrap_or_else(|| panic!("unknown backend '{bname}'"));
        for &n in ns_for(machine) {
            for &p in &PS_CUBES {
                if p > machine.max_cores {
                    continue;
                }
                rows.push(run_point(machine, &backend, n, p, false));
            }
        }
    }
    if with_baseline {
        // The C/MPI comparison is run with the best backend only (§6).
        let backend: Arc<dyn Backend> = Arc::new(BackendProfile::openmpi_fixed());
        let n = *NS_PAPER.last().unwrap();
        for &p in &PS_CUBES {
            if p > machine.max_cores {
                continue;
            }
            rows.push(run_point(machine, &backend, n, p, true));
        }
    }
    rows
}

/// Render rows as the paper-style series table.
pub fn render(rows: &[Fig5Row]) -> String {
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.algo.to_string(),
                r.backend.clone(),
                r.n.to_string(),
                r.p.to_string(),
                format!("{:.3}", r.t_parallel),
                format!("{:.1}%", r.efficiency * 100.0),
                format!("{:.3}", r.tflops),
            ]
        })
        .collect();
    render_table(
        &["algo", "backend", "n", "p", "T_P (s)", "efficiency", "TFlop/s"],
        &table,
    )
}

/// The headline claim of §6: Carver, n≈40000, p=512 ⇒ ~88.8% efficiency
/// w.r.t. theoretical peak (93.7% of empirical).  Returns (row, eff_vs_peak).
pub fn headline(machine: &MachineConfig) -> (Fig5Row, f64) {
    let backend: Arc<dyn Backend> = Arc::new(BackendProfile::openmpi_fixed());
    let row = run_point(machine, &backend, *NS_PAPER.last().unwrap(), 512, false);
    let vs_peak = row.efficiency * machine.rate / machine.peak;
    (row, vs_peak)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arc(p: BackendProfile) -> Arc<dyn Backend> {
        Arc::new(p)
    }

    #[test]
    fn efficiency_increases_with_n_at_fixed_p() {
        let m = MachineConfig::carver();
        let b = arc(BackendProfile::openmpi_fixed());
        let e1 = run_point(&m, &b, 10_080, 216, false).efficiency;
        let e2 = run_point(&m, &b, 40_320, 216, false).efficiency;
        assert!(e2 > e1, "{e2} vs {e1}");
    }

    #[test]
    fn headline_efficiency_near_paper_value() {
        // paper: 93.7% of empirical peak, 88.8% of theoretical at
        // (40000, 512); accept the modeled value within a few points.
        let (row, vs_peak) = headline(&MachineConfig::carver());
        assert!(
            row.efficiency > 0.85 && row.efficiency <= 1.0,
            "empirical-peak efficiency {:.3} out of range",
            row.efficiency
        );
        assert!(
            vs_peak > 0.80 && vs_peak < 0.98,
            "theoretical-peak efficiency {vs_peak:.3} out of range"
        );
    }

    #[test]
    fn stock_backend_loses_at_scale() {
        // Fig. 5 right: Θ(p) reduction must hurt at p=512
        let m = MachineConfig::horseshoe6();
        let fixed = run_point(&m, &arc(BackendProfile::openmpi_fixed()), 5_040, 512, false);
        let stock = run_point(&m, &arc(BackendProfile::openmpi_stock()), 5_040, 512, false);
        assert!(
            stock.efficiency < fixed.efficiency,
            "stock {} !< fixed {}",
            stock.efficiency,
            fixed.efficiency
        );
    }

    #[test]
    fn baseline_slightly_better_than_framework() {
        let m = MachineConfig::carver();
        let b = arc(BackendProfile::openmpi_fixed());
        let foo = run_point(&m, &b, 40_320, 512, false);
        let c = run_point(&m, &b, 40_320, 512, true);
        // §6: "The C-version performs only slightly better."
        assert!(c.efficiency >= foo.efficiency * 0.99);
        assert!(c.efficiency <= foo.efficiency * 1.10);
    }
}
