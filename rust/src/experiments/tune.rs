//! Empirical autotuning: sweep the packed GEMM's blocking on the real
//! native path and ping-pong messages to measure per-level link costs.
//!
//! The paper's §6 efficiency numbers rest on two empirical inputs: a
//! BLAS tuned to the host CPU and *measured* `t_s`/`t_w` interconnect
//! parameters.  `repro tune` reproduces both calibrations:
//!
//! * **Kernel sweep** — hill-climbs KC × MC × NC × microkernel ×
//!   threads by coordinate descent, each point measured through a real
//!   single-rank [`Compute::Native`] run (the GFlop/s read back from
//!   [`MetricsSnapshot::gflops`](crate::metrics::MetricsSnapshot::gflops),
//!   exactly what real-mode experiments report).  The built-in defaults
//!   are measured first and seed the climb, so the winning point is
//!   never worse than the defaults on its own (b, threads) cell.
//! * **Link ping-pong** — round-trips payloads of two sizes over the
//!   shared-memory transport (intra-node) and over real TCP loopback
//!   sockets (inter-node), solving `rtt/2 = ts + tw·bytes` for each
//!   level.  The resulting [`LinkCalibration`] replaces the hardcoded
//!   [`HierCost::hierarchical`](crate::comm::cost::HierCost) prices on
//!   hierarchical worlds.
//!
//! Results persist as a per-host [`TuneProfile`]
//! (`~/.foopar/tune-<host>.json`) consumed by
//! `Runtime::builder().tune_profile(..)`, the `tune_profile`
//! machine-config key, or the CLI `--profile` flag.

use std::time::Instant;

use anyhow::Result;

use crate::comm::cost::CostParams;
use crate::matrix::block::Block;
use crate::matrix::dense::Mat;
use crate::matrix::gemm;
use crate::matrix::params::{BlockParams, MicroKernel};
use crate::runtime::compute::Compute;
use crate::tune::{LinkCalibration, TuneCell, TuneProfile};
use crate::Runtime;

/// Shape and budget of a tuning sweep.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Block edge the GEMM cells run at.
    pub b: usize,
    /// Timed iterations per cell (one extra warmup runs untimed).
    pub iters: usize,
    /// Thread counts in the climb's threads axis (non-empty).
    pub threads: Vec<usize>,
    /// Quick mode trims each axis's candidate list (CI smoke).
    pub quick: bool,
}

impl SweepConfig {
    /// CI-smoke shape: small block, two thread counts, trimmed axes.
    pub fn quick() -> Self {
        SweepConfig { b: 128, iters: 2, threads: vec![1, 2], quick: true }
    }

    /// Full calibration shape (what a real host should persist).
    pub fn full() -> Self {
        SweepConfig { b: 256, iters: 5, threads: vec![1, 2, 4], quick: false }
    }
}

/// Measure one (blocking, threads) point at block edge `b` through a
/// real single-rank run, so the number is the rank's own metrics figure.
pub fn measure_gemm(b: usize, iters: usize, threads: usize, params: &BlockParams) -> f64 {
    let x = Mat::random(b, b, 1);
    let y = Mat::random(b, b, 2);
    // warmup outside the measured context (primes the pack-scratch pool
    // for this profile's panel sizes and the per-rank workers)
    std::hint::black_box(gemm::matmul_mt_with(&x, &y, threads, params));
    let xb = Block::real(x);
    let yb = Block::real(y);
    let res = Runtime::builder()
        .world(1)
        .cost(CostParams::free())
        .threads_per_rank(threads)
        .block_params(*params)
        .build()
        .expect("tune runtime")
        .run(|ctx| {
            for _ in 0..iters.max(1) {
                std::hint::black_box(Compute::Native.matmul(ctx, &xb, &yb));
            }
        });
    res.metrics[0].gflops()
}

/// Coordinate-descent sweep over KC × MC × NC × microkernel × threads.
/// Returns a profile (without link calibration) whose best point is, by
/// construction, no worse than the defaults on at least its own
/// (b, threads) cell — the defaults are the climb's starting state.
pub fn sweep(cfg: &SweepConfig) -> TuneProfile {
    assert!(!cfg.threads.is_empty(), "sweep needs at least one thread count");
    let mut cells: Vec<TuneCell> = Vec::new();
    // Memoize measured points: coordinate descent revisits neighbours,
    // and the profile's cells must stay unique per (kernel, b, threads)
    // for the bench-gate parser's identity key.
    let mut seen: Vec<(BlockParams, usize, f64)> = Vec::new();

    let default = BlockParams::default();
    let mut best = default;
    let mut best_threads = cfg.threads[0];
    let mut best_g = f64::NEG_INFINITY;
    for &t in &cfg.threads {
        let g = measure_gemm(cfg.b, cfg.iters, t, &default);
        cells.push(TuneCell { kernel: "default".into(), b: cfg.b, threads: t, gflops: g });
        seen.push((default, t, g));
        if g > best_g {
            best_g = g;
            best_threads = t;
        }
    }

    let (kcs, mcs, ncs): (&[usize], &[usize], &[usize]) = if cfg.quick {
        (&[128, 256], &[32, 64], &[64, 128])
    } else {
        (&[64, 128, 256, 512], &[32, 64, 128], &[64, 128, 256])
    };

    for _round in 0..3 {
        let mut improved = false;
        let mut candidates: Vec<(BlockParams, usize)> = Vec::new();
        for &kc in kcs {
            candidates.push((BlockParams { kc, ..best }, best_threads));
        }
        for &mc in mcs {
            candidates.push((BlockParams { mc, ..best }, best_threads));
        }
        for &nc in ncs {
            candidates.push((BlockParams { nc, ..best }, best_threads));
        }
        for micro in MicroKernel::ALL {
            candidates.push((BlockParams { micro, ..best }, best_threads));
        }
        for &t in &cfg.threads {
            candidates.push((best, t));
        }
        for (p, t) in candidates {
            if (p, t) == (best, best_threads) || p.validate().is_err() {
                continue;
            }
            if seen.iter().any(|&(sp, st, _)| (sp, st) == (p, t)) {
                continue;
            }
            let g = measure_gemm(cfg.b, cfg.iters, t, &p);
            cells.push(TuneCell {
                kernel: format!("{} t{t}", p.label()),
                b: cfg.b,
                threads: t,
                gflops: g,
            });
            seen.push((p, t, g));
            if g > best_g {
                best_g = g;
                best = p;
                best_threads = t;
                improved = true;
            }
        }
        if !improved {
            break;
        }
    }

    cells.push(TuneCell {
        kernel: "tuned".into(),
        b: cfg.b,
        threads: best_threads,
        gflops: best_g,
    });
    TuneProfile {
        host: TuneProfile::host_name(),
        block: best,
        threads: best_threads,
        gflops: best_g,
        link: None,
        cells,
        source: None,
    }
}

/// Arbitrary non-reserved tag for ping-pong traffic (reserved tags live
/// at the top of the `u64` range).
const PINGPONG_TAG: u64 = 0x746e_7570;

/// Wall-clock round-trip time of one `len`-float payload echo over the
/// named transport, averaged over `reps` timed rounds (plus one warmup).
fn pingpong_rtt(transport: &str, len: usize, reps: usize) -> Result<f64> {
    let reps = reps.max(1);
    let res = Runtime::builder()
        .world(2)
        .cost(CostParams::free())
        .transport(transport)
        .build()?
        .run(move |ctx| {
            let payload = vec![0.5f32; len];
            if ctx.rank == 0 {
                ctx.send(1, PINGPONG_TAG, payload.clone());
                let _: Vec<f32> = ctx.recv(1, PINGPONG_TAG);
                let t0 = Instant::now();
                for _ in 0..reps {
                    ctx.send(1, PINGPONG_TAG, payload.clone());
                    let _: Vec<f32> = ctx.recv(1, PINGPONG_TAG);
                }
                t0.elapsed().as_secs_f64() / reps as f64
            } else {
                for _ in 0..reps + 1 {
                    let v: Vec<f32> = ctx.recv(0, PINGPONG_TAG);
                    ctx.send(0, PINGPONG_TAG, v);
                }
                0.0
            }
        });
    Ok(res.results[0])
}

/// Solve `rtt/2 = ts + tw·bytes` from two payload sizes on one
/// transport.  Clamped below to keep noisy measurements from producing
/// zero or negative parameters (which would let the cost model claim
/// free bandwidth).
fn pingpong_cost(transport: &str, reps: usize) -> Result<CostParams> {
    const SMALL: usize = 8; // 32 B: latency-dominated
    const LARGE: usize = 1 << 16; // 256 KiB: bandwidth-dominated
    let rtt_small = pingpong_rtt(transport, SMALL, reps)?;
    let rtt_large = pingpong_rtt(transport, LARGE, reps)?;
    let ts = (rtt_small / 2.0).max(1e-9);
    let bytes = ((LARGE - SMALL) * 4) as f64;
    let tw = (((rtt_large - rtt_small) / 2.0) / bytes).max(1e-13);
    Ok(CostParams::new(ts, tw))
}

/// Measure this host's intra-node (shared-memory) and inter-node
/// (TCP loopback) link parameters by ping-pong.
pub fn calibrate_links(reps: usize) -> Result<LinkCalibration> {
    let intra = pingpong_cost("local", reps)?;
    let inter = pingpong_cost("tcp-loopback", reps)?;
    Ok(LinkCalibration { intra, inter })
}

/// Full tuning run: kernel sweep plus (optionally) link calibration.
pub fn run(cfg: &SweepConfig, calibrate: bool, link_reps: usize) -> Result<TuneProfile> {
    let mut profile = sweep(cfg);
    if calibrate {
        profile.link = Some(calibrate_links(link_reps)?);
    }
    Ok(profile)
}

/// One-screen summary for the CLI.
pub fn render(p: &TuneProfile) -> String {
    let mut out = String::new();
    out.push_str(&format!("host {}: best {} at {} threads — {:.2} GF/s\n",
        p.host, p.block.label(), p.threads, p.gflops));
    if let Some(d) = p
        .cells
        .iter()
        .find(|c| c.kernel == "default" && c.threads == p.threads)
    {
        let pct = if d.gflops > 0.0 { (p.gflops / d.gflops - 1.0) * 100.0 } else { 0.0 };
        out.push_str(&format!(
            "  vs default at {} threads: {:.2} GF/s ({:+.1}%)\n",
            d.threads, d.gflops, pct
        ));
    }
    match &p.link {
        Some(l) => out.push_str(&format!(
            "  links: intra ts={:.3e}s tw={:.3e}s/B, inter ts={:.3e}s tw={:.3e}s/B\n",
            l.intra.ts, l.intra.tw, l.inter.ts, l.inter.tw
        )),
        None => out.push_str("  links: not calibrated (run without --no-link)\n"),
    }
    out.push_str(&format!("  swept {} cells at b={}\n", p.cells.len(), p.cells[0].b));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_sweep_never_loses_to_defaults() {
        let cfg = SweepConfig { b: 48, iters: 1, threads: vec![1], quick: true };
        let p = sweep(&cfg);
        let default_cell = p
            .cells
            .iter()
            .find(|c| c.kernel == "default" && c.threads == p.threads)
            .expect("default cell present");
        assert!(p.gflops >= default_cell.gflops, "{} < {}", p.gflops, default_cell.gflops);
        assert!(p.block.validate().is_ok());
        // emitted JSON must survive the profile parser (what the CI
        // tune-smoke job checks through bench_gate --check)
        let back = TuneProfile::from_json(&p.to_json()).unwrap();
        assert_eq!(back.block, p.block);
        assert_eq!(back.cells.len(), p.cells.len());
    }

    #[test]
    fn measure_gemm_positive_under_nondefault_profile() {
        let p = BlockParams {
            kc: 32,
            mc: 16,
            nc: 32,
            micro: MicroKernel::Mr8Nr4,
            ..BlockParams::default()
        };
        let g = measure_gemm(32, 1, 1, &p);
        assert!(g > 0.0, "{g}");
    }

    #[test]
    fn shared_memory_pingpong_measures_positive_costs() {
        let c = pingpong_cost("local", 2).unwrap();
        assert!(c.ts > 0.0 && c.tw > 0.0, "ts={} tw={}", c.ts, c.tw);
    }

    #[test]
    fn render_mentions_best_and_links() {
        let cfg = SweepConfig { b: 32, iters: 1, threads: vec![1], quick: true };
        let p = sweep(&cfg);
        let s = render(&p);
        assert!(s.contains("best"), "{s}");
        assert!(s.contains("not calibrated"), "{s}");
    }
}
