//! The [`Data`] trait: everything that can travel through the fabric.
//!
//! FooPar serializes collection elements with user-defined serializers
//! (falling back to Java byte serialization).  In-process we never actually
//! serialize — values move by ownership — but the *cost model* needs the
//! wire size of every message, so `Data` exposes `byte_size`.
//!
//! `byte_size` should return the payload size a reasonable binary
//! serializer would produce (element count × element width); framing
//! overhead is absorbed into the backend's `t_s`.

/// A value that can be sent between ranks.
pub trait Data: Send + 'static {
    /// Serialized payload size in bytes (drives the `t_w·m` cost term).
    fn byte_size(&self) -> usize;
}

macro_rules! impl_data_scalar {
    ($($t:ty),*) => {
        $(impl Data for $t {
            fn byte_size(&self) -> usize { std::mem::size_of::<$t>() }
        })*
    };
}

impl_data_scalar!(u8, i8, u16, i16, u32, i32, u64, i64, usize, isize, f32, f64, bool, char);

impl Data for String {
    fn byte_size(&self) -> usize {
        self.len()
    }
}

impl Data for () {
    fn byte_size(&self) -> usize {
        0
    }
}

impl<T: Data> Data for Option<T> {
    fn byte_size(&self) -> usize {
        1 + self.as_ref().map_or(0, |v| v.byte_size())
    }
}

impl<T: Data> Data for Vec<T> {
    fn byte_size(&self) -> usize {
        8 + self.iter().map(|v| v.byte_size()).sum::<usize>()
    }
}

impl<A: Data, B: Data> Data for (A, B) {
    fn byte_size(&self) -> usize {
        self.0.byte_size() + self.1.byte_size()
    }
}

impl<A: Data, B: Data, C: Data> Data for (A, B, C) {
    fn byte_size(&self) -> usize {
        self.0.byte_size() + self.1.byte_size() + self.2.byte_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_sizes() {
        assert_eq!(3.14f32.byte_size(), 4);
        assert_eq!(1u64.byte_size(), 8);
        assert_eq!(().byte_size(), 0);
    }

    #[test]
    fn vec_size_counts_elements() {
        let v: Vec<f32> = vec![0.0; 100];
        assert_eq!(v.byte_size(), 8 + 400);
        let nested: Vec<Vec<f64>> = vec![vec![0.0; 10]; 3];
        assert_eq!(nested.byte_size(), 8 + 3 * (8 + 80));
    }

    #[test]
    fn option_and_tuple() {
        assert_eq!(Some(1.0f64).byte_size(), 9);
        assert_eq!(None::<f64>.byte_size(), 1);
        assert_eq!((1u32, 2.0f32).byte_size(), 8);
    }
}
