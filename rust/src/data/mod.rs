pub mod dseq;
pub mod dvar;
pub mod grid;
pub mod value;
