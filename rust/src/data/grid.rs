//! Multidimensional distributed sequences: `GridN` and the Cartesian
//! grid abstraction of §4.3.
//!
//! The generic Algorithm 1 loses a factor `q²` to the sequential ∀-loop;
//! FooPar's fix is constructors for arbitrary Cartesian grids whose
//! process↔coordinate mapping is static (row-major).  A [`GridData`]
//! holds one value per grid process; [`GridData::seq_along`] yields the
//! distributed sequence over the grid *line* through the calling
//! process's coordinate varying one dimension — `xSeq`, `ySeq`, `zSeq`
//! in the paper's Scala (Alg. 2 uses `zSeq` for the DNS reduction,
//! Alg. 3 uses `xSeq`/`ySeq` for the pivot row/column broadcasts).

use crate::data::dseq::DistSeq;
use crate::data::value::Data;
use crate::comm::group::Group;
use crate::spmd::Ctx;

/// An N-dimensional Cartesian process grid (row-major rank layout).
pub struct GridN<'a> {
    ctx: &'a Ctx,
    dims: Vec<usize>,
    /// Grid-rank → world-rank map; `None` = identity (the batch default
    /// of grid process i on world rank i).  A map lets the same grid run
    /// on an arbitrary rank subset — the serving runtime places each
    /// job's grid on the subset its scheduler carved out of the pool.
    ranks: Option<Vec<usize>>,
}

impl<'a> GridN<'a> {
    /// Grid over world ranks `0 .. dims.iter().product()`.
    /// Panics if the world is too small.
    pub fn new(ctx: &'a Ctx, dims: Vec<usize>) -> Self {
        let need: usize = dims.iter().product();
        assert!(need >= 1, "grid must be non-empty");
        assert!(
            need <= ctx.world,
            "grid {:?} needs {need} ranks, world has {}",
            dims,
            ctx.world
        );
        GridN { ctx, dims, ranks: None }
    }

    /// Grid whose process `i` (row-major) lives on world rank
    /// `ranks[i]`.  `ranks` must hold at least `dims.iter().product()`
    /// distinct world ranks; extras are ignored.  Every rank — mapped or
    /// not — may construct the grid (SPMD over the subset).
    pub fn new_on(ctx: &'a Ctx, dims: Vec<usize>, ranks: &[usize]) -> Self {
        let need: usize = dims.iter().product();
        assert!(need >= 1, "grid must be non-empty");
        assert!(
            need <= ranks.len(),
            "grid {:?} needs {need} ranks, subset has {}",
            dims,
            ranks.len()
        );
        let map: Vec<usize> = ranks[..need].to_vec();
        debug_assert!(map.iter().all(|&r| r < ctx.world), "rank outside world");
        debug_assert!(
            {
                let mut s = map.clone();
                s.sort_unstable();
                s.windows(2).all(|w| w[0] != w[1])
            },
            "grid ranks must be distinct"
        );
        GridN { ctx, dims, ranks: Some(map) }
    }

    /// Cubic 3-d grid q×q×q (Alg. 2).
    pub fn cube(ctx: &'a Ctx, q: usize) -> Self {
        Self::new(ctx, vec![q, q, q])
    }

    /// Square 2-d grid q×q (Alg. 3).
    pub fn square(ctx: &'a Ctx, q: usize) -> Self {
        Self::new(ctx, vec![q, q])
    }

    /// Square 2-d grid q×q over an explicit rank subset.
    pub fn square_on(ctx: &'a Ctx, q: usize, ranks: &[usize]) -> Self {
        Self::new_on(ctx, vec![q, q], ranks)
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    pub fn ndim(&self) -> usize {
        self.dims.len()
    }

    /// Number of grid processes.
    pub fn size(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major **world** rank of `coord` (mapped through the rank
    /// subset when one is set).
    pub fn rank_of(&self, coord: &[usize]) -> usize {
        assert_eq!(coord.len(), self.dims.len());
        let mut r = 0usize;
        for (c, d) in coord.iter().zip(&self.dims) {
            debug_assert!(c < d, "coordinate {c} out of bound {d}");
            r = r * d + c;
        }
        match &self.ranks {
            Some(map) => map[r],
            None => r,
        }
    }

    /// Grid rank (row-major position) of world `rank`, if mapped.
    fn grid_rank_of(&self, rank: usize) -> Option<usize> {
        match &self.ranks {
            Some(map) => map.iter().position(|&r| r == rank),
            None => (rank < self.size()).then_some(rank),
        }
    }

    /// Coordinate of world `rank`, if it is a grid process.
    pub fn coord_of(&self, rank: usize) -> Option<Vec<usize>> {
        let mut rem = self.grid_rank_of(rank)?;
        let mut coord = vec![0; self.dims.len()];
        for i in (0..self.dims.len()).rev() {
            coord[i] = rem % self.dims[i];
            rem /= self.dims[i];
        }
        Some(coord)
    }

    /// This rank's coordinate, if it participates in the grid.
    pub fn my_coord(&self) -> Option<Vec<usize>> {
        self.coord_of(self.ctx.rank)
    }

    /// Am I a grid process?
    pub fn is_member(&self) -> bool {
        self.grid_rank_of(self.ctx.rank).is_some()
    }

    /// Distribute a value per grid process: `gen` runs only on the owner
    /// with its own coordinate (lazy SPMD, like `DistSeq::from_fn`).
    pub fn map_d<T: Data>(&self, gen: impl FnOnce(&[usize]) -> T) -> GridData<'a, T> {
        let local = self.my_coord().map(|c| gen(&c));
        GridData {
            ctx: self.ctx,
            dims: self.dims.clone(),
            ranks: self.ranks.clone(),
            local,
        }
    }

    /// World ranks of the grid line through `coord` varying dimension
    /// `dim`, ordered by that coordinate.
    pub fn line_ranks(&self, coord: &[usize], dim: usize) -> Vec<usize> {
        assert!(dim < self.dims.len());
        let mut c = coord.to_vec();
        (0..self.dims[dim])
            .map(|v| {
                c[dim] = v;
                self.rank_of(&c)
            })
            .collect()
    }
}

/// One value per grid process (the result of `GridN::map_d`).
pub struct GridData<'a, T: Data> {
    ctx: &'a Ctx,
    dims: Vec<usize>,
    ranks: Option<Vec<usize>>,
    local: Option<T>,
}

impl<'a, T: Data> GridData<'a, T> {
    fn grid(&self) -> GridN<'a> {
        GridN {
            ctx: self.ctx,
            dims: self.dims.clone(),
            ranks: self.ranks.clone(),
        }
    }

    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// My coordinate, if a grid member.
    pub fn my_coord(&self) -> Option<Vec<usize>> {
        self.grid().coord_of(self.ctx.rank)
    }

    pub fn local(&self) -> Option<&T> {
        self.local.as_ref()
    }

    pub fn into_local(self) -> Option<T> {
        self.local
    }

    /// Transform the local value — non-communicating (Table 1's mapD).
    pub fn map_d<U: Data>(self, f: impl FnOnce(T) -> U) -> GridData<'a, U> {
        GridData {
            ctx: self.ctx,
            dims: self.dims,
            ranks: self.ranks,
            local: self.local.map(f),
        }
    }

    /// Like `map_d` with the coordinate visible to the lambda.
    pub fn map_d_at<U: Data>(self, f: impl FnOnce(&[usize], T) -> U) -> GridData<'a, U> {
        let coord = self.my_coord();
        GridData {
            ctx: self.ctx,
            dims: self.dims,
            ranks: self.ranks,
            local: self.local.map(|v| f(&coord.expect("member without coord"), v)),
        }
    }

    /// Elementwise combine with another grid of the same shape
    /// (Table 1's zipWithD — non-communicating).
    pub fn zip_with_d<U: Data, V: Data>(
        self,
        other: GridData<'a, U>,
        f: impl FnOnce(T, U) -> V,
    ) -> GridData<'a, V> {
        assert_eq!(self.dims, other.dims, "zipWithD requires equal grid shapes");
        debug_assert_eq!(self.ranks, other.ranks, "zipWithD requires equal rank maps");
        let local = match (self.local, other.local) {
            (Some(a), Some(b)) => Some(f(a, b)),
            (None, None) => None,
            _ => unreachable!("grid membership mismatch"),
        };
        GridData { ctx: self.ctx, dims: self.dims, ranks: self.ranks, local }
    }

    /// The distributed sequence over the grid line through my coordinate
    /// varying dimension `dim` (paper: `xSeq`/`ySeq`/`zSeq` for dims
    /// 0/1/2).  Requires `T: Clone`: the line's sequence borrows the
    /// grid value.  Non-members return an inert sequence.
    ///
    /// The returned sequence supports the whole `DistSeq` API, including
    /// the non-blocking forms: `data.x_seq().apply_start(k)` broadcasts
    /// element `k` along every column while the rank keeps computing
    /// (Alg. 3's pivot row/column with comm–comp overlap), and the
    /// pipelined DNS variant chunks `z_seq` reductions the same way.
    pub fn seq_along(&self, dim: usize) -> DistSeq<'a, T>
    where
        T: Clone,
    {
        match self.my_coord() {
            Some(coord) => {
                let ranks = self.grid().line_ranks(&coord, dim);
                let group = Group::new(self.ctx, ranks);
                DistSeq::from_parts(group, self.local.clone())
            }
            None => {
                // Non-grid ranks build a trivial singleton group over
                // themselves so the chain stays inert but well-formed.
                let group = Group::new(self.ctx, vec![self.ctx.rank]);
                DistSeq::from_parts(group, None)
            }
        }
    }

    /// `xSeq` — vary dimension 0.
    pub fn x_seq(&self) -> DistSeq<'a, T>
    where
        T: Clone,
    {
        self.seq_along(0)
    }

    /// `ySeq` — vary dimension 1.
    pub fn y_seq(&self) -> DistSeq<'a, T>
    where
        T: Clone,
    {
        self.seq_along(1)
    }

    /// `zSeq` — vary dimension 2 (the DNS reduction axis in Alg. 2).
    pub fn z_seq(&self) -> DistSeq<'a, T>
    where
        T: Clone,
    {
        self.seq_along(2)
    }

    /// Consuming variant of [`Self::seq_along`] (avoids the `Clone`).
    pub fn into_seq_along(self, dim: usize) -> DistSeq<'a, T> {
        match self.my_coord() {
            Some(coord) => {
                let ranks = self.grid().line_ranks(&coord, dim);
                let group = Group::new(self.ctx, ranks);
                DistSeq::from_parts(group, self.local)
            }
            None => {
                let group = Group::new(self.ctx, vec![self.ctx.rank]);
                DistSeq::from_parts(group, None)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;

    fn fixed() -> BackendProfile {
        BackendProfile::openmpi_fixed()
    }
    fn free() -> CostParams {
        CostParams::free()
    }

    #[test]
    fn rank_coord_roundtrip() {
        run(24, fixed(), free(), |ctx| {
            let g = GridN::new(ctx, vec![2, 3, 4]);
            for r in 0..g.size() {
                let c = g.coord_of(r).unwrap();
                assert_eq!(g.rank_of(&c), r);
                assert!(c[0] < 2 && c[1] < 3 && c[2] < 4);
            }
            assert_eq!(g.coord_of(24), None);
        });
    }

    #[test]
    fn row_major_layout() {
        run(8, fixed(), free(), |ctx| {
            let g = GridN::cube(ctx, 2);
            assert_eq!(g.rank_of(&[0, 0, 0]), 0);
            assert_eq!(g.rank_of(&[0, 0, 1]), 1);
            assert_eq!(g.rank_of(&[0, 1, 0]), 2);
            assert_eq!(g.rank_of(&[1, 0, 0]), 4);
        });
    }

    #[test]
    fn map_d_runs_only_on_members() {
        let res = run(10, fixed(), free(), |ctx| {
            let g = GridN::square(ctx, 3); // 9 processes, world 10
            g.map_d(|c| (c[0] * 10 + c[1]) as u64).into_local()
        });
        for (rank, v) in res.results.iter().enumerate() {
            if rank < 9 {
                let (i, j) = (rank / 3, rank % 3);
                assert_eq!(*v, Some((i * 10 + j) as u64));
            } else {
                assert_eq!(*v, None);
            }
        }
    }

    #[test]
    fn line_ranks_along_each_dim() {
        run(8, fixed(), free(), |ctx| {
            let g = GridN::cube(ctx, 2);
            // line through (1,0,1) varying dim 0 (x): (0,0,1), (1,0,1)
            assert_eq!(g.line_ranks(&[1, 0, 1], 0), vec![1, 5]);
            // varying dim 2 (z): (1,0,0), (1,0,1)
            assert_eq!(g.line_ranks(&[1, 0, 1], 2), vec![4, 5]);
        });
    }

    #[test]
    fn z_seq_reduces_to_z0_plane() {
        // 2x2x2 grid: value = 100*i + 10*j + k; reduce along z sums the
        // two k-values onto the k=0 member.
        let res = run(8, fixed(), free(), |ctx| {
            let g = GridN::cube(ctx, 2);
            let data = g.map_d(|c| (100 * c[0] + 10 * c[1] + c[2]) as i64);
            data.into_seq_along(2).reduce_d(|a, b| a + b)
        });
        for rank in 0..8 {
            let c = [(rank >> 2) & 1, (rank >> 1) & 1, rank & 1];
            let expect = if c[2] == 0 {
                Some((100 * c[0] + 10 * c[1]) as i64 * 2 + 1)
            } else {
                None
            };
            assert_eq!(res.results[rank], expect, "rank {rank}");
        }
    }

    #[test]
    fn x_seq_apply_broadcasts_along_column() {
        // 3x3 grid: apply(1) on xSeq gives everyone in column j the value
        // of process (1, j).
        let res = run(9, fixed(), free(), |ctx| {
            let g = GridN::square(ctx, 3);
            let data = g.map_d(|c| (10 * c[0] + c[1]) as u64);
            data.x_seq().apply(1)
        });
        for rank in 0..9 {
            let j = rank % 3;
            assert_eq!(res.results[rank], Some((10 + j) as u64), "rank {rank}");
        }
    }

    #[test]
    fn y_seq_varies_second_dim() {
        let res = run(9, fixed(), free(), |ctx| {
            let g = GridN::square(ctx, 3);
            let data = g.map_d(|c| (10 * c[0] + c[1]) as u64);
            data.y_seq().all_gather_d()
        });
        // row i gathers [10i, 10i+1, 10i+2]
        for rank in 0..9 {
            let i = rank / 3;
            let expect: Vec<u64> = (0..3).map(|j| (10 * i + j) as u64).collect();
            assert_eq!(res.results[rank], Some(expect), "rank {rank}");
        }
    }

    #[test]
    fn zip_with_d_on_grids() {
        let res = run(4, fixed(), free(), |ctx| {
            let g = GridN::square(ctx, 2);
            let a = g.map_d(|c| c[0] as i64);
            let b = g.map_d(|c| c[1] as i64);
            a.zip_with_d(b, |x, y| 10 * x + y).into_local()
        });
        assert_eq!(res.results, vec![Some(0), Some(1), Some(10), Some(11)]);
    }

    #[test]
    fn non_member_chain_is_inert() {
        let res = run(5, fixed(), free(), |ctx| {
            let g = GridN::square(ctx, 2);
            let data = g.map_d(|c| (c[0] + c[1]) as i64);
            // rank 4 is not in the 2x2 grid: whole chain no-ops
            data.x_seq().map_d(|v| v * 2).reduce_d(|a, b| a + b)
        });
        assert_eq!(res.results[4], None);
        assert_eq!(res.metrics[4].msgs_sent, 0);
    }

    #[test]
    fn subset_grid_runs_on_mapped_ranks() {
        // 2x2 grid placed on world ranks {4, 2, 5, 1} of a world of 6:
        // same collectives, only the placement differs.
        let res = run(6, fixed(), free(), |ctx| {
            let map = [4usize, 2, 5, 1];
            let g = GridN::square_on(ctx, 2, &map);
            assert_eq!(g.is_member(), map.contains(&ctx.rank));
            assert_eq!(g.rank_of(&[0, 1]), 2);
            assert_eq!(g.line_ranks(&[1, 0], 1), vec![5, 1]);
            let data = g.map_d(|c| (10 * c[0] + c[1]) as u64);
            data.y_seq().all_gather_d()
        });
        // grid row 0 = world {4, 2}, row 1 = world {5, 1}
        assert_eq!(res.results[4], Some(vec![0, 1]));
        assert_eq!(res.results[2], Some(vec![0, 1]));
        assert_eq!(res.results[5], Some(vec![10, 11]));
        assert_eq!(res.results[1], Some(vec![10, 11]));
        assert_eq!(res.results[0], None);
        assert_eq!(res.results[3], None);
        assert_eq!(res.metrics[0].msgs_sent, 0, "non-members stay silent");
    }

    #[test]
    fn disjoint_subset_grids_run_concurrently() {
        // Two 2x2 grids on disjoint subsets of one world-8, each inside
        // its own tag scope (the serving configuration): reductions on
        // one must not observe the other's traffic.
        let res = run(8, fixed(), free(), |ctx| {
            let (scope, map): (u64, [usize; 4]) = if ctx.rank < 4 {
                (0xA11CE, [0, 1, 2, 3])
            } else {
                (0xB0B, [4, 5, 6, 7])
            };
            ctx.with_tag_scope(scope, || {
                let g = GridN::square_on(ctx, 2, &map);
                let data = g.map_d(|c| (100 * scope + 10 * c[0] as u64 + c[1] as u64) as i64);
                data.into_seq_along(1).reduce_d(|a, b| a + b)
            })
        });
        // row roots: grid coords (i, 0) → world map[2i]
        let base_a = (0xA11CEu64 * 100) as i64;
        let base_b = (0xB0Bu64 * 100) as i64;
        assert_eq!(res.results[0], Some(2 * base_a + 1));
        assert_eq!(res.results[2], Some(2 * base_a + 20 + 1));
        assert_eq!(res.results[4], Some(2 * base_b + 1));
        assert_eq!(res.results[6], Some(2 * base_b + 20 + 1));
    }

    #[test]
    #[should_panic(expected = "needs")]
    fn grid_larger_than_world_panics() {
        run(4, fixed(), free(), |ctx| {
            let _ = GridN::cube(ctx, 2); // needs 8 > 4
        });
    }
}
