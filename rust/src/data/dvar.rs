//! Distributed singletons — "distributed variables" (§3.3).
//!
//! The paper: *"In its current state, FooPar supports distributed
//! singletons (aka. distributed variables), distributed sequences and
//! distributed multidimensional sequences."*
//!
//! A `DistVar<T>` is a value owned by exactly one rank of a group, with
//! SPMD-safe accessors: `read()` broadcasts it to every member
//! (Θ(log p (t_s + t_w m))), `set(...)` replaces it on the owner,
//! `move_to(...)` migrates ownership (Θ(t_s + t_w m)).

use std::marker::PhantomData;

use crate::comm::group::Group;
use crate::comm::message::Msg;
use crate::comm::nb::GroupOp;
use crate::comm::wire::WireData;
use crate::data::value::Data;
use crate::spmd::Ctx;

/// A single value owned by one member of a group.
pub struct DistVar<'a, T: Data> {
    group: Group<'a>,
    /// Group rank of the current owner.
    owner: usize,
    /// The value, present only on the owner.
    local: Option<T>,
}

impl<'a, T: Data> DistVar<'a, T> {
    /// Create over the whole world, owned by group rank `owner`.
    /// `init` runs only on the owner (lazy, like `DistSeq::from_fn`).
    pub fn new(ctx: &'a Ctx, owner: usize, init: impl FnOnce() -> T) -> Self {
        Self::over(ctx, (0..ctx.world).collect(), owner, init)
    }

    /// Create over an explicit group.
    pub fn over(
        ctx: &'a Ctx,
        ranks: Vec<usize>,
        owner: usize,
        init: impl FnOnce() -> T,
    ) -> Self {
        assert!(owner < ranks.len(), "owner outside group");
        let group = Group::new(ctx, ranks);
        let local = (group.try_index() == Some(owner)).then(init);
        DistVar { group, owner, local }
    }

    /// Group rank of the owner.
    pub fn owner(&self) -> usize {
        self.owner
    }

    /// Am I the owner?
    pub fn is_owner(&self) -> bool {
        self.group.try_index() == Some(self.owner)
    }

    /// Borrow the value if I own it.
    pub fn local(&self) -> Option<&T> {
        self.local.as_ref()
    }

    /// Broadcast the value to every group member —
    /// Θ(log p (t_s + t_w m)).  Non-members get `None`.
    pub fn read(&self) -> Option<T>
    where
        T: WireData + Clone,
    {
        if !self.group.is_member() {
            return None;
        }
        Some(self.group.bcast(self.owner, self.local.clone()))
    }

    /// Non-blocking [`Self::read`]: the owner's fan-out starts
    /// immediately; every member claims the value at
    /// [`PendingRead::wait`], with the broadcast overlapping whatever
    /// the rank computes in between (`max(T_comm, T_comp)` on the
    /// clock — see [`crate::comm::nb`]).  Non-members get an inert
    /// handle whose `wait()` is `None`.
    pub fn read_start(&self) -> PendingRead<'_, T>
    where
        T: WireData + Clone,
    {
        let raw = self.group.is_member().then(|| {
            self.group.ctx().metrics.on_collective();
            let v = self.local.clone().map(Msg::cloneable);
            self.group.ctx().collectives().bcast_start(&self.group, self.owner, v)
        });
        PendingRead { group: &self.group, raw, _t: PhantomData }
    }

    /// Replace the value; `f` runs only on the owner.  Collective-free.
    pub fn set(&mut self, f: impl FnOnce(Option<T>) -> T) {
        if self.is_owner() {
            let old = self.local.take();
            self.local = Some(f(old));
        }
    }

    /// Migrate ownership to group rank `new_owner` — one point-to-point
    /// message, Θ(t_s + t_w m).
    pub fn move_to(&mut self, new_owner: usize)
    where
        T: WireData,
    {
        assert!(new_owner < self.group.size());
        if new_owner == self.owner {
            return;
        }
        if self.group.is_member() {
            let tag = self.group.next_tag();
            let me = self.group.index();
            if me == self.owner {
                self.group
                    .send_to(new_owner, tag, self.local.take().expect("owner without value"));
            } else if me == new_owner {
                self.local = Some(self.group.recv_from(self.owner, tag));
            }
        }
        self.owner = new_owner;
    }
}

/// A distributed-variable read in flight: the result of
/// [`DistVar::read_start`].  `wait()` yields `Some(value)` on every
/// member, `None` on non-members.
#[must_use = "a pending read must be wait()ed by every member"]
pub struct PendingRead<'g, T: WireData> {
    group: &'g Group<'g>,
    raw: Option<GroupOp<'g>>,
    _t: PhantomData<fn() -> T>,
}

impl<'g, T: WireData> PendingRead<'g, T> {
    /// Advisory: is the broadcast value already buffered?
    pub fn test(&self) -> bool {
        self.raw.as_ref().map_or(true, |r| r.test(self.group))
    }

    /// Claim the value (merges the overlap clocks).
    pub fn wait(self) -> Option<T> {
        let PendingRead { group, raw, .. } = self;
        raw.map(|r| r.wait(group).one().downcast::<T>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;

    fn world(p: usize, f: impl Fn(&Ctx) -> Option<u64> + Sync) -> Vec<Option<u64>> {
        run(p, BackendProfile::openmpi_fixed(), CostParams::free(), f).results
    }

    #[test]
    fn init_only_on_owner() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        world(6, |ctx| {
            let v = DistVar::new(ctx, 2, || {
                CALLS.fetch_add(1, Ordering::SeqCst);
                77u64
            });
            v.local().copied()
        });
        assert_eq!(CALLS.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn read_broadcasts_to_all() {
        let res = world(5, |ctx| {
            let v = DistVar::new(ctx, 3, || 42u64);
            v.read()
        });
        assert!(res.iter().all(|r| *r == Some(42)));
    }

    #[test]
    fn set_then_read() {
        let res = world(4, |ctx| {
            let mut v = DistVar::new(ctx, 0, || 1u64);
            v.set(|old| old.unwrap() + 10);
            v.read()
        });
        assert!(res.iter().all(|r| *r == Some(11)));
    }

    #[test]
    fn move_to_transfers_ownership() {
        let res = world(4, |ctx| {
            let mut v = DistVar::new(ctx, 0, || ctx.rank as u64 + 100);
            v.move_to(2);
            assert_eq!(v.is_owner(), ctx.rank == 2);
            // the moved value is rank 0's (it owned at init)
            v.read()
        });
        assert!(res.iter().all(|r| *r == Some(100)));
    }

    #[test]
    fn read_start_broadcasts_with_overlap() {
        use crate::comm::cost::CostParams as CP;
        let res = run(
            4,
            BackendProfile::openmpi_fixed(),
            CP::new(1.0, 0.0),
            |ctx| {
                let v = DistVar::new(ctx, 1, || 77u64);
                let h = v.read_start();
                ctx.advance_compute(4.0, 0.0);
                (h.wait(), ctx.now())
            },
        );
        for (r, t) in &res.results {
            assert_eq!(*r, Some(77));
            // the 2-round binomial bcast hides entirely under 4s compute
            assert!((t - 4.0).abs() < 1e-12, "clock {t}");
        }
    }

    #[test]
    fn over_subgroup_outsiders_inert() {
        let res = world(5, |ctx| {
            let v = DistVar::over(ctx, vec![1, 3], 1, || 9u64);
            v.read()
        });
        assert_eq!(res[1], Some(9));
        assert_eq!(res[3], Some(9));
        assert_eq!(res[0], None);
        assert_eq!(res[4], None);
    }
}
