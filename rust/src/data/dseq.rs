//! Distributed sequences — FooPar's central data structure (§3.3).
//!
//! A `DistSeq<T>` is a sequence whose *i*-th element lives on the *i*-th
//! member of its communication group (static process↔data mapping).  All
//! inter-process communication happens through the group operations of
//! Table 1 — `mapD`, `zipWithD`, `reduceD`, `shiftD`, `allToAllD`,
//! `allGatherD`, `apply` — so user code contains no message passing at
//! all, which is how FooPar "practically eliminates" deadlocks and races.
//!
//! SPMD semantics: *every* rank constructs the sequence (cheaply — the
//! generator runs only for the element the rank owns, the lazy-proxy
//! trick of Fig. 2/3), and *every group member* must call each subsequent
//! group operation.  Non-members hold no element and no-op through the
//! entire chain, returning `None` where a value would be produced.
//!
//! | op | communication | `T_P` (Table 1) | overlapped `T_P` (`*_start`) |
//! |---|---|---|---|
//! | `map_d` | none | Θ(T_λ(m)) | — |
//! | `zip_with_d` | none | Θ(T_λ(m)) | — |
//! | `reduce_d` / `reduce_d_start` | tree/linear reduce | Θ(log p (t_s + t_w m + T_λ(m))) | max(T_comp, Θ(log p (t_s + t_w m + T_λ(m)))) |
//! | `shift_d` / `shift_d_start` | cyclic point-to-point | Θ(t_s + t_w m) | max(T_comp, Θ(t_s + t_w m)) |
//! | `all_to_all_d` | pairwise exchange | Θ((t_s + t_w m)(p−1)) | — |
//! | `all_gather_d` | ring | Θ((t_s + t_w m)(p−1)) | — |
//! | `apply` / `apply_start` | binomial bcast | Θ(log p (t_s + t_w m)) | max(T_comp, Θ(log p (t_s + t_w m))) |
//!
//! **Non-blocking forms.**  The `*_start` variants return a handle
//! (`PendingSeq` / `PendingReduce` / `PendingApply`) with `wait()` and
//! `test()`; the operation's communication runs on a forked comm
//! timeline while the rank computes, and `wait()` merges with the
//! **overlap-aware clock rule**: across a start→wait window the rank's
//! clock advances by `max(T_comm, T_comp)` instead of the sum (the
//! "overlapped `T_P`" column — `T_comp` is whatever the rank computed in
//! between).  See [`crate::comm::nb`].  Every member must `wait()` every
//! handle, in start order — the same SPMD discipline as the blocking
//! operations.
//!
//! **Ownership convention.**  Every group operation **consumes** the
//! sequence (`self` by value): chains read left-to-right, transformed
//! sequences carry their group forward (`map_d`, `zip_with_d`,
//! `shift_d`, `scan_d`, `all_to_all_d` return the next `DistSeq`;
//! `*_start` forms return the pending handle that yields it), and
//! terminal operations (`reduce_d`, `all_gather_d`, `gather_d`, `apply`)
//! return plain values.  To keep using a sequence after a terminal
//! operation, keep your own clone of the element (`local()` borrows it)
//! — no group operation secretly clones or borrows.

use std::marker::PhantomData;

use crate::comm::algorithms::OwnedReduceFn;
use crate::comm::group::Group;
use crate::comm::message::Msg;
use crate::comm::nb::GroupOp;
use crate::comm::wire::WireData;
use crate::data::value::Data;
use crate::spmd::Ctx;

/// A distributed sequence: element *i* lives on group member *i*.
pub struct DistSeq<'a, T: Data> {
    group: Group<'a>,
    local: Option<T>,
}

impl<'a, T: Data> DistSeq<'a, T> {
    /// Build a sequence of `ranks.len()` elements, element *i* owned by
    /// world rank `ranks[i]`.  `gen` runs **only** on the owning rank and
    /// only for its own index — every rank "generates the sequence" in
    /// SPMD terms, but lazily (no space/time overhead, §3.2).
    pub fn from_fn(ctx: &'a Ctx, ranks: Vec<usize>, gen: impl FnOnce(usize) -> T) -> Self {
        let group = Group::new(ctx, ranks);
        let local = group.try_index().map(gen);
        DistSeq { group, local }
    }

    /// Sequence over world ranks `0..len` (the `0 to n` idiom of §3.2).
    pub fn range(ctx: &'a Ctx, len: usize, gen: impl FnOnce(usize) -> T) -> Self {
        Self::from_fn(ctx, (0..len).collect(), gen)
    }

    /// Wrap an existing group + local element (used by [`crate::data::grid`]).
    pub(crate) fn from_parts(group: Group<'a>, local: Option<T>) -> Self {
        DistSeq { group, local }
    }

    /// Number of elements (== group size).
    pub fn len(&self) -> usize {
        self.group.size()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Does this rank own an element?
    pub fn is_member(&self) -> bool {
        self.group.is_member()
    }

    /// My element index (== my group rank), if member.
    pub fn index(&self) -> Option<usize> {
        self.group.try_index()
    }

    /// Borrow my element, if member.
    pub fn local(&self) -> Option<&T> {
        self.local.as_ref()
    }

    /// Take my element out (consumes the sequence).
    pub fn into_local(self) -> Option<T> {
        self.local
    }

    /// The underlying communication group.
    pub fn group(&self) -> &Group<'a> {
        &self.group
    }

    // ------------------------------------------------ non-communicating

    /// Transform each element in place — non-communicating, Θ(T_λ(m)).
    /// The group "follows" the result (§3.3: chained functional style).
    pub fn map_d<U: Data>(self, f: impl FnOnce(T) -> U) -> DistSeq<'a, U> {
        DistSeq { local: self.local.map(f), group: self.group }
    }

    /// Like [`Self::map_d`] but the lambda also sees the element index.
    pub fn map_d_indexed<U: Data>(self, f: impl FnOnce(usize, T) -> U) -> DistSeq<'a, U> {
        let idx = self.group.try_index();
        DistSeq {
            local: self.local.map(|v| f(idx.expect("member without index"), v)),
            group: self.group,
        }
    }

    /// Combine elementwise with `other` (same group required) —
    /// non-communicating, Θ(T_λ(m)).
    pub fn zip_with_d<U: Data, V: Data>(
        self,
        other: DistSeq<'a, U>,
        f: impl FnOnce(T, U) -> V,
    ) -> DistSeq<'a, V> {
        assert_eq!(
            self.group.ranks(),
            other.group.ranks(),
            "zipWithD requires sequences over the same group"
        );
        let local = match (self.local, other.local) {
            (Some(a), Some(b)) => Some(f(a, b)),
            (None, None) => None,
            _ => unreachable!("member/non-member mismatch between zipped sequences"),
        };
        DistSeq { local, group: self.group }
    }

    // ---------------------------------------------------- communicating

    /// Reduce the sequence to its first member (group rank 0) with the
    /// associative operator `op` — Θ(log p (t_s + t_w m + T_λ(m))) on
    /// tree backends, Θ(p·…) on the naive ones (§6).
    ///
    /// Returns `Some(result)` on the root member, `None` elsewhere.
    pub fn reduce_d(self, op: impl Fn(T, T) -> T) -> Option<T>
    where
        T: WireData,
    {
        let Some(local) = self.local else { return None };
        self.group.reduce(0, local, op)
    }

    /// Reduce with the result broadcast back to all members.
    pub fn all_reduce_d(self, op: impl Fn(T, T) -> T) -> Option<T>
    where
        T: WireData + Clone,
    {
        let local = self.local?;
        Some(self.group.allreduce(local, op))
    }

    /// Cyclic shift by `delta` — Θ(t_s + t_w m).
    pub fn shift_d(self, delta: isize) -> DistSeq<'a, T>
    where
        T: WireData,
    {
        let local = self.local.map(|v| self.group.shift(delta, v));
        DistSeq { local, group: self.group }
    }

    /// Every member obtains the whole sequence — Θ((t_s + t_w m)(p−1)).
    pub fn all_gather_d(self) -> Option<Vec<T>>
    where
        T: WireData + Clone,
    {
        let local = self.local?;
        Some(self.group.allgather(local))
    }

    /// Inclusive prefix scan: member i ends up with
    /// `v_0 ⊕ … ⊕ v_i` — Θ(log p (t_s + t_w m + T_λ(m))).
    /// (Extension beyond Table 1; the natural companion of `reduce_d`.)
    pub fn scan_d(self, op: impl Fn(T, T) -> T) -> DistSeq<'a, T>
    where
        T: WireData + Clone,
    {
        let local = self.local.map(|v| self.group.scan(v, op));
        DistSeq { local, group: self.group }
    }

    /// Gather the whole sequence at its first member (group rank 0) —
    /// Θ((t_s + t_w m)(p−1)) linear gather.
    pub fn gather_d(self) -> Option<Vec<T>>
    where
        T: WireData,
    {
        let local = self.local?;
        self.group.gather(0, local)
    }

    /// Every member obtains element `i` (one-to-all broadcast from its
    /// owner) — Θ(log p (t_s + t_w m)).  Table 1's `apply(i)`.
    pub fn apply(self, i: usize) -> Option<T>
    where
        T: WireData + Clone,
    {
        // Inert (non-member) chains no-op.
        let local = self.local?;
        let me = self.group.index();
        let v = (me == i).then_some(local);
        Some(self.group.bcast(i, v))
    }

    // ------------------------------------- non-blocking (handle) forms

    /// Non-blocking [`Self::shift_d`]: the outgoing element is posted
    /// immediately; compute until [`PendingSeq::wait`] claims the
    /// shifted sequence.  Across the window the clock advances by
    /// `max(T_comm, T_comp)` — the prefetch primitive of the pipelined
    /// Cannon variant.
    pub fn shift_d_start(self, delta: isize) -> PendingSeq<'a, T>
    where
        T: WireData,
    {
        let DistSeq { group, local } = self;
        let raw = local.map(|v| {
            group.ctx().metrics.on_collective();
            group.ctx().collectives().shift_start(&group, delta, Msg::new(v))
        });
        PendingSeq { group, raw, _t: PhantomData }
    }

    /// Non-blocking [`Self::reduce_d`]: contributions are sent
    /// immediately (a pure leaf completes at start); receive/fold rounds
    /// run at [`PendingReduce::wait`] on the comm timeline — the chunked
    /// z-reduction primitive of the pipelined DNS variant.
    pub fn reduce_d_start<'f>(self, op: impl Fn(T, T) -> T + 'f) -> PendingReduce<'a, 'f, T>
    where
        T: WireData,
    {
        let DistSeq { group, local } = self;
        let raw = local.map(|v| {
            group.ctx().metrics.on_collective();
            let erased: OwnedReduceFn<'f> =
                Box::new(move |a: Msg, b: Msg| Msg::new(op(a.downcast::<T>(), b.downcast::<T>())));
            group
                .ctx()
                .collectives()
                .reduce_start(&group, 0, Msg::new(v), erased)
        });
        PendingReduce { group, raw, _t: PhantomData }
    }

    /// Non-blocking [`Self::apply`]: the owner's fan-out starts
    /// immediately; every member claims the broadcast element at
    /// [`PendingApply::wait`].  This is the overlap form of the
    /// `seq_along`/`x_seq`/`y_seq` line broadcasts (Alg. 3's pivot row
    /// and column).
    pub fn apply_start(self, i: usize) -> PendingApply<'a, T>
    where
        T: WireData + Clone,
    {
        let DistSeq { group, local } = self;
        let raw = local.map(|v| {
            group.ctx().metrics.on_collective();
            let me = group.index();
            let value = (me == i).then(|| Msg::cloneable(v));
            group.ctx().collectives().bcast_start(&group, i, value)
        });
        PendingApply { group, raw, _t: PhantomData }
    }
}

impl<'a, T: WireData> DistSeq<'a, Vec<T>> {
    /// Personalized all-to-all (Table 1's `allToAllD`): member *i*'s j-th
    /// sub-element is delivered to member *j*; the result on member *i*
    /// is the vector of everyone's i-th sub-elements.
    pub fn all_to_all_d(self) -> DistSeq<'a, Vec<T>> {
        let local = self.local.map(|v| self.group.alltoall(v));
        DistSeq { local, group: self.group }
    }
}

// ------------------------------------------------------ pending handles

/// A [`DistSeq`] in flight: the result of [`DistSeq::shift_d_start`].
/// Owns the group; non-members hold an inert (always-ready) handle.
#[must_use = "a pending sequence must be wait()ed by every member"]
pub struct PendingSeq<'a, T: WireData> {
    group: Group<'a>,
    raw: Option<GroupOp<'static>>,
    _t: PhantomData<fn() -> T>,
}

impl<'a, T: WireData> PendingSeq<'a, T> {
    /// Advisory: is the incoming element already buffered?
    pub fn test(&self) -> bool {
        self.raw.as_ref().map_or(true, |r| r.test(&self.group))
    }

    /// Claim the shifted sequence (merges the overlap clocks).
    pub fn wait(self) -> DistSeq<'a, T> {
        let PendingSeq { group, raw, .. } = self;
        let local = raw.map(|r| r.wait(&group).one().downcast::<T>());
        DistSeq::from_parts(group, local)
    }

    /// `zipWithD` over the pending value: wait, then combine elementwise
    /// with `other` — lets a chain like
    /// `a.shift_d_start(-1) … zip_with_d(b, f)` read exactly like its
    /// blocking counterpart while the shift overlapped whatever ran in
    /// between.
    pub fn zip_with_d<U: Data, V: Data>(
        self,
        other: DistSeq<'a, U>,
        f: impl FnOnce(T, U) -> V,
    ) -> DistSeq<'a, V> {
        self.wait().zip_with_d(other, f)
    }
}

/// A reduction in flight: the result of [`DistSeq::reduce_d_start`].
/// `wait()` yields `Some(folded)` on the first member, `None` elsewhere.
#[must_use = "a pending reduction must be wait()ed by every member"]
pub struct PendingReduce<'a, 'f, T: WireData> {
    group: Group<'a>,
    raw: Option<GroupOp<'f>>,
    _t: PhantomData<fn() -> T>,
}

impl<'a, 'f, T: WireData> PendingReduce<'a, 'f, T> {
    /// Advisory: is the first incoming contribution already buffered?
    pub fn test(&self) -> bool {
        self.raw.as_ref().map_or(true, |r| r.test(&self.group))
    }

    /// Claim the reduction result (merges the overlap clocks).
    pub fn wait(self) -> Option<T> {
        let PendingReduce { group, raw, .. } = self;
        raw.and_then(|r| r.wait(&group).maybe_one())
            .map(|m| m.downcast::<T>())
    }
}

/// An element broadcast in flight: the result of
/// [`DistSeq::apply_start`].  `wait()` yields `Some(element_i)` on every
/// member, `None` on non-members.
#[must_use = "a pending broadcast must be wait()ed by every member"]
pub struct PendingApply<'a, T: WireData> {
    group: Group<'a>,
    raw: Option<GroupOp<'static>>,
    _t: PhantomData<fn() -> T>,
}

impl<'a, T: WireData> PendingApply<'a, T> {
    /// Advisory: is the broadcast element already buffered?
    pub fn test(&self) -> bool {
        self.raw.as_ref().map_or(true, |r| r.test(&self.group))
    }

    /// Claim the broadcast element (merges the overlap clocks).
    pub fn wait(self) -> Option<T> {
        let PendingApply { group, raw, .. } = self;
        raw.map(|r| r.wait(&group).one().downcast::<T>())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;

    fn fixed() -> BackendProfile {
        BackendProfile::openmpi_fixed()
    }
    fn free() -> CostParams {
        CostParams::free()
    }

    #[test]
    fn popcount_example_from_paper() {
        // §3.2: seq = 0 until worldSize-2; counts = seq mapD ones
        fn ones(i: usize) -> u32 {
            (i as u32).count_ones()
        }
        let p = 8;
        let res = run(p, fixed(), free(), |ctx| {
            let seq = DistSeq::range(ctx, ctx.world - 2, |i| i);
            seq.map_d(|i| ones(i)).into_local()
        });
        for (rank, r) in res.results.iter().enumerate() {
            if rank < p - 2 {
                assert_eq!(*r, Some(ones(rank)));
            } else {
                assert_eq!(*r, None); // last two ranks hold no element
            }
        }
    }

    #[test]
    fn generator_runs_only_on_owner() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        run(6, fixed(), free(), |ctx| {
            let _ = DistSeq::range(ctx, 4, |i| {
                CALLS.fetch_add(1, Ordering::SeqCst);
                i as u64
            });
        });
        // only the 4 owning ranks ran the generator (lazy SPMD, Fig. 2)
        assert_eq!(CALLS.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn map_then_reduce() {
        let res = run(5, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 5, |i| i as i64)
                .map_d(|v| v * v)
                .reduce_d(|a, b| a + b)
        });
        assert_eq!(res.results[0], Some(0 + 1 + 4 + 9 + 16));
        assert!(res.results[1..].iter().all(Option::is_none));
    }

    #[test]
    fn zip_with_d_combines_pairwise() {
        let res = run(4, fixed(), free(), |ctx| {
            let a = DistSeq::range(ctx, 4, |i| i as i64);
            let b = DistSeq::range(ctx, 4, |i| 10 * i as i64);
            a.zip_with_d(b, |x, y| x + y).reduce_d(|x, y| x + y)
        });
        assert_eq!(res.results[0], Some(0 + 11 + 22 + 33));
    }

    #[test]
    fn shift_d_rotates_elements() {
        let res = run(4, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 4, |i| i as i64).shift_d(1).into_local()
        });
        assert_eq!(
            res.results,
            vec![Some(3), Some(0), Some(1), Some(2)]
        );
    }

    #[test]
    fn all_gather_d_everywhere() {
        let res = run(3, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 3, |i| i as u64 * 7).all_gather_d()
        });
        for r in &res.results {
            assert_eq!(*r, Some(vec![0, 7, 14]));
        }
    }

    #[test]
    fn apply_broadcasts_ith_element() {
        let res = run(6, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 6, |i| format!("e{i}")).apply(4)
        });
        assert!(res.results.iter().all(|r| r.as_deref() == Some("e4")));
    }

    #[test]
    fn all_to_all_transposes() {
        let p = 4;
        let res = run(p, fixed(), free(), |ctx| {
            DistSeq::range(ctx, p, |i| (0..p).map(|j| (i * 10 + j) as u64).collect::<Vec<_>>())
                .all_to_all_d()
                .into_local()
        });
        for (me, r) in res.results.iter().enumerate() {
            let expect: Vec<u64> = (0..p).map(|i| (i * 10 + me) as u64).collect();
            assert_eq!(r.as_ref(), Some(&expect));
        }
    }

    #[test]
    fn all_reduce_everywhere() {
        let res = run(4, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 4, |i| i as i64 + 1).all_reduce_d(|a, b| a * b)
        });
        assert!(res.results.iter().all(|r| *r == Some(24)));
    }

    #[test]
    fn subsequence_on_subset_of_ranks() {
        // sequence over ranks {1, 3}: others no-op through the chain
        let res = run(4, fixed(), free(), |ctx| {
            DistSeq::from_fn(ctx, vec![1, 3], |i| (i as i64 + 1) * 100)
                .map_d(|v| v + 1)
                .reduce_d(|a, b| a + b)
        });
        assert_eq!(res.results, vec![None, Some(302), None, None]);
    }

    #[test]
    fn chained_ops_reuse_group_without_crosstalk() {
        // two sequences over the same ranks chained independently
        let res = run(4, fixed(), free(), |ctx| {
            let s1 = DistSeq::range(ctx, 4, |i| i as i64);
            let s2 = DistSeq::range(ctx, 4, |i| 100 + i as i64);
            let r1 = s1.map_d(|v| v).reduce_d(|a, b| a + b);
            let r2 = s2.reduce_d(|a, b| a + b);
            (r1, r2)
        });
        assert_eq!(res.results[0], (Some(6), Some(406)));
    }

    #[test]
    fn map_d_indexed_sees_index() {
        let res = run(3, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 3, |_| 0u64)
                .map_d_indexed(|i, _| i as u64)
                .into_local()
        });
        assert_eq!(res.results, vec![Some(0), Some(1), Some(2)]);
    }

    #[test]
    fn scan_d_prefix_sums() {
        let res = run(6, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 6, |i| i as i64 + 1)
                .scan_d(|a, b| a + b)
                .into_local()
        });
        // inclusive prefix sums of 1..=6
        let expect: Vec<Option<i64>> =
            vec![Some(1), Some(3), Some(6), Some(10), Some(15), Some(21)];
        assert_eq!(res.results, expect);
    }

    #[test]
    fn scan_d_preserves_order_noncommutative() {
        let res = run(5, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 5, |i| format!("{i}"))
                .scan_d(|a, b| a + &b)
                .into_local()
        });
        assert_eq!(res.results[4].as_deref(), Some("01234"));
        assert_eq!(res.results[0].as_deref(), Some("0"));
    }

    #[test]
    fn gather_d_collects_at_root() {
        let res = run(4, fixed(), free(), |ctx| {
            DistSeq::range(ctx, 4, |i| i as u64 * 5).gather_d()
        });
        assert_eq!(res.results[0], Some(vec![0, 5, 10, 15]));
        assert!(res.results[1..].iter().all(Option::is_none));
    }

    // ------------------------------------------------- pending handles

    #[test]
    fn shift_d_start_overlaps_compute() {
        let res = run(4, fixed(), CostParams::new(1.0, 0.0), |ctx| {
            let pending = DistSeq::range(ctx, 4, |i| i as i64).shift_d_start(1);
            ctx.advance_compute(3.0, 0.0); // overlaps the 1-round shift
            (pending.wait().into_local(), ctx.now())
        });
        let vals: Vec<Option<i64>> = res.results.iter().map(|r| r.0).collect();
        assert_eq!(vals, vec![Some(3), Some(0), Some(1), Some(2)]);
        // blocking: 3 + 1 = 4; overlapped: max(3, 1) = 3
        for (_, t) in &res.results {
            assert!((t - 3.0).abs() < 1e-12, "clock {t}");
        }
    }

    #[test]
    fn pending_zip_with_d_matches_blocking_chain() {
        let res = run(4, fixed(), free(), |ctx| {
            let a = DistSeq::range(ctx, 4, |i| i as i64);
            let b = DistSeq::range(ctx, 4, |i| 10 * i as i64);
            a.shift_d_start(1).zip_with_d(b, |x, y| x + y).into_local()
        });
        // shifted a = [3,0,1,2]; b = [0,10,20,30]
        assert_eq!(
            res.results,
            vec![Some(3), Some(10), Some(21), Some(32)]
        );
    }

    #[test]
    fn reduce_d_start_folds_in_order() {
        let res = run(5, fixed(), free(), |ctx| {
            let pending = DistSeq::range(ctx, 5, |i| format!("{i}")).reduce_d_start(|a, b| a + &b);
            ctx.advance_compute(1.0, 0.0);
            pending.wait()
        });
        assert_eq!(res.results[0].as_deref(), Some("01234"));
        assert!(res.results[1..].iter().all(Option::is_none));
    }

    #[test]
    fn apply_start_broadcasts_ith_element() {
        let res = run(6, fixed(), free(), |ctx| {
            let pending = DistSeq::range(ctx, 6, |i| format!("e{i}")).apply_start(4);
            pending.wait()
        });
        assert!(res.results.iter().all(|r| r.as_deref() == Some("e4")));
    }

    #[test]
    fn pending_handles_are_inert_on_non_members() {
        let res = run(4, fixed(), free(), |ctx| {
            let pending = DistSeq::from_fn(ctx, vec![1, 3], |i| i as i64).shift_d_start(1);
            let _ = pending.test(); // advisory; must not panic on non-members
            pending.wait().into_local()
        });
        assert_eq!(res.results, vec![None, Some(1), None, Some(0)]);
        assert_eq!(res.metrics[0].msgs_sent, 0);
    }

    #[test]
    #[should_panic(expected = "same group")]
    fn zip_with_d_rejects_mismatched_groups() {
        run(4, fixed(), free(), |ctx| {
            let a = DistSeq::range(ctx, 4, |i| i as i64);
            let b = DistSeq::range(ctx, 3, |i| i as i64);
            let _ = a.zip_with_d(b, |x, y| x + y);
        });
    }
}
