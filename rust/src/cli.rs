//! Minimal CLI argument parsing (the image's crate cache has no `clap`).
//!
//! Grammar: `repro <subcommand> [--flag value]... [--switch]... [pos]...`
//! Flags may be `--key value` or `--key=value`; anything after `--` is
//! positional.

use std::collections::HashMap;

use anyhow::{anyhow, bail, Result};

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        let mut rest_positional = false;
        while let Some(a) = it.next() {
            if rest_positional {
                out.positional.push(a);
                continue;
            }
            if a == "--" {
                rest_positional = true;
            } else if let Some(key) = a.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.flags.insert(key.to_string(), it.next().unwrap());
                } else {
                    out.switches.push(key.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn has(&self, key: &str) -> bool {
        self.switches.iter().any(|s| s == key) || self.flags.contains_key(key)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key}: expected number, got '{v}'")),
        }
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Required flag.
    pub fn require(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    /// Error on unknown subcommand.
    pub fn unknown(&self) -> Result<()> {
        match &self.subcommand {
            Some(s) => bail!("unknown subcommand '{s}' (see `repro help`)"),
            None => bail!("no subcommand (see `repro help`)"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("mmm --n 1024 --p=27 --mode real extra");
        assert_eq!(a.subcommand.as_deref(), Some("mmm"));
        assert_eq!(a.get("n"), Some("1024"));
        assert_eq!(a.get_usize("p", 0).unwrap(), 27);
        assert_eq!(a.get_str("mode", "?"), "real");
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn switches_without_values() {
        let a = parse("fig5 --verbose --machine carver");
        assert!(a.has("verbose"));
        assert_eq!(a.get("machine"), Some("carver"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn defaults_and_errors() {
        let a = parse("x");
        assert_eq!(a.get_usize("p", 8).unwrap(), 8);
        assert_eq!(a.get_f64("r", 1.5).unwrap(), 1.5);
        assert!(a.require("missing").is_err());
        let bad = parse("x --p abc");
        assert!(bad.get_usize("p", 0).is_err());
    }

    #[test]
    fn double_dash_stops_flags() {
        let a = parse("run -- --not-a-flag");
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }
}
