//! Machine + run configuration.
//!
//! A FooPar configuration is `FooPar-X-Y-Z` (§3): X the communication
//! module, Y the networking substrate, Z the hardware.  Here Z is a
//! [`MachineConfig`] — interconnect cost parameters and the calibrated
//! per-core GEMM rate that efficiency is normalized against (the paper
//! measures "empirical peak performance" with a single-core C+MKL/BLAS
//! matmul; our analogue is `repro peak`, a single-rank PJRT block GEMM).
//!
//! Built-ins model the paper's two systems; config files use a minimal
//! `key = value` dialect (see [`parse_kv`] — the image has no TOML crate,
//! so the parser is in-tree and deliberately tiny).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::comm::cost::CostParams;

/// A machine (the paper's `Z` axis): interconnect + per-core compute.
#[derive(Clone, Debug, PartialEq)]
pub struct MachineConfig {
    pub name: String,
    /// Calibrated per-core GEMM rate in flops/s (the "empirical peak" the
    /// paper normalizes efficiency by): 10.11 GF/s on Carver (MKL),
    /// 4.55 GF/s on Horseshoe-6 (generic BLAS).
    pub rate: f64,
    /// Theoretical per-core peak (Carver: 10.67 GF/s).
    pub peak: f64,
    /// Interconnect start-up latency t_s (seconds).
    pub ts: f64,
    /// Interconnect per-byte time t_w (seconds/byte).
    pub tw: f64,
    /// Largest core count in the queue (Carver: 512).
    pub max_cores: usize,
    /// Cores each rank's block kernels use (the BLAS-threads-per-process
    /// knob).  The paper runs one single-threaded BLAS per core, so every
    /// built-in machine says 1; raise it (config file `threads_per_rank`,
    /// CLI `--threads`, or `Runtime::builder().threads_per_rank(..)`) to
    /// run fewer, fatter ranks — results are bit-identical either way.
    pub threads_per_rank: usize,
    /// Ranks sharing one node under the hierarchical transport: `Some(n)`
    /// groups ranks into nodes of `n` (the last node takes the
    /// remainder), giving the hybrid transport its [`Topology`] and the
    /// cost model its intra/inter link split.  `None` means a flat world.
    /// Overridable per run (CLI `--ranks-per-node`,
    /// `Runtime::builder().ranks_per_node(..)`, `FOOPAR_RANKS_PER_NODE`).
    ///
    /// [`Topology`]: crate::comm::transport::hier::Topology
    pub ranks_per_node: Option<usize>,
    /// Backend names to sweep on this machine.
    pub backends: Vec<String>,
    /// Path of a per-host tune profile (see [`crate::tune::TuneProfile`])
    /// to load at `Runtime::build`: GEMM blocking params and calibrated
    /// intra/inter link costs measured by `repro tune`.  `None` (every
    /// built-in) keeps the default blocking and modeled link costs;
    /// `Runtime::builder().tune_profile(..)` / CLI `--profile` win over
    /// this key.
    pub tune_profile: Option<String>,
    /// How the algorithm entry points schedule themselves on this
    /// machine (config key `plan_mode`): `"auto"` dry-runs every
    /// candidate schedule on the cost model and interprets the cheapest,
    /// `"eager"` bypasses the planner for the hand-written defaults, and
    /// a schedule name (`"cannon-pipelined"`, `"dns"`, …) forces that
    /// schedule.  `None` defers to the builder, then `auto`.
    /// `Runtime::builder().plan_mode(..)` wins over this key.
    pub plan_mode: Option<crate::plan::PlanMode>,
}

impl MachineConfig {
    pub fn cost(&self) -> CostParams {
        CostParams::new(self.ts, self.tw)
    }

    /// Carver (NERSC iDataPlex, 4X QDR InfiniBand, MKL): the machine of
    /// Fig. 5 left.
    pub fn carver() -> Self {
        MachineConfig {
            name: "carver".into(),
            rate: 10.11e9,
            peak: 10.67e9,
            ts: 2.0e-6,
            tw: 2.5e-10,
            max_cores: 512,
            threads_per_rank: 1,
            ranks_per_node: None,
            backends: vec!["openmpi-fixed".into()],
            tune_profile: None,
            plan_mode: None,
        }
    }

    /// Horseshoe-6 (SDU, same interconnect class, generic BLAS): the
    /// machine of Fig. 5 right — the backend-comparison testbed.
    pub fn horseshoe6() -> Self {
        MachineConfig {
            name: "horseshoe6".into(),
            rate: 4.55e9,
            peak: 4.55e9,
            ts: 2.5e-6,
            tw: 2.5e-10,
            max_cores: 512,
            threads_per_rank: 1,
            ranks_per_node: None,
            backends: vec![
                "openmpi-fixed".into(),
                "openmpi-stock".into(),
                "mpj-express".into(),
                "fastmpj".into(),
            ],
            tune_profile: None,
            plan_mode: None,
        }
    }

    /// A laptop-ish profile for real-mode runs (shared-memory costs).
    pub fn local() -> Self {
        MachineConfig {
            name: "local".into(),
            rate: 5.0e9,
            peak: 5.0e9,
            ts: 2.0e-7,
            tw: 1.0e-10,
            max_cores: 64,
            threads_per_rank: 1,
            ranks_per_node: None,
            backends: vec!["shmem".into()],
            tune_profile: None,
            plan_mode: None,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "carver" => Some(Self::carver()),
            "horseshoe6" | "horseshoe" => Some(Self::horseshoe6()),
            "local" => Some(Self::local()),
            _ => None,
        }
    }

    /// Build from parsed key=value pairs.
    pub fn from_kv(kv: &HashMap<String, Value>) -> Result<Self> {
        let get = |k: &str| kv.get(k).ok_or_else(|| anyhow!("missing key '{k}'"));
        Ok(MachineConfig {
            name: get("name")?.as_str()?.to_string(),
            rate: get("rate")?.as_f64()?,
            peak: kv.get("peak").map(|v| v.as_f64()).transpose()?.unwrap_or(
                get("rate")?.as_f64()?,
            ),
            ts: get("ts")?.as_f64()?,
            tw: get("tw")?.as_f64()?,
            max_cores: get("max_cores")?.as_f64()? as usize,
            threads_per_rank: kv
                .get("threads_per_rank")
                .map(|v| v.as_f64())
                .transpose()?
                .map(|v| (v as usize).max(1))
                .unwrap_or(1),
            ranks_per_node: kv
                .get("ranks_per_node")
                .map(|v| v.as_f64())
                .transpose()?
                .map(|v| (v as usize).max(1)),
            backends: match kv.get("backends") {
                Some(v) => v.as_list()?.to_vec(),
                None => vec!["openmpi-fixed".into()],
            },
            tune_profile: kv
                .get("tune_profile")
                .map(|v| v.as_str().map(str::to_string))
                .transpose()?,
            plan_mode: kv
                .get("plan_mode")
                .map(|v| {
                    let s = v.as_str()?;
                    crate::plan::PlanMode::parse(s).ok_or_else(|| {
                        anyhow!(
                            "bad plan_mode '{s}' (expected auto, eager, or a schedule name: \
                             cannon, cannon-pipelined, dns, dns-pipelined, generic, fw)"
                        )
                    })
                })
                .transpose()?,
        })
    }

    /// Load from a config file (see [`parse_kv`] for the dialect).
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let kv = parse_kv(&text)?;
        Self::from_kv(&kv).with_context(|| format!("in {}", path.display()))
    }

    /// Resolve a CLI `--machine` argument: built-in name or file path.
    pub fn resolve(spec: &str) -> Result<Self> {
        if let Some(m) = Self::by_name(spec) {
            return Ok(m);
        }
        let p = Path::new(spec);
        if p.exists() {
            return Self::load(p);
        }
        bail!("unknown machine '{spec}' (built-ins: carver, horseshoe6, local; or a config path)")
    }
}

/// A parsed config value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Num(f64),
    List(Vec<String>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Num(n) => Ok(*n),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_list(&self) -> Result<&[String]> {
        match self {
            Value::List(v) => Ok(v),
            _ => bail!("expected list, got {self:?}"),
        }
    }
}

/// Parse the minimal config dialect:
///
/// ```text
/// # comment
/// name = "carver"
/// rate = 10.11e9
/// backends = ["openmpi-fixed", "fastmpj"]
/// ```
pub fn parse_kv(text: &str) -> Result<HashMap<String, Value>> {
    let mut out = HashMap::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
        let key = k.trim().to_string();
        let val = parse_value(v.trim())
            .with_context(|| format!("line {}: bad value for '{key}'", lineno + 1))?;
        out.insert(key, val);
    }
    Ok(out)
}

fn parse_value(s: &str) -> Result<Value> {
    if let Some(inner) = s.strip_prefix('"').and_then(|t| t.strip_suffix('"')) {
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|t| t.strip_suffix(']')) {
        let items = inner
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| {
                t.strip_prefix('"')
                    .and_then(|u| u.strip_suffix('"'))
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("list items must be quoted strings: {t}"))
            })
            .collect::<Result<Vec<_>>>()?;
        return Ok(Value::List(items));
    }
    s.parse::<f64>()
        .map(Value::Num)
        .map_err(|_| anyhow!("cannot parse '{s}' as number, string, or list"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve() {
        assert_eq!(MachineConfig::resolve("carver").unwrap().rate, 10.11e9);
        assert_eq!(MachineConfig::resolve("horseshoe").unwrap().rate, 4.55e9);
        assert!(MachineConfig::resolve("nope").is_err());
    }

    #[test]
    fn parse_dialect() {
        let kv = parse_kv(
            r#"
            # a machine
            name = "test"
            rate = 1.5e9
            ts = 1e-6     # latency
            tw = 2e-10
            max_cores = 64
            backends = ["a", "b"]
            "#,
        )
        .unwrap();
        let m = MachineConfig::from_kv(&kv).unwrap();
        assert_eq!(m.name, "test");
        assert_eq!(m.rate, 1.5e9);
        assert_eq!(m.backends, vec!["a", "b"]);
        assert_eq!(m.peak, 1.5e9); // defaults to rate
        assert_eq!(m.threads_per_rank, 1); // defaults to 1 BLAS thread
    }

    #[test]
    fn threads_per_rank_parses_and_clamps() {
        let base = "name = \"t\"\nrate = 1e9\nts = 1e-6\ntw = 1e-10\nmax_cores = 8\n";
        let kv = parse_kv(&format!("{base}threads_per_rank = 4\n")).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().threads_per_rank, 4);
        let kv = parse_kv(&format!("{base}threads_per_rank = 0\n")).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().threads_per_rank, 1);
    }

    #[test]
    fn ranks_per_node_parses_and_clamps() {
        let base = "name = \"t\"\nrate = 1e9\nts = 1e-6\ntw = 1e-10\nmax_cores = 8\n";
        let kv = parse_kv(base).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().ranks_per_node, None);
        let kv = parse_kv(&format!("{base}ranks_per_node = 4\n")).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().ranks_per_node, Some(4));
        let kv = parse_kv(&format!("{base}ranks_per_node = 0\n")).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().ranks_per_node, Some(1));
    }

    #[test]
    fn tune_profile_key_parses() {
        let base = "name = \"t\"\nrate = 1e9\nts = 1e-6\ntw = 1e-10\nmax_cores = 8\n";
        let kv = parse_kv(base).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().tune_profile, None);
        let kv = parse_kv(&format!("{base}tune_profile = \"/tmp/tune-host.json\"\n")).unwrap();
        assert_eq!(
            MachineConfig::from_kv(&kv).unwrap().tune_profile.as_deref(),
            Some("/tmp/tune-host.json")
        );
    }

    #[test]
    fn plan_mode_key_parses_and_validates() {
        use crate::plan::{PlanMode, Schedule};
        let base = "name = \"t\"\nrate = 1e9\nts = 1e-6\ntw = 1e-10\nmax_cores = 8\n";
        let kv = parse_kv(base).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().plan_mode, None);
        let kv = parse_kv(&format!("{base}plan_mode = \"auto\"\n")).unwrap();
        assert_eq!(MachineConfig::from_kv(&kv).unwrap().plan_mode, Some(PlanMode::Auto));
        let kv = parse_kv(&format!("{base}plan_mode = \"cannon-pipelined\"\n")).unwrap();
        assert_eq!(
            MachineConfig::from_kv(&kv).unwrap().plan_mode,
            Some(PlanMode::Forced(Schedule::CannonPipelined))
        );
        let kv = parse_kv(&format!("{base}plan_mode = \"bogus\"\n")).unwrap();
        assert!(MachineConfig::from_kv(&kv).is_err());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_kv("just words").is_err());
        assert!(parse_kv("x = [1, 2]").is_err()); // unquoted list items
        assert!(parse_kv("x = nope").is_err());
    }

    #[test]
    fn load_roundtrip_via_file() {
        let dir = std::env::temp_dir().join("foopar_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.toml");
        std::fs::write(
            &p,
            "name = \"filetest\"\nrate = 2e9\nts = 1e-6\ntw = 1e-10\nmax_cores = 8\n",
        )
        .unwrap();
        let m = MachineConfig::resolve(p.to_str().unwrap()).unwrap();
        assert_eq!(m.name, "filetest");
        assert_eq!(m.max_cores, 8);
    }

    #[test]
    fn carver_matches_paper_numbers() {
        let c = MachineConfig::carver();
        // §6: 10.11 GF/s empirical, 10.67 GF/s theoretical, 512 cores max
        assert_eq!(c.rate, 10.11e9);
        assert_eq!(c.peak, 10.67e9);
        assert_eq!(c.max_cores, 512);
    }
}
