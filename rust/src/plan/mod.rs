//! Lazy execution plans: describe → optimize → dry-run → interpret.
//!
//! The eager algorithm modules each hard-code one schedule (blocking
//! Cannon, pipelined DNS, …).  This layer records the algorithm as a
//! [`ir::PlanGraph`] instead, runs two rewrite passes —
//! [`passes::fuse`] collapses adjacent elementwise chains into one
//! fused kernel pass, [`passes::overlap`] splits comm nodes into
//! `*_start`/`wait()` pairs wherever independent compute can hide the
//! transfer — then **dry-runs** every candidate schedule on the
//! virtual-clock cost model ([`cost::price`], zero data movement) and
//! interprets the cheapest ([`exec`]).  Interpreted plans are
//! bit-identical to the eager paths: same kernels, same operand and
//! fold order, same `DistSeq` group operations — only the schedule is
//! chosen by model instead of by hand.
//!
//! The schedule choice is SPMD-consistent: it is a pure function of
//! the plan, the topology, the link parameters, and the spec — all of
//! which every rank holds identically — so all ranks pick the same
//! schedule with zero communication.
//!
//! **Ownership convention.**  Spec builders ([`MatmulSpec`],
//! [`FwSpec`]) and plan combinators ([`ir::PlanBuilder`]) consume
//! `self`, the same convention as the `DistSeq` group operations
//! (see [`crate::data::dseq`]): chains read left-to-right and fan-out
//! is explicit ([`ir::PlanBuilder::dup`]).

pub mod cost;
pub mod exec;
pub mod ir;
pub mod passes;

use crate::algos::floyd_warshall::FwSource;
use crate::algos::mmm_generic;
use crate::comm::cost::ceil_log2;
use crate::data::grid::GridN;
use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::{gemm_efficiency, Compute};
use crate::spmd::Ctx;
use crate::trace::{span, Category};

use cost::{price, PriceCtx};
use exec::{interpret, Sources};
use ir::{build_cannon, build_dns, build_fw};

/// Default modeled flop rate when the compute backend has none (real
/// kernels): ~10 GFlop/s per core, the right order for ranking comm
/// against compute on current hardware.
const DEFAULT_RATE: f64 = 1e10;

/// A concrete schedule the planner can price and interpret.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Cannon on q² ranks, blocking shifts.
    CannonBlocking,
    /// Cannon on q² ranks, shifts overlapped under the GEMMs.
    CannonPipelined,
    /// DNS on q³ ranks, one blocking z-reduction.
    DnsBlocking,
    /// DNS on q³ ranks, panel-chunked reductions overlapped.
    DnsPipelined,
    /// Algorithm 1: q² sequential group reductions on q³ ranks (kept
    /// eager — its schedule has nothing to overlap or fuse).
    Generic,
    /// Blocked Floyd–Warshall, blocking pivot broadcasts.
    FwBlocking,
}

impl Schedule {
    pub fn name(self) -> &'static str {
        match self {
            Schedule::CannonBlocking => "cannon",
            Schedule::CannonPipelined => "cannon-pipelined",
            Schedule::DnsBlocking => "dns",
            Schedule::DnsPipelined => "dns-pipelined",
            Schedule::Generic => "generic",
            Schedule::FwBlocking => "fw",
        }
    }

    pub fn parse(s: &str) -> Option<Schedule> {
        Some(match s {
            "cannon" | "cannon-blocking" => Schedule::CannonBlocking,
            "cannon-pipelined" => Schedule::CannonPipelined,
            "dns" | "dns-blocking" => Schedule::DnsBlocking,
            "dns-pipelined" => Schedule::DnsPipelined,
            "generic" => Schedule::Generic,
            "fw" => Schedule::FwBlocking,
            _ => return None,
        })
    }

    /// Stable numeric code (trace span args, wire stats).
    pub fn code(self) -> u8 {
        match self {
            Schedule::CannonBlocking => 0,
            Schedule::CannonPipelined => 1,
            Schedule::DnsBlocking => 2,
            Schedule::DnsPipelined => 3,
            Schedule::Generic => 4,
            Schedule::FwBlocking => 5,
        }
    }

    pub fn from_code(c: u8) -> Option<Schedule> {
        Some(match c {
            0 => Schedule::CannonBlocking,
            1 => Schedule::CannonPipelined,
            2 => Schedule::DnsBlocking,
            3 => Schedule::DnsPipelined,
            4 => Schedule::Generic,
            5 => Schedule::FwBlocking,
            _ => return None,
        })
    }

    /// Ranks this schedule needs for grid parameter `q`.
    fn ranks_needed(self, q: usize) -> usize {
        match self {
            Schedule::CannonBlocking | Schedule::CannonPipelined | Schedule::FwBlocking => q * q,
            Schedule::DnsBlocking | Schedule::DnsPipelined | Schedule::Generic => q * q * q,
        }
    }
}

/// How an algorithm entry point schedules itself.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlanMode {
    /// Dry-run every candidate, interpret the cheapest (the default).
    #[default]
    Auto,
    /// Bypass the planner entirely: run the hand-written eager default
    /// (the pre-plan behavior).
    Eager,
    /// Interpret exactly this schedule, no pricing.
    Forced(Schedule),
}

impl PlanMode {
    pub fn parse(s: &str) -> Option<PlanMode> {
        Some(match s {
            "auto" => PlanMode::Auto,
            "eager" => PlanMode::Eager,
            other => PlanMode::Forced(Schedule::parse(other)?),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Auto => "auto",
            PlanMode::Eager => "eager",
            PlanMode::Forced(s) => s.name(),
        }
    }
}

// ------------------------------------------------------------- matmul

/// Spec for the consolidated matrix-product entry point
/// ([`matmul`]).  Builder methods consume `self`.
pub struct MatmulSpec<'s> {
    comp: &'s Compute,
    q: usize,
    a: &'s BlockSource,
    b: &'s BlockSource,
    ranks: Option<&'s [usize]>,
    mode: Option<PlanMode>,
    chunks: usize,
    rate_hint: Option<f64>,
}

impl<'s> MatmulSpec<'s> {
    pub fn new(comp: &'s Compute, q: usize, a: &'s BlockSource, b: &'s BlockSource) -> Self {
        MatmulSpec { comp, q, a, b, ranks: None, mode: None, chunks: 4, rate_hint: None }
    }

    /// Place the grid on an explicit rank subset (the serving runtime's
    /// placement hook; see [`GridN::new_on`]).
    pub fn on(mut self, ranks: &'s [usize]) -> Self {
        self.ranks = Some(ranks);
        self
    }

    /// Override the runtime's [`PlanMode`] for this call.
    pub fn mode(mut self, mode: PlanMode) -> Self {
        self.mode = Some(mode);
        self
    }

    /// Panel count for the pipelined-DNS candidate (clamped to the
    /// block edge; default 4).
    pub fn chunks(mut self, chunks: usize) -> Self {
        assert!(chunks >= 1, "need at least one panel");
        self.chunks = chunks;
        self
    }

    /// Modeled flop rate for pricing when the compute backend is real
    /// (native/PJRT kernels carry no rate of their own).
    pub fn rate_hint(mut self, rate: f64) -> Self {
        self.rate_hint = Some(rate);
        self
    }

    fn rate(&self) -> f64 {
        self.rate_hint.unwrap_or(match self.comp {
            Compute::Modeled { rate } => *rate,
            _ => DEFAULT_RATE,
        })
    }

    fn panels(&self) -> usize {
        self.chunks.min(self.b.b).max(1)
    }
}

/// Outcome of a planned matrix product on one rank.
pub struct PlanOutput {
    /// `Some((i, j, block))` on the ranks the chosen schedule's output
    /// placement selects.
    pub c_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
    /// The schedule that actually ran.
    pub schedule: Schedule,
}

/// The consolidated matrix-product entry point: records the plan,
/// optimizes it, dry-runs the candidates, and interprets the cheapest
/// (or whatever [`PlanMode`] dictates).
pub fn matmul(ctx: &Ctx, spec: MatmulSpec<'_>) -> PlanOutput {
    assert_eq!(spec.a.b, spec.b.b, "block sizes of A and B must match");
    let mode = spec.mode.unwrap_or_else(|| ctx.plan_mode());
    let avail = spec.ranks.map_or(ctx.world, <[usize]>::len);

    let schedule = match mode {
        PlanMode::Forced(s) => {
            assert!(
                s != Schedule::FwBlocking,
                "fw is an APSP schedule; use plan::apsp"
            );
            assert!(
                s.ranks_needed(spec.q) <= avail,
                "schedule {} needs {} ranks, only {avail} available",
                s.name(),
                s.ranks_needed(spec.q)
            );
            assert!(
                !(s == Schedule::Generic && spec.ranks.is_some()),
                "the generic schedule has no subset placement"
            );
            s
        }
        PlanMode::Eager => eager_default(spec.q, avail, spec.ranks.is_some()),
        PlanMode::Auto => {
            let mut sp = span("plan", Category::Plan);
            let (chosen, candidates) = choose_matmul(ctx, &spec, avail);
            sp.arg("schedule", chosen.code() as f64);
            sp.arg("q", spec.q as f64);
            sp.arg("candidates", candidates.len() as f64);
            chosen
        }
    };

    let c_block = if mode == PlanMode::Eager {
        run_eager(ctx, &spec, schedule)
    } else {
        run_schedule(ctx, &spec, schedule)
    };
    PlanOutput { c_block, t_local: ctx.now(), schedule }
}

/// Price every feasible candidate (no execution, no messages).
pub fn explain_matmul(ctx: &Ctx, spec: MatmulSpec<'_>) -> Explain {
    let avail = spec.ranks.map_or(ctx.world, <[usize]>::len);
    let (chosen, candidates) = choose_matmul(ctx, &spec, avail);
    Explain {
        what: "matmul",
        q: spec.q,
        block: spec.a.b,
        world: avail,
        candidates,
        chosen,
    }
}

/// The pre-plan behavior: the CLI's old default was DNS when the cube
/// fits, else Cannon; placed (subset) runs always used Cannon.
fn eager_default(q: usize, avail: usize, placed: bool) -> Schedule {
    if !placed && q * q * q <= avail {
        Schedule::DnsBlocking
    } else {
        assert!(q * q <= avail, "need q² ranks for an eager matmul");
        Schedule::CannonBlocking
    }
}

fn feasible_matmul(q: usize, avail: usize, placed: bool) -> Vec<Schedule> {
    let mut v = Vec::new();
    if q * q <= avail {
        v.push(Schedule::CannonBlocking);
        v.push(Schedule::CannonPipelined);
    }
    if q * q * q <= avail {
        v.push(Schedule::DnsBlocking);
        v.push(Schedule::DnsPipelined);
        if !placed {
            v.push(Schedule::Generic);
        }
    }
    assert!(!v.is_empty(), "no schedule fits: q={q}, {avail} ranks available");
    v
}

fn choose_matmul(ctx: &Ctx, spec: &MatmulSpec<'_>, avail: usize) -> (Schedule, Vec<(Schedule, f64)>) {
    let candidates: Vec<(Schedule, f64)> =
        feasible_matmul(spec.q, avail, spec.ranks.is_some())
            .into_iter()
            .map(|s| (s, price_matmul(ctx, spec, s)))
            .collect();
    // Argmin with a strictly-lower-wins tie-break: on a free network the
    // pipelined rewrite saves nothing, and the earlier (simpler,
    // blocking) schedule keeps the tie.
    let mut chosen = candidates[0];
    for &c in &candidates[1..] {
        if c.1 < chosen.1 {
            chosen = c;
        }
    }
    (chosen.0, candidates)
}

fn grid_ranks(spec: &MatmulSpec<'_>, need: usize) -> Vec<usize> {
    match spec.ranks {
        Some(r) => r[..need].to_vec(),
        None => (0..need).collect(),
    }
}

fn price_matmul(ctx: &Ctx, spec: &MatmulSpec<'_>, s: Schedule) -> f64 {
    let b = spec.a.b;
    let rate = spec.rate();
    if s == Schedule::Generic {
        return price_generic(ctx, spec.q, b, rate);
    }
    let (g, dims) = match s {
        Schedule::CannonBlocking => (build_cannon(spec.q), vec![spec.q, spec.q]),
        Schedule::CannonPipelined => {
            let mut g = build_cannon(spec.q);
            passes::fuse(&mut g);
            passes::overlap(&mut g);
            (g, vec![spec.q, spec.q])
        }
        Schedule::DnsBlocking => (build_dns(spec.q, 1), vec![spec.q, spec.q, spec.q]),
        Schedule::DnsPipelined => {
            let mut g = build_dns(spec.q, spec.panels());
            passes::fuse(&mut g);
            passes::overlap(&mut g);
            (g, vec![spec.q, spec.q, spec.q])
        }
        Schedule::Generic | Schedule::FwBlocking => unreachable!(),
    };
    let need: usize = dims.iter().product();
    let pc = PriceCtx {
        topo: ctx.topology().as_ref(),
        link: ctx.link_cost(),
        rate,
        block: b,
        ranks: grid_ranks(spec, need),
        dims,
    };
    price(&g, &pc)
}

/// Closed-form price of Algorithm 1 (it is never interpreted): q²
/// sequential ∀-iterations of nop overhead, one group GEMM, and one
/// q-rank tree reduction — §4.2.1's bottleneck terms.
fn price_generic(ctx: &Ctx, q: usize, b: usize, rate: f64) -> f64 {
    let eff = gemm_efficiency(b);
    let t_mm = 2.0 * (b as f64).powi(3) / (rate * eff);
    let bytes = b * b * 4;
    let topo = ctx.topology();
    let link = ctx.link_cost();
    let mut worst: f64 = 0.0;
    for g in 0..q * q {
        let lo = g * q;
        for i in lo..lo + q {
            for j in (i + 1)..lo + q {
                worst = worst.max(link.msg(topo.same_node(i, j), bytes));
            }
        }
    }
    let t_red = ceil_log2(q) as f64 * (worst + (b * b) as f64 / rate);
    (q * q - 1) as f64 * mmm_generic::NOP_COST + t_mm + t_red
}

/// Interpret `schedule`'s plan (Generic runs its eager form — there is
/// nothing to rewrite in its one-GEMM-one-reduce groups).
fn run_schedule(
    ctx: &Ctx,
    spec: &MatmulSpec<'_>,
    schedule: Schedule,
) -> Option<(usize, usize, Block)> {
    let q = spec.q;
    let srcs = Sources::Mm { a: spec.a, b: spec.b, q };
    match schedule {
        Schedule::CannonBlocking | Schedule::CannonPipelined => {
            let grid = match spec.ranks {
                Some(r) => GridN::square_on(ctx, q, r),
                None => GridN::square(ctx, q),
            };
            let mut g = build_cannon(q);
            passes::fuse(&mut g);
            if schedule == Schedule::CannonPipelined {
                passes::overlap(&mut g);
            }
            let out = interpret(ctx, spec.comp, &g, &grid, &srcs);
            grid.my_coord().zip(out).map(|(c, blk)| (c[0], c[1], blk))
        }
        Schedule::DnsBlocking | Schedule::DnsPipelined => {
            let grid = match spec.ranks {
                Some(r) => GridN::new_on(ctx, vec![q, q, q], r),
                None => GridN::cube(ctx, q),
            };
            let mut g = match schedule {
                Schedule::DnsBlocking => build_dns(q, 1),
                _ => build_dns(q, spec.panels()),
            };
            passes::fuse(&mut g);
            if schedule == Schedule::DnsPipelined {
                passes::overlap(&mut g);
            }
            let out = interpret(ctx, spec.comp, &g, &grid, &srcs);
            match (grid.my_coord(), out) {
                (Some(cd), Some(blk)) => Some((cd[0], cd[1], blk)),
                _ => None,
            }
        }
        Schedule::Generic => {
            mmm_generic::mmm_generic(ctx, spec.comp, q, spec.a, spec.b).c_block
        }
        Schedule::FwBlocking => unreachable!("fw is not a matmul schedule"),
    }
}

/// Run the retained hand-written eager implementation of `schedule`.
fn run_eager(
    ctx: &Ctx,
    spec: &MatmulSpec<'_>,
    schedule: Schedule,
) -> Option<(usize, usize, Block)> {
    match schedule {
        Schedule::CannonBlocking => {
            let grid = match spec.ranks {
                Some(r) => GridN::square_on(ctx, spec.q, r),
                None => GridN::square(ctx, spec.q),
            };
            crate::algos::cannon::cannon_on_grid(ctx, spec.comp, spec.q, spec.a, spec.b, &grid)
                .c_block
        }
        Schedule::DnsBlocking => {
            crate::algos::mmm_dns::dns_eager(ctx, spec.comp, spec.q, spec.a, spec.b).c_block
        }
        Schedule::Generic => {
            mmm_generic::mmm_generic(ctx, spec.comp, spec.q, spec.a, spec.b).c_block
        }
        other => unreachable!("eager mode never selects {}", other.name()),
    }
}

// --------------------------------------------------------------- apsp

/// Spec for the consolidated all-pairs-shortest-paths entry point
/// ([`apsp`]).  Builder methods consume `self`.
pub struct FwSpec<'s> {
    comp: &'s Compute,
    q: usize,
    src: &'s FwSource,
    ranks: Option<&'s [usize]>,
    mode: Option<PlanMode>,
}

impl<'s> FwSpec<'s> {
    pub fn new(comp: &'s Compute, q: usize, src: &'s FwSource) -> Self {
        FwSpec { comp, q, src, ranks: None, mode: None }
    }

    /// Place the grid on an explicit rank subset.
    pub fn on(mut self, ranks: &'s [usize]) -> Self {
        self.ranks = Some(ranks);
        self
    }

    /// Override the runtime's [`PlanMode`] for this call.
    pub fn mode(mut self, mode: PlanMode) -> Self {
        self.mode = Some(mode);
        self
    }
}

/// Outcome of a planned APSP run on one rank.
pub struct FwPlanOutput {
    /// `Some((i, j, final block))` on grid members.
    pub d_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
    pub schedule: Schedule,
}

/// The consolidated APSP entry point.  One schedule exists (the
/// overlap pass proves the per-pivot broadcasts have no independent
/// compute to hide behind — see
/// `passes::tests::fw_pivot_broadcasts_do_not_split`), so Auto and
/// Forced(fw) interpret the same plan; Eager runs the hand-written
/// loop.
pub fn apsp(ctx: &Ctx, spec: FwSpec<'_>) -> FwPlanOutput {
    let mode = spec.mode.unwrap_or_else(|| ctx.plan_mode());
    if let PlanMode::Forced(s) = mode {
        assert!(s == Schedule::FwBlocking, "{} is not an APSP schedule", s.name());
    }
    let q = spec.q;
    let grid = match spec.ranks {
        Some(r) => GridN::square_on(ctx, q, r),
        None => GridN::square(ctx, q),
    };
    let d_block = if mode == PlanMode::Eager {
        crate::algos::floyd_warshall::fw_on_grid(ctx, spec.comp, q, spec.src, &grid).d_block
    } else {
        let n = spec.src.n();
        assert_eq!(n % q, 0, "n must be divisible by q");
        if mode == PlanMode::Auto {
            let mut sp = span("plan", Category::Plan);
            sp.arg("schedule", Schedule::FwBlocking.code() as f64);
            sp.arg("q", q as f64);
            sp.arg("candidates", 1.0);
        }
        let mut g = build_fw(n, q);
        passes::fuse(&mut g);
        passes::overlap(&mut g);
        let srcs = Sources::Fw { src: spec.src, b: n / q };
        let out = interpret(ctx, spec.comp, &g, &grid, &srcs);
        grid.my_coord().zip(out).map(|(c, blk)| (c[0], c[1], blk))
    };
    FwPlanOutput { d_block, t_local: ctx.now(), schedule: Schedule::FwBlocking }
}

/// Price the APSP plan (single candidate today — kept symmetric with
/// [`explain_matmul`] so `repro plan --explain` covers both).
pub fn explain_apsp(ctx: &Ctx, spec: FwSpec<'_>) -> Explain {
    let q = spec.q;
    let n = spec.src.n();
    assert_eq!(n % q, 0, "n must be divisible by q");
    let b = n / q;
    let need = q * q;
    let avail = spec.ranks.map_or(ctx.world, <[usize]>::len);
    let ranks = match spec.ranks {
        Some(r) => r[..need].to_vec(),
        None => (0..need).collect(),
    };
    let rate = match spec.comp {
        Compute::Modeled { rate } => *rate,
        _ => DEFAULT_RATE,
    };
    let g = build_fw(n, q);
    let pc = PriceCtx {
        topo: ctx.topology().as_ref(),
        link: ctx.link_cost(),
        rate,
        block: b,
        dims: vec![q, q],
        ranks,
    };
    let t = price(&g, &pc);
    Explain {
        what: "apsp",
        q,
        block: b,
        world: avail,
        candidates: vec![(Schedule::FwBlocking, t)],
        chosen: Schedule::FwBlocking,
    }
}

/// Reassemble a planned product's distributed result (verification,
/// examples, CLI).  Mirrors the per-algorithm `collect_c` helpers.
pub fn collect_c(results: &[PlanOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    let mut c = crate::matrix::dense::Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.c_block {
            c.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q, "expected one output block per grid slot");
    c
}

/// Reassemble a planned APSP's distributed distance matrix.
pub fn collect_d(results: &[FwPlanOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    let mut d = crate::matrix::dense::Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.d_block {
            d.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q, "expected one output block per grid slot");
    d
}

// ------------------------------------------------------------ explain

/// The planner's reasoning, for `repro plan --explain` and tests.
pub struct Explain {
    pub what: &'static str,
    pub q: usize,
    pub block: usize,
    pub world: usize,
    /// Every feasible schedule with its dry-run modeled `T_P`.
    pub candidates: Vec<(Schedule, f64)>,
    pub chosen: Schedule,
}

impl Explain {
    pub fn render(&self) -> String {
        let mut out = format!(
            "execution plan: {} q={} block={} ranks={}\n  {:<18} modeled T_P\n",
            self.what, self.q, self.block, self.world, "schedule"
        );
        for &(s, t) in &self.candidates {
            let mark = if s == self.chosen { '>' } else { ' ' };
            let tag = if s == self.chosen { "  (chosen)" } else { "" };
            out.push_str(&format!("{mark} {:<18} {t:.6e} s{tag}\n", s.name()));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_names_round_trip() {
        for s in [
            Schedule::CannonBlocking,
            Schedule::CannonPipelined,
            Schedule::DnsBlocking,
            Schedule::DnsPipelined,
            Schedule::Generic,
            Schedule::FwBlocking,
        ] {
            assert_eq!(Schedule::parse(s.name()), Some(s));
            assert_eq!(Schedule::from_code(s.code()), Some(s));
        }
        assert_eq!(Schedule::parse("nope"), None);
        assert_eq!(Schedule::from_code(99), None);
    }

    #[test]
    fn plan_mode_parses() {
        assert_eq!(PlanMode::parse("auto"), Some(PlanMode::Auto));
        assert_eq!(PlanMode::parse("eager"), Some(PlanMode::Eager));
        assert_eq!(
            PlanMode::parse("cannon-pipelined"),
            Some(PlanMode::Forced(Schedule::CannonPipelined))
        );
        assert_eq!(PlanMode::parse("bogus"), None);
    }

    #[test]
    fn feasibility_gates_by_available_ranks() {
        let c4 = feasible_matmul(2, 4, false);
        assert_eq!(c4, vec![Schedule::CannonBlocking, Schedule::CannonPipelined]);
        let c8 = feasible_matmul(2, 8, false);
        assert!(c8.contains(&Schedule::DnsPipelined));
        assert!(c8.contains(&Schedule::Generic));
        // placed runs exclude the generic schedule (no subset form)
        assert!(!feasible_matmul(2, 8, true).contains(&Schedule::Generic));
    }

    #[test]
    fn explain_render_marks_the_choice() {
        let e = Explain {
            what: "matmul",
            q: 4,
            block: 256,
            world: 16,
            candidates: vec![
                (Schedule::CannonBlocking, 2.0e-2),
                (Schedule::CannonPipelined, 1.5e-2),
            ],
            chosen: Schedule::CannonPipelined,
        };
        let r = e.render();
        assert!(r.contains("> cannon-pipelined"));
        assert!(r.contains("(chosen)"));
        assert!(r.contains("  cannon "));
    }
}
