//! Dry-run pricing: replay a plan's order on the virtual-clock cost
//! model with **zero data movement**, yielding the modeled `T_P` of
//! each candidate schedule.
//!
//! The walk uses the same execution order and the same FIFO wait rule
//! as the interpreter ([`crate::plan::exec`]) and the same cost
//! formulas the runtime charges — [`CostParams::msg`] per hop via the
//! topology-aware [`HierCost`] legs, [`ceil_log2`] rounds for the tree
//! collectives, and the [`Compute::Modeled`] kernel formulas
//! (GEMM flops at [`gemm_efficiency`], one element-touch per
//! elementwise flop).  Split comm nodes run on a forked timeline and
//! merge at their wait with `clock = max(main, fork)` — the overlap
//! rule of [`crate::comm::nb`].  The result is a deterministic
//! function of (graph, topology, link parameters, block edge, rate):
//! every rank computes the same prices without communicating, so the
//! planner's argmin choice is SPMD-consistent by construction.
//!
//! Prices are *estimates* for schedule ranking — they intentionally
//! price every rank at the worst link of each transfer (the critical
//! path) rather than simulating per-rank clocks.

use crate::comm::cost::{ceil_log2, HierCost};
use crate::comm::transport::Topology;
use crate::runtime::compute::gemm_efficiency;

use super::ir::{NodeId, Op, PlanGraph};

/// Everything the pricer may look at — all SPMD-consistent inputs.
pub(crate) struct PriceCtx<'t> {
    pub topo: &'t Topology,
    pub link: HierCost,
    /// Modeled per-core flop rate of the compute backend.
    pub rate: f64,
    /// Block edge (the algorithms move square b×b blocks; panel nodes
    /// price their column share).
    pub block: usize,
    /// Grid shape (must match the plan's `dims`).
    pub dims: Vec<usize>,
    /// World rank of each grid process, row-major.
    pub ranks: Vec<usize>,
}

impl PriceCtx<'_> {
    fn rank_of(&self, coord: &[usize]) -> usize {
        let mut r = 0usize;
        for (c, d) in coord.iter().zip(&self.dims) {
            r = r * d + c;
        }
        self.ranks[r]
    }

    /// Iterate every grid coordinate (row-major).
    fn coords(&self) -> Vec<Vec<usize>> {
        let mut out = vec![vec![]];
        for &d in &self.dims {
            out = out
                .into_iter()
                .flat_map(|c| {
                    (0..d).map(move |v| {
                        let mut c2 = c.clone();
                        c2.push(v);
                        c2
                    })
                })
                .collect();
        }
        out
    }

    fn msg(&self, r1: usize, r2: usize, bytes: usize) -> f64 {
        self.link.msg(self.topo.same_node(r1, r2), bytes)
    }

    /// Worst-case single-hop cost of a cyclic shift along `dim`: the
    /// slowest (owner → target) link over the whole grid.
    fn shift_cost(&self, dim: usize, delta: isize, bytes: usize) -> f64 {
        let len = self.dims[dim] as isize;
        if len <= 1 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        for c in self.coords() {
            let mut t = c.clone();
            t[dim] = ((c[dim] as isize + delta).rem_euclid(len)) as usize;
            worst = worst.max(self.msg(self.rank_of(&c), self.rank_of(&t), bytes));
        }
        worst
    }

    /// Binomial-tree reduce along `dim`: `⌈log₂ len⌉` rounds, each a
    /// worst-line message plus one elementwise combine of the payload.
    fn reduce_cost(&self, dim: usize, bytes: usize, elems: usize) -> f64 {
        let len = self.dims[dim];
        let rounds = ceil_log2(len) as f64;
        rounds * (self.worst_line_link(dim, bytes) + elems as f64 / self.rate)
    }

    /// Binomial-tree broadcast along `dim` of a `bytes` payload.
    fn bcast_cost(&self, dim: usize, bytes: usize) -> f64 {
        ceil_log2(self.dims[dim]) as f64 * self.worst_line_link(dim, bytes)
    }

    /// Slowest pairwise link within any grid line along `dim`.
    fn worst_line_link(&self, dim: usize, bytes: usize) -> f64 {
        let len = self.dims[dim];
        if len <= 1 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        for c in self.coords() {
            if c[dim] != 0 {
                continue; // one representative per line
            }
            let line: Vec<usize> = (0..len)
                .map(|v| {
                    let mut t = c.clone();
                    t[dim] = v;
                    self.rank_of(&t)
                })
                .collect();
            for i in 0..len {
                for j in (i + 1)..len {
                    worst = worst.max(self.msg(line[i], line[j], bytes));
                }
            }
        }
        worst
    }
}

const F32_BYTES: usize = 4;

/// Modeled wall-clock of one plan replay (the candidate's `T_P`).
pub(crate) fn price(g: &PlanGraph, pc: &PriceCtx) -> f64 {
    let b = pc.block;
    let block_bytes = b * b * F32_BYTES;
    let block_elems = b * b;
    let eff = gemm_efficiency(b);

    let mut now = 0.0f64;
    // Split comm nodes in flight: (id, stage, ready_time).
    let mut pending: Vec<(NodeId, usize, f64)> = Vec::new();

    for &id in &g.order {
        let node = &g.nodes[id];
        let inputs = node.op.inputs();

        // Same FIFO wait rule as the interpreter.
        let mut last = None;
        for (i, e) in pending.iter().enumerate() {
            if inputs.contains(&e.0) || (node.op.is_comm() && e.1 < node.stage) {
                last = Some(i);
            }
        }
        if let Some(i) = last {
            for (_, _, ready) in pending.drain(..=i) {
                now = now.max(ready);
            }
        }

        // Cost of this node on the main (compute) or forked (split
        // comm) timeline.
        let cost = match &node.op {
            Op::Load(_) | Op::Hstack { .. } => 0.0,
            Op::Matmul { .. } => 2.0 * (b as f64).powi(3) / (pc.rate * eff),
            Op::MatmulPanel { part, parts, .. } => {
                let (lo, hi) = (part * b / parts, (part + 1) * b / parts);
                2.0 * (b * b * (hi - lo)) as f64 / (pc.rate * eff)
            }
            Op::Ew { .. } => block_elems as f64 / pc.rate,
            Op::FusedEw { ops, .. } => (block_elems * ops.len()) as f64 / pc.rate,
            Op::FwUpdate { .. } => 2.0 * block_elems as f64 / pc.rate,
            Op::Shift { dim, delta, .. } => pc.shift_cost(*dim, *delta, block_bytes),
            Op::Reduce { dim, .. } => {
                // A reduce of a panel moves the panel's bytes; infer the
                // payload from the producing node.
                let (bytes, elems) = match inputs
                    .first()
                    .map(|&i| &g.nodes[i].op)
                {
                    Some(Op::MatmulPanel { part, parts, .. }) => {
                        let (lo, hi) = (part * b / parts, (part + 1) * b / parts);
                        (b * (hi - lo) * F32_BYTES, b * (hi - lo))
                    }
                    _ => (block_bytes, block_elems),
                };
                pc.reduce_cost(*dim, bytes, elems)
            }
            Op::PivotRow { .. } | Op::PivotCol { .. } => {
                // Extract the b-element segment, then broadcast it along
                // the line (dim 0 for rows, 1 for cols).
                let dim = matches!(node.op, Op::PivotCol { .. }) as usize;
                b as f64 / pc.rate + pc.bcast_cost(dim, b * F32_BYTES)
            }
        };

        if node.split && node.op.is_comm() {
            pending.push((id, node.stage, now + cost));
        } else {
            now += cost;
        }
    }

    for (_, _, ready) in pending {
        now = now.max(ready);
    }
    now
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::cost::CostParams;
    use crate::plan::ir::{build_cannon, build_dns};
    use crate::plan::passes::overlap;

    fn pc(topo: &Topology, link: HierCost, dims: Vec<usize>) -> PriceCtx<'_> {
        let n: usize = dims.iter().product();
        PriceCtx {
            topo,
            link,
            rate: 1e10,
            block: 256,
            dims,
            ranks: (0..n).collect(),
        }
    }

    #[test]
    fn pipelined_cannon_priced_below_blocking_on_slow_net() {
        let topo = Topology::flat(16);
        let link = HierCost::flat(CostParams::new(5e-5, 1e-8));
        let blocking = build_cannon(4);
        let mut pipelined = build_cannon(4);
        assert!(overlap(&mut pipelined) > 0);
        let ctx = pc(&topo, link, vec![4, 4]);
        let tb = price(&blocking, &ctx);
        let tp = price(&pipelined, &ctx);
        assert!(tp < tb, "pipelined {tp} !< blocking {tb}");
    }

    #[test]
    fn free_network_ties_break_to_blocking() {
        // With zero-cost comm the overlapped schedule saves nothing; the
        // prices tie, so an argmin with strictly-lower wins keeps the
        // simpler blocking schedule.
        let topo = Topology::flat(16);
        let link = HierCost::flat(CostParams::free());
        let blocking = build_cannon(4);
        let mut pipelined = build_cannon(4);
        overlap(&mut pipelined);
        let ctx = pc(&topo, link, vec![4, 4]);
        assert_eq!(price(&blocking, &ctx), price(&pipelined, &ctx));
    }

    #[test]
    fn chunked_dns_price_hides_most_reduce_time() {
        let topo = Topology::flat(8);
        let link = HierCost::flat(CostParams::new(5e-5, 1e-8));
        let blocking = build_dns(2, 1);
        let mut chunked = build_dns(2, 4);
        assert!(overlap(&mut chunked) > 0);
        let ctx = pc(&topo, link, vec![2, 2, 2]);
        let tb = price(&blocking, &ctx);
        let tc = price(&chunked, &ctx);
        assert!(tc < tb, "chunked {tc} !< blocking {tb}");
    }

    #[test]
    fn hierarchical_links_price_cross_node_shifts_higher() {
        // 2x2 grid on one node vs split across two nodes: the same plan
        // must price higher when shifts cross the node boundary.
        let one_node = Topology::flat(4);
        let two_nodes = Topology::uniform(4, 2);
        let link = HierCost::hierarchical(CostParams::qdr_infiniband());
        let g = build_cannon(2);
        let t_one = price(&g, &pc(&one_node, link, vec![2, 2]));
        let t_two = price(&g, &pc(&two_nodes, link, vec![2, 2]));
        assert!(t_two > t_one, "cross-node {t_two} !> same-node {t_one}");
    }
}
