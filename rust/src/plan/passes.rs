//! Plan optimization passes: elementwise fusion and automatic
//! split-phase overlap.
//!
//! Both passes are pure graph rewrites — they run identically on every
//! rank from the graph alone (the SPMD-consistency rule: no rank may
//! make a schedule decision another rank can't reproduce without
//! communication).

use super::ir::{Node, Op, PlanGraph};

/// Fuse adjacent elementwise chains: an `Ew` node whose left input is
/// another `Ew`/`FusedEw` with no other consumer, recorded in the same
/// stage, folds into one [`Op::FusedEw`] — executed as a single
/// [`crate::matrix::gemm::ew_chain_mt_with`] pass.  Per-element fold
/// order is preserved, so fusion is bit-exact; only the intermediate
/// materializations disappear.  Returns the number of nodes fused away.
pub(crate) fn fuse(g: &mut PlanGraph) -> usize {
    let mut fused = 0;
    loop {
        let uses = g.use_counts();
        // Find a fusable pair: consumer `id` whose chain head `x` is a
        // dead-end elementwise node in the same stage.
        let mut target = None;
        for &id in &g.order {
            let (x, op, y) = match g.nodes[id].op {
                Op::Ew { op, x, y } => (x, op, y),
                _ => continue,
            };
            let same_stage = g.nodes[x].stage == g.nodes[id].stage;
            let single_use = uses[x] == 1 && x != g.output;
            let chainable = matches!(g.nodes[x].op, Op::Ew { .. } | Op::FusedEw { .. });
            if same_stage && single_use && chainable {
                target = Some((id, x, op, y));
                break;
            }
        }
        let Some((id, x, op, y)) = target else { return fused };
        let new_op = match g.nodes[x].op.clone() {
            Op::Ew { op: op0, x: x0, y: y0 } => {
                Op::FusedEw { x: x0, ops: vec![(op0, y0), (op, y)] }
            }
            Op::FusedEw { x: x0, mut ops } => {
                ops.push((op, y));
                Op::FusedEw { x: x0, ops }
            }
            _ => unreachable!(),
        };
        g.nodes[id].op = new_op;
        g.order.retain(|&n| n != x);
        fused += 1;
    }
}

/// Reachability: is `to` reachable from `from` along op inputs-to-output
/// edges?  (Graphs here are tens of nodes; a per-query DFS is fine.)
fn reaches(g: &PlanGraph, from: usize, to: usize) -> bool {
    if from == to {
        return true;
    }
    // consumers of `from`
    let mut stack = vec![from];
    let mut seen = vec![false; g.nodes.len()];
    seen[from] = true;
    while let Some(n) = stack.pop() {
        for (id, node) in g.nodes.iter().enumerate() {
            if !seen[id] && node.op.inputs().contains(&n) {
                if id == to {
                    return true;
                }
                seen[id] = true;
                stack.push(id);
            }
        }
    }
    false
}

/// Automatic overlap: mark a comm node split-phase when at least one
/// compute node independent of it (neither ancestor nor descendant) sits
/// between its position and its first consumer's stage — i.e. there is
/// real work to hide the transfer behind.  Split nodes are then hoisted
/// to the front of their stage (stopping at their producers and behind
/// earlier split comms), which is exactly the hand-written pipelined
/// shape: *start the shifts, compute, wait*.  Returns the number of
/// nodes split.
pub(crate) fn overlap(g: &mut PlanGraph) -> usize {
    let mut split = 0;
    let n = g.nodes.len();
    for id in 0..n {
        if !g.nodes[id].op.is_comm() {
            continue;
        }
        // Candidate overlap window: compute nodes in a stage >= the comm
        // node's stage but strictly before its first consumer.
        let first_consumer_stage = g
            .nodes
            .iter()
            .filter(|node| node.op.inputs().contains(&id))
            .map(|node| node.stage)
            .min();
        let comm_stage = g.nodes[id].stage;
        let hideable = (0..n).any(|z| {
            if !g.nodes[z].op.is_compute() {
                return false;
            }
            let zs = g.nodes[z].stage;
            let in_window = zs >= comm_stage
                && match first_consumer_stage {
                    Some(fc) => zs < fc || (zs == fc && fc > comm_stage),
                    None => true,
                };
            in_window && !reaches(g, id, z) && !reaches(g, z, id)
        });
        if hideable {
            g.nodes[id].split = true;
            split += 1;
        }
    }
    if split > 0 {
        hoist_split(g);
    }
    split
}

/// Move each split comm node as early as possible within its stage:
/// bubble it up past nodes that are not its ancestors, stopping behind
/// any earlier split comm (so start order matches record order — the
/// same FIFO the eager pipelined variants use).
fn hoist_split(g: &mut PlanGraph) {
    let order = std::mem::take(&mut g.order);
    let mut out: Vec<usize> = Vec::with_capacity(order.len());
    for id in order {
        out.push(id);
        let node: &Node = &g.nodes[id];
        if !(node.split && node.op.is_comm()) {
            continue;
        }
        let mut pos = out.len() - 1;
        while pos > 0 {
            let prev = out[pos - 1];
            let same_stage = g.nodes[prev].stage == node.stage;
            let prev_is_split_comm = g.nodes[prev].split && g.nodes[prev].op.is_comm();
            if !same_stage || prev_is_split_comm || reaches(g, prev, id) {
                break;
            }
            out.swap(pos - 1, pos);
            pos -= 1;
        }
    }
    g.order = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::plan::ir::{build_cannon, build_dns, build_fw, EwKind, PlanBuilder, SourceMap};

    #[test]
    fn fuse_collapses_elementwise_chain() {
        // (a + b) min c + d recorded in one stage fuses to one node.
        let mut p = PlanBuilder::new(vec![1, 1]);
        let a = p.load(SourceMap::DirectA);
        let b = p.load(SourceMap::DirectB);
        let c = p.load(SourceMap::DirectA);
        let d = p.load(SourceMap::DirectB);
        let s = p.ew(EwKind::Add, a, b);
        let m = p.ew(EwKind::Min, s, c);
        let out = p.ew(EwKind::Add, m, d);
        let mut g = p.finish(out);
        assert_eq!(fuse(&mut g), 2);
        assert_eq!(g.order.len(), 5); // 4 loads + 1 fused node
        match &g.nodes[g.output].op {
            Op::FusedEw { ops, .. } => {
                let kinds: Vec<EwKind> = ops.iter().map(|(k, _)| *k).collect();
                assert_eq!(kinds, vec![EwKind::Add, EwKind::Min, EwKind::Add]);
            }
            other => panic!("expected FusedEw, got {other:?}"),
        }
    }

    #[test]
    fn fuse_respects_fanout_and_stages() {
        // A chain whose head has a second consumer must not fuse.
        let mut p = PlanBuilder::new(vec![1, 1]);
        let a = p.load(SourceMap::DirectA);
        let b = p.load(SourceMap::DirectB);
        let s = p.ew(EwKind::Add, a, b);
        let (s1, s2) = p.dup(s);
        let c = p.load(SourceMap::DirectA);
        let t = p.ew(EwKind::Min, s1, c);
        let out = p.ew(EwKind::Add, t, s2);
        let mut g = p.finish(out);
        // `s` has two consumers → only t-into-out may fuse... but t's
        // chain head is s (2 uses), so t stays; out's head t has 1 use →
        // out fuses with t, whose input s remains materialized.
        assert_eq!(fuse(&mut g), 1);
        // Cross-stage chains never fuse.
        let mut p = PlanBuilder::new(vec![1, 1]);
        let a = p.load(SourceMap::DirectA);
        let b = p.load(SourceMap::DirectB);
        let s = p.ew(EwKind::Add, a, b);
        p.next_stage();
        let c = p.load(SourceMap::DirectA);
        let out = p.ew(EwKind::Min, s, c);
        let mut g = p.finish(out);
        assert_eq!(fuse(&mut g), 0);
    }

    #[test]
    fn cannon_accumulate_does_not_fuse() {
        // Cannon's adds chain across stages (each add consumes the
        // previous stage's accumulator) — fusing them would break the
        // shift pipeline, and the stage guard prevents it.
        let mut g = build_cannon(4);
        assert_eq!(fuse(&mut g), 0);
    }

    #[test]
    fn overlap_splits_cannon_shifts_and_hoists_them() {
        let mut g = build_cannon(3);
        let split = overlap(&mut g);
        assert_eq!(split, 4); // 2 shifts per non-final stage
        // In the rewritten order, each stage's shifts precede its matmul,
        // preserving shift-A-before-shift-B record order.
        let pos = |id: usize| g.order.iter().position(|&n| n == id).unwrap();
        for (id, node) in g.nodes.iter().enumerate() {
            if let Op::Shift { .. } = node.op {
                assert!(node.split);
                // find this stage's matmul
                let mm = g
                    .nodes
                    .iter()
                    .enumerate()
                    .find(|(_, n)| matches!(n.op, Op::Matmul { .. }) && n.stage == node.stage)
                    .map(|(i, _)| i)
                    .unwrap();
                assert!(pos(id) < pos(mm), "shift {id} must start before matmul {mm}");
            }
        }
    }

    #[test]
    fn overlap_pipelines_chunked_dns_reductions() {
        let mut g = build_dns(2, 3);
        let split = overlap(&mut g);
        // Each panel reduce except the last hides behind the next
        // panel's GEMM; the last has nothing left to overlap, and a
        // blocking reduce costs exactly what the eager pipelined
        // variant's degenerate start-then-wait pair costs.
        assert_eq!(split, 2);
    }

    #[test]
    fn blocking_dns_has_nothing_to_overlap() {
        // One GEMM, one reduce, both in stage 0, GEMM is the reduce's
        // ancestor: no independent compute exists to hide behind.
        let mut g = build_dns(2, 1);
        assert_eq!(overlap(&mut g), 0);
    }

    #[test]
    fn fw_pivot_broadcasts_do_not_split() {
        // Alg. 3's per-pivot broadcasts feed the same stage's update,
        // and the prior update is their ancestor — there is no
        // independent compute window, so the pass must leave them
        // blocking (the eager FW shape).
        let mut g = build_fw(4, 2);
        assert_eq!(overlap(&mut g), 0);
    }
}
