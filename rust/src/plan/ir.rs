//! Plan IR: the deferred-execution DAG recorded over the distributed
//! collections.
//!
//! A [`PlanGraph`] is a flat arena of [`Node`]s plus an execution
//! `order`.  Nodes describe the same operations the eager algorithms
//! perform — block loads, (panel) GEMMs, elementwise combines, grid-line
//! shifts / reductions / pivot broadcasts, the FW update — but nothing
//! executes at build time; the interpreter ([`crate::plan::exec`])
//! replays the order against a live [`crate::data::grid::GridN`], and
//! the pricer ([`crate::plan::cost`]) replays it against the
//! virtual-clock cost model with zero data movement.
//!
//! **Ownership convention.**  Exactly like the `DistSeq` group
//! operations (the PR-3 convention documented in
//! [`crate::data::dseq`]), every [`PlanBuilder`] combinator **consumes**
//! its operand handles: a [`PlanRef`] is used once, chains read
//! left-to-right, and sharing a value between two consumers must be
//! explicit via [`PlanBuilder::dup`] — the plan-level analogue of the
//! `.clone()` an eager pipelined schedule performs before shifting a
//! block it still needs.  This keeps the recorded DAG's fan-out visible
//! in the source the same way the eager code's clones are.

pub use crate::matrix::gemm::EwKind;

/// Index of a node in its [`PlanGraph`] arena.
pub type NodeId = usize;

/// How a `Load` node maps a grid coordinate to a source block — the
/// initial data placements of the algorithms the planner schedules.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SourceMap {
    /// Cannon's skewed A placement: block `(i, (j + i) mod q)` at
    /// coordinate `(i, j)`.
    CannonA,
    /// Cannon's skewed B placement: block `((i + j) mod q, j)`.
    CannonB,
    /// DNS A placement on the cube: block `(i, k)` at `(i, j, k)`.
    DnsA,
    /// DNS B placement on the cube: block `(k, j)` at `(i, j, k)`.
    DnsB,
    /// Unskewed block `(i, j)` of A — building block for plain
    /// elementwise plans (fusion tests and custom DAGs).
    DirectA,
    /// Unskewed block `(i, j)` of B.
    DirectB,
    /// The FW distance block `(i, j)`.
    Fw,
}

/// One deferred operation.  Comm nodes (`Shift`, `Reduce`, `PivotRow`,
/// `PivotCol`) may be marked split-phase by the overlap pass; compute
/// nodes execute inline.
#[derive(Clone, Debug)]
pub enum Op {
    /// Materialize this rank's source block (lazy SPMD: only the owner
    /// generates, exactly like `GridN::map_d`).
    Load(SourceMap),
    /// Block product `a · b`.
    Matmul { a: NodeId, b: NodeId },
    /// Column panel `part` of `parts` of the product `a · b` (the
    /// pipelined-DNS chunking unit).
    MatmulPanel { a: NodeId, b: NodeId, part: usize, parts: usize },
    /// Elementwise combine `x ⊕ y`.
    Ew { op: EwKind, x: NodeId, y: NodeId },
    /// Fused chain `((x ⊕₁ m₁) ⊕₂ m₂) …` — produced by the fuse pass,
    /// never recorded directly.
    FusedEw { x: NodeId, ops: Vec<(EwKind, NodeId)> },
    /// Cyclic shift of `x` along grid dimension `dim` by `delta`.
    Shift { x: NodeId, dim: usize, delta: isize },
    /// Reduce `x` along `dim` with `⊕` onto the line root.
    Reduce { x: NodeId, dim: usize, op: EwKind },
    /// Broadcast row `kloc` of line element `kb` along dimension 0
    /// (Alg. 3's pivot-row `xSeq.apply`); yields a `Seg`.
    PivotRow { x: NodeId, kb: usize, kloc: usize },
    /// Broadcast column `kloc` of line element `kb` along dimension 1
    /// (Alg. 3's pivot-column `ySeq.apply`); yields a `Seg`.
    PivotCol { x: NodeId, kb: usize, kloc: usize },
    /// FW pivot update of block `d` with pivot segments `ik`/`kj`.
    FwUpdate { d: NodeId, ik: NodeId, kj: NodeId },
    /// Reassemble column panels into one block (pipelined DNS epilogue).
    Hstack { parts: Vec<NodeId> },
}

impl Op {
    /// The node ids this op consumes, in consumption order.
    pub fn inputs(&self) -> Vec<NodeId> {
        match self {
            Op::Load(_) => vec![],
            Op::Matmul { a, b } | Op::MatmulPanel { a, b, .. } => vec![*a, *b],
            Op::Ew { x, y, .. } => vec![*x, *y],
            Op::FusedEw { x, ops } => {
                let mut v = vec![*x];
                v.extend(ops.iter().map(|(_, n)| *n));
                v
            }
            Op::Shift { x, .. } | Op::Reduce { x, .. } => vec![*x],
            Op::PivotRow { x, .. } | Op::PivotCol { x, .. } => vec![*x],
            Op::FwUpdate { d, ik, kj } => vec![*d, *ik, *kj],
            Op::Hstack { parts } => parts.clone(),
        }
    }

    /// Does this op communicate (and may therefore be split-phase)?
    pub fn is_comm(&self) -> bool {
        matches!(
            self,
            Op::Shift { .. } | Op::Reduce { .. } | Op::PivotRow { .. } | Op::PivotCol { .. }
        )
    }

    /// Does this op burn kernel time?
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Op::Matmul { .. }
                | Op::MatmulPanel { .. }
                | Op::Ew { .. }
                | Op::FusedEw { .. }
                | Op::FwUpdate { .. }
        )
    }
}

/// One node: the op, the pipeline stage it was recorded in (the loop
/// iteration of the algorithm builder — overlap never crosses into an
/// earlier stage's comm), and whether the overlap pass split it into a
/// `*_start`/`wait()` pair.
#[derive(Clone, Debug)]
pub struct Node {
    pub op: Op,
    pub stage: usize,
    pub split: bool,
}

/// The recorded DAG plus its execution order.  `order` starts as record
/// order; the overlap pass hoists split comm nodes within their stage.
#[derive(Clone, Debug)]
pub struct PlanGraph {
    pub nodes: Vec<Node>,
    pub order: Vec<NodeId>,
    pub output: NodeId,
    /// Grid shape the plan executes on.
    pub dims: Vec<usize>,
}

impl PlanGraph {
    /// Remaining-consumer count per node (output counts as one) — the
    /// interpreter clones a shared value until its last consumer, which
    /// takes it (mirroring the eager code's explicit clones).
    pub fn use_counts(&self) -> Vec<usize> {
        let mut uses = vec![0usize; self.nodes.len()];
        for n in &self.nodes {
            for i in n.op.inputs() {
                uses[i] += 1;
            }
        }
        uses[self.output] += 1;
        uses
    }
}

/// Records a [`PlanGraph`].  See the module docs for the consume-`self`
/// handle convention.
pub struct PlanBuilder {
    nodes: Vec<Node>,
    stage: usize,
    dims: Vec<usize>,
}

/// A handle to a recorded node.  Deliberately neither `Copy` nor
/// `Clone`: each handle is consumed by exactly one combinator, and
/// fan-out is explicit through [`PlanBuilder::dup`].
#[must_use = "a plan handle describes deferred work; consume it with a combinator or finish()"]
pub struct PlanRef {
    id: NodeId,
}

impl PlanBuilder {
    pub fn new(dims: Vec<usize>) -> Self {
        PlanBuilder { nodes: Vec::new(), stage: 0, dims }
    }

    fn push(&mut self, op: Op) -> PlanRef {
        let id = self.nodes.len();
        self.nodes.push(Node { op, stage: self.stage, split: false });
        PlanRef { id }
    }

    /// Advance the stage counter — called once per algorithm loop
    /// iteration so the overlap pass knows which comm belongs to which
    /// pipeline step.
    pub fn next_stage(&mut self) {
        self.stage += 1;
    }

    /// Explicit fan-out: two handles to the same node (the plan-level
    /// `.clone()`).  The interpreter materializes the extra use as a
    /// cheap Arc bump, exactly like the eager pipelined code's clone
    /// before a shift.
    pub fn dup(&mut self, r: PlanRef) -> (PlanRef, PlanRef) {
        (PlanRef { id: r.id }, PlanRef { id: r.id })
    }

    pub fn load(&mut self, src: SourceMap) -> PlanRef {
        self.push(Op::Load(src))
    }

    pub fn matmul(&mut self, a: PlanRef, b: PlanRef) -> PlanRef {
        self.push(Op::Matmul { a: a.id, b: b.id })
    }

    pub fn matmul_panel(&mut self, a: PlanRef, b: PlanRef, part: usize, parts: usize) -> PlanRef {
        assert!(part < parts, "panel {part} of {parts}");
        self.push(Op::MatmulPanel { a: a.id, b: b.id, part, parts })
    }

    pub fn ew(&mut self, op: EwKind, x: PlanRef, y: PlanRef) -> PlanRef {
        self.push(Op::Ew { op, x: x.id, y: y.id })
    }

    pub fn shift(&mut self, x: PlanRef, dim: usize, delta: isize) -> PlanRef {
        assert!(dim < self.dims.len());
        self.push(Op::Shift { x: x.id, dim, delta })
    }

    pub fn reduce(&mut self, x: PlanRef, dim: usize, op: EwKind) -> PlanRef {
        assert!(dim < self.dims.len());
        self.push(Op::Reduce { x: x.id, dim, op })
    }

    pub fn pivot_row(&mut self, x: PlanRef, kb: usize, kloc: usize) -> PlanRef {
        self.push(Op::PivotRow { x: x.id, kb, kloc })
    }

    pub fn pivot_col(&mut self, x: PlanRef, kb: usize, kloc: usize) -> PlanRef {
        self.push(Op::PivotCol { x: x.id, kb, kloc })
    }

    pub fn fw_update(&mut self, d: PlanRef, ik: PlanRef, kj: PlanRef) -> PlanRef {
        self.push(Op::FwUpdate { d: d.id, ik: ik.id, kj: kj.id })
    }

    pub fn hstack(&mut self, parts: Vec<PlanRef>) -> PlanRef {
        let ids = parts.into_iter().map(|p| p.id).collect();
        self.push(Op::Hstack { parts: ids })
    }

    /// Seal the graph; `order` is record order until a pass rewrites it.
    pub fn finish(self, output: PlanRef) -> PlanGraph {
        let order = (0..self.nodes.len()).collect();
        PlanGraph { nodes: self.nodes, order, output: output.id, dims: self.dims }
    }
}

// ------------------------------------------------- algorithm builders

/// Cannon's algorithm on a q×q grid: skewed loads, then q steps of
/// multiply-accumulate with cyclic shifts of A (along dim 1) and B
/// (along dim 0) between steps — the exact op sequence of the eager
/// `cannon_on_grid`.
pub(crate) fn build_cannon(q: usize) -> PlanGraph {
    let mut p = PlanBuilder::new(vec![q, q]);
    let mut a = p.load(SourceMap::CannonA);
    let mut b = p.load(SourceMap::CannonB);
    let mut acc: Option<PlanRef> = None;
    for step in 0..q {
        let (prod, next) = if step + 1 == q {
            // Last step: no further shift, the operands die here.
            (p.matmul(a, b), None)
        } else {
            let (a_mm, a_sh) = p.dup(a);
            let (b_mm, b_sh) = p.dup(b);
            (p.matmul(a_mm, b_mm), Some((a_sh, b_sh)))
        };
        acc = Some(match acc {
            None => prod,
            Some(c) => p.ew(EwKind::Add, c, prod),
        });
        if let Some((a_sh, b_sh)) = next {
            a = p.shift(a_sh, 1, -1);
            b = p.shift(b_sh, 0, -1);
            p.next_stage();
        }
    }
    p.finish(acc.expect("q >= 1"))
}

/// DNS on a q×q×q cube: one local (panel) product per rank, reduced
/// along z.  `panels == 1` is the blocking Alg. 2 shape; `panels > 1`
/// records the panel-chunked shape whose per-panel reductions the
/// overlap pass pipelines (the eager `mmm_dns_pipelined` structure).
pub(crate) fn build_dns(q: usize, panels: usize) -> PlanGraph {
    assert!(panels >= 1);
    let mut p = PlanBuilder::new(vec![q, q, q]);
    let mut a = p.load(SourceMap::DnsA);
    let mut b = p.load(SourceMap::DnsB);
    if panels == 1 {
        let prod = p.matmul(a, b);
        let c = p.reduce(prod, 2, EwKind::Add);
        return p.finish(c);
    }
    let mut parts = Vec::with_capacity(panels);
    for part in 0..panels {
        let (a_use, a_keep) = p.dup(a);
        let (b_use, b_keep) = p.dup(b);
        a = a_keep;
        b = b_keep;
        let prod = p.matmul_panel(a_use, b_use, part, panels);
        parts.push(p.reduce(prod, 2, EwKind::Add));
        p.next_stage();
    }
    // The final dup pair of a/b is unused by construction; the handles
    // die here without a consumer, matching the eager code where the
    // last panel simply reads the blocks one more time.
    drop((a, b));
    let h = p.hstack(parts);
    p.finish(h)
}

/// Blocked Floyd–Warshall on a q×q grid over an n-vertex graph: n pivot
/// stages of row/column broadcast + update (Alg. 3).
pub(crate) fn build_fw(n: usize, q: usize) -> PlanGraph {
    assert_eq!(n % q, 0, "n must divide into q×q blocks");
    let b = n / q;
    let mut p = PlanBuilder::new(vec![q, q]);
    let mut d = p.load(SourceMap::Fw);
    for k in 0..n {
        let (kb, kloc) = (k / b, k % b);
        let (d_row, rest) = p.dup(d);
        let (d_col, d_upd) = p.dup(rest);
        let ik = p.pivot_row(d_row, kb, kloc);
        let kj = p.pivot_col(d_col, kb, kloc);
        d = p.fw_update(d_upd, ik, kj);
        p.next_stage();
    }
    p.finish(d)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cannon_graph_shape() {
        let g = build_cannon(3);
        // 2 loads + 3 matmuls + 2 adds + 2*2 shifts
        assert_eq!(g.nodes.len(), 11);
        assert_eq!(g.order.len(), g.nodes.len());
        assert_eq!(g.nodes.iter().filter(|n| n.op.is_comm()).count(), 4);
        assert_eq!(g.nodes[g.output].stage, 2);
        // every input id precedes its consumer in record order
        for (id, n) in g.nodes.iter().enumerate() {
            for i in n.op.inputs() {
                assert!(i < id);
            }
        }
    }

    #[test]
    fn dns_graph_shapes() {
        let blocking = build_dns(2, 1);
        assert_eq!(blocking.nodes.len(), 4); // 2 loads, matmul, reduce
        let chunked = build_dns(2, 3);
        // 2 loads + 3*(panel + reduce) + hstack
        assert_eq!(chunked.nodes.len(), 9);
        assert!(matches!(chunked.nodes[chunked.output].op, Op::Hstack { .. }));
    }

    #[test]
    fn fw_graph_shape() {
        let g = build_fw(4, 2);
        // load + 4 stages of (row, col, update)
        assert_eq!(g.nodes.len(), 13);
        assert_eq!(g.nodes[g.output].stage, 3);
    }

    #[test]
    fn use_counts_see_dup_fanout() {
        let g = build_cannon(2);
        let uses = g.use_counts();
        // the two loads feed both the first matmul and the first shifts
        assert_eq!(uses[0], 2);
        assert_eq!(uses[1], 2);
        assert_eq!(uses[g.output], 1);
    }
}
