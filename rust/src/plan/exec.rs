//! The plan interpreter: replays a [`PlanGraph`]'s order against a live
//! grid, issuing exactly the `DistSeq` operations the eager algorithms
//! perform.
//!
//! **Split-phase replay.**  Comm nodes the overlap pass marked `split`
//! are issued with the `*_start` forms and pushed on a FIFO of pending
//! handles.  Before executing any node the interpreter drains the
//! pending prefix up to the last entry that is either (a) an input of
//! the node, or (b) — when the node is itself a comm op — an entry from
//! an earlier stage (the single-outstanding-window discipline of the
//! hand-written pipelined variants: a new transfer never overtakes the
//! previous stage's).  Draining is strictly FIFO, so waits happen in
//! start order — the SPMD handle discipline [`crate::data::dseq`]
//! requires.  On the overlap-aware clock this replay is step-for-step
//! identical to the eager pipelined schedules (a comm the pass left
//! blocking costs exactly what a degenerate start-then-wait pair
//! costs), and values are bit-identical because every kernel sees the
//! same operands in the same fold order.

use crate::algos::floyd_warshall::FwSource;
use crate::data::grid::GridN;
use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::{Compute, Seg};
use crate::spmd::Ctx;

use super::ir::{NodeId, Op, PlanGraph, SourceMap};

/// Where `Load` nodes find their blocks — the spec inputs of the two
/// plan families.
pub(crate) enum Sources<'s> {
    /// Matrix product inputs: `q × q` blocks of A and B.
    Mm { a: &'s BlockSource, b: &'s BlockSource, q: usize },
    /// Floyd–Warshall distance matrix, block edge `b`.
    Fw { src: &'s FwSource, b: usize },
}

impl Sources<'_> {
    fn load(&self, map: SourceMap, c: &[usize]) -> Block {
        match (self, map) {
            (Sources::Mm { a, q, .. }, SourceMap::CannonA) => a.block(c[0], (c[1] + c[0]) % q),
            (Sources::Mm { b, q, .. }, SourceMap::CannonB) => b.block((c[0] + c[1]) % q, c[1]),
            (Sources::Mm { a, .. }, SourceMap::DnsA) => a.block(c[0], c[2]),
            (Sources::Mm { b, .. }, SourceMap::DnsB) => b.block(c[2], c[1]),
            (Sources::Mm { a, .. }, SourceMap::DirectA) => a.block(c[0], c[1]),
            (Sources::Mm { b, .. }, SourceMap::DirectB) => b.block(c[0], c[1]),
            (Sources::Fw { src, b }, SourceMap::Fw) => src.block(c[0], c[1], *b),
            (_, map) => panic!("source map {map:?} does not match the plan's sources"),
        }
    }
}

/// A node's value on this rank: `None` on non-members (the SPMD no-op
/// convention), a block or a pivot segment on members.
#[derive(Clone)]
enum Val {
    Blk(Option<Block>),
    Seg(Option<Seg>),
}

impl Val {
    fn blk(self) -> Option<Block> {
        match self {
            Val::Blk(b) => b,
            Val::Seg(_) => panic!("expected a block value, found a segment"),
        }
    }

    fn seg(self) -> Option<Seg> {
        match self {
            Val::Seg(s) => s,
            Val::Blk(_) => panic!("expected a segment value, found a block"),
        }
    }
}

/// Per-node value store with remaining-use counts: a shared value is
/// cloned (an Arc bump — uncharged, exactly the eager pipelined code's
/// explicit `.clone()` before a shift) until its last consumer takes it.
struct Env {
    vals: Vec<Option<Val>>,
    uses: Vec<usize>,
}

impl Env {
    fn put(&mut self, id: NodeId, v: Val) {
        self.vals[id] = Some(v);
    }

    fn take(&mut self, id: NodeId) -> Val {
        let n = self.uses[id];
        assert!(n > 0, "plan node {id} consumed more times than recorded");
        self.uses[id] = n - 1;
        if n == 1 {
            self.vals[id].take().expect("plan value consumed before it was produced")
        } else {
            self.vals[id].clone().expect("plan value consumed before it was produced")
        }
    }

    fn take_blk(&mut self, id: NodeId) -> Option<Block> {
        self.take(id).blk()
    }
}

/// An in-flight split-phase comm node.
enum PendingOp<'a, 'f> {
    Shift(crate::data::dseq::PendingSeq<'a, Block>),
    Reduce(crate::data::dseq::PendingReduce<'a, 'f, Block>),
    Apply(crate::data::dseq::PendingApply<'a, Seg>),
}

struct PendingEntry<'a, 'f> {
    id: NodeId,
    stage: usize,
    op: PendingOp<'a, 'f>,
}

fn drain_through(pending: &mut Vec<PendingEntry>, upto: usize, env: &mut Env) {
    for e in pending.drain(..=upto) {
        let val = match e.op {
            PendingOp::Shift(h) => Val::Blk(h.wait().into_local()),
            PendingOp::Reduce(h) => Val::Blk(h.wait()),
            PendingOp::Apply(h) => Val::Seg(h.wait()),
        };
        env.put(e.id, val);
    }
}

/// Rebuild a [`GridN::map_d`]-shaped distribution from this rank's
/// (optional) value — the bridge from the env back into the `DistSeq`
/// group operations.  Members always hold `Some`; the closure never runs
/// on non-members, whose chains stay inert.
fn regrid<'a>(
    grid: &GridN<'a>,
    v: Option<Block>,
) -> crate::data::grid::GridData<'a, Block> {
    grid.map_d(move |_| v.expect("grid member lost its block"))
}

/// Execute the plan on `grid`; returns this rank's output value (`None`
/// on ranks the output placement skips) at whatever virtual time the
/// replay reaches.
pub(crate) fn interpret<'a>(
    ctx: &'a Ctx,
    comp: &'a Compute,
    g: &PlanGraph,
    grid: &GridN<'a>,
    srcs: &Sources<'_>,
) -> Option<Block> {
    assert_eq!(g.dims, grid.dims(), "plan recorded for a different grid shape");
    let mut env = Env { vals: vec![None; g.nodes.len()], uses: g.use_counts() };
    let mut pending: Vec<PendingEntry<'a, '_>> = Vec::new();

    for &id in &g.order {
        let node = &g.nodes[id];
        let inputs = node.op.inputs();

        // Unified FIFO wait rule (see module docs).
        let mut last = None;
        for (i, e) in pending.iter().enumerate() {
            if inputs.contains(&e.id) || (node.op.is_comm() && e.stage < node.stage) {
                last = Some(i);
            }
        }
        if let Some(i) = last {
            drain_through(&mut pending, i, &mut env);
        }

        match &node.op {
            Op::Load(map) => {
                let v = grid.map_d(|c| srcs.load(*map, c)).into_local();
                env.put(id, Val::Blk(v));
            }
            Op::Matmul { a, b } => {
                let (av, bv) = (env.take_blk(*a), env.take_blk(*b));
                let out = match (av, bv) {
                    (Some(x), Some(y)) => Some(comp.matmul(ctx, &x, &y)),
                    _ => None,
                };
                env.put(id, Val::Blk(out));
            }
            Op::MatmulPanel { a, b, part, parts } => {
                let (av, bv) = (env.take_blk(*a), env.take_blk(*b));
                let out = match (av, bv) {
                    (Some(x), Some(y)) => {
                        let bcols = y.cols();
                        let (lo, hi) = (part * bcols / parts, (part + 1) * bcols / parts);
                        Some(comp.matmul_panel(ctx, &x, &y, lo, hi))
                    }
                    _ => None,
                };
                env.put(id, Val::Blk(out));
            }
            Op::Ew { op, x, y } => {
                let (xv, yv) = (env.take_blk(*x), env.take_blk(*y));
                let out = match (xv, yv) {
                    (Some(x), Some(y)) => Some(comp.ew(ctx, x, y, *op)),
                    _ => None,
                };
                env.put(id, Val::Blk(out));
            }
            Op::FusedEw { x, ops } => {
                let base = env.take_blk(*x);
                let args: Vec<(super::ir::EwKind, Option<Block>)> =
                    ops.iter().map(|(op, n)| (*op, env.take_blk(*n))).collect();
                let out = base.map(|b| {
                    let owned: Vec<_> = args
                        .into_iter()
                        .map(|(op, v)| (op, v.expect("fused operand missing on member")))
                        .collect();
                    comp.ew_chain(ctx, b, &owned)
                });
                env.put(id, Val::Blk(out));
            }
            Op::Shift { x, dim, delta } => {
                let seq = regrid(grid, env.take_blk(*x)).into_seq_along(*dim);
                if node.split {
                    let h = seq.shift_d_start(*delta);
                    pending.push(PendingEntry {
                        id,
                        stage: node.stage,
                        op: PendingOp::Shift(h),
                    });
                } else {
                    env.put(id, Val::Blk(seq.shift_d(*delta).into_local()));
                }
            }
            Op::Reduce { x, dim, op } => {
                let op = *op;
                let seq = regrid(grid, env.take_blk(*x)).into_seq_along(*dim);
                if node.split {
                    let h = seq.reduce_d_start(move |x, y| comp.ew(ctx, x, y, op));
                    pending.push(PendingEntry {
                        id,
                        stage: node.stage,
                        op: PendingOp::Reduce(h),
                    });
                } else {
                    env.put(id, Val::Blk(seq.reduce_d(|x, y| comp.ew(ctx, x, y, op))));
                }
            }
            Op::PivotRow { x, kb, kloc } => {
                let kloc = *kloc;
                let seq = regrid(grid, env.take_blk(*x))
                    .into_seq_along(0)
                    .map_d(|blk| comp.block_row(ctx, &blk, kloc));
                if node.split {
                    let h = seq.apply_start(*kb);
                    pending.push(PendingEntry {
                        id,
                        stage: node.stage,
                        op: PendingOp::Apply(h),
                    });
                } else {
                    env.put(id, Val::Seg(seq.apply(*kb)));
                }
            }
            Op::PivotCol { x, kb, kloc } => {
                let kloc = *kloc;
                let seq = regrid(grid, env.take_blk(*x))
                    .into_seq_along(1)
                    .map_d(|blk| comp.block_col(ctx, &blk, kloc));
                if node.split {
                    let h = seq.apply_start(*kb);
                    pending.push(PendingEntry {
                        id,
                        stage: node.stage,
                        op: PendingOp::Apply(h),
                    });
                } else {
                    env.put(id, Val::Seg(seq.apply(*kb)));
                }
            }
            Op::FwUpdate { d, ik, kj } => {
                let dv = env.take_blk(*d);
                let ikv = env.take(*ik).seg();
                let kjv = env.take(*kj).seg();
                let out = dv.map(|blk| match (&ikv, &kjv) {
                    (Some(ik), Some(kj)) => comp.fw_update(ctx, blk, ik, kj),
                    _ => blk,
                });
                env.put(id, Val::Blk(out));
            }
            Op::Hstack { parts } => {
                let vals: Vec<Option<Block>> =
                    parts.iter().map(|&p| env.take_blk(p)).collect();
                let out = if vals.iter().all(Option::is_some) {
                    Some(Block::hstack(vals.into_iter().map(Option::unwrap).collect()))
                } else {
                    None
                };
                env.put(id, Val::Blk(out));
            }
        }
    }

    // Every member waits every handle — drain whatever the wait rule
    // left outstanding (in the common schedules this is empty: the last
    // stage's comms are blocking or drained by their consumers).
    if !pending.is_empty() {
        let upto = pending.len() - 1;
        drain_through(&mut pending, upto, &mut env);
    }

    env.take_blk(g.output)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::cannon;
    use crate::algos::mmm_dns;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::plan::ir::{build_cannon, build_dns, build_fw, EwKind, PlanBuilder};
    use crate::plan::passes::{fuse, overlap};
    use crate::testing::spmd_run as run;

    fn fixed() -> BackendProfile {
        BackendProfile::openmpi_fixed()
    }

    #[test]
    fn interpreted_cannon_bit_identical_to_eager() {
        for q in [1usize, 2, 3] {
            let bsz = 6;
            let a = BlockSource::real(bsz, 70 + q as u64);
            let b = BlockSource::real(bsz, 80 + q as u64);
            let eager = run(q * q, fixed(), CostParams::free(), |ctx| {
                cannon::cannon_on_grid(ctx, &Compute::Native, q, &a, &b, &GridN::square(ctx, q))
            });
            let plan = run(q * q, fixed(), CostParams::free(), |ctx| {
                let g = build_cannon(q);
                let grid = GridN::square(ctx, q);
                let srcs = Sources::Mm { a: &a, b: &b, q };
                interpret(ctx, &Compute::Native, &g, &grid, &srcs)
            });
            for (e, p) in eager.results.iter().zip(&plan.results) {
                match (&e.c_block, p) {
                    (Some((_, _, x)), Some(y)) => assert_eq!(x, y, "q={q}"),
                    (None, None) => {}
                    _ => panic!("placement diverged at q={q}"),
                }
            }
        }
    }

    #[test]
    fn interpreted_pipelined_cannon_matches_eager_clocks_exactly() {
        // Slow network + modeled compute: if the replay's start/wait
        // order deviated from the eager pipelined schedule anywhere, the
        // overlap-aware clocks would differ.
        let q = 3;
        let machine = CostParams::new(5e-5, 1e-8);
        let comp = Compute::Modeled { rate: 1e10 };
        let a = BlockSource::proxy(128, 1);
        let b = BlockSource::proxy(128, 2);
        let eager = run(q * q, fixed(), machine, |ctx| {
            cannon::cannon_pipelined_eager(ctx, &comp, q, &a, &b).t_local
        });
        let plan = run(q * q, fixed(), machine, |ctx| {
            let mut g = build_cannon(q);
            assert!(overlap(&mut g) > 0);
            let grid = GridN::square(ctx, q);
            let srcs = Sources::Mm { a: &a, b: &b, q };
            let _ = interpret(ctx, &comp, &g, &grid, &srcs);
            ctx.now()
        });
        for (rank, (e, p)) in eager.results.iter().zip(&plan.results).enumerate() {
            assert!((e - p).abs() < 1e-12, "rank {rank}: eager {e} vs plan {p}");
        }
    }

    #[test]
    fn interpreted_pipelined_dns_matches_eager_clocks_exactly() {
        let (q, chunks) = (2, 3);
        let machine = CostParams::new(5e-5, 1e-8);
        let comp = Compute::Modeled { rate: 1e10 };
        let a = BlockSource::proxy(64, 1);
        let b = BlockSource::proxy(64, 2);
        let eager = run(q * q * q, fixed(), machine, |ctx| {
            mmm_dns::dns_pipelined_eager(ctx, &comp, q, &a, &b, chunks).t_local
        });
        let plan = run(q * q * q, fixed(), machine, |ctx| {
            let mut g = build_dns(q, chunks.min(64).max(1));
            assert!(overlap(&mut g) > 0);
            let grid = GridN::cube(ctx, q);
            let srcs = Sources::Mm { a: &a, b: &b, q };
            let _ = interpret(ctx, &comp, &g, &grid, &srcs);
            ctx.now()
        });
        for (rank, (e, p)) in eager.results.iter().zip(&plan.results).enumerate() {
            assert!((e - p).abs() < 1e-12, "rank {rank}: eager {e} vs plan {p}");
        }
    }

    #[test]
    fn fused_ew_chain_bit_identical_across_par_threshold() {
        // The fused `ew_chain` kernel switches to the parallel row-split
        // path at EW_PAR_THRESHOLD elements; both sides of the boundary
        // must reproduce the unfused per-op results bit for bit.
        let edge = (crate::matrix::gemm::EW_PAR_THRESHOLD as f64).sqrt() as usize;
        for bsz in [edge - 1, edge] {
            let a = BlockSource::real(bsz, 91);
            let b = BlockSource::real(bsz, 92);
            let build = || {
                let mut p = PlanBuilder::new(vec![1, 1]);
                let la = p.load(SourceMap::DirectA);
                let lb = p.load(SourceMap::DirectB);
                let lc = p.load(SourceMap::DirectA);
                let s = p.ew(EwKind::Add, la, lb);
                let m = p.ew(EwKind::Min, s, lc);
                p.finish(m)
            };
            let unfused = run(1, fixed(), CostParams::free(), |ctx| {
                let g = build();
                let grid = GridN::square(ctx, 1);
                let srcs = Sources::Mm { a: &a, b: &b, q: 1 };
                interpret(ctx, &Compute::Native, &g, &grid, &srcs)
            });
            let fused = run(1, fixed(), CostParams::free(), |ctx| {
                let mut g = build();
                assert_eq!(fuse(&mut g), 1);
                let grid = GridN::square(ctx, 1);
                let srcs = Sources::Mm { a: &a, b: &b, q: 1 };
                interpret(ctx, &Compute::Native, &g, &grid, &srcs)
            });
            assert_eq!(unfused.results, fused.results, "bsz={bsz}");
        }
    }

    #[test]
    fn interpreted_fw_bit_identical_to_eager() {
        use crate::algos::floyd_warshall::fw_on_grid;
        let (n, q) = (8usize, 2usize);
        let src = FwSource::Real { n, density: 0.4, seed: 9 };
        let eager = run(q * q, fixed(), CostParams::free(), |ctx| {
            fw_on_grid(ctx, &Compute::Native, q, &src, &GridN::square(ctx, q))
        });
        let plan = run(q * q, fixed(), CostParams::free(), |ctx| {
            let g = build_fw(n, q);
            let grid = GridN::square(ctx, q);
            let srcs = Sources::Fw { src: &src, b: n / q };
            interpret(ctx, &Compute::Native, &g, &grid, &srcs)
        });
        for (e, p) in eager.results.iter().zip(&plan.results) {
            match (&e.d_block, p) {
                (Some((_, _, x)), Some(y)) => {
                    assert_eq!(x.materialize().data, y.materialize().data)
                }
                (None, None) => {}
                _ => panic!("placement diverged"),
            }
        }
    }
}
