//! Artifact discovery: locate and enumerate `artifacts/*.hlo.txt`.
//!
//! The AOT pipeline (`python/compile/aot.py`) emits one HLO-text module
//! per (operation, block-size) pair, named `<op>_b<edge>.hlo.txt`, plus a
//! `manifest.json`.  This module finds the directory and parses the names
//! back; [`super::engine`] compiles them on demand.

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// Block operations with AOT artifacts.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Op {
    Matmul,
    MatmulAcc,
    Add,
    FwUpdate,
    MinPlus,
}

impl Op {
    pub fn stem(&self) -> &'static str {
        match self {
            Op::Matmul => "matmul",
            Op::MatmulAcc => "matmul_acc",
            Op::Add => "add",
            Op::FwUpdate => "fw_update",
            Op::MinPlus => "minplus",
        }
    }

    pub fn all() -> [Op; 5] {
        [Op::Matmul, Op::MatmulAcc, Op::Add, Op::FwUpdate, Op::MinPlus]
    }
}

/// `matmul_b128.hlo.txt`-style artifact file name.
pub fn artifact_file(op: Op, b: usize) -> String {
    format!("{}_b{}.hlo.txt", op.stem(), b)
}

/// Locate the artifacts directory: `$FOOPAR_ARTIFACTS`, else `artifacts/`
/// relative to the current dir or up to 3 parents (so tests and examples
/// work from target subdirectories).
pub fn default_dir() -> Option<PathBuf> {
    if let Ok(p) = std::env::var("FOOPAR_ARTIFACTS") {
        let p = PathBuf::from(p);
        return p.is_dir().then_some(p);
    }
    let mut base = std::env::current_dir().ok()?;
    for _ in 0..4 {
        let cand = base.join("artifacts");
        if cand.join("manifest.json").is_file() {
            return Some(cand);
        }
        if !base.pop() {
            break;
        }
    }
    None
}

/// The set of artifacts present in a directory.
#[derive(Clone, Debug)]
pub struct ArtifactSet {
    dir: PathBuf,
    /// (op, block edge) pairs with an artifact on disk.
    entries: BTreeSet<(Op, usize)>,
}

impl ArtifactSet {
    /// Scan `dir` for `<op>_b<edge>.hlo.txt` files.
    pub fn discover(dir: &Path) -> Result<Self> {
        if !dir.is_dir() {
            bail!("artifact directory {} does not exist (run `make artifacts`)", dir.display());
        }
        let mut entries = BTreeSet::new();
        for e in std::fs::read_dir(dir).context("reading artifact dir")? {
            let name = e?.file_name();
            let name = name.to_string_lossy();
            if let Some((op, b)) = parse_name(&name) {
                entries.insert((op, b));
            }
        }
        if entries.is_empty() {
            bail!("no *.hlo.txt artifacts in {} (run `make artifacts`)", dir.display());
        }
        Ok(ArtifactSet { dir: dir.to_path_buf(), entries })
    }

    /// Discover at the default location.
    pub fn discover_default() -> Result<Self> {
        let dir = default_dir()
            .context("artifacts/ not found — run `make artifacts` or set FOOPAR_ARTIFACTS")?;
        Self::discover(&dir)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn has(&self, op: Op, b: usize) -> bool {
        self.entries.contains(&(op, b))
    }

    pub fn path(&self, op: Op, b: usize) -> PathBuf {
        self.dir.join(artifact_file(op, b))
    }

    /// Block edges available for `op`, ascending.
    pub fn sizes(&self, op: Op) -> Vec<usize> {
        self.entries.iter().filter(|(o, _)| *o == op).map(|&(_, b)| b).collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &(Op, usize)> {
        self.entries.iter()
    }
}

/// Parse `<op>_b<edge>.hlo.txt` back into (Op, edge).
pub fn parse_name(name: &str) -> Option<(Op, usize)> {
    let stem = name.strip_suffix(".hlo.txt")?;
    // ops with underscores first (matmul_acc before matmul would misparse)
    for op in [Op::MatmulAcc, Op::FwUpdate, Op::Matmul, Op::Add, Op::MinPlus] {
        if let Some(rest) = stem.strip_prefix(op.stem()) {
            if let Some(bs) = rest.strip_prefix("_b") {
                if let Ok(b) = bs.parse::<usize>() {
                    return Some((op, b));
                }
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn name_roundtrip() {
        for op in Op::all() {
            for b in [32usize, 64, 128, 256] {
                let f = artifact_file(op, b);
                assert_eq!(parse_name(&f), Some((op, b)), "{f}");
            }
        }
    }

    #[test]
    fn parse_rejects_noise() {
        assert_eq!(parse_name("manifest.json"), None);
        assert_eq!(parse_name("matmul_b.hlo.txt"), None);
        assert_eq!(parse_name("matmul_bXX.hlo.txt"), None);
        assert_eq!(parse_name("matmul_b64.txt"), None);
    }

    #[test]
    fn matmul_acc_not_shadowed_by_matmul() {
        assert_eq!(parse_name("matmul_acc_b32.hlo.txt"), Some((Op::MatmulAcc, 32)));
    }

    #[test]
    fn discover_real_artifacts_if_present() {
        // Runs against the repo's artifacts/ when built via `make test`.
        if let Some(dir) = default_dir() {
            let set = ArtifactSet::discover(&dir).unwrap();
            assert!(set.has(Op::Matmul, 32) || !set.sizes(Op::Matmul).is_empty());
        }
    }
}
