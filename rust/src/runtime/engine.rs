//! The PJRT execution engine and its device-server thread.
//!
//! `Engine` owns a `PjRtClient` (CPU) plus a
//! compile-on-demand cache of loaded executables, one per
//! `(op, block-size)` artifact.  Because the `xla` crate's client is
//! `Rc`-based (`!Send`), the engine runs on one dedicated thread
//! ([`EngineServer`]) and SPMD ranks submit work through a cloneable,
//! thread-safe [`EngineHandle`] — the same discipline as a per-node
//! accelerator command queue.
//!
//! Interchange is HLO **text** (see python/compile/aot.py):
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile`.
//!
//! The whole execution path is gated behind the `pjrt` cargo feature
//! (the `xla` crate is not part of the baseline image).  Without the
//! feature, [`EngineServer::start`] / [`EngineServer::start_default`]
//! report "unavailable" and every caller falls back to the native gemm
//! path — the same behaviour as missing artifacts, so `--mode real`
//! keeps working everywhere.

#[cfg(feature = "pjrt")]
pub use self::real::Engine;
pub use self::imp::{EngineHandle, EngineServer};

#[cfg(feature = "pjrt")]
use self::real as imp;
#[cfg(not(feature = "pjrt"))]
use self::stub as imp;

#[cfg(feature = "pjrt")]
mod real {
    use std::collections::HashMap;
    use std::sync::mpsc;
    use std::sync::Mutex;
    use std::time::Instant;

    use anyhow::{anyhow, bail, Context, Result};

    use super::super::artifacts::{ArtifactSet, Op};
    use crate::matrix::dense::Mat;

    /// Single-threaded PJRT engine (lives on the server thread).
    pub struct Engine {
        client: xla::PjRtClient,
        artifacts: ArtifactSet,
        cache: HashMap<(Op, usize), xla::PjRtLoadedExecutable>,
    }

    impl Engine {
        pub fn new(artifacts: ArtifactSet) -> Result<Self> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Engine { client, artifacts, cache: HashMap::new() })
        }

        pub fn artifacts(&self) -> &ArtifactSet {
            &self.artifacts
        }

        fn executable(&mut self, op: Op, b: usize) -> Result<&xla::PjRtLoadedExecutable> {
            if !self.cache.contains_key(&(op, b)) {
                if !self.artifacts.has(op, b) {
                    bail!("no artifact for {:?} at block size {b}", op);
                }
                let path = self.artifacts.path(op, b);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .with_context(|| format!("parsing {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {}", path.display()))?;
                self.cache.insert((op, b), exe);
            }
            Ok(&self.cache[&(op, b)])
        }

        fn literal(m: &Mat) -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
        }

        /// Execute `op` at block size `b` on `inputs`; returns the single
        /// output matrix with shape `(rows, cols)`.
        pub fn exec(
            &mut self,
            op: Op,
            b: usize,
            inputs: &[&Mat],
            rows: usize,
            cols: usize,
        ) -> Result<Mat> {
            let exe = self.executable(op, b)?;
            let lits: Vec<xla::Literal> =
                inputs.iter().map(|m| Self::literal(m)).collect::<Result<_>>()?;
            let out = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
            let out = out.to_tuple1()?;
            let data = out.to_vec::<f32>()?;
            if data.len() != rows * cols {
                bail!("{:?}_b{b}: expected {}x{} output, got {} elements", op, rows, cols, data.len());
            }
            Ok(Mat::from_vec(rows, cols, data))
        }

        /// Block GEMM via the Pallas artifact: inputs (b,b)·(b,b) → (b,b).
        pub fn matmul(&mut self, a: &Mat, b: &Mat) -> Result<Mat> {
            let n = a.rows;
            self.exec(Op::Matmul, n, &[a, b], n, n)
        }

        pub fn matmul_acc(&mut self, c: &Mat, a: &Mat, b: &Mat) -> Result<Mat> {
            let n = a.rows;
            self.exec(Op::MatmulAcc, n, &[c, a, b], n, n)
        }

        pub fn add(&mut self, x: &Mat, y: &Mat) -> Result<Mat> {
            let n = x.rows;
            self.exec(Op::Add, n, &[x, y], n, x.cols)
        }

        /// FW pivot update: d (b,b), ik (1,b), kj (b,1) → (b,b).
        pub fn fw_update(&mut self, d: &Mat, ik: &Mat, kj: &Mat) -> Result<Mat> {
            let n = d.rows;
            self.exec(Op::FwUpdate, n, &[d, ik, kj], n, n)
        }

        pub fn minplus(&mut self, a: &Mat, b: &Mat) -> Result<Mat> {
            let n = a.rows;
            self.exec(Op::MinPlus, n, &[a, b], n, n)
        }
    }

    // --------------------------------------------------- server + handle

    struct Request {
        op: Op,
        b: usize,
        inputs: Vec<Mat>,
        rows: usize,
        cols: usize,
        reply: mpsc::Sender<Result<(Mat, f64)>>,
    }

    /// Thread-safe, cloneable handle to the device-server thread.
    ///
    /// `exec` returns the result matrix plus the *device execution
    /// seconds* (excluding queue wait) so callers can charge virtual
    /// compute time.
    pub struct EngineHandle {
        tx: Mutex<mpsc::Sender<Request>>,
        artifacts: ArtifactSet,
    }

    impl EngineHandle {
        pub fn supports(&self, op: Op, b: usize) -> bool {
            self.artifacts.has(op, b)
        }

        pub fn artifacts(&self) -> &ArtifactSet {
            &self.artifacts
        }

        pub fn exec(
            &self,
            op: Op,
            b: usize,
            inputs: Vec<Mat>,
            rows: usize,
            cols: usize,
        ) -> Result<(Mat, f64)> {
            let (rtx, rrx) = mpsc::channel();
            {
                let tx = self.tx.lock().unwrap();
                tx.send(Request { op, b, inputs, rows, cols, reply: rtx })
                    .map_err(|_| anyhow!("engine server is gone"))?;
            }
            rrx.recv().map_err(|_| anyhow!("engine server dropped reply"))?
        }

        pub fn matmul(&self, a: Mat, b: Mat) -> Result<(Mat, f64)> {
            let n = a.rows;
            self.exec(Op::Matmul, n, vec![a, b], n, n)
        }

        pub fn matmul_acc(&self, c: Mat, a: Mat, b: Mat) -> Result<(Mat, f64)> {
            let n = a.rows;
            self.exec(Op::MatmulAcc, n, vec![c, a, b], n, n)
        }

        pub fn add(&self, x: Mat, y: Mat) -> Result<(Mat, f64)> {
            let n = x.rows;
            let c = x.cols;
            self.exec(Op::Add, n, vec![x, y], n, c)
        }

        pub fn fw_update(&self, d: Mat, ik: Mat, kj: Mat) -> Result<(Mat, f64)> {
            let n = d.rows;
            self.exec(Op::FwUpdate, n, vec![d, ik, kj], n, n)
        }

        pub fn minplus(&self, a: Mat, b: Mat) -> Result<(Mat, f64)> {
            let n = a.rows;
            self.exec(Op::MinPlus, n, vec![a, b], n, n)
        }
    }

    /// Owns the device-server thread; dropping it shuts the server down.
    pub struct EngineServer {
        tx: mpsc::Sender<Request>,
        artifacts: ArtifactSet,
        join: Option<std::thread::JoinHandle<()>>,
    }

    impl EngineServer {
        /// Spawn the server with artifacts discovered at the default
        /// location.
        pub fn start_default() -> Result<Self> {
            Self::start(ArtifactSet::discover_default()?)
        }

        /// Spawn the server thread; the PJRT client is created on that
        /// thread (it is `!Send`).
        pub fn start(artifacts: ArtifactSet) -> Result<Self> {
            let (tx, rx) = mpsc::channel::<Request>();
            let arts = artifacts.clone();
            let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
            let join = std::thread::Builder::new()
                .name("pjrt-engine".into())
                .spawn(move || {
                    let mut engine = match Engine::new(arts) {
                        Ok(e) => {
                            let _ = ready_tx.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    while let Ok(req) = rx.recv() {
                        let t0 = Instant::now();
                        let refs: Vec<&Mat> = req.inputs.iter().collect();
                        let res = engine
                            .exec(req.op, req.b, &refs, req.rows, req.cols)
                            .map(|m| (m, t0.elapsed().as_secs_f64()));
                        let _ = req.reply.send(res);
                    }
                })
                .expect("spawn pjrt-engine thread");
            ready_rx
                .recv()
                .map_err(|_| anyhow!("engine thread died before ready"))?
                .context("starting PJRT engine")?;
            Ok(EngineServer { tx, artifacts, join: Some(join) })
        }

        /// A fresh handle for sharing with SPMD ranks.
        pub fn handle(&self) -> EngineHandle {
            EngineHandle { tx: Mutex::new(self.tx.clone()), artifacts: self.artifacts.clone() }
        }
    }

    impl Drop for EngineServer {
        fn drop(&mut self) {
            // Close the channel so the server loop exits, then join.
            let (dummy_tx, _) = mpsc::channel();
            drop(std::mem::replace(&mut self.tx, dummy_tx));
            if let Some(j) = self.join.take() {
                let _ = j.join();
            }
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod stub {
    //! Featureless stand-ins with the same public surface: construction
    //! always fails, `supports` is always false, so callers (the
    //! [`Compute`](crate::runtime::compute::Compute) layer, the CLI, the
    //! examples) take their native fallback paths.

    use anyhow::{bail, Result};

    use super::super::artifacts::{ArtifactSet, Op};
    use crate::matrix::dense::Mat;

    const UNAVAILABLE: &str =
        "PJRT engine unavailable: crate built without the `pjrt` feature \
         (requires the `xla` dependency)";

    /// Stub handle: supports nothing, executes nothing.
    pub struct EngineHandle {
        _private: (),
    }

    impl EngineHandle {
        pub fn supports(&self, _op: Op, _b: usize) -> bool {
            false
        }

        pub fn exec(
            &self,
            _op: Op,
            _b: usize,
            _inputs: Vec<Mat>,
            _rows: usize,
            _cols: usize,
        ) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }

        pub fn matmul(&self, _a: Mat, _b: Mat) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }

        pub fn matmul_acc(&self, _c: Mat, _a: Mat, _b: Mat) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }

        pub fn add(&self, _x: Mat, _y: Mat) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }

        pub fn fw_update(&self, _d: Mat, _ik: Mat, _kj: Mat) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }

        pub fn minplus(&self, _a: Mat, _b: Mat) -> Result<(Mat, f64)> {
            bail!(UNAVAILABLE)
        }
    }

    /// Stub server: never starts.
    pub struct EngineServer {
        _private: (),
    }

    impl EngineServer {
        pub fn start_default() -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn start(_artifacts: ArtifactSet) -> Result<Self> {
            bail!(UNAVAILABLE)
        }

        pub fn handle(&self) -> EngineHandle {
            EngineHandle { _private: () }
        }
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::matrix::dense::Mat;
    use crate::matrix::gemm;
    use crate::testing::assert_allclose;

    fn server() -> Option<EngineServer> {
        match EngineServer::start_default() {
            Ok(s) => Some(s),
            Err(e) => {
                eprintln!("skipping PJRT test (no artifacts): {e:#}");
                None
            }
        }
    }

    #[test]
    fn pjrt_matmul_matches_native() {
        let Some(srv) = server() else { return };
        let h = srv.handle();
        let a = Mat::random(32, 32, 1);
        let b = Mat::random(32, 32, 2);
        let (got, secs) = h.matmul(a.clone(), b.clone()).unwrap();
        let want = gemm::matmul(&a, &b);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
        assert!(secs > 0.0);
    }

    #[test]
    fn pjrt_matmul_acc_matches_native() {
        let Some(srv) = server() else { return };
        let h = srv.handle();
        let c = Mat::random(32, 32, 3);
        let a = Mat::random(32, 32, 4);
        let b = Mat::random(32, 32, 5);
        let (got, _) = h.matmul_acc(c.clone(), a.clone(), b.clone()).unwrap();
        let mut want = c;
        gemm::matmul_acc_into(&mut want, &a, &b);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn pjrt_fw_update_matches_native() {
        let Some(srv) = server() else { return };
        let h = srv.handle();
        let d = Mat::random(32, 32, 7);
        let ik = Mat::random(1, 32, 8);
        let kj = Mat::random(32, 1, 9);
        let (got, _) = h.fw_update(d.clone(), ik.clone(), kj.clone()).unwrap();
        let mut want = d;
        gemm::fw_update_into(&mut want, ik.row(0), &kj.col(0));
        assert_allclose(&got.data, &want.data, 1e-5, 1e-6);
    }

    #[test]
    fn handle_usable_from_many_threads() {
        let Some(srv) = server() else { return };
        let h = std::sync::Arc::new(srv.handle());
        std::thread::scope(|s| {
            for t in 0..4 {
                let h = h.clone();
                s.spawn(move || {
                    let a = Mat::random(32, 32, t);
                    let b = Mat::eye(32);
                    let (got, _) = h.matmul(a.clone(), b).unwrap();
                    assert_allclose(&got.data, &a.data, 1e-5, 1e-6);
                });
            }
        });
    }

    #[test]
    fn missing_artifact_errors_cleanly() {
        let Some(srv) = server() else { return };
        let h = srv.handle();
        let a = Mat::random(17, 17, 1); // 17 is not an artifact size
        let r = h.matmul(a.clone(), a);
        assert!(r.is_err());
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_server_reports_unavailable() {
        let err = EngineServer::start_default().unwrap_err();
        assert!(format!("{err:#}").contains("pjrt"), "{err:#}");
    }
}
