//! The per-rank compute layer: one abstraction over three execution
//! strategies for the block operations.
//!
//! * [`Compute::Pjrt`] — execute the AOT Pallas/JAX artifact through the
//!   PJRT device server (the paper's "MKL via JNI" analogue; real data).
//! * [`Compute::Native`] — in-process packed register-tiled gemm (the
//!   paper's "standard BLAS" analogue; real data, and the fallback for
//!   block sizes without artifacts).  Honors the runtime's
//!   `threads_per_rank` knob by scheduling (MC band × NC column-panel)
//!   tiles — and the chunks of the threaded elementwise kernels — over
//!   the per-rank worker pool through the work-stealing scheduler
//!   ([`crate::matrix::par`]) — bit-identical results for any thread
//!   count.
//! * [`Compute::Modeled`] — no data is touched; the rank's virtual clock
//!   advances by `flops / rate` where `rate` is the calibrated per-core
//!   GFlop/s of the machine config (how we run n=40000, p=512 on a
//!   laptop).  Blocks stay [`Block::Proxy`]; wire costs stay exact.
//!
//! Every method charges the owning rank's virtual clock, so algorithm
//! code is mode-oblivious: `comp.matmul(ctx, &a, &b)`.

use std::sync::Arc;

use super::artifacts::Op;
use super::engine::EngineHandle;
use crate::data::value::Data;
use crate::matrix::block::Block;
use crate::matrix::buf::Buf;
use crate::matrix::dense::Mat;
use crate::matrix::gemm;
use crate::spmd::Ctx;

/// A row/column segment travelling through FW broadcasts: real values or
/// a size-only proxy (modeled mode).
///
/// Real segments hold their elements in a shared copy-on-write [`Buf`]
/// — the same substrate as [`Mat`] — so cloning a `Seg` (and therefore
/// fanning a pivot row/column out through a shmem broadcast) is a
/// reference-count bump, not a `memcpy`: every rank of a process column
/// holds the *same* allocation until someone mutates
/// ([`Seg::data_mut`] splits it, keeping ranks isolated).
#[derive(Clone, Debug, PartialEq)]
pub enum Seg {
    Real(Buf),
    Proxy { len: usize },
}

impl Seg {
    /// Wrap a vector of real values (no copy).
    pub fn real(v: Vec<f32>) -> Self {
        Seg::Real(v.into())
    }

    pub fn len(&self) -> usize {
        match self {
            Seg::Real(v) => v.len(),
            Seg::Proxy { len } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_slice(&self) -> &[f32] {
        match self {
            Seg::Real(v) => v,
            Seg::Proxy { .. } => panic!("attempted to read data of a proxy segment"),
        }
    }

    /// Do two real segments share one allocation?  The zero-copy
    /// assertion used by tests: after a shmem bcast of a pivot row,
    /// every rank's segment satisfies this against the root's.
    pub fn shares_allocation(a: &Seg, b: &Seg) -> bool {
        match (a, b) {
            (Seg::Real(x), Seg::Real(y)) => Buf::shares_allocation(x, y),
            _ => false,
        }
    }

    /// Mutable view of a real segment's elements.  Copy-on-write: if the
    /// allocation is shared (post-broadcast), this rank gets its own
    /// copy first — mutation never leaks into peers.
    pub fn data_mut(&mut self) -> &mut [f32] {
        match self {
            Seg::Real(v) => v.as_mut_slice(),
            Seg::Proxy { .. } => panic!("attempted to mutate a proxy segment"),
        }
    }
}

impl Data for Seg {
    fn byte_size(&self) -> usize {
        self.len() * 4
    }
}

/// Execution strategy for block compute (see module docs).
#[derive(Clone)]
pub enum Compute {
    /// Native rust gemm on real data.
    Native,
    /// PJRT artifacts on real data, native fallback for unknown sizes.
    Pjrt(Arc<EngineHandle>),
    /// Virtual-clock-only: `rate` is per-core flops/second.
    Modeled { rate: f64 },
}

/// GEMM block-size efficiency roll-off: real BLAS implementations reach
/// the machine's peak rate only asymptotically in the block edge (cache /
/// panel effects).  Effective rate = `rate · b/(b + GEMM_B_HALF)`.
///
/// Calibration: `GEMM_B_HALF = 320` puts the modeled Carver headline point
/// (n = 40320, p = 512, b = 5040) at 93.7% of empirical peak = 88.8% of
/// theoretical — the paper's exact §6 numbers.  All other Fig. 5 points
/// follow from the same single constant (see EXPERIMENTS.md).
pub const GEMM_B_HALF: f64 = 320.0;

/// Fraction of peak a b-edge GEMM achieves.
pub fn gemm_efficiency(b: usize) -> f64 {
    b as f64 / (b as f64 + GEMM_B_HALF)
}

impl Compute {
    pub fn is_modeled(&self) -> bool {
        matches!(self, Compute::Modeled { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Compute::Native => "native",
            Compute::Pjrt(_) => "pjrt",
            Compute::Modeled { .. } => "modeled",
        }
    }

    fn charge_modeled(&self, ctx: &Ctx, flops: f64) {
        if let Compute::Modeled { rate } = self {
            ctx.advance_compute(flops / rate, flops);
        }
    }

    /// Charge `elems` element-touches of linear work (extractions/copies,
    /// e.g. the Θ(B) pivot-row copy in Alg. 3).  Modeled mode only; in
    /// real modes the copy happens inside a timed region.
    pub fn charge_elems(&self, ctx: &Ctx, elems: usize) {
        self.charge_modeled(ctx, elems as f64);
    }

    /// Extract row `r` of a block as a [`Seg`] (Alg. 3 line 6 mapD body).
    pub fn block_row(&self, ctx: &Ctx, blk: &Block, r: usize) -> Seg {
        self.charge_elems(ctx, blk.cols());
        match blk {
            Block::Real(m) => Seg::real(m.row(r).to_vec()),
            Block::Proxy { cols, .. } => Seg::Proxy { len: *cols },
        }
    }

    /// Extract column `c` of a block as a [`Seg`] (Alg. 3 line 7 mapD body).
    pub fn block_col(&self, ctx: &Ctx, blk: &Block, c: usize) -> Seg {
        self.charge_elems(ctx, blk.rows());
        match blk {
            Block::Real(m) => Seg::real(m.col(c)),
            Block::Proxy { rows, .. } => Seg::Proxy { len: *rows },
        }
    }

    /// `A · B` on blocks.
    pub fn matmul(&self, ctx: &Ctx, a: &Block, b: &Block) -> Block {
        let flops = gemm::gemm_flops(a.rows(), a.cols(), b.cols());
        match self {
            Compute::Modeled { rate } => {
                let eff = gemm_efficiency(a.rows().min(b.cols()).min(a.cols()));
                ctx.advance_compute(flops / (rate * eff), flops);
                Block::Proxy { rows: a.rows(), cols: b.cols(), seed: 0 }
            }
            Compute::Native => ctx.timed_compute(flops, || {
                Block::Real(gemm::matmul_mt_with(
                    a.as_mat(),
                    b.as_mat(),
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                ))
            }),
            Compute::Pjrt(h) => {
                let n = a.rows();
                if h.supports(Op::Matmul, n) && a.cols() == n && b.cols() == n {
                    let (am, bm) = (a.as_mat().clone(), b.as_mat().clone());
                    let (out, secs) = h.matmul(am, bm).expect("pjrt matmul");
                    ctx.advance_compute(secs, flops);
                    Block::Real(out)
                } else {
                    ctx.timed_compute(flops, || {
                        Block::Real(gemm::matmul_mt_with(
                            a.as_mat(),
                            b.as_mat(),
                            ctx.threads_per_rank(),
                            ctx.block_params(),
                        ))
                    })
                }
            }
        }
    }

    /// `A · B[:, lo..hi)` — one column panel of the product (the
    /// pipelined DNS variant computes its block panel-by-panel so each
    /// panel's z-reduction can overlap the next panel's GEMM).
    ///
    /// Bit-identity: the native kernel accumulates each `c[i][j]` over
    /// `k` in the same order whether `B` is whole or column-sliced, so
    /// the hstack of all panels equals the full-block product exactly.
    /// Modeled mode charges the panel's share of the full GEMM at the
    /// *full block's* efficiency — the panel split is a schedule choice,
    /// not a smaller GEMM (the kernel still streams the whole A block) —
    /// so the total modeled compute equals the blocking run's.
    pub fn matmul_panel(&self, ctx: &Ctx, a: &Block, b: &Block, lo: usize, hi: usize) -> Block {
        debug_assert!(lo < hi && hi <= b.cols(), "panel [{lo}, {hi}) of {} cols", b.cols());
        let flops = gemm::gemm_flops(a.rows(), a.cols(), hi - lo);
        match self {
            Compute::Modeled { rate } => {
                let eff = gemm_efficiency(a.rows().min(b.cols()).min(a.cols()));
                ctx.advance_compute(flops / (rate * eff), flops);
                Block::Proxy { rows: a.rows(), cols: hi - lo, seed: 0 }
            }
            // PJRT artifacts are square-block-only; panels take the
            // native path like any other unsupported shape.
            _ => ctx.timed_compute(flops, || {
                let panel = b.as_mat().col_slice(lo, hi);
                Block::Real(gemm::matmul_mt_with(
                    a.as_mat(),
                    &panel,
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                ))
            }),
        }
    }

    /// `C + A · B` on blocks (DNS partial sums).
    pub fn matmul_acc(&self, ctx: &Ctx, c: Block, a: &Block, b: &Block) -> Block {
        let flops = gemm::gemm_flops(a.rows(), a.cols(), b.cols())
            + (a.rows() * b.cols()) as f64;
        match self {
            Compute::Modeled { rate } => {
                let eff = gemm_efficiency(a.rows().min(b.cols()).min(a.cols()));
                ctx.advance_compute(flops / (rate * eff), flops);
                Block::Proxy { rows: a.rows(), cols: b.cols(), seed: 0 }
            }
            Compute::Native => ctx.timed_compute(flops, || {
                // into_mat: a uniquely-owned accumulator mutates in
                // place (no copy); a shared one copy-on-writes once
                let mut cm = c.into_mat();
                gemm::matmul_acc_into_mt_with(
                    &mut cm,
                    a.as_mat(),
                    b.as_mat(),
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                );
                Block::Real(cm)
            }),
            Compute::Pjrt(h) => {
                let n = a.rows();
                if h.supports(Op::MatmulAcc, n) && a.cols() == n && b.cols() == n {
                    let (out, secs) = h
                        .matmul_acc(c.as_mat().clone(), a.as_mat().clone(), b.as_mat().clone())
                        .expect("pjrt matmul_acc");
                    ctx.advance_compute(secs, flops);
                    Block::Real(out)
                } else {
                    ctx.timed_compute(flops, || {
                        let mut cm = c.into_mat();
                        gemm::matmul_acc_into_mt_with(
                            &mut cm,
                            a.as_mat(),
                            b.as_mat(),
                            ctx.threads_per_rank(),
                            ctx.block_params(),
                        );
                        Block::Real(cm)
                    })
                }
            }
        }
    }

    /// `X + Y` — the `reduceD (_ + _)` combine operator on blocks.
    /// Native path threads past the bandwidth threshold (see
    /// [`gemm::EW_PAR_THRESHOLD`]) and lands on the elementwise metric
    /// counters, so `repro peak` reports it next to the GEMM rate.
    pub fn add(&self, ctx: &Ctx, x: Block, y: Block) -> Block {
        let flops = (x.rows() * x.cols()) as f64;
        match self {
            Compute::Modeled { .. } => {
                self.charge_modeled(ctx, flops);
                x
            }
            Compute::Native => ctx.timed_elementwise(flops, || {
                Block::Real(gemm::add_mt_with(
                    x.as_mat(),
                    y.as_mat(),
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                ))
            }),
            Compute::Pjrt(h) => {
                let n = x.rows();
                if h.supports(Op::Add, n) && x.cols() == n {
                    let (out, secs) =
                        h.add(x.as_mat().clone(), y.as_mat().clone()).expect("pjrt add");
                    ctx.advance_compute(secs, flops);
                    Block::Real(out)
                } else {
                    ctx.timed_elementwise(flops, || {
                        Block::Real(gemm::add_mt_with(
                            x.as_mat(),
                            y.as_mat(),
                            ctx.threads_per_rank(),
                            ctx.block_params(),
                        ))
                    })
                }
            }
        }
    }

    /// Elementwise `min(X, Y)` — the tropical ⊕ at block level (the
    /// APSP-by-squaring combine), mode-aware and threaded past the
    /// bandwidth threshold like [`Compute::add`].
    pub fn min_blocks(&self, ctx: &Ctx, a: Block, b: Block) -> Block {
        let flops = (a.rows() * a.cols()) as f64;
        if self.is_modeled() {
            self.charge_modeled(ctx, flops);
            return a;
        }
        match (&a, &b) {
            (Block::Real(x), Block::Real(y)) => ctx.timed_elementwise(flops, || {
                let m = gemm::min_mat_mt_with(x, y, ctx.threads_per_rank(), ctx.block_params());
                Block::Real(m)
            }),
            // proxies in a real mode only occur for degenerate
            // non-member blocks; pass the left operand through
            _ => a,
        }
    }

    /// Elementwise combine `x ⊕ y` on blocks with `⊕` chosen at runtime
    /// — the single-op entry the plan interpreter uses for an unfused
    /// [`gemm::EwKind`] node.  Dispatches to [`Compute::add`] /
    /// [`Compute::min_blocks`], so clocks and results are exactly those
    /// of the eager combine.
    pub fn ew(&self, ctx: &Ctx, x: Block, y: Block, op: gemm::EwKind) -> Block {
        match op {
            gemm::EwKind::Add => self.add(ctx, x, y),
            gemm::EwKind::Min => self.min_blocks(ctx, x, y),
        }
    }

    /// Fused elementwise chain `((base ⊕₁ m₁) ⊕₂ m₂) …` in one kernel
    /// pass ([`gemm::ew_chain_mt_with`]) — the plan layer's fuse target.
    /// Per-element fold order equals the op order, so the result is
    /// bit-identical to the unfused chain of [`Compute::ew`] calls; the
    /// modeled charge (one element-touch per op, like [`Compute::add`])
    /// is also identical, fused or not — fusion saves real memory
    /// traffic, never model time.
    pub fn ew_chain(&self, ctx: &Ctx, base: Block, args: &[(gemm::EwKind, Block)]) -> Block {
        if args.is_empty() {
            return base;
        }
        let flops = (base.rows() * base.cols() * args.len()) as f64;
        if self.is_modeled() {
            self.charge_modeled(ctx, flops);
            return base;
        }
        // Proxies in a real mode only occur for degenerate non-member
        // blocks (same rule as min_blocks): pass the base through.
        if base.is_proxy() || args.iter().any(|(_, b)| b.is_proxy()) {
            return base;
        }
        ctx.timed_elementwise(flops, || {
            let refs: Vec<(gemm::EwKind, &Mat)> =
                args.iter().map(|(op, b)| (*op, b.as_mat())).collect();
            Block::Real(gemm::ew_chain_mt_with(
                base.as_mat(),
                &refs,
                ctx.threads_per_rank(),
                ctx.block_params(),
            ))
        })
    }

    /// Floyd-Warshall pivot update (Alg. 3 lines 9-14) on a block.
    pub fn fw_update(&self, ctx: &Ctx, d: Block, ik: &Seg, kj: &Seg) -> Block {
        let flops = 2.0 * (d.rows() * d.cols()) as f64;
        match self {
            Compute::Modeled { .. } => {
                self.charge_modeled(ctx, flops);
                d
            }
            Compute::Native => ctx.timed_elementwise(flops, || {
                let mut dm = d.into_mat();
                gemm::fw_update_into_mt_with(
                    &mut dm,
                    ik.as_slice(),
                    kj.as_slice(),
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                );
                Block::Real(dm)
            }),
            Compute::Pjrt(h) => {
                let n = d.rows();
                if h.supports(Op::FwUpdate, n) && d.cols() == n {
                    let ikm = Mat::from_vec(1, n, ik.as_slice().to_vec());
                    let kjm = Mat::from_vec(n, 1, kj.as_slice().to_vec());
                    let (out, secs) =
                        h.fw_update(d.as_mat().clone(), ikm, kjm).expect("pjrt fw_update");
                    ctx.advance_compute(secs, flops);
                    Block::Real(out)
                } else {
                    ctx.timed_elementwise(flops, || {
                        let mut dm = d.into_mat();
                        gemm::fw_update_into_mt_with(
                            &mut dm,
                            ik.as_slice(),
                            kj.as_slice(),
                            ctx.threads_per_rank(),
                            ctx.block_params(),
                        );
                        Block::Real(dm)
                    })
                }
            }
        }
    }

    /// Tropical GEMM on blocks (repeated-squaring APSP extension).
    pub fn minplus(&self, ctx: &Ctx, a: &Block, b: &Block) -> Block {
        let flops = gemm::gemm_flops(a.rows(), a.cols(), b.cols());
        match self {
            Compute::Modeled { rate } => {
                let eff = gemm_efficiency(a.rows().min(b.cols()).min(a.cols()));
                ctx.advance_compute(flops / (rate * eff), flops);
                Block::Proxy { rows: a.rows(), cols: b.cols(), seed: 0 }
            }
            Compute::Native => ctx.timed_compute(flops, || {
                Block::Real(gemm::minplus_matmul_mt_with(
                    a.as_mat(),
                    b.as_mat(),
                    ctx.threads_per_rank(),
                    ctx.block_params(),
                ))
            }),
            Compute::Pjrt(h) => {
                let n = a.rows();
                if h.supports(Op::MinPlus, n) && a.cols() == n && b.cols() == n {
                    let (out, secs) = h
                        .minplus(a.as_mat().clone(), b.as_mat().clone())
                        .expect("pjrt minplus");
                    ctx.advance_compute(secs, flops);
                    Block::Real(out)
                } else {
                    ctx.timed_compute(flops, || {
                        Block::Real(gemm::minplus_matmul_mt_with(
                            a.as_mat(),
                            b.as_mat(),
                            ctx.threads_per_rank(),
                            ctx.block_params(),
                        ))
                    })
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    fn with_ctx<R: Send>(f: impl Fn(&Ctx) -> R + Sync) -> R {
        run(1, BackendProfile::openmpi_fixed(), CostParams::free(), f)
            .results
            .remove(0)
    }

    #[test]
    fn native_matmul_matches_gemm() {
        let got = with_ctx(|ctx| {
            let a = Block::real(Mat::random(16, 16, 1));
            let b = Block::real(Mat::random(16, 16, 2));
            Compute::Native.matmul(ctx, &a, &b)
        });
        let want = gemm::matmul(&Mat::random(16, 16, 1), &Mat::random(16, 16, 2));
        assert_allclose(&got.as_mat().data, &want.data, 1e-5, 1e-6);
    }

    #[test]
    fn panel_matmul_bit_identical_to_full_product() {
        let got = with_ctx(|ctx| {
            let a = Block::real(Mat::random(24, 24, 5));
            let b = Block::real(Mat::random(24, 24, 6));
            let full = Compute::Native.matmul(ctx, &a, &b);
            let panels: Vec<crate::matrix::block::Block> = [(0usize, 7usize), (7, 16), (16, 24)]
                .iter()
                .map(|&(lo, hi)| Compute::Native.matmul_panel(ctx, &a, &b, lo, hi))
                .collect();
            (full, crate::matrix::block::Block::hstack(panels))
        });
        // exact equality, not allclose: same kernel, same fp order
        assert_eq!(got.0.as_mat().data, got.1.as_mat().data);
    }

    #[test]
    fn panel_matmul_modeled_totals_match_full_block() {
        let rate = 1e9;
        let (t_full, t_panels) = with_ctx(|ctx| {
            let a = Block::proxy(64, 1);
            let b = Block::proxy(64, 2);
            let t0 = ctx.now();
            let _ = Compute::Modeled { rate }.matmul(ctx, &a, &b);
            let t1 = ctx.now();
            for (lo, hi) in [(0usize, 32usize), (32, 64)] {
                let p = Compute::Modeled { rate }.matmul_panel(ctx, &a, &b, lo, hi);
                assert!(p.is_proxy());
            }
            (t1 - t0, ctx.now() - t1)
        });
        assert!((t_full - t_panels).abs() < 1e-15, "full {t_full} vs panels {t_panels}");
    }

    #[test]
    fn modeled_matmul_charges_flops_over_rate() {
        let rate = 1e9;
        let t = run(1, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let a = Block::proxy(64, 1);
            let b = Block::proxy(64, 2);
            let c = Compute::Modeled { rate }.matmul(ctx, &a, &b);
            assert!(c.is_proxy());
            ctx.now()
        })
        .results[0];
        let expect = gemm::gemm_flops(64, 64, 64) / (rate * gemm_efficiency(64));
        assert!((t - expect).abs() < 1e-12, "t={t} expect={expect}");
    }

    #[test]
    fn modeled_add_keeps_proxy_and_charges() {
        let t = run(1, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let x = Block::proxy(32, 1);
            let y = Block::proxy(32, 2);
            let z = Compute::Modeled { rate: 1e6 }.add(ctx, x, y);
            assert!(z.is_proxy());
            ctx.now()
        })
        .results[0];
        assert!((t - (32.0 * 32.0) / 1e6).abs() < 1e-12);
    }

    #[test]
    fn native_fw_update_matches_gemm() {
        let got = with_ctx(|ctx| {
            let d = Block::real(Mat::random(8, 8, 3));
            let ik = Seg::real((0..8).map(|i| i as f32).collect());
            let kj = Seg::real((0..8).map(|i| (8 - i) as f32).collect());
            Compute::Native.fw_update(ctx, d, &ik, &kj)
        });
        let mut want = Mat::random(8, 8, 3);
        let ik: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let kj: Vec<f32> = (0..8).map(|i| (8 - i) as f32).collect();
        gemm::fw_update_into(&mut want, &ik, &kj);
        assert_allclose(&got.as_mat().data, &want.data, 0.0, 0.0);
    }

    #[test]
    fn seg_byte_size() {
        assert_eq!(Seg::real(vec![0.0; 10]).byte_size(), 40);
        assert_eq!(Seg::Proxy { len: 10 }.byte_size(), 40);
    }

    #[test]
    fn seg_clone_shares_allocation_and_cow_isolates() {
        let a = Seg::real(vec![1.0, 2.0, 3.0]);
        let mut b = a.clone();
        assert!(Seg::shares_allocation(&a, &b), "clone must be an Arc bump");
        b.data_mut()[0] = 9.0; // copy-on-write splits the allocation here
        assert!(!Seg::shares_allocation(&a, &b));
        assert_eq!(a.as_slice()[0], 1.0);
        assert_eq!(b.as_slice()[0], 9.0);
        // proxies never share
        assert!(!Seg::shares_allocation(&a, &Seg::Proxy { len: 3 }));
    }

    #[test]
    fn min_blocks_matches_elementwise_min() {
        let got = with_ctx(|ctx| {
            let a = Block::real(Mat::random(16, 16, 1));
            let b = Block::real(Mat::random(16, 16, 2));
            Compute::Native.min_blocks(ctx, a, b)
        });
        let (a, b) = (Mat::random(16, 16, 1), Mat::random(16, 16, 2));
        for (i, v) in got.as_mat().data.iter().enumerate() {
            assert_eq!(*v, a.data[i].min(b.data[i]));
        }
    }

    #[test]
    fn min_blocks_modeled_keeps_proxy_and_charges() {
        let t = run(1, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let a = Block::proxy(32, 1);
            let b = Block::proxy(32, 2);
            let z = Compute::Modeled { rate: 1e6 }.min_blocks(ctx, a, b);
            assert!(z.is_proxy());
            ctx.now()
        })
        .results[0];
        assert!((t - (32.0 * 32.0) / 1e6).abs() < 1e-12);
    }

    #[test]
    fn elementwise_metrics_tick_on_native_add() {
        let res = run(1, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let x = Block::real(Mat::random(32, 32, 1));
            let y = Block::real(Mat::random(32, 32, 2));
            let _ = Compute::Native.add(ctx, x, y);
        });
        let m = res.metrics[0];
        assert_eq!(m.ew_flops, 32.0 * 32.0);
        assert!(m.ew_time >= 0.0);
        // elementwise is a sub-counter of total compute, not a sibling
        assert_eq!(m.flops, m.ew_flops);
    }
}
