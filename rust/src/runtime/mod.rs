//! PJRT runtime: load AOT artifacts (HLO text lowered from JAX/Pallas)
//! and execute them from the rank hot path.
//!
//! Architecture note: the `xla` crate's `PjRtClient` is `Rc`-based
//! (`!Send`), so the client lives on a dedicated **device-server thread**
//! ([`engine::EngineServer`]) and ranks talk to it through a channel RPC
//! ([`engine::EngineHandle`]) — the same shape as a per-node accelerator
//! queue.  Python never runs here; artifacts were produced once by
//! `make artifacts`.

pub mod artifacts;
pub mod compute;
pub mod engine;
