//! Distributed span tracing: the timeline-level ground truth behind the
//! aggregate counters in [`crate::metrics`].
//!
//! # What this is
//!
//! A low-overhead per-rank span tracer.  Every instrumented layer — the
//! collectives in [`crate::comm::group`], the split-phase `wait()` halves
//! in [`crate::comm::nb`], transport post/take in [`crate::spmd::Ctx`],
//! kernel tiles in [`crate::matrix::par`], and the serving dispatcher in
//! [`crate::serve`] — brackets its work in a [`span`].  Spans carry
//! `{name, category, rank, tid, t_start, t_end, args}` plus optional
//! *flow ids* linking each send to the matching recv.  At teardown the
//! runtime gathers every rank's spans to rank 0 (shared memory
//! in-process; the wire codec on a reserved tag next to the clock-gather
//! tag for multi-process runs) and can emit:
//!
//! * **Chrome-trace / Perfetto JSON** ([`TraceData::chrome_json`]): one
//!   "process" per rank, one "thread" per worker, `ph:"X"` complete
//!   events, and `ph:"s"`/`ph:"f"` flow arrows from each send span to
//!   the recv span that consumed the message.  Load the file at
//!   <https://ui.perfetto.dev> (or `chrome://tracing`).
//! * **A critical-path report** ([`TraceData::critical_path_report`]):
//!   walks each thread's span nesting to attribute *exclusive* wall time
//!   to compute vs collective vs transport vs idle per rank, and prints
//!   measured-vs-virtual-clock deltas per collective so the LogGP-style
//!   cost model can be validated against reality.
//!
//! # Enabling it
//!
//! Tracing is off by default and compiles to a single relaxed atomic
//! load on every instrumented path ([`enabled`]) — the bench gate proves
//! the disabled path does not move the GFlop/s needle.  Turn it on with
//! any of:
//!
//! * [`Runtime::builder().trace("out.json")`](crate::spmd::RuntimeBuilder::trace)
//!   — write Chrome JSON + print the critical-path report at teardown;
//! * [`Runtime::builder().trace_collect()`](crate::spmd::RuntimeBuilder::trace_collect)
//!   — attach the raw [`TraceData`] to the
//!   [`RunResult`](crate::spmd::RunResult) instead (tests, tooling);
//! * `FOOPAR_TRACE=out.json` in the environment;
//! * `repro mmm --trace out.json` from the CLI.
//!
//! # Mechanics (and why it stays cheap)
//!
//! Spans are buffered in a plain thread-local `Vec` — append is two
//! pointer writes, no locks, no syscalls; a per-thread cap plus a global
//! drop counter bounds memory on runaway traces.  Buffers flush into a
//! process-global collector exactly once per scope (rank body end /
//! parallel region end), so the hot path never contends.  One *session*
//! (a static mutex) is active per process at a time; concurrent
//! untraced runtimes in the same process record nothing because spans
//! require both the global enable flag *and* a thread-local activation
//! mark set only by the traced runtime's rank scopes.
//!
//! Thread ids are virtual: `tid = rank·256 + k` with `k = 0` for the
//! rank's main thread and `k = 1 + slot` for intra-rank worker slots —
//! globally unique across ranks (pool threads are reused across ranks,
//! so real OS thread ids would collide) and stable across sequential
//! parallel regions.  Timestamps are `f64` UNIX seconds derived from a
//! per-process monotonic anchor, so same-host multi-process traces line
//! up to clock-sync precision.

use std::borrow::Cow;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};
use std::time::Instant;

use crate::comm::wire::{WireData, WireError, WireReader};
use crate::data::value::Data;
use crate::metrics::JsonWriter;

/// Virtual-tid block per rank: tid `rank·256` is the rank's main
/// thread, `rank·256 + 1 + slot` its intra-rank worker slots.
pub const TIDS_PER_RANK: u32 = 256;

/// Per-thread span buffer cap between flushes; beyond it spans are
/// counted in [`TraceData::dropped`] instead of recorded.
const BUF_CAP: usize = 1 << 18;

// ------------------------------------------------------------------ spans

/// What layer a span belongs to — the unit of attribution in the
/// critical-path report.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    /// The whole rank body (root span; its exclusive time is idle /
    /// uninstrumented).
    Rank,
    /// A collective operation (bcast, reduce, …) or its start/wait half.
    Collective,
    /// A point-to-point transport operation (post/take) on a flat
    /// (single-level) world.
    Comm,
    /// A compute kernel tile (GEMM / elementwise chunk).
    Kernel,
    /// Serving-plane work (admission, job lifecycle).
    Serve,
    /// A transport operation whose peer shares the caller's node
    /// (hierarchical worlds only — the shmem leg of a hybrid transport).
    CommIntra,
    /// A transport operation crossing a node boundary (the network leg
    /// of a hybrid transport).
    CommInter,
    /// Execution-plan orchestration (build / optimize / price /
    /// interpret in [`crate::plan`]); its exclusive time is planner
    /// overhead, everything the interpreter launches nests inside it.
    Plan,
}

impl Category {
    pub fn as_str(self) -> &'static str {
        match self {
            Category::Rank => "rank",
            Category::Collective => "collective",
            Category::Comm => "comm",
            Category::Kernel => "kernel",
            Category::Serve => "serve",
            Category::CommIntra => "comm-intra",
            Category::CommInter => "comm-inter",
            Category::Plan => "plan",
        }
    }

    fn code(self) -> u8 {
        match self {
            Category::Rank => 0,
            Category::Collective => 1,
            Category::Comm => 2,
            Category::Kernel => 3,
            Category::Serve => 4,
            Category::CommIntra => 5,
            Category::CommInter => 6,
            Category::Plan => 7,
        }
    }

    fn from_code(c: u8) -> Result<Self, WireError> {
        Ok(match c {
            0 => Category::Rank,
            1 => Category::Collective,
            2 => Category::Comm,
            3 => Category::Kernel,
            4 => Category::Serve,
            5 => Category::CommIntra,
            6 => Category::CommInter,
            7 => Category::Plan,
            _ => return Err(WireError::Malformed("unknown span category")),
        })
    }
}

/// One timed interval on one (rank, virtual thread).
#[derive(Clone, Debug)]
pub struct Span {
    pub name: Cow<'static, str>,
    pub cat: Category,
    pub rank: u32,
    /// Virtual thread id, globally unique: `rank·256 + k`.
    pub tid: u32,
    /// UNIX seconds (anchor-derived; see module docs).
    pub t_start: f64,
    pub t_end: f64,
    /// Numeric annotations (bytes, peer, virtual-clock start/end, …).
    pub args: Vec<(Cow<'static, str>, f64)>,
    /// Nonzero: this span *posted* a message; id shared with the recv.
    pub flow_out: u64,
    /// Nonzero: this span *took* a message; id shared with the send.
    pub flow_in: u64,
}

impl Span {
    /// Look up a numeric annotation by key.
    pub fn arg(&self, key: &str) -> Option<f64> {
        self.args.iter().find(|(k, _)| k == key).map(|&(_, v)| v)
    }
}

impl Data for Span {
    fn byte_size(&self) -> usize {
        57 + self.name.len() + self.args.iter().map(|(k, _)| 16 + k.len()).sum::<usize>()
    }
}

impl WireData for Span {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.name.len() as u64).encode(out);
        out.extend_from_slice(self.name.as_bytes());
        out.push(self.cat.code());
        self.rank.encode(out);
        self.tid.encode(out);
        self.t_start.encode(out);
        self.t_end.encode(out);
        self.flow_out.encode(out);
        self.flow_in.encode(out);
        (self.args.len() as u64).encode(out);
        for (k, v) in &self.args {
            (k.len() as u64).encode(out);
            out.extend_from_slice(k.as_bytes());
            v.encode(out);
        }
    }

    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let n = r.len()?;
        let name = String::from_utf8(r.take(n)?.to_vec())
            .map_err(|_| WireError::Malformed("span name not UTF-8"))?;
        let cat = Category::from_code(r.u8()?)?;
        let rank = u32::decode(r)?;
        let tid = u32::decode(r)?;
        let t_start = f64::decode(r)?;
        let t_end = f64::decode(r)?;
        let flow_out = u64::decode(r)?;
        let flow_in = u64::decode(r)?;
        let nargs = r.len()?;
        let mut args = Vec::with_capacity(nargs.min(64));
        for _ in 0..nargs {
            let kn = r.len()?;
            let k = String::from_utf8(r.take(kn)?.to_vec())
                .map_err(|_| WireError::Malformed("span arg key not UTF-8"))?;
            args.push((Cow::Owned(k), f64::decode(r)?));
        }
        Ok(Span {
            name: Cow::Owned(name),
            cat,
            rank,
            tid,
            t_start,
            t_end,
            args,
            flow_out,
            flow_in,
        })
    }
}

// ------------------------------------------------------- process globals

static ENABLED: AtomicBool = AtomicBool::new(false);
static DROPPED: AtomicU64 = AtomicU64::new(0);
static COLLECTOR: Mutex<Vec<Span>> = Mutex::new(Vec::new());
/// Serializes trace sessions within one process — `cargo test` runs
/// many runtimes concurrently in one process, and only one may own the
/// global enable flag at a time.
static SESSION: Mutex<()> = Mutex::new(());
static ANCHOR: OnceLock<(Instant, f64)> = OnceLock::new();

/// Is a trace session live in this process?  One relaxed load — the
/// entire disabled-path cost of every instrumented call site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Current trace timestamp: UNIX seconds via a monotonic per-process
/// anchor (monotone within a process; comparable across same-host
/// processes to clock-sync precision).
pub fn now() -> f64 {
    let &(anchor, base) = ANCHOR.get_or_init(|| {
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0);
        (Instant::now(), unix)
    });
    base + anchor.elapsed().as_secs_f64()
}

struct TlState {
    active: bool,
    rank: u32,
    tid: u32,
    buf: Vec<Span>,
    /// Per-(src,dst,tag) message sequence numbers for flow-id pairing.
    flow_seq: HashMap<(u32, u32, u64), u64>,
}

thread_local! {
    static TL: RefCell<TlState> = RefCell::new(TlState {
        active: false,
        rank: 0,
        tid: 0,
        buf: Vec::new(),
        flow_seq: HashMap::new(),
    });
}

fn flush_tl() {
    TL.with(|tl| {
        let mut tl = tl.borrow_mut();
        if tl.buf.is_empty() {
            return;
        }
        let mut c = COLLECTOR.lock().unwrap_or_else(|e| e.into_inner());
        c.append(&mut tl.buf);
    });
}

// ------------------------------------------------------------- recording

struct LiveSpan {
    name: &'static str,
    cat: Category,
    rank: u32,
    tid: u32,
    t_start: f64,
    args: Vec<(&'static str, f64)>,
    flow_out: u64,
    flow_in: u64,
}

/// An open span; records itself into the thread-local buffer on drop.
/// Inert (all methods no-ops) when tracing is disabled or the current
/// thread is not part of a traced runtime.
pub struct SpanGuard {
    live: Option<LiveSpan>,
}

/// Open a span.  The cheap path: one atomic load when tracing is off.
#[inline]
pub fn span(name: &'static str, cat: Category) -> SpanGuard {
    if !enabled() {
        return SpanGuard { live: None };
    }
    let live = TL.with(|tl| {
        let tl = tl.borrow();
        if !tl.active {
            return None;
        }
        Some(LiveSpan {
            name,
            cat,
            rank: tl.rank,
            tid: tl.tid,
            t_start: now(),
            args: Vec::new(),
            flow_out: 0,
            flow_in: 0,
        })
    });
    SpanGuard { live }
}

impl SpanGuard {
    /// Is this span actually recording?  Lets call sites skip arg
    /// computation entirely on the disabled path.
    #[inline]
    pub fn is_active(&self) -> bool {
        self.live.is_some()
    }

    /// Attach a numeric annotation.
    #[inline]
    pub fn arg(&mut self, key: &'static str, val: f64) {
        if let Some(live) = &mut self.live {
            live.args.push((key, val));
        }
    }

    /// Mark this span as the sending side of flow `id` (from
    /// [`flow_point`]).  Zero ids are ignored.
    #[inline]
    pub fn flow_out(&mut self, id: u64) {
        if let Some(live) = &mut self.live {
            live.flow_out = id;
        }
    }

    /// Mark this span as the receiving side of flow `id`.
    #[inline]
    pub fn flow_in(&mut self, id: u64) {
        if let Some(live) = &mut self.live {
            live.flow_in = id;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(live) = self.live.take() {
            let t_end = now();
            TL.with(|tl| {
                let mut tl = tl.borrow_mut();
                if tl.buf.len() >= BUF_CAP {
                    DROPPED.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                tl.buf.push(Span {
                    name: Cow::Borrowed(live.name),
                    cat: live.cat,
                    rank: live.rank,
                    tid: live.tid,
                    t_start: live.t_start,
                    t_end,
                    args: live.args.into_iter().map(|(k, v)| (Cow::Borrowed(k), v)).collect(),
                    flow_out: live.flow_out,
                    flow_in: live.flow_in,
                });
            });
        }
    }
}

fn mix3(a: u64, b: u64, c: u64) -> u64 {
    let mut x = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b.rotate_left(17))
        .wrapping_add(c.wrapping_mul(0xBF58_476D_1CE4_E5B9));
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x | 1 // zero means "no flow"
}

/// Next flow id for the `(src, dst, tag)` channel, as seen from the
/// calling thread.  Both endpoints derive the same id independently:
/// the sender calls this when posting, the receiver when taking, and
/// mailbox FIFO ordering per `(src, tag)` guarantees the k-th post
/// pairs with the k-th take.  Returns 0 (ignored) when not tracing.
pub fn flow_point(src: usize, dst: usize, tag: u64) -> u64 {
    if !enabled() {
        return 0;
    }
    TL.with(|tl| {
        let mut tl = tl.borrow_mut();
        if !tl.active {
            return 0;
        }
        let seq = tl.flow_seq.entry((src as u32, dst as u32, tag)).or_insert(0);
        *seq += 1;
        mix3(((src as u64) << 32) | dst as u64, tag, *seq)
    })
}

// ---------------------------------------------------------------- scopes

/// A live trace session: owns the process-global enable flag.  Created
/// by the runtime when tracing is requested; [`Session::finish`] yields
/// the collected [`TraceData`].
pub struct Session {
    _lock: MutexGuard<'static, ()>,
}

/// Start a trace session.  Blocks until any concurrent session in this
/// process finishes (sessions are serialized; see module docs).
pub fn begin_session() -> Session {
    let lock = SESSION.lock().unwrap_or_else(|e| e.into_inner());
    COLLECTOR.lock().unwrap_or_else(|e| e.into_inner()).clear();
    DROPPED.store(0, Ordering::SeqCst);
    ENABLED.store(true, Ordering::SeqCst);
    Session { _lock: lock }
}

impl Session {
    /// End the session and take every span flushed so far.  Call after
    /// all rank scopes have dropped (the SPMD join guarantees this).
    pub fn finish(self) -> TraceData {
        ENABLED.store(false, Ordering::SeqCst);
        let spans = std::mem::take(&mut *COLLECTOR.lock().unwrap_or_else(|e| e.into_inner()));
        TraceData { spans, dropped: DROPPED.swap(0, Ordering::SeqCst) }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        ENABLED.store(false, Ordering::SeqCst);
    }
}

/// Marks the current thread as rank `rank`'s main thread for the span
/// APIs.  Flushes and deactivates on drop.
pub struct RankScope {
    _not_send: std::marker::PhantomData<*const ()>,
}

pub fn rank_scope(rank: usize) -> RankScope {
    TL.with(|tl| {
        let mut tl = tl.borrow_mut();
        tl.active = true;
        tl.rank = rank as u32;
        tl.tid = rank as u32 * TIDS_PER_RANK;
        tl.buf.clear();
        tl.flow_seq.clear();
    });
    RankScope { _not_send: std::marker::PhantomData }
}

impl Drop for RankScope {
    fn drop(&mut self) {
        flush_tl();
        TL.with(|tl| tl.borrow_mut().active = false);
    }
}

/// Tracing identity of the thread that *launches* a parallel region —
/// captured before handing work to pool threads, which carry no
/// activation of their own.
#[derive(Clone, Copy, Debug)]
pub struct ParallelAttr {
    rank: u32,
}

/// Capture the launching thread's tracing identity, or `None` when the
/// region should run untraced.
pub fn parallel_attr() -> Option<ParallelAttr> {
    if !enabled() {
        return None;
    }
    TL.with(|tl| {
        let tl = tl.borrow();
        tl.active.then_some(ParallelAttr { rank: tl.rank })
    })
}

/// Activates span recording on a pool worker thread for the duration of
/// one parallel region, as worker slot `slot` of the captured rank.
/// Saves and restores the thread's previous identity (pool threads are
/// shared), flushing recorded spans on drop.
pub struct WorkerScope {
    prev: (bool, u32, u32),
}

pub fn worker_scope(attr: ParallelAttr, slot: usize) -> WorkerScope {
    debug_assert!(
        (slot as u32) < TIDS_PER_RANK - 1,
        "worker slot {slot} overflows the per-rank virtual-tid block"
    );
    TL.with(|tl| {
        let mut tl = tl.borrow_mut();
        let prev = (tl.active, tl.rank, tl.tid);
        tl.active = true;
        tl.rank = attr.rank;
        tl.tid = attr.rank * TIDS_PER_RANK + 1 + slot as u32;
        WorkerScope { prev }
    })
}

impl Drop for WorkerScope {
    fn drop(&mut self) {
        flush_tl();
        TL.with(|tl| {
            let mut tl = tl.borrow_mut();
            (tl.active, tl.rank, tl.tid) = self.prev;
        });
    }
}

// ------------------------------------------------------------ trace data

/// Every span of one run, gathered to rank 0.  `WireData`, so worker
/// processes ship theirs over the reserved trace-gather tag.
#[derive(Clone, Debug, Default)]
pub struct TraceData {
    pub spans: Vec<Span>,
    /// Spans lost to the per-thread buffer cap (0 in healthy traces).
    pub dropped: u64,
}

impl Data for TraceData {
    fn byte_size(&self) -> usize {
        16 + self.spans.iter().map(|s| s.byte_size()).sum::<usize>()
    }
}

impl WireData for TraceData {
    fn encode(&self, out: &mut Vec<u8>) {
        self.spans.encode(out);
        self.dropped.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(TraceData { spans: Vec::decode(r)?, dropped: u64::decode(r)? })
    }
}

impl TraceData {
    /// Fold another rank's gathered spans into this one.
    pub fn merge(&mut self, mut other: TraceData) {
        self.spans.append(&mut other.spans);
        self.dropped += other.dropped;
    }

    /// Export as Chrome-trace JSON (the `{"traceEvents": [...]}` object
    /// format): one process per rank, one thread per worker, `ph:"X"`
    /// complete events in microseconds relative to the earliest span,
    /// and `ph:"s"`/`ph:"f"` flow arrows for send→recv pairs.  Loadable
    /// in Perfetto / `chrome://tracing`.
    pub fn chrome_json(&self) -> String {
        let t0 = self
            .spans
            .iter()
            .map(|s| s.t_start)
            .fold(f64::INFINITY, f64::min);
        let t0 = if t0.is_finite() { t0 } else { 0.0 }; // empty trace
        let us = |t: f64| (t - t0) * 1e6;

        let mut ranks: BTreeMap<u32, ()> = BTreeMap::new();
        let mut threads: BTreeMap<(u32, u32), ()> = BTreeMap::new();
        for s in &self.spans {
            ranks.insert(s.rank, ());
            threads.insert((s.rank, s.tid), ());
        }

        let mut w = JsonWriter::new();
        w.begin_obj();
        w.key("displayTimeUnit").str_val("ms");
        w.key("traceEvents").begin_arr();

        for &rank in ranks.keys() {
            w.begin_obj();
            w.key("name").str_val("process_name");
            w.key("ph").str_val("M");
            w.key("pid").uint(rank as u64);
            w.key("tid").uint(0);
            w.key("args").begin_obj();
            w.key("name").str_val(&format!("rank {rank}"));
            w.end_obj();
            w.end_obj();
            w.begin_obj();
            w.key("name").str_val("process_sort_index");
            w.key("ph").str_val("M");
            w.key("pid").uint(rank as u64);
            w.key("tid").uint(0);
            w.key("args").begin_obj();
            w.key("sort_index").uint(rank as u64);
            w.end_obj();
            w.end_obj();
        }
        for &(rank, tid) in threads.keys() {
            let k = tid - rank * TIDS_PER_RANK;
            let tname = if k == 0 {
                "main".to_string()
            } else {
                format!("worker {}", k - 1)
            };
            w.begin_obj();
            w.key("name").str_val("thread_name");
            w.key("ph").str_val("M");
            w.key("pid").uint(rank as u64);
            w.key("tid").uint(tid as u64);
            w.key("args").begin_obj();
            w.key("name").str_val(&tname);
            w.end_obj();
            w.end_obj();
            w.begin_obj();
            w.key("name").str_val("thread_sort_index");
            w.key("ph").str_val("M");
            w.key("pid").uint(rank as u64);
            w.key("tid").uint(tid as u64);
            w.key("args").begin_obj();
            w.key("sort_index").uint(k as u64);
            w.end_obj();
            w.end_obj();
        }

        for s in &self.spans {
            w.begin_obj();
            w.key("name").str_val(&s.name);
            w.key("cat").str_val(s.cat.as_str());
            w.key("ph").str_val("X");
            w.key("pid").uint(s.rank as u64);
            w.key("tid").uint(s.tid as u64);
            w.key("ts").num(us(s.t_start));
            w.key("dur").num((us(s.t_end) - us(s.t_start)).max(0.0));
            if !s.args.is_empty() {
                w.key("args").begin_obj();
                for (k, v) in &s.args {
                    w.key(k).num(*v);
                }
                w.end_obj();
            }
            w.end_obj();
            // Flow arrows.  The "s" point sits at the send span's start
            // (the post happens after it) and the "f" point at the recv
            // span's end (the take happened before it), so arrows always
            // run forward in time and bind to their slices.
            if s.flow_out != 0 {
                w.begin_obj();
                w.key("name").str_val("msg");
                w.key("cat").str_val("flow");
                w.key("ph").str_val("s");
                w.key("id").uint(s.flow_out);
                w.key("pid").uint(s.rank as u64);
                w.key("tid").uint(s.tid as u64);
                w.key("ts").num(us(s.t_start));
                w.end_obj();
            }
            if s.flow_in != 0 {
                w.begin_obj();
                w.key("name").str_val("msg");
                w.key("cat").str_val("flow");
                w.key("ph").str_val("f");
                w.key("bp").str_val("e");
                w.key("id").uint(s.flow_in);
                w.key("pid").uint(s.rank as u64);
                w.key("tid").uint(s.tid as u64);
                w.key("ts").num(us(s.t_end));
                w.end_obj();
            }
        }

        w.end_arr();
        w.key("otherData").begin_obj();
        w.key("dropped_spans").uint(self.dropped);
        w.end_obj();
        w.end_obj();
        w.finish()
    }

    /// Attribute measured wall time per rank to compute / collective /
    /// transport / idle by walking each thread's span nesting, and
    /// compare measured collective times against the virtual-clock cost
    /// model.  `clocks` are the per-rank virtual clocks from the run
    /// (pass `&[]` when unavailable).
    pub fn critical_path_report(&self, clocks: &[f64]) -> String {
        if self.spans.is_empty() {
            return "trace: no spans recorded\n".to_string();
        }

        #[derive(Default, Clone, Copy)]
        struct Acc {
            compute: f64,
            collective: f64,
            comm: f64,
            comm_intra: f64,
            comm_inter: f64,
            serve: f64,
            idle: f64,
            t_min: f64,
            t_max: f64,
            init: bool,
        }
        fn account(acc: &mut Acc, cat: Category, excl: f64) {
            match cat {
                Category::Kernel => acc.compute += excl,
                Category::Collective => acc.collective += excl,
                Category::Comm => acc.comm += excl,
                Category::CommIntra => acc.comm_intra += excl,
                Category::CommInter => acc.comm_inter += excl,
                // Plan exclusive time is pure orchestration overhead —
                // bucket it with serve-plane bookkeeping rather than
                // compute so the meas/virt kernel calibration stays
                // honest.
                Category::Serve | Category::Plan => acc.serve += excl,
                Category::Rank => acc.idle += excl,
            }
        }

        let mut groups: BTreeMap<(u32, u32), Vec<&Span>> = BTreeMap::new();
        for s in &self.spans {
            groups.entry((s.rank, s.tid)).or_default().push(s);
        }

        let mut per_rank: BTreeMap<u32, Acc> = BTreeMap::new();
        for ((rank, _tid), mut spans) in groups {
            spans.sort_by(|a, b| {
                a.t_start
                    .total_cmp(&b.t_start)
                    .then(b.t_end.total_cmp(&a.t_end))
            });
            let mut local = Acc::default();
            // Stack walk over (assumed properly nested) spans: each
            // span's *exclusive* time is its duration minus its direct
            // children's, so nothing is double-counted.
            let mut stack: Vec<(f64, f64, f64, Category)> = Vec::new();
            for s in &spans {
                if !local.init {
                    local.t_min = s.t_start;
                    local.t_max = s.t_end;
                    local.init = true;
                }
                local.t_min = local.t_min.min(s.t_start);
                local.t_max = local.t_max.max(s.t_end);
                while stack
                    .last()
                    .is_some_and(|&(_, te, _, _)| te <= s.t_start + 1e-12)
                {
                    let (ts, te, child, cat) = stack.pop().unwrap();
                    account(&mut local, cat, (te - ts - child).max(0.0));
                }
                if let Some(parent) = stack.last_mut() {
                    parent.2 += s.t_end - s.t_start;
                }
                stack.push((s.t_start, s.t_end, 0.0, s.cat));
            }
            while let Some((ts, te, child, cat)) = stack.pop() {
                account(&mut local, cat, (te - ts - child).max(0.0));
            }
            let acc = per_rank.entry(rank).or_default();
            acc.compute += local.compute;
            acc.collective += local.collective;
            acc.comm += local.comm;
            acc.comm_intra += local.comm_intra;
            acc.comm_inter += local.comm_inter;
            acc.serve += local.serve;
            acc.idle += local.idle;
            if !acc.init {
                acc.t_min = local.t_min;
                acc.t_max = local.t_max;
                acc.init = true;
            } else {
                acc.t_min = acc.t_min.min(local.t_min);
                acc.t_max = acc.t_max.max(local.t_max);
            }
        }

        let ms = |s: f64| format!("{:.3}", s * 1e3);
        let mut rows = Vec::new();
        let mut crit: Option<(u32, f64)> = None;
        for (&rank, acc) in &per_rank {
            let wall = (acc.t_max - acc.t_min).max(0.0);
            if crit.map(|(_, w)| wall > w).unwrap_or(true) {
                crit = Some((rank, wall));
            }
            let vclock = clocks.get(rank as usize).copied().unwrap_or(f64::NAN);
            rows.push(vec![
                rank.to_string(),
                ms(wall),
                ms(acc.compute),
                ms(acc.collective),
                ms(acc.comm),
                ms(acc.comm_intra),
                ms(acc.comm_inter),
                ms(acc.serve),
                ms(acc.idle),
                if vclock.is_finite() { format!("{vclock:.6}") } else { "-".into() },
            ]);
        }

        let mut out = String::new();
        out.push_str("critical-path report (measured wall time, exclusive per category)\n");
        out.push_str(&crate::metrics::render_table(
            &[
                "rank",
                "wall(ms)",
                "compute(ms)",
                "collective(ms)",
                "comm(ms)",
                "intra(ms)",
                "inter(ms)",
                "serve(ms)",
                "idle(ms)",
                "virt clock(s)",
            ],
            &rows,
        ));
        if let Some((rank, wall)) = crit {
            out.push_str(&format!(
                "critical rank: {rank} ({} ms measured — the T_P contributor)\n",
                ms(wall)
            ));
        }

        // Per-collective measured vs virtual-clock deltas.
        let mut per_coll: BTreeMap<&str, (u64, f64, f64)> = BTreeMap::new();
        for s in &self.spans {
            if s.cat != Category::Collective {
                continue;
            }
            let e = per_coll.entry(s.name.as_ref()).or_default();
            e.0 += 1;
            e.1 += (s.t_end - s.t_start).max(0.0);
            if let (Some(v0), Some(v1)) = (s.arg("v_start"), s.arg("v_end")) {
                e.2 += (v1 - v0).max(0.0);
            }
        }
        if !per_coll.is_empty() {
            let rows: Vec<Vec<String>> = per_coll
                .iter()
                .map(|(name, &(n, meas, virt))| {
                    vec![
                        name.to_string(),
                        n.to_string(),
                        ms(meas),
                        format!("{:.6}", virt),
                        if virt > 0.0 {
                            format!("{:.2}", meas / virt)
                        } else {
                            "-".into()
                        },
                    ]
                })
                .collect();
            out.push_str("\ncollectives: measured vs virtual clock\n");
            out.push_str(&crate::metrics::render_table(
                &["op", "count", "measured(ms)", "virtual(s)", "meas/virt"],
                &rows,
            ));
        }
        if self.dropped > 0 {
            out.push_str(&format!(
                "\nwarning: {} spans dropped (per-thread buffer cap)\n",
                self.dropped
            ));
        }
        out
    }
}

// ----------------------------------------------------------- validation

/// What [`validate_chrome`] measured while checking a trace.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceSummary {
    /// All events in `traceEvents`.
    pub events: usize,
    /// `ph:"X"` complete events.
    pub x_events: usize,
    /// Distinct pids (ranks).
    pub ranks: usize,
    /// Distinct (pid, tid) pairs among X events.
    pub threads: usize,
    /// Flow ids with both an `s` and an `f` event.
    pub flow_pairs: usize,
    /// `s` events with no matching `f` (receiver outside the trace).
    pub unmatched_send: usize,
}

/// Validate Chrome-trace JSON structurally: parses, every `ph:"X"` event
/// is well-formed with `dur >= 0` (i.e. `t_end >= t_start`), no tid is
/// shared by two pids (cross-rank collision), every flow `f` pairs with
/// exactly one `s` (and, when `strict_flows`, vice versa).  Used by the
/// round-trip tests and the `trace_check` CI binary.
pub fn validate_chrome(json: &str, strict_flows: bool) -> Result<TraceSummary, String> {
    let root = mini_json::parse(json)?;
    let events = root
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing traceEvents array")?;

    let mut summary = TraceSummary { events: events.len(), ..Default::default() };
    let mut tid_owner: HashMap<u64, u64> = HashMap::new();
    let mut pids: HashMap<u64, ()> = HashMap::new();
    let mut threads: HashMap<(u64, u64), ()> = HashMap::new();
    let mut sends: HashMap<u64, usize> = HashMap::new();
    let mut recvs: HashMap<u64, usize> = HashMap::new();

    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        let field = |k: &str| -> Result<f64, String> {
            ev.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("event {i} (ph {ph}): missing numeric {k}"))
        };
        match ph {
            "X" => {
                ev.get("name")
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| format!("event {i}: X without name"))?;
                let pid = field("pid")? as u64;
                let tid = field("tid")? as u64;
                let ts = field("ts")?;
                let dur = field("dur")?;
                if !ts.is_finite() || !dur.is_finite() || dur < 0.0 {
                    return Err(format!(
                        "event {i}: bad ts/dur ({ts}/{dur}) — t_end < t_start?"
                    ));
                }
                match tid_owner.entry(tid) {
                    std::collections::hash_map::Entry::Occupied(e) if *e.get() != pid => {
                        return Err(format!(
                            "tid {tid} appears under both pid {} and pid {pid} — \
                             cross-rank tid collision",
                            e.get()
                        ));
                    }
                    std::collections::hash_map::Entry::Occupied(_) => {}
                    std::collections::hash_map::Entry::Vacant(e) => {
                        e.insert(pid);
                    }
                }
                pids.insert(pid, ());
                threads.insert((pid, tid), ());
                summary.x_events += 1;
            }
            "s" => {
                *sends.entry(field("id")? as u64).or_insert(0) += 1;
            }
            "f" => {
                *recvs.entry(field("id")? as u64).or_insert(0) += 1;
            }
            "M" => {}
            other => return Err(format!("event {i}: unexpected ph {other:?}")),
        }
    }

    for (&id, &n) in &sends {
        if n > 1 {
            return Err(format!("flow id {id}: {n} send events (ids must be unique)"));
        }
    }
    for (&id, &n) in &recvs {
        if n > 1 {
            return Err(format!("flow id {id}: {n} recv events (ids must be unique)"));
        }
        if !sends.contains_key(&id) {
            return Err(format!("flow id {id}: recv (ph f) without a matching send"));
        }
    }
    for &id in sends.keys() {
        if recvs.contains_key(&id) {
            summary.flow_pairs += 1;
        } else {
            summary.unmatched_send += 1;
        }
    }
    if strict_flows && summary.unmatched_send > 0 {
        return Err(format!(
            "{} send flow events without a matching recv",
            summary.unmatched_send
        ));
    }

    summary.ranks = pids.len();
    summary.threads = threads.len();
    Ok(summary)
}

/// A deliberately small JSON reader — just enough to validate our own
/// Chrome-trace output without a parsing dependency.
mod mini_json {
    pub enum Value {
        Null,
        Bool(bool),
        Num(f64),
        Str(String),
        Arr(Vec<Value>),
        Obj(Vec<(String, Value)>),
    }

    impl Value {
        pub fn get(&self, key: &str) -> Option<&Value> {
            match self {
                Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
                _ => None,
            }
        }
        pub fn as_f64(&self) -> Option<f64> {
            match self {
                Value::Num(n) => Some(*n),
                _ => None,
            }
        }
        pub fn as_str(&self) -> Option<&str> {
            match self {
                Value::Str(s) => Some(s),
                _ => None,
            }
        }
        pub fn as_arr(&self) -> Option<&[Value]> {
            match self {
                Value::Arr(v) => Some(v),
                _ => None,
            }
        }
    }

    pub fn parse(s: &str) -> Result<Value, String> {
        let mut p = P { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    struct P<'a> {
        b: &'a [u8],
        i: usize,
    }

    impl P<'_> {
        fn ws(&mut self) {
            while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
            {
                self.i += 1;
            }
        }

        fn peek(&self) -> Option<u8> {
            self.b.get(self.i).copied()
        }

        fn expect(&mut self, c: u8) -> Result<(), String> {
            if self.peek() == Some(c) {
                self.i += 1;
                Ok(())
            } else {
                Err(format!("expected {:?} at offset {}", c as char, self.i))
            }
        }

        fn value(&mut self) -> Result<Value, String> {
            match self.peek().ok_or("unexpected end of input")? {
                b'{' => self.obj(),
                b'[' => self.arr(),
                b'"' => Ok(Value::Str(self.string()?)),
                b't' => self.lit("true", Value::Bool(true)),
                b'f' => self.lit("false", Value::Bool(false)),
                b'n' => self.lit("null", Value::Null),
                _ => self.num(),
            }
        }

        fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
            if self.b[self.i..].starts_with(word.as_bytes()) {
                self.i += word.len();
                Ok(v)
            } else {
                Err(format!("bad literal at offset {}", self.i))
            }
        }

        fn num(&mut self) -> Result<Value, String> {
            let start = self.i;
            while self
                .peek()
                .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
            {
                self.i += 1;
            }
            std::str::from_utf8(&self.b[start..self.i])
                .ok()
                .and_then(|s| s.parse::<f64>().ok())
                .map(Value::Num)
                .ok_or_else(|| format!("bad number at offset {start}"))
        }

        fn string(&mut self) -> Result<String, String> {
            self.expect(b'"')?;
            let mut out = String::new();
            loop {
                match self.peek().ok_or("unterminated string")? {
                    b'"' => {
                        self.i += 1;
                        return Ok(out);
                    }
                    b'\\' => {
                        self.i += 1;
                        match self.peek().ok_or("unterminated escape")? {
                            b'"' => out.push('"'),
                            b'\\' => out.push('\\'),
                            b'/' => out.push('/'),
                            b'n' => out.push('\n'),
                            b't' => out.push('\t'),
                            b'r' => out.push('\r'),
                            b'b' => out.push('\u{8}'),
                            b'f' => out.push('\u{c}'),
                            b'u' => {
                                if self.i + 4 >= self.b.len() {
                                    return Err("truncated \\u escape".into());
                                }
                                let raw = &self.b[self.i + 1..self.i + 5];
                                let hex = std::str::from_utf8(raw).map_err(|_| "bad \\u escape")?;
                                let cp = u32::from_str_radix(hex, 16)
                                    .map_err(|_| "bad \\u escape")?;
                                out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                                self.i += 4;
                            }
                            c => return Err(format!("bad escape \\{}", c as char)),
                        }
                        self.i += 1;
                    }
                    _ => {
                        // consume one UTF-8 scalar
                        let rest = std::str::from_utf8(&self.b[self.i..])
                            .map_err(|_| "invalid UTF-8 in string")?;
                        let c = rest.chars().next().unwrap();
                        out.push(c);
                        self.i += c.len_utf8();
                    }
                }
            }
        }

        fn obj(&mut self) -> Result<Value, String> {
            self.expect(b'{')?;
            let mut kv = Vec::new();
            self.ws();
            if self.peek() == Some(b'}') {
                self.i += 1;
                return Ok(Value::Obj(kv));
            }
            loop {
                self.ws();
                let k = self.string()?;
                self.ws();
                self.expect(b':')?;
                self.ws();
                let v = self.value()?;
                kv.push((k, v));
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b'}') => {
                        self.i += 1;
                        return Ok(Value::Obj(kv));
                    }
                    _ => return Err(format!("expected , or }} at offset {}", self.i)),
                }
            }
        }

        fn arr(&mut self) -> Result<Value, String> {
            self.expect(b'[')?;
            let mut items = Vec::new();
            self.ws();
            if self.peek() == Some(b']') {
                self.i += 1;
                return Ok(Value::Arr(items));
            }
            loop {
                self.ws();
                items.push(self.value()?);
                self.ws();
                match self.peek() {
                    Some(b',') => self.i += 1,
                    Some(b']') => {
                        self.i += 1;
                        return Ok(Value::Arr(items));
                    }
                    _ => return Err(format!("expected , or ] at offset {}", self.i)),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(name: &'static str, cat: Category, rank: u32, tid: u32, t0: f64, t1: f64) -> Span {
        Span {
            name: Cow::Borrowed(name),
            cat,
            rank,
            tid,
            t_start: t0,
            t_end: t1,
            args: Vec::new(),
            flow_out: 0,
            flow_in: 0,
        }
    }

    #[test]
    fn span_wire_roundtrip_preserves_everything() {
        let mut s = mk("bcast", Category::Collective, 3, 3 * TIDS_PER_RANK, 1.5, 2.5);
        s.args.push((Cow::Borrowed("bytes"), 4096.0));
        s.args.push((Cow::Borrowed("v_start"), 0.25));
        s.flow_out = 77;
        s.flow_in = 99;
        let mut buf = Vec::new();
        s.encode(&mut buf);
        assert_eq!(buf.len(), s.byte_size(), "byte_size must match encoding");
        let mut r = WireReader::new(&buf);
        let d = Span::decode(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(d.name, "bcast");
        assert_eq!(d.cat, Category::Collective);
        assert_eq!((d.rank, d.tid), (3, 3 * TIDS_PER_RANK));
        assert_eq!((d.t_start, d.t_end), (1.5, 2.5));
        assert_eq!(d.args.len(), 2);
        assert_eq!(d.arg("bytes"), Some(4096.0));
        assert_eq!((d.flow_out, d.flow_in), (77, 99));
    }

    #[test]
    fn trace_data_wire_roundtrip() {
        let td = TraceData {
            spans: vec![
                mk("a", Category::Kernel, 0, 0, 0.0, 1.0),
                mk("b", Category::Comm, 1, TIDS_PER_RANK, 0.5, 0.75),
            ],
            dropped: 3,
        };
        let mut buf = Vec::new();
        td.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        let d = TraceData::decode(&mut r).unwrap();
        assert_eq!(r.remaining(), 0);
        assert_eq!(d.spans.len(), 2);
        assert_eq!(d.dropped, 3);
        assert_eq!(d.spans[1].name, "b");
    }

    #[test]
    fn flow_ids_are_nonzero_and_sequence_dependent() {
        let a = mix3(1 << 32 | 2, 42, 1);
        let b = mix3(1 << 32 | 2, 42, 2);
        let c = mix3(2 << 32 | 1, 42, 1);
        assert_ne!(a, 0);
        assert_ne!(a, b, "same channel, different seq");
        assert_ne!(a, c, "direction must distinguish ids");
        assert_eq!(a, mix3(1 << 32 | 2, 42, 1), "deterministic");
    }

    #[test]
    fn session_records_spans_with_rank_tids() {
        let session = begin_session();
        {
            let _rs = rank_scope(2);
            let mut sp = span("work", Category::Kernel);
            assert!(sp.is_active());
            sp.arg("bytes", 64.0);
            drop(sp);
            let id = flow_point(2, 0, 7);
            assert_ne!(id, 0);
        }
        let td = session.finish();
        assert_eq!(td.spans.len(), 1);
        assert_eq!(td.dropped, 0);
        assert_eq!(td.spans[0].rank, 2);
        assert_eq!(td.spans[0].tid, 2 * TIDS_PER_RANK);
        assert!(td.spans[0].t_end >= td.spans[0].t_start);
        assert_eq!(td.spans[0].arg("bytes"), Some(64.0));
        // after finish, everything is inert again
        assert!(!enabled());
        assert!(!span("x", Category::Kernel).is_active());
        assert_eq!(flow_point(0, 1, 0), 0);
    }

    #[test]
    fn spans_outside_a_rank_scope_are_inert_even_mid_session() {
        let session = begin_session();
        // this thread never entered a rank scope: a concurrent untraced
        // runtime in the same process must not pollute the session
        assert!(!span("stray", Category::Comm).is_active());
        assert_eq!(flow_point(0, 1, 5), 0);
        let td = session.finish();
        assert_eq!(td.spans.len(), 0);
    }

    #[test]
    fn worker_scope_assigns_per_slot_tids_and_restores() {
        let session = begin_session();
        {
            let _rs = rank_scope(1);
            let attr = parallel_attr().expect("active rank thread has an attr");
            {
                let _ws = worker_scope(attr, 3);
                let sp = span("tile", Category::Kernel);
                assert!(sp.is_active());
                drop(sp);
            }
            // restored to the rank's own identity
            let sp = span("after", Category::Rank);
            drop(sp);
        }
        let td = session.finish();
        assert_eq!(td.spans.len(), 2);
        let tile = td.spans.iter().find(|s| s.name == "tile").unwrap();
        let after = td.spans.iter().find(|s| s.name == "after").unwrap();
        assert_eq!(tile.tid, TIDS_PER_RANK + 1 + 3);
        assert_eq!(tile.rank, 1);
        assert_eq!(after.tid, TIDS_PER_RANK);
    }

    #[test]
    fn chrome_json_validates_and_pairs_flows() {
        let mut send = mk("send", Category::Comm, 0, 0, 1.0, 1.1);
        send.flow_out = 1234;
        let mut recv = mk("recv", Category::Comm, 1, TIDS_PER_RANK, 1.05, 1.2);
        recv.flow_in = 1234;
        let td = TraceData {
            spans: vec![
                mk("rank", Category::Rank, 0, 0, 0.0, 2.0),
                mk("rank", Category::Rank, 1, TIDS_PER_RANK, 0.0, 2.0),
                send,
                recv,
                mk("tile", Category::Kernel, 0, 1, 0.2, 0.9),
            ],
            dropped: 0,
        };
        let json = td.chrome_json();
        let sum = validate_chrome(&json, true).expect("valid chrome trace");
        assert_eq!(sum.x_events, 5);
        assert_eq!(sum.ranks, 2);
        assert_eq!(sum.threads, 3);
        assert_eq!(sum.flow_pairs, 1);
        assert_eq!(sum.unmatched_send, 0);
    }

    #[test]
    fn validator_rejects_cross_rank_tid_collisions_and_bad_flows() {
        // two pids sharing tid 0
        let td = TraceData {
            spans: vec![
                mk("a", Category::Rank, 0, 0, 0.0, 1.0),
                mk("b", Category::Rank, 1, 0, 0.0, 1.0),
            ],
            dropped: 0,
        };
        let err = validate_chrome(&td.chrome_json(), false).unwrap_err();
        assert!(err.contains("collision"), "{err}");

        // recv without a send
        let mut orphan = mk("recv", Category::Comm, 0, 0, 0.0, 1.0);
        orphan.flow_in = 9;
        let td = TraceData { spans: vec![orphan], dropped: 0 };
        let err = validate_chrome(&td.chrome_json(), false).unwrap_err();
        assert!(err.contains("without a matching send"), "{err}");

        // send without a recv: ok lax, error strict
        let mut dangling = mk("send", Category::Comm, 0, 0, 0.0, 1.0);
        dangling.flow_out = 9;
        let td = TraceData { spans: vec![dangling], dropped: 0 };
        assert_eq!(validate_chrome(&td.chrome_json(), false).unwrap().unmatched_send, 1);
        assert!(validate_chrome(&td.chrome_json(), true).is_err());
    }

    #[test]
    fn critical_path_attributes_exclusive_time() {
        // rank span 0..10s, one collective 1..4 containing a comm 2..3,
        // one kernel 5..9.  Exclusive: rank=idle 10-3-4=3, collective
        // 3-1=2, comm 1, kernel 4.
        let td = TraceData {
            spans: vec![
                mk("rank", Category::Rank, 0, 0, 0.0, 10.0),
                mk("bcast", Category::Collective, 0, 0, 1.0, 4.0),
                mk("recv", Category::Comm, 0, 0, 2.0, 3.0),
                mk("tile", Category::Kernel, 0, 0, 5.0, 9.0),
            ],
            dropped: 0,
        };
        let report = td.critical_path_report(&[0.125]);
        assert!(report.contains("4000.000"), "kernel exclusive:\n{report}");
        assert!(report.contains("2000.000"), "collective exclusive:\n{report}");
        assert!(report.contains("1000.000"), "comm exclusive:\n{report}");
        assert!(report.contains("3000.000"), "idle:\n{report}");
        assert!(report.contains("critical rank: 0"), "{report}");
        assert!(report.contains("0.125000"), "virtual clock column:\n{report}");
        assert!(report.contains("bcast"), "per-collective table:\n{report}");
    }

    #[test]
    fn mini_json_parses_escapes_and_numbers() {
        let v = mini_json::parse(
            "{\"a\": [1, -2.5e3, true, null, \"x\\n\\u0041\"], \"b\": {}}",
        )
        .unwrap();
        let arr = v.get("a").and_then(|a| a.as_arr()).unwrap();
        assert_eq!(arr.len(), 5);
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(-2500.0));
        assert_eq!(arr[4].as_str(), Some("x\nA"));
        assert!(mini_json::parse("{\"a\":}").is_err());
        assert!(mini_json::parse("[1,]").is_err());
    }
}
