//! # foopar — FooPar reproduced in Rust (+ JAX/Pallas AOT compute)
//!
//! A data-structure-centric SPMD framework for distributed-memory parallel
//! computing, reproducing Hargreaves & Merkle, *"FooPar: A Functional Object
//! Oriented Parallel Framework in Scala"* (CS.DC 2013).
//!
//! Algorithms are written **solely** through group operations on distributed
//! collections ([`data::DistSeq`], [`data::Grid`]) — `mapD`, `zipWithD`,
//! `reduceD`, `shiftD`, `allToAllD`, `allGatherD`, `apply` — which eliminates
//! explicit message passing (and with it deadlocks and races) while keeping
//! every operation's parallel runtime analyzable (Table 1 of the paper).
//!
//! Runs start at [`Runtime::builder`]: world size, a communication
//! backend chosen by name from the [`comm::backend::registry`] (the
//! paper's swappable `FooPar-X` modules — user backends plug in via the
//! [`Backend`] and [`Collectives`] traits), a transport (`"local"`
//! threads over shared memory, or `"tcp"` for one OS process per rank
//! over the [`comm::transport`] wire subsystem — the paper's
//! distributed-memory story), and machine cost parameters.
//!
//! The per-rank compute hot spots (block GEMM, Floyd-Warshall pivot updates)
//! are JAX/Pallas kernels AOT-lowered to HLO and executed through the PJRT C
//! API ([`runtime`]); Python never runs on the request path.
//!
//! Because this reproduction targets a laptop rather than a 512-core
//! InfiniBand cluster, ranks are OS threads exchanging real messages over an
//! in-process [`comm::fabric`], and every message/compute advances a
//! per-rank LogGP-style *virtual clock* (`ts + tw·bytes`); parallel time is
//! the max clock at completion.  See DESIGN.md §3 for the substitution
//! argument.

pub mod analysis;
pub mod cli;
pub mod comm;
pub mod config;
pub mod data;
pub mod graph;
pub mod matrix;
pub mod metrics;
pub mod plan;
pub mod runtime;
pub mod serve;
pub mod spmd;
pub mod testing;
pub mod trace;
pub mod tune;

pub mod algos;
pub mod experiments;

pub use comm::backend::{Backend, BackendProfile};
pub use comm::collectives::Collectives;
pub use comm::transport::Transport;
pub use comm::wire::WireData;
pub use matrix::params::{BlockParams, MicroKernel};
pub use serve::{JobOutput, JobSpec, JobStatus, ServeClient, ServeHandle, ServeOptions};
pub use spmd::{Runtime, RuntimeBuilder};
pub use tune::TuneProfile;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
