//! Extension: all-pairs shortest paths by repeated min-plus squaring on
//! the DNS grid.
//!
//! Not in the paper's evaluation, but a natural demonstration of the
//! framework's composability (its §7 outlook): the tropical semiring
//! product `D ⊗ D` has exactly the DNS communication pattern of Alg. 2
//! with (×, +) replaced by (+, min), so ⌈log₂ n⌉ squarings of the
//! distributed distance matrix solve APSP.  Uses the `minplus` Pallas
//! kernel in real-PJRT mode.
//!
//! Contrast with Alg. 3: Θ(log n) coarse rounds of Θ(n³/p) work instead
//! of n fine-grained pivot rounds — more total flops (log n × n³), less
//! latency-bound.  The apsp bench compares both.

use crate::data::grid::GridN;
use crate::graph::Graph;
use crate::matrix::block::Block;
use crate::matrix::dense::Mat;
use crate::matrix::gemm::INF;
use crate::runtime::compute::Compute;
use crate::spmd::Ctx;

use super::floyd_warshall::FwSource;

/// Outcome on one rank.
pub struct SqOutput {
    pub d_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
}

/// The (i, j) block of the current global distance matrix, gathered via
/// all-gather along grid lines each round.  p = q² ranks.
///
/// Round structure (one squaring): every process needs row-block-line i
/// of D and column-block-line j of D; we fetch them with `allGatherD`
/// along `ySeq` (my block row) and `xSeq` (my block column), then fold
/// min-plus products over the q pairs.
pub fn apsp_squaring_par(ctx: &Ctx, comp: &Compute, q: usize, src: &FwSource) -> SqOutput {
    let n = src.n();
    assert_eq!(n % q, 0);
    let b = n / q;
    let grid = GridN::square(ctx, q);

    let init = |c: &[usize]| -> Block {
        match src {
            FwSource::Real { n, density, seed } => {
                let g = Graph::random(*n, *density, *seed);
                let mut blk = Mat::zeros(b, b);
                for r in 0..b {
                    for cc in 0..b {
                        blk.set(r, cc, g.w.at(c[0] * b + r, c[1] * b + cc));
                    }
                }
                Block::Real(blk)
            }
            FwSource::Proxy { .. } => Block::proxy(b, (c[0] * 977 + c[1]) as u64),
        }
    };

    let mut data = grid.map_d(init);

    let mut span = 1usize;
    while span < n {
        // Gather my block-row (vary j: ySeq) and block-column (vary i: xSeq).
        let row_blocks = data.y_seq().all_gather_d();
        let col_blocks = data.x_seq().all_gather_d();
        data = data.map_d(|mine| {
            let (Some(rb), Some(cb)) = (&row_blocks, &col_blocks) else {
                return mine;
            };
            // D'_{ij} = min(D_{ij}, min_k D_{ik} ⊗ D_{kj})
            let mut acc = mine;
            for k in 0..q {
                let prod = comp.minplus(ctx, &rb[k], &cb[k]);
                acc = comp.min_blocks(ctx, acc, prod);
            }
            acc
        });
        span *= 2;
    }

    let d_block = data
        .my_coord()
        .map(|c| (c[0], c[1]))
        .zip(data.into_local())
        .map(|((i, j), blk)| (i, j, blk));
    SqOutput { d_block, t_local: ctx.now() }
}

/// Reassemble the result (verification).
pub fn collect_d(results: &[SqOutput], q: usize, b: usize) -> Mat {
    let mut d = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.d_block {
            d.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q);
    d
}

/// Clamp matrix at INF (squaring can carry INF+x slightly below 2·INF).
pub fn saturate(mut m: Mat) -> Mat {
    for v in m.data.iter_mut() {
        if *v > INF {
            *v = INF;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::graph::floyd_warshall_seq;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    fn check(n: usize, q: usize, density: f64, seed: u64) {
        let src = FwSource::Real { n, density, seed };
        let res = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            apsp_squaring_par(ctx, &Compute::Native, q, &src)
        });
        let got = saturate(collect_d(&res.results, q, n / q));
        let g = Graph::random(n, density, seed);
        let want = floyd_warshall_seq(&g);
        for (a, b) in got.data.iter().zip(&want.data) {
            if *a >= INF || *b >= INF {
                assert!(*a >= INF && *b >= INF, "{a} vs {b}");
            } else {
                assert!((a - b).abs() <= 1e-3 + 1e-4 * b.abs(), "{a} vs {b}");
            }
        }
        let _ = assert_allclose; // keep import used on all paths
    }

    #[test]
    fn squaring_matches_fw_seq() {
        check(8, 2, 0.4, 9);
        check(12, 3, 0.25, 10);
    }

    #[test]
    fn squaring_matches_fw_par() {
        let n = 16;
        let q = 2;
        let src = FwSource::Real { n, density: 0.3, seed: 11 };
        let sq = run(4, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            apsp_squaring_par(ctx, &Compute::Native, q, &src)
        });
        let fw = run(4, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            let grid = crate::data::grid::GridN::square(ctx, q);
            crate::algos::floyd_warshall::fw_on_grid(ctx, &Compute::Native, q, &src, &grid)
        });
        let a = saturate(collect_d(&sq.results, q, n / q));
        let b = crate::algos::floyd_warshall::collect_d(&fw.results, q, n / q);
        for (x, y) in a.data.iter().zip(&b.data) {
            if *x >= INF || *y >= INF {
                assert!(*x >= INF && *y >= INF);
            } else {
                assert!((x - y).abs() <= 1e-3);
            }
        }
    }

    #[test]
    fn squaring_modeled_mode() {
        let src = FwSource::Proxy { n: 512 };
        let res = run(
            16,
            BackendProfile::openmpi_fixed(),
            CostParams::new(1e-6, 1e-9),
            |ctx| apsp_squaring_par(ctx, &Compute::Modeled { rate: 1e9 }, 4, &src),
        );
        assert!(res.t_parallel > 0.0);
    }
}
