//! Algorithm 1: generic matrix-matrix multiplication (§4.2).
//!
//! The paper's Scala:
//! ```scala
//! val A  = Array.fill(M, M)(MJBLProxy(SEED, b))
//! val Bt = Array.fill(M, M)(MJBLProxy(SEED, b)).transpose
//! for (i <- 0 until M; j <- 0 until N)
//!   A(i) zip Bt(j) mapD { case (a, b) => a * b } reduceD (_ + _)
//! ```
//!
//! With p = q³ ranks, each (i, j) iteration distributes the k-dimension
//! over a fresh q-rank group; the q² iterations of the ∀-loop run
//! **sequentially** on every rank (SPMD), which is exactly the
//! bottleneck §4.2.1 analyzes: a per-rank Θ(q²) = Θ(p^{2/3}) nop
//! overhead that degrades the isoefficiency to Θ(p^{5/3}).  We charge
//! each nop iteration [`NOP_COST`] seconds of virtual time, playing the
//! role of the JVM loop/implicit-conversion overhead in the paper.

use crate::data::dseq::DistSeq;
use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::Compute;
use crate::spmd::Ctx;

/// Virtual cost of one nop ∀-loop iteration on a non-participating rank
/// (loop bookkeeping + the implicit-conversion overhead the paper counts
/// as `q²` work).  ~1 µs ≈ a handful of JVM allocations.
pub const NOP_COST: f64 = 1.0e-6;

/// Outcome on one rank.
pub struct GenericOutput {
    /// `Some((i, j, block))` on ranks `g·q` (the reduction roots).
    pub c_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
}

/// Run Algorithm 1 with p = q³ ranks (world must be ≥ q³).
pub fn mmm_generic(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> GenericOutput {
    assert_eq!(a.b, b.b);
    let mut c_block = None;

    // for (i <- 0 until M; j <- 0 until N) — sequential on every rank.
    for i in 0..q {
        for j in 0..q {
            // Group of q ranks handling C_{i,j}: ranks g·q .. g·q+q.
            let g = i * q + j;
            let ranks: Vec<usize> = (g * q..(g + 1) * q).collect();
            if !ranks.contains(&ctx.rank) {
                // Nop iteration: the rank still walks the loop and pays
                // the constant overhead (the q² term of §4.2.1).
                ctx.advance_compute(NOP_COST, 0.0);
                continue;
            }
            // A(i) zip Bt(j): element k is (A[i][k], B[k][j]) — lazy, the
            // generator runs only on the owner of k.
            let seq = DistSeq::from_fn(ctx, ranks, |k| (a.block(i, k), b.block(k, j)));
            // mapD { case (a, b) => a * b }
            let prod = seq.map_d(|(ab, bb)| comp.matmul(ctx, &ab, &bb));
            // reduceD (_ + _) — root is group rank 0 == world rank g·q.
            if let Some(blk) = prod.reduce_d(|x, y| comp.add(ctx, x, y)) {
                debug_assert!(c_block.is_none(), "one C block per root");
                c_block = Some((i, j, blk));
            }
        }
    }
    GenericOutput { c_block, t_local: ctx.now() }
}

/// Gather per-rank C blocks into the full result matrix (verification).
pub fn collect_c(results: &[GenericOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    use crate::matrix::dense::Mat;
    let mut c = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.c_block {
            c.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q, "expected one C block per (i,j)");
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::seq::matmul_seq;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    #[test]
    fn generic_matches_sequential_q2() {
        let (q, bsz) = (2, 8);
        let a = BlockSource::real(bsz, 11);
        let b = BlockSource::real(bsz, 22);
        let res = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_generic(ctx, &Compute::Native, q, &a, &b)
        });
        let c = collect_c(&res.results, q, bsz);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn generic_matches_sequential_q3() {
        let (q, bsz) = (3, 4);
        let a = BlockSource::real(bsz, 5);
        let b = BlockSource::real(bsz, 6);
        let res = run(27, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_generic(ctx, &Compute::Native, q, &a, &b)
        });
        let c = collect_c(&res.results, q, bsz);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn generic_agrees_with_dns() {
        let (q, bsz) = (2, 4);
        let a = BlockSource::real(bsz, 31);
        let b = BlockSource::real(bsz, 32);
        let gen = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_generic(ctx, &Compute::Native, q, &a, &b)
        });
        let dns = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            crate::algos::mmm_dns::dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let cg = collect_c(&gen.results, q, bsz);
        let cd = crate::algos::mmm_dns::collect_c(&dns.results, q, bsz);
        assert_allclose(&cg.data, &cd.data, 1e-5, 1e-6);
    }

    #[test]
    fn roots_are_every_qth_rank() {
        let q = 2;
        let a = BlockSource::real(4, 1);
        let b = BlockSource::real(4, 2);
        let res = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_generic(ctx, &Compute::Native, q, &a, &b)
        });
        for (rank, out) in res.results.iter().enumerate() {
            if rank % q == 0 {
                let (i, j, _) = out.c_block.as_ref().expect("root rank holds C");
                assert_eq!(i * q + j, rank / q);
            } else {
                assert!(out.c_block.is_none());
            }
        }
    }

    #[test]
    fn nop_overhead_scales_with_q_squared() {
        // modeled, free comms, zero-flop proxies: residual virtual time
        // on any rank ≈ (q² − participating) · NOP_COST
        let q = 2;
        let a = BlockSource::proxy(4, 1);
        let b = BlockSource::proxy(4, 2);
        let res = run(
            8,
            BackendProfile::openmpi_fixed(),
            CostParams::free(),
            |ctx| {
                mmm_generic(ctx, &Compute::Modeled { rate: 1e30 }, q, &a, &b);
                ctx.now()
            },
        );
        // every rank participates in exactly 1 of the q² groups
        let expect = (q * q - 1) as f64 * NOP_COST;
        for (rank, t) in res.results.iter().enumerate() {
            assert!((t - expect).abs() < expect * 0.5 + 1e-9, "rank {rank}: {t} vs {expect}");
        }
    }
}
