//! Extension: Cannon's algorithm — memory-efficient MMM on a 2-d grid.
//!
//! Not in the paper's evaluation, but the canonical demonstration of the
//! one Table-1 operation its algorithms never exercise: **`shiftD`**.
//! Cannon's algorithm multiplies with p = q² processes holding exactly
//! one block of A and one of B each (Θ(n²/p) memory per rank vs the DNS
//! algorithm's q-fold replication at p = q³), at the cost of 2(q−1)
//! cyclic shifts:
//!
//! ```text
//! skew:   A row i  shifted left  by i;  B column j shifted up by j
//! repeat q times:  C += A_local · B_local;  shift A left 1, B up 1
//! ```
//!
//! `T_P = q·(2(n/q)³/rate) + 2q·(t_s + t_w (n/q)²)`, cost-optimal with
//! isoefficiency Θ(p^{3/2}) — between the generic (p^{5/3}) and DNS
//! (p log p) variants; the ablation bench quantifies the trade.

use crate::data::grid::GridN;
use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::Compute;
use crate::spmd::Ctx;

pub struct CannonOutput {
    /// `Some((i, j, block))` on every grid member.
    pub c_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
}

/// Run Cannon's algorithm on a q×q grid (world ≥ q²); n = q·block edge.
#[deprecated(
    note = "use `algos::matmul(ctx, MatmulSpec::new(comp, q, a, b))` — \
            the planner prices Cannon against the alternatives; force it \
            with `.mode(PlanMode::Forced(Schedule::CannonBlocking))`"
)]
pub fn mmm_cannon(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> CannonOutput {
    let out = crate::plan::matmul(
        ctx,
        crate::plan::MatmulSpec::new(comp, q, a, b)
            .mode(crate::plan::PlanMode::Forced(crate::plan::Schedule::CannonBlocking)),
    );
    CannonOutput { c_block: out.c_block, t_local: out.t_local }
}

/// [`mmm_cannon`] over an explicit rank subset: grid process (i, j)
/// (row-major) runs on world rank `ranks[i*q + j]`.  Results are
/// identical to the world-anchored variant (placement never enters the
/// arithmetic).
#[deprecated(
    note = "use `algos::matmul(ctx, MatmulSpec::new(comp, q, a, b).on(ranks))` — \
            subset placement is a spec option now"
)]
pub fn mmm_cannon_on(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
    ranks: &[usize],
) -> CannonOutput {
    let out = crate::plan::matmul(
        ctx,
        crate::plan::MatmulSpec::new(comp, q, a, b)
            .on(ranks)
            .mode(crate::plan::PlanMode::Forced(crate::plan::Schedule::CannonBlocking)),
    );
    CannonOutput { c_block: out.c_block, t_local: out.t_local }
}

/// The hand-written blocking schedule — the eager path the planner's
/// interpreted `CannonBlocking` plan must match bit-for-bit, and the
/// serving runtime's placement hook: each job's members receive the
/// same grid in their assignment, so the subset grid is SPMD-consistent
/// without any world-wide agreement.
pub(crate) fn cannon_on_grid(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
    grid: &GridN,
) -> CannonOutput {
    assert_eq!(a.b, b.b);

    // Initial skew, expressed as the *source* indices each rank loads:
    // rank (i, j) starts with A(i, (j+i) mod q) and B((i+j) mod q, j) —
    // identical to physically shifting row i left by i / column j up by
    // j, but with zero messages thanks to lazy block sources (the same
    // MJBLProxy trick Alg. 1 uses).
    let ga = grid.map_d(|c| a.block(c[0], (c[1] + c[0]) % q));
    let gb = grid.map_d(|c| b.block((c[0] + c[1]) % q, c[1]));

    let coord = ga.my_coord();
    let mut a_cur = ga.into_local();
    let mut b_cur = gb.into_local();
    let mut acc: Option<Block> = None;

    for step in 0..q {
        // local multiply-accumulate
        if let (Some(ab), Some(bb)) = (&a_cur, &b_cur) {
            let prod = comp.matmul(ctx, ab, bb);
            acc = Some(match acc {
                None => prod,
                Some(c) => comp.add(ctx, c, prod),
            });
        }
        if step + 1 == q {
            break;
        }
        // shift A left along my row (ySeq line), B up along my column
        // (xSeq line) — Table 1's shiftD, Θ(t_s + t_w m) each.
        let data_a = grid.map_d(|_| a_cur.take().expect("member lost A block"));
        a_cur = data_a.into_seq_along(1).shift_d(-1).into_local();
        let data_b = grid.map_d(|_| b_cur.take().expect("member lost B block"));
        b_cur = data_b.into_seq_along(0).shift_d(-1).into_local();
    }

    let c_block = coord.zip(acc).map(|(c, blk)| (c[0], c[1], blk));
    CannonOutput { c_block, t_local: ctx.now() }
}

/// Pipelined Cannon: **prefetch the next blocks while multiplying the
/// current ones**.  Each step clones its A/B blocks, starts their cyclic
/// shifts with [`DistSeq::shift_d_start`](crate::data::dseq::DistSeq),
/// multiplies the (unmoved) current blocks, and only then `wait()`s —
/// so on the overlap-aware clock a step costs
/// `max(T_mult, t_s + t_w (n/q)²)` instead of the blocking
/// `T_mult + 2(t_s + t_w (n/q)²)`:
///
/// ```text
/// T_P = skew + q·max(2(n/q)³/rate, t_s + t_w (n/q)²) + last multiply
/// ```
///
/// (The A-row and B-column shifts travel disjoint grid lines, so their
/// comm timelines overlap each other as well as the GEMM.)  Results are
/// **bit-identical** to [`mmm_cannon`]: the same block values make the
/// same multiply-accumulate sequence — only the schedule changes.
#[deprecated(
    note = "use `algos::matmul(ctx, MatmulSpec::new(comp, q, a, b))` — \
            the planner's overlap pass derives this schedule automatically; \
            force it with `.mode(PlanMode::Forced(Schedule::CannonPipelined))`"
)]
pub fn mmm_cannon_pipelined(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> CannonOutput {
    let out = crate::plan::matmul(
        ctx,
        crate::plan::MatmulSpec::new(comp, q, a, b)
            .mode(crate::plan::PlanMode::Forced(crate::plan::Schedule::CannonPipelined)),
    );
    CannonOutput { c_block: out.c_block, t_local: out.t_local }
}

/// The hand-written split-phase schedule, kept as the reference the
/// planner's `overlap` rewrite is tested (and benched) against: the
/// interpreter must reproduce these clocks exactly, and the bench gate
/// trips if the auto-chosen plan ever models slower than this.
pub(crate) fn cannon_pipelined_eager(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> CannonOutput {
    assert_eq!(a.b, b.b);
    let grid = GridN::square(ctx, q);

    let ga = grid.map_d(|c| a.block(c[0], (c[1] + c[0]) % q));
    let gb = grid.map_d(|c| b.block((c[0] + c[1]) % q, c[1]));

    let coord = ga.my_coord();
    let mut a_cur = ga.into_local();
    let mut b_cur = gb.into_local();
    let mut acc: Option<Block> = None;

    for step in 0..q {
        // Prefetch: start shifting copies of the current blocks before
        // touching the GEMM — the wire time hides under the multiply.
        let pending = if step + 1 < q {
            let da = grid.map_d(|_| a_cur.clone().expect("member lost A block"));
            let ha = da.into_seq_along(1).shift_d_start(-1);
            let db = grid.map_d(|_| b_cur.clone().expect("member lost B block"));
            let hb = db.into_seq_along(0).shift_d_start(-1);
            Some((ha, hb))
        } else {
            None
        };
        // local multiply-accumulate on the *current* blocks
        if let (Some(ab), Some(bb)) = (&a_cur, &b_cur) {
            let prod = comp.matmul(ctx, ab, bb);
            acc = Some(match acc {
                None => prod,
                Some(c) => comp.add(ctx, c, prod),
            });
        }
        if let Some((ha, hb)) = pending {
            a_cur = ha.wait().into_local();
            b_cur = hb.wait().into_local();
        }
    }

    let c_block = coord.zip(acc).map(|(c, blk)| (c[0], c[1], blk));
    CannonOutput { c_block, t_local: ctx.now() }
}

/// Reassemble the result (verification).
pub fn collect_c(results: &[CannonOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    use crate::matrix::dense::Mat;
    let mut c = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.c_block {
            c.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::seq::matmul_seq;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    /// The eager blocking path (tests target the internals; the public
    /// names are planner shims now).
    fn cannon_eager(
        ctx: &Ctx,
        comp: &Compute,
        q: usize,
        a: &BlockSource,
        b: &BlockSource,
    ) -> CannonOutput {
        cannon_on_grid(ctx, comp, q, a, b, &GridN::square(ctx, q))
    }

    fn check(q: usize, bsz: usize, seed: u64) {
        let a = BlockSource::real(bsz, seed);
        let b = BlockSource::real(bsz, seed + 1);
        let res = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            cannon_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let c = collect_c(&res.results, q, bsz);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-3, 1e-4);
    }

    #[test]
    fn cannon_matches_sequential() {
        check(1, 8, 1);
        check(2, 8, 2);
        check(3, 4, 3);
        check(4, 4, 4);
    }

    #[test]
    fn cannon_agrees_with_dns() {
        let (q, bsz) = (2, 8);
        let a = BlockSource::real(bsz, 91);
        let b = BlockSource::real(bsz, 92);
        let cannon = run(4, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            cannon_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let dns = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            crate::algos::mmm_dns::dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let cc = collect_c(&cannon.results, q, bsz);
        let cd = crate::algos::mmm_dns::collect_c(&dns.results, q, bsz);
        assert_allclose(&cc.data, &cd.data, 1e-4, 1e-5);
    }

    #[test]
    fn cannon_on_subset_bit_identical_to_anchored() {
        // Same multiply on a 2x2 grid anchored at world 0 vs placed on
        // ranks {2, 5, 3, 4} of a world of 6: placement must not enter
        // the arithmetic.
        let (q, bsz) = (2usize, 8usize);
        let a = BlockSource::real(bsz, 61);
        let b = BlockSource::real(bsz, 62);
        let anchored = run(4, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            cannon_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let subset = run(6, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            cannon_on_grid(ctx, &Compute::Native, q, &a, &b, &GridN::square_on(ctx, q, &[2, 5, 3, 4]))
        });
        let ca = collect_c(&anchored.results, q, bsz);
        let cs = collect_c(&subset.results, q, bsz);
        assert_eq!(ca.data, cs.data);
        // unmapped ranks stayed silent
        assert_eq!(subset.metrics[0].msgs_sent, 0);
        assert_eq!(subset.metrics[1].msgs_sent, 0);
    }

    #[test]
    fn cannon_memory_vs_dns_processor_tradeoff() {
        // same n: Cannon uses q² ranks where DNS uses q³ — modeled T_P of
        // Cannon is higher (less parallelism) but per-rank communication
        // uses shiftD (cheap) instead of reductions
        let n = 4096;
        let q2 = 8; // cannon grid 8x8 = 64 ranks
        let q3 = 4; // dns grid 4x4x4 = 64 ranks — same p!
        let machine = CostParams::qdr_infiniband();
        let comp = Compute::Modeled { rate: 1e10 };
        let ac = BlockSource::proxy(n / q2, 1);
        let bc = BlockSource::proxy(n / q2, 2);
        let cannon = run(64, BackendProfile::openmpi_fixed(), machine, |ctx| {
            cannon_eager(ctx, &comp, q2, &ac, &bc)
        });
        let ad = BlockSource::proxy(n / q3, 1);
        let bd = BlockSource::proxy(n / q3, 2);
        let dns = run(64, BackendProfile::openmpi_fixed(), machine, |ctx| {
            crate::algos::mmm_dns::dns_eager(ctx, &comp, q3, &ad, &bd)
        });
        // both do n³/p multiply work; both must be within 2x of each other
        let ratio = cannon.t_parallel / dns.t_parallel;
        assert!(
            (0.5..2.0).contains(&ratio),
            "cannon {} vs dns {} (ratio {ratio})",
            cannon.t_parallel,
            dns.t_parallel
        );
    }

    #[test]
    fn pipelined_cannon_bit_identical_to_blocking() {
        for (q, bsz, seed) in [(2usize, 8usize, 21u64), (3, 4, 22), (4, 4, 23)] {
            let a = BlockSource::real(bsz, seed);
            let b = BlockSource::real(bsz, seed + 1);
            let blocking = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                cannon_eager(ctx, &Compute::Native, q, &a, &b)
            });
            let pipelined =
                run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                    cannon_pipelined_eager(ctx, &Compute::Native, q, &a, &b)
                });
            let cb = collect_c(&blocking.results, q, bsz);
            let cp = collect_c(&pipelined.results, q, bsz);
            // exact: same kernel, same multiply-accumulate order
            assert_eq!(cb.data, cp.data, "q={q}");
        }
    }

    #[test]
    fn pipelined_cannon_t_p_strictly_below_blocking() {
        // comm-visible modeled config: shifts cost real virtual time
        let q = 4;
        let machine = CostParams::new(5e-5, 1e-8); // slow gigabit-ish net
        let comp = Compute::Modeled { rate: 1e10 };
        let a = BlockSource::proxy(256, 1);
        let b = BlockSource::proxy(256, 2);
        let blocking = run(q * q, BackendProfile::openmpi_fixed(), machine, |ctx| {
            cannon_eager(ctx, &comp, q, &a, &b)
        });
        let pipelined = run(q * q, BackendProfile::openmpi_fixed(), machine, |ctx| {
            cannon_pipelined_eager(ctx, &comp, q, &a, &b)
        });
        assert!(
            pipelined.t_parallel < blocking.t_parallel,
            "pipelined {} !< blocking {}",
            pipelined.t_parallel,
            blocking.t_parallel
        );
        // the hidden comm shows up in the overlap metric
        let hidden: f64 = pipelined.metrics.iter().map(|m| m.overlap_hidden).sum();
        assert!(hidden > 0.0);
    }

    #[test]
    fn pipelined_cannon_modeled_proxies_stay_lazy() {
        let a = BlockSource::proxy(128, 1);
        let b = BlockSource::proxy(128, 2);
        let res = run(9, BackendProfile::openmpi_fixed(), CostParams::qdr_infiniband(), |ctx| {
            cannon_pipelined_eager(ctx, &Compute::Modeled { rate: 1e9 }, 3, &a, &b)
        });
        for out in &res.results {
            if let Some((_, _, blk)) = &out.c_block {
                assert!(blk.is_proxy());
            }
        }
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_bit_identical_to_eager() {
        // The one-PR migration shims route through the planner with a
        // forced schedule; callers must see exactly the old results.
        let (q, bsz) = (2usize, 8usize);
        let a = BlockSource::real(bsz, 71);
        let b = BlockSource::real(bsz, 72);
        let eager = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            cannon_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let shim = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_cannon(ctx, &Compute::Native, q, &a, &b)
        });
        assert_eq!(
            collect_c(&eager.results, q, bsz).data,
            collect_c(&shim.results, q, bsz).data
        );
        let shim_pipe = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_cannon_pipelined(ctx, &Compute::Native, q, &a, &b)
        });
        assert_eq!(
            collect_c(&eager.results, q, bsz).data,
            collect_c(&shim_pipe.results, q, bsz).data
        );
    }

    #[test]
    fn cannon_modeled_proxies_stay_lazy() {
        let a = BlockSource::proxy(128, 1);
        let b = BlockSource::proxy(128, 2);
        let res = run(9, BackendProfile::openmpi_fixed(), CostParams::qdr_infiniband(), |ctx| {
            cannon_eager(ctx, &Compute::Modeled { rate: 1e9 }, 3, &a, &b)
        });
        for out in &res.results {
            if let Some((_, _, blk)) = &out.c_block {
                assert!(blk.is_proxy());
            }
        }
    }
}
