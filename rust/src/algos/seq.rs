//! Sequential references: `T_S` implementations and workload models.
//!
//! The problem size `W` of the isoefficiency analysis (§2) is *defined*
//! as the sequential runtime, `W := T_S`.  For matrix-matrix
//! multiplication `T_S = 2n³/rate`; for Floyd-Warshall `T_S = 2n³/rate`
//! (n³ relax steps of one add + one min).

use crate::matrix::dense::Mat;
use crate::matrix::gemm;

/// Sequential matrix product (native gemm) — the correctness oracle and
/// single-core baseline for MMM experiments.
pub fn matmul_seq(a: &Mat, b: &Mat) -> Mat {
    gemm::matmul(a, b)
}

/// FLOPs of an n×n matrix multiplication.
pub fn mmm_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3)
}

/// Modeled sequential runtime of MMM at `rate` flops/s.
pub fn mmm_ts(n: usize, rate: f64) -> f64 {
    mmm_flops(n) / rate
}

/// FLOPs of Floyd-Warshall on n vertices (one add + one compare per
/// (i,j,k) triple).
pub fn fw_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3)
}

/// Modeled sequential runtime of Floyd-Warshall at `rate` flops/s.
pub fn fw_ts(n: usize, rate: f64) -> f64 {
    fw_flops(n) / rate
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_models() {
        assert_eq!(mmm_flops(10), 2000.0);
        assert_eq!(fw_flops(10), 2000.0);
        assert_eq!(mmm_ts(10, 1000.0), 2.0);
    }

    #[test]
    fn matmul_seq_is_gemm() {
        let a = Mat::random(8, 8, 1);
        let b = Mat::random(8, 8, 2);
        assert_eq!(matmul_seq(&a, &b), gemm::matmul(&a, &b));
    }
}
