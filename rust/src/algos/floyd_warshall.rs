//! Algorithm 3: parallel Floyd-Warshall on a 2-d grid (§5).
//!
//! The paper's Scala:
//! ```scala
//! var grid = GridN(R, R) mapD { case i :: j :: Nil => BLOCKS(i)(j) }
//! for (k <- 0 until n) {
//!   val ik = grid.xSeq.mapD(_(k % B)).apply(k / B)
//!   val kj = grid.ySeq.mapD(_.map(_(k % B))).apply(k / B)
//!   grid = grid.mapD { block => …min(block(i)(j), ik(j) + kj(i))… }
//! }
//! ```
//!
//! Process (i, j) of the q×q grid (p = q², B = n/q) owns block (i, j) of
//! the distance matrix.  For each pivot k: the pivot-row segment `ik`
//! travels down each process *column* (`xSeq` + one-to-all `apply`), the
//! pivot-column segment `kj` travels across each process *row* (`ySeq`),
//! and every block updates in parallel.  `T_P = Θ(n(B + (t_s+t_w B)
//! log q + B²/…))`, isoefficiency Θ((√p log p)³).
//!
//! Data plane: the pivot segments are [`Seg`]s on the shared
//! copy-on-write buffer ([`crate::matrix::buf::Buf`]), so the n per-pivot
//! broadcasts move **by reference** on shared memory — the extraction
//! copies Θ(B) once, the fan-out to √p grid members copies nothing
//! (asserted by `tests/integration_dataplane.rs`) — and the block update
//! itself threads across `threads_per_rank` cores past the bandwidth
//! threshold (see [`crate::matrix::gemm::EW_PAR_THRESHOLD`]).

use crate::data::grid::GridN;
use crate::graph::Graph;
use crate::matrix::block::Block;
use crate::runtime::compute::{Compute, Seg};
use crate::spmd::Ctx;

/// Input supplier for the distributed distance matrix.
#[derive(Clone)]
pub enum FwSource {
    /// Real mode: every rank deterministically generates the same graph
    /// (SPMD) and extracts its own block.
    Real { n: usize, density: f64, seed: u64 },
    /// Modeled mode: blocks are size-only proxies.
    Proxy { n: usize },
}

impl FwSource {
    pub fn n(&self) -> usize {
        match self {
            FwSource::Real { n, .. } | FwSource::Proxy { n } => *n,
        }
    }

    /// The (i, j) block of the initial distance matrix, edge `b`.
    /// Crate-visible so the plan interpreter's `Load` nodes share the
    /// exact source mapping.
    pub(crate) fn block(&self, i: usize, j: usize, b: usize) -> Block {
        match self {
            FwSource::Real { n, density, seed } => {
                let g = Graph::random(*n, *density, *seed);
                let mut blk = crate::matrix::dense::Mat::zeros(b, b);
                for r in 0..b {
                    for c in 0..b {
                        blk.set(r, c, g.w.at(i * b + r, j * b + c));
                    }
                }
                Block::Real(blk)
            }
            FwSource::Proxy { .. } => Block::proxy(b, (i * 1000 + j) as u64),
        }
    }
}

/// Outcome on one rank.
pub struct FwOutput {
    /// `Some((i, j, final block))` for grid members.
    pub d_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
}

/// Run Algorithm 3 on a q×q grid (world must be ≥ q²); `n` divisible by q.
#[deprecated(
    note = "use `algos::apsp(ctx, FwSpec::new(comp, q, src))` — \
            the planner interprets the Floyd–Warshall plan"
)]
pub fn floyd_warshall_par(ctx: &Ctx, comp: &Compute, q: usize, src: &FwSource) -> FwOutput {
    let out = crate::plan::apsp(ctx, crate::plan::FwSpec::new(comp, q, src));
    FwOutput { d_block: out.d_block, t_local: out.t_local }
}

/// [`floyd_warshall_par`] over an explicit rank subset: grid process
/// (i, j) runs on world rank `ranks[i*q + j]`.  The distance
/// arithmetic is placement-independent.
#[deprecated(
    note = "use `algos::apsp(ctx, FwSpec::new(comp, q, src).on(ranks))` — \
            subset placement is a spec option now"
)]
pub fn floyd_warshall_par_on(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    src: &FwSource,
    ranks: &[usize],
) -> FwOutput {
    let out = crate::plan::apsp(ctx, crate::plan::FwSpec::new(comp, q, src).on(ranks));
    FwOutput { d_block: out.d_block, t_local: out.t_local }
}

/// The hand-written pivot loop — the eager path the planner's
/// interpreted Floyd–Warshall plan must match bit-for-bit, and the
/// serving runtime's placement hook.
pub(crate) fn fw_on_grid(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    src: &FwSource,
    grid: &GridN,
) -> FwOutput {
    let n = src.n();
    assert_eq!(n % q, 0, "n must be divisible by q");
    let b = n / q;

    // var grid = GridN(R, R) mapD { (i, j) => BLOCKS(i)(j) }
    let mut data = grid.map_d(|c| src.block(c[0], c[1], b));

    for k in 0..n {
        let kb = k / b; // which block row/col holds the pivot
        let kloc = k % b; // offset within the block

        // ik: pivot-row segment for my process column —
        //   grid.xSeq.mapD(_(k % B)).apply(k / B)
        let ik = data
            .x_seq()
            .map_d(|blk| comp.block_row(ctx, &blk, kloc))
            .apply(kb);

        // kj: pivot-column segment for my process row —
        //   grid.ySeq.mapD(_.map(_(k % B))).apply(k / B)
        let kj = data
            .y_seq()
            .map_d(|blk| comp.block_col(ctx, &blk, kloc))
            .apply(kb);

        // grid = grid.mapD { block => min(block, kj ⊕ ik) }
        data = data.map_d(|blk| match (&ik, &kj) {
            (Some(ik), Some(kj)) => comp.fw_update(ctx, blk, ik, kj),
            _ => blk, // non-members carry no data anyway
        });
    }

    let d_block = data
        .my_coord()
        .map(|c| (c[0], c[1]))
        .zip(data.into_local())
        .map(|((i, j), blk)| (i, j, blk));
    FwOutput { d_block, t_local: ctx.now() }
}

/// Reassemble the distributed result (verification / examples).
pub fn collect_d(results: &[FwOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    use crate::matrix::dense::Mat;
    let mut d = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.d_block {
            d.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q);
    d
}

/// Convenience: a `Seg` pair check used by property tests.
pub fn seg_len_ok(s: &Seg, b: usize) -> bool {
    s.len() == b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::graph::floyd_warshall_seq;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    fn check_against_seq(n: usize, q: usize, density: f64, seed: u64) {
        let src = FwSource::Real { n, density, seed };
        let res = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            fw_on_grid(ctx, &Compute::Native, q, &src, &GridN::square(ctx, q))
        });
        let got = collect_d(&res.results, q, n / q);
        let g = Graph::random(n, density, seed);
        let want = floyd_warshall_seq(&g);
        assert_allclose(&got.data, &want.data, 1e-4, 1e-4);
    }

    #[test]
    fn fw_par_matches_seq_small() {
        check_against_seq(8, 2, 0.4, 1);
    }

    #[test]
    fn fw_par_matches_seq_q3() {
        check_against_seq(12, 3, 0.3, 2);
    }

    #[test]
    fn fw_par_matches_seq_sparse_and_dense() {
        check_against_seq(16, 4, 0.05, 3);
        check_against_seq(16, 2, 0.9, 4);
    }

    #[test]
    fn fw_par_single_process_degenerates_to_seq() {
        check_against_seq(8, 1, 0.5, 5);
    }

    #[test]
    fn fw_on_subset_matches_anchored() {
        let (n, q, density, seed) = (8usize, 2usize, 0.4f64, 7u64);
        let src = FwSource::Real { n, density, seed };
        let anchored = run(4, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            fw_on_grid(ctx, &Compute::Native, q, &src, &GridN::square(ctx, q))
        });
        let subset = run(6, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            fw_on_grid(ctx, &Compute::Native, q, &src, &GridN::square_on(ctx, q, &[5, 1, 4, 0]))
        });
        let da = collect_d(&anchored.results, q, n / q);
        let ds = collect_d(&subset.results, q, n / q);
        assert_eq!(da.data, ds.data);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shim_bit_identical_to_eager() {
        let (n, q, density, seed) = (8usize, 2usize, 0.4f64, 9u64);
        let src = FwSource::Real { n, density, seed };
        let eager = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            fw_on_grid(ctx, &Compute::Native, q, &src, &GridN::square(ctx, q))
        });
        let shim = run(q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            floyd_warshall_par(ctx, &Compute::Native, q, &src)
        });
        assert_eq!(
            collect_d(&eager.results, q, n / q).data,
            collect_d(&shim.results, q, n / q).data
        );
    }

    #[test]
    fn fw_modeled_runs_at_scale_without_data() {
        // n=1024, q=4 modeled: 1024 pivots over proxies, no floats
        let src = FwSource::Proxy { n: 1024 };
        let res = run(
            16,
            BackendProfile::openmpi_fixed(),
            CostParams::new(1e-6, 1e-9),
            |ctx| fw_on_grid(ctx, &Compute::Modeled { rate: 1e9 }, 4, &src, &GridN::square(ctx, 4)),
        );
        assert!(res.t_parallel > 0.0);
        for out in &res.results {
            if let Some((_, _, blk)) = &out.d_block {
                assert!(blk.is_proxy());
            }
        }
    }
}
