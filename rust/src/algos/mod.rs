//! The paper's algorithms, written against the FooPar public API.
//!
//! * [`mmm_generic`] — Algorithm 1: generic matrix-matrix multiplication
//!   (zip/mapD/reduceD over q³ ranks, sequential ∀-loop, isoefficiency
//!   Θ(p^{5/3})).
//! * [`mmm_dns`] — Algorithm 2: Grid3D / DNS multiplication
//!   (zipWithD · zSeq · reduceD, isoefficiency Θ(p log p)); plus
//!   `mmm_dns_pipelined`, the chunked-reduction overlap variant built on
//!   the non-blocking `reduce_d_start` handles.
//! * [`floyd_warshall`] — Algorithm 3: 2-d grid parallel Floyd-Warshall.
//! * [`apsp_squaring`] — extension: APSP by repeated min-plus squaring on
//!   the DNS grid (uses the tropical Pallas kernel).
//! * [`cannon`] — extension: Cannon's 2-d algorithm (memory-efficient,
//!   exercises `shiftD`; isoefficiency Θ(p^{3/2})); plus
//!   `mmm_cannon_pipelined`, which prefetches the next blocks with
//!   `shift_d_start` while multiplying the current ones.
//! * [`dns_baseline`] — hand-coded DNS directly on the fabric, no
//!   framework abstractions: the "C/MPI version" of §6 used to measure
//!   FooPar's abstraction overhead.
//! * [`seq`] — sequential references (`T_S`) and correctness oracles.
//!
//! The consolidated entry points are [`matmul`] and [`apsp`] (re-exported
//! from [`crate::plan`]): describe the product once, let the planner fuse
//! elementwise chains, derive the split-phase overlap schedule, dry-run
//! every candidate on the cost model, and interpret the cheapest.  The
//! per-algorithm names above remain as deprecated shims for one release.

pub mod dns_baseline;
pub mod floyd_warshall;
pub mod mmm_dns;
pub mod mmm_generic;
pub mod apsp_squaring;
pub mod cannon;
pub mod seq;

pub use crate::plan::{
    apsp, collect_c, collect_d, explain_apsp, explain_matmul, matmul, Explain, FwPlanOutput,
    FwSpec, MatmulSpec, PlanMode, PlanOutput, Schedule,
};
