//! Hand-coded DNS matrix multiplication directly on the fabric — the
//! analogue of the paper's "highly optimized parallel version of the DNS
//! algorithm, using C/MPI" (§6).
//!
//! Functionally identical to [`crate::algos::mmm_dns`], but with **zero
//! framework machinery**: no groups, no distributed sequences, no grid —
//! raw rank arithmetic, explicit tags, a manually unrolled binomial
//! reduction.  Comparing its virtual/wall time against Algorithm 2
//! measures exactly what Fig. 5 measures between the C version and
//! FooPar: the abstraction overhead (paper: "The C-version performs only
//! slightly better").

use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::Compute;
use crate::spmd::Ctx;

/// Tag namespace for the hand-rolled reduction (disjoint from group tags
/// by construction: group ids are hash-mixed, this is a fixed pattern).
const BASE_TAG: u64 = 0xC0DE_BA5E_0000_0000;

pub struct BaselineOutput {
    pub c_block: Option<(usize, usize, Block)>,
    pub t_local: f64,
}

/// DNS multiply with p = q³ ranks, hand-coded.
pub fn dns_baseline(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> BaselineOutput {
    let p = q * q * q;
    if ctx.rank >= p {
        return BaselineOutput { c_block: None, t_local: ctx.now() };
    }
    // row-major (i, j, k) layout — identical to GridN::cube
    let (i, j, k) = (ctx.rank / (q * q), (ctx.rank / q) % q, ctx.rank % q);

    // local product C_partial = A(i,k) · B(k,j)
    let ab = a.block(i, k);
    let bb = b.block(k, j);
    let mut acc = comp.matmul(ctx, &ab, &bb);

    // binomial reduction along the z-line (k = 0..q), root k=0
    let line_base = (i * q + j) * q; // world rank of (i, j, 0)
    let tag = BASE_TAG + (i * q + j) as u64;
    let mut mask = 1usize;
    let mut sent = false;
    while mask < q {
        if k & mask == 0 {
            let src_k = k | mask;
            if src_k < q {
                let other: Block = ctx.recv(line_base + src_k, tag);
                acc = comp.add(ctx, acc, other);
            }
        } else {
            ctx.send(line_base + (k & !mask), tag, acc);
            sent = true;
            acc = Block::proxy(0, 0); // moved out; placeholder
            break;
        }
        mask <<= 1;
    }

    let c_block = (!sent && k == 0).then_some((i, j, acc));
    BaselineOutput { c_block, t_local: ctx.now() }
}

/// Reassemble the result (verification).
pub fn collect_c(results: &[BaselineOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    use crate::matrix::dense::Mat;
    let mut c = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.c_block {
            c.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::seq::matmul_seq;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    #[test]
    fn baseline_matches_sequential() {
        for (q, bsz) in [(2usize, 8usize), (3, 4)] {
            let a = BlockSource::real(bsz, 51);
            let b = BlockSource::real(bsz, 52);
            let res = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                dns_baseline(ctx, &Compute::Native, q, &a, &b)
            });
            let c = collect_c(&res.results, q, bsz);
            let want = matmul_seq(&a.assemble(q), &b.assemble(q));
            assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
        }
    }

    #[test]
    fn baseline_agrees_with_framework_dns() {
        let (q, bsz) = (2, 8);
        let a = BlockSource::real(bsz, 61);
        let b = BlockSource::real(bsz, 62);
        let base = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_baseline(ctx, &Compute::Native, q, &a, &b)
        });
        let dns = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            crate::algos::mmm_dns::dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let cb = collect_c(&base.results, q, bsz);
        let cd = crate::algos::mmm_dns::collect_c(&dns.results, q, bsz);
        assert_allclose(&cb.data, &cd.data, 1e-6, 1e-7);
    }

    #[test]
    fn baseline_virtual_time_close_to_framework() {
        // modeled at the paper's scale: framework overhead (extra virtual
        // time of Alg. 2 over the baseline) must be small
        let q = 4;
        let a = BlockSource::proxy(128, 1);
        let b = BlockSource::proxy(128, 2);
        let machine = CostParams::qdr_infiniband();
        let comp = Compute::Modeled { rate: 1e10 };
        let base = run(64, BackendProfile::openmpi_fixed(), machine, |ctx| {
            dns_baseline(ctx, &comp, q, &a, &b)
        });
        let dns = run(64, BackendProfile::openmpi_fixed(), machine, |ctx| {
            crate::algos::mmm_dns::dns_eager(ctx, &comp, q, &a, &b)
        });
        let rel = (dns.t_parallel - base.t_parallel).abs() / base.t_parallel;
        assert!(rel < 0.05, "framework overhead {:.1}% too large", rel * 100.0);
    }
}
