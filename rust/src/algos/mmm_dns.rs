//! Algorithm 2: matrix-matrix multiplication with the Grid3D abstraction
//! (the DNS communication pattern, §4.3).
//!
//! The paper's Scala:
//! ```scala
//! val G  = Grid3D(R, R, R)
//! val GA = G mapD { case (i, j, k) => A(i)(k) }
//! val GB = G mapD { case (i, j, k) => B(k)(j) }
//! val C  = ((GA zipWithD GB)(_ * _) zSeq) reduceD (_ + _)
//! ```
//!
//! Process (i,j,k) holds `A(i,k)` and `B(k,j)` (Fig. 4a), multiplies them
//! locally (Fig. 4b), and partial products are summed along the z-axis
//! onto the k=0 plane (Fig. 4c).  With p = q³:
//! `T_P = Θ(n³/p) + Θ((n²/p^{2/3}) log p)`, isoefficiency Θ(p log p) —
//! matching the DNS algorithm.

use crate::comm::group::Group;
use crate::data::dseq::DistSeq;
use crate::data::grid::GridN;
use crate::matrix::block::{Block, BlockSource};
use crate::runtime::compute::Compute;
use crate::spmd::Ctx;

/// Outcome on one rank.
pub struct DnsOutput {
    /// `Some((i, j, block))` on the k=0 plane (the owners of C's blocks).
    pub c_block: Option<(usize, usize, Block)>,
    /// Virtual time when this rank finished.
    pub t_local: f64,
}

/// Run Algorithm 2 on a q×q×q grid (requires `ctx.world >= q³`).
///
/// `a` / `b` supply the input blocks of edge `n/q`; `comp` decides real
/// vs modeled execution.  Every rank participates SPMD-style; ranks
/// outside the grid no-op and return `None`.
#[deprecated(
    note = "use `algos::matmul(ctx, MatmulSpec::new(comp, q, a, b))` — \
            the planner prices DNS against the alternatives; force it \
            with `.mode(PlanMode::Forced(Schedule::DnsBlocking))`"
)]
pub fn mmm_dns(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> DnsOutput {
    let out = crate::plan::matmul(
        ctx,
        crate::plan::MatmulSpec::new(comp, q, a, b)
            .mode(crate::plan::PlanMode::Forced(crate::plan::Schedule::DnsBlocking)),
    );
    DnsOutput { c_block: out.c_block, t_local: out.t_local }
}

/// The hand-written blocking schedule — the eager path the planner's
/// interpreted `DnsBlocking` plan must match bit-for-bit.
pub(crate) fn dns_eager(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
) -> DnsOutput {
    assert_eq!(a.b, b.b, "block sizes of A and B must match");
    let grid = GridN::cube(ctx, q);

    // GA = G mapD { (i,j,k) => A(i)(k) };  GB = G mapD { (i,j,k) => B(k)(j) }
    let ga = grid.map_d(|c| a.block(c[0], c[2]));
    let gb = grid.map_d(|c| b.block(c[2], c[1]));

    // (GA zipWithD GB)(_ * _)
    let prod = ga.zip_with_d(gb, |x, y| comp.matmul(ctx, &x, &y));

    // … zSeq reduceD (_ + _): sum partial products onto the k=0 plane.
    let coord = prod.my_coord();
    let c = prod.into_seq_along(2).reduce_d(|x, y| comp.add(ctx, x, y));

    let c_block = match (c, coord) {
        (Some(blk), Some(cd)) => Some((cd[0], cd[1], blk)),
        _ => None,
    };
    DnsOutput { c_block, t_local: ctx.now() }
}

/// Pipelined DNS: compute the local product **panel by panel** and start
/// each panel's z-axis reduction while the next panel multiplies — the
/// "prefetch next block while multiplying the current one" schedule, so
/// most of the Θ((n²/p^{2/3}) log p) reduction hides under the Θ(n³/p)
/// GEMM on the overlap-aware clock:
///
/// ```text
/// T_P ≈ Θ(n³/p) + (1/K)·Θ((n²/p^{2/3}) log p)      (K = chunks)
/// ```
///
/// At most one reduction handle is outstanding at a time (start panel
/// `c+1`'s GEMM, wait panel `c`'s reduce), keeping the comm schedule
/// single-port like the blocking run.  Results are **bit-identical** to
/// [`mmm_dns`]: the native kernel accumulates each element over `k` in
/// the same order whether B is whole or column-sliced, each column's
/// z-fold order is unchanged, and the panel hstack reassembles the exact
/// block (modeled runs reassemble the exact proxy metadata).
#[deprecated(
    note = "use `algos::matmul(ctx, MatmulSpec::new(comp, q, a, b).chunks(chunks))` — \
            the planner's overlap pass derives this schedule automatically; \
            force it with `.mode(PlanMode::Forced(Schedule::DnsPipelined))`"
)]
pub fn mmm_dns_pipelined(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
    chunks: usize,
) -> DnsOutput {
    let out = crate::plan::matmul(
        ctx,
        crate::plan::MatmulSpec::new(comp, q, a, b)
            .chunks(chunks)
            .mode(crate::plan::PlanMode::Forced(crate::plan::Schedule::DnsPipelined)),
    );
    DnsOutput { c_block: out.c_block, t_local: out.t_local }
}

/// The hand-written split-phase schedule, kept as the reference the
/// planner's `overlap` rewrite is tested (and benched) against.
pub(crate) fn dns_pipelined_eager(
    ctx: &Ctx,
    comp: &Compute,
    q: usize,
    a: &BlockSource,
    b: &BlockSource,
    chunks: usize,
) -> DnsOutput {
    assert_eq!(a.b, b.b, "block sizes of A and B must match");
    assert!(chunks >= 1, "need at least one panel");
    let grid = GridN::cube(ctx, q);

    let ga = grid.map_d(|c| a.block(c[0], c[2]));
    let gb = grid.map_d(|c| b.block(c[2], c[1]));
    let coord = grid.my_coord();
    let a_blk = ga.into_local();
    let b_blk = gb.into_local();

    let bcols = b.b;
    let k = chunks.min(bcols).max(1);
    let zranks = coord.as_ref().map(|c| grid.line_ranks(c, 2));

    let mut panels: Vec<Option<Block>> = (0..k).map(|_| None).collect();
    let mut pending: Option<(usize, crate::data::dseq::PendingReduce<'_, '_, Block>)> = None;
    for c in 0..k {
        let (lo, hi) = (c * bcols / k, (c + 1) * bcols / k);
        // panel GEMM on the main clock — overlaps the previous panel's
        // in-flight reduction
        let prod = match (&a_blk, &b_blk) {
            (Some(ab), Some(bb)) => Some(comp.matmul_panel(ctx, ab, bb, lo, hi)),
            _ => None,
        };
        if let Some((idx, h)) = pending.take() {
            panels[idx] = h.wait();
        }
        // start this panel's z-reduction; it rides under panel c+1's GEMM
        let zseq = match (&zranks, prod) {
            (Some(ranks), Some(p)) => {
                DistSeq::from_parts(Group::new(ctx, ranks.clone()), Some(p))
            }
            _ => DistSeq::from_parts(Group::new(ctx, vec![ctx.rank]), None),
        };
        pending = Some((c, zseq.reduce_d_start(|x, y| comp.add(ctx, x, y))));
    }
    if let Some((idx, h)) = pending.take() {
        panels[idx] = h.wait();
    }

    // Reassemble on the k=0 plane (group rank 0 of every z-line).
    let c_block = match coord {
        Some(cd) if cd[2] == 0 => {
            let blocks: Vec<Block> = panels
                .into_iter()
                .map(|p| p.expect("k=0 member missing a reduced panel"))
                .collect();
            Some((cd[0], cd[1], Block::hstack(blocks)))
        }
        _ => None,
    };
    DnsOutput { c_block, t_local: ctx.now() }
}

/// Gather per-rank C blocks into the full result matrix (verification /
/// examples; not part of the timed algorithm).
pub fn collect_c(results: &[DnsOutput], q: usize, b: usize) -> crate::matrix::dense::Mat {
    use crate::matrix::dense::Mat;
    let mut c = Mat::zeros(q * b, q * b);
    let mut seen = 0;
    for out in results {
        if let Some((i, j, blk)) = &out.c_block {
            c.set_block(*i, *j, &blk.materialize());
            seen += 1;
        }
    }
    assert_eq!(seen, q * q, "expected one C block per (i,j)");
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algos::seq::matmul_seq;
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::testing::spmd_run as run;
    use crate::testing::assert_allclose;

    #[test]
    fn dns_matches_sequential_q2() {
        let (q, bsz) = (2, 8);
        let a = BlockSource::real(bsz, 100);
        let b = BlockSource::real(bsz, 200);
        let res = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let c = collect_c(&res.results, q, bsz);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn dns_matches_sequential_q3() {
        let (q, bsz) = (3, 4);
        let a = BlockSource::real(bsz, 7);
        let b = BlockSource::real(bsz, 8);
        let res = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let c = collect_c(&res.results, q, bsz);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
    }

    #[test]
    fn dns_pattern_fig4_c_blocks_on_k0_plane() {
        // Fig. 4: the (partial) result lands on process (i, j, 0).
        let q = 2;
        let a = BlockSource::real(4, 1);
        let b = BlockSource::real(4, 2);
        let res = run(8, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        for (rank, out) in res.results.iter().enumerate() {
            let (i, j, k) = (rank / 4, (rank / 2) % 2, rank % 2);
            if k == 0 {
                let (ci, cj, _) = out.c_block.as_ref().expect("k=0 plane owns C");
                assert_eq!((*ci, *cj), (i, j));
            } else {
                assert!(out.c_block.is_none());
            }
        }
    }

    #[test]
    fn dns_modeled_charges_compute_and_comm() {
        let q = 2;
        let rate = 1e9;
        let a = BlockSource::proxy(64, 1);
        let b = BlockSource::proxy(64, 2);
        let res = run(
            8,
            BackendProfile::openmpi_fixed(),
            CostParams::new(1e-5, 1e-9),
            |ctx| dns_eager(ctx, &Compute::Modeled { rate }, q, &a, &b),
        );
        // every rank did one 64³ multiply; reduction adds comm + adds
        let mult = 2.0 * 64f64.powi(3) / rate;
        assert!(res.t_parallel > mult, "T_P {} <= mult {mult}", res.t_parallel);
        // all C blocks are proxies, no data materialized
        for out in &res.results {
            if let Some((_, _, blk)) = &out.c_block {
                assert!(blk.is_proxy());
            }
        }
    }

    #[test]
    fn pipelined_dns_bit_identical_to_blocking() {
        for (q, bsz, chunks) in [(2usize, 8usize, 1usize), (2, 8, 3), (3, 6, 4)] {
            let a = BlockSource::real(bsz, 300 + chunks as u64);
            let b = BlockSource::real(bsz, 400 + chunks as u64);
            let blocking =
                run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                    dns_eager(ctx, &Compute::Native, q, &a, &b)
                });
            let pipelined =
                run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                    dns_pipelined_eager(ctx, &Compute::Native, q, &a, &b, chunks)
                });
            let cb = collect_c(&blocking.results, q, bsz);
            let cp = collect_c(&pipelined.results, q, bsz);
            // exact: same kernel fp order per element, same z-fold order
            assert_eq!(cb.data, cp.data, "q={q} chunks={chunks}");
        }
    }

    #[test]
    fn pipelined_dns_t_p_strictly_below_blocking() {
        let q = 2;
        let machine = CostParams::new(5e-5, 1e-8); // comm-visible network
        let comp = Compute::Modeled { rate: 1e10 };
        let a = BlockSource::proxy(256, 1);
        let b = BlockSource::proxy(256, 2);
        let blocking = run(q * q * q, BackendProfile::openmpi_fixed(), machine, |ctx| {
            dns_eager(ctx, &comp, q, &a, &b)
        });
        let pipelined = run(q * q * q, BackendProfile::openmpi_fixed(), machine, |ctx| {
            dns_pipelined_eager(ctx, &comp, q, &a, &b, 4)
        });
        // identical proxy metadata…
        for (bl, pi) in blocking.results.iter().zip(&pipelined.results) {
            match (&bl.c_block, &pi.c_block) {
                (Some((i, j, x)), Some((i2, j2, y))) => {
                    assert_eq!((i, j), (i2, j2));
                    assert_eq!(x, y);
                }
                (None, None) => {}
                _ => panic!("c_block placement diverged"),
            }
        }
        // …at strictly lower overlapped T_P
        assert!(
            pipelined.t_parallel < blocking.t_parallel,
            "pipelined {} !< blocking {}",
            pipelined.t_parallel,
            blocking.t_parallel
        );
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_bit_identical_to_eager() {
        let (q, bsz) = (2usize, 8usize);
        let a = BlockSource::real(bsz, 81);
        let b = BlockSource::real(bsz, 82);
        let eager = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        let shim = run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            mmm_dns(ctx, &Compute::Native, q, &a, &b)
        });
        assert_eq!(
            collect_c(&eager.results, q, bsz).data,
            collect_c(&shim.results, q, bsz).data
        );
        let shim_pipe =
            run(q * q * q, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
                mmm_dns_pipelined(ctx, &Compute::Native, q, &a, &b, 3)
            });
        assert_eq!(
            collect_c(&eager.results, q, bsz).data,
            collect_c(&shim_pipe.results, q, bsz).data
        );
    }

    #[test]
    fn dns_extra_world_ranks_idle() {
        let q = 2;
        let a = BlockSource::real(4, 3);
        let b = BlockSource::real(4, 4);
        let res = run(10, BackendProfile::openmpi_fixed(), CostParams::free(), |ctx| {
            dns_eager(ctx, &Compute::Native, q, &a, &b)
        });
        assert!(res.results[8].c_block.is_none());
        assert!(res.results[9].c_block.is_none());
        assert_eq!(res.metrics[9].msgs_sent, 0);
        let c = collect_c(&res.results, q, 4);
        let want = matmul_seq(&a.assemble(q), &b.assemble(q));
        assert_allclose(&c.data, &want.data, 1e-4, 1e-5);
    }
}
