//! SPMD runtime: rank contexts, the [`Runtime`] builder entry point, and
//! the rank launchers (thread-per-rank in-process, process-per-rank over
//! TCP).
//!
//! FooPar programs are SPMD: every rank runs the same closure; distributed
//! collections decide per-rank behaviour (§3.2 of the paper).  A run is
//! configured through the builder —
//!
//! ```text
//! let res = Runtime::builder()
//!     .world(8)                 // number of ranks
//!     .backend("shmem")         // registry lookup (or .backend_profile /
//!                               //  .backend_obj for explicit objects)
//!     .transport("tcp")         // delivery substrate: "local" (threads
//!                               //  over shared memory, the default),
//!                               //  "tcp-loopback", "tcp" (one OS
//!                               //  process per rank, re-exec spawner),
//!                               //  or "hybrid" (two-level: shmem within
//!                               //  a node, tcp across; needs
//!                               //  .ranks_per_node(n))
//!     .machine("carver")        // interconnect costs (or .cost(...))
//!     .run(|ctx| ...)?;         // the SPMD closure, once per rank
//! ```
//!
//! — which launches `world` ranks over the selected
//! [`Transport`](crate::comm::transport::Transport), hands each a
//! [`Ctx`] wired to the backend's
//! [`Collectives`](crate::comm::collectives::Collectives) strategy, and
//! collects results, per-rank virtual clocks and metrics at the join.
//!
//! The parallel runtime reported for a run, `T_P`, is the **maximum
//! virtual clock** over ranks — exactly the quantity the paper's
//! isoefficiency analysis reasons about.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::anyhow;

use crate::comm::backend::{registry, Backend, BackendProfile};
use crate::comm::collectives::Collectives;
use crate::comm::cost::{CostParams, HierCost};
use crate::comm::fabric::Fabric;
use crate::comm::message::Msg;
use crate::comm::transport::hier::{self, HierTransport, Topology};
use crate::comm::transport::tcp::TcpTransport;
use crate::comm::transport::{launch, Envelope, Transport};
use crate::comm::wire::WireData;
use crate::config::MachineConfig;
use crate::matrix::params::BlockParams;
use crate::metrics::{MetricsSnapshot, ProfileTag, RankMetrics};
use crate::plan::PlanMode;
use crate::trace;
use crate::tune::TuneProfile;

/// Per-rank execution context: identity, clock, transport access,
/// metrics, and the active backend's collective strategy.
pub struct Ctx {
    pub rank: usize,
    pub world: usize,
    transport: Arc<dyn Transport>,
    /// Virtual time in seconds (the paper's cost model §2).
    clock: Cell<f64>,
    /// Effective cost parameters (machine base × backend shaping).
    /// In a hierarchical world this is the **inter-node** link; flat
    /// worlds have only one link, so it is *the* cost either way and
    /// every pre-hierarchy caller keeps its meaning.
    pub cost: CostParams,
    /// Node topology of the world (single flat node unless the runtime
    /// was built with `ranks_per_node`).
    topo: Arc<Topology>,
    /// Per-level link pricing: intra-node vs inter-node message costs.
    /// Flat worlds price both levels at `cost`, so clocks are unchanged.
    link: HierCost,
    backend: Arc<dyn Backend>,
    collectives: Arc<dyn Collectives>,
    pub metrics: RankMetrics,
    /// Group-signature → number of groups created with that signature;
    /// used to give every group instance a distinct tag namespace that is
    /// consistent across members without any coordination messages.
    tag_alloc: RefCell<HashMap<u64, u64>>,
    /// Active tag scope (0 = none).  Inside [`Ctx::with_tag_scope`],
    /// group-id allocation switches to `scoped_tag_alloc` and folds the
    /// scope seed into every id, so namespaces depend only on the scope
    /// seed plus the *scope-local* creation order — not on whatever
    /// groups this rank created before (which diverges across members of
    /// a serving job whose peers ran different jobs first).
    tag_scope: Cell<u64>,
    /// Scope-local instance counters; cleared at every scope entry.
    scoped_tag_alloc: RefCell<HashMap<u64, u64>>,
    /// Non-zero while the clock is forked onto a non-blocking operation's
    /// comm timeline (see [`Ctx::with_clock`]) — guards against nesting.
    overlap_depth: Cell<u32>,
    /// Cores this rank's block kernels may use (the paper's
    /// BLAS-threads-per-process knob); `Compute::Native` schedules
    /// (MC × NC) GEMM tiles and elementwise chunks across this many
    /// pool workers via the work-stealing scheduler.  Results are
    /// bit-identical for every value — see [`crate::matrix::gemm`].
    threads_per_rank: usize,
    /// Active GEMM blocking profile (kc/mc/nc/microkernel/elementwise
    /// threshold) — default constants unless the runtime loaded a
    /// [`TuneProfile`] or the builder pinned one.  `Compute::Native`
    /// threads this into every kernel call.
    block: BlockParams,
    /// How the consolidated algorithm entry points schedule themselves
    /// (see [`crate::plan`]): price-and-pick by default, overridable per
    /// runtime or per machine config.
    plan_mode: PlanMode,
}

impl Ctx {
    #[allow(clippy::too_many_arguments)]
    fn new(
        rank: usize,
        transport: Arc<dyn Transport>,
        backend: Arc<dyn Backend>,
        machine: CostParams,
        threads_per_rank: usize,
        topo: Arc<Topology>,
        block: BlockParams,
        link_override: Option<HierCost>,
        plan_mode: PlanMode,
    ) -> Self {
        let cost = backend.cost(machine);
        let collectives = backend.collectives();
        debug_assert_eq!(topo.world(), transport.world(), "topology/world mismatch");
        // Flat world: one link level, both priced at `cost` — clocks are
        // bit-identical to the pre-hierarchy model.  Hierarchical world:
        // same-node hops run at shared-memory parameters under the
        // machine's network parameters between nodes — unless a measured
        // link calibration (from `repro tune`) overrides both levels with
        // this host's actual ping-pong latency/bandwidth.
        let link = match link_override {
            Some(l) if !topo.is_flat() => l,
            _ if topo.is_flat() => HierCost::flat(cost),
            _ => HierCost::hierarchical(cost),
        };
        let metrics = RankMetrics::new();
        metrics.set_profile(ProfileTag::of(&block));
        Ctx {
            rank,
            world: transport.world(),
            transport,
            clock: Cell::new(0.0),
            cost,
            topo,
            link,
            backend,
            collectives,
            metrics,
            tag_alloc: RefCell::new(HashMap::new()),
            tag_scope: Cell::new(0),
            scoped_tag_alloc: RefCell::new(HashMap::new()),
            overlap_depth: Cell::new(0),
            threads_per_rank: threads_per_rank.max(1),
            block,
            plan_mode,
        }
    }

    /// Cores this rank's block kernels may use (≥ 1); set through
    /// [`RuntimeBuilder::threads_per_rank`] or the machine config.
    #[inline]
    pub fn threads_per_rank(&self) -> usize {
        self.threads_per_rank
    }

    /// The GEMM blocking profile active for this rank's kernels; set
    /// through [`RuntimeBuilder::block_params`], a loaded
    /// [`TuneProfile`], or the defaults.
    #[inline]
    pub fn block_params(&self) -> &BlockParams {
        &self.block
    }

    /// Cost of one point-to-point message to/from `peer`, priced on the
    /// link the topology selects (intra-node vs inter-node).  On a flat
    /// topology both links equal `self.cost`, so this is exactly the
    /// pre-hierarchy `cost.msg(bytes)`.
    #[inline]
    fn msg_cost(&self, peer: usize, bytes: usize) -> f64 {
        self.link.msg(self.topo.same_node(self.rank, peer), bytes)
    }

    /// Trace category for traffic with `peer`: flat worlds keep the
    /// single `Comm` category; hierarchical worlds split legs into
    /// `CommIntra`/`CommInter` so the critical-path report attributes
    /// time per level.
    #[inline]
    fn comm_cat(&self, peer: usize) -> trace::Category {
        if self.topo.is_flat() {
            trace::Category::Comm
        } else if self.topo.same_node(self.rank, peer) {
            trace::Category::CommIntra
        } else {
            trace::Category::CommInter
        }
    }

    /// The active communication backend.
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Name of the active backend (registry key).
    pub fn backend_name(&self) -> &str {
        self.backend.name()
    }

    /// The active backend's collective strategy object — what
    /// [`Group`](crate::comm::group::Group) methods dispatch through.
    pub fn collectives(&self) -> &dyn Collectives {
        self.collectives.as_ref()
    }

    /// Current virtual time of this rank (seconds).
    #[inline]
    pub fn now(&self) -> f64 {
        self.clock.get()
    }

    /// Advance the virtual clock by modeled *compute* time.
    #[inline]
    pub fn advance_compute(&self, secs: f64, flops: f64) {
        debug_assert!(secs >= 0.0);
        self.clock.set(self.clock.get() + secs);
        self.metrics.on_compute(flops, secs);
    }

    /// Run `f`, measure its wall time, and charge it as compute.
    /// Used in *real* mode where the block kernels actually execute.
    pub fn timed_compute<R>(&self, flops: f64, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        self.advance_compute(t0.elapsed().as_secs_f64(), flops);
        r
    }

    /// Like [`Ctx::timed_compute`], but additionally attributes the work
    /// to the **elementwise** metric sub-counters (`ew_flops`/`ew_time`)
    /// — the bandwidth-bound kernels (add, fw_update, min) report their
    /// own GFlop/s next to the GEMM rate in `repro peak` and the run
    /// summaries.  Totals are unchanged: elementwise is a refinement of
    /// compute, not a sibling timeline.
    pub fn timed_elementwise<R>(&self, flops: f64, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let r = f();
        let secs = t0.elapsed().as_secs_f64();
        self.advance_compute(secs, flops);
        self.metrics.on_elementwise(flops, secs);
        r
    }

    /// Blocking send of `value` to `dst` under `tag`.
    ///
    /// Cost model (§2, "telephone" semantics): both endpoints are occupied
    /// for the full transfer `ts + tw·bytes`.  The sender stamps the
    /// envelope with its clock at send initiation (*ready* time) and then
    /// advances by the cost; the receiver pays the cost again on its own
    /// clock starting at `max(own, ready)`.  Sender-side occupancy makes a
    /// linear broadcast cost Θ(p) at the root; receiver-side occupancy
    /// makes a linear reduction cost Θ(p) at the root — both emergent.
    pub fn send<T: WireData>(&self, dst: usize, tag: u64, value: T) {
        self.send_msg(dst, tag, Msg::new(value));
    }

    /// Erased variant of [`Ctx::send`]: every payload crossing the
    /// transport is a [`Msg`], so generic and collective traffic share
    /// one cost and metrics path.
    pub fn send_msg(&self, dst: usize, tag: u64, msg: Msg) {
        debug_assert!(dst < self.world, "send to rank {dst} outside world");
        debug_assert_ne!(dst, self.rank, "self-send is a framework bug");
        debug_assert_ne!(
            tag, CLOCK_GATHER_TAG,
            "tag u64::MAX is reserved for the runtime's end-of-run clock gather"
        );
        debug_assert_ne!(
            tag, TRACE_GATHER_TAG,
            "tag u64::MAX-3 is reserved for the runtime's end-of-run trace gather"
        );
        let bytes = msg.bytes();
        let mut sp = trace::span("send", self.comm_cat(dst));
        if sp.is_active() {
            sp.arg("peer", dst as f64);
            sp.arg("bytes", bytes as f64);
            sp.flow_out(trace::flow_point(self.rank, dst, tag));
        }
        let ready = self.clock.get();
        let secs = self.msg_cost(dst, bytes);
        self.clock.set(ready + secs);
        self.metrics.on_send(bytes, secs);
        self.transport.post(
            dst,
            Envelope { src: self.rank, tag, bytes, ready, payload: msg },
        );
    }

    /// Blocking receive from `src` under `tag`.
    ///
    /// The transfer starts at `max(own_clock, sender_ready)` and occupies
    /// the receiver for `ts + tw·bytes`.
    pub fn recv<T: WireData>(&self, src: usize, tag: u64) -> T {
        self.recv_msg(src, tag).try_downcast::<T>().unwrap_or_else(|_| {
            panic!(
                "rank {}: recv(src={src}, tag={tag:#x}) payload type mismatch (expected {})",
                self.rank,
                std::any::type_name::<T>()
            )
        })
    }

    /// Erased variant of [`Ctx::recv`].
    pub fn recv_msg(&self, src: usize, tag: u64) -> Msg {
        let mut sp = trace::span("recv", self.comm_cat(src));
        let env = self.transport.take(self.rank, src, tag);
        if sp.is_active() {
            sp.arg("peer", src as f64);
            sp.arg("bytes", env.bytes as f64);
            sp.flow_in(trace::flow_point(src, self.rank, tag));
        }
        let before = self.clock.get();
        let after = before.max(env.ready) + self.msg_cost(src, env.bytes);
        self.clock.set(after);
        self.metrics.on_recv(env.bytes, after - before);
        env.payload
    }

    /// Combined send + receive as one **full-duplex round** (single-port
    /// duplex model): the rank sends to `dst` and receives from `src`
    /// simultaneously, paying `max(send_cost, recv_cost)` once, starting
    /// at `max(own_clock, sender_ready)`.  This is the primitive behind
    /// ring/pairwise collectives — a ring all-gather round costs
    /// `ts + tw·m`, not `2(ts + tw·m)`, matching §2's model where a
    /// circular shift is `t_s + t_w·m`.
    pub fn send_recv<T: WireData, U: WireData>(
        &self,
        dst: usize,
        src: usize,
        tag: u64,
        value: T,
    ) -> U {
        self.send_recv_msg(dst, src, tag, Msg::new(value))
            .try_downcast::<U>()
            .unwrap_or_else(|_| {
                panic!(
                    "rank {}: send_recv(src={src}, tag={tag:#x}) payload type mismatch (expected {})",
                    self.rank,
                    std::any::type_name::<U>()
                )
            })
    }

    /// Erased variant of [`Ctx::send_recv`].
    pub fn send_recv_msg(&self, dst: usize, src: usize, tag: u64, msg: Msg) -> Msg {
        debug_assert_ne!(
            tag, CLOCK_GATHER_TAG,
            "tag u64::MAX is reserved for the runtime's end-of-run clock gather"
        );
        debug_assert_ne!(
            tag, TRACE_GATHER_TAG,
            "tag u64::MAX-3 is reserved for the runtime's end-of-run trace gather"
        );
        let bytes_out = msg.bytes();
        // A duplex round touching two peers is "inter" if either leg
        // crosses a node boundary (the slower link dominates the round).
        let cat = if self.topo.is_flat() {
            trace::Category::Comm
        } else if self.topo.same_node(self.rank, dst) && self.topo.same_node(self.rank, src) {
            trace::Category::CommIntra
        } else {
            trace::Category::CommInter
        };
        let mut sp = trace::span("sendrecv", cat);
        if sp.is_active() {
            sp.arg("dst", dst as f64);
            sp.arg("src", src as f64);
            sp.arg("bytes_out", bytes_out as f64);
            sp.flow_out(trace::flow_point(self.rank, dst, tag));
        }
        let ready = self.clock.get();
        self.transport.post(
            dst,
            Envelope { src: self.rank, tag, bytes: bytes_out, ready, payload: msg },
        );
        let env = self.transport.take(self.rank, src, tag);
        if sp.is_active() {
            sp.arg("bytes_in", env.bytes as f64);
            sp.flow_in(trace::flow_point(src, self.rank, tag));
        }
        let start = ready.max(env.ready);
        let cost = self.msg_cost(dst, bytes_out).max(self.msg_cost(src, env.bytes));
        let after = start + cost;
        self.clock.set(after);
        self.metrics.on_send(bytes_out, 0.0);
        self.metrics.on_recv(env.bytes, after - ready);
        env.payload
    }

    // ------------------------------------------- non-blocking primitives
    //
    // The split-phase machinery behind `comm::nb`: a non-blocking group
    // operation *forks* the virtual clock at `*_start` (the fork is the
    // operation's private comm timeline), runs its deferred message
    // rounds on the fork inside `wait()`, and finally *merges* by taking
    // the max of the main clock and the fork — which is exactly the
    // overlap-aware cost rule: across an overlap region a rank's clock
    // advances by `max(T_comm, T_comp)` instead of their sum.

    /// Run `f` with the clock forked to `at`; every send/receive/compute
    /// inside charges the fork.  Returns `f`'s result and the fork's
    /// final value; the main clock is restored untouched.  Panics on
    /// nesting (a deferred phase must not `wait()` another handle).
    ///
    /// Unwind-safe: if `f` panics (a mailbox-poison failure surfacing
    /// through a handle's `wait()` is an expected event), a drop guard
    /// restores the main clock — folding in the fork's progress so a
    /// caught panic leaves `now()` consistent — and clears the nesting
    /// flag, instead of leaving the rank stuck on the fork.
    pub(crate) fn with_clock<R>(&self, at: f64, f: impl FnOnce() -> R) -> (R, f64) {
        assert_eq!(
            self.overlap_depth.get(),
            0,
            "rank {}: nested overlap region — a pending operation's wait() must not \
             run inside another pending operation's deferred phase",
            self.rank
        );
        struct Unfork<'c> {
            ctx: &'c Ctx,
            saved: f64,
        }
        impl Drop for Unfork<'_> {
            fn drop(&mut self) {
                let fork_end = self.ctx.clock.replace(self.saved);
                if std::thread::panicking() && fork_end > self.saved {
                    self.ctx.clock.set(fork_end);
                }
                self.ctx.overlap_depth.set(0);
            }
        }
        self.overlap_depth.set(1);
        let saved = self.clock.replace(at);
        let guard = Unfork { ctx: self, saved };
        let r = f();
        let end = self.clock.get();
        drop(guard);
        (r, end)
    }

    /// Merge a completed comm timeline back into the main clock:
    /// `clock = max(clock, comm_end)`.  The time both timelines spent
    /// advancing concurrently is recorded as overlap-hidden comm time.
    pub(crate) fn finish_overlap(&self, t0: f64, comm_end: f64) {
        let main = self.clock.get();
        let hidden = (main - t0).min(comm_end - t0).max(0.0);
        if hidden > 0.0 {
            self.metrics.on_overlap(hidden);
        }
        if comm_end > main {
            self.clock.set(comm_end);
        }
    }

    /// Post half of a split duplex exchange: deliver `msg` to `dst`
    /// stamped ready at the current clock, advancing **no** clock — the
    /// transfer is paid once, by [`Ctx::recv_duplex`] at completion
    /// (single-port duplex, like [`Ctx::send_recv_msg`] split in two).
    pub(crate) fn post_only(&self, dst: usize, tag: u64, msg: Msg) {
        debug_assert!(dst < self.world, "send to rank {dst} outside world");
        debug_assert_ne!(dst, self.rank, "self-send is a framework bug");
        debug_assert_ne!(
            tag, CLOCK_GATHER_TAG,
            "tag u64::MAX is reserved for the runtime's end-of-run clock gather"
        );
        debug_assert_ne!(
            tag, TRACE_GATHER_TAG,
            "tag u64::MAX-3 is reserved for the runtime's end-of-run trace gather"
        );
        let bytes = msg.bytes();
        let mut sp = trace::span("post", self.comm_cat(dst));
        if sp.is_active() {
            sp.arg("peer", dst as f64);
            sp.arg("bytes", bytes as f64);
            sp.flow_out(trace::flow_point(self.rank, dst, tag));
        }
        self.metrics.on_send(bytes, 0.0);
        self.transport.post(
            dst,
            Envelope { src: self.rank, tag, bytes, ready: self.clock.get(), payload: msg },
        );
    }

    /// Completing receive of a split duplex exchange started with
    /// [`Ctx::post_only`]: the round costs `max(send, recv)` once,
    /// starting at `max(own_clock, sender_ready)` — identical to the
    /// blocking [`Ctx::send_recv_msg`] when no compute was interleaved.
    /// `sent_to` is the rank the post half targeted, so the send leg is
    /// priced on the link it actually crossed.
    pub(crate) fn recv_duplex(
        &self,
        src: usize,
        tag: u64,
        sent_bytes: usize,
        sent_to: usize,
    ) -> Msg {
        let mut sp = trace::span("recv", self.comm_cat(src));
        let env = self.transport.take(self.rank, src, tag);
        if sp.is_active() {
            sp.arg("peer", src as f64);
            sp.arg("bytes", env.bytes as f64);
            sp.flow_in(trace::flow_point(src, self.rank, tag));
        }
        let before = self.clock.get();
        let start = before.max(env.ready);
        let cost = self.msg_cost(sent_to, sent_bytes).max(self.msg_cost(src, env.bytes));
        let after = start + cost;
        self.clock.set(after);
        self.metrics.on_recv(env.bytes, after - before);
        env.payload
    }

    /// Allocate the tag namespace for a new group over `ranks`.
    /// Deterministic per rank and consistent across members as long as the
    /// SPMD program creates groups in the same order on every member.
    pub fn alloc_group_id(&self, ranks: &[usize]) -> u64 {
        let mut sig: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a over member list
        for &r in ranks {
            sig ^= r as u64;
            sig = sig.wrapping_mul(0x1000_0000_01b3);
        }
        let scope = self.tag_scope.get();
        let mut alloc = if scope != 0 {
            self.scoped_tag_alloc.borrow_mut()
        } else {
            self.tag_alloc.borrow_mut()
        };
        let inst = alloc.entry(sig).or_insert(0);
        let id = sig
            .rotate_left(17)
            .wrapping_add(*inst)
            .wrapping_mul(0x9E37_79B9_7F4A_7C15);
        *inst += 1;
        if scope != 0 {
            crate::comm::group::Group::derive_id(id, scope)
        } else {
            id
        }
    }

    /// Run `f` with group-id allocation keyed to `seed` instead of this
    /// rank's lifetime counters.
    ///
    /// A long-lived rank's `tag_alloc` counters reflect *every* group it
    /// ever created, so two ranks that ran different histories (serving:
    /// different prior jobs, or a job that failed partway) would hand
    /// out different ids for the "same" SPMD group — and collectives
    /// would deadlock or cross-match.  Inside a scope the counters start
    /// from zero and every id folds in `seed`, so members of one job
    /// agree by construction (same seed, same job-local creation order)
    /// and distinct jobs get collision-spaced namespaces (splitmix64
    /// avalanche).  Scopes must not nest, and `seed` must be non-zero
    /// (0 means "unscoped").  Unwind-safe: a panic inside `f` restores
    /// the unscoped state.
    pub fn with_tag_scope<R>(&self, seed: u64, f: impl FnOnce() -> R) -> R {
        assert_ne!(seed, 0, "tag scope seed 0 is reserved for 'unscoped'");
        assert_eq!(self.tag_scope.get(), 0, "tag scopes must not nest");
        self.scoped_tag_alloc.borrow_mut().clear();
        self.tag_scope.set(seed);
        struct Reset<'a>(&'a Ctx);
        impl Drop for Reset<'_> {
            fn drop(&mut self) {
                self.0.tag_scope.set(0);
                self.0.scoped_tag_alloc.borrow_mut().clear();
            }
        }
        let guard = Reset(self);
        let out = f();
        drop(guard);
        out
    }

    /// The transport carrying this rank's messages (shared memory or
    /// TCP; see [`crate::comm::transport`]).
    pub fn transport(&self) -> &Arc<dyn Transport> {
        &self.transport
    }

    /// The node topology this rank runs under.  Flat (one node spanning
    /// the world) on every transport unless the runtime was built with
    /// `ranks_per_node`; hierarchical collectives and per-level link
    /// pricing key off it.
    pub fn topology(&self) -> &Arc<Topology> {
        &self.topo
    }

    /// Per-level link cost parameters.  On a flat topology both levels
    /// equal [`Ctx::cost`]; on a hierarchical one, same-node messages
    /// run at shared-memory parameters and cross-node messages at the
    /// machine's network parameters.
    pub fn link_cost(&self) -> HierCost {
        self.link
    }

    /// The runtime's scheduling policy for the consolidated algorithm
    /// entry points ([`crate::plan::matmul`] / [`crate::plan::apsp`]):
    /// [`PlanMode::Auto`] unless the builder or machine config said
    /// otherwise.  A spec-level `.mode(..)` wins over this.
    pub fn plan_mode(&self) -> PlanMode {
        self.plan_mode
    }
}

/// Outcome of one SPMD run.
///
/// In-process transports fill every vector with one entry per rank.  In
/// a multi-process run (`transport("tcp")`) each OS process only holds
/// its own rank's state, so `results` and `metrics` have exactly one
/// entry (the local rank's); `clocks` and `t_parallel` are global on
/// rank 0 — the launcher gathers final clocks — and local elsewhere.
/// Cross-rank data products should be gathered *inside* the closure with
/// group collectives (see `examples/matmul_dns_tcp.rs`).
pub struct RunResult<R> {
    /// Per-rank return values, indexed by rank (multi-process: the local
    /// rank's value only).
    pub results: Vec<R>,
    /// Parallel virtual runtime `T_P = max_r clock_r` (seconds).
    pub t_parallel: f64,
    /// Per-rank final virtual clocks.
    pub clocks: Vec<f64>,
    /// Real wall time of the whole run.
    pub wall: Duration,
    /// Per-rank metric snapshots.
    pub metrics: Vec<MetricsSnapshot>,
    /// Gathered spans when the runtime was built with tracing on
    /// (`None` otherwise; multi-process: populated on rank 0 only).
    pub trace: Option<trace::TraceData>,
}

// ------------------------------------------------------------- Runtime

/// A configured SPMD runtime: world size + backend + machine costs.
///
/// Build one with [`Runtime::builder`], then [`Runtime::run`] any number
/// of SPMD closures on it (sweeps reuse one runtime per configuration).
pub struct Runtime {
    world: usize,
    backend: Arc<dyn Backend>,
    machine: CostParams,
    transport: TransportChoice,
    threads_per_rank: usize,
    /// Node shape: `Some(n)` packs ranks onto nodes of `n` (last node
    /// takes the remainder), `None` is flat.  Honored on every
    /// transport — the hierarchical collectives and per-level pricing
    /// follow the topology, not the substrate — and required by
    /// `"hybrid"`, whose routing needs node boundaries.
    ranks_per_node: Option<usize>,
    trace: TraceMode,
    /// Active GEMM blocking profile every rank's kernels run with —
    /// defaults unless a [`TuneProfile`] was loaded or the builder
    /// pinned explicit [`BlockParams`].
    block: BlockParams,
    /// Measured per-level link pricing from a tune profile's ping-pong
    /// calibration; applied on hierarchical topologies only (flat worlds
    /// keep the single machine link so existing clocks are unchanged).
    link_cal: Option<HierCost>,
    /// Where the active profile came from, for reports ("path" or
    /// "(inline)"); `None` when running on defaults.
    profile_label: Option<String>,
    /// Scheduling policy handed to every rank's `Ctx` (see
    /// [`Ctx::plan_mode`]).
    plan_mode: PlanMode,
}

/// How span tracing is configured for a runtime (see [`crate::trace`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum TraceMode {
    /// No tracing (the default).  Every instrumented call site costs a
    /// single relaxed atomic load.
    #[default]
    Off,
    /// Collect spans and attach the raw [`trace::TraceData`] to the
    /// [`RunResult`] (tests and tooling).
    Collect,
    /// Collect, write Chrome-trace JSON to the path at teardown, and
    /// print the critical-path report.
    File(std::path::PathBuf),
}

/// Reserved tag for the launcher's end-of-run clock gather in
/// multi-process mode.  `Ctx::send_msg`/`send_recv_msg` debug-assert
/// that user traffic never uses it (group tags are hash-derived, so the
/// collision odds are ~2⁻⁶⁴ per operation — but reserved means checked,
/// not hoped).
const CLOCK_GATHER_TAG: u64 = u64::MAX;

/// Reserved tag for the end-of-run trace gather in multi-process mode —
/// next to the clock-gather tag, past the serving plane's control tags
/// (`u64::MAX - 1`, `u64::MAX - 2`).  Carries each worker rank's
/// [`trace::TraceData`] to rank 0 with zero modeled bytes, after the
/// rank's own spans were flushed, so gathering never perturbs either the
/// virtual clocks or the trace itself.
const TRACE_GATHER_TAG: u64 = u64::MAX - 3;

impl Runtime {
    /// Start configuring a runtime.  Defaults: `world(1)`, backend
    /// `"openmpi-fixed"`, machine `CostParams::default()` (QDR
    /// InfiniBand), transport `"local"` (threads over shared memory).
    pub fn builder() -> RuntimeBuilder {
        RuntimeBuilder {
            world: 1,
            backend: BackendChoice::Object(Arc::new(BackendProfile::openmpi_fixed())),
            machine: MachineChoice::Cost(CostParams::default()),
            transport: None,
            threads_per_rank: None,
            ranks_per_node: None,
            trace: TraceMode::Off,
            tune: None,
            block: None,
            machine_tune_path: None,
            plan_mode: None,
        }
    }

    /// The GEMM blocking profile every rank of this runtime runs with.
    pub fn block_params(&self) -> &BlockParams {
        &self.block
    }

    /// Provenance of the active tune profile (file path or "(inline)"),
    /// `None` when the runtime runs on the built-in defaults.
    pub fn profile_label(&self) -> Option<&str> {
        self.profile_label.as_deref()
    }

    /// How tracing is configured for this runtime.
    pub fn trace_mode(&self) -> &TraceMode {
        &self.trace
    }

    /// Number of ranks this runtime launches.
    pub fn world(&self) -> usize {
        self.world
    }

    /// True when this runtime spawns one OS process per rank (the
    /// `"tcp"` transport).  The serving runtime refuses multi-process
    /// worlds: its job queue and driver live in one address space, and
    /// external submitters reach a resident pool over the TCP client
    /// API ([`crate::serve::ServeClient`]) instead.
    pub fn is_multiprocess(&self) -> bool {
        self.transport == TransportChoice::Tcp
    }

    /// The configured backend.
    pub fn backend(&self) -> &Arc<dyn Backend> {
        &self.backend
    }

    /// The machine's base cost parameters (before backend shaping).
    pub fn machine_cost(&self) -> CostParams {
        self.machine
    }

    /// Cores each rank's block kernels may use (≥ 1).
    pub fn threads_per_rank(&self) -> usize {
        self.threads_per_rank
    }

    /// Name of the configured transport.
    pub fn transport_name(&self) -> &'static str {
        match self.transport {
            TransportChoice::InProcess => "local",
            TransportChoice::TcpLoopback => "tcp-loopback",
            TransportChoice::Tcp => "tcp",
            TransportChoice::Hybrid => "hybrid",
        }
    }

    /// The node topology every rank of this runtime will see: flat
    /// unless built with `ranks_per_node` (builder knob, machine-config
    /// key, or `FOOPAR_RANKS_PER_NODE`).
    pub fn topology(&self) -> Topology {
        match self.ranks_per_node {
            Some(n) => Topology::uniform(self.world, n),
            None => Topology::flat(self.world),
        }
    }

    /// Launch `world` ranks running `f` in SPMD over a fresh transport.
    ///
    /// `f` runs once per rank; the returned [`RunResult`] orders
    /// everything by rank (see its docs for multi-process semantics).
    /// Rank panics propagate (with rank id) after all ranks finished or
    /// died — the deadlock timeout in
    /// [`Mailbox::take`](crate::comm::transport::Mailbox::take)
    /// guarantees progress.
    ///
    /// In-process ranks execute on the process-wide [`pool`] of reusable
    /// worker threads: spawning 512 OS threads per run used to dominate
    /// the end-to-end driver wall time (§Perf in EXPERIMENTS.md).  With
    /// `transport("tcp")` each rank is an OS process instead (rank 0 is
    /// the calling process; the rest are re-exec'd workers, see
    /// [`launch`]).
    pub fn run<R, F>(&self, f: F) -> RunResult<R>
    where
        R: Send,
        F: Fn(&Ctx) -> R + Sync,
    {
        let world = self.world;
        assert!(world > 0);
        let res = match self.transport {
            TransportChoice::InProcess => self.run_threads(Fabric::new(world), f),
            TransportChoice::TcpLoopback => self.run_threads(
                TcpTransport::loopback(world).expect("bind tcp-loopback listeners"),
                f,
            ),
            TransportChoice::Tcp => self.run_processes(f),
            TransportChoice::Hybrid => self.run_threads(
                HierTransport::new(self.topology())
                    .expect("bind hybrid inter-node listeners"),
                f,
            ),
        };
        // File mode: emit the artifacts at teardown (multi-process: the
        // trace is only on rank 0, so workers skip this naturally).
        if let TraceMode::File(path) = &self.trace {
            if let Some(td) = &res.trace {
                match std::fs::write(path, td.chrome_json()) {
                    Ok(()) => eprintln!(
                        "trace: wrote {} spans to {} (load at https://ui.perfetto.dev)",
                        td.spans.len(),
                        path.display()
                    ),
                    Err(e) => eprintln!("trace: failed to write {}: {e}", path.display()),
                }
                print!("{}", td.critical_path_report(&res.clocks));
            }
        }
        res
    }

    /// Thread-per-rank launch over any transport whose ranks are all
    /// local to this process.
    fn run_threads<R, F>(&self, transport: Arc<dyn Transport>, f: F) -> RunResult<R>
    where
        R: Send,
        F: Fn(&Ctx) -> R + Sync,
    {
        let world = self.world;
        let wall0 = Instant::now();
        let topo = Arc::new(self.topology());
        // One trace session per process; serialized against concurrent
        // traced runs (tests) by the session lock inside begin_session.
        let session = (self.trace != TraceMode::Off).then(trace::begin_session);
        let slots: Vec<Mutex<Option<(R, f64, MetricsSnapshot)>>> =
            (0..world).map(|_| Mutex::new(None)).collect();

        pool::scoped_run(world, &|rank| {
            // Activate span recording for this rank body (declared before
            // the rank span so it drops after it, flushing everything).
            let _trace_scope = session.as_ref().map(|_| trace::rank_scope(rank));
            let mut rank_span = trace::span("rank", trace::Category::Rank);
            let ctx = Ctx::new(
                rank,
                transport.clone(),
                self.backend.clone(),
                self.machine,
                self.threads_per_rank,
                topo.clone(),
                self.block,
                self.link_cal,
                self.plan_mode,
            );
            rank_span.arg("kc", ctx.block.kc as f64);
            rank_span.arg("mc", ctx.block.mc as f64);
            rank_span.arg("nc", ctx.block.nc as f64);
            let r = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&ctx))) {
                Ok(r) => r,
                Err(e) => {
                    // A dying rank strands every peer blocked on a message
                    // it will never send.  Poison the transport so blocked
                    // receives fail promptly with the root cause (and the
                    // stranded rank/src/tag) instead of burning the 60 s
                    // deadlock timeout.
                    let msg = e
                        .downcast_ref::<String>()
                        .map(|s| s.as_str())
                        .or_else(|| e.downcast_ref::<&str>().copied())
                        .unwrap_or("<non-string panic>");
                    transport.fail(&format!("rank {rank} died mid-run: {msg}"));
                    std::panic::resume_unwind(e);
                }
            };
            rank_span.arg("v_end", ctx.now());
            drop(rank_span);
            transport.close(rank);
            *slots[rank].lock().unwrap() = Some((r, ctx.now(), ctx.metrics.snapshot()));
        });

        // All rank scopes have flushed (scoped_run is a barrier): take
        // the session's spans.  In-process ranks share the collector, so
        // the gather costs zero transport messages.
        let trace_data = session.map(trace::Session::finish);
        let wall = wall0.elapsed();
        let mut results = Vec::with_capacity(world);
        let mut clocks = Vec::with_capacity(world);
        let mut metrics = Vec::with_capacity(world);
        for s in slots {
            let (r, c, m) = s
                .into_inner()
                .unwrap()
                .expect("rank finished without result");
            results.push(r);
            clocks.push(c);
            metrics.push(m);
        }
        let t_parallel = clocks.iter().cloned().fold(0.0, f64::max);
        RunResult { results, t_parallel, clocks, wall, metrics, trace: trace_data }
    }

    /// Process-per-rank launch: this process runs one rank (0 in the
    /// parent, `FOOPAR_TCP_RANK` in a spawned worker); the rest of the
    /// world lives in sibling processes reached over TCP loopback.
    fn run_processes<R, F>(&self, f: F) -> RunResult<R>
    where
        R: Send,
        F: Fn(&Ctx) -> R + Sync,
    {
        let world = self.world;
        if world == 1 {
            return self.run_threads(Fabric::new(1), f);
        }
        let proc = launch::establish(world).expect("establish tcp multi-process world");
        let me = proc.rank();
        let transport: Arc<dyn Transport> = proc.transport();
        let wall0 = Instant::now();
        // Parent only: poll worker liveness in the background and poison
        // the local transport when one dies, so a collective blocked on
        // the dead rank fails promptly with its exit status instead of
        // hanging until the deadlock oracle fires.
        let watchdog_stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let watchdog = proc.spawn_watchdog(watchdog_stop.clone());
        let ctx = Ctx::new(
            me,
            transport.clone(),
            self.backend.clone(),
            self.machine,
            self.threads_per_rank,
            Arc::new(self.topology()),
            self.block,
            self.link_cal,
            self.plan_mode,
        );
        // Each process runs its own trace session for its one rank; the
        // spans are gathered to rank 0 below.  The re-exec'd workers
        // resolve the same TraceMode as the parent (same builder code
        // path, inherited FOOPAR_TRACE), so gather participation agrees.
        let session = (self.trace != TraceMode::Off).then(trace::begin_session);
        let r = {
            let _trace_scope = session.as_ref().map(|_| trace::rank_scope(me));
            let mut rank_span = trace::span("rank", trace::Category::Rank);
            rank_span.arg("kc", ctx.block.kc as f64);
            rank_span.arg("mc", ctx.block.mc as f64);
            rank_span.arg("nc", ctx.block.nc as f64);
            let r = f(&ctx);
            rank_span.arg("v_end", ctx.now());
            r
        };

        // End-of-run clock gather so rank 0 reports the true T_P =
        // max_r clock_r.  Zero modeled bytes: launcher bookkeeping must
        // not perturb the virtual-time results.
        let (clocks, t_parallel) = if me == 0 {
            let mut all = vec![0.0f64; world];
            all[0] = ctx.now();
            for src in 1..world {
                // Poll-with-liveness instead of a bare blocking take: a
                // worker that died mid-run can never post its clock, and
                // failing fast with its exit status beats a 60 s
                // "deadlock?" timeout.  Falls through to the blocking
                // take (and its deadlock oracle) once the envelope — or
                // nothing at all — is in flight.
                let timeout = crate::comm::transport::RECV_TIMEOUT;
                let deadline = Instant::now() + timeout;
                // Clean-exit grace: a worker that already exited 0 may
                // still have its clock frame in flight for a moment —
                // but not for seconds.  Past the grace window, a clean
                // exit with no clock means the worker's closure left
                // the process early (exit(0) mid-run), which no failure
                // watchdog can flag; name it instead of the bare
                // 60 s "hung?" timeout.
                let grace = Instant::now() + Duration::from_secs(5);
                while !transport.probe(0, src, CLOCK_GATHER_TAG) {
                    proc.check_children().expect("tcp worker process died mid-run");
                    assert!(
                        !(Instant::now() > grace && proc.child_exited_ok(src)),
                        "rank 0: worker rank {src} exited successfully without posting \
                         its end-of-run clock — did its SPMD closure exit the process \
                         early?"
                    );
                    assert!(
                        Instant::now() <= deadline,
                        "rank 0: clock gather from rank {src} timed out after {timeout:?} \
                         — worker process alive but hung?"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
                let env = transport.take(0, src, CLOCK_GATHER_TAG);
                all[src] = env.payload.downcast::<f64>();
            }
            let t = all.iter().cloned().fold(0.0, f64::max);
            (all, t)
        } else {
            transport.post(
                0,
                Envelope {
                    src: me,
                    tag: CLOCK_GATHER_TAG,
                    bytes: 0,
                    ready: ctx.now(),
                    payload: Msg::new(ctx.now()),
                },
            );
            (vec![ctx.now()], ctx.now())
        };
        // Trace gather on the reserved tag next to the clock gather.
        // The clock gather above already proved every worker alive, so a
        // plain blocking take (with its deadlock oracle) suffices here.
        // Zero modeled bytes, and each rank's spans were flushed before
        // its post — gathering perturbs neither clocks nor trace.
        let trace_data = session.map(trace::Session::finish).and_then(|local| {
            if me == 0 {
                let mut all = local;
                for src in 1..world {
                    let env = transport.take(0, src, TRACE_GATHER_TAG);
                    all.merge(env.payload.downcast::<trace::TraceData>());
                }
                Some(all)
            } else {
                transport.post(
                    0,
                    Envelope {
                        src: me,
                        tag: TRACE_GATHER_TAG,
                        bytes: 0,
                        ready: ctx.now(),
                        payload: Msg::new(local),
                    },
                );
                None
            }
        });
        transport.close(me);
        watchdog_stop.store(true, std::sync::atomic::Ordering::Release);
        if let Some(h) = watchdog {
            let _ = h.join();
        }
        let metrics = vec![ctx.metrics.snapshot()];
        let wall = wall0.elapsed();
        proc.finish().expect("tcp worker process failed");
        RunResult { results: vec![r], t_parallel, clocks, wall, metrics, trace: trace_data }
    }
}

enum BackendChoice {
    /// Resolved through the registry at [`RuntimeBuilder::build`] time.
    Named(String),
    Object(Arc<dyn Backend>),
}

enum MachineChoice {
    /// Resolved through [`MachineConfig::resolve`] at build time.
    Named(String),
    Cost(CostParams),
}

/// Which delivery substrate carries envelopes (resolved at build time).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TransportChoice {
    /// Threads over shared-memory mailboxes ([`Fabric`]).
    InProcess,
    /// Threads over real TCP loopback sockets (full wire path, single
    /// process — what the transport-parity tests run on).
    TcpLoopback,
    /// One OS process per rank over TCP loopback ([`launch`]).
    Tcp,
    /// Two-level hybrid: threads whose same-node envelopes cross
    /// shared-memory mailboxes and cross-node envelopes cross real TCP
    /// loopback sockets, routed by the runtime's [`Topology`]
    /// ([`HierTransport`]).  Requires `ranks_per_node`.
    Hybrid,
}

/// Builder for [`Runtime`] — the entry point of every SPMD program.
pub struct RuntimeBuilder {
    world: usize,
    backend: BackendChoice,
    machine: MachineChoice,
    /// Transport name, resolved at [`RuntimeBuilder::build`]
    /// (`None` = default in-process).
    transport: Option<String>,
    /// Explicit per-rank kernel thread count; `None` defers to the
    /// machine config (which defaults to 1).
    threads_per_rank: Option<usize>,
    /// Explicit node shape; `None` defers to the machine config, then
    /// the `FOOPAR_RANKS_PER_NODE` env variable, then flat.
    ranks_per_node: Option<usize>,
    /// Span tracing; `Off` defers to the `FOOPAR_TRACE` env variable at
    /// build time.
    trace: TraceMode,
    /// Explicit tune profile object (wins over any file path).
    tune: Option<TuneProfile>,
    /// Explicit blocking override (tests; wins over any profile).
    block: Option<BlockParams>,
    /// Profile path from a machine config's `tune_profile` key, loaded
    /// at [`RuntimeBuilder::build`] unless an explicit profile was set.
    machine_tune_path: Option<String>,
    /// Explicit scheduling policy; `None` defers to the machine config,
    /// then [`PlanMode::Auto`].
    plan_mode: Option<PlanMode>,
}

impl RuntimeBuilder {
    /// Number of ranks (must be > 0).
    pub fn world(mut self, world: usize) -> Self {
        self.world = world;
        self
    }

    /// Select the communication backend by registry name (built-ins:
    /// `openmpi-fixed`, `openmpi-stock`, `mpj-express`, `fastmpj`,
    /// `shmem` — plus anything registered via
    /// [`registry::register`]).  Resolved at [`RuntimeBuilder::build`].
    pub fn backend(mut self, name: &str) -> Self {
        self.backend = BackendChoice::Named(name.to_string());
        self
    }

    /// Use an explicit backend object (bypasses the registry).
    pub fn backend_obj(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backend = BackendChoice::Object(backend);
        self
    }

    /// Use an explicit built-in profile (bypasses the registry).
    pub fn backend_profile(self, profile: BackendProfile) -> Self {
        self.backend_obj(Arc::new(profile))
    }

    /// Select the machine by name or config-file path (see
    /// [`MachineConfig::resolve`]); its interconnect `t_s`/`t_w` become
    /// the base cost parameters.  Resolved at [`RuntimeBuilder::build`].
    pub fn machine(mut self, spec: &str) -> Self {
        self.machine = MachineChoice::Named(spec.to_string());
        self
    }

    /// Use an explicit machine config's interconnect costs (and its
    /// `threads_per_rank` / `ranks_per_node` / `tune_profile`, unless
    /// set explicitly).
    pub fn machine_config(mut self, machine: &MachineConfig) -> Self {
        if self.threads_per_rank.is_none() {
            self.threads_per_rank = Some(machine.threads_per_rank.max(1));
        }
        if self.ranks_per_node.is_none() {
            self.ranks_per_node = machine.ranks_per_node;
        }
        if self.machine_tune_path.is_none() {
            self.machine_tune_path = machine.tune_profile.clone();
        }
        if self.plan_mode.is_none() {
            self.plan_mode = machine.plan_mode;
        }
        self.cost(machine.cost())
    }

    /// Run every rank's kernels with this tune profile: its block
    /// parameters drive the GEMM/elementwise kernels and, when the
    /// profile carries a link calibration, its measured latency/bandwidth
    /// price the hierarchical cost model (non-flat topologies).  Wins
    /// over a machine config's `tune_profile` key.
    pub fn tune_profile(mut self, profile: &TuneProfile) -> Self {
        self.tune = Some(profile.clone());
        self
    }

    /// Pin raw block parameters directly (tests and sweeps; wins over
    /// any tune profile).  Validated at [`RuntimeBuilder::build`].
    pub fn block_params(mut self, params: BlockParams) -> Self {
        self.block = Some(params);
        self
    }

    /// Cores each rank's block kernels may use (clamped to ≥ 1).  The
    /// paper's configurations run one BLAS thread per core and one rank
    /// per core; raising this instead runs fewer, fatter ranks — results
    /// are **bit-identical** either way (deterministic accumulation
    /// order; see [`crate::matrix::gemm`]), only the schedule changes.
    pub fn threads_per_rank(mut self, threads: usize) -> Self {
        self.threads_per_rank = Some(threads.max(1));
        self
    }

    /// How the consolidated algorithm entry points
    /// ([`crate::plan::matmul`] / [`crate::plan::apsp`]) schedule
    /// themselves: [`PlanMode::Auto`] (the default) dry-runs every
    /// candidate schedule on the cost model and interprets the cheapest;
    /// [`PlanMode::Eager`] bypasses the planner for the hand-written
    /// defaults; [`PlanMode::Forced`] pins one schedule.  Wins over the
    /// machine config's `plan_mode` key; a spec-level `.mode(..)` wins
    /// over both.
    pub fn plan_mode(mut self, mode: PlanMode) -> Self {
        self.plan_mode = Some(mode);
        self
    }

    /// Use raw cost parameters (tests: `CostParams::free()`).
    pub fn cost(mut self, cost: CostParams) -> Self {
        self.machine = MachineChoice::Cost(cost);
        self
    }

    /// Node shape of the world: ranks are packed onto nodes of `n`
    /// consecutive ranks (the last node takes the remainder, so uneven
    /// shapes arise naturally — `world(8).ranks_per_node(3)` is 3+3+2).
    /// Clamped to ≥ 1; `n = 1` puts every rank on its own node.
    ///
    /// Honored on **every** transport: the topology drives the
    /// per-level cost model and the `"hier"` backend's two-level
    /// collective strategies even when delivery is flat, and it is
    /// required by `transport("hybrid")`, which routes same-node
    /// envelopes over shared memory and cross-node envelopes over TCP.
    /// Unset, the machine config's `ranks_per_node` key and then the
    /// `FOOPAR_RANKS_PER_NODE` env variable are consulted; absent all
    /// three, the world is flat.
    pub fn ranks_per_node(mut self, n: usize) -> Self {
        self.ranks_per_node = Some(n.max(1));
        self
    }

    /// Trace every run of this runtime and write Chrome-trace JSON to
    /// `path` at teardown (plus print the critical-path report).  Load
    /// the file at <https://ui.perfetto.dev>.  Equivalent to setting
    /// `FOOPAR_TRACE=<path>` in the environment, or `--trace <path>` on
    /// the `repro` CLI.  See [`crate::trace`] for what gets recorded.
    pub fn trace(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.trace = TraceMode::File(path.into());
        self
    }

    /// Trace every run of this runtime and attach the raw
    /// [`trace::TraceData`] to the [`RunResult`] instead of writing a
    /// file — the programmatic form (tests, tooling).
    pub fn trace_collect(mut self) -> Self {
        self.trace = TraceMode::Collect;
        self
    }

    /// Select the delivery substrate:
    ///
    /// * `"local"` (alias `"shmem"`) — threads over in-process
    ///   shared-memory mailboxes (the default);
    /// * `"tcp-loopback"` — threads, but every envelope crosses a real
    ///   TCP loopback socket through the wire codec (full wire path
    ///   without process orchestration; what the parity tests use);
    /// * `"tcp"` — one OS process per rank over TCP loopback, spawned by
    ///   the re-exec [`launch`]er (payload types must implement
    ///   [`WireData`]; results come back local-only, see [`RunResult`]);
    /// * `"hybrid"` — threads routed two-level by the node topology:
    ///   same-node envelopes over shared-memory mailboxes, cross-node
    ///   envelopes over real TCP loopback sockets (requires
    ///   [`RuntimeBuilder::ranks_per_node`] or an equivalent config/env
    ///   setting; cross-node payloads must implement [`WireData`]).
    ///
    /// Orthogonal to [`RuntimeBuilder::backend`]: the backend decides
    /// *which algorithm* a collective runs, the transport decides *what
    /// carries its messages* — any combination works, with identical
    /// results.
    pub fn transport(mut self, name: &str) -> Self {
        self.transport = Some(name.to_string());
        self
    }

    /// Resolve names against the backend registry / machine configs /
    /// transport table.
    pub fn build(self) -> crate::Result<Runtime> {
        if self.world == 0 {
            return Err(anyhow!("world size must be positive"));
        }
        let backend = match self.backend {
            BackendChoice::Object(b) => b,
            BackendChoice::Named(name) => registry::by_name(&name).ok_or_else(|| {
                anyhow!(
                    "unknown backend '{name}' (registered: {})",
                    registry::names().join(", ")
                )
            })?,
        };
        let (machine, machine_threads, machine_rpn, machine_plan) = match self.machine {
            MachineChoice::Cost(c) => (c, 1, None, None),
            MachineChoice::Named(spec) => {
                let m = MachineConfig::resolve(&spec)?;
                (m.cost(), m.threads_per_rank.max(1), m.ranks_per_node, m.plan_mode)
            }
        };
        let threads_per_rank = self.threads_per_rank.unwrap_or(machine_threads);
        // Scheduling policy precedence: builder knob > machine config >
        // Auto (price-and-pick).
        let plan_mode = self.plan_mode.or(machine_plan).unwrap_or_default();
        // Node shape precedence: builder knob > machine config > launch
        // environment (`FOOPAR_RANKS_PER_NODE`, forwarded to re-exec'd
        // workers so all processes derive the same topology) > flat.
        let ranks_per_node = self.ranks_per_node.or(machine_rpn).or_else(|| {
            std::env::var(hier::ENV_RANKS_PER_NODE)
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n > 0)
        });
        let transport = match self.transport.as_deref() {
            None | Some("local") | Some("shmem") | Some("inprocess") => {
                TransportChoice::InProcess
            }
            Some("tcp-loopback") => TransportChoice::TcpLoopback,
            Some("tcp") => TransportChoice::Tcp,
            Some("hybrid") => TransportChoice::Hybrid,
            Some(other) => {
                return Err(anyhow!(
                    "unknown transport '{other}' (available: local, tcp-loopback, tcp, hybrid)"
                ))
            }
        };
        if transport == TransportChoice::Hybrid && ranks_per_node.is_none() {
            return Err(anyhow!(
                "transport 'hybrid' needs a node shape: set .ranks_per_node(n), the machine \
                 config's ranks_per_node key, or {}",
                hier::ENV_RANKS_PER_NODE
            ));
        }
        let trace = match self.trace {
            // An explicit builder choice wins; `Off` defers to the env so
            // `FOOPAR_TRACE=out.json` works on any unmodified binary.
            TraceMode::Off => match std::env::var("FOOPAR_TRACE") {
                Ok(p) if !p.is_empty() => TraceMode::File(p.into()),
                _ => TraceMode::Off,
            },
            t => t,
        };
        // Blocking precedence: explicit block params > explicit tune
        // profile > machine config's `tune_profile` path > defaults.
        // A broken profile file is an error, not a silent fallback —
        // the user asked for tuned kernels and should get them (or know
        // why not).
        let profile = match self.tune {
            Some(p) => Some(p),
            None => match &self.machine_tune_path {
                Some(path) => Some(TuneProfile::load(std::path::Path::new(path))?),
                None => None,
            },
        };
        let block = self
            .block
            .or_else(|| profile.as_ref().map(|p| p.block))
            .unwrap_or_default();
        block
            .validate()
            .map_err(|e| anyhow!("invalid block parameters: {e}"))?;
        let link_cal = profile.as_ref().and_then(|p| p.link).map(|c| c.hier());
        let profile_label = profile.as_ref().map(TuneProfile::source_label);
        Ok(Runtime {
            world: self.world,
            backend,
            machine,
            transport,
            threads_per_rank,
            ranks_per_node,
            trace,
            block,
            link_cal,
            profile_label,
            plan_mode,
        })
    }

    /// Build and immediately run `f` (the common single-shot path).
    pub fn run<R, F>(self, f: F) -> crate::Result<RunResult<R>>
    where
        R: Send,
        F: Fn(&Ctx) -> R + Sync,
    {
        Ok(self.build()?.run(f))
    }
}

/// A process-wide pool of reusable rank worker threads.
///
/// `Runtime::run` is called hundreds of times per bench sweep (every
/// Fig. 5 / isoefficiency point is a fresh SPMD world); spawning and
/// joining p OS threads each time cost ~35 µs/thread — ~18 ms of the
/// ~23 ms p=512 end-to-end driver.  The pool amortizes that: workers are
/// checked out per run, execute one rank closure, and return to the free
/// list.
///
/// Scoped-execution safety: the submitted closure is lifetime-erased, but
/// [`scoped_run`] does not return until **every** checked-out worker has
/// signalled completion (even on rank panic — workers catch unwinds), so
/// the closure and its borrows strictly outlive all uses.  Rank panics are
/// re-raised on the caller with the rank id after the barrier.
pub mod pool {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::{mpsc, Condvar, Mutex, OnceLock};

    /// One pending rank execution: closure pointer + completion channel.
    struct Job {
        /// Type-erased `&'scope (dyn Fn(usize) + Sync)` with the scope
        /// lifetime transmuted away; valid until `done` is signalled.
        f: *const (dyn Fn(usize) + Sync),
        rank: usize,
        done: *const Barrier,
    }
    // SAFETY: the pointee is Sync (shared closure) and the barrier is
    // Sync; pointers cross threads only under the scoped_run protocol.
    unsafe impl Send for Job {}

    struct Barrier {
        remaining: AtomicUsize,
        mutex: Mutex<Vec<(usize, String)>>, // collected rank panics
        cv: Condvar,
    }

    struct Worker {
        tx: mpsc::Sender<Job>,
    }

    fn spawn_worker(id: usize) -> Worker {
        let (tx, rx) = mpsc::channel::<Job>();
        std::thread::Builder::new()
            .name(format!("foopar-worker-{id}"))
            // 1 MiB is ample — ranks keep blocks on the heap (§Perf).
            .stack_size(1 << 20)
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    // SAFETY: scoped_run keeps the closure + barrier alive
                    // until we signal below.
                    let f = unsafe { &*job.f };
                    let barrier = unsafe { &*job.done };
                    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        f(job.rank)
                    }));
                    if let Err(e) = res {
                        let msg = e
                            .downcast_ref::<String>()
                            .map(|s| s.as_str())
                            .or_else(|| e.downcast_ref::<&str>().copied())
                            .unwrap_or("<non-string panic>")
                            .to_string();
                        barrier.mutex.lock().unwrap().push((job.rank, msg));
                    }
                    if barrier.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                        // last one out: wake the submitter
                        let _g = barrier.mutex.lock().unwrap();
                        barrier.cv.notify_all();
                    }
                }
            })
            .expect("spawn pool worker");
        Worker { tx }
    }

    fn free_list() -> &'static Mutex<Vec<Worker>> {
        static POOL: OnceLock<Mutex<Vec<Worker>>> = OnceLock::new();
        POOL.get_or_init(|| Mutex::new(Vec::new()))
    }

    static NEXT_ID: AtomicUsize = AtomicUsize::new(0);

    /// Run `f(rank)` for every `rank in 0..world`, each on its own worker
    /// thread, returning after all completed.  Re-raises the first rank
    /// panic (by rank order) on the caller.
    pub fn scoped_run(world: usize, f: &(dyn Fn(usize) + Sync)) {
        // check out / grow
        let mut workers = {
            let mut free = free_list().lock().unwrap();
            let take = free.len().min(world);
            let mut ws: Vec<Worker> = free.drain(..take).collect();
            while ws.len() < world {
                ws.push(spawn_worker(NEXT_ID.fetch_add(1, Ordering::Relaxed)));
            }
            ws
        };

        let barrier = Barrier {
            remaining: AtomicUsize::new(world),
            mutex: Mutex::new(Vec::new()),
            cv: Condvar::new(),
        };
        // SAFETY (lifetime erasure): we block on the barrier below before
        // returning, so `f` and `barrier` outlive every worker access.
        let f_erased: *const (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<_, &'static (dyn Fn(usize) + Sync)>(f) };
        for (rank, w) in workers.iter().enumerate().take(world) {
            w.tx
                .send(Job { f: f_erased, rank, done: &barrier })
                .expect("pool worker died");
        }

        // wait for ALL ranks (panicked or not) — this is the soundness
        // barrier for the lifetime erasure above.
        let mut guard = barrier.mutex.lock().unwrap();
        while barrier.remaining.load(Ordering::Acquire) != 0 {
            guard = barrier.cv.wait(guard).unwrap();
        }
        let mut panics = std::mem::take(&mut *guard);
        drop(guard);

        // return workers to the pool before propagating panics
        free_list().lock().unwrap().append(&mut workers);

        if !panics.is_empty() {
            panics.sort_by_key(|(r, _)| *r);
            let (rank, msg) = &panics[0];
            panic!("rank {rank} panicked: {msg}");
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::sync::atomic::AtomicU64;

        #[test]
        fn runs_every_rank_exactly_once() {
            let hits = AtomicU64::new(0);
            scoped_run(16, &|rank| {
                hits.fetch_add(1 << rank, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), (1u64 << 16) - 1);
        }

        #[test]
        fn workers_are_reused() {
            let before = NEXT_ID.load(Ordering::Relaxed);
            scoped_run(4, &|_| {});
            scoped_run(4, &|_| {});
            scoped_run(4, &|_| {});
            let after = NEXT_ID.load(Ordering::Relaxed);
            assert!(after - before <= 4, "spawned {} new workers", after - before);
        }

        #[test]
        fn borrows_local_state_soundly() {
            let data: Vec<u64> = (0..32).collect();
            let sums: Vec<Mutex<u64>> = (0..32).map(|_| Mutex::new(0)).collect();
            scoped_run(32, &|rank| {
                *sums[rank].lock().unwrap() = data[rank] * 2;
            });
            for (i, s) in sums.iter().enumerate() {
                assert_eq!(*s.lock().unwrap(), i as u64 * 2);
            }
        }

        #[test]
        fn panic_propagates_with_lowest_rank_and_pool_survives() {
            let r = std::panic::catch_unwind(|| {
                scoped_run(8, &|rank| {
                    if rank % 3 == 1 {
                        panic!("boom {rank}");
                    }
                });
            });
            let err = r.unwrap_err();
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "<non-string>".into());
            assert!(msg.contains("rank 1"), "{msg}");
            // pool still usable after panics
            scoped_run(8, &|_| {});
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::spmd_run;

    fn free() -> (BackendProfile, CostParams) {
        (BackendProfile::openmpi_fixed(), CostParams::new(1.0, 0.001))
    }

    #[test]
    fn run_returns_rank_ordered_results() {
        let (b, m) = free();
        let res = spmd_run(8, b, m, |ctx| ctx.rank * 10);
        assert_eq!(res.results, vec![0, 10, 20, 30, 40, 50, 60, 70]);
        assert_eq!(res.t_parallel, 0.0);
    }

    #[test]
    fn send_recv_advances_clocks() {
        let (b, m) = free();
        // rank 0 sends 1000 "bytes"-worth Vec<f32> (8 + 4*248 = 1000)
        let res = spmd_run(2, b, m, |ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 42, vec![0f32; 248]);
            } else {
                let v: Vec<f32> = ctx.recv(0, 42);
                assert_eq!(v.len(), 248);
            }
            ctx.now()
        });
        // sender clock: ts + tw*1000 = 1 + 1 = 2.0; receiver same (was at 0)
        assert!((res.results[0] - 2.0).abs() < 1e-12, "{}", res.results[0]);
        assert!((res.results[1] - 2.0).abs() < 1e-12);
        assert_eq!(res.t_parallel, 2.0);
    }

    #[test]
    fn late_receiver_starts_transfer_at_own_clock() {
        let (b, m) = free();
        let res = spmd_run(2, b, m, |ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 1, 0u8); // cost ts + tw = 1.001
            } else {
                ctx.advance_compute(10.0, 0.0);
                let _: u8 = ctx.recv(0, 1);
            }
            ctx.now()
        });
        // receiver busy until 10, then pays the transfer itself
        assert!((res.results[1] - 11.001).abs() < 1e-9, "{}", res.results[1]);
    }

    #[test]
    fn compute_advances_clock_and_flops() {
        let (b, m) = free();
        let res = spmd_run(1, b, m, |ctx| {
            ctx.advance_compute(0.5, 1e9);
            ctx.now()
        });
        assert_eq!(res.results[0], 0.5);
        assert_eq!(res.metrics[0].flops, 1e9);
    }

    #[test]
    fn group_ids_consistent_across_ranks() {
        let (b, m) = free();
        let res = spmd_run(4, b, m, |ctx| {
            let a = ctx.alloc_group_id(&[0, 1, 2, 3]);
            let b2 = ctx.alloc_group_id(&[0, 1, 2, 3]); // second instance differs
            let c = ctx.alloc_group_id(&[0, 2]);
            (a, b2, c)
        });
        let (a0, b0, c0) = res.results[0];
        for &(a, b2, c) in &res.results {
            assert_eq!(a, a0);
            assert_eq!(b2, b0);
            assert_eq!(c, c0);
        }
        assert_ne!(a0, b0);
        assert_ne!(a0, c0);
    }

    #[test]
    fn tag_scope_ids_independent_of_history() {
        let (b, m) = free();
        let res = spmd_run(2, b, m, |ctx| {
            // Divergent histories: rank 0 creates extra groups first.
            for _ in 0..ctx.rank * 3 + 1 {
                ctx.alloc_group_id(&[0, 1]);
            }
            // Inside a scope, ids depend only on the seed + scope-local
            // order — identical across ranks despite the divergence.
            let scoped = ctx.with_tag_scope(0xDEAD_BEEF, || {
                (ctx.alloc_group_id(&[0, 1]), ctx.alloc_group_id(&[0, 1]))
            });
            // A different seed yields a different namespace.
            let other = ctx.with_tag_scope(0xFEED_F00D, || ctx.alloc_group_id(&[0, 1]));
            (scoped, other)
        });
        let ((a0, b0), o0) = res.results[0];
        let ((a1, b1), o1) = res.results[1];
        assert_eq!((a0, b0), (a1, b1), "scoped ids diverged across ranks");
        assert_eq!(o0, o1);
        assert_ne!(a0, b0, "scope-local instances must differ");
        assert_ne!(a0, o0, "different seeds must give different namespaces");
    }

    #[test]
    fn tag_scope_restores_after_panic() {
        let (b, m) = free();
        let res = spmd_run(1, b, m, |ctx| {
            let before = ctx.alloc_group_id(&[0]);
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                ctx.with_tag_scope(7, || -> u64 { panic!("job died") })
            }));
            assert!(r.is_err());
            // Unscoped allocation resumes exactly where it left off.
            let after = ctx.alloc_group_id(&[0]);
            (before, after)
        });
        let (before, after) = res.results[0];
        assert_ne!(before, after);
    }

    #[test]
    fn timed_compute_charges_wall_time() {
        let (b, m) = free();
        let res = spmd_run(1, b, m, |ctx| {
            let v = ctx.timed_compute(100.0, || {
                std::thread::sleep(Duration::from_millis(5));
                123
            });
            assert_eq!(v, 123);
            ctx.now()
        });
        assert!(res.results[0] >= 0.004, "clock {} too small", res.results[0]);
    }

    #[test]
    fn wall_clock_measured() {
        let (b, m) = free();
        let res = spmd_run(2, b, m, |_| std::thread::sleep(Duration::from_millis(2)));
        assert!(res.wall >= Duration::from_millis(2));
    }

    // ------------------------------------------------ Runtime builder

    #[test]
    fn builder_defaults_build() {
        let rt = Runtime::builder().build().unwrap();
        assert_eq!(rt.world(), 1);
        assert_eq!(rt.backend().name(), "openmpi-fixed");
        assert_eq!(rt.machine_cost(), CostParams::default());
    }

    #[test]
    fn builder_resolves_backend_by_name() {
        let rt = Runtime::builder().world(3).backend("shmem").build().unwrap();
        assert_eq!(rt.backend().name(), "shmem");
        let res = rt.run(|ctx| ctx.backend_name().to_string());
        assert!(res.results.iter().all(|n| n == "shmem"));
    }

    #[test]
    fn builder_rejects_unknown_backend_and_zero_world() {
        assert!(Runtime::builder().backend("no-such").build().is_err());
        assert!(Runtime::builder().world(0).build().is_err());
    }

    #[test]
    fn builder_threads_per_rank_knob() {
        assert_eq!(Runtime::builder().build().unwrap().threads_per_rank(), 1);
        assert_eq!(
            Runtime::builder().threads_per_rank(4).build().unwrap().threads_per_rank(),
            4
        );
        // zero clamps to one
        assert_eq!(
            Runtime::builder().threads_per_rank(0).build().unwrap().threads_per_rank(),
            1
        );
        // visible on every rank context
        let res = Runtime::builder()
            .world(2)
            .threads_per_rank(3)
            .build()
            .unwrap()
            .run(|ctx| ctx.threads_per_rank());
        assert_eq!(res.results, vec![3, 3]);
    }

    #[test]
    fn builder_resolves_machine_by_name() {
        let rt = Runtime::builder().machine("carver").build().unwrap();
        let carver = MachineConfig::carver().cost();
        assert_eq!(rt.machine_cost(), carver);
        assert!(Runtime::builder().machine("no-such-machine").build().is_err());
    }

    #[test]
    fn runtime_is_reusable_across_runs() {
        let rt = Runtime::builder()
            .world(4)
            .backend_profile(BackendProfile::shmem())
            .cost(CostParams::free())
            .build()
            .unwrap();
        for round in 0..3u64 {
            let res = rt.run(move |ctx| ctx.rank as u64 + round);
            assert_eq!(res.results, vec![round, round + 1, round + 2, round + 3]);
        }
    }

    #[test]
    fn backend_cost_shaping_applies() {
        // mpj-express multiplies ts by 20
        let rt = Runtime::builder()
            .world(2)
            .backend("mpj-express")
            .cost(CostParams::new(1.0, 0.0))
            .build()
            .unwrap();
        let res = rt.run(|ctx| {
            if ctx.rank == 0 {
                ctx.send(1, 1, 0u8);
            } else {
                let _: u8 = ctx.recv(0, 1);
            }
            ctx.now()
        });
        assert!((res.results[0] - 20.0).abs() < 1e-9, "{}", res.results[0]);
    }

    // --------------------------------------------------- transports

    #[test]
    fn builder_resolves_transports_and_rejects_unknown() {
        for (name, expect) in [
            ("local", "local"),
            ("shmem", "local"),
            ("tcp-loopback", "tcp-loopback"),
            ("tcp", "tcp"),
        ] {
            let rt = Runtime::builder().transport(name).build().unwrap();
            assert_eq!(rt.transport_name(), expect, "{name}");
        }
        assert_eq!(Runtime::builder().build().unwrap().transport_name(), "local");
        let err = Runtime::builder().transport("carrier-pigeon").build().unwrap_err();
        assert!(format!("{err:#}").contains("carrier-pigeon"));
        // hybrid resolves only with a node shape
        let rt = Runtime::builder().transport("hybrid").ranks_per_node(2).build().unwrap();
        assert_eq!(rt.transport_name(), "hybrid");
        let err = Runtime::builder().transport("hybrid").build().unwrap_err();
        assert!(format!("{err:#}").contains("ranks_per_node"), "{err:#}");
    }

    #[test]
    fn builder_ranks_per_node_shapes_topology() {
        // default: flat on every transport
        let rt = Runtime::builder().world(4).build().unwrap();
        assert!(rt.topology().is_flat());
        // explicit shape, honored on the in-process transport too
        let rt = Runtime::builder().world(8).ranks_per_node(3).build().unwrap();
        let topo = rt.topology();
        assert_eq!(topo.node_sizes(), &[3, 3, 2]);
        let res = rt.run(|ctx| {
            (ctx.topology().node_of(ctx.rank), ctx.topology().is_leader(ctx.rank))
        });
        assert_eq!(
            res.results,
            vec![
                (0, true),
                (0, false),
                (0, false),
                (1, true),
                (1, false),
                (1, false),
                (2, true),
                (2, false)
            ]
        );
        // zero clamps to one (every rank its own node)
        let rt = Runtime::builder().world(2).ranks_per_node(0).build().unwrap();
        assert_eq!(rt.topology().num_nodes(), 2);
    }

    #[test]
    fn hierarchical_links_price_intra_below_inter() {
        let res = Runtime::builder()
            .world(4)
            .ranks_per_node(2)
            .backend_profile(BackendProfile::openmpi_fixed())
            .cost(CostParams::new(1.0, 0.001))
            .build()
            .unwrap()
            .run(|ctx| {
                let link = ctx.link_cost();
                assert!(link.intra.msg(1000) < link.inter.msg(1000));
                // same-node exchange 0↔1 is priced on the intra link;
                // cross-node exchange 0↔2 on the inter (machine) link
                match ctx.rank {
                    0 => {
                        ctx.send(1, 1, 0u8);
                        let t_intra = ctx.now();
                        ctx.send(2, 2, 0u8);
                        (t_intra, ctx.now() - t_intra)
                    }
                    1 => {
                        let _: u8 = ctx.recv(0, 1);
                        (ctx.now(), 0.0)
                    }
                    2 => {
                        let _: u8 = ctx.recv(0, 2);
                        (ctx.now(), 0.0)
                    }
                    _ => (0.0, 0.0),
                }
            });
        let (t_intra, t_inter_leg) = res.results[0];
        assert!(t_intra < 0.1, "intra send priced on shared-memory link: {t_intra}");
        assert!(t_inter_leg > 1.0, "inter send priced on machine link: {t_inter_leg}");
    }

    #[test]
    fn hybrid_run_matches_in_process_results() {
        let mk = |transport: &str| {
            Runtime::builder()
                .world(4)
                .ranks_per_node(2)
                .backend_profile(BackendProfile::openmpi_fixed())
                .cost(CostParams::new(1.0, 0.001))
                .transport(transport)
                .build()
                .unwrap()
                .run(|ctx| {
                    if ctx.rank == 0 {
                        ctx.send(1, 8, vec![1.5f64, 2.5]); // intra leg
                        ctx.send(3, 9, vec![4.5f64, 8.0]); // inter leg
                        0.0
                    } else if ctx.rank == 1 {
                        let v: Vec<f64> = ctx.recv(0, 8);
                        v.iter().sum()
                    } else if ctx.rank == 3 {
                        let v: Vec<f64> = ctx.recv(0, 9);
                        v.iter().sum()
                    } else {
                        -1.0
                    }
                })
        };
        let shm = mk("local");
        let hyb = mk("hybrid");
        assert_eq!(shm.results, hyb.results);
        // virtual time is transport-independent: both runs carry the
        // same topology, so clocks agree even though delivery differs
        assert_eq!(shm.clocks, hyb.clocks);
        assert_eq!(shm.t_parallel, hyb.t_parallel);
    }

    #[test]
    fn tcp_loopback_run_matches_in_process_results() {
        let mk = |transport: &str| {
            Runtime::builder()
                .world(4)
                .backend_profile(BackendProfile::openmpi_fixed())
                .cost(CostParams::new(1.0, 0.001))
                .transport(transport)
                .build()
                .unwrap()
                .run(|ctx| {
                    if ctx.rank == 0 {
                        ctx.send(1, 9, vec![1.5f64, 2.5]);
                        0.0
                    } else if ctx.rank == 1 {
                        let v: Vec<f64> = ctx.recv(0, 9);
                        v.iter().sum()
                    } else {
                        -1.0
                    }
                })
        };
        let shm = mk("local");
        let tcp = mk("tcp-loopback");
        assert_eq!(shm.results, tcp.results);
        // virtual time is transport-independent by construction
        assert_eq!(shm.clocks, tcp.clocks);
        assert_eq!(shm.t_parallel, tcp.t_parallel);
    }
}
