//! The in-process message fabric: mailboxes, tags, and virtual-time stamps.
//!
//! Ranks are OS threads; a message is an [`Envelope`] posted into the
//! destination rank's [`Mailbox`].  Matching is by `(src, tag)` with
//! out-of-order buffering (a rank may receive messages in any arrival
//! order but consumes them selectively, like MPI tag matching).
//!
//! **Virtual time.**  Both endpoints are occupied for the full transfer
//! `ts + tw·bytes` (the paper's §2 cost model; "telephone" semantics):
//! the sender advances its clock by the cost and stamps the envelope with
//! its *ready* time (clock at send initiation); the receiver starts the
//! transfer at `max(receiver_clock, ready)` and pays the full cost again
//! on its own clock.  Collective costs therefore *emerge* from their
//! message patterns instead of being plugged in as formulas — a linear
//! reduction really costs Θ(p) at the root, because the root's clock
//! serializes p−1 incoming transfers.
//!
//! Deadlock detection: `take` panics after [`RECV_TIMEOUT`] with a
//! diagnostic.  FooPar's design claim is that group operations make
//! deadlocks impossible; the timeout is our test oracle for that claim
//! (a deadlock in the framework would fail loudly, not hang CI).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::comm::message::Msg;

/// Wall-clock bound on a blocking receive before we declare deadlock.
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

/// One message in flight.
pub struct Envelope {
    pub src: usize,
    pub tag: u64,
    /// Modeled wire size (drives cost and metrics).
    pub bytes: usize,
    /// Sender's virtual clock at send initiation (transfer-ready time).
    pub ready: f64,
    /// The erased payload (generic sends are wrapped by `Ctx`).
    pub payload: Msg,
}

#[derive(Default)]
struct MailboxInner {
    queue: VecDeque<Envelope>,
    /// Ranks that have exited (posting to them is a bug; receiving from
    /// them can never succeed).
    closed: bool,
}

/// One rank's incoming message buffer.
#[derive(Default)]
pub struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
    /// Bumped on every post; lets `take` spin-wait for new arrivals
    /// without touching the mutex (§Perf).
    seq: AtomicU64,
}

/// The fabric connecting `world` ranks.
pub struct Fabric {
    boxes: Vec<Mailbox>,
}

impl Fabric {
    pub fn new(world: usize) -> Arc<Self> {
        assert!(world > 0, "world size must be positive");
        let boxes = (0..world).map(|_| Mailbox::default()).collect();
        Arc::new(Fabric { boxes })
    }

    pub fn world(&self) -> usize {
        self.boxes.len()
    }

    /// Deliver an envelope to `dst`'s mailbox.
    ///
    /// Panics (with sender, destination, and tag diagnostics) if `dst`'s
    /// mailbox is closed: the destination rank already exited, so the
    /// message could never be received — silently queueing it would turn
    /// a collective-membership bug into a downstream deadlock.
    pub fn post(&self, dst: usize, env: Envelope) {
        let mb = &self.boxes[dst];
        {
            let mut inner = mb.inner.lock().unwrap();
            if inner.closed {
                // drop the guard before panicking so the mutex is not
                // poisoned for diagnostics readers
                drop(inner);
                panic!(
                    "rank {}: post(dst={dst}, tag={:#x}, {} bytes) to closed mailbox — \
                     rank {dst} already exited; sending to a non-participant is a \
                     collective-membership bug",
                    env.src, env.tag, env.bytes
                );
            }
            inner.queue.push_back(env);
        }
        self.boxes[dst].seq.fetch_add(1, Ordering::Release);
        // Only the owning rank ever blocks on its own mailbox — a single
        // waiter, so notify_one suffices (perf: avoids thundering-herd
        // wakeups; see EXPERIMENTS.md §Perf).
        mb.cv.notify_one();
    }

    /// Blocking, selective receive: first buffered envelope matching
    /// `(src, tag)`.  Panics after [`RECV_TIMEOUT`] (deadlock oracle).
    ///
    /// Deliberately futex-based with **no spin phase**: a bounded spin
    /// (tried in the §Perf pass, both lock-scan and lock-free `seq`
    /// variants) regressed ping-pong latency up to 9× on low-core-count
    /// hosts — the spinner burns the quantum the *sender* needs.  The
    /// `seq` counter is kept for diagnostics.
    pub fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        let mb = &self.boxes[me];
        let mut inner = mb.inner.lock().unwrap();
        loop {
            if let Some(pos) = inner
                .queue
                .iter()
                .position(|e| e.src == src && e.tag == tag)
            {
                return inner.queue.remove(pos).unwrap();
            }
            let pending: Vec<(usize, u64)> =
                inner.queue.iter().map(|e| (e.src, e.tag)).collect();
            let (guard, res) = mb
                .cv
                .wait_timeout(inner, RECV_TIMEOUT)
                .unwrap();
            inner = guard;
            if res.timed_out()
                && !inner
                    .queue
                    .iter()
                    .any(|e| e.src == src && e.tag == tag)
            {
                panic!(
                    "rank {me}: recv(src={src}, tag={tag:#x}) timed out after {RECV_TIMEOUT:?} \
                     — deadlock? pending envelopes: {pending:?}"
                );
            }
        }
    }

    /// Non-blocking probe for a matching envelope.
    pub fn probe(&self, me: usize, src: usize, tag: u64) -> bool {
        let inner = self.boxes[me].inner.lock().unwrap();
        inner.queue.iter().any(|e| e.src == src && e.tag == tag)
    }

    /// Number of buffered envelopes for rank `me` (diagnostics).
    pub fn pending(&self, me: usize) -> usize {
        self.boxes[me].inner.lock().unwrap().queue.len()
    }

    /// Mark a rank's mailbox closed (rank exited).
    pub fn close(&self, me: usize) {
        self.boxes[me].inner.lock().unwrap().closed = true;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn env(src: usize, tag: u64, val: i64) -> Envelope {
        Envelope { src, tag, bytes: 8, ready: 0.0, payload: Msg::new(val) }
    }

    #[test]
    fn post_then_take() {
        let f = Fabric::new(2);
        f.post(1, env(0, 7, 42));
        let e = f.take(1, 0, 7);
        assert_eq!(e.payload.downcast::<i64>(), 42);
    }

    #[test]
    fn selective_matching_out_of_order() {
        let f = Fabric::new(2);
        f.post(1, env(0, 1, 10));
        f.post(1, env(0, 2, 20));
        // take tag 2 first even though tag 1 arrived first
        assert_eq!(f.take(1, 0, 2).payload.downcast::<i64>(), 20);
        assert_eq!(f.take(1, 0, 1).payload.downcast::<i64>(), 10);
    }

    #[test]
    fn matching_by_source() {
        let f = Fabric::new(3);
        f.post(2, env(0, 5, 100));
        f.post(2, env(1, 5, 200));
        assert_eq!(f.take(2, 1, 5).payload.downcast::<i64>(), 200);
        assert_eq!(f.take(2, 0, 5).payload.downcast::<i64>(), 100);
    }

    #[test]
    fn blocking_take_wakes_on_post() {
        let f = Fabric::new(2);
        let f2 = f.clone();
        let h = thread::spawn(move || {
            let e = f2.take(1, 0, 9);
            e.payload.downcast::<i64>()
        });
        thread::sleep(Duration::from_millis(20));
        f.post(1, env(0, 9, 77));
        assert_eq!(h.join().unwrap(), 77);
    }

    #[test]
    fn ready_stamp_preserved() {
        let f = Fabric::new(2);
        f.post(1, Envelope { src: 0, tag: 0, bytes: 4, ready: 1.25, payload: Msg::new(0i64) });
        assert_eq!(f.take(1, 0, 0).ready, 1.25);
    }

    #[test]
    fn post_to_closed_mailbox_panics_with_diagnostics() {
        let f = Fabric::new(2);
        f.close(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.post(1, env(0, 0x2A, 7));
        }));
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("closed mailbox"), "{msg}");
        assert!(msg.contains("rank 0"), "{msg}");
        assert!(msg.contains("dst=1"), "{msg}");
        assert!(msg.contains("0x2a"), "{msg}");
        // nothing was queued
        assert_eq!(f.pending(1), 0);
    }

    #[test]
    fn open_mailboxes_unaffected_by_closed_sibling() {
        let f = Fabric::new(3);
        f.close(2);
        f.post(1, env(0, 1, 5));
        assert_eq!(f.take(1, 0, 1).payload.downcast::<i64>(), 5);
    }

    #[test]
    fn pending_counts() {
        let f = Fabric::new(2);
        assert_eq!(f.pending(1), 0);
        f.post(1, env(0, 1, 1));
        f.post(1, env(0, 2, 2));
        assert_eq!(f.pending(1), 2);
    }
}
