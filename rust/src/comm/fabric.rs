//! The in-process message fabric — the shared-memory [`Transport`].
//!
//! Ranks are OS threads; a message is an [`Envelope`] posted into the
//! destination rank's [`Mailbox`].  Matching is by `(src, tag)` with
//! out-of-order buffering (a rank may receive messages in any arrival
//! order but consumes them selectively, like MPI tag matching).  Since
//! all ranks share one address space, payloads move by **ownership** —
//! no serialization ever happens on this transport; the wire codec is
//! only exercised by [`tcp`](crate::comm::transport::tcp).
//!
//! **Virtual time.**  Both endpoints are occupied for the full transfer
//! `ts + tw·bytes` (the paper's §2 cost model; "telephone" semantics):
//! the sender advances its clock by the cost and stamps the envelope with
//! its *ready* time (clock at send initiation); the receiver starts the
//! transfer at `max(receiver_clock, ready)` and pays the full cost again
//! on its own clock.  Collective costs therefore *emerge* from their
//! message patterns instead of being plugged in as formulas — a linear
//! reduction really costs Θ(p) at the root, because the root's clock
//! serializes p−1 incoming transfers.
//!
//! Deadlock detection: `take` panics after [`RECV_TIMEOUT`] with a
//! diagnostic (see [`Mailbox::take`]).

use std::sync::Arc;

pub use crate::comm::transport::{Envelope, RECV_TIMEOUT};
use crate::comm::transport::{Mailbox, Transport};

/// The in-process fabric connecting `world` ranks: one [`Mailbox`] per
/// rank in shared memory.
pub struct Fabric {
    boxes: Vec<Mailbox>,
}

impl Fabric {
    pub fn new(world: usize) -> Arc<Self> {
        assert!(world > 0, "world size must be positive");
        let boxes = (0..world).map(|_| Mailbox::default()).collect();
        Arc::new(Fabric { boxes })
    }

    pub fn world(&self) -> usize {
        self.boxes.len()
    }

    /// Deliver an envelope to `dst`'s mailbox (panics with diagnostics
    /// if `dst` already exited — see [`Mailbox::post`]).
    pub fn post(&self, dst: usize, env: Envelope) {
        self.boxes[dst].post(dst, env);
    }

    /// Blocking, selective receive: first buffered envelope matching
    /// `(src, tag)`.  Panics after [`RECV_TIMEOUT`] (deadlock oracle).
    pub fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        self.boxes[me].take(me, src, tag)
    }

    /// Non-blocking probe for a matching envelope.
    pub fn probe(&self, me: usize, src: usize, tag: u64) -> bool {
        self.boxes[me].probe(src, tag)
    }

    /// Number of buffered envelopes for rank `me` (diagnostics).
    pub fn pending(&self, me: usize) -> usize {
        self.boxes[me].pending()
    }

    /// Mark a rank's mailbox closed (rank exited).  Idempotent.
    pub fn close(&self, me: usize) {
        let _ = self.boxes[me].close();
    }

    /// Poison every mailbox (a rank died): blocked receives fail
    /// promptly with `reason` — see [`Mailbox::fail`].
    pub fn fail(&self, reason: &str) {
        for b in &self.boxes {
            b.fail(reason);
        }
    }

    /// Poison only `ranks`' mailboxes — the serving runtime's scoped
    /// failure (one job's members fail promptly, disjoint jobs keep
    /// running).  See [`Mailbox::fail`].
    pub fn fail_ranks(&self, ranks: &[usize], reason: &str) {
        for &r in ranks {
            self.boxes[r].fail(reason);
        }
    }

    /// Un-poison rank `me`'s mailbox, dropping stale envelopes — see
    /// [`Mailbox::clear_fail`].
    pub fn clear_fail(&self, me: usize) {
        self.boxes[me].clear_fail();
    }
}

impl Transport for Fabric {
    fn world(&self) -> usize {
        Fabric::world(self)
    }

    fn name(&self) -> &'static str {
        "shmem"
    }

    fn post(&self, dst: usize, env: Envelope) {
        Fabric::post(self, dst, env);
    }

    fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        Fabric::take(self, me, src, tag)
    }

    fn probe(&self, me: usize, src: usize, tag: u64) -> bool {
        Fabric::probe(self, me, src, tag)
    }

    fn pending(&self, me: usize) -> usize {
        Fabric::pending(self, me)
    }

    fn close(&self, me: usize) {
        Fabric::close(self, me);
    }

    fn fail(&self, reason: &str) {
        Fabric::fail(self, reason);
    }

    fn fail_ranks(&self, ranks: &[usize], reason: &str) {
        Fabric::fail_ranks(self, ranks, reason);
    }

    fn clear_fail(&self, me: usize) {
        Fabric::clear_fail(self, me);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::message::Msg;
    use std::thread;
    use std::time::Duration;

    fn env(src: usize, tag: u64, val: i64) -> Envelope {
        Envelope { src, tag, bytes: 8, ready: 0.0, payload: Msg::new(val) }
    }

    #[test]
    fn post_then_take() {
        let f = Fabric::new(2);
        f.post(1, env(0, 7, 42));
        let e = f.take(1, 0, 7);
        assert_eq!(e.payload.downcast::<i64>(), 42);
    }

    #[test]
    fn selective_matching_out_of_order() {
        let f = Fabric::new(2);
        f.post(1, env(0, 1, 10));
        f.post(1, env(0, 2, 20));
        // take tag 2 first even though tag 1 arrived first
        assert_eq!(f.take(1, 0, 2).payload.downcast::<i64>(), 20);
        assert_eq!(f.take(1, 0, 1).payload.downcast::<i64>(), 10);
    }

    #[test]
    fn matching_by_source() {
        let f = Fabric::new(3);
        f.post(2, env(0, 5, 100));
        f.post(2, env(1, 5, 200));
        assert_eq!(f.take(2, 1, 5).payload.downcast::<i64>(), 200);
        assert_eq!(f.take(2, 0, 5).payload.downcast::<i64>(), 100);
    }

    #[test]
    fn blocking_take_wakes_on_post() {
        let f = Fabric::new(2);
        let f2 = f.clone();
        let h = thread::spawn(move || {
            let e = f2.take(1, 0, 9);
            e.payload.downcast::<i64>()
        });
        thread::sleep(Duration::from_millis(20));
        f.post(1, env(0, 9, 77));
        assert_eq!(h.join().unwrap(), 77);
    }

    #[test]
    fn ready_stamp_preserved() {
        let f = Fabric::new(2);
        f.post(1, Envelope { src: 0, tag: 0, bytes: 4, ready: 1.25, payload: Msg::new(0i64) });
        assert_eq!(f.take(1, 0, 0).ready, 1.25);
    }

    #[test]
    fn post_to_closed_mailbox_panics_with_diagnostics() {
        let f = Fabric::new(2);
        f.close(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f.post(1, env(0, 0x2A, 7));
        }));
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("closed mailbox"), "{msg}");
        assert!(msg.contains("rank 0"), "{msg}");
        assert!(msg.contains("dst=1"), "{msg}");
        assert!(msg.contains("0x2a"), "{msg}");
        // nothing was queued
        assert_eq!(f.pending(1), 0);
    }

    #[test]
    fn take_on_closed_mailbox_panics_with_diagnostics() {
        let f = Fabric::new(2);
        f.close(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = f.take(1, 0, 0x3B);
        }));
        let err = r.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("closed mailbox"), "{msg}");
        assert!(msg.contains("rank 1"), "{msg}");
        assert!(msg.contains("src=0"), "{msg}");
        assert!(msg.contains("0x3b"), "{msg}");
    }

    #[test]
    fn take_blocked_then_closed_panics_promptly() {
        // a rank blocked in take must fail as soon as its mailbox closes,
        // not after the 60 s deadlock timeout
        let f = Fabric::new(2);
        let f2 = f.clone();
        let h = thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = f2.take(1, 0, 1);
            }))
        });
        thread::sleep(Duration::from_millis(20));
        f.close(1);
        let res = h.join().unwrap();
        let err = res.unwrap_err();
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("closed mailbox"), "{msg}");
    }

    #[test]
    fn open_mailboxes_unaffected_by_closed_sibling() {
        let f = Fabric::new(3);
        f.close(2);
        f.post(1, env(0, 1, 5));
        assert_eq!(f.take(1, 0, 1).payload.downcast::<i64>(), 5);
    }

    #[test]
    fn fail_wakes_blocked_take_promptly_with_diagnostics() {
        let f = Fabric::new(2);
        let f2 = f.clone();
        let t0 = std::time::Instant::now();
        let h = thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = f2.take(0, 1, 0x5C);
            }))
        });
        thread::sleep(Duration::from_millis(20));
        f.fail("rank 1 died mid-run: boom");
        let err = h.join().unwrap().unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5), "poison was not prompt");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("rank 1 died mid-run: boom"), "{msg}");
        assert!(msg.contains("src=1"), "{msg}");
        assert!(msg.contains("0x5c"), "{msg}");
    }

    #[test]
    fn fail_ranks_poisons_only_targets_and_clear_recovers() {
        let f = Fabric::new(3);
        f.post(1, env(0, 1, 11)); // stale envelope on the doomed rank
        f.fail_ranks(&[1], "job 7 member died");
        // rank 1 poisoned...
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = f.take(1, 0, 1);
        }));
        let msg = r
            .unwrap_err()
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("job 7 member died"), "{msg}");
        // ...rank 2 untouched
        f.post(2, env(0, 3, 33));
        assert_eq!(f.take(2, 0, 3).payload.downcast::<i64>(), 33);
        // recovery: clear drops the stale envelope and re-admits traffic
        f.clear_fail(1);
        assert_eq!(f.pending(1), 0, "stale envelopes must be dropped");
        f.post(1, env(0, 9, 99));
        assert_eq!(f.take(1, 0, 9).payload.downcast::<i64>(), 99);
    }

    #[test]
    fn pending_counts() {
        let f = Fabric::new(2);
        assert_eq!(f.pending(1), 0);
        f.post(1, env(0, 1, 1));
        f.post(1, env(0, 2, 2));
        assert_eq!(f.pending(1), 2);
    }
}
