//! Non-blocking group operations: handle-based async collectives with
//! communication–computation overlap.
//!
//! Every collective in the [`Collectives`](super::collectives::Collectives)
//! trait has a `*_start` form returning a handle.  A handle splits the
//! operation into two phases:
//!
//! 1. **start** — everything that depends on nothing is done eagerly:
//!    the operation's tags are allocated, and sends whose payload is
//!    already in hand (a shift's outgoing block, a broadcast root's
//!    fan-out, a reduction leaf's contribution) are posted immediately;
//! 2. **wait** — the deferred remainder (receives, tree forwards,
//!    folds) runs when the caller claims the result.
//!
//! **The overlap-aware clock rule.**  At `*_start` the rank's virtual
//! clock is *forked*: the operation's message rounds (and any compute
//! inside its fold operators) advance the fork — its private *comm
//! timeline* — while the rank's main clock keeps advancing with whatever
//! the rank computes in between.  `wait()` *merges*:
//!
//! ```text
//!     clock  =  max(main clock, comm timeline)
//!            =  t_start + max(T_comp, T_comm)
//! ```
//!
//! instead of the blocking `t_start + T_comp + T_comm` — so pipelined
//! algorithms (Cannon/DNS prefetch variants, see [`crate::algos`]) show
//! their overlap in `T_P` and the isoefficiency analysis, exactly the
//! classic route to closing the gap to peak.  The comm time hidden this
//! way is recorded per rank in
//! [`RankMetrics::overlap_hidden`](crate::metrics::RankMetrics).
//!
//! **SPMD contract.**  `*_start` and `wait()` are collective calls like
//! their blocking counterparts: every member must call both, in the same
//! order, on the same group instance.  Dropping a handle without
//! `wait()`ing strands the peers (their deadlock oracle will fire).
//! `test()` is advisory and free of clock effects: `true` means the
//! first outstanding receive is already buffered and `wait()` will
//! likely not block in wall time — `false` is not proof of absence (see
//! [`Transport::probe`](crate::comm::transport::Transport::probe)).
//!
//! The erased [`GroupOp`] is the object-safe currency of the
//! [`Collectives`](super::collectives::Collectives) trait; user code
//! sees the typed wrappers ([`Op`], [`ReduceOp`], [`VecOp`],
//! [`GatherOp`], [`BarrierOp`]) returned by the `Group::*_start`
//! methods, or the data-layer handles (`PendingSeq`, `PendingReduce`,
//! `PendingApply`, `PendingRead`) built on top of them.

use std::marker::PhantomData;

use crate::comm::group::Group;
use crate::comm::message::Msg;
use crate::comm::wire::WireData;

/// Result shape of an erased in-flight collective.
pub enum OpOutput {
    /// A value everywhere (bcast, shift, scatter, scan, allreduce).
    One(Msg),
    /// A value at the root only (reduce).
    MaybeOne(Option<Msg>),
    /// The group-ordered vector everywhere (allgather, alltoall).
    Many(Vec<Msg>),
    /// The group-ordered vector at the root only (gather).
    MaybeMany(Option<Vec<Msg>>),
    /// Nothing (barrier).
    Unit,
}

impl OpOutput {
    pub fn one(self) -> Msg {
        match self {
            OpOutput::One(m) => m,
            _ => panic!("pending operation did not produce a single value"),
        }
    }

    pub fn maybe_one(self) -> Option<Msg> {
        match self {
            OpOutput::MaybeOne(m) => m,
            _ => panic!("pending operation did not produce a root value"),
        }
    }

    pub fn many(self) -> Vec<Msg> {
        match self {
            OpOutput::Many(v) => v,
            _ => panic!("pending operation did not produce a vector"),
        }
    }

    pub fn maybe_many(self) -> Option<Vec<Msg>> {
        match self {
            OpOutput::MaybeMany(v) => v,
            _ => panic!("pending operation did not produce a root vector"),
        }
    }

    pub fn unit(self) {
        match self {
            OpOutput::Unit => {}
            _ => panic!("pending operation unexpectedly produced a value"),
        }
    }
}

enum Phase<'f> {
    /// The operation completed in its start phase (root-side fan-out,
    /// leaf-side contribution, p = 1, zero-delta shift, …).
    Ready(OpOutput),
    /// The deferred remainder: receives / forwards / folds, run on the
    /// comm timeline inside `wait()`.  The group is passed back in at
    /// wait — the closure captures only protocol state, never the group,
    /// so data-layer handles can own their group alongside the op.
    Deferred(Box<dyn for<'x, 'y> FnOnce(&'x Group<'y>) -> OpOutput + 'f>),
}

/// An in-flight group operation over erased [`Msg`] values — what the
/// [`Collectives`](super::collectives::Collectives) `*_start` methods
/// return.  See the module docs for the phase split and the clock rule.
#[must_use = "a pending group operation must be wait()ed by every member — \
              dropping the handle strands its peers"]
pub struct GroupOp<'f> {
    /// Guard against waiting on a different group than started on.
    group_id: u64,
    /// Main-clock value at `*_start` (fork point).
    t0: f64,
    /// Comm-timeline clock after the start phase.
    comm_clock: f64,
    /// First outstanding receive `(world src, tag)`, if known — the
    /// probe target of `test()`.
    probe: Option<(usize, u64)>,
    phase: Phase<'f>,
}

impl<'f> GroupOp<'f> {
    /// An operation whose start phase completed it (its sends, if any,
    /// advanced the comm timeline to `comm_clock`).
    pub fn ready(g: &Group, t0: f64, comm_clock: f64, out: OpOutput) -> Self {
        GroupOp {
            group_id: g.id(),
            t0,
            comm_clock,
            probe: None,
            phase: Phase::Ready(out),
        }
    }

    /// An operation with a deferred remainder.  `comm_clock` is the comm
    /// timeline after the start phase's sends; `probe` names the first
    /// outstanding receive for `test()`.
    pub fn deferred(
        g: &Group,
        t0: f64,
        comm_clock: f64,
        probe: Option<(usize, u64)>,
        f: impl for<'x, 'y> FnOnce(&'x Group<'y>) -> OpOutput + 'f,
    ) -> Self {
        GroupOp {
            group_id: g.id(),
            t0,
            comm_clock,
            probe,
            phase: Phase::Deferred(Box::new(f)),
        }
    }

    /// Fully-deferred fallback: run the whole blocking operation on the
    /// comm timeline at `wait()`.  This is how the `Collectives` trait
    /// defaults every `*_start` — results and the overlap clock rule are
    /// correct for any custom strategy for free; split-phase
    /// implementations (early sends, meaningful `test()`) are an
    /// override, not an obligation.
    pub fn run_deferred(
        g: &Group,
        f: impl for<'x, 'y> FnOnce(&'x Group<'y>) -> OpOutput + 'f,
    ) -> Self {
        let t0 = g.ctx().now();
        Self::deferred(g, t0, t0, None, f)
    }

    // Composition accessors (crate-internal): a multi-stage operation
    // (e.g. allreduce = reduce then bcast) wraps an inner handle in an
    // outer one — the outer adopts the inner's fork state and runs the
    // inner's remainder inline on its own comm timeline.

    /// Fork point of this operation (main clock at `*_start`).
    pub(crate) fn fork_t0(&self) -> f64 {
        self.t0
    }

    /// Comm-timeline clock after this operation's start phase.
    pub(crate) fn fork_comm_clock(&self) -> f64 {
        self.comm_clock
    }

    /// The `test()` probe target, if any.
    pub(crate) fn probe_target(&self) -> Option<(usize, u64)> {
        self.probe
    }

    /// Run the deferred remainder on the **current** clock — no fork, no
    /// merge.  Only valid inside an enclosing handle's deferred phase
    /// whose comm timeline was seeded with this handle's
    /// [`fork_comm_clock`](Self::fork_comm_clock).
    pub(crate) fn finish_inline(self, g: &Group) -> OpOutput {
        assert_eq!(
            self.group_id,
            g.id(),
            "pending operation waited on a different group than it started on"
        );
        match self.phase {
            Phase::Ready(out) => out,
            Phase::Deferred(f) => f(g),
        }
    }

    /// Advisory completion probe (no clock effects): is the first
    /// outstanding receive already buffered?  Handles that completed at
    /// start report `true`; deferred handles without a tracked receive
    /// (fully-deferred defaults) report `false` — unknown is not
    /// completion, and `false` already means only "keep waiting".
    pub fn test(&self, g: &Group) -> bool {
        match (&self.phase, self.probe) {
            (Phase::Ready(_), _) => true,
            (Phase::Deferred(_), None) => false,
            (Phase::Deferred(_), Some((src, tag))) => {
                let ctx = g.ctx();
                ctx.transport().probe(ctx.rank, src, tag)
            }
        }
    }

    /// Complete the operation: run the deferred remainder on the comm
    /// timeline, then merge `clock = max(clock, comm timeline)`.
    ///
    /// Must be called with the same group the operation started on.
    pub fn wait(self, g: &Group) -> OpOutput {
        assert_eq!(
            self.group_id,
            g.id(),
            "pending operation waited on a different group than it started on"
        );
        let ctx = g.ctx();
        // The composition path (`finish_inline`) is deliberately not
        // spanned: an enclosing handle's wait already covers it.
        let mut sp = crate::trace::span("wait", crate::trace::Category::Collective);
        if sp.is_active() {
            sp.arg("v_start", ctx.now());
        }
        let (out, comm_end) = match self.phase {
            Phase::Ready(out) => (out, self.comm_clock),
            Phase::Deferred(f) => ctx.with_clock(self.comm_clock, || f(g)),
        };
        ctx.finish_overlap(self.t0, comm_end);
        if sp.is_active() {
            sp.arg("v_end", ctx.now());
        }
        out
    }
}

// ---------------------------------------------------------------- typed

macro_rules! handle_common {
    () => {
        /// Advisory completion probe — see [`GroupOp::test`].
        pub fn test(&self) -> bool {
            self.raw.test(self.g)
        }
    };
}

/// Handle of a pending collective producing one `T` everywhere
/// (bcast, shift, scatter, scan, allreduce).
#[must_use = "a pending group operation must be wait()ed by every member"]
pub struct Op<'g, T: WireData> {
    g: &'g Group<'g>,
    raw: GroupOp<'g>,
    _t: PhantomData<fn() -> T>,
}

impl<'g, T: WireData> Op<'g, T> {
    pub(crate) fn new(g: &'g Group<'g>, raw: GroupOp<'g>) -> Self {
        Op { g, raw, _t: PhantomData }
    }

    handle_common!();

    /// Complete and claim the value (merges the overlap clocks).
    pub fn wait(self) -> T {
        self.raw.wait(self.g).one().downcast::<T>()
    }
}

/// Handle of a pending reduction: `Some(T)` at the root, `None` elsewhere.
#[must_use = "a pending group operation must be wait()ed by every member"]
pub struct ReduceOp<'g, T: WireData> {
    g: &'g Group<'g>,
    raw: GroupOp<'g>,
    _t: PhantomData<fn() -> T>,
}

impl<'g, T: WireData> ReduceOp<'g, T> {
    pub(crate) fn new(g: &'g Group<'g>, raw: GroupOp<'g>) -> Self {
        ReduceOp { g, raw, _t: PhantomData }
    }

    handle_common!();

    pub fn wait(self) -> Option<T> {
        self.raw.wait(self.g).maybe_one().map(|m| m.downcast::<T>())
    }
}

/// Handle of a pending allgather/alltoall: the group-ordered vector.
#[must_use = "a pending group operation must be wait()ed by every member"]
pub struct VecOp<'g, T: WireData> {
    g: &'g Group<'g>,
    raw: GroupOp<'g>,
    _t: PhantomData<fn() -> T>,
}

impl<'g, T: WireData> VecOp<'g, T> {
    pub(crate) fn new(g: &'g Group<'g>, raw: GroupOp<'g>) -> Self {
        VecOp { g, raw, _t: PhantomData }
    }

    handle_common!();

    pub fn wait(self) -> Vec<T> {
        self.raw
            .wait(self.g)
            .many()
            .into_iter()
            .map(|m| m.downcast::<T>())
            .collect()
    }
}

/// Handle of a pending gather: `Some(vec)` at the root, `None` elsewhere.
#[must_use = "a pending group operation must be wait()ed by every member"]
pub struct GatherOp<'g, T: WireData> {
    g: &'g Group<'g>,
    raw: GroupOp<'g>,
    _t: PhantomData<fn() -> T>,
}

impl<'g, T: WireData> GatherOp<'g, T> {
    pub(crate) fn new(g: &'g Group<'g>, raw: GroupOp<'g>) -> Self {
        GatherOp { g, raw, _t: PhantomData }
    }

    handle_common!();

    pub fn wait(self) -> Option<Vec<T>> {
        self.raw
            .wait(self.g)
            .maybe_many()
            .map(|v| v.into_iter().map(|m| m.downcast::<T>()).collect())
    }
}

/// Handle of a pending barrier.
#[must_use = "a pending group operation must be wait()ed by every member"]
pub struct BarrierOp<'g> {
    g: &'g Group<'g>,
    raw: GroupOp<'g>,
}

impl<'g> BarrierOp<'g> {
    pub(crate) fn new(g: &'g Group<'g>, raw: GroupOp<'g>) -> Self {
        BarrierOp { g, raw }
    }

    handle_common!();

    pub fn wait(self) {
        self.raw.wait(self.g).unit()
    }
}

#[cfg(test)]
mod tests {
    use crate::comm::backend::BackendProfile;
    use crate::comm::cost::CostParams;
    use crate::comm::group::Group;
    use crate::testing::spmd_run as run;

    fn fixed() -> BackendProfile {
        BackendProfile::openmpi_fixed()
    }

    /// ts = 1, tw = 0: clocks count message rounds.
    fn unit_cost() -> CostParams {
        CostParams::new(1.0, 0.0)
    }

    #[test]
    fn shift_overlap_clock_is_max_not_sum() {
        let res = run(4, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.shift_start(1, ctx.rank as u64);
            ctx.advance_compute(3.0, 0.0); // overlaps the 1-round shift
            let v = h.wait();
            (v, ctx.now())
        });
        for (me, (v, t)) in res.results.iter().enumerate() {
            assert_eq!(*v, ((me + 3) % 4) as u64, "value at rank {me}");
            // blocking: 3 (compute) + 1 (shift) = 4; overlapped: max = 3
            assert!((t - 3.0).abs() < 1e-12, "rank {me}: clock {t}");
        }
    }

    #[test]
    fn shift_without_compute_costs_like_blocking() {
        let res = run(4, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.shift_start(1, 0u8);
            h.wait();
            ctx.now()
        });
        assert!(res.results.iter().all(|t| (t - 1.0).abs() < 1e-12));
    }

    #[test]
    fn zero_delta_shift_is_ready_immediately() {
        let res = run(3, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.shift_start(0, ctx.rank as u64);
            assert!(h.test());
            (h.wait(), ctx.now())
        });
        for (me, (v, t)) in res.results.iter().enumerate() {
            assert_eq!(*v, me as u64);
            assert_eq!(*t, 0.0);
        }
    }

    #[test]
    fn bcast_overlap_hides_tree_rounds() {
        let res = run(4, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.bcast_start(0, (ctx.rank == 0).then_some(42u64));
            ctx.advance_compute(5.0, 0.0);
            let v = h.wait();
            (v, ctx.now())
        });
        // blocking T_P for p=4 binomial bcast is 2 rounds → 2 + 5 = 7;
        // overlapped: every rank's comm timeline (≤ 2) hides under 5.
        for (me, (v, t)) in res.results.iter().enumerate() {
            assert_eq!(*v, 42, "rank {me}");
            assert!((t - 5.0).abs() < 1e-12, "rank {me}: clock {t}");
        }
    }

    #[test]
    fn reduce_start_preserves_fold_order() {
        for p in [2, 3, 4, 7, 8] {
            let res = run(p, fixed(), CostParams::free(), |ctx| {
                let g = Group::world(ctx);
                let h = g.reduce_start(0, format!("{}.", ctx.rank), |a, b| a + &b);
                h.wait()
            });
            let expect: String = (0..p).map(|r| format!("{r}.")).collect();
            assert_eq!(res.results[0].as_deref(), Some(expect.as_str()), "p={p}");
            assert!(res.results[1..].iter().all(Option::is_none));
        }
    }

    #[test]
    fn reduce_overlap_at_root_hides_comm() {
        let res = run(8, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.reduce_start(0, 1u64, |a, b| a + b);
            ctx.advance_compute(10.0, 0.0);
            let v = h.wait();
            (v, ctx.now())
        });
        assert_eq!(res.results[0].0, Some(8));
        // binomial reduce is 3 rounds at the root for p=8; all hidden
        assert!((res.results[0].1 - 10.0).abs() < 1e-12, "{}", res.results[0].1);
        let t_p = res.results.iter().map(|r| r.1).fold(0.0, f64::max);
        assert!((t_p - 10.0).abs() < 1e-12, "T_P {t_p}");
    }

    #[test]
    fn overlap_hidden_metric_records_savings() {
        let res = run(2, fixed(), unit_cost(), |ctx| {
            let g = Group::world(ctx);
            let h = g.shift_start(1, 0u8);
            ctx.advance_compute(3.0, 0.0);
            h.wait();
        });
        // the 1-second shift was fully hidden on both ranks
        for m in &res.metrics {
            assert!((m.overlap_hidden - 1.0).abs() < 1e-12, "{}", m.overlap_hidden);
        }
    }

    #[test]
    fn allgather_start_matches_blocking_values() {
        for p in [1, 2, 3, 5, 8] {
            let res = run(p, fixed(), CostParams::free(), |ctx| {
                let g = Group::world(ctx);
                let h = g.allgather_start(ctx.rank as u64 * 10);
                ctx.advance_compute(1.0, 0.0);
                h.wait()
            });
            let expect: Vec<u64> = (0..p as u64).map(|r| r * 10).collect();
            assert!(res.results.iter().all(|v| *v == expect), "p={p}");
        }
    }

    #[test]
    fn alltoall_start_transposes() {
        for p in [1, 2, 4, 6] {
            let res = run(p, fixed(), CostParams::free(), |ctx| {
                let g = Group::world(ctx);
                let items: Vec<u64> = (0..p).map(|j| (ctx.rank * 100 + j) as u64).collect();
                let h = g.alltoall_start(items);
                h.wait()
            });
            for (me, got) in res.results.iter().enumerate() {
                let expect: Vec<u64> = (0..p).map(|i| (i * 100 + me) as u64).collect();
                assert_eq!(*got, expect, "p={p} me={me}");
            }
        }
    }

    #[test]
    fn gather_scatter_scan_barrier_allreduce_start_values() {
        let res = run(6, fixed(), CostParams::free(), |ctx| {
            let g = Group::world(ctx);
            let gathered = g.gather_start(3, ctx.rank as u64).wait();
            let doubled = g
                .scatter_start(3, gathered.map(|v| v.iter().map(|x| x * 2).collect()))
                .wait();
            let prefix = g.scan_start(ctx.rank as i64 + 1, |a, b| a + b).wait();
            g.barrier_start().wait();
            let top = g.allreduce_start(ctx.rank as i64, |a, b| a.max(b)).wait();
            (doubled, prefix, top)
        });
        for (me, (d, s, t)) in res.results.iter().enumerate() {
            assert_eq!(*d, me as u64 * 2);
            assert_eq!(*s, ((me + 1) * (me + 2) / 2) as i64);
            assert_eq!(*t, 5);
        }
    }

    #[test]
    fn test_turns_true_once_the_peer_posted() {
        let res = run(2, fixed(), CostParams::free(), |ctx| {
            let g = Group::world(ctx);
            let h = g.shift_start(1, ctx.rank as u64);
            // the peer's start already posted on the shmem fabric; spin
            // with a generous bound so wire transports would pass too
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
            while !h.test() {
                assert!(std::time::Instant::now() < deadline, "test() never turned true");
                std::thread::yield_now();
            }
            h.wait()
        });
        assert_eq!(res.results, vec![1, 0]);
    }

    #[test]
    fn two_outstanding_ops_on_one_group_do_not_cross() {
        let res = run(4, fixed(), CostParams::free(), |ctx| {
            let g = Group::world(ctx);
            let h1 = g.shift_start(1, ctx.rank as u64);
            let h2 = g.shift_start(2, (ctx.rank * 100) as u64);
            let a = h1.wait();
            let b = h2.wait();
            (a, b)
        });
        for (me, (a, b)) in res.results.iter().enumerate() {
            assert_eq!(*a, ((me + 3) % 4) as u64);
            assert_eq!(*b, (((me + 2) % 4) * 100) as u64);
        }
    }

    #[test]
    fn waits_in_reverse_start_order_complete() {
        // out-of-order waits are legal: tags keep rounds apart
        let res = run(3, fixed(), CostParams::free(), |ctx| {
            let g = Group::world(ctx);
            let h1 = g.shift_start(1, ctx.rank as u64);
            let h2 = g.shift_start(1, (ctx.rank + 10) as u64);
            let b = h2.wait();
            let a = h1.wait();
            (a, b)
        });
        for (me, (a, b)) in res.results.iter().enumerate() {
            assert_eq!(*a, ((me + 2) % 3) as u64);
            assert_eq!(*b, (((me + 2) % 3) + 10) as u64);
        }
    }

    #[test]
    fn wrong_group_wait_panics() {
        let r = std::panic::catch_unwind(|| {
            run(2, fixed(), CostParams::free(), |ctx| {
                let g1 = Group::world(ctx);
                let g2 = Group::world(ctx);
                let h = crate::comm::algorithms::shift_cyclic_start(
                    &g1,
                    1,
                    crate::comm::message::Msg::new(0u8),
                );
                let _ = h.wait(&g2);
            });
        });
        assert!(r.is_err());
    }
}
