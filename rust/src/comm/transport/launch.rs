//! Multi-process launcher: re-exec spawning with env-var rendezvous.
//!
//! `Runtime::builder().transport("tcp")` turns one program into an
//! MPI-style multi-process run, no external launcher required:
//!
//! 1. the **parent** process (no `FOOPAR_TCP_RANK` in its environment)
//!    becomes rank 0.  It binds a rendezvous listener plus its own data
//!    listener, then re-execs its own binary (`current_exe`, same
//!    arguments) once per remaining rank with three environment
//!    variables set: [`ENV_RANK`], [`ENV_WORLD`], [`ENV_RENDEZVOUS`];
//! 2. each **worker** re-runs `main` from the top, reaches the same
//!    `Runtime::run` call (SPMD symmetry), binds its data listener, and
//!    reports `rank port` over the rendezvous connection;
//! 3. the parent collects all registrations, broadcasts the full
//!    rank→port map back over the same connections, and every process
//!    builds its [`TcpTransport::endpoint`].  Loopback-only by design —
//!    this is the CI-friendly single-host story.
//!
//! Because workers re-execute `main`, code before the `run` call runs in
//! every process: keep it idempotent, and gate output or expensive
//! side-effects on [`child_rank`] (see `examples/matmul_dns_tcp.rs`).
//! After `run` returns, the parent has waited on every worker and
//! verified exit status; workers should simply return from `main`.
//!
//! One multi-process run per program execution: the rendezvous address
//! in a worker's environment refers to the parent's *first* run, so a
//! second `transport("tcp")` run panics with an explanation instead of
//! hanging.
//!
//! Topology: node boundaries must agree across ranks, so the parent
//! forwards [`ENV_RANKS_PER_NODE`](super::hier::ENV_RANKS_PER_NODE)
//! explicitly (builder/config-derived values are re-derived by each
//! worker re-running the same `main` — SPMD symmetry covers those).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::process::{Child, Command};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use super::tcp::TcpTransport;
use std::sync::{Arc, Mutex};

/// Worker rank (absent in the parent/launcher process).
pub const ENV_RANK: &str = "FOOPAR_TCP_RANK";
/// Total number of ranks, for cross-checking the builder configuration.
pub const ENV_WORLD: &str = "FOOPAR_TCP_WORLD";
/// `host:port` of the parent's rendezvous listener.
pub const ENV_RENDEZVOUS: &str = "FOOPAR_TCP_RENDEZVOUS";

/// How long the parent waits for all workers to register (a worker that
/// dies before registering fails the run within this bound, not never).
const RENDEZVOUS_TIMEOUT: Duration = Duration::from_secs(30);

static USED: AtomicBool = AtomicBool::new(false);

/// `Some(rank)` when this process is a spawned worker of a multi-process
/// run; `None` in the parent (which doubles as rank 0).
pub fn child_rank() -> Option<usize> {
    std::env::var(ENV_RANK).ok()?.parse().ok()
}

/// Spawned worker processes with kill-on-drop: any parent failure path
/// (rendezvous bail, rank-0 panic, clock-gather failure) reaps the
/// workers instead of orphaning N−1 re-exec'd processes that would each
/// burn a 60 s deadlock timeout before dying on their own.
struct Workers(Vec<Child>);

impl Drop for Workers {
    fn drop(&mut self) {
        for child in &mut self.0 {
            if matches!(child.try_wait(), Ok(None)) {
                let _ = child.kill();
                let _ = child.wait();
            }
        }
    }
}

/// One process's view of an established multi-process world.
pub struct ProcWorld {
    rank: usize,
    world: usize,
    transport: Arc<TcpTransport>,
    /// Spawned workers (parent only).  Behind `Arc<Mutex<…>>` so the
    /// liveness watchdog can poll from its own thread; the watchdog only
    /// holds a `Weak`, so kill-on-drop still fires if the parent unwinds.
    children: Arc<Mutex<Workers>>,
    /// First worker failure observed (set by the watchdog before it
    /// reaps the survivors).  [`ProcWorld::check_children`] reports this
    /// root cause instead of blaming a sibling the watchdog killed.
    first_failure: Arc<OnceLock<String>>,
}

impl ProcWorld {
    /// This process's rank (parent: 0).
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn world(&self) -> usize {
        self.world
    }

    pub fn transport(&self) -> Arc<TcpTransport> {
        self.transport.clone()
    }

    /// Parent: non-blocking liveness poll — `Err` if any worker already
    /// exited with a failure status.  Lets the parent fail fast (with
    /// the worker's exit status) instead of blocking on a receive that
    /// can never complete.  Workers: no-op.
    pub fn check_children(&self) -> crate::Result<()> {
        let mut kids = self.children.lock().unwrap();
        // Checked under the children lock: the watchdog records its
        // verdict (and reaps the survivors) while holding it, so once
        // we are here any verdict is visible — and it wins, because a
        // naive scan would blame a sibling the watchdog signal-killed.
        if let Some(reason) = self.first_failure.get() {
            bail!("{reason}");
        }
        for (i, child) in kids.0.iter_mut().enumerate() {
            if let Some(status) = child.try_wait()? {
                if !status.success() {
                    bail!("tcp worker rank {} exited with {status} mid-run", i + 1);
                }
            }
        }
        Ok(())
    }

    /// Parent: has worker `rank` already exited successfully?  Lets the
    /// end-of-run clock gather distinguish "frame still in flight" from
    /// "worker exited cleanly without ever posting it" (user code
    /// calling `exit(0)` mid-run — invisible to the failure watchdog).
    pub fn child_exited_ok(&self, rank: usize) -> bool {
        let mut kids = self.children.lock().unwrap();
        match rank.checked_sub(1).and_then(|i| kids.0.get_mut(i)) {
            Some(child) => matches!(child.try_wait(), Ok(Some(s)) if s.success()),
            None => false,
        }
    }

    /// Parent: spawn a background liveness watchdog that polls the
    /// worker processes and, when one exits with a failure status,
    /// poisons the local transport — so a receive blocked on the dead
    /// rank (e.g. a non-blocking handle's `wait()`) panics promptly with
    /// the worker's exit status and the stranded (rank, src, tag)
    /// diagnostics instead of hanging out the deadlock timeout.
    ///
    /// Returns `None` on workers (nothing to watch).  The thread exits
    /// when `stop` is set or the `ProcWorld` is dropped (it only holds a
    /// `Weak` to the children, preserving kill-on-drop).
    pub fn spawn_watchdog(
        &self,
        stop: Arc<std::sync::atomic::AtomicBool>,
    ) -> Option<std::thread::JoinHandle<()>> {
        if self.children.lock().unwrap().0.is_empty() {
            return None;
        }
        let kids = Arc::downgrade(&self.children);
        let transport = self.transport.clone();
        let first_failure = self.first_failure.clone();
        let handle = std::thread::Builder::new()
            .name("foopar-tcp-watchdog".into())
            .spawn(move || loop {
                if stop.load(Ordering::Acquire) {
                    return;
                }
                let Some(kids) = kids.upgrade() else { return };
                let mut dead: Option<String> = None;
                {
                    let mut guard = kids.lock().unwrap();
                    for (i, child) in guard.0.iter_mut().enumerate() {
                        match child.try_wait() {
                            Ok(Some(status)) if !status.success() => {
                                dead = Some(format!(
                                    "tcp worker rank {} exited with {status} mid-run",
                                    i + 1
                                ));
                                break;
                            }
                            _ => {}
                        }
                    }
                    if let Some(reason) = &dead {
                        // Pin the root cause before reaping: once the
                        // survivors are signal-killed, a naive child
                        // scan would blame the wrong rank.
                        let _ = first_failure.set(reason.clone());
                        // A dead worker dooms the run.  Sibling workers
                        // blocked on the dead rank cannot be poisoned
                        // from here (their mailboxes live in their own
                        // processes) — reap them now instead of letting
                        // them burn their own deadlock timeout.
                        for child in guard.0.iter_mut() {
                            if matches!(child.try_wait(), Ok(None)) {
                                let _ = child.kill();
                            }
                        }
                    }
                }
                drop(kids);
                if let Some(reason) = dead {
                    use super::Transport;
                    transport.fail(&reason);
                    return;
                }
                std::thread::sleep(Duration::from_millis(25));
            })
            .expect("spawn tcp watchdog thread");
        Some(handle)
    }

    /// Parent: wait for every worker and fail if any exited non-zero.
    /// Workers: no-op.
    pub fn finish(self) -> crate::Result<()> {
        let mut failures = Vec::new();
        {
            let mut kids = self.children.lock().unwrap();
            for (i, child) in kids.0.iter_mut().enumerate() {
                let rank = i + 1;
                match child.wait() {
                    Ok(status) if status.success() => {}
                    Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
                    Err(e) => failures.push(format!("rank {rank} wait failed: {e}")),
                }
            }
        }
        // The watchdog's pinned verdict wins outright: the survivors'
        // signal-kill statuses are collateral from its reaping, not
        // failures of their own.
        if let Some(reason) = self.first_failure.get() {
            return Err(anyhow!("{reason}"));
        }
        if failures.is_empty() {
            Ok(())
        } else {
            Err(anyhow!("tcp worker process failures: {}", failures.join("; ")))
        }
    }
}

/// Establish the multi-process world for `world` ranks (parent or
/// worker, decided by the environment — see module docs).
pub fn establish(world: usize) -> crate::Result<ProcWorld> {
    if USED.swap(true, Ordering::SeqCst) {
        bail!(
            "transport(\"tcp\") supports one multi-process run per program execution \
             (workers re-exec main and rendezvous with the parent's first run); \
             use transport(\"tcp-loopback\") for repeated in-process wire runs"
        );
    }
    match child_rank() {
        Some(rank) => establish_worker(rank, world),
        None => establish_parent(world),
    }
}

fn establish_parent(world: usize) -> crate::Result<ProcWorld> {
    let rendezvous = TcpListener::bind("127.0.0.1:0").context("bind rendezvous listener")?;
    let rdv_addr = rendezvous.local_addr()?;
    let listener = TcpListener::bind("127.0.0.1:0").context("bind rank 0 data listener")?;

    let exe = std::env::current_exe().context("resolve current_exe for re-exec")?;
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut children = Workers(Vec::with_capacity(world - 1));
    for rank in 1..world {
        let mut cmd = Command::new(&exe);
        cmd.args(&args)
            .env(ENV_RANK, rank.to_string())
            .env(ENV_WORLD, world.to_string())
            .env(ENV_RENDEZVOUS, rdv_addr.to_string());
        // Explicit (not just inherited): every rank must derive the same
        // node topology or hierarchical routing would disagree.
        if let Ok(rpn) = std::env::var(super::hier::ENV_RANKS_PER_NODE) {
            cmd.env(super::hier::ENV_RANKS_PER_NODE, rpn);
        }
        let child = cmd
            .spawn()
            .with_context(|| format!("re-exec {} for rank {rank}", exe.display()))?;
        children.0.push(child);
    }

    // Collect `rank port` registrations, with a deadline and early
    // failure if a worker dies before registering.
    let mut ports: Vec<Option<u16>> = vec![None; world];
    ports[0] = Some(listener.local_addr()?.port());
    let mut socks: Vec<Option<TcpStream>> = (0..world).map(|_| None).collect();
    rendezvous.set_nonblocking(true)?;
    let deadline = Instant::now() + RENDEZVOUS_TIMEOUT;
    let mut registered = 1;
    while registered < world {
        match rendezvous.accept() {
            Ok((stream, _)) => {
                stream.set_nonblocking(false)?;
                // tiny line-oriented control messages: defeat Nagle so
                // the port-map round trip is not delayed
                let _ = stream.set_nodelay(true);
                stream.set_read_timeout(Some(RENDEZVOUS_TIMEOUT))?;
                let mut line = String::new();
                BufReader::new(stream.try_clone()?).read_line(&mut line)?;
                let mut it = line.split_whitespace();
                let rank: usize = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| anyhow!("bad rendezvous registration {line:?}"))?;
                let port: u16 = it
                    .next()
                    .and_then(|s| s.parse().ok())
                    .ok_or_else(|| anyhow!("bad rendezvous registration {line:?}"))?;
                if rank == 0 || rank >= world || ports[rank].is_some() {
                    bail!("duplicate or out-of-range rendezvous rank {rank}");
                }
                ports[rank] = Some(port);
                socks[rank] = Some(stream);
                registered += 1;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                for (i, child) in children.0.iter_mut().enumerate() {
                    if let Some(status) = child.try_wait()? {
                        bail!(
                            "tcp worker rank {} exited with {status} before registering",
                            i + 1
                        );
                    }
                }
                if Instant::now() > deadline {
                    bail!(
                        "rendezvous timed out after {RENDEZVOUS_TIMEOUT:?} with \
                         {registered}/{world} ranks registered"
                    );
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }

    // Broadcast the full port map.
    let map = ports
        .iter()
        .map(|p| p.unwrap().to_string())
        .collect::<Vec<_>>()
        .join(" ");
    for sock in socks.iter_mut().flatten() {
        writeln!(sock, "{map}").context("send port map to worker")?;
    }

    let peers = ports
        .iter()
        .map(|p| SocketAddr::from(([127, 0, 0, 1], p.unwrap())))
        .collect();
    let transport = TcpTransport::endpoint(0, world, listener, peers);
    Ok(ProcWorld {
        rank: 0,
        world,
        transport,
        children: Arc::new(Mutex::new(children)),
        first_failure: Arc::new(OnceLock::new()),
    })
}

fn establish_worker(rank: usize, world: usize) -> crate::Result<ProcWorld> {
    let env_world: usize = std::env::var(ENV_WORLD)
        .context("worker missing FOOPAR_TCP_WORLD")?
        .parse()
        .context("FOOPAR_TCP_WORLD not an integer")?;
    if env_world != world {
        bail!(
            "SPMD asymmetry: spawned for world {env_world} but Runtime::builder() \
             requested world {world} — parent and workers must execute the same run"
        );
    }
    let rdv = std::env::var(ENV_RENDEZVOUS).context("worker missing FOOPAR_TCP_RENDEZVOUS")?;
    let listener = TcpListener::bind("127.0.0.1:0").context("bind worker data listener")?;
    let port = listener.local_addr()?.port();

    let mut stream = TcpStream::connect(&rdv)
        .with_context(|| format!("rank {rank}: connect rendezvous {rdv}"))?;
    // registration + port map are single short lines — defeat Nagle
    let _ = stream.set_nodelay(true);
    writeln!(stream, "{rank} {port}").context("register with rendezvous")?;
    let mut line = String::new();
    stream
        .set_read_timeout(Some(RENDEZVOUS_TIMEOUT))
        .context("rendezvous read timeout")?;
    BufReader::new(stream).read_line(&mut line).context("read port map")?;
    let ports: Vec<u16> = line
        .split_whitespace()
        .map(|s| s.parse())
        .collect::<Result<_, _>>()
        .context("parse port map")?;
    if ports.len() != world {
        bail!("port map has {} entries, expected {world}", ports.len());
    }
    let peers = ports
        .iter()
        .map(|&p| SocketAddr::from(([127, 0, 0, 1], p)))
        .collect();
    let transport = TcpTransport::endpoint(rank, world, listener, peers);
    Ok(ProcWorld {
        rank,
        world,
        transport,
        children: Arc::new(Mutex::new(Workers(Vec::new()))),
        first_failure: Arc::new(OnceLock::new()),
    })
}
