//! The per-rank mailbox: `(src, tag)`-matched buffering shared by every
//! transport.
//!
//! Carved out of the PR 1 `Fabric` so wire transports reuse the exact
//! matching, blocking, and deadlock-oracle semantics: the in-process
//! [`Fabric`](crate::comm::fabric::Fabric) owns one mailbox per rank and
//! posts into it directly; [`TcpTransport`](super::tcp::TcpTransport)
//! owns one mailbox per *local* rank and has socket reader threads post
//! decoded frames into it.  `take` never knows which.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use super::Envelope;

/// Wall-clock bound on a blocking receive before we declare deadlock.
///
/// FooPar's design claim is that group operations make deadlocks
/// impossible; the timeout is our test oracle for that claim (a deadlock
/// in the framework fails loudly instead of hanging CI).
pub const RECV_TIMEOUT: Duration = Duration::from_secs(60);

#[derive(Default)]
struct MailboxInner {
    queue: VecDeque<Envelope>,
    /// The owning rank has exited (posting to it is a bug; receiving
    /// from it can never succeed).
    closed: bool,
    /// A peer failure makes every pending/future receive hopeless (a
    /// rank died mid-run, a wire frame arrived torn).  Blocked and
    /// future `take`s panic promptly with this root cause and their own
    /// (rank, src, tag) instead of burning the deadlock timeout.
    poisoned: Option<String>,
}

/// One rank's incoming message buffer.
#[derive(Default)]
pub struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
    /// Bumped on every post; lets tooling observe arrivals without
    /// touching the mutex (§Perf; kept for diagnostics).
    seq: AtomicU64,
}

impl Mailbox {
    /// Buffer an envelope addressed to rank `dst` (the mailbox owner).
    ///
    /// Panics (with sender, destination, and tag diagnostics) if the
    /// mailbox is closed: the destination rank already exited, so the
    /// message could never be received — silently queueing it would turn
    /// a collective-membership bug into a downstream deadlock.
    pub fn post(&self, dst: usize, env: Envelope) {
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.closed {
                // drop the guard before panicking so the mutex is not
                // poisoned for diagnostics readers
                drop(inner);
                panic!(
                    "rank {}: post(dst={dst}, tag={:#x}, {} bytes) to closed mailbox — \
                     rank {dst} already exited; sending to a non-participant is a \
                     collective-membership bug",
                    env.src, env.tag, env.bytes
                );
            }
            inner.queue.push_back(env);
        }
        self.seq.fetch_add(1, Ordering::Release);
        // Only the owning rank ever blocks on its own mailbox — a single
        // waiter, so notify_one suffices (perf: avoids thundering-herd
        // wakeups; see EXPERIMENTS.md §Perf).
        self.cv.notify_one();
    }

    /// Blocking, selective receive by rank `me` (the mailbox owner):
    /// first buffered envelope matching `(src, tag)`.  Panics after
    /// [`RECV_TIMEOUT`] (deadlock oracle), and panics immediately — with
    /// the same rank/src/tag diagnostics as [`Mailbox::post`] — if the
    /// mailbox is already closed (receiving after exit is a
    /// collective-membership bug, not a reason to block for a minute).
    ///
    /// Deliberately futex-based with **no spin phase**: a bounded spin
    /// (tried in the §Perf pass, both lock-scan and lock-free `seq`
    /// variants) regressed ping-pong latency up to 9× on low-core-count
    /// hosts — the spinner burns the quantum the *sender* needs.
    pub fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(pos) = inner
                .queue
                .iter()
                .position(|e| e.src == src && e.tag == tag)
            {
                return inner.queue.remove(pos).unwrap();
            }
            if let Some(reason) = inner.poisoned.clone() {
                let pending: Vec<(usize, u64)> =
                    inner.queue.iter().map(|e| (e.src, e.tag)).collect();
                drop(inner);
                panic!(
                    "rank {me}: recv(src={src}, tag={tag:#x}) failed: {reason} \
                     (pending envelopes: {pending:?})"
                );
            }
            if inner.closed {
                let pending: Vec<(usize, u64)> =
                    inner.queue.iter().map(|e| (e.src, e.tag)).collect();
                drop(inner);
                panic!(
                    "rank {me}: recv(src={src}, tag={tag:#x}) on closed mailbox — \
                     rank {me} already exited; receiving after exit is a \
                     collective-membership bug (pending envelopes: {pending:?})"
                );
            }
            let pending: Vec<(usize, u64)> =
                inner.queue.iter().map(|e| (e.src, e.tag)).collect();
            let (guard, res) = self.cv.wait_timeout(inner, RECV_TIMEOUT).unwrap();
            inner = guard;
            if res.timed_out()
                && !inner
                    .queue
                    .iter()
                    .any(|e| e.src == src && e.tag == tag)
            {
                panic!(
                    "rank {me}: recv(src={src}, tag={tag:#x}) timed out after {RECV_TIMEOUT:?} \
                     — deadlock? pending envelopes: {pending:?}"
                );
            }
        }
    }

    /// Non-blocking probe for a matching envelope.
    pub fn probe(&self, src: usize, tag: u64) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.queue.iter().any(|e| e.src == src && e.tag == tag)
    }

    /// Number of buffered envelopes (diagnostics).
    pub fn pending(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    /// Poison the mailbox: a peer failure (dead rank, torn wire frame)
    /// makes every pending/future receive hopeless.  Blocked `take`s
    /// wake immediately and panic with `reason` plus their own
    /// (rank, src, tag) diagnostics.  Posting stays allowed (the failure
    /// is propagated through receivers, not senders — avoiding a race on
    /// which side trips first).  Idempotent: the first reason wins.
    pub fn fail(&self, reason: &str) {
        let mut inner = self.inner.lock().unwrap();
        if inner.poisoned.is_none() {
            inner.poisoned = Some(reason.to_string());
        }
        drop(inner);
        self.cv.notify_all();
    }

    /// Clear a poison mark, making the mailbox receivable again.
    ///
    /// The batch runtime never needs this — a poisoned run is over.  The
    /// serving runtime does: rank death is scoped to the *owning job*
    /// (the coordinator poisons exactly that job's members), and a
    /// poisoned worker that has unwound its job clears its own mailbox
    /// before accepting the next assignment.  Any envelopes still queued
    /// from the failed job are dropped here — their tags live in the
    /// dead job's namespace and could never match again.
    pub fn clear_fail(&self) {
        let mut inner = self.inner.lock().unwrap();
        if inner.poisoned.take().is_some() {
            inner.queue.clear();
        }
    }

    /// True when no future receive can succeed: the mailbox is poisoned
    /// or its owning rank already closed.  Lets polling receivers — the
    /// hybrid transport's inter-node probe+sleep loop — fall through to
    /// a blocking `take`, which panics promptly with full diagnostics,
    /// instead of polling forever past a failure.
    pub fn unreceivable(&self) -> bool {
        let inner = self.inner.lock().unwrap();
        inner.poisoned.is_some() || inner.closed
    }

    /// Mark the owning rank exited.  Idempotent; returns `true` only on
    /// the open→closed transition (so callers keeping shutdown counters
    /// stay correct under double-close).
    pub fn close(&self) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let transitioned = !inner.closed;
        inner.closed = true;
        drop(inner);
        // wake a blocked `take` so it panics with diagnostics instead of
        // sleeping out the timeout
        self.cv.notify_one();
        transitioned
    }
}
