//! [`TcpTransport`]: rank-to-rank delivery over TCP sockets with
//! length-prefixed frames — the distributed-memory [`Transport`].
//!
//! Topology: every rank owns a loopback `TcpListener`; a process holds
//! one or more *local* ranks (one per process in multi-process runs via
//! [`launch`](super::launch); all of them in the in-test
//! [`TcpTransport::loopback`] mode).  Outgoing traffic to rank `d` goes
//! over one lazily-established connection per destination, shared by
//! every local rank (frames are self-describing, so multiplexing is
//! free); each accepted connection gets a detached **reader thread**
//! that decodes frames into the destination rank's [`Mailbox`] — from
//! there on, tag matching, blocking receive, and the deadlock oracle are
//! exactly the shared-memory semantics.
//!
//! Frame format (all integers little-endian):
//!
//! ```text
//! u32  frame length (bytes after this field)
//! u64  src rank
//! u64  tag
//! u64  modeled envelope size (cost-model bytes, not frame bytes)
//! u64  sender virtual-clock `ready` stamp (f64 bits)
//! ...  Msg wire form (type fingerprint, modeled size, payload)
//! ```
//!
//! The `ready` stamp and modeled size cross the wire unmodified, so the
//! §2 virtual-time cost model — and therefore every emergent collective
//! cost — is identical to the in-process fabric.  The *payload* is the
//! [`wire`](crate::comm::wire) encoding; decoding back to the concrete
//! type happens lazily at the receiver's `downcast`.
//!
//! Even in single-process `loopback` mode every envelope makes a real
//! kernel round trip (encode → socket → decode) — that is the point:
//! the transport-parity tests drive the full wire path without needing
//! process orchestration.

use std::io::{IoSlice, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::{Envelope, Mailbox, Transport};
use crate::comm::message::Msg;
use crate::comm::wire::{WireError, WireReader};

/// How long `post` retries connecting to a peer's listener before
/// declaring it dead (covers rendezvous-to-first-send races).
const CONNECT_RETRY: Duration = Duration::from_millis(50);
const CONNECT_ATTEMPTS: usize = 100;

/// TCP transport endpoint set for one process (see module docs).
pub struct TcpTransport {
    world: usize,
    /// Mailbox per rank; `Some` only for ranks local to this process.
    boxes: Vec<Option<Mailbox>>,
    /// Listener address of every rank.
    peers: Vec<SocketAddr>,
    /// Outgoing connection per destination rank (lazy, shared by all
    /// local ranks; a frame is written atomically under the lock).
    conns: Vec<Mutex<Option<TcpStream>>>,
    /// Local ranks that have not yet closed; at zero, sockets shut down.
    open_local: Mutex<usize>,
    shutdown: AtomicBool,
    /// Reusable frame-payload buffers: `post` encodes each outgoing
    /// message into a pooled `Vec<u8>` instead of allocating per frame
    /// (block-sized payloads make per-send allocation a measurable tax).
    frame_pool: Mutex<Vec<Vec<u8>>>,
}

/// Upper bound on pooled frame buffers kept alive (the pool exists to
/// amortize steady-state sends, not to retain peak memory).
const FRAME_POOL_MAX: usize = 16;

/// Write one frame as a **single vectored write** — stack header plus
/// pooled payload, no concatenation copy — falling back to `write_all`
/// for the rare short write.  Retries `Interrupted` like `write_all`
/// does internally (the multi-process launcher forks workers, so
/// signals mid-send are a real event, not a failure).
fn write_frame(stream: &mut TcpStream, header: &[u8], payload: &[u8]) -> std::io::Result<()> {
    let n = loop {
        match stream.write_vectored(&[IoSlice::new(header), IoSlice::new(payload)]) {
            Ok(n) => break n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    };
    if n >= header.len() + payload.len() {
        return Ok(());
    }
    if n < header.len() {
        stream.write_all(&header[n..])?;
        stream.write_all(payload)
    } else {
        stream.write_all(&payload[n - header.len()..])
    }
}

impl TcpTransport {
    /// All `world` ranks in this process, each with its own loopback
    /// listener — full wire path, no process orchestration.  This is
    /// what `Runtime::builder().transport("tcp-loopback")` runs on.
    pub fn loopback(world: usize) -> std::io::Result<Arc<Self>> {
        let mut listeners = Vec::with_capacity(world);
        let mut peers = Vec::with_capacity(world);
        for rank in 0..world {
            let l = TcpListener::bind("127.0.0.1:0")?;
            peers.push(l.local_addr()?);
            listeners.push((rank, l));
        }
        Ok(Self::start(world, listeners, peers))
    }

    /// One local rank (`me`) with its already-bound listener plus the
    /// full peer address map — the multi-process endpoint built by
    /// [`launch::establish`](super::launch::establish).
    pub fn endpoint(
        me: usize,
        world: usize,
        listener: TcpListener,
        peers: Vec<SocketAddr>,
    ) -> Arc<Self> {
        assert_eq!(peers.len(), world, "peer map must cover the world");
        Self::start(world, vec![(me, listener)], peers)
    }

    fn start(
        world: usize,
        listeners: Vec<(usize, TcpListener)>,
        peers: Vec<SocketAddr>,
    ) -> Arc<Self> {
        let mut boxes: Vec<Option<Mailbox>> = (0..world).map(|_| None).collect();
        for (rank, _) in &listeners {
            boxes[*rank] = Some(Mailbox::default());
        }
        let t = Arc::new(TcpTransport {
            world,
            boxes,
            peers,
            conns: (0..world).map(|_| Mutex::new(None)).collect(),
            open_local: Mutex::new(listeners.len()),
            shutdown: AtomicBool::new(false),
            frame_pool: Mutex::new(Vec::new()),
        });
        for (rank, listener) in listeners {
            let tt = t.clone();
            std::thread::Builder::new()
                .name(format!("foopar-tcp-accept-{rank}"))
                .spawn(move || tt.accept_loop(rank, listener))
                .expect("spawn tcp accept thread");
        }
        t
    }

    /// Accept incoming connections for local rank `rank`, one detached
    /// reader thread per connection.
    fn accept_loop(self: Arc<Self>, rank: usize, listener: TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _)) => {
                    if self.shutdown.load(Ordering::Acquire) {
                        break; // the wake-up connection from shutdown_io
                    }
                    let _ = stream.set_nodelay(true);
                    let tt = self.clone();
                    std::thread::Builder::new()
                        .name(format!("foopar-tcp-read-{rank}"))
                        .spawn(move || tt.reader_loop(rank, stream))
                        .expect("spawn tcp reader thread");
                }
                Err(_) => break,
            }
        }
    }

    /// Decode one frame body (everything after the length prefix).
    fn parse_frame(buf: &[u8]) -> Result<Envelope, WireError> {
        let mut r = WireReader::new(buf);
        let src = r.len()?;
        let tag = r.u64()?;
        let bytes = r.len()?;
        let ready = f64::from_bits(r.u64()?);
        let payload = Msg::decode_from(&mut r)?;
        Ok(Envelope { src, tag, bytes, ready, payload })
    }

    /// Drain one connection: decode frames into `rank`'s mailbox until
    /// the peer closes (EOF) or shutdown resets the socket.
    ///
    /// Delivery failures (malformed frame, closed-mailbox delivery)
    /// happen on this detached thread, where an ordinary panic would die
    /// silently.  In multi-process mode (one local rank) the process
    /// exits non-zero so the launcher reports the failure immediately —
    /// the shared-memory "fail loudly" story.  In loopback mode (many
    /// ranks of a test binary share this process) the error is printed
    /// and the connection dropped, so only the affected run fails — via
    /// the stranded peer's deadlock oracle — instead of every test in
    /// the binary dying with it.
    fn reader_loop(&self, rank: usize, mut stream: TcpStream) {
        let mut len4 = [0u8; 4];
        loop {
            // Read the length prefix in two steps so a clean
            // between-frames close (0-byte read — normal end-of-run) is
            // distinguishable from a peer dying mid-header.
            match stream.read(&mut len4[..1]) {
                Ok(0) | Err(_) => break, // clean EOF or shutdown reset
                Ok(_) => {}
            }
            if stream.read_exact(&mut len4[1..]).is_err() {
                // 1-3 header bytes then EOF: the peer died mid-send.
                if !self.shutdown.load(Ordering::Acquire) {
                    Transport::fail(
                        self,
                        &format!(
                            "torn tcp frame header — peer feeding rank {rank} \
                             died mid-send"
                        ),
                    );
                }
                break;
            }
            let len = u32::from_le_bytes(len4) as usize;
            let mut buf = vec![0u8; len];
            if stream.read_exact(&mut buf).is_err() {
                // A frame header with no (complete) body: the peer died
                // mid-send.  Unlike a clean between-frames EOF (normal
                // end-of-run), a torn frame is always a failure — poison
                // every local mailbox (not just this connection's
                // destination: sibling ranks blocked on the same dead
                // peer are equally stranded) so blocked receives fail
                // promptly with diagnostics instead of burning the
                // deadlock timeout.
                if !self.shutdown.load(Ordering::Acquire) {
                    Transport::fail(
                        self,
                        &format!(
                            "torn tcp frame ({len}-byte body never arrived) — \
                             peer feeding rank {rank} died mid-send"
                        ),
                    );
                }
                break;
            }
            let deliver = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let env = Self::parse_frame(&buf).unwrap_or_else(|e| {
                    panic!("rank {rank}: malformed tcp frame ({len} bytes): {e}")
                });
                self.boxes[rank]
                    .as_ref()
                    .expect("reader for non-local rank")
                    .post(rank, env);
            }));
            if let Err(e) = deliver {
                let msg = e
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                eprintln!("fatal tcp transport error delivering to rank {rank}: {msg}");
                let local_ranks = self.boxes.iter().filter(|b| b.is_some()).count();
                if local_ranks == 1 {
                    std::process::exit(101);
                }
                break;
            }
        }
    }

    fn connect(&self, dst: usize) -> TcpStream {
        for attempt in 0..CONNECT_ATTEMPTS {
            match TcpStream::connect(self.peers[dst]) {
                Ok(s) => {
                    let _ = s.set_nodelay(true);
                    return s;
                }
                Err(e) if attempt + 1 == CONNECT_ATTEMPTS => panic!(
                    "tcp connect to rank {dst} at {} failed after {CONNECT_ATTEMPTS} attempts: {e}",
                    self.peers[dst]
                ),
                Err(_) => std::thread::sleep(CONNECT_RETRY),
            }
        }
        unreachable!()
    }

    /// Tear down sockets once every local rank has closed: drop outgoing
    /// connections (peers' readers see EOF) and wake our accept loops
    /// with a dummy connection so they observe the shutdown flag.
    fn shutdown_io(&self) {
        self.shutdown.store(true, Ordering::Release);
        for c in &self.conns {
            *c.lock().unwrap() = None;
        }
        for (rank, mb) in self.boxes.iter().enumerate() {
            if mb.is_some() {
                let _ = TcpStream::connect(self.peers[rank]);
            }
        }
    }

    fn mailbox(&self, me: usize) -> &Mailbox {
        self.boxes[me]
            .as_ref()
            .unwrap_or_else(|| panic!("rank {me} is not local to this process"))
    }

    /// True when receives for local rank `me` can no longer succeed
    /// (mailbox poisoned or closed) — see [`Mailbox::unreceivable`].
    /// Used by the hybrid transport's inter-node poll loop to stop
    /// polling and surface the failure diagnostics promptly.
    pub fn unreceivable(&self, me: usize) -> bool {
        self.mailbox(me).unreceivable()
    }

    /// Check a payload buffer out of the frame pool (empty, capacity
    /// retained from earlier frames).
    fn take_frame_buf(&self) -> Vec<u8> {
        let mut buf = self.frame_pool.lock().unwrap().pop().unwrap_or_default();
        buf.clear();
        buf
    }

    /// Return a payload buffer to the pool (dropped when full).
    fn give_frame_buf(&self, buf: Vec<u8>) {
        let mut pool = self.frame_pool.lock().unwrap();
        if pool.len() < FRAME_POOL_MAX {
            pool.push(buf);
        }
    }
}

impl Transport for TcpTransport {
    fn world(&self) -> usize {
        self.world
    }

    fn name(&self) -> &'static str {
        "tcp"
    }

    fn post(&self, dst: usize, env: Envelope) {
        // frame = len | src | tag | bytes | ready | msg wire form.  The
        // fixed 36-byte head lives on the stack; the payload encoding
        // goes into a pooled, reusable buffer; the two leave the process
        // as one vectored write — no per-frame allocation, no
        // header+payload concatenation copy.
        let mut payload = self.take_frame_buf();
        env.payload.encode_into(&mut payload);
        let len = u32::try_from(32 + payload.len()).expect("frame over 4 GiB");
        let mut header = [0u8; 36];
        header[0..4].copy_from_slice(&len.to_le_bytes());
        header[4..12].copy_from_slice(&(env.src as u64).to_le_bytes());
        header[12..20].copy_from_slice(&env.tag.to_le_bytes());
        header[20..28].copy_from_slice(&(env.bytes as u64).to_le_bytes());
        header[28..36].copy_from_slice(&env.ready.to_bits().to_le_bytes());

        {
            let mut guard = self.conns[dst].lock().unwrap();
            if guard.is_none() {
                *guard = Some(self.connect(dst));
            }
            if let Err(e) = write_frame(guard.as_mut().unwrap(), &header, &payload) {
                panic!(
                    "rank {}: tcp send (dst={dst}, tag={:#x}, {} bytes) failed: {e}",
                    env.src, env.tag, env.bytes
                );
            }
        }
        self.give_frame_buf(payload);
    }

    fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        self.mailbox(me).take(me, src, tag)
    }

    fn probe(&self, me: usize, src: usize, tag: u64) -> bool {
        self.mailbox(me).probe(src, tag)
    }

    fn pending(&self, me: usize) -> usize {
        self.mailbox(me).pending()
    }

    fn close(&self, me: usize) {
        // only an open→closed transition decrements, so close (like
        // Fabric's) is idempotent and the shutdown count stays correct
        if self.mailbox(me).close() {
            let mut open = self.open_local.lock().unwrap();
            *open -= 1;
            if *open == 0 {
                self.shutdown_io();
            }
        }
    }

    fn fail(&self, reason: &str) {
        for mb in self.boxes.iter().flatten() {
            mb.fail(reason);
        }
    }

    fn fail_ranks(&self, ranks: &[usize], reason: &str) {
        // Scoped poison for the ranks this process holds.  In loopback
        // mode (every rank local) that is fully scoped, like Fabric's;
        // in multi-process mode a failed job's *remote* members are not
        // reachable from here and surface through the deadlock oracle
        // instead — serving over multi-process tcp therefore treats any
        // rank death as a batch-style fatal (see serve docs).
        for &r in ranks {
            if let Some(mb) = &self.boxes[r] {
                mb.fail(reason);
            }
        }
    }

    fn clear_fail(&self, me: usize) {
        if let Some(mb) = &self.boxes[me] {
            mb.clear_fail();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loopback_delivers_across_real_sockets() {
        let t = TcpTransport::loopback(2).expect("bind loopback");
        t.post(
            1,
            Envelope { src: 0, tag: 7, bytes: 8, ready: 1.5, payload: Msg::new(42u64) },
        );
        let env = t.take(1, 0, 7);
        assert_eq!(env.src, 0);
        assert_eq!(env.ready, 1.5);
        assert_eq!(env.bytes, 8);
        assert!(env.payload.is_encoded());
        assert_eq!(env.payload.downcast::<u64>(), 42);
        t.close(0);
        t.close(1);
    }

    #[test]
    fn loopback_selective_matching_and_probe() {
        let t = TcpTransport::loopback(2).expect("bind loopback");
        t.post(1, Envelope { src: 0, tag: 1, bytes: 8, ready: 0.0, payload: Msg::new(10i64) });
        t.post(1, Envelope { src: 0, tag: 2, bytes: 8, ready: 0.0, payload: Msg::new(20i64) });
        // wait for the reader thread to buffer both
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while t.pending(1) < 2 {
            assert!(std::time::Instant::now() < deadline, "frames never arrived");
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(t.probe(1, 0, 2));
        assert!(!t.probe(1, 0, 3));
        assert_eq!(t.take(1, 0, 2).payload.downcast::<i64>(), 20);
        assert_eq!(t.take(1, 0, 1).payload.downcast::<i64>(), 10);
        t.close(0);
        t.close(1);
    }

    #[test]
    fn torn_frame_poisons_blocked_take_promptly() {
        // A peer that dies mid-send leaves a frame header with no body.
        // The receive blocked on that message must fail with diagnostics
        // promptly, not after the 60 s deadlock oracle.
        let t = TcpTransport::loopback(2).expect("bind loopback");
        let t2 = t.clone();
        let t0 = std::time::Instant::now();
        let h = std::thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let _ = t2.take(0, 1, 0x77);
            }))
        });
        std::thread::sleep(Duration::from_millis(30));
        {
            // hand-roll a torn frame: header promises 100 bytes, only 10
            // ever arrive before the "sender" dies
            let mut s = TcpStream::connect(t.peers[0]).expect("connect to rank 0 listener");
            s.write_all(&100u32.to_le_bytes()).unwrap();
            s.write_all(&[0u8; 10]).unwrap();
        } // drop = peer death
        let err = h.join().unwrap().unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(20), "poison was not prompt");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("torn tcp frame"), "{msg}");
        assert!(msg.contains("src=1"), "{msg}");
        assert!(msg.contains("0x77"), "{msg}");
        t.close(0);
        t.close(1);
    }

    #[test]
    fn multiple_sources_multiplex_onto_one_mailbox() {
        let t = TcpTransport::loopback(3).expect("bind loopback");
        t.post(2, Envelope { src: 0, tag: 5, bytes: 8, ready: 0.0, payload: Msg::new(100i64) });
        t.post(2, Envelope { src: 1, tag: 5, bytes: 8, ready: 0.0, payload: Msg::new(200i64) });
        assert_eq!(t.take(2, 1, 5).payload.downcast::<i64>(), 200);
        assert_eq!(t.take(2, 0, 5).payload.downcast::<i64>(), 100);
        for r in 0..3 {
            t.close(r);
        }
    }
}
