//! The wire-level transport subsystem: pluggable rank-to-rank delivery.
//!
//! The paper's FooPar-X configurations promise "easy access to different
//! communication backends for distributed memory architectures" (§3).
//! PR 1 made the *collective strategy* pluggable; this layer makes the
//! *delivery substrate* pluggable too:
//!
//! ```text
//!   algorithms.rs      textbook collectives as explicit message rounds
//!        │
//!   collectives.rs     pluggable per-backend strategy objects
//!        │
//!   group.rs / Ctx     tag namespaces, virtual-time cost model
//!        │
//!   Transport (this)   post / take / probe / close over Envelopes
//!        ├── Fabric            in-process shared-memory mailboxes
//!        ├── TcpTransport      length-prefixed frames over TCP sockets
//!        └── HierTransport     two-level hybrid: Fabric within a node,
//!                              TcpTransport across nodes, routed by a
//!                              Topology (hier.rs)
//! ```
//!
//! A [`Transport`] moves [`Envelope`]s between ranks.  The in-process
//! implementation is [`Fabric`](crate::comm::fabric::Fabric) (ranks are
//! threads, payloads move by ownership); [`tcp::TcpTransport`] carries
//! the same envelopes across OS processes as length-prefixed frames,
//! encoding payloads with the [`wire`](crate::comm::wire) codec.  All
//! collective algorithms run unchanged over either — the portability
//! claim, end to end.
//!
//! Multi-process runs are launched by [`launch`]: a re-exec-based
//! spawner with env-var rendezvous, selected with
//! `Runtime::builder().transport("tcp")`.

use crate::comm::message::Msg;

pub mod hier;
pub mod launch;
pub mod mailbox;
pub mod tcp;

pub use hier::{HierTransport, Topology};
pub use mailbox::{Mailbox, RECV_TIMEOUT};

/// One message in flight between two ranks.
pub struct Envelope {
    pub src: usize,
    pub tag: u64,
    /// Modeled wire size (drives cost and metrics).
    pub bytes: usize,
    /// Sender's virtual clock at send initiation (transfer-ready time).
    pub ready: f64,
    /// The erased payload (generic sends are wrapped by `Ctx`).
    pub payload: Msg,
}

/// Rank-to-rank envelope delivery — the seam between the cost-modeled
/// messaging layer ([`Ctx`](crate::spmd::Ctx)) and the physical
/// substrate (shared memory, TCP, …).
///
/// Semantics every implementation must provide (they are what the
/// collective algorithms rely on):
///
/// * **selective receive** — [`Transport::take`] blocks until an
///   envelope matching `(src, tag)` is buffered for `me`, consuming it;
///   arrival order is unconstrained (MPI-style tag matching);
/// * **deadlock oracle** — `take` panics with diagnostics after
///   [`RECV_TIMEOUT`] instead of hanging forever;
/// * **closed-mailbox detection** — delivering to, or taking from, a
///   rank that already [`Transport::close`]d fails loudly with rank/tag
///   diagnostics (a collective-membership bug must not become a silent
///   deadlock).  *Where* it surfaces is transport-specific: shared
///   memory panics synchronously in the posting rank; wire transports
///   detect it at the receiving process's delivery thread (non-zero
///   exit in multi-process mode, printed error + the stranded sender's
///   deadlock oracle in loopback mode);
/// * **virtual-time transparency** — the `ready` stamp and modeled
///   `bytes` of an envelope are delivered unmodified, so the §2 cost
///   model is identical on every transport.
pub trait Transport: Send + Sync {
    /// Number of ranks this transport connects.
    fn world(&self) -> usize;

    /// Short name for diagnostics (`"shmem"`, `"tcp"`).
    fn name(&self) -> &'static str;

    /// Deliver an envelope to `dst`'s mailbox.
    fn post(&self, dst: usize, env: Envelope);

    /// Blocking selective receive: first buffered envelope matching
    /// `(src, tag)` addressed to `me`.
    fn take(&self, me: usize, src: usize, tag: u64) -> Envelope;

    /// Non-blocking probe for a matching envelope.
    ///
    /// Advisory only: `true` means the envelope is buffered and `take`
    /// will return immediately; `false` is **not** proof of absence.  On
    /// wire transports a frame the peer already posted may still be in
    /// flight (socket buffers, reader threads), whereas the shared-memory
    /// fabric makes posts visible synchronously — portable callers must
    /// not turn `false` into a protocol decision, only into "keep
    /// waiting".
    fn probe(&self, me: usize, src: usize, tag: u64) -> bool;

    /// Number of buffered envelopes for rank `me` (diagnostics).
    fn pending(&self, me: usize) -> usize;

    /// Mark rank `me` exited: its mailbox refuses further traffic.
    fn close(&self, me: usize);

    /// Poison every mailbox local to this process: blocked and future
    /// receives panic **promptly** with `reason` plus their own
    /// (rank, src, tag) diagnostics.  Called when a rank or peer process
    /// dies mid-run, so collectives blocked on the dead rank — including
    /// a non-blocking handle's `wait()` — surface the root cause instead
    /// of burning the [`RECV_TIMEOUT`] deadlock oracle.
    fn fail(&self, reason: &str);

    /// Poison only the mailboxes of `ranks` — the scoped form of
    /// [`Transport::fail`] the serving runtime uses to fail one job's
    /// members while jobs on disjoint rank subsets keep running.
    ///
    /// The default falls back to the whole-process [`Transport::fail`]
    /// (correct but unscoped): transports that cannot address individual
    /// remote mailboxes — a multi-process wire transport holds only its
    /// local ranks' — degrade to the batch behavior, where any rank
    /// death ends the run.  In-process transports override this with a
    /// true per-rank poison.
    fn fail_ranks(&self, ranks: &[usize], reason: &str) {
        let _ = ranks;
        self.fail(reason);
    }

    /// Un-poison rank `me`'s mailbox (dropping any stale envelopes), so
    /// a serving worker that unwound a failed job can accept its next
    /// assignment.  Default: no-op — transports without scoped failure
    /// never re-admit a poisoned rank, matching their [`Transport::fail`]
    /// semantics.
    fn clear_fail(&self, me: usize) {
        let _ = me;
    }
}
