//! [`HierTransport`]: hierarchical hybrid delivery — shared-memory
//! mailboxes within a node, TCP across nodes — routed by a [`Topology`].
//!
//! Real clusters are two-level: ranks on one host talk through shared
//! memory, ranks on different hosts cross the network.  The flat
//! transports model one level or the other; this one composes both.  A
//! [`Topology`] assigns every world rank to a *node* (consecutive ranks
//! fill nodes of `ranks_per_node`, the last node taking the remainder);
//! each node's first rank is its *leader*.  Envelopes between same-node
//! ranks go through an intra-node [`Fabric`]; envelopes crossing a node
//! boundary go through an inter-node [`TcpTransport`] over real loopback
//! sockets — so the hybrid mode exercises the full wire path for exactly
//! the traffic that would cross a network, without process
//! orchestration.
//!
//! Virtual-time transparency: both legs deliver the envelope's `ready`
//! stamp and modeled byte count unmodified, so the §2 cost model holds —
//! with the twist that [`Ctx`](crate::spmd::Ctx) prices intra-node and
//! inter-node hops with distinct [`HierCost`] link parameters, which is
//! what lets the model compare flat and two-level collective schedules
//! per world shape (see [`crate::comm::cost`]).
//!
//! Idle-leader polling: a node leader parked on inter-node traffic (an
//! idle hierarchy — nothing wrong, just nothing to do yet) must not trip
//! the mailbox deadlock oracle, whose 60 s bound is calibrated for
//! same-node waits.  Inter-node receives therefore use the serve-style
//! probe+sleep pattern: poll for the envelope, sleep briefly, and fall
//! through to the blocking `take` — with its prompt poison/close
//! diagnostics — only once the envelope (or a failure) has arrived.
//!
//! [`HierCost`]: crate::comm::cost::HierCost

use std::sync::Arc;
use std::time::Duration;

use super::tcp::TcpTransport;
use super::{Envelope, Transport};
use crate::comm::fabric::Fabric;

/// Launch-time override for the node shape: ranks per node, read by
/// `Runtime::build` when neither the builder nor the machine config set
/// one.  The multi-process launcher forwards it to re-exec'd workers so
/// every process of a run derives the same topology.
pub const ENV_RANKS_PER_NODE: &str = "FOOPAR_RANKS_PER_NODE";

/// How often an inter-node receive polls for its envelope.  Short enough
/// that collective rounds stay sub-millisecond, long enough that an idle
/// leader costs a few thousand mutex probes per second, not a core.
const POLL_INTERVAL: Duration = Duration::from_micros(200);

/// The node structure of a world: which node each rank lives on, where
/// each node starts, and who leads it (its first rank).
///
/// Consecutive world ranks fill nodes in order — node `n` of a uniform
/// topology covers ranks `[n·rpn, min((n+1)·rpn, world))` — so a node's
/// members are always a contiguous rank range, which is what lets the
/// two-level collectives split a group with
/// [`Group::partition`](crate::comm::group::Group::partition) while
/// preserving member order (and therefore fold order).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Topology {
    /// Node id per world rank (monotone non-decreasing).
    node_of: Vec<usize>,
    /// First world rank of each node.
    node_starts: Vec<usize>,
    node_sizes: Vec<usize>,
}

impl Topology {
    /// Build from explicit node sizes (all positive); world =
    /// `sizes.iter().sum()`.  This is the general form — uneven shapes
    /// like `[3, 5]` are first-class.
    pub fn from_node_sizes(sizes: &[usize]) -> Self {
        assert!(!sizes.is_empty(), "topology needs at least one node");
        assert!(sizes.iter().all(|&s| s > 0), "topology nodes must be non-empty");
        let mut node_of = Vec::with_capacity(sizes.iter().sum());
        let mut node_starts = Vec::with_capacity(sizes.len());
        let mut start = 0;
        for (n, &s) in sizes.iter().enumerate() {
            node_starts.push(start);
            node_of.extend(std::iter::repeat(n).take(s));
            start += s;
        }
        Topology { node_of, node_starts, node_sizes: sizes.to_vec() }
    }

    /// `world` ranks packed `ranks_per_node` to a node, the last node
    /// taking the remainder (so `uniform(8, 3)` is the uneven `3+3+2`).
    pub fn uniform(world: usize, ranks_per_node: usize) -> Self {
        assert!(world > 0, "topology needs at least one rank");
        let rpn = ranks_per_node.max(1);
        let sizes: Vec<usize> = (0..world)
            .step_by(rpn)
            .map(|start| rpn.min(world - start))
            .collect();
        Self::from_node_sizes(&sizes)
    }

    /// Everything on one node — the degenerate topology every flat
    /// transport runs under.
    pub fn flat(world: usize) -> Self {
        assert!(world > 0, "topology needs at least one rank");
        Self::from_node_sizes(&[world])
    }

    /// Total number of ranks.
    pub fn world(&self) -> usize {
        self.node_of.len()
    }

    pub fn num_nodes(&self) -> usize {
        self.node_sizes.len()
    }

    /// True for single-node topologies: no inter-node level exists, so
    /// hierarchical strategies and per-level pricing degenerate to flat.
    pub fn is_flat(&self) -> bool {
        self.num_nodes() == 1
    }

    /// Node id of `rank`.
    pub fn node_of(&self, rank: usize) -> usize {
        self.node_of[rank]
    }

    /// Rank's position within its node.
    pub fn local_rank(&self, rank: usize) -> usize {
        rank - self.node_starts[self.node_of[rank]]
    }

    /// World rank leading node `node` (its first rank).
    pub fn leader_of(&self, node: usize) -> usize {
        self.node_starts[node]
    }

    /// World rank leading `rank`'s node.
    pub fn leader(&self, rank: usize) -> usize {
        self.leader_of(self.node_of[rank])
    }

    pub fn is_leader(&self, rank: usize) -> bool {
        self.leader(rank) == rank
    }

    pub fn node_size(&self, node: usize) -> usize {
        self.node_sizes[node]
    }

    /// All node sizes, in node order.
    pub fn node_sizes(&self) -> &[usize] {
        &self.node_sizes
    }

    pub fn max_node_size(&self) -> usize {
        self.node_sizes.iter().copied().max().unwrap_or(1)
    }

    /// World ranks of node `node`, in order.
    pub fn node_ranks(&self, node: usize) -> std::ops::Range<usize> {
        let start = self.node_starts[node];
        start..start + self.node_sizes[node]
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of[a] == self.node_of[b]
    }
}

/// The hybrid transport: per-node [`Fabric`] mailboxes under an
/// inter-node [`TcpTransport`], routed per envelope by the [`Topology`]
/// (see module docs).
pub struct HierTransport {
    topo: Topology,
    /// Same-node envelopes: straight into the destination's mailbox.
    intra: Arc<Fabric>,
    /// Cross-node envelopes: encoded, through a real loopback socket,
    /// decoded by the destination's reader thread.
    inter: Arc<TcpTransport>,
}

impl HierTransport {
    /// Bind the inter-node listeners and build the fabric for `topo`.
    pub fn new(topo: Topology) -> std::io::Result<Arc<Self>> {
        let world = topo.world();
        Ok(Arc::new(HierTransport {
            intra: Fabric::new(world),
            inter: TcpTransport::loopback(world)?,
            topo,
        }))
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    fn leg(&self, a: usize, b: usize) -> &dyn Transport {
        if self.topo.same_node(a, b) {
            self.intra.as_ref()
        } else {
            self.inter.as_ref()
        }
    }
}

impl Transport for HierTransport {
    fn world(&self) -> usize {
        self.topo.world()
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }

    fn post(&self, dst: usize, env: Envelope) {
        self.leg(env.src, dst).post(dst, env);
    }

    fn take(&self, me: usize, src: usize, tag: u64) -> Envelope {
        if self.topo.same_node(me, src) {
            return self.intra.take(me, src, tag);
        }
        // Inter-node: probe+sleep instead of the blocking condvar wait,
        // so an idle leader never burns the deadlock oracle's timeout
        // (see module docs).  Falls through to the blocking take — and
        // its prompt, fully-diagnosed panic — the moment the envelope
        // arrives or the mailbox becomes unreceivable (poison/close).
        loop {
            if self.inter.probe(me, src, tag) || self.inter.unreceivable(me) {
                return self.inter.take(me, src, tag);
            }
            std::thread::sleep(POLL_INTERVAL);
        }
    }

    fn probe(&self, me: usize, src: usize, tag: u64) -> bool {
        self.leg(me, src).probe(me, src, tag)
    }

    fn pending(&self, me: usize) -> usize {
        self.intra.pending(me) + self.inter.pending(me)
    }

    fn close(&self, me: usize) {
        self.intra.close(me);
        self.inter.close(me);
    }

    fn fail(&self, reason: &str) {
        self.intra.fail(reason);
        self.inter.fail(reason);
    }

    fn fail_ranks(&self, ranks: &[usize], reason: &str) {
        self.intra.fail_ranks(ranks, reason);
        self.inter.fail_ranks(ranks, reason);
    }

    fn clear_fail(&self, me: usize) {
        self.intra.clear_fail(me);
        self.inter.clear_fail(me);
    }
}

#[cfg(test)]
mod tests {
    use std::time::{Duration, Instant};

    use super::*;
    use crate::comm::message::Msg;

    fn env(src: usize, tag: u64, val: u64) -> Envelope {
        Envelope { src, tag, bytes: 8, ready: 0.0, payload: Msg::new(val) }
    }

    #[test]
    fn topology_uniform_uneven_and_flat() {
        let t = Topology::uniform(8, 3); // 3 + 3 + 2
        assert_eq!(t.world(), 8);
        assert_eq!(t.num_nodes(), 3);
        assert_eq!(t.node_sizes(), &[3, 3, 2]);
        assert!(!t.is_flat());
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(2), 0);
        assert_eq!(t.node_of(3), 1);
        assert_eq!(t.node_of(7), 2);
        assert_eq!(t.local_rank(4), 1);
        assert_eq!(t.leader(4), 3);
        assert_eq!(t.leader_of(2), 6);
        assert!(t.is_leader(0) && t.is_leader(3) && t.is_leader(6));
        assert!(!t.is_leader(1) && !t.is_leader(7));
        assert!(t.same_node(0, 2) && !t.same_node(2, 3));
        assert_eq!(t.node_ranks(1).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert_eq!(t.max_node_size(), 3);

        let explicit = Topology::from_node_sizes(&[3, 5]);
        assert_eq!(explicit.world(), 8);
        assert_eq!(explicit.leader(5), 3);

        let flat = Topology::flat(4);
        assert!(flat.is_flat());
        assert!(flat.same_node(0, 3));
        // ranks_per_node >= world collapses to one node
        assert!(Topology::uniform(4, 16).is_flat());
    }

    #[test]
    fn routes_same_node_through_fabric_and_cross_node_through_tcp() {
        let t = HierTransport::new(Topology::uniform(4, 2)).expect("bind hybrid");
        // 0 → 1 shares node 0: delivered synchronously by the fabric.
        t.post(1, env(0, 7, 11));
        assert_eq!(t.intra.pending(1), 1, "same-node envelope must use the fabric");
        assert_eq!(t.inter.pending(1), 0);
        assert_eq!(t.take(1, 0, 7).payload.downcast::<u64>(), 11);
        // 0 → 2 crosses nodes: arrives via a tcp reader thread.
        t.post(2, env(0, 9, 22));
        let got = t.take(2, 0, 9);
        assert_eq!(got.payload.downcast::<u64>(), 22);
        assert_eq!(t.intra.pending(2), 0, "cross-node envelope must use tcp");
        for r in 0..4 {
            t.close(r);
        }
    }

    #[test]
    fn ready_and_bytes_cross_both_legs_unmodified() {
        let t = HierTransport::new(Topology::uniform(4, 2)).expect("bind hybrid");
        t.post(1, Envelope { src: 0, tag: 1, bytes: 99, ready: 2.5, payload: Msg::new(1u64) });
        t.post(2, Envelope { src: 0, tag: 2, bytes: 77, ready: 4.5, payload: Msg::new(2u64) });
        let a = t.take(1, 0, 1);
        assert_eq!((a.bytes, a.ready), (99, 2.5));
        let b = t.take(2, 0, 2);
        assert_eq!((b.bytes, b.ready), (77, 4.5));
        for r in 0..4 {
            t.close(r);
        }
    }

    /// Satellite regression: an inter-node receive is a poll loop, so a
    /// leader idling on traffic that arrives "late" (here: delayed past
    /// several poll intervals; in a serving hierarchy: minutes) is just
    /// patience — the wait completes when the envelope lands instead of
    /// racing the mailbox deadlock oracle's fixed budget.
    #[test]
    fn idle_inter_node_wait_survives_delayed_delivery() {
        let t = HierTransport::new(Topology::uniform(4, 2)).expect("bind hybrid");
        let t2 = t.clone();
        let waiter = std::thread::spawn(move || t2.take(2, 0, 0x1D7E).payload.downcast::<u64>());
        std::thread::sleep(Duration::from_millis(150));
        t.post(2, env(0, 0x1D7E, 99));
        assert_eq!(waiter.join().unwrap(), 99);
        for r in 0..4 {
            t.close(r);
        }
    }

    /// The poll loop must not out-wait a real failure: poison lands the
    /// blocked inter-node take on the mailbox's diagnostic panic
    /// promptly, not after a timeout (and never spins forever).
    #[test]
    fn poison_wakes_idle_inter_node_wait_promptly() {
        let t = HierTransport::new(Topology::uniform(4, 2)).expect("bind hybrid");
        let t2 = t.clone();
        let t0 = Instant::now();
        let waiter = std::thread::spawn(move || {
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t2.take(2, 0, 0xDEAD)))
        });
        std::thread::sleep(Duration::from_millis(50));
        t.fail("rank 0 died mid-run: boom");
        let err = waiter.join().unwrap().unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(20), "poison was not prompt");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "<non-string panic>".into());
        assert!(msg.contains("boom"), "{msg}");
        assert!(msg.contains("src=0"), "{msg}");
    }

    #[test]
    fn close_closes_both_legs() {
        // Single-node topology so the post routes intra: the fabric's
        // closed-mailbox panic is synchronous in the poster (the tcp
        // leg detects closed mailboxes at its reader thread instead).
        let t = HierTransport::new(Topology::uniform(2, 2)).expect("bind hybrid");
        t.close(0);
        t.close(1);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| t.post(1, env(0, 1, 1))));
        assert!(r.is_err(), "posting to a closed hybrid rank must panic");
    }
}
