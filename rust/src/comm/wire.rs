//! The wire codec: [`WireData`] encode/decode for everything that may
//! cross a process boundary.
//!
//! FooPar serializes collection elements with user-defined serializers,
//! falling back to Java byte serialization (§3.1).  Our equivalent is
//! explicit: a type is sendable iff it implements [`WireData`] — a
//! little-endian binary codec on top of [`Data`]'s byte-size accounting.
//! The in-process [`Fabric`](crate::comm::fabric::Fabric) never calls it
//! (payloads move by ownership); [`TcpTransport`]
//! (crate::comm::transport::tcp) encodes every envelope payload with it
//! and the receiver decodes lazily at the `downcast` site, so the codec
//! cost is paid exactly once per wire hop.
//!
//! Format conventions (all integers little-endian):
//!
//! * fixed-width numbers as their `to_le_bytes`; `usize`/`isize` always
//!   as 8 bytes (cross-arch stable);
//! * `Vec<T>` / `String` as a `u64` length followed by the elements;
//! * `Option<T>` as a presence byte followed by the value;
//! * enums ([`Block`], [`Seg`]) as a variant byte followed by fields;
//! * [`Msg`](crate::comm::message::Msg) as a self-describing header
//!   (type fingerprint, modeled size, payload length) + payload — this
//!   is what lets erased bundles like the recursive-doubling all-gather's
//!   `Vec<(u64, Msg)>` nest across the wire.
//!
//! Decoding is bounds-checked ([`WireReader`] never panics on truncated
//! input — it returns [`WireError`]); *type* safety across the wire is
//! enforced by the [`type_fingerprint`] carried in every `Msg` header,
//! which `downcast` checks before decoding.

use crate::data::value::Data;
use crate::matrix::block::Block;
use crate::matrix::dense::Mat;
use crate::runtime::compute::Seg;

/// Decode failure: the bytes do not describe a value of the requested
/// type.  Always a framework/protocol bug (SPMD symmetry pins the type
/// of every message), so callers surface it loudly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Fewer bytes than the value needs.
    Truncated { need: usize, have: usize },
    /// Structurally invalid (bad variant byte, invalid UTF-8, …).
    Malformed(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated wire data: need {need} bytes, have {have}")
            }
            WireError::Malformed(what) => write!(f, "malformed wire data: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

/// Bounds-checked cursor over received bytes.
pub struct WireReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> WireReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        WireReader { buf, pos: 0 }
    }

    /// Bytes not yet consumed.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Consume the next `n` bytes.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Truncated { need: n, have: self.remaining() });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take(1)?[0])
    }

    pub fn u64(&mut self) -> Result<u64, WireError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// A `u64` that must fit a `usize` (lengths, counts).
    pub fn len(&mut self) -> Result<usize, WireError> {
        self.u64()?
            .try_into()
            .map_err(|_| WireError::Malformed("length exceeds usize"))
    }
}

/// A [`Data`] value with a binary wire format — the bound on everything
/// that travels through [`Group`](crate::comm::group::Group) collectives
/// and [`Ctx`](crate::spmd::Ctx) point-to-point sends.
///
/// Implementations must round-trip: `decode(encode(v)) == v`, and the
/// encoding must be a pure function of the value (the transport-parity
/// tests assert bit-identical collective results across transports).
pub trait WireData: Data + Sized {
    /// Append this value's encoding to `out`.
    fn encode(&self, out: &mut Vec<u8>);

    /// Decode one value, consuming exactly its encoding from `r`.
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError>;

    /// Bulk hook: encode a slice of values.  Element-wise by default;
    /// fixed-width primitives override it (one `reserve`, contiguous
    /// writes) so `Vec<f32>` / `Mat` payloads — the dominant wire
    /// traffic — avoid per-element reallocation checks.
    fn encode_slice(items: &[Self], out: &mut Vec<u8>) {
        for v in items {
            v.encode(out);
        }
    }

    /// Bulk hook: decode `n` values.  Element-wise by default;
    /// fixed-width primitives override it with a single bounds check
    /// over the whole run instead of one per element.
    fn decode_many(n: usize, r: &mut WireReader<'_>) -> Result<Vec<Self>, WireError> {
        // cap the pre-allocation: a corrupt length must not OOM before
        // the element decode fails
        let mut out = Vec::with_capacity(n.min(r.remaining().max(1)));
        for _ in 0..n {
            out.push(Self::decode(r)?);
        }
        Ok(out)
    }
}

/// Fingerprint of a type — carried in every
/// [`Msg`](crate::comm::message::Msg) wire header so a cross-process
/// `downcast` to the wrong type fails loudly instead of misdecoding.
/// Derived from [`std::any::TypeId`] (hashed with the deterministic,
/// unkeyed [`DefaultHasher`](std::collections::hash_map::DefaultHasher)
/// rather than walking the type-name string — this runs on every
/// `Msg` construction and downcast, including the shmem hot path).
/// Stable within one binary (multi-process runs re-exec the same
/// executable), which is the only place it is compared.
pub fn type_fingerprint<T: 'static>() -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    std::any::TypeId::of::<T>().hash(&mut h);
    h.finish()
}

// --------------------------------------------------------------- scalars

macro_rules! impl_wire_num {
    ($($t:ty),*) => {$(
        impl WireData for $t {
            fn encode(&self, out: &mut Vec<u8>) {
                out.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
                Ok(<$t>::from_le_bytes(
                    r.take(std::mem::size_of::<$t>())?.try_into().unwrap(),
                ))
            }
            fn encode_slice(items: &[Self], out: &mut Vec<u8>) {
                out.reserve(std::mem::size_of_val(items));
                for v in items {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
            fn decode_many(n: usize, r: &mut WireReader<'_>) -> Result<Vec<Self>, WireError> {
                const W: usize = std::mem::size_of::<$t>();
                let nb = n
                    .checked_mul(W)
                    .ok_or(WireError::Malformed("element count overflow"))?;
                let bytes = r.take(nb)?;
                Ok(bytes
                    .chunks_exact(W)
                    .map(|c| <$t>::from_le_bytes(c.try_into().unwrap()))
                    .collect())
            }
        }
    )*};
}

impl_wire_num!(u8, i8, u16, i16, u32, i32, u64, i64, f32, f64);

impl WireData for usize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u64).encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        r.len()
    }
}

impl WireData for isize {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as i64).encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        i64::decode(r)?
            .try_into()
            .map_err(|_| WireError::Malformed("isize out of range"))
    }
}

impl WireData for bool {
    fn encode(&self, out: &mut Vec<u8>) {
        out.push(*self as u8);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(WireError::Malformed("bool byte not 0/1")),
        }
    }
}

impl WireData for char {
    fn encode(&self, out: &mut Vec<u8>) {
        (*self as u32).encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        char::from_u32(u32::decode(r)?).ok_or(WireError::Malformed("invalid char scalar"))
    }
}

impl WireData for () {
    fn encode(&self, _out: &mut Vec<u8>) {}
    fn decode(_r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok(())
    }
}

// ------------------------------------------------------------ containers

impl WireData for String {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        out.extend_from_slice(self.as_bytes());
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let n = r.len()?;
        let bytes = r.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Malformed("invalid UTF-8"))
    }
}

// Routed through the bulk hooks, so `Vec<f32>`/`Vec<u8>` payloads get
// the primitives' contiguous fast path while nested element types fall
// back to element-wise encode/decode.
impl<T: WireData> WireData for Vec<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.len() as u64).encode(out);
        T::encode_slice(self, out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let n = r.len()?;
        T::decode_many(n, r)
    }
}

impl<T: WireData> WireData for Option<T> {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            None => out.push(0),
            Some(v) => {
                out.push(1);
                v.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            _ => Err(WireError::Malformed("Option tag not 0/1")),
        }
    }
}

impl<A: WireData, B: WireData> WireData for (A, B) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok((A::decode(r)?, B::decode(r)?))
    }
}

impl<A: WireData, B: WireData, C: WireData> WireData for (A, B, C) {
    fn encode(&self, out: &mut Vec<u8>) {
        self.0.encode(out);
        self.1.encode(out);
        self.2.encode(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Ok((A::decode(r)?, B::decode(r)?, C::decode(r)?))
    }
}

// ---------------------------------------------------------- matrix types

impl WireData for Mat {
    fn encode(&self, out: &mut Vec<u8>) {
        (self.rows as u64).encode(out);
        (self.cols as u64).encode(out);
        f32::encode_slice(&self.data, out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        let rows = r.len()?;
        let cols = r.len()?;
        let n = rows
            .checked_mul(cols)
            .ok_or(WireError::Malformed("matrix dims overflow"))?;
        let data = f32::decode_many(n, r)?;
        Ok(Mat { rows, cols, data: data.into() })
    }
}

impl WireData for Block {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            Block::Real(m) => {
                out.push(0);
                m.encode(out);
            }
            Block::Proxy { rows, cols, seed } => {
                out.push(1);
                rows.encode(out);
                cols.encode(out);
                seed.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            0 => Ok(Block::Real(Mat::decode(r)?)),
            1 => Ok(Block::Proxy {
                rows: usize::decode(r)?,
                cols: usize::decode(r)?,
                seed: u64::decode(r)?,
            }),
            _ => Err(WireError::Malformed("Block variant byte")),
        }
    }
}

impl WireData for Seg {
    fn encode(&self, out: &mut Vec<u8>) {
        match self {
            // Real segments live in a shared CoW `Buf` (in-process they
            // move by reference); on the wire they are a plain length-
            // prefixed f32 run, same as a `Vec<f32>`.
            Seg::Real(v) => {
                out.push(0);
                (v.len() as u64).encode(out);
                f32::encode_slice(v.as_slice(), out);
            }
            Seg::Proxy { len } => {
                out.push(1);
                len.encode(out);
            }
        }
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        match r.u8()? {
            0 => Ok(Seg::real(Vec::<f32>::decode(r)?)),
            1 => Ok(Seg::Proxy { len: usize::decode(r)? }),
            _ => Err(WireError::Malformed("Seg variant byte")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: WireData + PartialEq + std::fmt::Debug>(v: T) {
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let mut r = WireReader::new(&buf);
        let back = T::decode(&mut r).expect("decode");
        assert_eq!(back, v);
        assert_eq!(r.remaining(), 0, "decode must consume exactly the encoding");
    }

    #[test]
    fn scalars_roundtrip() {
        roundtrip(0u8);
        roundtrip(-7i8);
        roundtrip(0xBEEFu16);
        roundtrip(-1234i16);
        roundtrip(0xDEAD_BEEFu32);
        roundtrip(i32::MIN);
        roundtrip(u64::MAX);
        roundtrip(i64::MIN);
        roundtrip(usize::MAX);
        roundtrip(-42isize);
        roundtrip(3.14f32);
        roundtrip(f64::NEG_INFINITY);
        roundtrip(true);
        roundtrip('λ');
        roundtrip(());
    }

    #[test]
    fn f32_bit_exact() {
        // bit-exactness matters for the transport-parity claim
        let v = f32::from_bits(0x7F80_0001); // a signaling NaN payload
        let mut buf = Vec::new();
        v.encode(&mut buf);
        let back = f32::decode(&mut WireReader::new(&buf)).unwrap();
        assert_eq!(back.to_bits(), v.to_bits());
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(String::from("héllo wörld"));
        roundtrip(vec![1u64, 2, 3]);
        roundtrip(Vec::<f64>::new());
        roundtrip(vec![vec![1.5f64], vec![], vec![2.5, 3.5]]);
        roundtrip(Some(9u32));
        roundtrip(None::<String>);
        roundtrip((1u64, -2i64));
        roundtrip((1usize, 2usize, String::from("c")));
    }

    #[test]
    fn matrix_types_roundtrip() {
        roundtrip(Mat::random(5, 3, 42));
        roundtrip(Block::Real(Mat::random(4, 4, 7)));
        roundtrip(Block::Proxy { rows: 64, cols: 32, seed: 0xAB });
        roundtrip(Seg::real(vec![1.0, -2.0, 3.5]));
        roundtrip(Seg::Proxy { len: 100 });
    }

    #[test]
    fn truncated_input_errors_not_panics() {
        let mut buf = Vec::new();
        vec![1.0f64; 4].encode(&mut buf);
        for cut in 0..buf.len() {
            let res = Vec::<f64>::decode(&mut WireReader::new(&buf[..cut]));
            assert!(res.is_err(), "cut at {cut} must fail");
        }
    }

    #[test]
    fn malformed_variants_error() {
        assert_eq!(
            bool::decode(&mut WireReader::new(&[2])),
            Err(WireError::Malformed("bool byte not 0/1"))
        );
        assert!(Block::decode(&mut WireReader::new(&[9])).is_err());
        let mut bad_str = Vec::new();
        (2u64).encode(&mut bad_str);
        bad_str.extend_from_slice(&[0xFF, 0xFE]);
        assert!(String::decode(&mut WireReader::new(&bad_str)).is_err());
    }

    #[test]
    fn fingerprints_distinguish_types() {
        assert_ne!(type_fingerprint::<u64>(), type_fingerprint::<i64>());
        assert_ne!(type_fingerprint::<Vec<f32>>(), type_fingerprint::<Vec<f64>>());
        assert_eq!(type_fingerprint::<String>(), type_fingerprint::<String>());
    }
}
