//! Type-erased message payloads — the interchange type of the pluggable
//! [`Collectives`](crate::comm::collectives::Collectives) layer.
//!
//! Rust trait objects cannot have generic methods, but collective
//! operations are generic over the element type `T: Data`.  [`Msg`]
//! bridges the two: a `Msg` owns an erased value together with its
//! modeled wire size (so the virtual-time cost model keeps working
//! end-to-end) and, when the original type was `Clone`, a cloning thunk
//! (so tree/ring algorithms can fan a value out to several peers).
//!
//! The generic user-facing entry points on
//! [`Group`](crate::comm::group::Group) wrap values into `Msg`s, dispatch
//! through the active backend's `dyn Collectives`, and downcast the
//! results — user code never sees a `Msg` unless it implements a custom
//! collectives strategy.

use std::any::Any;

use crate::data::value::Data;

/// An erased value travelling through a collective: payload + modeled
/// wire size + (optionally) a cloning thunk.
pub struct Msg {
    payload: Box<dyn Any + Send>,
    bytes: usize,
    clone_fn: Option<fn(&(dyn Any + Send)) -> Box<dyn Any + Send>>,
}

fn clone_box<T: Data + Clone>(any: &(dyn Any + Send)) -> Box<dyn Any + Send> {
    let v = any
        .downcast_ref::<T>()
        .expect("cloneable Msg payload type drifted");
    Box::new(v.clone())
}

impl Msg {
    /// Erase a value.  The resulting message is *not* duplicable — fine
    /// for point-to-point hops and fold-style collectives (reduce,
    /// gather, alltoall, shift), which never copy payloads.
    pub fn new<T: Data>(value: T) -> Self {
        let bytes = value.byte_size();
        Msg { payload: Box::new(value), bytes, clone_fn: None }
    }

    /// Erase a cloneable value.  Required by fan-out collectives (bcast,
    /// allgather, scan), whose algorithms send the same value to several
    /// peers.
    pub fn cloneable<T: Data + Clone>(value: T) -> Self {
        let bytes = value.byte_size();
        Msg { payload: Box::new(value), bytes, clone_fn: Some(clone_box::<T>) }
    }

    /// Modeled wire size in bytes (drives the `t_w·m` cost term).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Can this message be duplicated?
    pub fn is_cloneable(&self) -> bool {
        self.clone_fn.is_some()
    }

    /// Duplicate the payload.  Panics for messages built with
    /// [`Msg::new`] — collective algorithms that fan out values must be
    /// fed via [`Msg::cloneable`] (the `Group` entry points enforce this
    /// with `T: Clone` bounds).
    pub fn dup(&self) -> Msg {
        let f = self
            .clone_fn
            .expect("collective algorithm needs a cloneable value (wrap with Msg::cloneable)");
        Msg { payload: f(self.payload.as_ref()), bytes: self.bytes, clone_fn: self.clone_fn }
    }

    /// Recover the value, or give the message back on type mismatch.
    pub fn try_downcast<T: Data>(self) -> Result<T, Msg> {
        let Msg { payload, bytes, clone_fn } = self;
        match payload.downcast::<T>() {
            Ok(v) => Ok(*v),
            Err(payload) => Err(Msg { payload, bytes, clone_fn }),
        }
    }

    /// Recover the value; panics with the expected type name on
    /// mismatch.  Used by the `Group` wrappers, where the type is pinned
    /// by construction.
    pub fn downcast<T: Data>(self) -> T {
        self.try_downcast::<T>().unwrap_or_else(|_| {
            panic!(
                "Msg payload type mismatch (expected {})",
                std::any::type_name::<T>()
            )
        })
    }
}

/// `Msg` is itself `Data`, so erased values can be bundled into larger
/// messages (e.g. the recursive-doubling all-gather ships a
/// `Vec<(u64, Msg)>` per round) with byte accounting identical to the
/// equivalent concrete `Vec<(u64, T)>`.
impl Data for Msg {
    fn byte_size(&self) -> usize {
        self.bytes
    }
}

impl std::fmt::Debug for Msg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Msg")
            .field("bytes", &self.bytes)
            .field("cloneable", &self.is_cloneable())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_value_and_bytes() {
        let m = Msg::new(vec![1.0f32; 10]);
        assert_eq!(m.bytes(), 8 + 40);
        assert_eq!(m.downcast::<Vec<f32>>(), vec![1.0f32; 10]);
    }

    #[test]
    fn cloneable_dup_is_deep() {
        let m = Msg::cloneable("hello".to_string());
        let d = m.dup();
        assert_eq!(d.bytes(), m.bytes());
        assert_eq!(m.downcast::<String>(), "hello");
        assert_eq!(d.downcast::<String>(), "hello");
    }

    #[test]
    #[should_panic(expected = "cloneable")]
    fn plain_msg_refuses_dup() {
        let _ = Msg::new(1u64).dup();
    }

    #[test]
    fn try_downcast_returns_msg_on_mismatch() {
        let m = Msg::new(1u64);
        let back = m.try_downcast::<String>().unwrap_err();
        assert_eq!(back.bytes(), 8);
        assert_eq!(back.downcast::<u64>(), 1);
    }

    #[test]
    fn bundle_bytes_match_concrete_vec() {
        // Vec<(u64, Msg)> must cost the same as Vec<(u64, T)>
        let items: Vec<(u64, Msg)> = (0..3).map(|i| (i, Msg::new(0.5f64))).collect();
        let concrete: Vec<(u64, f64)> = (0..3).map(|i| (i, 0.5f64)).collect();
        assert_eq!(Msg::new(items).bytes(), concrete.byte_size());
    }
}
