//! Type-erased message payloads — the interchange type of the pluggable
//! [`Collectives`](crate::comm::collectives::Collectives) layer.
//!
//! Rust trait objects cannot have generic methods, but collective
//! operations are generic over the element type `T: WireData`.  [`Msg`]
//! bridges the two: a `Msg` owns an erased value together with its
//! modeled wire size (so the virtual-time cost model keeps working
//! end-to-end), a monomorphized encoder (so the value can cross a
//! process boundary on wire transports), and, when the original type was
//! `Clone`, a cloning thunk (so tree/ring algorithms can fan a value out
//! to several peers).
//!
//! A `Msg` exists in one of two states:
//!
//! * **local** — the erased `Box<dyn Any>` as constructed by the sender;
//!   the only state the in-process fabric ever sees (ownership moves, no
//!   copy);
//! * **encoded** — raw bytes as produced by [`Msg::encode_into`] and
//!   reconstituted by a wire transport's reader thread.  Decoding back
//!   to the concrete type happens lazily at the [`Msg::downcast`] site,
//!   guarded by the [`type_fingerprint`] carried in the header.
//!
//! The generic user-facing entry points on
//! [`Group`](crate::comm::group::Group) wrap values into `Msg`s, dispatch
//! through the active backend's `dyn Collectives`, and downcast the
//! results — user code never sees a `Msg` unless it implements a custom
//! collectives strategy.

use std::any::Any;

use crate::comm::wire::{type_fingerprint, WireData, WireError, WireReader};
use crate::data::value::Data;

type CloneFn = fn(&(dyn Any + Send)) -> Box<dyn Any + Send>;
type EncodeFn = fn(&(dyn Any + Send), &mut Vec<u8>);

enum Payload {
    /// In-process: the erased value itself plus its monomorphized thunks.
    Local {
        value: Box<dyn Any + Send>,
        clone_fn: Option<CloneFn>,
        encode_fn: EncodeFn,
    },
    /// Arrived over a wire transport: the value's encoding, decoded
    /// lazily at the `downcast` site.
    Encoded(Vec<u8>),
}

/// An erased value travelling through a collective: payload + modeled
/// wire size + codec/cloning thunks.
pub struct Msg {
    payload: Payload,
    bytes: usize,
    /// Fingerprint of the erased type (wire-side `downcast` guard).
    type_fp: u64,
}

fn clone_box<T: WireData + Clone>(any: &(dyn Any + Send)) -> Box<dyn Any + Send> {
    let v = any
        .downcast_ref::<T>()
        .expect("cloneable Msg payload type drifted");
    Box::new(v.clone())
}

fn encode_box<T: WireData>(any: &(dyn Any + Send), out: &mut Vec<u8>) {
    any.downcast_ref::<T>()
        .expect("Msg payload type drifted")
        .encode(out)
}

impl Msg {
    /// Erase a value.  The resulting message is *not* duplicable — fine
    /// for point-to-point hops and fold-style collectives (reduce,
    /// gather, alltoall, shift), which never copy payloads.
    pub fn new<T: WireData>(value: T) -> Self {
        let bytes = value.byte_size();
        Msg {
            payload: Payload::Local {
                value: Box::new(value),
                clone_fn: None,
                encode_fn: encode_box::<T>,
            },
            bytes,
            type_fp: type_fingerprint::<T>(),
        }
    }

    /// Erase a cloneable value.  Required by fan-out collectives (bcast,
    /// allgather, scan), whose algorithms send the same value to several
    /// peers.
    pub fn cloneable<T: WireData + Clone>(value: T) -> Self {
        let bytes = value.byte_size();
        Msg {
            payload: Payload::Local {
                value: Box::new(value),
                clone_fn: Some(clone_box::<T>),
                encode_fn: encode_box::<T>,
            },
            bytes,
            type_fp: type_fingerprint::<T>(),
        }
    }

    /// Modeled wire size in bytes (drives the `t_w·m` cost term).
    #[inline]
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Can this message be duplicated?  Encoded messages always can
    /// (duplicating bytes needs no `Clone` on the original type).
    pub fn is_cloneable(&self) -> bool {
        match &self.payload {
            Payload::Local { clone_fn, .. } => clone_fn.is_some(),
            Payload::Encoded(_) => true,
        }
    }

    /// Did this message arrive over a wire transport (payload still in
    /// encoded form)?
    pub fn is_encoded(&self) -> bool {
        matches!(self.payload, Payload::Encoded(_))
    }

    /// Duplicate the payload.  Panics for local messages built with
    /// [`Msg::new`] — collective algorithms that fan out values must be
    /// fed via [`Msg::cloneable`] (the `Group` entry points enforce this
    /// with `T: Clone` bounds).
    pub fn dup(&self) -> Msg {
        let payload = match &self.payload {
            Payload::Local { value, clone_fn, encode_fn } => {
                let f = clone_fn.expect(
                    "collective algorithm needs a cloneable value (wrap with Msg::cloneable)",
                );
                Payload::Local {
                    value: f(value.as_ref()),
                    clone_fn: *clone_fn,
                    encode_fn: *encode_fn,
                }
            }
            Payload::Encoded(buf) => Payload::Encoded(buf.clone()),
        };
        Msg { payload, bytes: self.bytes, type_fp: self.type_fp }
    }

    /// Recover the value, or give the message back on type mismatch.
    /// Encoded payloads are decoded here (the one codec invocation per
    /// wire hop); a fingerprint mismatch returns the message untouched.
    pub fn try_downcast<T: WireData>(self) -> Result<T, Msg> {
        if self.type_fp != type_fingerprint::<T>() {
            return Err(self);
        }
        match self.payload {
            Payload::Local { value, clone_fn, encode_fn } => match value.downcast::<T>() {
                Ok(v) => Ok(*v),
                Err(value) => Err(Msg {
                    payload: Payload::Local { value, clone_fn, encode_fn },
                    bytes: self.bytes,
                    type_fp: self.type_fp,
                }),
            },
            Payload::Encoded(buf) => {
                let mut r = WireReader::new(&buf);
                let v = T::decode(&mut r).unwrap_or_else(|e| {
                    panic!(
                        "wire decode of {} failed: {e} ({} payload bytes)",
                        std::any::type_name::<T>(),
                        buf.len()
                    )
                });
                // a decode that reads fewer bytes than encode wrote is a
                // codec bug — surface it here, not as silent truncation
                assert_eq!(
                    r.remaining(),
                    0,
                    "wire decode of {} left {} of {} payload bytes unconsumed — \
                     encode/decode of this WireData impl disagree",
                    std::any::type_name::<T>(),
                    r.remaining(),
                    buf.len()
                );
                Ok(v)
            }
        }
    }

    /// Recover the value; panics with the expected type name on
    /// mismatch.  Used by the `Group` wrappers, where the type is pinned
    /// by construction.
    pub fn downcast<T: WireData>(self) -> T {
        self.try_downcast::<T>().unwrap_or_else(|_| {
            panic!(
                "Msg payload type mismatch (expected {})",
                std::any::type_name::<T>()
            )
        })
    }

    /// Append this message's wire form to `out`: type fingerprint,
    /// modeled size, payload length, payload encoding.  Called by wire
    /// transports for every outgoing envelope (and by the nested-`Msg`
    /// [`WireData`] impl for erased bundles).
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.type_fp.to_le_bytes());
        out.extend_from_slice(&(self.bytes as u64).to_le_bytes());
        let len_pos = out.len();
        out.extend_from_slice(&[0u8; 8]);
        match &self.payload {
            Payload::Local { value, encode_fn, .. } => encode_fn(value.as_ref(), out),
            Payload::Encoded(buf) => out.extend_from_slice(buf),
        }
        let plen = (out.len() - len_pos - 8) as u64;
        out[len_pos..len_pos + 8].copy_from_slice(&plen.to_le_bytes());
    }

    /// Read one wire-form message (the inverse of [`Msg::encode_into`]).
    /// The payload stays encoded until `downcast`.
    pub fn decode_from(r: &mut WireReader<'_>) -> Result<Msg, WireError> {
        let type_fp = r.u64()?;
        let bytes = r.len()?;
        let plen = r.len()?;
        let payload = r.take(plen)?.to_vec();
        Ok(Msg { payload: Payload::Encoded(payload), bytes, type_fp })
    }
}

/// `Msg` is itself `Data`, so erased values can be bundled into larger
/// messages (e.g. the recursive-doubling all-gather ships a
/// `Vec<(u64, Msg)>` per round) with byte accounting identical to the
/// equivalent concrete `Vec<(u64, T)>`.
impl Data for Msg {
    fn byte_size(&self) -> usize {
        self.bytes
    }
}

/// `Msg` is also `WireData`, so those bundles cross process boundaries:
/// the nested message's header travels inside the outer payload and the
/// inner value stays encoded until *its* `downcast`.
impl WireData for Msg {
    fn encode(&self, out: &mut Vec<u8>) {
        self.encode_into(out);
    }
    fn decode(r: &mut WireReader<'_>) -> Result<Self, WireError> {
        Msg::decode_from(r)
    }
}

impl std::fmt::Debug for Msg {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Msg")
            .field("bytes", &self.bytes)
            .field("cloneable", &self.is_cloneable())
            .field("encoded", &self.is_encoded())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_preserves_value_and_bytes() {
        let m = Msg::new(vec![1.0f32; 10]);
        assert_eq!(m.bytes(), 8 + 40);
        assert_eq!(m.downcast::<Vec<f32>>(), vec![1.0f32; 10]);
    }

    #[test]
    fn cloneable_dup_is_deep() {
        let m = Msg::cloneable("hello".to_string());
        let d = m.dup();
        assert_eq!(d.bytes(), m.bytes());
        assert_eq!(m.downcast::<String>(), "hello");
        assert_eq!(d.downcast::<String>(), "hello");
    }

    #[test]
    #[should_panic(expected = "cloneable")]
    fn plain_msg_refuses_dup() {
        let _ = Msg::new(1u64).dup();
    }

    #[test]
    fn try_downcast_returns_msg_on_mismatch() {
        let m = Msg::new(1u64);
        let back = m.try_downcast::<String>().unwrap_err();
        assert_eq!(back.bytes(), 8);
        assert_eq!(back.downcast::<u64>(), 1);
    }

    #[test]
    fn bundle_bytes_match_concrete_vec() {
        // Vec<(u64, Msg)> must cost the same as Vec<(u64, T)>
        let items: Vec<(u64, Msg)> = (0..3).map(|i| (i, Msg::new(0.5f64))).collect();
        let concrete: Vec<(u64, f64)> = (0..3).map(|i| (i, 0.5f64)).collect();
        assert_eq!(Msg::new(items).bytes(), concrete.byte_size());
    }

    // ------------------------------------------------------- wire form

    fn wire_hop(m: &Msg) -> Msg {
        let mut buf = Vec::new();
        m.encode_into(&mut buf);
        let mut r = WireReader::new(&buf);
        let back = Msg::decode_from(&mut r).expect("decode");
        assert_eq!(r.remaining(), 0);
        back
    }

    #[test]
    fn wire_roundtrip_preserves_value_bytes_and_type() {
        let m = Msg::new(vec![1.5f64, -2.5, 3.5]);
        let bytes = m.bytes();
        let back = wire_hop(&m);
        assert!(back.is_encoded());
        assert_eq!(back.bytes(), bytes);
        assert_eq!(back.downcast::<Vec<f64>>(), vec![1.5, -2.5, 3.5]);
    }

    #[test]
    fn wire_downcast_to_wrong_type_is_rejected() {
        let back = wire_hop(&Msg::new(7u64));
        // fingerprint guard: no misdecode, the message comes back
        let err = back.try_downcast::<f64>().unwrap_err();
        assert_eq!(err.downcast::<u64>(), 7);
    }

    #[test]
    fn encoded_msg_is_always_cloneable() {
        // Msg::new gives no clone thunk, but the encoded form dups freely
        let back = wire_hop(&Msg::new(String::from("x")));
        assert!(back.is_cloneable());
        assert_eq!(back.dup().downcast::<String>(), "x");
        assert_eq!(back.downcast::<String>(), "x");
    }

    #[test]
    fn double_hop_reencodes_without_decoding() {
        // forwarders (e.g. bcast interior nodes) re-encode the raw bytes
        let m = Msg::cloneable(vec![9u64, 8, 7]);
        let once = wire_hop(&m);
        let twice = wire_hop(&once);
        assert_eq!(twice.bytes(), m.bytes());
        assert_eq!(twice.downcast::<Vec<u64>>(), vec![9, 8, 7]);
    }

    #[test]
    fn nested_bundles_cross_the_wire() {
        // the recursive-doubling all-gather's round payload
        let bundle: Vec<(u64, Msg)> =
            vec![(0, Msg::new(10i64)), (3, Msg::new(30i64))];
        let back = wire_hop(&Msg::new(bundle)).downcast::<Vec<(u64, Msg)>>();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, 0);
        assert_eq!(back[1].0, 3);
        assert_eq!(back[0].1.dup().downcast::<i64>(), 10);
        assert_eq!(back[1].1.dup().downcast::<i64>(), 30);
    }
}
