//! Communication cost model (Hockney / LogGP flavour).
//!
//! The paper (§2) models the cost of passing an `m`-word message as
//! `t_c = t_s + t_w · m` where `t_s` is the start-up time and `t_w` the
//! per-word transfer time.  We keep the same two-parameter model but in
//! *bytes* and *seconds*: every message that crosses the fabric advances
//! virtual clocks by `ts + tw_byte · bytes`.
//!
//! These parameters are per-machine (interconnect) and per-backend
//! (software stack overhead multipliers) — see [`crate::comm::backend`]
//! and [`crate::config`].
//!
//! **Overlap rule.**  Blocking operations advance a rank's clock
//! serially.  A non-blocking group operation ([`crate::comm::nb`]) runs
//! its message rounds on a *forked* clock instead; the handle's `wait()`
//! merges `clock = max(main, fork)`, so across an overlap region a rank
//! pays `max(T_comm, T_comp)` rather than the sum — the cost-model
//! expression of communication–computation overlap.

/// Cost parameters of one (machine, backend) combination.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Message start-up latency `t_s` in seconds.
    pub ts: f64,
    /// Per-byte transfer time `t_w` in seconds (1/bandwidth).
    pub tw: f64,
}

impl CostParams {
    pub const fn new(ts: f64, tw: f64) -> Self {
        CostParams { ts, tw }
    }

    /// Cost in seconds of one point-to-point message of `bytes` bytes.
    #[inline]
    pub fn msg(&self, bytes: usize) -> f64 {
        self.ts + self.tw * bytes as f64
    }

    /// 4X QDR InfiniBand (Carver): 32 Gb/s point-to-point → 4 GB/s,
    /// `tw = 0.25 ns/B`; MPI start-up ≈ 2 µs.
    pub const fn qdr_infiniband() -> Self {
        CostParams::new(2.0e-6, 2.5e-10)
    }

    /// In-process shared memory: memcpy-speed transfer, negligible latency.
    pub const fn shared_memory() -> Self {
        CostParams::new(2.0e-7, 1.0e-10)
    }

    /// A zero-cost network, useful for isolating compute in tests.
    pub const fn free() -> Self {
        CostParams::new(0.0, 0.0)
    }
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams::qdr_infiniband()
    }
}

/// Rounds of a binomial tree (or dissemination schedule) over `n` ranks.
/// Crate-visible so the plan layer's dry-run pricer charges reductions
/// and broadcasts with the exact round count the collectives use.
pub(crate) fn ceil_log2(n: usize) -> usize {
    if n <= 1 {
        0
    } else {
        (usize::BITS - (n - 1).leading_zeros()) as usize
    }
}

/// Two-level link pricing for a hierarchical world: intra-node hops and
/// inter-node hops carry distinct `(ts, tw)` parameters.
///
/// A flat world prices both levels identically (so every pre-hierarchy
/// cost result is unchanged); a hybrid world prices same-node messages
/// at shared-memory speed and cross-node messages at the machine's
/// network parameters.  The closed-form `*_flat` / `*_two_level`
/// estimates below model each collective schedule's critical path so the
/// hierarchical strategy can choose flat vs two-level **per world
/// shape** — deterministically, from topology alone, so every rank of a
/// collective makes the same choice without communicating.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HierCost {
    /// Link parameters for same-node messages.
    pub intra: CostParams,
    /// Link parameters for cross-node messages.
    pub inter: CostParams,
}

impl HierCost {
    /// Nominal payload size (bytes) used when choosing a strategy.  The
    /// real payload is only known at the root of rooted collectives, so
    /// the choice must not depend on it — all ranks price the same
    /// representative message instead.
    pub const MODEL_BYTES: usize = 1024;

    pub const fn new(intra: CostParams, inter: CostParams) -> Self {
        HierCost { intra, inter }
    }

    /// Single-level world: both legs cost the same — the degenerate form
    /// every flat transport runs under (keeps pre-hierarchy clocks
    /// bit-identical).
    pub const fn flat(cost: CostParams) -> Self {
        HierCost::new(cost, cost)
    }

    /// Hybrid world: shared-memory links inside a node, the machine's
    /// network parameters between nodes.
    pub const fn hierarchical(inter: CostParams) -> Self {
        HierCost::new(CostParams::shared_memory(), inter)
    }

    /// Cost of one point-to-point message on the leg `same_node` selects.
    #[inline]
    pub fn msg(&self, same_node: bool, bytes: usize) -> f64 {
        if same_node {
            self.intra.msg(bytes)
        } else {
            self.inter.msg(bytes)
        }
    }

    // ---- modeled T_P of collective schedules (strategy chooser) ----
    //
    // Flat algorithms ignore the topology, so the model prices their
    // rounds pessimistically at inter-node cost: once a world spans
    // nodes, most hops of a binomial/ring schedule cross a boundary.

    /// Binomial bcast/reduce over `p` ranks, every round at network cost.
    pub fn tree_flat(&self, p: usize, bytes: usize) -> f64 {
        ceil_log2(p) as f64 * self.inter.msg(bytes)
    }

    /// Two-level bcast/reduce: binomial across `nodes` leaders at
    /// network cost, binomial within the largest node at shared-memory
    /// cost, plus one intra-node root↔leader hop.
    pub fn tree_two_level(&self, nodes: usize, max_node: usize, bytes: usize) -> f64 {
        ceil_log2(nodes) as f64 * self.inter.msg(bytes)
            + (ceil_log2(max_node) + 1) as f64 * self.intra.msg(bytes)
    }

    /// Flat ring allgather over `p` ranks: `p − 1` rounds of one block.
    pub fn allgather_flat(&self, p: usize, bytes: usize) -> f64 {
        p.saturating_sub(1) as f64 * self.inter.msg(bytes)
    }

    /// Two-level allgather: gather the node (`max_node − 1` intra sends
    /// of one block), ring over `nodes` leaders with whole-node bundles,
    /// then bcast the full `p`-block result back down the node tree.
    pub fn allgather_two_level(
        &self,
        p: usize,
        nodes: usize,
        max_node: usize,
        bytes: usize,
    ) -> f64 {
        max_node.saturating_sub(1) as f64 * self.intra.msg(bytes)
            + nodes.saturating_sub(1) as f64 * self.inter.msg(max_node * bytes)
            + ceil_log2(max_node) as f64 * self.intra.msg(p * bytes)
    }

    /// Flat dissemination barrier over `p` ranks: `⌈log2 p⌉` unit rounds.
    pub fn barrier_flat(&self, p: usize) -> f64 {
        ceil_log2(p) as f64 * self.inter.msg(0)
    }

    /// Two-level barrier: gather unit tokens inside the node,
    /// dissemination across leaders, bcast the release down.
    pub fn barrier_two_level(&self, nodes: usize, max_node: usize) -> f64 {
        max_node.saturating_sub(1) as f64 * self.intra.msg(0)
            + ceil_log2(nodes) as f64 * self.inter.msg(0)
            + ceil_log2(max_node) as f64 * self.intra.msg(0)
    }

    /// Should bcast/reduce over `p` ranks in `nodes` nodes (largest
    /// `max_node`) run the two-level schedule?
    pub fn prefer_two_level_tree(&self, p: usize, nodes: usize, max_node: usize) -> bool {
        nodes > 1
            && self.tree_two_level(nodes, max_node, Self::MODEL_BYTES)
                < self.tree_flat(p, Self::MODEL_BYTES)
    }

    /// Should allgather run the two-level schedule?
    pub fn prefer_two_level_allgather(&self, p: usize, nodes: usize, max_node: usize) -> bool {
        nodes > 1
            && self.allgather_two_level(p, nodes, max_node, Self::MODEL_BYTES)
                < self.allgather_flat(p, Self::MODEL_BYTES)
    }

    /// Should barrier run the two-level schedule?
    pub fn prefer_two_level_barrier(&self, p: usize, nodes: usize, max_node: usize) -> bool {
        nodes > 1 && self.barrier_two_level(nodes, max_node) < self.barrier_flat(p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_cost_is_affine() {
        let c = CostParams::new(1.0e-6, 1.0e-9);
        assert_eq!(c.msg(0), 1.0e-6);
        let one_k = c.msg(1000);
        let two_k = c.msg(2000);
        // slope is tw per byte
        assert!((two_k - one_k - 1.0e-6 * 1.0).abs() < 1e-12);
    }

    #[test]
    fn presets_ordered_by_speed() {
        let ib = CostParams::qdr_infiniband();
        let shm = CostParams::shared_memory();
        assert!(shm.ts < ib.ts);
        assert!(shm.tw <= ib.tw);
    }

    #[test]
    fn ceil_log2_rounds() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(8), 3);
        assert_eq!(ceil_log2(9), 4);
    }

    #[test]
    fn hierarchical_links_prefer_two_level_at_scale() {
        // 8 ranks on 2 nodes of 4 over a real network: replacing
        // network rounds with shared-memory rounds wins everywhere.
        let h = HierCost::hierarchical(CostParams::qdr_infiniband());
        assert!(h.prefer_two_level_tree(8, 2, 4));
        assert!(h.prefer_two_level_allgather(8, 2, 4));
        assert!(h.prefer_two_level_barrier(8, 2, 4));
        // Uneven 3+5 at world 8 still wins.
        assert!(h.prefer_two_level_tree(8, 2, 5));
        assert!(h.prefer_two_level_allgather(8, 2, 5));
    }

    #[test]
    fn flat_links_or_flat_shape_never_prefer_two_level() {
        // Both legs at the same cost: the extra leader hops only hurt.
        let f = HierCost::flat(CostParams::qdr_infiniband());
        assert!(!f.prefer_two_level_tree(8, 2, 4));
        assert!(!f.prefer_two_level_allgather(8, 2, 4));
        assert!(!f.prefer_two_level_barrier(8, 2, 4));
        // One rank per node (nodes == p): no intra level to exploit.
        let h = HierCost::hierarchical(CostParams::qdr_infiniband());
        assert!(!h.prefer_two_level_tree(8, 8, 1));
        // Single node: nothing to do at the inter level.
        assert!(!h.prefer_two_level_tree(8, 1, 8));
    }

    #[test]
    fn flat_hiercost_prices_both_legs_identically() {
        let c = CostParams::new(1.0e-6, 1.0e-9);
        let f = HierCost::flat(c);
        assert_eq!(f.msg(true, 4096), c.msg(4096));
        assert_eq!(f.msg(false, 4096), c.msg(4096));
        let h = HierCost::hierarchical(c);
        assert!(h.msg(true, 4096) < h.msg(false, 4096));
    }
}
