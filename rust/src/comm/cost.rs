//! Communication cost model (Hockney / LogGP flavour).
//!
//! The paper (§2) models the cost of passing an `m`-word message as
//! `t_c = t_s + t_w · m` where `t_s` is the start-up time and `t_w` the
//! per-word transfer time.  We keep the same two-parameter model but in
//! *bytes* and *seconds*: every message that crosses the fabric advances
//! virtual clocks by `ts + tw_byte · bytes`.
//!
//! These parameters are per-machine (interconnect) and per-backend
//! (software stack overhead multipliers) — see [`crate::comm::backend`]
//! and [`crate::config`].
//!
//! **Overlap rule.**  Blocking operations advance a rank's clock
//! serially.  A non-blocking group operation ([`crate::comm::nb`]) runs
//! its message rounds on a *forked* clock instead; the handle's `wait()`
//! merges `clock = max(main, fork)`, so across an overlap region a rank
//! pays `max(T_comm, T_comp)` rather than the sum — the cost-model
//! expression of communication–computation overlap.

/// Cost parameters of one (machine, backend) combination.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Message start-up latency `t_s` in seconds.
    pub ts: f64,
    /// Per-byte transfer time `t_w` in seconds (1/bandwidth).
    pub tw: f64,
}

impl CostParams {
    pub const fn new(ts: f64, tw: f64) -> Self {
        CostParams { ts, tw }
    }

    /// Cost in seconds of one point-to-point message of `bytes` bytes.
    #[inline]
    pub fn msg(&self, bytes: usize) -> f64 {
        self.ts + self.tw * bytes as f64
    }

    /// 4X QDR InfiniBand (Carver): 32 Gb/s point-to-point → 4 GB/s,
    /// `tw = 0.25 ns/B`; MPI start-up ≈ 2 µs.
    pub const fn qdr_infiniband() -> Self {
        CostParams::new(2.0e-6, 2.5e-10)
    }

    /// In-process shared memory: memcpy-speed transfer, negligible latency.
    pub const fn shared_memory() -> Self {
        CostParams::new(2.0e-7, 1.0e-10)
    }

    /// A zero-cost network, useful for isolating compute in tests.
    pub const fn free() -> Self {
        CostParams::new(0.0, 0.0)
    }
}

impl Default for CostParams {
    fn default() -> Self {
        CostParams::qdr_infiniband()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn msg_cost_is_affine() {
        let c = CostParams::new(1.0e-6, 1.0e-9);
        assert_eq!(c.msg(0), 1.0e-6);
        let one_k = c.msg(1000);
        let two_k = c.msg(2000);
        // slope is tw per byte
        assert!((two_k - one_k - 1.0e-6 * 1.0).abs() < 1e-12);
    }

    #[test]
    fn presets_ordered_by_speed() {
        let ib = CostParams::qdr_infiniband();
        let shm = CostParams::shared_memory();
        assert!(shm.ts < ib.ts);
        assert!(shm.tw <= ib.tw);
    }
}
