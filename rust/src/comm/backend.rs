//! Communication backends (the paper's FooPar-X configurations): the
//! [`Backend`] trait, the name-keyed [`registry`], and the built-in
//! [`BackendProfile`]s.
//!
//! §3 of the paper: a FooPar configuration is `FooPar-X-Y-Z` with X the
//! communication module — `{OpenMPI, MPJ-Express, FastMPJ, SharedMemory}`.
//! §6 shows the backends differ mainly in (a) which *algorithm* their
//! collectives use and (b) software overhead on top of the interconnect:
//!
//! * the OpenMPI java-binding nightly implements `MPI_Reduce` with a
//!   simplistic Θ(p) sequence of send/recvs (it does **not** call the
//!   native reduction); the authors patched it to a Θ(log p) tree — our
//!   [`BackendProfile::openmpi_fixed`] vs [`BackendProfile::openmpi_stock`];
//! * MPJ-Express also uses a Θ(p) reduction and adds java-serialization
//!   overhead — [`BackendProfile::mpj_express`];
//! * FastMPJ is closed source; measured between the two —
//!   [`BackendProfile::fastmpj`].
//!
//! A [`Backend`] supplies (a) a strategy object implementing
//! [`Collectives`] and (b) a shaping of the machine's base
//! [`CostParams`]; switching backends changes **no algorithm code**,
//! exactly the paper's portability claim.  Backends live in a global
//! name-keyed [`registry`]: the built-ins are pre-registered, and user
//! code can [`registry::register`] its own `Backend` implementation —
//! with custom algorithm choices, custom cost shaping, or an entirely
//! custom [`Collectives`] strategy — and select it by name through
//! [`Runtime::builder`](crate::spmd::Runtime::builder).

use std::sync::Arc;

use super::collectives::{Collectives, HierCollectives, StandardCollectives};
use super::cost::CostParams;

/// Which reduction algorithm a backend's `reduceD` uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Binomial tree: Θ(log p) rounds — native MPI behaviour.
    Binomial,
    /// Root receives p−1 messages sequentially: Θ(p) — the unpatched
    /// OpenMPI-java / MPJ-Express behaviour the paper calls out.
    Linear,
}

/// Broadcast algorithm (one-to-all).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastAlgo {
    /// Recursive doubling / binomial tree: Θ(log p).
    Binomial,
    /// Root sends p−1 messages: Θ(p).
    Linear,
}

/// All-gather algorithm (all-to-all broadcast).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllGatherAlgo {
    /// Ring: (p−1) rounds of (t_s + t_w·m) — Table 1's Θ((ts+tw m)(p−1)).
    Ring,
    /// Recursive doubling: Θ(ts log p + tw m (p−1)) on a hypercube.
    RecursiveDoubling,
}

/// A communication backend: collective strategy + cost shaping.
///
/// Implementations are registered by name in the [`registry`] and
/// selected via `Runtime::builder().backend("name")`.  The two methods
/// mirror the paper's observation that backends differ in *algorithms*
/// ([`Backend::collectives`]) and *software overhead*
/// ([`Backend::cost`]).
pub trait Backend: Send + Sync + 'static {
    /// Registry key (and display name) of this backend.
    fn name(&self) -> &str;

    /// The collective strategy object ranks dispatch through.  Called
    /// once per rank at SPMD launch.
    fn collectives(&self) -> Arc<dyn Collectives>;

    /// Shape the machine's base cost parameters (software start-up and
    /// serialization overhead).  Default: the interconnect cost as-is.
    fn cost(&self, machine: CostParams) -> CostParams {
        machine
    }

    /// The built-in profile behind this backend, if any.  Custom
    /// backends return `None` (the default); [`BackendProfile::by_name`]
    /// is implemented on top of this.
    fn profile(&self) -> Option<BackendProfile> {
        None
    }
}

/// A built-in backend: named algorithm selection + cost multipliers.
///
/// This is the declarative subset of [`Backend`] — enough to model every
/// backend of the paper's evaluation.  For anything it cannot express
/// (adaptive algorithm choice, topology-aware costs, a from-scratch
/// [`Collectives`]), implement [`Backend`] directly and register it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackendProfile {
    pub name: &'static str,
    pub reduce: ReduceAlgo,
    pub bcast: BcastAlgo,
    pub allgather: AllGatherAlgo,
    /// Multiplier on the machine's `t_s` (software start-up overhead,
    /// e.g. JVM/daemon dispatch).
    pub ts_factor: f64,
    /// Multiplier on the machine's `t_w` (e.g. serialization copies).
    pub tw_factor: f64,
}

impl BackendProfile {
    /// Effective cost parameters on a machine with base `machine` costs.
    pub fn cost(&self, machine: CostParams) -> CostParams {
        CostParams::new(machine.ts * self.ts_factor, machine.tw * self.tw_factor)
    }

    /// The strategy set this profile selects.
    pub fn strategies(&self) -> StandardCollectives {
        StandardCollectives {
            bcast: self.bcast,
            reduce: self.reduce,
            allgather: self.allgather,
        }
    }

    /// OpenMPI java bindings with the authors' Θ(log p) reduce patch —
    /// the backend used for all Carver results.
    pub const fn openmpi_fixed() -> Self {
        BackendProfile {
            name: "openmpi-fixed",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 1.0,
            tw_factor: 1.0,
        }
    }

    /// Unmodified OpenMPI java nightly: naive Θ(p) reduce.
    pub const fn openmpi_stock() -> Self {
        BackendProfile {
            name: "openmpi-stock",
            reduce: ReduceAlgo::Linear,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 1.0,
            tw_factor: 1.0,
        }
    }

    /// MPJ-Express: Θ(p) reduce + daemon-mode dispatch (start-up ~tens of
    /// µs) + java byte-serialization copies on the wire (§3.1's fallback
    /// serializer; §6 notes the "advantages of slower backends (like
    /// running in daemon mode)").
    pub const fn mpj_express() -> Self {
        BackendProfile {
            name: "mpj-express",
            reduce: ReduceAlgo::Linear,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 20.0,
            tw_factor: 4.0,
        }
    }

    /// FastMPJ: native-ish transport, tree collectives, some java overhead.
    pub const fn fastmpj() -> Self {
        BackendProfile {
            name: "fastmpj",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 2.0,
            tw_factor: 1.3,
        }
    }

    /// In-process shared memory (FooPar's SharedMemory module).
    pub const fn shmem() -> Self {
        BackendProfile {
            name: "shmem",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 0.1,
            tw_factor: 0.4,
        }
    }

    /// Look up a built-in profile by name through the [`registry`].
    /// Custom backends resolve too, but only if they expose a profile
    /// ([`Backend::profile`]); prefer [`registry::by_name`] otherwise.
    pub fn by_name(name: &str) -> Option<Self> {
        registry::by_name(name).and_then(|b| b.profile())
    }

    /// The built-in comparison profiles (Fig. 5 right sweeps these).
    pub fn all() -> Vec<Self> {
        vec![
            Self::openmpi_fixed(),
            Self::openmpi_stock(),
            Self::mpj_express(),
            Self::fastmpj(),
        ]
    }
}

impl Default for BackendProfile {
    fn default() -> Self {
        Self::openmpi_fixed()
    }
}

impl Backend for BackendProfile {
    fn name(&self) -> &str {
        self.name
    }

    fn collectives(&self) -> Arc<dyn Collectives> {
        Arc::new(self.strategies())
    }

    fn cost(&self, machine: CostParams) -> CostParams {
        // delegates to the inherent method (inherent impls win the
        // `BackendProfile::cost` path lookup)
        BackendProfile::cost(self, machine)
    }

    fn profile(&self) -> Option<BackendProfile> {
        Some(*self)
    }
}

/// The topology-aware built-in backend, registered as `"hier"`: flat
/// binomial/ring algorithms upgraded to two-level (leader-staged)
/// schedules on hierarchical worlds whenever the cost model prices them
/// cheaper (see [`HierCollectives`]).  On a flat world it behaves
/// exactly like the default `openmpi-fixed` strategy set, so it is safe
/// to select unconditionally; it has no declarative
/// [`BackendProfile`] — its algorithm choice is adaptive, the case the
/// profile subset explicitly cannot express.
#[derive(Clone, Copy, Debug, Default)]
pub struct HierBackend;

impl Backend for HierBackend {
    fn name(&self) -> &str {
        "hier"
    }

    fn collectives(&self) -> Arc<dyn Collectives> {
        Arc::new(HierCollectives::default())
    }
}

/// The global name-keyed backend registry.
///
/// The built-in backends (five declarative profiles plus the adaptive
/// [`HierBackend`]) are pre-registered on first use;
/// [`register`] adds (or replaces, by name) a user backend for the rest
/// of the process.  Lookup order is registration order, so sweeps like
/// Fig. 5's stay deterministic.
pub mod registry {
    use std::sync::{Mutex, OnceLock};

    use super::{Arc, Backend, BackendProfile, HierBackend};

    fn store() -> &'static Mutex<Vec<Arc<dyn Backend>>> {
        static STORE: OnceLock<Mutex<Vec<Arc<dyn Backend>>>> = OnceLock::new();
        STORE.get_or_init(|| {
            let builtins: Vec<Arc<dyn Backend>> = vec![
                Arc::new(BackendProfile::openmpi_fixed()),
                Arc::new(BackendProfile::openmpi_stock()),
                Arc::new(BackendProfile::mpj_express()),
                Arc::new(BackendProfile::fastmpj()),
                Arc::new(BackendProfile::shmem()),
                Arc::new(HierBackend),
            ];
            Mutex::new(builtins)
        })
    }

    /// Register a backend under its [`Backend::name`], replacing any
    /// previous backend of the same name (built-ins included).
    pub fn register(backend: Arc<dyn Backend>) {
        let mut s = store().lock().unwrap();
        let name = backend.name().to_string();
        s.retain(|b| b.name() != name);
        s.push(backend);
    }

    /// Look a backend up by name.
    pub fn by_name(name: &str) -> Option<Arc<dyn Backend>> {
        store().lock().unwrap().iter().find(|b| b.name() == name).cloned()
    }

    /// All registered backends, in registration order.
    pub fn all() -> Vec<Arc<dyn Backend>> {
        store().lock().unwrap().clone()
    }

    /// Registered backend names, in registration order.
    pub fn names() -> Vec<String> {
        store().lock().unwrap().iter().map(|b| b.name().to_string()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name_roundtrips() {
        for b in BackendProfile::all() {
            assert_eq!(BackendProfile::by_name(b.name).unwrap(), b);
        }
        assert!(BackendProfile::by_name("nope").is_none());
    }

    #[test]
    fn stock_is_linear_fixed_is_tree() {
        assert_eq!(BackendProfile::openmpi_stock().reduce, ReduceAlgo::Linear);
        assert_eq!(BackendProfile::openmpi_fixed().reduce, ReduceAlgo::Binomial);
    }

    #[test]
    fn cost_applies_factors() {
        let m = CostParams::new(1e-6, 1e-9);
        let c = BackendProfile::mpj_express().cost(m);
        assert!((c.ts - 20e-6).abs() < 1e-15);
        assert!((c.tw - 4e-9).abs() < 1e-15);
    }

    #[test]
    fn trait_cost_agrees_with_inherent_cost() {
        let m = CostParams::new(1e-6, 1e-9);
        for p in BackendProfile::all() {
            let b: &dyn Backend = &p;
            assert_eq!(b.cost(m), p.cost(m), "{}", p.name);
        }
    }

    #[test]
    fn registry_preloads_builtins() {
        for name in ["openmpi-fixed", "openmpi-stock", "mpj-express", "fastmpj", "shmem"] {
            let b = registry::by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(b.name(), name);
            assert!(b.profile().is_some());
        }
        assert!(registry::by_name("no-such-backend").is_none());
    }

    #[test]
    fn registry_preloads_hier_without_profile() {
        let b = registry::by_name("hier").expect("hier backend registered");
        assert_eq!(b.name(), "hier");
        // adaptive strategy: no declarative profile, cost passthrough
        assert!(b.profile().is_none());
        let m = CostParams::new(1e-6, 1e-9);
        assert_eq!(b.cost(m), m);
    }

    #[test]
    fn registry_register_replace_and_list() {
        struct Dummy;
        impl Backend for Dummy {
            fn name(&self) -> &str {
                "unit-test-dummy"
            }
            fn collectives(&self) -> Arc<dyn super::Collectives> {
                Arc::new(crate::comm::collectives::StandardCollectives::default())
            }
        }
        registry::register(Arc::new(Dummy));
        let got = registry::by_name("unit-test-dummy").unwrap();
        assert_eq!(got.name(), "unit-test-dummy");
        assert!(got.profile().is_none());
        assert!(registry::names().iter().any(|n| n == "unit-test-dummy"));
        // replacing by the same name keeps exactly one entry
        registry::register(Arc::new(Dummy));
        let count = registry::names().iter().filter(|n| *n == "unit-test-dummy").count();
        assert_eq!(count, 1);
    }

    #[test]
    fn profile_strategies_match_fields() {
        let p = BackendProfile::openmpi_stock();
        let s = p.strategies();
        assert_eq!(s.reduce, ReduceAlgo::Linear);
        assert_eq!(s.bcast, BcastAlgo::Binomial);
        assert_eq!(s.allgather, AllGatherAlgo::Ring);
    }
}
