//! Communication backend profiles (the paper's FooPar-X configurations).
//!
//! §3 of the paper: a FooPar configuration is `FooPar-X-Y-Z` with X the
//! communication module — `{OpenMPI, MPJ-Express, FastMPJ, SharedMemory}`.
//! §6 shows the backends differ mainly in (a) which *algorithm* their
//! collectives use and (b) software overhead on top of the interconnect:
//!
//! * the OpenMPI java-binding nightly implements `MPI_Reduce` with a
//!   simplistic Θ(p) sequence of send/recvs (it does **not** call the
//!   native reduction); the authors patched it to a Θ(log p) tree — our
//!   [`BackendProfile::openmpi_fixed`] vs [`BackendProfile::openmpi_stock`];
//! * MPJ-Express also uses a Θ(p) reduction and adds java-serialization
//!   overhead — [`BackendProfile::mpj_express`];
//! * FastMPJ is closed source; measured between the two —
//!   [`BackendProfile::fastmpj`].
//!
//! A profile selects collective algorithms and multiplies the machine's
//! base `CostParams`; switching backends changes **no algorithm code**,
//! exactly the paper's portability claim.

use super::cost::CostParams;

/// Which reduction algorithm a backend's `reduceD` uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReduceAlgo {
    /// Binomial tree: Θ(log p) rounds — native MPI behaviour.
    Binomial,
    /// Root receives p−1 messages sequentially: Θ(p) — the unpatched
    /// OpenMPI-java / MPJ-Express behaviour the paper calls out.
    Linear,
}

/// Broadcast algorithm (one-to-all).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BcastAlgo {
    /// Recursive doubling / binomial tree: Θ(log p).
    Binomial,
    /// Root sends p−1 messages: Θ(p).
    Linear,
}

/// All-gather algorithm (all-to-all broadcast).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AllGatherAlgo {
    /// Ring: (p−1) rounds of (t_s + t_w·m) — Table 1's Θ((ts+tw m)(p−1)).
    Ring,
    /// Recursive doubling: Θ(ts log p + tw m (p−1)) on a hypercube.
    RecursiveDoubling,
}

/// A communication backend: algorithm selection + cost multipliers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BackendProfile {
    pub name: &'static str,
    pub reduce: ReduceAlgo,
    pub bcast: BcastAlgo,
    pub allgather: AllGatherAlgo,
    /// Multiplier on the machine's `t_s` (software start-up overhead,
    /// e.g. JVM/daemon dispatch).
    pub ts_factor: f64,
    /// Multiplier on the machine's `t_w` (e.g. serialization copies).
    pub tw_factor: f64,
}

impl BackendProfile {
    /// Effective cost parameters on a machine with base `machine` costs.
    pub fn cost(&self, machine: CostParams) -> CostParams {
        CostParams::new(machine.ts * self.ts_factor, machine.tw * self.tw_factor)
    }

    /// OpenMPI java bindings with the authors' Θ(log p) reduce patch —
    /// the backend used for all Carver results.
    pub const fn openmpi_fixed() -> Self {
        BackendProfile {
            name: "openmpi-fixed",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 1.0,
            tw_factor: 1.0,
        }
    }

    /// Unmodified OpenMPI java nightly: naive Θ(p) reduce.
    pub const fn openmpi_stock() -> Self {
        BackendProfile {
            name: "openmpi-stock",
            reduce: ReduceAlgo::Linear,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 1.0,
            tw_factor: 1.0,
        }
    }

    /// MPJ-Express: Θ(p) reduce + daemon-mode dispatch (start-up ~tens of
    /// µs) + java byte-serialization copies on the wire (§3.1's fallback
    /// serializer; §6 notes the "advantages of slower backends (like
    /// running in daemon mode)").
    pub const fn mpj_express() -> Self {
        BackendProfile {
            name: "mpj-express",
            reduce: ReduceAlgo::Linear,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 20.0,
            tw_factor: 4.0,
        }
    }

    /// FastMPJ: native-ish transport, tree collectives, some java overhead.
    pub const fn fastmpj() -> Self {
        BackendProfile {
            name: "fastmpj",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 2.0,
            tw_factor: 1.3,
        }
    }

    /// In-process shared memory (FooPar's SharedMemory module).
    pub const fn shmem() -> Self {
        BackendProfile {
            name: "shmem",
            reduce: ReduceAlgo::Binomial,
            bcast: BcastAlgo::Binomial,
            allgather: AllGatherAlgo::Ring,
            ts_factor: 0.1,
            tw_factor: 0.4,
        }
    }

    /// Look up a profile by name (CLI / config files).
    pub fn by_name(name: &str) -> Option<Self> {
        Some(match name {
            "openmpi-fixed" => Self::openmpi_fixed(),
            "openmpi-stock" => Self::openmpi_stock(),
            "mpj-express" => Self::mpj_express(),
            "fastmpj" => Self::fastmpj(),
            "shmem" => Self::shmem(),
            _ => return None,
        })
    }

    /// All built-in profiles (Fig. 5 right sweeps these).
    pub fn all() -> Vec<Self> {
        vec![
            Self::openmpi_fixed(),
            Self::openmpi_stock(),
            Self::mpj_express(),
            Self::fastmpj(),
        ]
    }
}

impl Default for BackendProfile {
    fn default() -> Self {
        Self::openmpi_fixed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookup_by_name_roundtrips() {
        for b in BackendProfile::all() {
            assert_eq!(BackendProfile::by_name(b.name).unwrap(), b);
        }
        assert!(BackendProfile::by_name("nope").is_none());
    }

    #[test]
    fn stock_is_linear_fixed_is_tree() {
        assert_eq!(BackendProfile::openmpi_stock().reduce, ReduceAlgo::Linear);
        assert_eq!(BackendProfile::openmpi_fixed().reduce, ReduceAlgo::Binomial);
    }

    #[test]
    fn cost_applies_factors() {
        let m = CostParams::new(1e-6, 1e-9);
        let c = BackendProfile::mpj_express().cost(m);
        assert!((c.ts - 20e-6).abs() < 1e-15);
        assert!((c.tw - 4e-9).abs() < 1e-15);
    }
}
